"""Scrape-and-validate for the ``/metrics`` endpoint (stdlib only).

CI starts ``launch/serve.py --metrics-port`` against the synthetic WOL,
then runs this check: poll the endpoint until it answers (``--wait``
bounds the poll — the launcher trains briefly before serving), parse the
Prometheus text exposition with a small stdlib parser, and fail unless

  * every line is well-formed (``# HELP``/``# TYPE`` comments, or
    ``name{labels} value`` samples with a parseable float value),
  * every sample's metric family has a ``# TYPE`` line (histogram
    samples match their family via the ``_bucket``/``_sum``/``_count``
    suffixes),
  * every ``--require`` name is present as a metric family (default:
    ``lss_audit_recall_at_k`` — the online recall auditor must be live,
    not just importable).

Usage::

    python tools/check_metrics.py --url http://127.0.0.1:9100/metrics \
        --wait 120 --require lss_audit_recall_at_k

Exit 0 on success, 1 on any violation (with the offending lines).
"""

from __future__ import annotations

import argparse
import re
import sys
import time
import urllib.error
import urllib.request

# one sample line: name, optional {labels}, a float value
SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?P<labels>\{[^}]*\})?"
    r" (?P<value>[^ ]+)$")
LABEL_RE = re.compile(r'^[a-zA-Z_][a-zA-Z0-9_]*="[^"]*"$')
TYPE_RE = re.compile(
    r"^# TYPE (?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*) "
    r"(?P<kind>counter|gauge|histogram|summary|untyped)$")
HELP_RE = re.compile(r"^# HELP [a-zA-Z_:][a-zA-Z0-9_:]* .*$")

HIST_SUFFIXES = ("_bucket", "_sum", "_count")


def _parse_value(raw: str) -> float:
    if raw in ("+Inf", "-Inf", "NaN"):
        return {"+Inf": float("inf"), "-Inf": float("-inf"),
                "NaN": float("nan")}[raw]
    return float(raw)


def parse_exposition(text: str) -> tuple[dict, list[str]]:
    """Parse Prometheus text format.  Returns ``(families, errors)``
    where ``families`` maps family name -> {"type": kind, "samples":
    [(name, labels_str, value)]}."""
    families: dict[str, dict] = {}
    errors: list[str] = []
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        m = TYPE_RE.match(line)
        if m:
            families.setdefault(m["name"], {"type": m["kind"],
                                            "samples": []})
            families[m["name"]]["type"] = m["kind"]
            continue
        if line.startswith("#"):
            if not HELP_RE.match(line):
                errors.append(f"line {lineno}: malformed comment: {line!r}")
            continue
        m = SAMPLE_RE.match(line)
        if m is None:
            errors.append(f"line {lineno}: malformed sample: {line!r}")
            continue
        try:
            value = _parse_value(m["value"])
        except ValueError:
            errors.append(f"line {lineno}: bad value in {line!r}")
            continue
        labels = m["labels"]
        if labels:
            inner = labels[1:-1]
            if inner and not all(LABEL_RE.match(p)
                                 for p in inner.split(",")):
                errors.append(f"line {lineno}: malformed labels: {line!r}")
                continue
        name = m["name"]
        fam = name
        if fam not in families:                  # histogram child sample?
            for suf in HIST_SUFFIXES:
                if name.endswith(suf) and name[:-len(suf)] in families:
                    fam = name[:-len(suf)]
                    break
        if fam not in families:
            errors.append(f"line {lineno}: sample {name!r} has no "
                          f"# TYPE line")
            continue
        families[fam]["samples"].append((name, labels or "", value))
    for fam, rec in families.items():
        if not rec["samples"]:
            errors.append(f"family {fam!r} has a # TYPE line but no "
                          f"samples")
    return families, errors


def fetch(url: str, wait_s: float, require: list[str]) -> str:
    """Poll ``url`` until it answers AND every required family is
    present (the launcher trains before serving; the auditor publishes
    once traffic flows), or ``wait_s`` elapses — then return the last
    body (validation reports what was missing)."""
    deadline = time.monotonic() + wait_s
    body, last_err = "", None
    while True:
        try:
            with urllib.request.urlopen(url, timeout=5.0) as r:
                body = r.read().decode()
            fams, _ = parse_exposition(body)
            if all(any(f == req or f.startswith(req) for f in fams)
                   for req in require):
                return body
            last_err = f"required families not yet present in {url}"
        except (urllib.error.URLError, ConnectionError, OSError) as e:
            last_err = str(e)
        if time.monotonic() >= deadline:
            if body:
                return body               # validate what we got
            print(f"FAIL: no scrape from {url} within {wait_s}s "
                  f"({last_err})", file=sys.stderr)
            sys.exit(1)
        time.sleep(1.0)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--url", default="http://127.0.0.1:9100/metrics")
    ap.add_argument("--wait", type=float, default=120.0,
                    help="seconds to poll for the endpoint + required "
                         "families before validating whatever arrived")
    ap.add_argument("--require", nargs="*",
                    default=["lss_audit_recall_at_k"],
                    help="metric families that must be present "
                         "(prefix match)")
    args = ap.parse_args()

    body = fetch(args.url, args.wait, args.require)
    families, errors = parse_exposition(body)
    for req in args.require:
        if not any(f == req or f.startswith(req) for f in families):
            errors.append(f"required metric family {req!r} not present")
    n_samples = sum(len(f["samples"]) for f in families.values())
    if errors:
        print(f"FAIL: {len(errors)} violation(s) in {args.url} "
              f"({len(families)} families, {n_samples} samples):",
              file=sys.stderr)
        for e in errors[:20]:
            print(f"  - {e}", file=sys.stderr)
        sys.exit(1)
    print(f"ok: {args.url} — {len(families)} families, "
          f"{n_samples} samples, required: {args.require}")


if __name__ == "__main__":
    main()
