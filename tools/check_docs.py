"""Docs consistency check (the CI docs job).

Two failure classes, both of which otherwise rot silently:

* **Broken intra-repo links** — every relative markdown link or inline
  path reference in ``docs/*.md`` + ``README.md`` must resolve to a
  real file in the repo.
* **Stale env-var names** — every ``REPRO_*`` variable mentioned in the
  docs must appear in ``src/``, and every ``REPRO_*`` variable defined
  in ``src/`` must appear in docs/KERNELS.md's authoritative table —
  so adding a knob without documenting it (or documenting a renamed
  one) fails CI instead of shipping stale docs.

Usage: ``python tools/check_docs.py`` (exit 1 on any failure; no deps
beyond the stdlib, so the docs job doesn't need jax installed).
"""

from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOC_FILES = sorted(ROOT.glob("docs/*.md")) + [ROOT / "README.md"]
KERNELS_DOC = ROOT / "docs" / "KERNELS.md"

# [text](target) — skip absolute URLs and pure anchors
_LINK = re.compile(r"\[[^\]]*\]\(([^)#][^)]*)\)")
# `path/to/file.py` style inline references (only ones with a slash and
# a real-file-looking suffix; prose like `serve/decode/` counts too)
_INLINE = re.compile(r"`([A-Za-z0-9_./-]+/[A-Za-z0-9_./-]*)`")
_ENV = re.compile(r"\bREPRO_[A-Z0-9_]+\b")


def check_links() -> list[str]:
    errors = []
    for doc in DOC_FILES:
        text = doc.read_text()
        targets = set(_LINK.findall(text))
        targets |= {m for m in _INLINE.findall(text)}
        for t in sorted(targets):
            t = t.split("#")[0].rstrip("/")
            if not t or t.startswith(("http://", "https://", "mailto:")):
                continue
            # resolve relative to the doc, the repo root, and the two
            # package shorthands the prose uses (`serve/engine.py` and
            # `repro/utils/compat.py` both mean src/repro/...)
            roots = (doc.parent / t, ROOT / t, ROOT / "src" / t,
                     ROOT / "src" / "repro" / t)
            if not any(p.exists() for p in roots):
                errors.append(f"{doc.relative_to(ROOT)}: broken link or "
                              f"stale path reference: {t}")
    return errors


def check_env_vars() -> list[str]:
    errors = []
    src_text = "\n".join(p.read_text()
                         for p in sorted(ROOT.glob("src/**/*.py")))
    src_vars = set(_ENV.findall(src_text))
    doc_vars: set[str] = set()
    for doc in DOC_FILES:
        for v in _ENV.findall(doc.read_text()):
            doc_vars.add(v)
            if v not in src_vars:
                errors.append(f"{doc.relative_to(ROOT)}: env var {v} is "
                              f"not defined anywhere in src/ (renamed or "
                              f"removed?)")
    kernels_vars = set(_ENV.findall(KERNELS_DOC.read_text()))
    for v in sorted(src_vars - kernels_vars):
        errors.append(f"src/ defines {v} but docs/KERNELS.md's env-var "
                      f"table does not mention it")
    return errors


def main() -> int:
    errors = check_links() + check_env_vars()
    for e in errors:
        print(f"DOCS CHECK FAILED: {e}", file=sys.stderr)
    if not errors:
        print(f"docs check ok: {len(DOC_FILES)} files, links + env vars "
              f"consistent")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
