"""Schema check for ``BENCH_kernels.json`` (the CI guard after the
kernels C-sweep).

The artifact mixes row kinds (per-kernel timings, the dedup C-sweep,
the slab_dtype storage sweep), so a field quietly dropped from one
producer would not fail any consumer — it would just vanish from the
record.  This check pins the per-kind required fields; in particular a
``slab_dtype`` row without its ``recall``/``recall_delta_vs_fp32``
fields fails CI, so storage compression can never silently stop
reporting its accuracy cost.

Usage: ``python tools/check_bench_schema.py [path]`` (default
``BENCH_kernels.json``; exit 1 on any violation; stdlib only).
"""

from __future__ import annotations

import json
import sys

# every row
BASE_FIELDS = ("kernel", "us_per_query", "shape")
# dedup C-sweep rows (identified by having a "dedup" field)
DEDUP_FIELDS = ("dedup", "c", "impl")
# slab_dtype sweep rows (identified by having a "slab_dtype" field)
SLAB_FIELDS = ("slab_dtype", "impl", "dma_bytes_per_query",
               "recall", "recall_delta_vs_fp32")

SLAB_DTYPES = {"fp32", "bf16", "int8"}


def check(rec: dict) -> list[str]:
    errors = []
    rows = rec.get("rows")
    if not isinstance(rows, list) or not rows:
        return ["artifact has no rows"]
    seen_slab: set[str] = set()
    for i, r in enumerate(rows):
        missing = [f for f in BASE_FIELDS if f not in r]
        if "dedup" in r:
            missing += [f for f in DEDUP_FIELDS if f not in r]
        if "slab_dtype" in r:
            missing += [f for f in SLAB_FIELDS if f not in r]
            seen_slab.add(r.get("slab_dtype"))
        if missing:
            errors.append(f"row {i} ({r.get('kernel')}): missing "
                          f"required fields {missing}")
    if seen_slab and seen_slab != SLAB_DTYPES:
        errors.append(f"slab_dtype sweep incomplete: got {sorted(seen_slab)}"
                      f", want {sorted(SLAB_DTYPES)} (a format was "
                      f"silently dropped)")
    if seen_slab:
        fp32 = [r for r in rows if r.get("slab_dtype") == "fp32"]
        if any(r["recall_delta_vs_fp32"] != 0 for r in fp32):
            errors.append("fp32 slab row has nonzero recall_delta_vs_fp32 "
                          "(the baseline drifted)")
    return errors


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_kernels.json"
    try:
        with open(path) as f:
            rec = json.load(f)
    except (OSError, ValueError) as e:
        print(f"SCHEMA CHECK FAILED: cannot read {path}: {e}",
              file=sys.stderr)
        return 1
    errors = check(rec)
    for e in errors:
        print(f"SCHEMA CHECK FAILED: {e}", file=sys.stderr)
    if not errors:
        n_slab = sum(1 for r in rec["rows"] if "slab_dtype" in r)
        print(f"schema ok: {len(rec['rows'])} rows "
              f"({n_slab} slab_dtype rows)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
