"""Schema check for the CI bench artifacts (``BENCH_kernels.json``,
``BENCH_decode.json`` and ``BENCH_obs.json``).

Both artifacts mix row kinds (per-kernel timings, the dedup C-sweep, the
slab_dtype storage sweep; decode sweep points and the paged-KV capacity
rows), so a field quietly dropped from one producer would not fail any
consumer — it would just vanish from the record.  This check pins the
per-kind required fields; in particular a ``slab_dtype`` row without its
``recall``/``recall_delta_vs_fp32`` fields fails CI (storage compression
can never silently stop reporting its accuracy cost), and a decode
artifact missing any of the three capacity kinds — ``sessions_per_gb``,
``long_context``, ``prefix_cache`` — fails CI (the paged-KV memory story
can never silently drop out of the bench).  The obs artifact must carry
both an ``overhead`` row (obs-on vs no-op throughput/p99) and an
``audit_recall`` row whose online recall agrees with the offline brute
force within ``OBS_AUDIT_TOL``.  The multihost artifact
(``BENCH_multihost.json``) must keep its 1- and 2-process qps_scaling
rows, a capacity row, and a summary row recording the equal-total-m
1->2 aggregate-QPS ratio — which must reach ``MULTIHOST_MIN_RATIO``
whenever the machine had >= 2 CPUs (on one core two processes
timeshare and the ratio is physically meaningless, so it is recorded
but not gated).  The refresh artifact (``BENCH_refresh.json``) must
keep all three of its ``swap_latency`` / ``recall_staleness`` /
``rollback`` rows; the swap row is gated on zero failed requests,
bit-exactness vs a cold-built engine, and (with >= 2 CPUs and enough
in-window samples) a during-swap p99 no worse than
``REFRESH_MAX_P99_RATIO`` x steady; the rollback row must actually
have rolled back, at least once, inside its probation window.

Usage: ``python tools/check_bench_schema.py [path]`` (default
``BENCH_kernels.json``; the artifact's own ``bench`` field selects the
schema; exit 1 on any violation; stdlib only).
"""

from __future__ import annotations

import json
import sys

# ----------------------------------------------------- kernels schema --
# every row
BASE_FIELDS = ("kernel", "us_per_query", "shape")
# dedup C-sweep rows (identified by having a "dedup" field)
DEDUP_FIELDS = ("dedup", "c", "impl")
# slab_dtype sweep rows (identified by having a "slab_dtype" field)
SLAB_FIELDS = ("slab_dtype", "impl", "dma_bytes_per_query",
               "recall", "recall_delta_vs_fp32")

SLAB_DTYPES = {"fp32", "bf16", "int8"}

# ------------------------------------------------------ decode schema --
DECODE_SWEEP_FIELDS = (
    "head", "streams", "qps", "prompt_len", "max_new_tokens", "kv_layout",
    "tokens_per_s", "ttft_p50_ms", "ttft_p95_ms", "itl_p50_ms",
    "blocking_tok_s", "speedup_vs_blocking")
DECODE_CAPACITY_FIELDS = {
    "sessions_per_gb": (
        "kv_layout", "page_tokens", "prompt_lens", "peak_pages",
        "paged_bytes_per_session", "dense_bytes_per_session",
        "sessions_per_gb", "sessions_per_gb_dense",
        "sessions_per_gb_ratio"),
    "long_context": (
        "kv_layout", "page_tokens", "prompt_len", "n_pages", "peak_pages",
        "arena_bytes", "dense_equal_mem_max_len",
        "fits_dense_at_equal_memory"),
    "prefix_cache": (
        "kv_layout", "page_tokens", "prompt_len", "n_sessions",
        "n_prefill_skipped", "prefix_hit_rate", "n_prefill_compiles",
        "n_prefill_buckets"),
}


def check_kernels(rec: dict) -> list[str]:
    errors = []
    rows = rec.get("rows")
    if not isinstance(rows, list) or not rows:
        return ["artifact has no rows"]
    seen_slab: set[str] = set()
    for i, r in enumerate(rows):
        missing = [f for f in BASE_FIELDS if f not in r]
        if "dedup" in r:
            missing += [f for f in DEDUP_FIELDS if f not in r]
        if "slab_dtype" in r:
            missing += [f for f in SLAB_FIELDS if f not in r]
            seen_slab.add(r.get("slab_dtype"))
        if missing:
            errors.append(f"row {i} ({r.get('kernel')}): missing "
                          f"required fields {missing}")
    if seen_slab and seen_slab != SLAB_DTYPES:
        errors.append(f"slab_dtype sweep incomplete: got {sorted(seen_slab)}"
                      f", want {sorted(SLAB_DTYPES)} (a format was "
                      f"silently dropped)")
    if seen_slab:
        fp32 = [r for r in rows if r.get("slab_dtype") == "fp32"]
        if any(r["recall_delta_vs_fp32"] != 0 for r in fp32):
            errors.append("fp32 slab row has nonzero recall_delta_vs_fp32 "
                          "(the baseline drifted)")
    return errors


def check_decode(rec: dict) -> list[str]:
    errors = []
    rows = rec.get("rows")
    if not isinstance(rows, list) or not rows:
        return ["artifact has no rows"]
    seen_kinds: set[str] = set()
    for i, r in enumerate(rows):
        kind = r.get("kind", "sweep")     # pre-paged artifacts: all sweep
        seen_kinds.add(kind)
        if kind == "sweep":
            required = DECODE_SWEEP_FIELDS
        elif kind in DECODE_CAPACITY_FIELDS:
            required = DECODE_CAPACITY_FIELDS[kind]
        else:
            errors.append(f"row {i}: unknown decode row kind {kind!r}")
            continue
        missing = [f for f in required if f not in r]
        if missing:
            errors.append(f"row {i} (kind={kind}): missing required "
                          f"fields {missing}")
    for kind in DECODE_CAPACITY_FIELDS:
        if kind not in seen_kinds:
            errors.append(f"decode artifact has no {kind!r} row (a "
                          f"capacity row was silently dropped)")
    spg = [r for r in rows if r.get("kind") == "sessions_per_gb"]
    if any(r.get("sessions_per_gb_ratio", 0) < 1.0 for r in spg):
        errors.append("sessions_per_gb_ratio < 1: paged layout is WORSE "
                      "than dense per-slot reservation")
    return errors


# --------------------------------------------------- multihost schema --
MULTIHOST_QPS_FIELDS = (
    "processes", "local_devices", "n_shards", "total_m", "per_host_m",
    "batch", "iters", "qps", "us_per_query")
MULTIHOST_CAP_FIELDS = (
    "processes", "budget_gb_per_host", "index_bytes_per_host",
    "bytes_per_row", "max_m_total")
MULTIHOST_SUMMARY_FIELDS = ("qps_ratio_1_to_2", "total_m", "per_host_m",
                            "n_cpus")
MULTIHOST_MIN_RATIO = 1.7


def check_multihost(rec: dict) -> list[str]:
    errors = []
    rows = rec.get("rows")
    if not isinstance(rows, list) or not rows:
        return ["artifact has no rows"]
    seen_kinds: set[str] = set()
    qps_procs: set[int] = set()
    for i, r in enumerate(rows):
        kind = r.get("kind")
        seen_kinds.add(kind)
        if kind == "qps_scaling":
            required = MULTIHOST_QPS_FIELDS
            qps_procs.add(r.get("processes"))
        elif kind == "capacity":
            required = MULTIHOST_CAP_FIELDS
        elif kind == "summary":
            required = MULTIHOST_SUMMARY_FIELDS
        else:
            errors.append(f"row {i}: unknown multihost row kind {kind!r}")
            continue
        missing = [f for f in required if f not in r]
        if missing:
            errors.append(f"row {i} (kind={kind}): missing required "
                          f"fields {missing}")
    for kind in ("qps_scaling", "capacity", "summary"):
        if kind not in seen_kinds:
            errors.append(f"multihost artifact has no {kind!r} row (a "
                          f"scaling row was silently dropped)")
    if "qps_scaling" in seen_kinds and not {1, 2} <= qps_procs:
        errors.append(f"qps_scaling rows cover processes "
                      f"{sorted(qps_procs)}; the 1- and 2-process points "
                      f"are both required (the scaling story can never "
                      f"silently drop a fleet size)")
    for r in rows:
        if r.get("kind") != "summary":
            continue
        ratio = r.get("qps_ratio_1_to_2")
        if not isinstance(ratio, (int, float)):
            errors.append("summary row: qps_ratio_1_to_2 is not recorded "
                          "as a number")
        elif r.get("n_cpus", 0) >= 2 and ratio < MULTIHOST_MIN_RATIO:
            errors.append(
                f"summary row: equal-total-m qps ratio 1->2 processes is "
                f"{ratio:.2f} < {MULTIHOST_MIN_RATIO} on "
                f"{r.get('n_cpus')} cpus — splitting the vocab across "
                f"two hosts is not paying for itself")
    return errors


# ----------------------------------------------------- refresh schema --
REFRESH_SWAP_FIELDS = (
    "head", "m", "qps", "n_requests", "n_swaps", "p99_steady_ms",
    "p99_swap_ms", "p99_swap_ratio", "swap_window_n", "n_failed",
    "n_shed", "exact_after_swaps", "n_cpus")
REFRESH_STALENESS_FIELDS = (
    "n_cycles", "n_calib", "recall_stale", "recall_refreshed",
    "recall_offline_refit", "gap_to_offline")
REFRESH_ROLLBACK_FIELDS = (
    "outcome", "rollback_total", "time_to_rollback_s", "probation_s",
    "min_audit_rows", "rollback_delta")
# during-swap p99 may not exceed steady p99 by more than this factor
# (gated only with >= 2 CPUs and a meaningful in-window sample — on one
# core the warming trace timeshares with serving and the ratio measures
# the box, not the swap)
REFRESH_MAX_P99_RATIO = 3.0
REFRESH_MIN_WINDOW_N = 20


def check_refresh(rec: dict) -> list[str]:
    errors = []
    rows = rec.get("rows")
    if not isinstance(rows, list) or not rows:
        return ["artifact has no rows"]
    seen_kinds: set[str] = set()
    for i, r in enumerate(rows):
        kind = r.get("kind")
        seen_kinds.add(kind)
        if kind == "swap_latency":
            required = REFRESH_SWAP_FIELDS
        elif kind == "recall_staleness":
            required = REFRESH_STALENESS_FIELDS
        elif kind == "rollback":
            required = REFRESH_ROLLBACK_FIELDS
        else:
            errors.append(f"row {i}: unknown refresh row kind {kind!r}")
            continue
        missing = [f for f in required if f not in r]
        if missing:
            errors.append(f"row {i} (kind={kind}): missing required "
                          f"fields {missing}")
    for kind in ("swap_latency", "recall_staleness", "rollback"):
        if kind not in seen_kinds:
            errors.append(f"refresh artifact has no {kind!r} row (the "
                          f"{kind} story was silently dropped)")
    for r in rows:
        kind = r.get("kind")
        if kind == "swap_latency":
            if r.get("n_failed", 1) != 0:
                errors.append(
                    f"swap_latency row: {r.get('n_failed')} requests "
                    f"failed under swap load — a swap may never fail a "
                    f"request")
            if r.get("exact_after_swaps") is not True:
                errors.append(
                    "swap_latency row: post-swap results diverged from a "
                    "cold-built engine on the same index")
            ratio = r.get("p99_swap_ratio")
            if not isinstance(ratio, (int, float)):
                errors.append("swap_latency row: p99_swap_ratio is not "
                              "recorded as a number")
            elif (r.get("n_cpus", 0) >= 2
                  and r.get("swap_window_n", 0) >= REFRESH_MIN_WINDOW_N
                  and ratio > REFRESH_MAX_P99_RATIO):
                errors.append(
                    f"swap_latency row: p99 during swap is {ratio:.2f}x "
                    f"steady (> {REFRESH_MAX_P99_RATIO}) on "
                    f"{r.get('n_cpus')} cpus — the swap is not "
                    f"zero-downtime")
        elif kind == "rollback":
            if r.get("outcome") != "rolled_back":
                errors.append(
                    f"rollback row: outcome is {r.get('outcome')!r}, not "
                    f"'rolled_back' — the injected recall regression "
                    f"survived probation")
            if r.get("rollback_total", 0) < 1:
                errors.append("rollback row: rollback_total < 1 (the "
                              "rollback drill silently stopped rolling "
                              "back)")
            ttr = r.get("time_to_rollback_s")
            prob = r.get("probation_s")
            if (isinstance(ttr, (int, float))
                    and isinstance(prob, (int, float)) and ttr > prob):
                errors.append(
                    f"rollback row: rollback took {ttr:.2f}s, past the "
                    f"{prob}s probation window")
    return errors


# --------------------------------------------------------- obs schema --
OBS_OVERHEAD_FIELDS = (
    "rps_on", "rps_off", "overhead_pct", "p99_on_ms", "p99_off_ms",
    "audit_rate", "n_requests")
OBS_AUDIT_FIELDS = (
    "recall_online", "recall_offline", "recall_delta", "n_rows",
    "top_k", "audit_rate")
OBS_AUDIT_TOL = 1e-3


def check_obs(rec: dict) -> list[str]:
    errors = []
    rows = rec.get("rows")
    if not isinstance(rows, list) or not rows:
        return ["artifact has no rows"]
    seen_kinds: set[str] = set()
    for i, r in enumerate(rows):
        kind = r.get("kind")
        seen_kinds.add(kind)
        if kind == "overhead":
            required = OBS_OVERHEAD_FIELDS
        elif kind == "audit_recall":
            required = OBS_AUDIT_FIELDS
        else:
            errors.append(f"row {i}: unknown obs row kind {kind!r}")
            continue
        missing = [f for f in required if f not in r]
        if missing:
            errors.append(f"row {i} (kind={kind}): missing required "
                          f"fields {missing}")
    for kind in ("overhead", "audit_recall"):
        if kind not in seen_kinds:
            errors.append(f"obs artifact has no {kind!r} row (the "
                          f"{kind} story was silently dropped)")
    for r in rows:
        if r.get("kind") != "audit_recall":
            continue
        delta = abs(r.get("recall_online", 0.0)
                    - r.get("recall_offline", 1.0))
        if delta > OBS_AUDIT_TOL:
            errors.append(
                f"audit_recall row: online recall "
                f"{r.get('recall_online')} disagrees with offline "
                f"brute force {r.get('recall_offline')} by {delta:.2e} "
                f"(> {OBS_AUDIT_TOL}) — the auditor is lying")
    return errors


def check(rec: dict) -> list[str]:
    if rec.get("bench") == "decode":
        return check_decode(rec)
    if rec.get("bench") == "obs":
        return check_obs(rec)
    if rec.get("bench") == "multihost":
        return check_multihost(rec)
    if rec.get("bench") == "refresh":
        return check_refresh(rec)
    return check_kernels(rec)


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_kernels.json"
    try:
        with open(path) as f:
            rec = json.load(f)
    except (OSError, ValueError) as e:
        print(f"SCHEMA CHECK FAILED: cannot read {path}: {e}",
              file=sys.stderr)
        return 1
    errors = check(rec)
    for e in errors:
        print(f"SCHEMA CHECK FAILED: {e}", file=sys.stderr)
    if not errors:
        if rec.get("bench") == "obs":
            oh = next(r for r in rec["rows"] if r["kind"] == "overhead")
            print(f"schema ok: {len(rec['rows'])} obs rows (overhead "
                  f"{oh['overhead_pct']:.2f}%)")
        elif rec.get("bench") == "multihost":
            s = next(r for r in rec["rows"] if r["kind"] == "summary")
            print(f"schema ok: {len(rec['rows'])} multihost rows "
                  f"(1->2 qps ratio {s['qps_ratio_1_to_2']:.2f} on "
                  f"{s['n_cpus']} cpus)")
        elif rec.get("bench") == "refresh":
            sw = next(r for r in rec["rows"]
                      if r["kind"] == "swap_latency")
            rb = next(r for r in rec["rows"] if r["kind"] == "rollback")
            print(f"schema ok: {len(rec['rows'])} refresh rows (p99 "
                  f"swap ratio {sw['p99_swap_ratio']:.2f} over "
                  f"{sw['n_swaps']} swaps, 0 failed, rollback in "
                  f"{rb['time_to_rollback_s']:.2f}s)")
        elif rec.get("bench") == "decode":
            kinds = [r.get("kind", "sweep") for r in rec["rows"]]
            print(f"schema ok: {len(rec['rows'])} decode rows "
                  f"({sum(k == 'sweep' for k in kinds)} sweep, "
                  f"{sum(k != 'sweep' for k in kinds)} capacity)")
        else:
            n_slab = sum(1 for r in rec["rows"] if "slab_dtype" in r)
            print(f"schema ok: {len(rec['rows'])} rows "
                  f"({n_slab} slab_dtype rows)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
