"""Deterministic fault-injection harness.

Production code declares *hook points* — named places where a failure
mode is worth rehearsing — by calling :func:`fire`.  Unarmed, a hook
point is one dict lookup (no locks, no logging, no jax); tests arm a
point with an *action* and the next ``fire`` executes it:

  * an ``Exception`` instance or class  -> raised at the hook point
    (fail-the-refit, leader-crash-during-OP_SWAP),
  * a ``float``/``int``                 -> ``time.sleep`` that long
    (slow-the-refit),
  * a callable ``fn(ctx: dict)``        -> run with the hook's context;
    it may raise, sleep, or MUTATE ``ctx`` to override values the
    caller reads back (corrupt-recall overrides ``ctx["recall"]``).

Actions are consumed deterministically: ``arm`` leaves the action in
place until :func:`disarm`/:func:`reset`; ``arm(..., times=n)`` auto
disarms after n fires.  ``fire_count`` exposes how often a point
fired while the harness was active (any point armed) so tests can
assert a path was actually taken.

The canonical points (names are plain strings; constants below keep
tests and docs honest):

  ``refresh.refit``          before the background refit computes
  ``refresh.built``          after the candidate index is built
  ``refresh.probation``      each probation poll (ctx: recall, rows)
  ``engine.swap``            inside the swap critical section
  ``multihost.swap_commit``  between the OP_SWAP_INDEX payload and the
                             commit flag broadcast (leader crash window)
"""

from __future__ import annotations

import contextlib
import threading
import time
from typing import Any

__all__ = ["arm", "disarm", "reset", "fire", "armed", "fire_count",
           "injected", "REFRESH_REFIT", "REFRESH_BUILT",
           "REFRESH_PROBATION", "ENGINE_SWAP", "MULTIHOST_SWAP_COMMIT"]

REFRESH_REFIT = "refresh.refit"
REFRESH_BUILT = "refresh.built"
REFRESH_PROBATION = "refresh.probation"
ENGINE_SWAP = "engine.swap"
MULTIHOST_SWAP_COMMIT = "multihost.swap_commit"

_mu = threading.Lock()
_armed: dict[str, tuple[Any, int | None]] = {}   # point -> (action, left)
_counts: dict[str, int] = {}


def arm(point: str, action, *, times: int | None = None) -> None:
    """Arm ``point`` with ``action`` (exception | seconds | callable).
    ``times`` bounds how many fires consume it (None = until disarm)."""
    with _mu:
        _armed[point] = (action, times)


def disarm(point: str) -> None:
    with _mu:
        _armed.pop(point, None)


def reset() -> None:
    """Disarm every point and zero the fire counters (test teardown)."""
    with _mu:
        _armed.clear()
        _counts.clear()


def armed(point: str) -> bool:
    with _mu:
        return point in _armed


def fire_count(point: str) -> int:
    with _mu:
        return _counts.get(point, 0)


@contextlib.contextmanager
def injected(point: str, action, *, times: int | None = None):
    """Scope an armed action to a ``with`` block (always disarms)."""
    arm(point, action, times=times)
    try:
        yield
    finally:
        disarm(point)


def fire(point: str, **ctx) -> dict:
    """Execute ``point``'s armed action (if any) and return the context
    dict — possibly mutated by a callable action.  Never blocks or
    raises unless a test armed it to."""
    if not _armed:                       # production fast path: one read
        return ctx
    with _mu:
        _counts[point] = _counts.get(point, 0) + 1
        entry = _armed.get(point)
        if entry is None:
            return ctx
        action, left = entry
        if left is not None:
            left -= 1
            if left <= 0:
                del _armed[point]
            else:
                _armed[point] = (action, left)
    if isinstance(action, BaseException) or (
            isinstance(action, type) and issubclass(action, BaseException)):
        raise action
    if isinstance(action, (int, float)):
        time.sleep(float(action))
        return ctx
    action(ctx)
    return ctx
