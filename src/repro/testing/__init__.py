"""Test-support utilities that production code may import.

``repro.testing.faults`` is the deterministic fault-injection harness:
serving code declares named hook points (``faults.fire``) that are
no-ops in production and become failures / delays / value overrides
when a test arms them.  Nothing in this package depends on jax.
"""

from repro.testing import faults

__all__ = ["faults"]
