"""Decoder-only LM covering the five assigned architectures.

One config class spans dense (qwen2-0.5b/7b, qwen3-4b) and MoE
(qwen2-moe-a2.7b: shared+routed top-4; arctic-480b: dense-residual ∥ 128e
top-2).  Layers are scanned (`jax.lax.scan`) so HLO size and compile time
are O(1) in depth, and remat policy applies per-layer.

Entry points:
  * ``lm_loss(params, batch, cfg)``     — training loss (blockwise attn).
  * ``prefill(params, tokens, cfg, max_len)`` — build a KV cache.
  * ``decode_step(params, token, cache, cfg)`` — one token; returns the
    final-norm hidden state so the serving engine can apply either the
    full vocab head or the LSS head (the paper's technique).
  * ``decode_step_pooled(params, token, k, v, lengths, cfg)`` — one token
    per POOL SLOT with per-row cache lengths (continuous batching; see
    ``repro.serve.decode``).
  * ``param_specs(cfg)`` / ``cache_specs(cfg, policy)`` — PartitionSpecs.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P  # noqa: F401

from repro.models import layers as L
from repro.models.moe import MoEConfig, init_moe_params, moe_ffn
from repro.utils.sharding import maybe_shard, mesh_axis_size


class TransformerConfig(NamedTuple):
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_base: float = 1e6
    tie_embeddings: bool = False
    # MoE: style "none" | "replace" (FFN -> MoE) | "parallel" (dense + MoE)
    moe_style: str = "none"
    n_experts: int = 0
    n_experts_padded: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    shared_expert_ff: int = 0     # qwen2-moe shared expert hidden size
    capacity_factor: float = 1.25
    # FSDP-shard expert d_ff over 'data' — required only when expert
    # params exceed what the model axis alone can hold (arctic-480b).
    # Costs a per-layer weight all-gather; see EXPERIMENTS.md §Perf.
    moe_fsdp: bool = False
    moe_groups: int = 1           # GShard dispatch groups (= data shards)
    dtype: Any = jnp.bfloat16
    remat: bool = True
    kv_chunk: int = 512
    q_chunk: int = 2048    # long-prefill query chunking
    # "scan": O(1) HLO in depth (production). "unroll": Python loop —
    # used by the dry-run because XLA cost_analysis counts a scan body
    # ONCE (trip count ignored), which would poison the roofline.
    layers_impl: str = "scan"

    @property
    def moe_cfg(self) -> MoEConfig | None:
        if self.moe_style == "none":
            return None
        return MoEConfig(self.n_experts, self.moe_top_k, self.d_model,
                         self.moe_d_ff, self.n_experts_padded,
                         self.capacity_factor, n_groups=self.moe_groups)

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS cross-checks)."""
        d, f = self.d_model, self.d_ff
        nq = self.n_heads * self.head_dim
        nkv = self.n_kv_heads * self.head_dim
        attn = d * nq + 2 * d * nkv + nq * d
        if self.qkv_bias:
            attn += nq + 2 * nkv
        dense_ffn = 3 * d * f if self.moe_style in ("none", "parallel") else 0
        moe = 0
        if self.moe_style != "none":
            moe = self.n_experts * 3 * d * self.moe_d_ff + d * self.n_experts
        shared = 3 * d * self.shared_expert_ff + d if self.shared_expert_ff else 0
        if self.moe_style == "replace":
            dense_ffn = 0
        per_layer = attn + dense_ffn + moe + shared + 2 * d
        head = 0 if self.tie_embeddings else self.vocab * d
        return self.n_layers * per_layer + self.vocab * d + head + d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if self.moe_style == "none":
            return self.param_count()
        full = self.param_count()
        inactive = (self.n_experts - self.moe_top_k) * 3 * self.d_model \
            * self.moe_d_ff * self.n_layers
        return full - inactive


# ------------------------------------------------------------------ init --

def init_params(key: jax.Array, cfg: TransformerConfig) -> dict:
    dt = cfg.dtype
    d, f = cfg.d_model, cfg.d_ff
    nq = cfg.n_heads * cfg.head_dim
    nkv = cfg.n_kv_heads * cfg.head_dim
    keys = jax.random.split(key, 16)
    s = d ** -0.5

    def nrm(k, shape, scale):
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dt)

    lyr = {
        "ln1": jnp.ones((cfg.n_layers, d), jnp.float32),
        "ln2": jnp.ones((cfg.n_layers, d), jnp.float32),
        "wq": nrm(keys[0], (cfg.n_layers, d, nq), s),
        "wk": nrm(keys[1], (cfg.n_layers, d, nkv), s),
        "wv": nrm(keys[2], (cfg.n_layers, d, nkv), s),
        "wo": nrm(keys[3], (cfg.n_layers, nq, d), nq ** -0.5),
    }
    if cfg.qkv_bias:
        lyr["bq"] = jnp.zeros((cfg.n_layers, nq), dt)
        lyr["bk"] = jnp.zeros((cfg.n_layers, nkv), dt)
        lyr["bv"] = jnp.zeros((cfg.n_layers, nkv), dt)
    if cfg.qk_norm:
        lyr["q_norm"] = jnp.ones((cfg.n_layers, cfg.head_dim), jnp.float32)
        lyr["k_norm"] = jnp.ones((cfg.n_layers, cfg.head_dim), jnp.float32)
    if cfg.moe_style in ("none", "parallel"):
        lyr["w_gate"] = nrm(keys[4], (cfg.n_layers, d, f), s)
        lyr["w_up"] = nrm(keys[5], (cfg.n_layers, d, f), s)
        lyr["w_down"] = nrm(keys[6], (cfg.n_layers, f, d), f ** -0.5)
    if cfg.moe_style != "none":
        moe_keys = jax.random.split(keys[7], cfg.n_layers)
        stacked = jax.vmap(lambda k: init_moe_params(k, cfg.moe_cfg, dt))(
            moe_keys)
        lyr["moe"] = stacked
    if cfg.shared_expert_ff:
        sf = cfg.shared_expert_ff
        lyr["sh_gate"] = nrm(keys[8], (cfg.n_layers, d, sf), s)
        lyr["sh_up"] = nrm(keys[9], (cfg.n_layers, d, sf), s)
        lyr["sh_down"] = nrm(keys[10], (cfg.n_layers, sf, d), sf ** -0.5)
        lyr["sh_gate_w"] = nrm(keys[11], (cfg.n_layers, d, 1), s)

    params = {
        "embed": nrm(keys[12], (cfg.vocab, d), 1.0),
        "layers": lyr,
        "final_norm": jnp.ones((d,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = nrm(keys[13], (cfg.vocab, d), s)
    return params


def param_specs(cfg: TransformerConfig) -> dict:
    """NamedSharding PartitionSpecs (mesh axes: data, model [, pod]).

    Conventions: vocab & d_ff & experts shard over ``model``; the large
    MoE expert tensors additionally FSDP-shard d_ff over ``data`` (arctic
    would not fit otherwise); attention heads shard over ``model`` (GSPMD
    pads non-divisible head counts — waste is reported by the roofline).
    """
    lyr = {
        "ln1": P(None, None), "ln2": P(None, None),
        "wq": P(None, None, "model"),
        "wk": P(None, None, "model"),
        "wv": P(None, None, "model"),
        "wo": P(None, "model", None),
    }
    if cfg.qkv_bias:
        lyr["bq"] = P(None, "model")
        lyr["bk"] = P(None, "model")
        lyr["bv"] = P(None, "model")
    if cfg.qk_norm:
        lyr["q_norm"] = P(None, None)
        lyr["k_norm"] = P(None, None)
    if cfg.moe_style in ("none", "parallel"):
        lyr["w_gate"] = P(None, None, "model")
        lyr["w_up"] = P(None, None, "model")
        lyr["w_down"] = P(None, "model", None)
    if cfg.moe_style != "none":
        fs = "data" if cfg.moe_fsdp else None
        lyr["moe"] = {
            "router": P(None, None, None),
            "w_gate": P(None, "model", None, fs),
            "w_up": P(None, "model", None, fs),
            "w_down": P(None, "model", fs, None),
        }
    if cfg.shared_expert_ff:
        lyr["sh_gate"] = P(None, None, "model")
        lyr["sh_up"] = P(None, None, "model")
        lyr["sh_down"] = P(None, "model", None)
        lyr["sh_gate_w"] = P(None, None, None)
    specs = {
        "embed": P("model", None),
        "layers": lyr,
        "final_norm": P(None),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = P("model", None)
    return specs


# -------------------------------------------------------------- forward ---

def _attn_block(x, lp, cfg: TransformerConfig, positions, mode,
                cache=None, kv_len=None):
    """Shared attention block. mode: train | prefill | decode."""
    b, s, d = x.shape
    h = L.rms_norm(x, lp["ln1"])
    q = jnp.einsum("bsd,dn->bsn", h, lp["wq"])
    k = jnp.einsum("bsd,dn->bsn", h, lp["wk"])
    v = jnp.einsum("bsd,dn->bsn", h, lp["wv"])
    if cfg.qkv_bias:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = q.reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    if mode == "decode":
        # Decode moves ONE token: per-token activations are KBs while the
        # packed [*, n*h] -> [*, n, h] reshape straddles the model-axis
        # shard boundary when heads don't divide TP (qwen2-7b: 224
        # cols/shard vs h=128), triggering GSPMD "involuntary full
        # rematerialization" (26 GB/dev of gathers at decode_32k).
        # Replicating the tiny q/k/v fixes that; but when heads DO divide
        # TP (qwen2-moe: 16H/16KV) head-sharded attention is already
        # optimal and forcing replication regresses 1.4x — so the
        # constraint is alignment-conditional.  §Perf hillclimb 2.
        tp = mesh_axis_size("model")
        if tp and (cfg.n_heads % tp or cfg.n_kv_heads % tp):
            q = maybe_shard(q, P("data", None, None, None))
            k = maybe_shard(k, P("data", None, None, None))
            v = maybe_shard(v, P("data", None, None, None))
    if cfg.qk_norm:
        q = L.rms_norm(q, lp["q_norm"])
        k = L.rms_norm(k, lp["k_norm"])
    q = L.apply_rope(q, positions, cfg.rope_base)
    k = L.apply_rope(k, positions, cfg.rope_base)

    if mode == "decode":
        pos = kv_len - 1                           # write slot (traced)
        k_cache = _write_cache(cache[0], k, pos)
        v_cache = _write_cache(cache[1], v, pos)
        out = L.attention_decode(q, k_cache, v_cache, kv_len)
        new_cache = (k_cache, v_cache)
    else:
        out = L.attention_blockwise(q, k, v, causal=True,
                                    kv_chunk=cfg.kv_chunk,
                                    q_chunk=cfg.q_chunk)
        new_cache = (k, v) if mode == "prefill" else None
    out = out.reshape(b, s, cfg.n_heads * cfg.head_dim)
    return x + jnp.einsum("bsn,nd->bsd", out, lp["wo"]), new_cache


def _write_cache(cache: jax.Array, kv: jax.Array, pos: jax.Array) -> jax.Array:
    """Write the [B, 1, KV, H] step into cache[:, pos] (traced pos, scalar
    or [B] for per-row write positions under continuous batching)."""
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        onehot = (jnp.arange(cache.shape[1]) == pos)[None, :, None, None]
    else:
        onehot = (jnp.arange(cache.shape[1])[None, :]
                  == pos[:, None])[:, :, None, None]
    return jnp.where(onehot, kv.astype(cache.dtype), cache)


def _ffn_block(x, lp, cfg: TransformerConfig):
    b, s, d = x.shape
    h = L.rms_norm(x, lp["ln2"])
    aux = jnp.zeros((), jnp.float32)
    out = jnp.zeros_like(h)
    if cfg.moe_style in ("none", "parallel"):
        out = out + L.swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"])
    if cfg.moe_style != "none":
        flat = h.reshape(b * s, d)
        moe_out, aux = moe_ffn(flat, lp["moe"], cfg.moe_cfg)
        out = out + moe_out.reshape(b, s, d)
    if cfg.shared_expert_ff:
        gate = jax.nn.sigmoid(
            jnp.einsum("bsd,dz->bsz", h, lp["sh_gate_w"]).astype(jnp.float32))
        sh = L.swiglu(h, lp["sh_gate"], lp["sh_up"], lp["sh_down"])
        out = out + (sh * gate.astype(sh.dtype))
    return x + out, aux


def _layer(x, lp, cfg, positions, mode, cache=None, kv_len=None):
    x, new_cache = _attn_block(x, lp, cfg, positions, mode, cache, kv_len)
    x, aux = _ffn_block(x, lp, cfg)
    return x, new_cache, aux


def _scan_layers(params, x, cfg: TransformerConfig, positions, mode):
    """Run the layer stack (train/prefill). Returns (x, caches, aux)."""
    fn = _layer
    if cfg.remat and mode == "train":
        fn = jax.checkpoint(_layer, static_argnums=(2, 4))

    if cfg.layers_impl == "unroll":
        aux = jnp.zeros((), jnp.float32)
        caches = []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, cache_i, aux_i = fn(x, lp, cfg, positions, mode)
            aux = aux + aux_i
            caches.append(cache_i)
        if mode == "prefill":
            caches = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
        else:
            caches = None
        return x, caches, aux

    def body(carry, lp):
        h, aux_tot = carry
        h, new_cache, aux = fn(h, lp, cfg, positions, mode)
        return (h, aux_tot + aux), new_cache

    (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                    params["layers"])
    return x, caches, aux


def forward(params: dict, tokens: jax.Array, cfg: TransformerConfig,
            mode: str = "train"):
    """tokens [B, S] -> (hidden [B, S, D] after final norm, caches, aux)."""
    x = params["embed"][tokens].astype(cfg.dtype)
    positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)
    x = maybe_shard(x, P("data", None, None))
    x, caches, aux = _scan_layers(params, x, cfg, positions, mode)
    return L.rms_norm(x, params["final_norm"]), caches, aux


def logits_head(params: dict, hidden: jax.Array,
                cfg: TransformerConfig) -> jax.Array:
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bsd,vd->bsv", hidden, head).astype(jnp.float32)


def gold_logit(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """label-logit extraction that stays sharded.

    ``take_along_axis`` over a vocab-sharded axis makes GSPMD all-gather
    the full [B, S, V] logits (measured: 33 GB/device on qwen2-0.5b).
    The iota-mask sum partitions cleanly: each shard contributes its local
    slice, combined by one tiny [B, S] all-reduce.
    """
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                    logits.ndim - 1)
    sel = jnp.where(iota == labels[..., None], logits, 0)
    return sel.sum(-1)


def lm_loss(params: dict, batch: dict, cfg: TransformerConfig) -> jax.Array:
    """batch: tokens [B, S] int32, labels [B, S] (-100 = masked)."""
    hidden, _, aux = forward(params, batch["tokens"], cfg, mode="train")
    logits = logits_head(params, hidden, cfg)
    labels = batch["labels"]
    mask = labels >= 0
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = gold_logit(logits, jnp.maximum(labels, 0))
    nll = (logz - gold) * mask
    loss = nll.sum() / jnp.maximum(mask.sum(), 1)
    return loss + 0.01 * aux


# ---------------------------------------------------------------- serving --

class KVCache(NamedTuple):
    k: jax.Array       # [n_layers, B, S_max, KV, H]
    v: jax.Array
    length: jax.Array  # int32 [] — valid prefix length


def init_cache(cfg: TransformerConfig, batch: int, max_len: int,
               dtype=None) -> KVCache:
    dt = dtype or cfg.dtype
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(jnp.zeros(shape, dt), jnp.zeros(shape, dt),
                   jnp.zeros((), jnp.int32))


def cache_specs(cfg: TransformerConfig, batch: int) -> KVCache:
    """Sharding policy: batch over data when it divides, else the sequence
    axis takes both mesh axes (long-context batch=1 decode)."""
    if batch >= 16:
        spec = P(None, "data", "model", None, None)
    else:
        spec = P(None, None, ("data", "model"), None, None)
    return KVCache(spec, spec, P())


def prefill(params: dict, tokens: jax.Array, cfg: TransformerConfig,
            max_len: int) -> tuple[jax.Array, KVCache]:
    """Run the prompt; returns (final-norm hidden [B, S, D], cache)."""
    hidden, caches, _ = forward(params, tokens, cfg, mode="prefill")
    k, v = caches                                    # [L, B, S, KV, H]
    pad = max_len - tokens.shape[1]
    if pad > 0:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    return hidden, KVCache(k.astype(cfg.dtype), v.astype(cfg.dtype),
                           jnp.asarray(tokens.shape[1], jnp.int32))


def _decode_layers(params: dict, token: jax.Array, k: jax.Array,
                   v: jax.Array, positions: jax.Array, kv_len,
                   cfg: TransformerConfig
                   ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Shared one-token layer loop.  token [B], k/v [L, B, S, KV, H],
    positions [B, 1], kv_len scalar or [B] -> (hidden [B, D], k_new,
    v_new).  Every op is row-parallel over B."""
    x = params["embed"][token[:, None]].astype(cfg.dtype)   # [B, 1, D]

    if cfg.layers_impl == "unroll":
        ks, vs = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, (k_i, v_i), _ = _layer(x, lp, cfg, positions, "decode",
                                      (k[i], v[i]), kv_len)
            ks.append(k_i)
            vs.append(v_i)
        k_new, v_new = jnp.stack(ks), jnp.stack(vs)
    else:
        def body(carry, xs):
            h = carry
            lp, kc, vc = xs
            h, new_cache, _ = _layer(h, lp, cfg, positions, "decode",
                                     (kc, vc), kv_len)
            return h, new_cache

        x, (k_new, v_new) = jax.lax.scan(body, x, (params["layers"], k, v))
    return L.rms_norm(x[:, 0], params["final_norm"]), k_new, v_new


def decode_step(params: dict, token: jax.Array, cache: KVCache,
                cfg: TransformerConfig) -> tuple[jax.Array, KVCache]:
    """One decode step. token [B] int32 -> (hidden [B, D], new cache).

    The caller applies the head: ``logits_head`` for exact serving or the
    LSS index (repro.core) for sub-linear WOL serving.
    """
    b = token.shape[0]
    kv_len = cache.length + 1
    positions = jnp.full((b, 1), cache.length, jnp.int32)
    hidden, k_new, v_new = _decode_layers(params, token, cache.k, cache.v,
                                          positions, kv_len, cfg)
    return hidden, KVCache(k_new, v_new, kv_len)


def decode_step_pooled(params: dict, token: jax.Array, k: jax.Array,
                       v: jax.Array, lengths: jax.Array,
                       cfg: TransformerConfig
                       ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step over a slot pool with PER-ROW cache lengths.

    The continuous-batching counterpart of :func:`decode_step`: rows are
    independent streams at different depths, so each row reads its own
    valid prefix and writes its new KV at its own position.  token [B]
    int32, k/v [L, B, S_max, KV, H] slabs, lengths [B] int32 (current
    valid prefix per slot) -> (hidden [B, D], k_new, v_new).

    Row ``i`` computes exactly what :func:`decode_step` computes for a
    batch-1 cache of the same width ``S_max`` — every op is row-parallel —
    which is what makes interleaved decode token-exact with a blocking
    per-stream loop (asserted in tests/test_decode_stream.py).
    """
    return _decode_layers(params, token, k, v,
                          lengths[:, None].astype(jnp.int32),  # positions
                          lengths + 1, cfg)


def decode_step_paged(params: dict, token: jax.Array, k_arena: jax.Array,
                      v_arena: jax.Array, page_table: jax.Array,
                      lengths: jax.Array, cfg: TransformerConfig,
                      max_len: int
                      ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """:func:`decode_step_pooled` over PAGED KV storage.

    token [B] int32, k/v arenas [L, n_pages, page_tokens, KV, H],
    page_table [B, pages_per_slot] int32 (0 = unmapped -> the reserved
    scratch page), lengths [B] int32 -> (hidden [B, D], k_arena, v_arena).

    Bit-identity with the dense layout is by construction: each row's
    pages are gathered IN ORDER into a contiguous view sliced to exactly
    ``max_len`` — the same ``[L, B, max_len, KV, H]`` operand shape the
    dense slabs present — so :func:`_decode_layers` runs the very same
    program over the very same valid contents (positions >= lengths are
    masked to exact zeros inside ``attention_decode`` either way; what
    garbage sits there — arena zeros vs stale rows — cannot matter
    because everything the model ever writes is finite).  Slicing to
    ``max_len`` (not ``pages_per_slot * page_tokens``) is load-bearing:
    XLA:CPU reductions are not shape-invariant at the ulp level, so the
    view width must equal the dense width exactly.

    Memory caveat: that gather materializes a contiguous
    ``[L, B, max_len, KV, H]`` view per step — the size of the full
    dense slab — unless the backend fuses it into attention, so the
    paged layout's savings are in PERSISTENT arena bytes (what bounds
    how many sessions a device can hold between steps), while the
    per-step transient peak can match the dense layout's.  The
    ``BENCH_decode.json`` capacity rows count persistent bytes only;
    see docs/ARCHITECTURE.md "Paged KV decode" for the trade-off.

    The new KV row is scattered back into each row's current write page
    (page ``lengths // p``, offset ``lengths % p``).  Rows that must not
    write — parked slots and rows at ``lengths == max_len`` (where the
    dense one-hot write falls off the end of the slab) — are redirected
    to scratch page 0, so a freed slot's in-flight step can never
    corrupt a recycled page.  All shapes are static: joins, leaves, and
    page-table churn cost zero recompiles.
    """
    n_l, _, p, n_kv, h_dim = k_arena.shape
    b, n_pp = page_table.shape
    w = max_len

    def view(arena):
        return arena[:, page_table].reshape(n_l, b, n_pp * p,
                                            n_kv, h_dim)[:, :, :w]

    hidden, k_new, v_new = _decode_layers(
        params, token, view(k_arena), view(v_arena),
        lengths[:, None].astype(jnp.int32), lengths + 1, cfg)

    rows = jnp.arange(b)
    wpos = jnp.clip(lengths, 0, w - 1)
    pidx = jnp.clip(lengths // p, 0, n_pp - 1)
    dest = jnp.take_along_axis(page_table, pidx[:, None], axis=1)[:, 0]
    dest = jnp.where(lengths < w, dest, 0)          # full rows -> scratch
    off = jnp.where(lengths < w, lengths % p, 0)
    k_arena = k_arena.at[:, dest, off].set(
        k_new[:, rows, wpos].astype(k_arena.dtype))
    v_arena = v_arena.at[:, dest, off].set(
        v_new[:, rows, wpos].astype(v_arena.dtype))
    return hidden, k_arena, v_arena
