"""RecSys architectures: DeepFM, AutoInt, DIEN, BERT4Rec.

The shared substrate is the sparse embedding path — JAX has no
EmbeddingBag, so it is built here from ``jnp.take`` + masked reductions
(``segment_sum`` for ragged bags).  CTR models use one unified table
``[sum(vocab_f), dim]`` with per-field offsets, row-sharded over the
``model`` mesh axis (the standard table-sharding used by DLRM-scale
systems; GSPMD turns the gather into an all-to-all-ish exchange).

BERT4Rec's next-item softmax over a 1M-item catalogue is a WOL — the
paper's technique (LSS, repro.core) serves it sub-linearly; see
``retrieval_scores`` + serve/engine.py.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L


# ------------------------------------------------------- embedding bags ----

def embedding_lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    """Plain row gather ``[V, D] x [...]-> [..., D]`` (one id per field)."""
    return table[ids]


def embedding_bag(table: jax.Array, ids: jax.Array, mode: str = "mean",
                  weights: jax.Array | None = None) -> jax.Array:
    """EmbeddingBag over ragged bags. ids: ``[B, F]`` padded -1."""
    mask = (ids >= 0)
    rows = table[jnp.maximum(ids, 0)]                     # [B, F, D]
    if weights is not None:
        rows = rows * weights[..., None].astype(rows.dtype)
    rows = jnp.where(mask[..., None], rows, 0)
    if mode == "sum":
        return rows.sum(1)
    if mode == "mean":
        return rows.sum(1) / jnp.maximum(mask.sum(1), 1)[:, None].astype(rows.dtype)
    if mode == "max":
        return jnp.where(mask[..., None], rows, -jnp.inf).max(1)
    raise ValueError(mode)


def _mlp(x: jax.Array, ws: Sequence[jax.Array], bs: Sequence[jax.Array],
         final_act: bool = False) -> jax.Array:
    for i, (w, b) in enumerate(zip(ws, bs)):
        x = x @ w + b
        if i < len(ws) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def _init_mlp(key, dims, dtype):
    ks = jax.random.split(key, len(dims) - 1)
    ws = [(jax.random.normal(k, (dims[i], dims[i + 1])) * dims[i] ** -0.5
           ).astype(dtype) for i, k in enumerate(ks)]
    bs = [jnp.zeros((dims[i + 1],), dtype) for i in range(len(dims) - 1)]
    return ws, bs


# ---------------------------------------------------------------- DeepFM ---

class CTRConfig(NamedTuple):
    name: str
    kind: str                      # deepfm | autoint | dien
    n_fields: int = 39
    vocab_per_field: int = 100_000   # synthetic uniform field vocab
    embed_dim: int = 10
    mlp_dims: tuple = (400, 400, 400)
    # autoint
    n_attn_layers: int = 3
    n_heads: int = 2
    d_attn: int = 32
    # dien
    seq_len: int = 100
    gru_dim: int = 108
    unroll_scan: bool = False   # dry-run cost accounting (see transformer)
    dtype: any = jnp.float32

    @property
    def total_vocab(self) -> int:
        return self.n_fields * self.vocab_per_field

    def param_count(self) -> int:
        n = self.total_vocab * self.embed_dim
        if self.kind == "deepfm":
            n += self.total_vocab  # linear term
            dims = [self.n_fields * self.embed_dim, *self.mlp_dims, 1]
            n += sum(dims[i] * dims[i + 1] + dims[i + 1]
                     for i in range(len(dims) - 1))
        return n


def field_offsets(cfg: CTRConfig) -> jax.Array:
    return (jnp.arange(cfg.n_fields) * cfg.vocab_per_field).astype(jnp.int32)


def init_deepfm(key: jax.Array, cfg: CTRConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    dims = [cfg.n_fields * cfg.embed_dim, *cfg.mlp_dims, 1]
    ws, bs = _init_mlp(k3, dims, cfg.dtype)
    return {
        "table": (jax.random.normal(k1, (cfg.total_vocab, cfg.embed_dim))
                  * 0.01).astype(cfg.dtype),
        "linear": (jax.random.normal(k2, (cfg.total_vocab,)) * 0.01
                   ).astype(cfg.dtype),
        "mlp_w": ws, "mlp_b": bs,
        "bias": jnp.zeros((), cfg.dtype),
    }


def deepfm_specs(cfg: CTRConfig) -> dict:
    return {
        "table": P("model", None), "linear": P("model"),
        "mlp_w": [P(None, None)] * (len(cfg.mlp_dims) + 1),
        "mlp_b": [P(None)] * (len(cfg.mlp_dims) + 1),
        "bias": P(),
    }


def deepfm_logits(params: dict, ids: jax.Array, cfg: CTRConfig) -> jax.Array:
    """ids: int32 ``[B, n_fields]`` (field-local); returns CTR logit [B]."""
    gids = ids + field_offsets(cfg)[None, :]
    emb = embedding_lookup(params["table"], gids)          # [B, F, D]
    lin = params["linear"][gids].sum(-1)                   # [B]
    # FM second-order: 0.5 * ((sum v)^2 - sum v^2)
    s = emb.sum(1)
    fm = 0.5 * (jnp.square(s) - jnp.square(emb).sum(1)).sum(-1)
    deep = _mlp(emb.reshape(ids.shape[0], -1), params["mlp_w"],
                params["mlp_b"])[:, 0]
    return (lin + fm + deep + params["bias"]).astype(jnp.float32)


# --------------------------------------------------------------- AutoInt ---

def init_autoint(key: jax.Array, cfg: CTRConfig) -> dict:
    ks = jax.random.split(key, 2 + cfg.n_attn_layers)
    d = cfg.embed_dim
    da, nh = cfg.d_attn, cfg.n_heads
    layers = []
    for i in range(cfg.n_attn_layers):
        k1, k2, k3, k4 = jax.random.split(ks[2 + i], 4)
        d_in = d if i == 0 else da * nh
        s = d_in ** -0.5
        layers.append({
            "wq": (jax.random.normal(k1, (d_in, nh * da)) * s).astype(cfg.dtype),
            "wk": (jax.random.normal(k2, (d_in, nh * da)) * s).astype(cfg.dtype),
            "wv": (jax.random.normal(k3, (d_in, nh * da)) * s).astype(cfg.dtype),
            "wres": (jax.random.normal(k4, (d_in, nh * da)) * s).astype(cfg.dtype),
        })
    d_out = cfg.n_fields * cfg.d_attn * cfg.n_heads
    return {
        "table": (jax.random.normal(ks[0], (cfg.total_vocab, d)) * 0.01
                  ).astype(cfg.dtype),
        "attn": layers,
        "w_out": (jax.random.normal(ks[1], (d_out, 1)) * d_out ** -0.5
                  ).astype(cfg.dtype),
        "bias": jnp.zeros((), cfg.dtype),
    }


def autoint_specs(cfg: CTRConfig) -> dict:
    layer = {"wq": P(None, "model"), "wk": P(None, "model"),
             "wv": P(None, "model"), "wres": P(None, "model")}
    return {"table": P("model", None),
            "attn": [layer] * cfg.n_attn_layers,
            "w_out": P(None, None), "bias": P()}


def autoint_logits(params: dict, ids: jax.Array, cfg: CTRConfig) -> jax.Array:
    gids = ids + field_offsets(cfg)[None, :]
    h = embedding_lookup(params["table"], gids)            # [B, F, D]
    for lp in params["attn"]:
        b, f, _ = h.shape
        q = (h @ lp["wq"]).reshape(b, f, cfg.n_heads, cfg.d_attn)
        k = (h @ lp["wk"]).reshape(b, f, cfg.n_heads, cfg.d_attn)
        v = (h @ lp["wv"]).reshape(b, f, cfg.n_heads, cfg.d_attn)
        scores = jnp.einsum("bfnd,bgnd->bnfg", q, k) * cfg.d_attn ** -0.5
        probs = jax.nn.softmax(scores.astype(jnp.float32), -1).astype(h.dtype)
        o = jnp.einsum("bnfg,bgnd->bfnd", probs, v).reshape(b, f, -1)
        h = jax.nn.relu(o + h @ lp["wres"])
    out = h.reshape(ids.shape[0], -1) @ params["w_out"]
    return (out[:, 0] + params["bias"]).astype(jnp.float32)


# ------------------------------------------------------------------ DIEN ---

def init_dien(key: jax.Array, cfg: CTRConfig) -> dict:
    ks = jax.random.split(key, 8)
    d, g = cfg.embed_dim, cfg.gru_dim
    s_d, s_g = d ** -0.5, g ** -0.5
    def gru(k, d_in):
        k1, k2 = jax.random.split(k)
        return {
            "wx": (jax.random.normal(k1, (d_in, 3 * g)) * d_in ** -0.5
                   ).astype(cfg.dtype),
            "wh": (jax.random.normal(k2, (g, 3 * g)) * s_g).astype(cfg.dtype),
            "b": jnp.zeros((3 * g,), cfg.dtype),
        }
    mlp_dims = [g + 2 * d, *cfg.mlp_dims, 1]
    ws, bs = _init_mlp(ks[3], mlp_dims, cfg.dtype)
    return {
        "table": (jax.random.normal(ks[0], (cfg.total_vocab, d)) * 0.01
                  ).astype(cfg.dtype),
        "gru1": gru(ks[1], d),
        "augru": gru(ks[2], g),   # consumes gru1's hidden states
        "w_attn": (jax.random.normal(ks[4], (g, d)) * s_g).astype(cfg.dtype),
        "mlp_w": ws, "mlp_b": bs,
        "bias": jnp.zeros((), cfg.dtype),
    }


def dien_specs(cfg: CTRConfig) -> dict:
    # GRU params are tiny (3*108 wide, indivisible by the model axis):
    # replicate them; the huge item table stays row-sharded.
    gru = {"wx": P(None, None), "wh": P(None, None), "b": P(None)}
    return {"table": P("model", None), "gru1": gru, "augru": gru,
            "w_attn": P(None, None),
            "mlp_w": [P(None, None)] * (len(cfg.mlp_dims) + 1),
            "mlp_b": [P(None)] * (len(cfg.mlp_dims) + 1),
            "bias": P()}


def _gru_scan(x: jax.Array, p: dict, g: int, att: jax.Array | None = None,
              unroll: bool = False) -> jax.Array:
    """GRU (att=None) or AUGRU (att [B, S] scales the update gate).

    x: [B, S, D] -> hidden states [B, S, G]."""
    bsz = x.shape[0]

    def cell(h, xs):
        xt, at = xs
        gx = xt @ p["wx"] + p["b"]
        gh = h @ p["wh"]
        r = jax.nn.sigmoid(gx[:, :g] + gh[:, :g])
        z = jax.nn.sigmoid(gx[:, g:2 * g] + gh[:, g:2 * g])
        n = jnp.tanh(gx[:, 2 * g:] + r * gh[:, 2 * g:])
        z = z * at[:, None]                 # AUGRU gate (at=1 => plain GRU)
        h = (1 - z) * h + z * n
        return h, h

    if att is None:
        att = jnp.ones(x.shape[:2], x.dtype)
    if unroll:
        h = jnp.zeros((bsz, g), x.dtype)
        ys = []
        for t in range(x.shape[1]):
            h, _ = cell(h, (x[:, t], att[:, t]))
            ys.append(h)
        return jnp.stack(ys, 1)
    xs = (jnp.swapaxes(x, 0, 1), jnp.swapaxes(att, 0, 1))
    _, ys = jax.lax.scan(cell, jnp.zeros((bsz, g), x.dtype), xs)
    return jnp.swapaxes(ys, 0, 1)


def dien_logits(params: dict, batch_ids: dict, cfg: CTRConfig) -> jax.Array:
    """batch_ids: {"hist": [B, S] item ids (-1 pad), "target": [B]}."""
    hist, target = batch_ids["hist"], batch_ids["target"]
    mask = (hist >= 0)
    emb_h = embedding_lookup(params["table"], jnp.maximum(hist, 0))
    emb_h = jnp.where(mask[..., None], emb_h, 0)          # [B, S, D]
    emb_t = embedding_lookup(params["table"], target)     # [B, D]
    g = cfg.gru_dim
    h1 = _gru_scan(emb_h, params["gru1"], g,
                   unroll=cfg.unroll_scan)                # interest extract
    att = jnp.einsum("bsg,gd,bd->bs", h1, params["w_attn"], emb_t)
    att = jax.nn.softmax(jnp.where(mask, att, -1e30), -1).astype(h1.dtype)
    h2 = _gru_scan(h1, params["augru"], g, att,
                   unroll=cfg.unroll_scan)                # interest evolve
    final = h2[:, -1]                                     # [B, G]
    hist_mean = embedding_bag(params["table"], hist, "mean")
    feat = jnp.concatenate([final, emb_t, hist_mean], -1)
    out = _mlp(feat, params["mlp_w"], params["mlp_b"])[:, 0]
    return (out + params["bias"]).astype(jnp.float32)


# --------------------------------------------------------------- BERT4Rec --

class Bert4RecConfig(NamedTuple):
    name: str
    n_items: int = 1_000_000
    embed_dim: int = 64
    n_blocks: int = 2
    n_heads: int = 2
    seq_len: int = 200
    dtype: any = jnp.float32

    def param_count(self) -> int:
        d = self.embed_dim
        per_block = 4 * d * d + 8 * d * d + 4 * d   # attn + 4d FFN + norms
        return self.n_items * d * 2 + self.seq_len * d \
            + self.n_blocks * per_block


def init_bert4rec(key: jax.Array, cfg: Bert4RecConfig) -> dict:
    ks = jax.random.split(key, 3 + cfg.n_blocks)
    d = cfg.embed_dim
    s = d ** -0.5
    blocks = []
    for i in range(cfg.n_blocks):
        k1, k2, k3, k4, k5, k6 = jax.random.split(ks[3 + i], 6)
        blocks.append({
            "wq": (jax.random.normal(k1, (d, d)) * s).astype(cfg.dtype),
            "wk": (jax.random.normal(k2, (d, d)) * s).astype(cfg.dtype),
            "wv": (jax.random.normal(k3, (d, d)) * s).astype(cfg.dtype),
            "wo": (jax.random.normal(k4, (d, d)) * s).astype(cfg.dtype),
            "w1": (jax.random.normal(k5, (d, 4 * d)) * s).astype(cfg.dtype),
            "w2": (jax.random.normal(k6, (4 * d, d)) * (4 * d) ** -0.5
                   ).astype(cfg.dtype),
            "ln1": jnp.ones((d,), jnp.float32),
            "ln2": jnp.ones((d,), jnp.float32),
        })
    return {
        "items": (jax.random.normal(ks[0], (cfg.n_items, d)) * s
                  ).astype(cfg.dtype),
        "pos": (jax.random.normal(ks[1], (cfg.seq_len, d)) * 0.02
                ).astype(cfg.dtype),
        "blocks": blocks,
        "head": (jax.random.normal(ks[2], (cfg.n_items, d)) * s
                 ).astype(cfg.dtype),
        "final_norm": jnp.ones((d,), jnp.float32),
    }


def bert4rec_specs(cfg: Bert4RecConfig) -> dict:
    # The encoder is TINY (d=64): tensor-parallel sharding it all-reduces
    # [B, S, 64] activations per block (15.9 GB/dev measured at
    # serve_bulk) to save KBs of weights.  Replicate the encoder; shard
    # only the 1M-row item/head tables.  §Perf hillclimb 3.
    block = {"wq": P(None, None), "wk": P(None, None),
             "wv": P(None, None), "wo": P(None, None),
             "w1": P(None, None), "w2": P(None, None),
             "ln1": P(None), "ln2": P(None)}
    return {"items": P("model", None), "pos": P(None, None),
            "blocks": [block] * cfg.n_blocks,
            "head": P("model", None), "final_norm": P(None)}


def bert4rec_encode(params: dict, seq: jax.Array,
                    cfg: Bert4RecConfig) -> jax.Array:
    """seq: int32 [B, S] item ids (-1 pad) -> hidden [B, S, D].

    Bidirectional attention (cloze objective) — the per-position hidden is
    the LSS query against the item-catalogue WOL."""
    mask = seq >= 0
    x = params["items"][jnp.maximum(seq, 0)] + params["pos"][None]
    x = jnp.where(mask[..., None], x, 0).astype(cfg.dtype)
    nh = cfg.n_heads
    d = cfg.embed_dim
    hd = d // nh
    for blk in params["blocks"]:
        h = L.rms_norm(x, blk["ln1"])
        b, s, _ = h.shape
        q = (h @ blk["wq"]).reshape(b, s, nh, hd)
        k = (h @ blk["wk"]).reshape(b, s, nh, hd)
        v = (h @ blk["wv"]).reshape(b, s, nh, hd)
        logits = jnp.einsum("bqnh,bknh->bnqk", q, k) * hd ** -0.5
        logits = jnp.where(mask[:, None, None, :], logits, -1e30)
        probs = jax.nn.softmax(logits.astype(jnp.float32), -1).astype(x.dtype)
        o = jnp.einsum("bnqk,bknh->bqnh", probs, v).reshape(b, s, d)
        x = x + o @ blk["wo"]
        h = L.rms_norm(x, blk["ln2"])
        x = x + jax.nn.gelu(h @ blk["w1"]) @ blk["w2"]
    return L.rms_norm(x, params["final_norm"])


def bert4rec_loss(params: dict, batch: dict, cfg: Bert4RecConfig) -> jax.Array:
    """Cloze loss. batch: seq [B, S] (-1 pad), labels [B, S] (-1 = unmasked
    position; >= 0 = the held-out item at a masked position)."""
    hidden = bert4rec_encode(params, batch["seq"], cfg)
    labels = batch["labels"]
    mask = labels >= 0
    logits = jnp.einsum("bsd,vd->bsv", hidden, params["head"]
                        ).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None],
                               -1)[..., 0]
    return ((logz - gold) * mask).sum() / jnp.maximum(mask.sum(), 1)


def retrieval_scores(params: dict, user_hidden: jax.Array,
                     candidates: jax.Array | None = None) -> jax.Array:
    """Score a user embedding against the catalogue (the paper's WOL
    setting verbatim).  candidates=None -> full [B, V] matmul (the
    baseline LSS beats); ids [C] -> gathered scoring."""
    head = params["head"]
    if candidates is not None:
        head = head[candidates]
    return jnp.einsum("bd,vd->bv", user_hidden.astype(jnp.float32),
                      head.astype(jnp.float32))
