"""models subpackage."""
