"""The paper's own model families (§4, Appendix B.2).

* Extreme classification: Embedding(bag) -> ReLU -> WOL.  Input is sparse
  BoW (multi-hot token ids, padded with -1); the embedding layer is an
  EmbeddingBag (mean) — built from take + mask like everything sparse in
  this framework.
* word2vec: same body with one-hot input (single center word id).

The model exposes ``embed(params, x)`` — the layer-below-the-WOL
embedding, i.e. the LSS query — separately from ``logits``/``loss``, so
the LSS index plugs in without touching model code.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.utils.sharding import maybe_shard


class XCConfig(NamedTuple):
    name: str
    input_dim: int        # BoW vocabulary
    hidden: int           # 128 in the paper
    output_dim: int       # WOL width (number of labels / vocab)
    max_in: int = 64      # max active input features per sample
    max_labels: int = 8   # max labels per sample (padded -1)
    dtype: any = jnp.float32

    def param_count(self) -> int:
        return self.input_dim * self.hidden + \
            self.output_dim * (self.hidden + 1)


def init_params(key: jax.Array, cfg: XCConfig) -> dict:
    k1, k2 = jax.random.split(key)
    s1 = cfg.input_dim ** -0.5
    s2 = cfg.hidden ** -0.5
    return {
        "embed": (jax.random.normal(k1, (cfg.input_dim, cfg.hidden)) * s1
                  ).astype(cfg.dtype),
        "w_out": (jax.random.normal(k2, (cfg.output_dim, cfg.hidden)) * s2
                  ).astype(cfg.dtype),
        "b_out": jnp.zeros((cfg.output_dim,), cfg.dtype),
    }


def param_specs(cfg: XCConfig) -> dict:
    return {
        "embed": P("model", None),   # input vocab sharded
        "w_out": P("model", None),   # WOL rows sharded (LSS shards match)
        "b_out": P("model"),
    }


def embed(params: dict, x_ids: jax.Array) -> jax.Array:
    """EmbeddingBag(mean) + ReLU.  x_ids: int32 ``[B, max_in]``, -1 pad.

    This is the LSS query embedding (the paper collects it right before
    the WOL).
    """
    mask = (x_ids >= 0)[..., None]
    rows = params["embed"][jnp.maximum(x_ids, 0)]         # [B, F, H]
    denom = jnp.maximum(mask.sum(1), 1).astype(rows.dtype)
    bag = jnp.where(mask, rows, 0).sum(1) / denom
    return jax.nn.relu(bag)


def logits(params: dict, x_ids: jax.Array) -> jax.Array:
    h = embed(params, x_ids)
    h = maybe_shard(h, P("data", None))
    out = jnp.einsum("bh,vh->bv", h, params["w_out"]) + params["b_out"]
    return out.astype(jnp.float32)


def loss(params: dict, batch: dict, cfg: XCConfig) -> jax.Array:
    """Multi-label softmax CE (uniform over the true labels), the standard
    XMC training loss.  batch: x [B, max_in], labels [B, max_labels]."""
    lg = logits(params, batch["x"])
    labels = batch["labels"]
    mask = labels >= 0
    logz = jax.nn.logsumexp(lg, axis=-1, keepdims=True)
    # shardable multi-label gold logits: one iota-mask pass per label slot
    iota = jax.lax.broadcasted_iota(jnp.int32, lg.shape, 1)
    gold = jnp.stack(
        [jnp.sum(jnp.where(iota == jnp.maximum(labels[:, j:j + 1], 0),
                           lg, 0), axis=-1)
         for j in range(labels.shape[1])], axis=-1)
    nll = -(gold - logz) * mask
    return (nll.sum(-1) / jnp.maximum(mask.sum(-1), 1)).mean()


def predict_topk(params: dict, x_ids: jax.Array, k: int = 5) -> jax.Array:
    return jax.lax.top_k(logits(params, x_ids), k)[1]
