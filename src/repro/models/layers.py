"""Shared transformer layers: norms, RoPE, GQA attention, SwiGLU.

Attention has three execution paths:
  * ``naive``     — full [S, S] scores; oracle for tests.
  * ``blockwise`` — online-softmax over KV chunks (lax.scan); memory O(S·c)
                    instead of O(S²); the production/dry-run path (pure
                    jnp, lowers on every backend; a Pallas flash kernel
                    can replace it on real TPUs).
  * ``decode``    — one query position against a KV cache.

All functions are pure; params are plain dicts of arrays.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ------------------------------------------------------------------ norms --

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale + bias).astype(x.dtype)


# ------------------------------------------------------------------- rope --

def rope_freqs(head_dim: int, base: float = 1e6) -> jax.Array:
    return 1.0 / (base ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               base: float = 1e6) -> jax.Array:
    """x: ``[B, S, N, H]``, positions: ``[B, S]`` (int)."""
    freqs = rope_freqs(x.shape[-1], base)                    # [H/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, S, H/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- attention --

def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """``[B, S, KV, H] -> [B, S, KV*n_rep, H]`` for GQA."""
    if n_rep == 1:
        return k
    b, s, kv, h = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, h)
                            ).reshape(b, s, kv * n_rep, h)


def attention_naive(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True) -> jax.Array:
    """Oracle. q: [B,S,N,H]; k,v: [B,S,KV,H]."""
    n_rep = q.shape[2] // k.shape[2]
    k, v = _repeat_kv(k, n_rep), _repeat_kv(v, n_rep)
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bqnh,bknh->bnqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        s = q.shape[1]
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bnqk,bknh->bqnh", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def attention_blockwise(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, kv_chunk: int = 512,
                        q_chunk: int | None = None) -> jax.Array:
    """Online-softmax attention, O(S·chunk) memory. Shapes as naive.

    ``q_chunk``: additionally scan over query chunks — required for long
    prefill where even one [B, N, S, kv_chunk] score tile would blow HBM.
    """
    if q_chunk is not None and q.shape[1] > q_chunk:
        b, s, n, h = q.shape
        assert s % q_chunk == 0, (s, q_chunk)
        qc = q.reshape(b, s // q_chunk, q_chunk, n, h)

        def outer(carry, xs):
            qi, i = xs
            out = _attention_blockwise_inner(
                qi, k, v, causal=causal, kv_chunk=kv_chunk,
                q_offset=i * q_chunk)
            return carry, out

        _, outs = jax.lax.scan(outer, None,
                               (jnp.moveaxis(qc, 1, 0),
                                jnp.arange(s // q_chunk)))
        return jnp.moveaxis(outs, 0, 1).reshape(b, s, n, h)
    return _attention_blockwise_inner(q, k, v, causal=causal,
                                      kv_chunk=kv_chunk, q_offset=0)


def _attention_blockwise_inner(q: jax.Array, k: jax.Array, v: jax.Array,
                               causal: bool, kv_chunk: int,
                               q_offset: jax.Array | int) -> jax.Array:
    b, s, n, h = q.shape
    kv_heads = k.shape[2]
    n_rep = n // kv_heads
    scale = h ** -0.5
    kv_chunk = min(kv_chunk, k.shape[1])
    kv_len = k.shape[1]
    pad = (-kv_len) % kv_chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = k.shape[1] // kv_chunk

    kc = k.reshape(b, n_chunks, kv_chunk, kv_heads, h)
    vc = v.reshape(b, n_chunks, kv_chunk, kv_heads, h)
    q32 = q.astype(jnp.float32) * scale
    qpos = q_offset + jnp.arange(s)

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def body(carry, xs):
        # remat per tile: the [B, N, Sq, c] score tile would otherwise be
        # saved as a backward residual for EVERY kv chunk (measured:
        # ~17 GB/layer/device at train_4k) — flash-attention's backward
        # recomputes it instead.
        m_prev, l_prev, acc = carry
        kj, vj, j = xs
        kj = _repeat_kv(kj, n_rep).astype(jnp.float32)   # [B, c, N, H]
        vj = _repeat_kv(vj, n_rep).astype(jnp.float32)
        logits = jnp.einsum("bqnh,bknh->bnqk", q32, kj)   # [B,N,S,c]
        kpos = j * kv_chunk + jnp.arange(kv_chunk)
        mask = kpos[None, :] < kv_len
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        logits = jnp.where(mask[None, None], logits, NEG_INF)
        m_new = jnp.maximum(m_prev, logits.max(-1))       # [B,N,S]
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new[..., None])
        l_new = l_prev * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum("bnqk,bknh->bnqh", p, vj)
        return (m_new, l_new, acc), None

    init = (jnp.full((b, n, s), NEG_INF, jnp.float32),
            jnp.zeros((b, n, s), jnp.float32),
            jnp.zeros((b, n, s, h), jnp.float32))
    xs = (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
          jnp.arange(n_chunks))
    (m, l, acc), _ = jax.lax.scan(body, init, xs)
    out = acc / jnp.maximum(l, 1e-30)[..., None]          # [B,N,S,H]
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)        # [B,S,N,H]


def attention_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     kv_len: jax.Array | int) -> jax.Array:
    """One-step decode. q: [B,1,N,H]; caches: [B,S,KV,H]; kv_len: valid len,
    a scalar (all rows share one length) or [B] (continuous batching: each
    row of the cache pool has its own valid prefix).

    GQA via a GROUPED einsum — the head-repeat broadcast+reshape merges
    (kv, n_rep) dims across the cache's shard boundary, which GSPMD can
    only resolve by replicating the full f32 cache (measured: 26 GB/dev
    at qwen2-7b decode_32k; §Perf hillclimb 2 iter 3).  The grouped form
    never materializes the repeat, and the softmax's max/sum over the
    seq-sharded cache lower to the flash-decoding partial-softmax
    all-reduce combine.
    """
    b, one, n, h = q.shape
    kv = k_cache.shape[2]
    r = n // kv
    scale = h ** -0.5
    k32 = k_cache.astype(jnp.float32)
    v32 = v_cache.astype(jnp.float32)
    spos = jnp.arange(k_cache.shape[1])
    valid = spos[None, :] < jnp.reshape(kv_len, (-1, 1))   # [B or 1, S]
    if r == 1:
        # MHA: no repeat needed; the plain 4-D einsum partitions best
        # (the 5-D grouped form measured 1.4x slower here).
        q32 = q.astype(jnp.float32) * scale
        logits = jnp.einsum("bqnh,bknh->bnqk", q32, k32)
        logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bnqk,bknh->bqnh", probs, v32)
        return out.astype(q.dtype)
    qg = (q.astype(jnp.float32) * scale).reshape(b, one, kv, r, h)
    logits = jnp.einsum("bqgrh,bkgh->bgrqk", qg, k32)   # [B,KV,r,1,S]
    logits = jnp.where(valid[:, None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrqk,bkgh->bqgrh", probs, v32)
    return out.reshape(b, one, n, h).astype(q.dtype)


# ------------------------------------------------------------------ ffn ----

def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)
