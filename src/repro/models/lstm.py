"""The paper's RNN language model (Appendix B.2): embed -> 2x LSTM(200)
-> dropout -> WOL.  LSTM cells via lax.scan (no flax)."""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class LSTMConfig(NamedTuple):
    name: str
    vocab: int
    hidden: int = 200
    n_layers: int = 2
    dropout: float = 0.2
    dtype: any = jnp.float32

    def param_count(self) -> int:
        per_layer = 4 * self.hidden * (2 * self.hidden + 1)
        return self.vocab * self.hidden * 2 + self.n_layers * per_layer \
            + self.vocab


def init_params(key: jax.Array, cfg: LSTMConfig) -> dict:
    ks = jax.random.split(key, 2 + cfg.n_layers)
    h = cfg.hidden
    s = h ** -0.5
    layers = {
        "wx": jnp.stack([jax.random.normal(ks[2 + i], (h, 4 * h)) * s
                         for i in range(cfg.n_layers)]).astype(cfg.dtype),
        "wh": jnp.stack([jax.random.normal(jax.random.fold_in(ks[2 + i], 1),
                                           (h, 4 * h)) * s
                         for i in range(cfg.n_layers)]).astype(cfg.dtype),
        "b": jnp.zeros((cfg.n_layers, 4 * h), cfg.dtype),
    }
    return {
        "embed": (jax.random.normal(ks[0], (cfg.vocab, h)) * s
                  ).astype(cfg.dtype),
        "layers": layers,
        "w_out": (jax.random.normal(ks[1], (cfg.vocab, h)) * s
                  ).astype(cfg.dtype),
        "b_out": jnp.zeros((cfg.vocab,), cfg.dtype),
    }


def param_specs(cfg: LSTMConfig) -> dict:
    return {
        "embed": P("model", None),
        "layers": {"wx": P(None, None, "model"),
                   "wh": P(None, None, "model"),
                   "b": P(None, "model")},
        "w_out": P("model", None),
        "b_out": P("model"),
    }


def _lstm_layer(x: jax.Array, wx, wh, b) -> jax.Array:
    """x: [B, S, H] -> [B, S, H] (scan over time)."""
    bsz, _, h = x.shape

    def cell(carry, xt):
        hp, cp = carry
        gates = xt @ wx + hp @ wh + b
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        c = jax.nn.sigmoid(f) * cp + jax.nn.sigmoid(i) * jnp.tanh(g)
        hn = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (hn, c), hn

    init = (jnp.zeros((bsz, h), x.dtype), jnp.zeros((bsz, h), x.dtype))
    _, ys = jax.lax.scan(cell, init, jnp.swapaxes(x, 0, 1))
    return jnp.swapaxes(ys, 0, 1)


def embed_seq(params: dict, tokens: jax.Array, cfg: LSTMConfig,
              dropout_key=None) -> jax.Array:
    """tokens [B, S] -> last-layer hidden states [B, S, H] (the LSS query
    at each position)."""
    x = params["embed"][tokens]
    for i in range(cfg.n_layers):
        x = _lstm_layer(x, params["layers"]["wx"][i],
                        params["layers"]["wh"][i], params["layers"]["b"][i])
    if dropout_key is not None and cfg.dropout > 0:
        keep = jax.random.bernoulli(dropout_key, 1 - cfg.dropout, x.shape)
        x = jnp.where(keep, x / (1 - cfg.dropout), 0)
    return x


def loss(params: dict, batch: dict, cfg: LSTMConfig,
         dropout_key=None) -> jax.Array:
    h = embed_seq(params, batch["tokens"], cfg, dropout_key)
    lg = jnp.einsum("bsh,vh->bsv", h, params["w_out"]) + params["b_out"]
    lg = lg.astype(jnp.float32)
    labels = batch["labels"]
    mask = labels >= 0
    logz = jax.nn.logsumexp(lg, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, lg.shape, lg.ndim - 1)
    gold = jnp.sum(jnp.where(iota == jnp.maximum(labels, 0)[..., None],
                             lg, 0), axis=-1)
    return ((logz - gold) * mask).sum() / jnp.maximum(mask.sum(), 1)
