"""Mixture-of-Experts layer: top-k routing + argsort dispatch.

Dispatch is sort-based (gather/scatter), NOT the GShard one-hot einsum:
the one-hot formulation inflates HLO FLOPs by O(S·E·C·d) and would poison
the roofline "useful compute" ratio; gathers are ~free in cost_analysis
and on TPU lower to dynamic-slice streams.

Static shapes throughout: per-expert capacity C = ceil(tokens·top_k/E) ·
capacity_factor; overflow tokens are dropped (their combine weight is 0),
underflow slots are zero-padded.  Experts are sharded over the ``model``
mesh axis by the launcher; GSPMD inserts the all-to-alls at the
scatter/gather boundaries.

Supports the two assigned MoE archs:
  * qwen2-moe: 60 routed (padded to 64 for even sharding) top-4,
    renormalised probs, + 1 shared expert with a sigmoid gate.
  * arctic: 128 routed top-2 + a DENSE residual MLP in parallel.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.utils.sharding import maybe_shard


class MoEConfig(NamedTuple):
    n_experts: int           # routed experts (logical, pre-padding)
    top_k: int
    d_model: int
    d_ff: int                # per-expert hidden
    n_experts_padded: int    # physical experts (divisible by model axis)
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    # GShard-style dispatch groups (= data-axis size in production).
    # Capacity is PER GROUP and the scatter/gather becomes a batched op
    # sharded on the group dim — a global-sort dispatch forces GSPMD to
    # all-gather the scatter updates (measured 16 GB/device/layer on
    # qwen2-moe train_4k; EXPERIMENTS.md §Perf hillclimb 1).
    n_groups: int = 1


def router_topk(x: jax.Array, w_router: jax.Array, cfg: MoEConfig
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Token-choice top-k routing.

    x: ``[T, D]`` flattened tokens. Returns (expert ids [T, k],
    combine weights [T, k], aux load-balancing loss []).
    """
    logits = x.astype(jnp.float32) @ w_router.astype(jnp.float32)  # [T, Ep]
    # padded experts never win: mask their logits
    if cfg.n_experts_padded > cfg.n_experts:
        pad_mask = jnp.arange(cfg.n_experts_padded) >= cfg.n_experts
        logits = jnp.where(pad_mask[None], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, cfg.top_k)          # [T, k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    # Switch-style aux loss: E * sum_e f_e * p_e
    me = probs.mean(0)                                       # [Ep]
    ce = jnp.zeros((cfg.n_experts_padded,)).at[top_e.reshape(-1)].add(
        1.0 / top_e.size)
    aux = cfg.n_experts * jnp.sum(me * ce)
    return top_e, top_p.astype(x.dtype), aux


def dispatch_indices(top_e: jax.Array, n_experts: int, capacity: int
                     ) -> tuple[jax.Array, jax.Array]:
    """Sort-based dispatch plan.

    Args:
      top_e: ``[T, k]`` expert assignment per (token, slot).
    Returns:
      buffer_pos: int32 ``[T*k]`` position in the ``[E*C]`` expert buffer
                  (or E*C, a trash slot, when over capacity).
      keep: bool ``[T*k]``.
    """
    flat_e = top_e.reshape(-1)                               # [T*k]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank = jnp.arange(flat_e.shape[0], dtype=jnp.int32) - starts.astype(jnp.int32)
    keep_sorted = rank < capacity
    pos_sorted = jnp.where(keep_sorted, sorted_e * capacity + rank,
                           n_experts * capacity)
    # invert the sort: buffer position per original (token, slot)
    inv = jnp.argsort(order, stable=True)
    return pos_sorted[inv], keep_sorted[inv]


def moe_ffn(x: jax.Array, params: dict, cfg: MoEConfig
            ) -> tuple[jax.Array, jax.Array]:
    """Full MoE FFN on flattened tokens ``[T, D]`` -> (out, aux_loss).

    Group-local (GShard-style) dispatch: tokens are split into
    ``n_groups`` groups (one per data shard in production), each with its
    own capacity and its own sort — the scatter/gather carry a leading
    batch dim that GSPMD partitions without communication, and the expert
    einsum is local over (group=data, expert=model).

    params: router [D, Ep], w_gate/w_up [Ep, D, F], w_down [Ep, F, D].
    """
    t, d = x.shape
    ep = cfg.n_experts_padded
    g_n = cfg.n_groups if t % cfg.n_groups == 0 else 1
    tg = t // g_n
    capacity = max(8, int(cfg.capacity_factor * tg * cfg.top_k / ep))
    top_e, top_p, aux = router_topk(x, params["router"], cfg)

    xg = x.reshape(g_n, tg, d)
    if g_n > 1:
        xg = maybe_shard(xg, P("data", None, None))
    top_e_g = top_e.reshape(g_n, tg, cfg.top_k)
    if g_n > 1:
        pos, keep = jax.vmap(dispatch_indices, in_axes=(0, None, None))(
            top_e_g, ep, capacity)                    # [G, Tg*k]
        # batched scatter into [G, E*C+1, D] (trash row last)
        xk = jnp.repeat(xg, cfg.top_k, axis=1)        # [G, Tg*k, D]
        buf = jnp.zeros((g_n, ep * capacity + 1, d), x.dtype)
        buf = jax.vmap(lambda b, p, u, k: b.at[p].set(
            jnp.where(k[:, None], u, 0), mode="drop"))(buf, pos, xk, keep)
    else:
        # unbatched path (tiny decode batches): a singleton-batched
        # scatter partitions worse than the plain one.
        pos, keep = dispatch_indices(top_e, ep, capacity)
        xk = jnp.repeat(x, cfg.top_k, axis=0)
        buf0 = jnp.zeros((ep * capacity + 1, d), x.dtype)
        buf = buf0.at[pos].set(jnp.where(keep[:, None], xk, 0),
                               mode="drop")[None]
        pos, keep = pos[None], keep[None]
    h = buf[:, :-1].reshape(g_n, ep, capacity, d)     # [G, E, C, D]
    if g_n > 1:
        h = maybe_shard(h, P("data", "model", None, None))

    # expert SwiGLU: local over (G=data, E=model)
    gt = jnp.einsum("gecd,edf->gecf", h, params["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", h, params["w_up"])
    y = jnp.einsum("gecf,efd->gecd", jax.nn.silu(gt) * u,
                   params["w_down"])
    if g_n > 1:
        y = maybe_shard(y, P("data", "model", None, None))

    # batched gather back + weighted combine
    yk = y.reshape(g_n, ep * capacity, d)
    yk = jnp.concatenate([yk, jnp.zeros_like(yk[:, :1])], 1)
    yk = jax.vmap(lambda b, p: b[p])(yk, pos)         # [G, Tg*k, D]
    yk = jnp.where(keep[..., None], yk, 0)
    w = top_p.reshape(g_n, tg * cfg.top_k, 1).astype(yk.dtype)
    out = (yk * w).reshape(g_n, tg, cfg.top_k, d).sum(2)
    return out.reshape(t, d), aux


def moe_ffn_dense_oracle(x: jax.Array, params: dict, cfg: MoEConfig
                         ) -> jax.Array:
    """No-capacity-drop oracle: run every expert on every token, mask by
    routing weights.  O(T·E·F) — tests only."""
    top_e, top_p, _ = router_topk(x, params["router"], cfg)
    g = jnp.einsum("td,edf->tef", x, params["w_gate"])
    u = jnp.einsum("td,edf->tef", x, params["w_up"])
    y = jnp.einsum("tef,efd->ted", jax.nn.silu(g) * u, params["w_down"])
    weights = jnp.zeros((x.shape[0], cfg.n_experts_padded), x.dtype)
    rows = jnp.arange(x.shape[0])[:, None]
    weights = weights.at[rows, top_e].add(top_p)
    return jnp.einsum("ted,te->td", y, weights)


def init_moe_params(key: jax.Array, cfg: MoEConfig, dtype=jnp.float32) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, f, ep = cfg.d_model, cfg.d_ff, cfg.n_experts_padded
    s_in = d ** -0.5
    s_ff = f ** -0.5
    return {
        "router": (jax.random.normal(k1, (d, ep)) * s_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(k2, (ep, d, f)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(k3, (ep, d, f)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k4, (ep, f, d)) * s_ff).astype(dtype),
    }
