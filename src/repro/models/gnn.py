"""GCN (Kipf & Welling, arXiv:1609.02907) in pure JAX.

Message passing is built from ``jax.ops.segment_sum`` over an edge list
(JAX has no CSR SpMM — the scatter IS the system here):

    h' = ReLU( D^-1/2 (A + I) D^-1/2  h  W )

Four execution shapes (the assigned cells):
  * full_graph_sm / ogb_products: full-batch training step on [N, F] +
    edge list [E, 2].
  * minibatch_lg: layer-wise neighbor sampling (GraphSAGE-style fanout
    15-10) from a padded-CSR, then GCN on the sampled block.
  * molecule: batched small graphs, vmap'd forward + mean-pool readout.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class GCNConfig(NamedTuple):
    name: str
    n_layers: int = 2
    d_feat: int = 1433
    d_hidden: int = 16
    n_classes: int = 7
    aggregator: str = "mean"    # sym-normalized mean
    readout: str = "none"       # "mean" for graph-level tasks
    dtype: any = jnp.float32

    def param_count(self) -> int:
        dims = [self.d_feat] + [self.d_hidden] * (self.n_layers - 1) \
            + [self.n_classes]
        return sum(dims[i] * dims[i + 1] + dims[i + 1]
                   for i in range(len(dims) - 1))


def init_params(key: jax.Array, cfg: GCNConfig) -> dict:
    dims = [cfg.d_feat] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    ks = jax.random.split(key, len(dims) - 1)
    return {
        "w": [((jax.random.normal(k, (dims[i], dims[i + 1]))
                * dims[i] ** -0.5).astype(cfg.dtype))
              for i, k in enumerate(ks)],
        "b": [jnp.zeros((dims[i + 1],), cfg.dtype)
              for i in range(len(dims) - 1)],
    }


def param_specs(cfg: GCNConfig) -> dict:
    return {"w": [P(None, None)] * cfg.n_layers,
            "b": [P(None)] * cfg.n_layers}


def _sym_norm_agg(h: jax.Array, edges: jax.Array, n_nodes: int) -> jax.Array:
    """Symmetric-normalized aggregation with self loops.

    h: [N, D]; edges: int32 [E, 2] (src, dst), -1 rows = padding.
    """
    src, dst = edges[:, 0], edges[:, 1]
    valid = src >= 0
    s = jnp.where(valid, src, 0)
    t = jnp.where(valid, dst, 0)
    ones = valid.astype(jnp.float32)
    deg = jnp.ones((n_nodes,), jnp.float32)          # self loop
    deg = deg.at[t].add(ones)
    inv_sqrt = jax.lax.rsqrt(deg)
    coef = (inv_sqrt[s] * inv_sqrt[t] * ones)[:, None].astype(h.dtype)
    msgs = h[s] * coef
    agg = jax.ops.segment_sum(msgs, t, num_segments=n_nodes)
    return agg + h * (inv_sqrt ** 2)[:, None].astype(h.dtype)


def forward(params: dict, x: jax.Array, edges: jax.Array,
            cfg: GCNConfig) -> jax.Array:
    """x: [N, F], edges: [E, 2] -> logits [N, C] (or [C] after readout)."""
    h = x.astype(cfg.dtype)
    n = x.shape[0]
    for i, (w, b) in enumerate(zip(params["w"], params["b"])):
        h = _sym_norm_agg(h, edges, n) @ w + b
        if i < cfg.n_layers - 1:
            h = jax.nn.relu(h)
    if cfg.readout == "mean":
        h = h.mean(0)
    return h.astype(jnp.float32)


def loss(params: dict, batch: dict, cfg: GCNConfig) -> jax.Array:
    """batch: x [N,F], edges [E,2], labels [N] (-1 = not in train mask)."""
    logits = forward(params, batch["x"], batch["edges"], cfg)
    labels = batch["labels"]
    mask = labels >= 0
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[:, None],
                               axis=-1)[:, 0]
    return ((logz - gold) * mask).sum() / jnp.maximum(mask.sum(), 1)


def molecule_loss(params: dict, batch: dict, cfg: GCNConfig) -> jax.Array:
    """Batched small graphs: x [G,n,F], edges [G,e,2], labels [G]."""
    logits = jax.vmap(lambda x, e: forward(params, x, e, cfg))(
        batch["x"], batch["edges"])                       # [G, C]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["labels"][:, None], -1)[:, 0]
    return (logz - gold).mean()


# -------------------------------------------------------- neighbor sampler --

def sample_block(key: jax.Array, indptr: jax.Array, indices: jax.Array,
                 seeds: jax.Array, fanout: int
                 ) -> tuple[jax.Array, jax.Array]:
    """One-hop uniform neighbor sampling (with replacement) from CSR.

    seeds: [B] node ids. Returns (neighbors [B, fanout], edges [B*fanout, 2]
    as (neighbor -> seed) pairs).  Isolated nodes self-loop.
    """
    deg = (indptr[seeds + 1] - indptr[seeds]).astype(jnp.int32)   # [B]
    r = jax.random.randint(key, (seeds.shape[0], fanout), 0, 1 << 30)
    off = r % jnp.maximum(deg, 1)[:, None]
    idx = indptr[seeds][:, None] + off
    nbrs = jnp.where(deg[:, None] > 0, indices[idx], seeds[:, None])
    edges = jnp.stack([nbrs.reshape(-1),
                       jnp.repeat(seeds, fanout)], axis=1)
    return nbrs, edges


def sampled_subgraph(key: jax.Array, indptr: jax.Array, indices: jax.Array,
                     seeds: jax.Array, fanouts: tuple[int, ...]
                     ) -> tuple[jax.Array, jax.Array]:
    """Multi-hop sampling: returns (node ids [N_blk], edges [E_blk, 2])
    with LOCAL node indexing (position in the node-id array).

    Static shapes: N_blk = B * prod(1+fanout...) upper bound via
    concatenation; duplicate nodes are kept (extra compute, exact result —
    same static-shape trade the LSS tables make).
    """
    frontier = seeds
    all_nodes = [seeds]
    all_edges = []
    offset = 0
    for i, f in enumerate(fanouts):
        key, kk = jax.random.split(key)
        nbrs, _ = sample_block(kk, indptr, indices, frontier, f)
        flat = nbrs.reshape(-1)
        child_off = offset + frontier.shape[0] if i == 0 else offset
        # local edges: neighbor j of frontier node i -> edge (nbr_pos, i_pos)
        nbr_pos = sum(n.shape[0] for n in all_nodes) + jnp.arange(flat.shape[0])
        dst_pos = offset + jnp.repeat(jnp.arange(frontier.shape[0]), f)
        all_edges.append(jnp.stack([nbr_pos, dst_pos], 1))
        offset = sum(n.shape[0] for n in all_nodes)
        all_nodes.append(flat)
        frontier = flat
    nodes = jnp.concatenate(all_nodes)
    edges = jnp.concatenate(all_edges).astype(jnp.int32)
    return nodes, edges
