"""XLA environment knobs that must be set BEFORE jax is imported.

Deliberately a top-level jax-free module (``repro/__init__`` is too):
``XLA_FLAGS`` is parsed once at backend initialization, so launchers
edit it first and import jax after — importing this helper must not
drag jax in transitively.
"""

from __future__ import annotations

import os

__all__ = ["force_host_device_count"]

_FLAG = "--xla_force_host_platform_device_count"


def force_host_device_count(n: int, env=os.environ) -> str:
    """Set ``--xla_force_host_platform_device_count=n`` in ``XLA_FLAGS``,
    PRESERVING every other flag already there (a user's
    ``--xla_cpu_enable_fast_math`` etc. must survive the launcher).
    Replaces an existing device-count flag.  Returns the new value.
    """
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if not f.startswith(f"{_FLAG}=") and f != _FLAG]
    flags.append(f"{_FLAG}={int(n)}")
    env["XLA_FLAGS"] = " ".join(flags)
    return env["XLA_FLAGS"]
