"""Synthetic dataset generators (no internet in this environment).

Every generator plants TOPIC STRUCTURE — the property real XMC / LM /
recsys data has and that LSS exploits (learned hyperplanes can co-bucket a
topic's labels with its queries; unstructured random data provably cannot
be partitioned better than chance, see tests/test_lss_learning.py).

Dataset dims mirror the paper's Table 4 stand-ins where used by the
benchmarks (Wiki10-31k, Delicious-200K, Text8, Wiki-Text-2).
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np


class XCData(NamedTuple):
    x: np.ndarray        # int32 [n, max_in]  BoW token ids, -1 pad
    labels: np.ndarray   # int32 [n, max_labels], -1 pad
    n_topics: int


def xc_dataset(seed: int, n_samples: int, input_dim: int, output_dim: int,
               n_topics: int = 64, max_in: int = 32, max_labels: int = 4,
               label_skew: float = 1.2, sig_tokens: int = 6,
               noise_frac: float = 0.35) -> XCData:
    """Topic-planted extreme classification.

    Two-level structure mirroring real XMC data:
      * topics own slices of the input vocabulary and label space
        (zipf-popular) — this is the CLUSTER structure LSS's learned
        hyperplanes exploit;
      * each label carries ``sig_tokens`` signature tokens from its
        topic's vocab slice — this makes labels sample-predictable
        (bounded Bayes error), so Full/LSS P@1 are meaningful.
    A sample = signature tokens of its 1..max_labels/2 labels + topic
    noise tokens.
    """
    rng = np.random.default_rng(seed)
    tok_topic = rng.integers(0, n_topics, size=input_dim)      # token->topic
    lab_topic = rng.integers(0, n_topics, size=output_dim)     # label->topic
    tok_by_topic = [np.where(tok_topic == t)[0] for t in range(n_topics)]
    lab_by_topic = [np.where(lab_topic == t)[0] for t in range(n_topics)]
    # label signature tokens (within the label's topic slice)
    sig = np.zeros((output_dim, sig_tokens), np.int64)
    for j in range(output_dim):
        pool = tok_by_topic[lab_topic[j]]
        if len(pool) == 0:
            pool = np.arange(input_dim)
        sig[j] = pool[rng.integers(0, len(pool), size=sig_tokens)]
    # topic popularity ~ zipf
    pop = (1.0 / np.arange(1, n_topics + 1) ** label_skew)
    pop /= pop.sum()

    x = np.full((n_samples, max_in), -1, np.int32)
    y = np.full((n_samples, max_labels), -1, np.int32)
    n_sig = max(1, int(max_in * (1 - noise_frac)))
    for i in range(n_samples):
        t = rng.choice(n_topics, p=pop)
        pool_l = lab_by_topic[t]
        if len(pool_l) == 0:
            pool_l = np.arange(output_dim)
        k = rng.integers(1, max(max_labels // 2, 1) + 1)
        labs = np.unique(pool_l[rng.integers(0, len(pool_l), size=k)])
        toks = sig[labs].reshape(-1)
        toks = toks[rng.permutation(len(toks))][:n_sig]
        pool_t = tok_by_topic[t]
        if len(pool_t):
            noise = pool_t[rng.integers(0, len(pool_t),
                                        size=max_in - len(toks))]
            toks = np.concatenate([toks, noise])
        x[i, :len(toks[:max_in])] = toks[:max_in]
        y[i, :len(labs)] = labs[:max_labels]
    return XCData(x, y, n_topics)


def lm_dataset(seed: int, n_tokens: int, vocab: int, seq_len: int,
               n_topics: int = 32) -> np.ndarray:
    """Topic-switching zipf LM stream -> [n_seqs, seq_len] int32."""
    rng = np.random.default_rng(seed)
    tok_topic = rng.integers(0, n_topics, size=vocab)
    by_topic = [np.where(tok_topic == t)[0] for t in range(n_topics)]
    n_seqs = n_tokens // seq_len
    out = np.zeros((n_seqs, seq_len), np.int32)
    for i in range(n_seqs):
        t = rng.integers(0, n_topics)
        pos = 0
        while pos < seq_len:
            run = int(rng.integers(8, 32))
            pool = by_topic[t]
            ranks = rng.zipf(1.3, size=run) % max(len(pool), 1)
            out[i, pos:pos + run] = pool[ranks][: seq_len - pos]
            pos += run
            if rng.random() < 0.2:
                t = rng.integers(0, n_topics)
    return out


def ctr_dataset(seed: int, n: int, n_fields: int, vocab_per_field: int
                ) -> tuple[np.ndarray, np.ndarray]:
    """Criteo-like CTR with a planted logistic ground truth.

    Returns (ids [n, n_fields] field-local int32, labels [n] {0,1}).
    """
    rng = np.random.default_rng(seed)
    # zipf-distributed ids (realistic table access pattern)
    ids = (rng.zipf(1.2, size=(n, n_fields)) - 1) % vocab_per_field
    w = rng.normal(0, 1.0, size=(n_fields, 16))
    emb = rng.normal(0, 0.3, size=(n_fields, vocab_per_field, 2))
    # ground truth = sum of per-field effects + one pairwise interaction
    eff = np.take_along_axis(emb[:, :, 0].T[None].repeat(n, 0),
                             ids[:, None, :], axis=2)
    s = emb[np.arange(n_fields)[None, :], ids, 0].sum(1)
    s += emb[0, ids[:, 0], 1] * emb[1, ids[:, 1], 1] * 3.0
    p = 1 / (1 + np.exp(-(s - s.mean()) / (s.std() + 1e-6)))
    labels = (rng.random(n) < p).astype(np.int32)
    return ids.astype(np.int32), labels


def seqrec_dataset(seed: int, n_users: int, seq_len: int, n_items: int,
                   n_clusters: int = 50, mask_prob: float = 0.2
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Cluster-random-walk item sequences + cloze masking for BERT4Rec.

    Returns (seq [n, S] with masked positions id-preserved, labels [n, S]
    with -1 at unmasked positions).
    """
    rng = np.random.default_rng(seed)
    item_cluster = rng.integers(0, n_clusters, size=n_items)
    by_cluster = [np.where(item_cluster == c)[0] for c in range(n_clusters)]
    seq = np.zeros((n_users, seq_len), np.int32)
    for i in range(n_users):
        c = rng.integers(0, n_clusters)
        for s in range(seq_len):
            if rng.random() < 0.1:
                c = rng.integers(0, n_clusters)
            pool = by_cluster[c]
            seq[i, s] = pool[rng.integers(0, len(pool))] if len(pool) else 0
    mask = rng.random((n_users, seq_len)) < mask_prob
    labels = np.where(mask, seq, -1).astype(np.int32)
    return seq, labels


def graph_dataset(seed: int, n_nodes: int, n_edges: int, d_feat: int,
                  n_classes: int, homophily: float = 0.8
                  ) -> dict[str, np.ndarray]:
    """Homophilous random graph: nodes get classes; edges prefer same-class
    endpoints; features = class centroid + noise."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, size=n_nodes)
    cent = rng.normal(0, 1, size=(n_classes, d_feat))
    x = cent[labels] + rng.normal(0, 0.8, size=(n_nodes, d_feat))
    src = rng.integers(0, n_nodes, size=n_edges)
    dst = np.where(rng.random(n_edges) < homophily,
                   # same-class partner: random node then snap to a same-class one
                   rng.permutation(n_nodes)[src % n_nodes],
                   rng.integers(0, n_nodes, size=n_edges))
    same = rng.random(n_edges) < homophily
    # resample dst for homophilous edges from the same class as src
    by_class = [np.where(labels == c)[0] for c in range(n_classes)]
    dst_h = np.array([by_class[labels[s]][rng.integers(len(by_class[labels[s]]))]
                      for s in src[same]]) if same.any() else np.array([], np.int64)
    dst[same] = dst_h
    train_mask = rng.random(n_nodes) < 0.6
    return {
        "x": x.astype(np.float32),
        "edges": np.stack([src, dst], 1).astype(np.int32),
        "labels": labels.astype(np.int32),
        "train_labels": np.where(train_mask, labels, -1).astype(np.int32),
    }


def to_csr(edges: np.ndarray, n_nodes: int) -> tuple[np.ndarray, np.ndarray]:
    """Edge list -> (indptr [N+1], indices [E]) for the neighbor sampler."""
    order = np.argsort(edges[:, 1], kind="stable")
    sorted_dst = edges[order, 1]
    indices = edges[order, 0].astype(np.int32)
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.add.at(indptr, sorted_dst + 1, 1)
    indptr = np.cumsum(indptr)
    return indptr.astype(np.int32), indices
