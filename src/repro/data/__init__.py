"""data subpackage."""
