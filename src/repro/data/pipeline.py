"""Deterministic, checkpoint-resumable, mesh-sharded batch pipeline.

Design constraints from the 1000-node target:
  * iterator state is ONE integer (global step) + the shuffle seed — a
    restore on a different mesh shape resumes mid-epoch deterministically;
  * batches are placed with NamedSharding over the data axis so pjit never
    re-shards the input;
  * per-epoch Fisher-Yates shuffle keyed by (seed, epoch).
"""

from __future__ import annotations

from typing import Any, Iterator

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class ShardedBatchIterator:
    """Yields dict batches, sharded over ``data_axes`` of ``mesh``."""

    def __init__(self, arrays: dict[str, np.ndarray], batch_size: int,
                 *, seed: int = 0, mesh: Mesh | None = None,
                 data_axes: tuple[str, ...] = ("data",),
                 start_step: int = 0, drop_remainder: bool = True):
        sizes = {k: v.shape[0] for k, v in arrays.items()}
        assert len(set(sizes.values())) == 1, sizes
        self.arrays = arrays
        self.n = next(iter(sizes.values()))
        self.batch_size = batch_size
        self.seed = seed
        self.mesh = mesh
        self.data_axes = data_axes
        self.step = start_step
        self.batches_per_epoch = self.n // batch_size
        assert self.batches_per_epoch > 0, (self.n, batch_size)

    # -- checkpointable state ------------------------------------------
    def state_dict(self) -> dict[str, int]:
        return {"step": self.step, "seed": self.seed}

    def load_state_dict(self, state: dict[str, int]) -> None:
        self.step = int(state["step"])
        self.seed = int(state["seed"])

    # -- iteration ------------------------------------------------------
    def _epoch_perm(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, epoch))
        return rng.permutation(self.n)

    def __iter__(self) -> Iterator[dict[str, Any]]:
        return self

    def __next__(self) -> dict[str, Any]:
        epoch = self.step // self.batches_per_epoch
        i = self.step % self.batches_per_epoch
        perm = self._epoch_perm(epoch)
        idx = perm[i * self.batch_size:(i + 1) * self.batch_size]
        batch = {k: v[idx] for k, v in self.arrays.items()}
        self.step += 1
        if self.mesh is not None:
            spec = P(self.data_axes)
            batch = {
                k: jax.device_put(v, NamedSharding(self.mesh, P(
                    self.data_axes, *([None] * (v.ndim - 1)))))
                for k, v in batch.items()
            }
        return batch
