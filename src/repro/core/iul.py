"""Index Update Loss (paper §3.3): learn the hyperplanes.

The learning signal is *retrieval-aware* (this is the paper's key deviation
from standard learning-to-MIPS): pairs are mined against the CURRENT tables —

  positive (q, w_y):  label y missed by the retrieved set S and q·w_y > t1
  negative (q, w_i):  i ∈ S, not a label, and q·w_i < t2

and the loss pulls positives into the query's bucket / pushes negatives out
via the tanh relaxation K(x) = tanh(theta^T x):

  IUL = -Σ_{P+} log σ(K(w)·K(q)) - Σ_{P-} log(1 - σ(K(w)·K(q)))

Static-shape adaptation: pairs carry a validity mask instead of being
compacted; the two sides are *balance-weighted* (each side normalised by its
valid count), matching the paper's g = min(|P+|,|P-|) truncation in
expectation without data-dependent shapes.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import simhash
from repro.core.lss import (LSSConfig, LSSIndex, build_index, retrieve,
                            sparse_logits_gather, label_recall)
from repro.optim import adamw_init, adamw_update

__all__ = ["MinedPairs", "mine_pairs", "calibrate_thresholds", "iul_loss",
           "iul_train_epoch", "fit_lss", "collision_prob",
           "IULState", "iul_init", "iul_refit_epoch", "calib_recall"]


class MinedPairs(NamedTuple):
    """Static-shape pair batch. w-ids index the WOL; masks mark validity."""

    pos_w: jax.Array     # int32 [B, NL]  label neuron ids (or 0 if invalid)
    pos_mask: jax.Array  # bool  [B, NL]
    neg_w: jax.Array     # int32 [B, C]   retrieved non-label ids
    neg_mask: jax.Array  # bool  [B, C]


def calibrate_thresholds(q_aug: jax.Array, w_aug: jax.Array,
                         labels: jax.Array, cfg: LSSConfig
                         ) -> tuple[jax.Array, jax.Array]:
    """Data-driven t1/t2 (the paper hand-tunes them per dataset).

    t1 = low quantile of *label* inner products  (most labels count as
         positives unless their logit is hopeless), t2 = high quantile of
         *all sampled* inner products (most non-labels qualify as negatives
         unless they are genuinely strong).  Guarantees t1 > t2 is NOT
         required by construction; the paper requires t1 > t2 for a "valid
         setting" — we enforce it by clamping t2 below t1.
    """
    lab_ip = jnp.einsum("bd,bld->bl", q_aug,
                        w_aug[jnp.maximum(labels, 0)])
    lab_ip = jnp.where(labels >= 0, lab_ip, jnp.nan)
    t1 = jnp.nanquantile(lab_ip, cfg.t1_quantile)
    all_ip = q_aug @ w_aug[:: max(1, w_aug.shape[0] // 512)].T
    t2 = jnp.quantile(all_ip, cfg.t2_quantile)
    return t1, jnp.minimum(t2, t1 - 1e-6)


def mine_pairs(q_aug: jax.Array, labels: jax.Array, w_aug: jax.Array,
               index: LSSIndex, t1: jax.Array, t2: jax.Array) -> MinedPairs:
    """Algorithm 1 lines 3-11, batched and static-shape.

    labels: int32 ``[B, NL]`` padded with -1.
    """
    cand_ids, _ = retrieve(q_aug, index)                     # [B, C]
    # positives: labels NOT in S with inner product > t1
    in_set = (labels[:, :, None] == cand_ids[:, None, :]).any(-1)
    lab_ip = jnp.einsum("bd,bld->bl", q_aug.astype(jnp.float32),
                        w_aug[jnp.maximum(labels, 0)].astype(jnp.float32))
    pos_mask = (labels >= 0) & ~in_set & (lab_ip > t1)
    # negatives: retrieved non-labels with inner product < t2
    is_label = (cand_ids[:, :, None] == labels[:, None, :]).any(-1)
    cand_ip = sparse_logits_gather(q_aug, w_aug, cand_ids)
    neg_mask = (cand_ids >= 0) & ~is_label & (cand_ip < t2)
    return MinedPairs(jnp.maximum(labels, 0), pos_mask,
                      jnp.maximum(cand_ids, 0), neg_mask)


def iul_loss(theta: jax.Array, q_aug: jax.Array, w_aug: jax.Array,
             pairs: MinedPairs) -> jax.Array:
    """Balanced IUL (paper eq. 1).  log σ via log_sigmoid for stability."""
    kq = simhash.soft_codes(q_aug, theta)                    # [B, KL]
    kw_pos = simhash.soft_codes(w_aug[pairs.pos_w], theta)   # [B, NL, KL]
    kw_neg = simhash.soft_codes(w_aug[pairs.neg_w], theta)   # [B, C, KL]
    ip_pos = jnp.einsum("bk,blk->bl", kq, kw_pos)
    ip_neg = jnp.einsum("bk,bck->bc", kq, kw_neg)
    # -log σ(x) = -log_sigmoid(x); -log(1-σ(x)) = -log_sigmoid(-x)
    pos_terms = -jax.nn.log_sigmoid(ip_pos) * pairs.pos_mask
    neg_terms = -jax.nn.log_sigmoid(-ip_neg) * pairs.neg_mask
    n_pos = jnp.maximum(pairs.pos_mask.sum(), 1.0)
    n_neg = jnp.maximum(pairs.neg_mask.sum(), 1.0)
    # balance: each side contributes its mean (≡ g pairs per side, g=min)
    return pos_terms.sum() / n_pos + neg_terms.sum() / n_neg


def collision_prob(theta: jax.Array, q_aug: jax.Array, w_aug: jax.Array,
                   pairs: MinedPairs, k_bits: int, n_tables: int
                   ) -> tuple[jax.Array, jax.Array]:
    """Fig-2 metric: P(all K bits of a table collide) for pos / neg pairs."""
    def table_collide(x, y):     # [..., KL] bool each (broadcastable)
        eq = x == y
        eq = eq.reshape(eq.shape[:-1] + (n_tables, k_bits))
        return eq.all(-1).astype(jnp.float32).mean(-1)       # [...] over L
    bq = simhash.hash_bits(q_aug, theta)                     # [B, KL]
    bp = simhash.hash_bits(w_aug[pairs.pos_w], theta)        # [B, NL, KL]
    bn = simhash.hash_bits(w_aug[pairs.neg_w], theta)
    cp = table_collide(bq[:, None, :], bp)
    cn = table_collide(bq[:, None, :], bn)
    p_pos = jnp.sum(cp * pairs.pos_mask) / jnp.maximum(pairs.pos_mask.sum(), 1)
    p_neg = jnp.sum(cn * pairs.neg_mask) / jnp.maximum(pairs.neg_mask.sum(), 1)
    return p_pos, p_neg


def iul_train_epoch(theta, opt_state, q_aug_all, labels_all, w_aug, index,
                    t1, t2, cfg: LSSConfig, key):
    """One epoch: mine per batch against the frozen epoch index, Adam on θ."""
    n = q_aug_all.shape[0]
    bsz = min(cfg.iul_batch, n)
    n_batches = n // bsz
    perm = jax.random.permutation(key, n)[: n_batches * bsz]
    order = perm.reshape(n_batches, bsz)

    grad_fn = jax.value_and_grad(iul_loss)

    def body(carry, idx):
        theta, opt_state = carry
        q = q_aug_all[idx]
        lab = labels_all[idx]
        pairs = mine_pairs(q, lab, w_aug, index, t1, t2)

        def inner(carry, _):
            theta, opt_state = carry
            loss, g = grad_fn(theta, q, w_aug, pairs)
            theta, opt_state = adamw_update(g, opt_state, theta,
                                            lr=cfg.iul_lr)
            return (theta, opt_state), loss

        (theta, opt_state), losses = jax.lax.scan(
            inner, (theta, opt_state), None, length=cfg.iul_inner_steps)
        cp, cn = collision_prob(theta, q, w_aug, pairs, cfg.k_bits,
                                cfg.n_tables)
        return (theta, opt_state), (losses[-1], cp, cn)

    (theta, opt_state), hist = jax.lax.scan(body, (theta, opt_state), order)
    return theta, opt_state, hist


# ----------------------------------------------- snapshot-based entry --
# Module-level jitted programs shared by the offline fit AND the online
# refresher: jax.jit caches per function object, so per-call jax.jit
# wrappers would retrace every refresh cycle.  ``cfg`` (a hashable
# NamedTuple) is the static argument.
_EPOCH_JIT = jax.jit(iul_train_epoch, static_argnames=("cfg",))
_REBUILD_JIT = jax.jit(build_index, static_argnames=("cfg",))


class IULState(NamedTuple):
    """Resumable IUL training state over one calibration snapshot.

    Everything an epoch step needs besides the (immutable) snapshot
    arrays: the hyperplanes being trained, the Adam moments, the mined
    thresholds, and the RNG key.  A background refresher carries this
    across refresh cycles so training CONTINUES from the serving
    hyperplanes instead of restarting cold each interval."""

    theta: jax.Array
    opt_state: Any
    t1: jax.Array
    t2: jax.Array
    key: jax.Array


def iul_init(key, q_aug: jax.Array, labels_all: jax.Array,
             w_aug: jax.Array, cfg: LSSConfig,
             theta: jax.Array | None = None) -> IULState:
    """Seed an IUL training stream against a calibration snapshot.

    ``theta=None`` draws fresh hyperplanes (the offline ``fit_lss``
    path, preserving its exact RNG sequence); passing the SERVING
    index's theta resumes training from it (the online refresh path:
    the snapshot is new, the hash is warm)."""
    if theta is None:
        k0, key = jax.random.split(key)
        theta = simhash.init_hyperplanes(k0, w_aug.shape[1], cfg.k_bits,
                                         cfg.n_tables)
    t1, t2 = calibrate_thresholds(q_aug, w_aug, labels_all, cfg)
    return IULState(theta, adamw_init(theta), t1, t2, key)


def iul_refit_epoch(state: IULState, q_aug: jax.Array,
                    labels_all: jax.Array, w_aug: jax.Array,
                    index: LSSIndex, cfg: LSSConfig
                    ) -> tuple[IULState, LSSIndex, dict]:
    """ONE training epoch + rebuild against a frozen snapshot — the
    online refresher's unit of work (pure jax, no engine state, safe
    entirely off the serving hot path).  Mines against ``index`` (the
    previous rebuild, per Algorithm 1), returns the advanced state, the
    candidate index, and the epoch's metrics."""
    key, ke = jax.random.split(state.key)
    theta, opt_state, (loss, cp, cn) = _EPOCH_JIT(
        state.theta, state.opt_state, q_aug, labels_all, w_aug, index,
        state.t1, state.t2, cfg, ke)
    new_index = _REBUILD_JIT(w_aug, theta, cfg)
    info = {"loss": float(loss.mean()),
            "p_collide_pos": float(cp.mean()),
            "p_collide_neg": float(cn.mean()),
            "recall": calib_recall(new_index, q_aug, labels_all)}
    return state._replace(theta=theta, opt_state=opt_state, key=key), \
        new_index, info


def calib_recall(index: LSSIndex, q_aug: jax.Array, labels_all: jax.Array,
                 n: int = 1024) -> float:
    """Calibration-set label recall of ``index`` (first ``n`` rows) —
    the model-selection metric fit_lss and the refresher share."""
    cand, _ = retrieve(q_aug[: min(n, q_aug.shape[0])], index)
    return float(label_recall(cand, labels_all[: cand.shape[0]]))


def fit_lss(key, q_all: jax.Array, labels_all: jax.Array, w: jax.Array,
            b: jax.Array | None, cfg: LSSConfig,
            verbose: bool = False):
    """Full offline preprocessing (paper Algorithm 1, iterated).

    Returns (index, history dict of per-epoch metrics).
    """
    w_aug = simhash.augment_neurons(w, b)
    q_aug = simhash.augment_queries(q_all)
    state = iul_init(key, q_aug, labels_all, w_aug, cfg)

    hist = {"loss": [], "p_collide_pos": [], "p_collide_neg": [],
            "recall": []}
    # One compiled rebuild reused every epoch (module-level _REBUILD_JIT):
    # hash all m neurons, build all L tables (vmapped), and re-bucketize
    # the weight slabs in a single XLA program instead of re-dispatching
    # the whole op chain eagerly per epoch — the dominant fit_lss cost at
    # m >= 1M on CPU.
    index = _REBUILD_JIT(w_aug, state.theta, cfg)
    best_index, best_rec = index, -1.0
    for ep in range(cfg.iul_epochs):
        state, index, info = iul_refit_epoch(state, q_aug, labels_all,
                                             w_aug, index, cfg)
        rec = info["recall"]
        # model selection: IUL's mining distribution shifts every rebuild,
        # so individual epochs can regress — serve the best epoch's index
        # (calibration recall), not the last one.
        if rec > best_rec:
            best_rec, best_index = rec, index
        hist["loss"].append(info["loss"])
        hist["p_collide_pos"].append(info["p_collide_pos"])
        hist["p_collide_neg"].append(info["p_collide_neg"])
        hist["recall"].append(rec)
        if verbose:
            print(f"[iul] epoch {ep}: loss={info['loss']:.4f} "
                  f"P+collide={info['p_collide_pos']:.3f} "
                  f"P-collide={info['p_collide_neg']:.3f} recall={rec:.3f}")
    return best_index, hist
