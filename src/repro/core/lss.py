"""LSS retrieval + sparse-WOL inference (paper Algorithm 2, TPU-native).

Pipeline per query embedding q (from the layer below the WOL):

    q --augment--> [q,0] --theta--> L bucket ids --tables--> candidate ids
      --bucket-major slab / gather--> sparse logits --dedup+mask--> top-k

Everything is static-shape: the candidate set is ``[B, L*P]`` with -1
padding; duplicates across tables are masked (not compacted) before
ranking, which preserves exact top-k semantics.

Retrieval and scoring dispatch through the kernel registry
(``repro.kernels.registry``): on a bucket-major index, ``lss_forward``
routes the whole pipeline through the fused ``lss_topk`` op (one Pallas
pass on TPU, the jnp oracle on CPU); ``retrieve`` and
``sparse_logits_bucketed`` route through the ``simhash_codes`` /
``bucket_logits`` ops.  Pass ``impl=`` to pin an implementation
(``ref`` | ``pallas`` | ``pallas_interpret``) or leave ``None`` for
backend auto-selection.  ``dedup=`` likewise pins the cross-table dedup
algorithm (``quadratic`` | ``bitonic``); left ``None``, the registry
auto-switches to the bitonic sorting network once C = L*P crosses the
measured crossover, so large candidate counts are a strategy change,
not a hard wall — a warning fires only past the VMEM budget derived
from the actual (C, d, P) shape (``kernels.lss_topk.ops``).

Slab storage is a third knob, resolved HERE at :func:`build_index` time
rather than per call: ``LSSConfig.slab_dtype`` (``fp32`` | ``bf16`` |
``int8``; None = the ``lss_topk.slab_dtype`` registry strategy, env
``REPRO_LSS_SLAB_DTYPE``).  A quantized index stores its bucket-major
slabs in the compressed format (int8 carries a per-neuron-row scale
table in ``LSSIndex.w_scale``) and both lss_topk impls dequantize on
the fly.  Because ``fit_lss`` rebuilds the index through this same
constructor every IUL epoch, refits REQUANTIZE automatically — there is
no path that silently mixes fp32 tables with stale quantized slabs.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import simhash
from repro.core.tables import LSSTables, build_tables, bucketize_weights
from repro.kernels import bucket_logits, lss_topk, simhash_codes
from repro.kernels.lss_topk.slabs import (dequantize_slabs, quantize_slabs,
                                          resolve_slab_dtype)

__all__ = [
    "LSSConfig", "LSSIndex", "build_index", "retrieve", "dedup_mask",
    "sparse_logits_gather", "sparse_logits_bucketed", "lss_forward",
    "lss_predict", "label_recall", "precision_at_k", "avg_sample_size",
]

NEG_INF = -1e30


class LSSConfig(NamedTuple):
    k_bits: int = 4
    n_tables: int = 1
    capacity: int = 0          # 0 -> auto: 2 * m / 2^K rounded up to 8
    use_bucket_major: bool = True   # materialise [L, 2^K, P, d] weight slabs
    # slab storage format: fp32 | bf16 | int8, None = registry strategy
    # (lss_topk.slab_dtype / $REPRO_LSS_SLAB_DTYPE, auto -> fp32)
    slab_dtype: str | None = None
    # IUL pair-mining thresholds (inner-product quantiles; see iul.py)
    t1_quantile: float = 0.3
    t2_quantile: float = 0.7
    iul_lr: float = 1e-3
    iul_epochs: int = 8
    iul_batch: int = 256
    iul_inner_steps: int = 8   # gradient steps per mined pair batch

    def resolve_capacity(self, m: int) -> int:
        if self.capacity:
            return self.capacity
        p = -(-2 * m // 2 ** self.k_bits)        # 2x the perfectly-even load
        return max(8, -(-p // 8) * 8)            # round up to a lane multiple


class LSSIndex(NamedTuple):
    """The frozen serving-time index (a pytree; shardable under pjit).

    ``w_bucketed`` may store fp32, bf16 or int8 slabs — the storage
    format is recovered from the array dtype, and ``w_scale`` is the
    int8 format's per-neuron-row fp32 scale table (None otherwise).
    Hash tables are always built from the fp32 ``w_aug``, so candidate
    retrieval (the paper's label recall) is identical across formats;
    only the ranked logits see quantization error.
    """

    theta: jax.Array             # [d_aug, K*L] learned hyperplanes
    tables: LSSTables            # bucket-major neuron ids
    w_bucketed: jax.Array | None  # [L, 2^K, P, d_aug] or None (gather path)
    w_scale: jax.Array | None = None  # [L, 2^K, P] f32, int8 storage only


jax.tree_util.register_pytree_node(
    LSSIndex,
    lambda i: ((i.theta, i.tables, i.w_bucketed, i.w_scale), None),
    lambda _, leaves: LSSIndex(*leaves),
)


def build_index(w_aug: jax.Array, theta: jax.Array, cfg: LSSConfig) -> LSSIndex:
    """(Re)build tables (and slabs) for the current hyperplanes.

    Resolves the slab storage format (``cfg.slab_dtype`` >
    ``lss_topk.slab_dtype`` strategy) and quantizes the bucket-major
    slabs at construction, so every rebuild — including each IUL refit
    epoch inside ``fit_lss``'s jitted ``rebuild`` — requantizes from the
    current fp32 weights.
    """
    cap = cfg.resolve_capacity(w_aug.shape[0])
    tables = build_tables(w_aug, theta, cfg.k_bits, cfg.n_tables, cap)
    if not cfg.use_bucket_major:
        return LSSIndex(theta, tables, None, None)
    wb, w_scale = quantize_slabs(bucketize_weights(w_aug, tables),
                                 resolve_slab_dtype(cfg.slab_dtype))
    return LSSIndex(theta, tables, wb, w_scale)


def retrieve(q_aug: jax.Array, index: LSSIndex, impl: str | None = None
             ) -> tuple[jax.Array, jax.Array]:
    """Query the L tables.

    Returns:
      cand_ids: int32 ``[B, L*P]`` neuron ids (-1 = empty slot)
      buckets:  int32 ``[B, L]`` the bucket hit in each table
    """
    t = index.tables
    # registry-dispatched simhash_codes on the normalized queries is
    # exactly simhash.bucket_ids (sign is scale-invariant; the ref impls
    # share the same fp32 op sequence)
    buckets = simhash_codes(simhash.unit(q_aug), index.theta, t.k_bits,
                            t.n_tables, impl=impl)
    # table_ids[l, buckets[b, l]] for every (b, l)
    cand = jnp.take_along_axis(
        t.table_ids[None],                       # [1, L, 2^K, P]
        buckets.T[None, :, :, None],             # [1, L, B, 1]
        axis=2,
    )[0]                                         # [L, B, P]
    cand_ids = jnp.swapaxes(cand, 0, 1).reshape(q_aug.shape[0], -1)
    return cand_ids, buckets


def dedup_mask(ids: jax.Array) -> jax.Array:
    """Bool mask ``[B, C]``: True for the first occurrence of each non-neg id.

    Sort-based: duplicates and -1 padding get False.  Static shape.
    """
    order = jnp.argsort(ids, axis=-1, stable=True)
    sorted_ids = jnp.take_along_axis(ids, order, axis=-1)
    first = jnp.concatenate(
        [jnp.ones_like(sorted_ids[:, :1], bool),
         sorted_ids[:, 1:] != sorted_ids[:, :-1]], axis=-1)
    first &= sorted_ids >= 0
    # scatter back to original positions
    b = jnp.arange(ids.shape[0])[:, None]
    mask = jnp.zeros(ids.shape, bool).at[b, order].set(first)
    return mask


def sparse_logits_gather(q_aug: jax.Array, w_aug: jax.Array,
                         cand_ids: jax.Array) -> jax.Array:
    """Reference path: random-gather W rows then batched dot.

    ``[B, d] x [m, d] x [B, C] -> [B, C]``; -1 slots get NEG_INF.
    """
    rows = w_aug[jnp.maximum(cand_ids, 0)]              # [B, C, d_aug]
    logits = jnp.einsum("bd,bcd->bc", q_aug.astype(jnp.float32),
                        rows.astype(jnp.float32))
    return jnp.where(cand_ids >= 0, logits, NEG_INF)


def sparse_logits_bucketed(q_aug: jax.Array, index: LSSIndex,
                           buckets: jax.Array, impl: str | None = None
                           ) -> tuple[jax.Array, jax.Array]:
    """Bucket-major path: one contiguous ``[P, d]`` slab per (query, table).

    Routes through the registry ``bucket_logits`` op on the flattened
    ``[S, P, d]`` slab layout (S = L * 2^K) — the jnp ref for the XLA
    path, the scalar-prefetch Pallas kernel on TPU.
    """
    t = index.tables
    # this unfused path hands whole slabs to bucket_logits, so widen
    # quantized storage up front (the fused lss_topk path widens in-kernel)
    wb = dequantize_slabs(index.w_bucketed, index.w_scale)  # [L, 2^K, P, d]
    w_flat = wb.reshape(t.n_tables * t.n_buckets, t.capacity, wb.shape[-1])
    slab_ids = buckets + jnp.arange(
        t.n_tables, dtype=buckets.dtype)[None, :] * t.n_buckets   # [B, L]
    logits = bucket_logits(q_aug, w_flat, slab_ids, impl=impl)    # [B,L,P]
    ids = t.table_ids.reshape(-1, t.capacity)[slab_ids]           # [B,L,P]
    ids = ids.reshape(q_aug.shape[0], -1)
    logits = logits.reshape(q_aug.shape[0], -1)
    return jnp.where(ids >= 0, logits, NEG_INF), ids


class LSSForward(NamedTuple):
    """Everything Algorithm 2 produces from ONE retrieval pass.

    The serving engine ranks from ``top_logits``/``top_ids`` and computes
    its sample-size / recall metrics from ``sample_size``/``cand_ids`` —
    no second ``retrieve`` call."""

    top_logits: jax.Array        # [B, k]
    top_ids: jax.Array           # [B, k]   (-1 beyond the candidate count)
    sample_size: jax.Array       # [B]      unique neurons scored per query
    cand_ids: jax.Array          # [B, C]   retrieved ids, -1 padded


def lss_forward(q: jax.Array, index: LSSIndex, w_aug: jax.Array | None,
                top_k: int = 5, *, impl: str | None = None,
                dedup: str | None = None) -> LSSForward:
    """Full Algorithm 2 with serving metrics, single retrieval pass.

    On a bucket-major index the whole retrieve -> slab logits -> dedup ->
    top-k pipeline is one registry-dispatched ``lss_topk`` op (a single
    fused Pallas pass on TPU); ``dedup`` pins its cross-table dedup
    strategy (``quadratic`` | ``bitonic``, None = auto on C).  ``w_aug``
    is only needed for the gather path (``w_bucketed is None``), which
    keeps the XLA gather lowering.
    """
    q_aug = simhash.augment_queries(q)
    if index.w_bucketed is not None:
        t = index.tables
        out = lss_topk(q_aug, index.theta, t.table_ids, index.w_bucketed,
                       top_k=top_k, impl=impl, dedup=dedup,
                       w_scale=index.w_scale)
        return LSSForward(*out)
    cand_ids, _ = retrieve(q_aug, index, impl=impl)
    logits = sparse_logits_gather(q_aug, w_aug, cand_ids)
    mask = dedup_mask(cand_ids)
    logits = jnp.where(mask, logits, NEG_INF)
    top_logits, pos = jax.lax.top_k(logits, top_k)
    top_ids = jnp.take_along_axis(cand_ids, pos, axis=-1)
    top_ids = jnp.where(top_logits > NEG_INF / 2, top_ids, -1)
    return LSSForward(top_logits, top_ids, jnp.sum(mask, axis=-1), cand_ids)


def lss_predict(q: jax.Array, index: LSSIndex, w_aug: jax.Array | None,
                top_k: int = 5, *, impl: str | None = None,
                dedup: str | None = None) -> tuple[jax.Array, jax.Array]:
    """(top-k logits, top-k neuron ids) ``[B, k]`` — see ``lss_forward``."""
    out = lss_forward(q, index, w_aug, top_k, impl=impl, dedup=dedup)
    return out.top_logits, out.top_ids


# ---------------------------------------------------------------- metrics --

def label_recall(cand_ids: jax.Array, labels: jax.Array) -> jax.Array:
    """Paper's Label Retrieval Rate: fraction of true labels retrieved.

    labels: int32 ``[B, NL]`` padded with -1.
    """
    hit = (labels[:, :, None] == cand_ids[:, None, :]).any(-1)   # [B, NL]
    valid = labels >= 0
    return jnp.sum(hit & valid) / jnp.maximum(jnp.sum(valid), 1)


def precision_at_k(pred_ids: jax.Array, labels: jax.Array, k: int) -> jax.Array:
    """Standard XMC P@k: mean over samples of |top-k ∩ labels| / k."""
    topk = pred_ids[:, :k]
    hit = (topk[:, :, None] == labels[:, None, :]) & (labels >= 0)[:, None, :]
    return jnp.mean(jnp.sum(hit.any(-1) & (topk >= 0), axis=-1) / k)


def avg_sample_size(cand_ids: jax.Array) -> jax.Array:
    """Paper's Sample Size: mean #unique neurons scored per query."""
    return jnp.mean(jnp.sum(dedup_mask(cand_ids), axis=-1))
