"""LSS core: the paper's contribution as a composable JAX module."""

from repro.core.simhash import (augment_neurons, augment_queries,
                                bucket_ids, hash_bits, init_hyperplanes,
                                pack_bits, soft_codes)
from repro.core.tables import LSSTables, bucket_load_stats, build_tables
from repro.core.lss import (LSSConfig, LSSForward, LSSIndex,
                            avg_sample_size, build_index, label_recall,
                            lss_forward, lss_predict, precision_at_k,
                            retrieve)
from repro.core.iul import (MinedPairs, calibrate_thresholds, collision_prob,
                            fit_lss, iul_loss, mine_pairs)

__all__ = [
    "augment_neurons", "augment_queries", "bucket_ids", "hash_bits",
    "init_hyperplanes", "pack_bits", "soft_codes",
    "LSSTables", "bucket_load_stats", "build_tables",
    "LSSConfig", "LSSForward", "LSSIndex", "avg_sample_size", "build_index",
    "label_recall", "lss_forward", "lss_predict", "precision_at_k",
    "retrieve",
    "MinedPairs", "calibrate_thresholds", "collision_prob", "fit_lss",
    "iul_loss", "mine_pairs",
]
