"""SimHash primitives for LSS.

A SimHash code of an input ``x`` under hyperplanes ``theta`` is the sign
pattern of ``theta^T x``.  LSS (the paper's contribution) *learns* the
hyperplanes; the hashing mechanics here are shared by the random
initialisation (SimHash / SLIDE baseline) and the learned index.

Conventions
-----------
* Neurons are augmented with their bias: ``c_i = [w_i, b_i]`` in R^{d+1}.
  Queries are augmented with a zero: ``[q, 0]``.  Helpers below do this.
* ``theta`` has shape ``[d_aug, K * L]`` — K bits for each of L tables.
* Bucket ids pack the K sign bits of one table into an int32 in
  ``[0, 2^K)``; shape ``[..., L]``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "augment_neurons",
    "augment_queries",
    "init_hyperplanes",
    "unit",
    "hash_bits",
    "soft_codes",
    "pack_bits",
    "bucket_ids",
]


def augment_neurons(w: jax.Array, b: jax.Array | None = None) -> jax.Array:
    """``[m, d] (+ [m])`` -> ``[m, d+1]`` neurons ``[w_i, b_i]``."""
    if b is None:
        b = jnp.zeros((w.shape[0],), w.dtype)
    return jnp.concatenate([w, b[:, None].astype(w.dtype)], axis=-1)


def augment_queries(q: jax.Array) -> jax.Array:
    """``[..., d]`` -> ``[..., d+1]`` queries ``[q, 0]``."""
    return jnp.concatenate([q, jnp.zeros(q.shape[:-1] + (1,), q.dtype)], axis=-1)


def init_hyperplanes(key: jax.Array, d_aug: int, k_bits: int, n_tables: int,
                     dtype=jnp.float32) -> jax.Array:
    """i.i.d. N(0, 1) hyperplanes, shape ``[d_aug, K * L]`` (SimHash init)."""
    return jax.random.normal(key, (d_aug, k_bits * n_tables), dtype)


def unit(x: jax.Array) -> jax.Array:
    """L2-normalize the hashed vector.  ``sign(theta^T x)`` is invariant to
    positive scaling of x, so hard buckets are unchanged — but the tanh
    relaxation would saturate at ``|theta^T x| ~ ||x|| ~ sqrt(d)`` and kill
    IUL gradients.  Normalizing is therefore part of the hash definition
    (the fused lss_topk kernel replicates it bit-for-bit)."""
    n = jnp.linalg.norm(x.astype(jnp.float32), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) / jnp.maximum(n, 1e-12))


_unit = unit   # historical private name


def hash_bits(x: jax.Array, theta: jax.Array) -> jax.Array:
    """Hard hash bits ``sign(theta^T x) > 0`` -> bool ``[..., K*L]``."""
    return (_unit(x) @ theta.astype(jnp.float32)) > 0


def soft_codes(x: jax.Array, theta: jax.Array) -> jax.Array:
    """Differentiable relaxation ``K(x) = tanh(theta^T x)`` (paper eq. 1)."""
    return jnp.tanh(_unit(x) @ theta.astype(jnp.float32))


def pack_bits(bits: jax.Array, k_bits: int, n_tables: int) -> jax.Array:
    """Pack bool bits ``[..., K*L]`` into int32 bucket ids ``[..., L]``.

    Bit j of table l is ``bits[..., l*K + j]`` with weight ``2^j``.
    """
    shaped = bits.reshape(bits.shape[:-1] + (n_tables, k_bits))
    weights = (2 ** jnp.arange(k_bits, dtype=jnp.int32))
    return jnp.sum(shaped.astype(jnp.int32) * weights, axis=-1)


def bucket_ids(x: jax.Array, theta: jax.Array, k_bits: int,
               n_tables: int) -> jax.Array:
    """``[..., d_aug]`` -> int32 bucket ids ``[..., L]`` in ``[0, 2^K)``."""
    return pack_bits(hash_bits(x, theta), k_bits, n_tables)
