"""Static-shape, bucket-major LSH tables (the TPU adaptation of LSS).

The paper's CPU implementation uses chained hash buckets of variable size.
On TPU everything must be static-shape and contiguous, so a table is:

    table_ids : int32 [L, 2^K, P]   neuron ids, bucket-major, -1 padded

and, optionally, a *bucket-major weight layout*:

    table_w   : [L, 2^K, P, d_aug]  the WOL rows physically permuted so a
                                    query touches ONE contiguous [P, d_aug]
                                    slab per table — a dynamic-slice + MXU
                                    matmul instead of a random gather.

Buckets that overflow capacity ``P`` are truncated (the IUL loss actively
balances load — paper §3.3 property 3); the overflow fraction is reported
as a first-class metric so capacity can be sized.

``bucketize_weights`` always emits fp32 slabs; quantized storage
(``lss_topk.slab_dtype`` = bf16 | int8) is applied on top by
``core.lss.build_index`` via ``kernels.lss_topk.slabs.quantize_slabs``,
AFTER bucketization — empty (-1) slots are zero rows, which every format
round-trips to exactly 0, so the "padded slots score logit 0, masked by
id" contract here is storage-format independent.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import simhash

__all__ = ["LSSTables", "build_tables", "bucketize_weights", "bucket_load_stats"]


class LSSTables(NamedTuple):
    """Pytree holding the static LSS index for one WOL."""

    table_ids: jax.Array      # int32 [L, 2^K, P], -1 = empty slot
    n_dropped: jax.Array      # int32 [L] neurons truncated by overflow
    k_bits: int               # static
    n_tables: int             # static
    capacity: int             # static P

    @property
    def n_buckets(self) -> int:
        return 2 ** self.k_bits


# `k_bits`/`n_tables`/`capacity` are static metadata, not leaves.
jax.tree_util.register_pytree_node(
    LSSTables,
    lambda t: ((t.table_ids, t.n_dropped), (t.k_bits, t.n_tables, t.capacity)),
    lambda aux, leaves: LSSTables(*leaves, *aux),
)


def _one_table(bucket_of_neuron: jax.Array, n_buckets: int,
               capacity: int) -> tuple[jax.Array, jax.Array]:
    """Build one bucket-major table from per-neuron bucket ids ``[m]``.

    Returns (ids [2^K, P], n_dropped []).  Pure static-shape: stable-sort
    neurons by bucket, rank-within-bucket via a searchsorted offset, scatter
    ranks < P into the table.
    """
    m = bucket_of_neuron.shape[0]
    order = jnp.argsort(bucket_of_neuron, stable=True)          # [m]
    sorted_buckets = bucket_of_neuron[order]                    # [m]
    # First occurrence index of each neuron's bucket in the sorted array.
    starts = jnp.searchsorted(sorted_buckets, sorted_buckets, side="left")
    rank = jnp.arange(m, dtype=jnp.int32) - starts.astype(jnp.int32)
    keep = rank < capacity
    # Scatter neuron ids into [2^K * P]; dropped ranks go to a trash slot.
    flat_pos = jnp.where(keep, sorted_buckets * capacity + rank,
                         n_buckets * capacity)
    flat = jnp.full((n_buckets * capacity + 1,), -1, jnp.int32)
    flat = flat.at[flat_pos].set(order.astype(jnp.int32), mode="drop")
    ids = flat[:-1].reshape(n_buckets, capacity)
    return ids, jnp.sum(~keep).astype(jnp.int32)


def build_tables(w_aug: jax.Array, theta: jax.Array, k_bits: int,
                 n_tables: int, capacity: int) -> LSSTables:
    """Hash every neuron and build L bucket-major tables.

    Args:
      w_aug: ``[m, d_aug]`` augmented WOL neurons.
      theta: ``[d_aug, K*L]`` hyperplanes.
    """
    buckets = simhash.bucket_ids(w_aug, theta, k_bits, n_tables)   # [m, L]
    ids, dropped = jax.vmap(_one_table, in_axes=(1, None, None))(
        buckets, 2 ** k_bits, capacity)
    return LSSTables(ids, dropped, k_bits, n_tables, capacity)


def bucketize_weights(w_aug: jax.Array, tables: LSSTables) -> jax.Array:
    """Materialise the bucket-major weight layout ``[L, 2^K, P, d_aug]``.

    Empty slots (-1) become zero rows, so a dot against them contributes a
    logit of exactly 0; retrieval masks them out by id before ranking.
    """
    safe = jnp.maximum(tables.table_ids, 0)
    w = w_aug[safe]                                   # [L, 2^K, P, d_aug]
    mask = (tables.table_ids >= 0)[..., None]
    return jnp.where(mask, w, jnp.zeros_like(w))


def bucket_load_stats(tables: LSSTables) -> dict[str, jax.Array]:
    """Load-balance metrics for EXPERIMENTS.md and capacity tuning."""
    occ = jnp.sum(tables.table_ids >= 0, axis=-1)     # [L, 2^K]
    total = occ.sum(axis=-1) + tables.n_dropped       # [L] == m
    return {
        "mean_bucket_occupancy": occ.mean(),
        "max_bucket_occupancy": occ.max(),
        "empty_bucket_frac": jnp.mean(occ == 0),
        "overflow_frac": (tables.n_dropped / jnp.maximum(total, 1)).mean(),
    }
