"""Vocab-sharded LSS: the distributed serving form of the paper's index.

Each shard of the model axis owns m/TP contiguous WOL neurons and builds an
independent LSS index over them (theta is replicated — hyperplanes are tiny).
Per query:

    shard-local retrieve -> local sparse logits -> local top-k
    -> all-gather k candidates per shard (O(TP*k) per query, NOT O(m))
    -> global top-k

This replaces the paper's "embarrassingly parallel over CPU threads" claim
with "embarrassingly parallel over vocab shards" and makes the WOL head's
communication volume independent of vocabulary size.

Quantized slab storage composes transparently: ``LSSIndex.w_scale`` is
an ordinary pytree leaf, so per-shard int8 indexes stack, shard over the
model axis, and flow through shard_map exactly like the fp32 slabs —
nothing here is storage-format aware.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.lss import LSSConfig, LSSIndex, build_index, lss_forward
from repro.utils import compat

__all__ = ["build_local_index", "local_topk", "sharded_lss_predict",
           "sharded_lss_forward", "make_sharded_predict",
           "hierarchical_topk_merge", "multihost_lss_predict",
           "multihost_lss_forward", "make_multihost_predict"]


def build_local_index(w_aug_local: jax.Array, theta: jax.Array,
                      cfg: LSSConfig) -> LSSIndex:
    """Build the index for this shard's rows (call inside shard_map or on
    pre-split host arrays). Neuron ids inside are LOCAL row indices."""
    return build_index(w_aug_local, theta, cfg)


def local_topk(q: jax.Array, index: LSSIndex, w_aug_local: jax.Array | None,
               k: int, with_aux: bool = False, impl: str | None = None,
               dedup: str | None = None):
    """Shard-local Algorithm 2 returning exactly-k (logits, local ids).

    Delegates to ``lss_forward`` (registry-dispatched; the fused Pallas
    pass on a bucket-major index), so shard-local slots fewer than k read
    -1 rather than an arbitrary duplicate id that would survive the
    global all-gather.  With ``with_aux`` also returns the per-query
    local sample size from the SAME retrieval pass.
    """
    out = lss_forward(q, index, w_aug_local, k, impl=impl, dedup=dedup)
    if with_aux:
        return out.top_logits, out.top_ids, out.sample_size
    return out.top_logits, out.top_ids


def sharded_lss_predict(q: jax.Array, index: LSSIndex,
                        w_aug_local: jax.Array | None, *, k: int,
                        axis_name: str, m_local: int,
                        impl: str | None = None, dedup: str | None = None
                        ) -> tuple[jax.Array, jax.Array]:
    """Body to run INSIDE shard_map: q replicated, index/w shard-local.

    Returns global (top-k logits, top-k GLOBAL neuron ids), replicated.
    """
    logits, ids = local_topk(q, index, w_aug_local, k,
                             impl=impl, dedup=dedup)            # [B, k]
    offset = jax.lax.axis_index(axis_name) * m_local
    gids = jnp.where(ids >= 0, ids + offset, -1)
    all_logits = jax.lax.all_gather(logits, axis_name, axis=1)  # [B, TP, k]
    all_ids = jax.lax.all_gather(gids, axis_name, axis=1)
    all_logits = all_logits.reshape(q.shape[0], -1)
    all_ids = all_ids.reshape(q.shape[0], -1)
    top_logits, pos = jax.lax.top_k(all_logits, k)
    top_ids = jnp.take_along_axis(all_ids, pos, axis=-1)
    return top_logits, top_ids


def sharded_lss_forward(q: jax.Array, index: LSSIndex,
                        w_aug_local: jax.Array | None, *, k: int,
                        axis_name: str, m_local: int,
                        impl: str | None = None, dedup: str | None = None
                        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """``sharded_lss_predict`` + per-query GLOBAL sample size (psum of the
    shard-local unique-candidate counts) from the single retrieval pass."""
    logits, ids, local_sample = local_topk(q, index, w_aug_local, k,
                                           with_aux=True, impl=impl,
                                           dedup=dedup)
    offset = jax.lax.axis_index(axis_name) * m_local
    gids = jnp.where(ids >= 0, ids + offset, -1)
    all_logits = jax.lax.all_gather(logits, axis_name, axis=1)  # [B, TP, k]
    all_ids = jax.lax.all_gather(gids, axis_name, axis=1)
    all_logits = all_logits.reshape(q.shape[0], -1)
    all_ids = all_ids.reshape(q.shape[0], -1)
    top_logits, pos = jax.lax.top_k(all_logits, k)
    top_ids = jnp.take_along_axis(all_ids, pos, axis=-1)
    sample = jax.lax.psum(local_sample, axis_name)              # [B]
    return top_logits, top_ids, sample


def hierarchical_topk_merge(logits: jax.Array, gids: jax.Array, k: int, *,
                            model_axis: str, host_axis: str, n_hosts: int
                            ) -> tuple[jax.Array, jax.Array]:
    """Two-stage top-k merge for a (host, model) mesh.

    Stage 1 all-gathers the k candidates per shard over the fast
    intra-host ``model_axis`` and reduces to k per host; stage 2
    all-gathers only those k per host over the slow ``host_axis``, so
    cross-host traffic is O(n_hosts * k) per query — independent of both
    m and the per-host shard count.

    Bit-identical to the flat single-stage merge: ``jax.lax.top_k`` is
    stable (ties resolve to the lowest position), shard blocks are
    host-contiguous in the gather order, and every sub-k shard slot
    carries (NEG_INF, -1), so any candidate the intra-host stage drops
    already had k better-or-equal-earlier candidates on its own host and
    could never enter the flat global top-k either.  With ``n_hosts == 1``
    stage 2 is skipped and this IS the flat merge.
    """
    b = logits.shape[0]
    all_logits = jax.lax.all_gather(logits, model_axis, axis=1)
    all_ids = jax.lax.all_gather(gids, model_axis, axis=1)
    host_logits, pos = jax.lax.top_k(all_logits.reshape(b, -1), k)
    host_ids = jnp.take_along_axis(all_ids.reshape(b, -1), pos, axis=-1)
    if n_hosts == 1:
        return host_logits, host_ids
    x_logits = jax.lax.all_gather(host_logits, host_axis, axis=1)
    x_ids = jax.lax.all_gather(host_ids, host_axis, axis=1)
    top_logits, pos = jax.lax.top_k(x_logits.reshape(b, -1), k)
    top_ids = jnp.take_along_axis(x_ids.reshape(b, -1), pos, axis=-1)
    return top_logits, top_ids


def _global_shard_ids(ids: jax.Array, *, model_axis: str, host_axis: str,
                      shards_per_host: int, m_local: int) -> jax.Array:
    shard = (jax.lax.axis_index(host_axis) * shards_per_host
             + jax.lax.axis_index(model_axis))
    return jnp.where(ids >= 0, ids + shard * m_local, -1)


def multihost_lss_predict(q: jax.Array, index: LSSIndex,
                          w_aug_local: jax.Array | None, *, k: int,
                          model_axis: str, host_axis: str, n_hosts: int,
                          shards_per_host: int, m_local: int,
                          impl: str | None = None, dedup: str | None = None
                          ) -> tuple[jax.Array, jax.Array]:
    """``sharded_lss_predict`` for a (host, model) mesh: shard-local
    retrieve + top-k, then the hierarchical merge.  Global neuron id =
    (host * shards_per_host + model) * m_local + local id."""
    logits, ids = local_topk(q, index, w_aug_local, k,
                             impl=impl, dedup=dedup)
    gids = _global_shard_ids(ids, model_axis=model_axis,
                             host_axis=host_axis,
                             shards_per_host=shards_per_host,
                             m_local=m_local)
    return hierarchical_topk_merge(logits, gids, k, model_axis=model_axis,
                                   host_axis=host_axis, n_hosts=n_hosts)


def multihost_lss_forward(q: jax.Array, index: LSSIndex,
                          w_aug_local: jax.Array | None, *, k: int,
                          model_axis: str, host_axis: str, n_hosts: int,
                          shards_per_host: int, m_local: int,
                          impl: str | None = None, dedup: str | None = None
                          ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """``multihost_lss_predict`` + global per-query sample size (psum
    over BOTH mesh axes) from the single retrieval pass."""
    logits, ids, local_sample = local_topk(q, index, w_aug_local, k,
                                           with_aux=True, impl=impl,
                                           dedup=dedup)
    gids = _global_shard_ids(ids, model_axis=model_axis,
                             host_axis=host_axis,
                             shards_per_host=shards_per_host,
                             m_local=m_local)
    top_logits, top_ids = hierarchical_topk_merge(
        logits, gids, k, model_axis=model_axis, host_axis=host_axis,
        n_hosts=n_hosts)
    sample = jax.lax.psum(local_sample, (host_axis, model_axis))
    return top_logits, top_ids, sample


def make_multihost_predict(mesh: jax.sharding.Mesh, host_axis: str,
                           model_axis: str, cfg: LSSConfig, m_local: int,
                           k: int, with_aux: bool = False,
                           impl: str | None = None,
                           dedup: str | None = None):
    """:func:`make_sharded_predict` for a 2-axis (host, model) mesh.

    Stacked per-shard pytrees carry a leading [n_shards] dim sharded
    over BOTH axes (``P((host_axis, model_axis))``); shard s lives on
    host ``s // shards_per_host`` — build the stack with
    ``serve.heads.shard_index(..., shard_range=...)`` plus
    ``compat.make_global_array`` so no process materializes remote
    shards.  q and the outputs are replicated.  On a mesh whose host
    axis is 1 the merge reduces to the flat single-stage path
    bit-identically.
    """
    n_hosts = mesh.shape[host_axis]
    shards_per_host = mesh.shape[model_axis]
    body = partial(
        multihost_lss_forward if with_aux else multihost_lss_predict,
        k=k, model_axis=model_axis, host_axis=host_axis, n_hosts=n_hosts,
        shards_per_host=shards_per_host, m_local=m_local, impl=impl,
        dedup=dedup)
    stack_spec = P((host_axis, model_axis))

    def unstacked_body(q, index_stack, w_stack):
        index = jax.tree.map(lambda x: x[0], index_stack)
        w = None if w_stack is None else w_stack[0]
        return body(q, index, w)

    out_specs = (P(), P(), P()) if with_aux else (P(), P())

    def fn(q, index_stack, w_stack=None):
        in_specs = (
            P(),
            jax.tree.map(lambda _: stack_spec, index_stack),
            None if w_stack is None
            else jax.tree.map(lambda _: stack_spec, w_stack),
        )
        mapped = compat.shard_map(
            unstacked_body, mesh=mesh, in_specs=in_specs,
            out_specs=out_specs)
        return mapped(q, index_stack, w_stack)

    return fn


def make_sharded_predict(mesh: jax.sharding.Mesh, model_axis: str,
                         cfg: LSSConfig, m_local: int, k: int,
                         batch_axis: str | None = None,
                         with_aux: bool = False,
                         impl: str | None = None,
                         dedup: str | None = None):
    """Wrap the sharded predictor in shard_map for the given mesh.

    Expects stacked per-shard pytrees: index leaves with a leading [TP] dim
    sharded over ``model_axis``; q sharded over ``batch_axis`` (or
    replicated).  Returns a function (q, stacked_index, w_local_stack|None)
    -> (logits [B,k], ids [B,k]) — plus sample size [B] if ``with_aux``.
    ``impl`` pins the registry kernel impl for the shard-local retrieval;
    ``dedup`` its cross-table dedup strategy (quadratic | bitonic).
    """
    qspec = P(batch_axis) if batch_axis else P()
    body = partial(sharded_lss_forward if with_aux else sharded_lss_predict,
                   k=k, axis_name=model_axis, m_local=m_local, impl=impl,
                   dedup=dedup)

    def unstacked_body(q, index_stack, w_stack):
        index = jax.tree.map(lambda x: x[0], index_stack)
        w = None if w_stack is None else w_stack[0]
        return body(q, index, w)

    out_specs = (qspec, qspec, qspec) if with_aux else (qspec, qspec)

    def fn(q, index_stack, w_stack=None):
        in_specs = (
            qspec,
            jax.tree.map(lambda _: P(model_axis), index_stack),
            None if w_stack is None
            else jax.tree.map(lambda _: P(model_axis), w_stack),
        )
        mapped = compat.shard_map(
            unstacked_body, mesh=mesh, in_specs=in_specs,
            out_specs=out_specs)
        return mapped(q, index_stack, w_stack)

    return fn
