"""arctic-480b [moe] 35L d_model=7168 56H (GQA kv=8) d_ff=4864
vocab=32000, MoE 128e top-2 — 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base; hf].

Dense-residual MoE: every layer runs a dense SwiGLU (d_ff=4864) IN
PARALLEL with the 128-expert top-2 MoE (moe_style="parallel").  Expert
tensors are 2D-sharded (experts over 'model', d_ff over 'data' — FSDP)
— 480B params cannot live on one axis of a 256-chip pod.  Optimizer
state is kept in bf16 for this arch (8-bit-Adam-style memory trade,
documented in EXPERIMENTS.md §Dry-run).
"""

import jax.numpy as jnp

from repro.configs.base import ArchSpec, lm_shapes
from repro.core.lss import LSSConfig
from repro.models.transformer import TransformerConfig

CONFIG = ArchSpec(
    arch_id="arctic-480b",
    family="lm",
    model_cfg=TransformerConfig(
        name="arctic-480b", n_layers=35, d_model=7168, n_heads=56,
        n_kv_heads=8, head_dim=128, d_ff=4864, vocab=32000,
        qkv_bias=False, rope_base=1e6, dtype=jnp.bfloat16,
        moe_style="parallel", n_experts=128, n_experts_padded=128,
        moe_top_k=2, moe_d_ff=4864, moe_fsdp=True),
    shapes=lm_shapes(),
    lss=LSSConfig(k_bits=8, n_tables=1),
    notes="Optimizer state bf16 (memory); vocab 32000 -> K=8 LSS head.",
)
