"""gcn-cora [gnn] n_layers=2 d_hidden=16 aggregator=mean norm=sym
[arXiv:1609.02907; paper].

The feature/class dims are SHAPE-dependent (each assigned cell is a
different public graph): cora 1433/7, reddit-like minibatch 602/41,
ogbn-products 100/47, molecule 32/16.  LSS is INAPPLICABLE: the output
layer is 7..47 classes wide — nothing to sample (DESIGN.md
§Arch-applicability).
"""

from repro.configs.base import ArchSpec, ShapeSpec
from repro.models.gnn import GCNConfig

CONFIG = ArchSpec(
    arch_id="gcn-cora",
    family="gnn",
    model_cfg=GCNConfig(name="gcn-cora", n_layers=2, d_hidden=16,
                        d_feat=1433, n_classes=7),
    shapes={
        "full_graph_sm": ShapeSpec("full_graph_sm", "train", {
            "n_nodes": 2708, "n_edges": 10556, "d_feat": 1433,
            "n_classes": 7}),
        "minibatch_lg": ShapeSpec("minibatch_lg", "train_sampled", {
            "n_nodes": 232965, "n_edges": 114615892, "batch_nodes": 1024,
            "fanout": (15, 10), "d_feat": 602, "n_classes": 41}),
        "ogb_products": ShapeSpec("ogb_products", "train", {
            "n_nodes": 2449029, "n_edges": 61859140, "d_feat": 100,
            "n_classes": 47}),
        "molecule": ShapeSpec("molecule", "train_batched", {
            "n_nodes": 30, "n_edges": 64, "batch": 128, "d_feat": 32,
            "n_classes": 16}),
    },
    lss=None,
    notes="LSS inapplicable (7-47-wide output).",
)
