"""--arch <id> registry over the ten assigned architectures."""

import importlib

ARCH_MODULES = {
    "arctic-480b": "repro.configs.arctic_480b",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2_7b",
    "qwen2-0.5b": "repro.configs.qwen2_0_5b",
    "qwen2-7b": "repro.configs.qwen2_7b",
    "qwen3-4b": "repro.configs.qwen3_4b",
    "gcn-cora": "repro.configs.gcn_cora",
    "bert4rec": "repro.configs.bert4rec",
    "dien": "repro.configs.dien",
    "deepfm": "repro.configs.deepfm",
    "autoint": "repro.configs.autoint",
}

ALL_ARCHS = list(ARCH_MODULES)


def get_config(arch_id: str):
    if arch_id not in ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ALL_ARCHS}")
    return importlib.import_module(ARCH_MODULES[arch_id]).CONFIG


def all_cells() -> list[tuple[str, str]]:
    """All 40 assigned (arch, shape) dry-run cells."""
    cells = []
    for a in ALL_ARCHS:
        for s in get_config(a).shapes:
            cells.append((a, s))
    return cells
