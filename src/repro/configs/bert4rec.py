"""bert4rec [recsys] embed_dim=64 n_blocks=2 n_heads=2 seq_len=200
interaction=bidir-seq [arXiv:1904.06690; paper].

Item catalogue 1,000,000 (so retrieval_cand's n_candidates is the full
catalogue): the next-item softmax IS the paper's wide output layer —
this is the flagship recsys LSS integration."""

from repro.configs.base import ArchSpec, ShapeSpec
from repro.core.lss import LSSConfig
from repro.models.recsys import Bert4RecConfig

CONFIG = ArchSpec(
    arch_id="bert4rec",
    family="recsys_seq",
    model_cfg=Bert4RecConfig(name="bert4rec", n_items=1_000_000,
                             embed_dim=64, n_blocks=2, n_heads=2,
                             seq_len=200),
    shapes={
        "train_batch": ShapeSpec("train_batch", "train", {"batch": 65536}),
        "serve_p99": ShapeSpec("serve_p99", "serve", {"batch": 512}),
        "serve_bulk": ShapeSpec("serve_bulk", "serve", {"batch": 262144}),
        "retrieval_cand": ShapeSpec("retrieval_cand", "retrieval",
                                    {"batch": 1, "n_candidates": 1000000}),
    },
    lss=LSSConfig(k_bits=12, n_tables=1),
    notes="LSS serves the 1M-item catalogue WOL.",
)
