"""The paper's own four evaluation settings (Table 4 / Appendix B).

``full`` configs carry the exact paper dimensions (used by the dry-run
and FLOP accounting); ``bench`` configs are reduced stand-ins actually
trained in benchmarks (synthetic data; CPU container).
"""

from typing import NamedTuple

from repro.core.lss import LSSConfig
from repro.models.lstm import LSTMConfig
from repro.models.xc import XCConfig


class PaperSetting(NamedTuple):
    name: str
    kind: str               # xc | word2vec | lstm
    full: object
    bench: object
    lss: LSSConfig
    bench_lss: LSSConfig


WIKI10 = PaperSetting(
    name="wiki10-31k", kind="xc",
    full=XCConfig("wiki10-31k", input_dim=101938, hidden=128,
                  output_dim=30938, max_in=64, max_labels=8),
    bench=XCConfig("wiki10-31k-bench", input_dim=8000, hidden=64,
                   output_dim=4000, max_in=32, max_labels=4),
    lss=LSSConfig(k_bits=6, n_tables=1),
    bench_lss=LSSConfig(k_bits=4, n_tables=1, iul_epochs=10,
                        iul_inner_steps=10, iul_lr=0.02),
)

DELICIOUS = PaperSetting(
    name="delicious-200k", kind="xc",
    full=XCConfig("delicious-200k", input_dim=782585, hidden=128,
                  output_dim=205443, max_in=64, max_labels=8),
    bench=XCConfig("delicious-200k-bench", input_dim=12000, hidden=64,
                   output_dim=8000, max_in=32, max_labels=4),
    lss=LSSConfig(k_bits=9, n_tables=1),   # paper best: K=4,L=1 rel. scale
    bench_lss=LSSConfig(k_bits=5, n_tables=1, iul_epochs=10,
                        iul_inner_steps=10, iul_lr=0.02),
)

TEXT8 = PaperSetting(
    name="text8", kind="word2vec",
    full=XCConfig("text8", input_dim=1355336, hidden=128,
                  output_dim=1355336, max_in=1, max_labels=50),
    bench=XCConfig("text8-bench", input_dim=20000, hidden=64,
                   output_dim=20000, max_in=1, max_labels=10),
    lss=LSSConfig(k_bits=11, n_tables=1),
    bench_lss=LSSConfig(k_bits=6, n_tables=1, iul_epochs=8,
                        iul_inner_steps=10, iul_lr=0.02),
)

WIKITEXT2 = PaperSetting(
    name="wiki-text-2", kind="lstm",
    full=LSTMConfig("wiki-text-2", vocab=50000, hidden=200, n_layers=2),
    bench=LSTMConfig("wiki-text-2-bench", vocab=8000, hidden=96,
                     n_layers=2),
    lss=LSSConfig(k_bits=8, n_tables=1),
    bench_lss=LSSConfig(k_bits=5, n_tables=1, iul_epochs=8,
                        iul_inner_steps=10, iul_lr=0.02),
)

ALL = {s.name: s for s in (WIKI10, DELICIOUS, TEXT8, WIKITEXT2)}
