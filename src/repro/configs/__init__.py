"""configs subpackage: one module per assigned arch + registry."""
