"""qwen2-7b [dense] 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — GQA, QKV bias [arXiv:2407.10671; hf]."""

import jax.numpy as jnp

from repro.configs.base import ArchSpec, lm_shapes
from repro.core.lss import LSSConfig
from repro.models.transformer import TransformerConfig

CONFIG = ArchSpec(
    arch_id="qwen2-7b",
    family="lm",
    model_cfg=TransformerConfig(
        name="qwen2-7b", n_layers=28, d_model=3584, n_heads=28,
        n_kv_heads=4, head_dim=128, d_ff=18944, vocab=152064,
        qkv_bias=True, qk_norm=False, rope_base=1e6, dtype=jnp.bfloat16),
    shapes=lm_shapes(),
    lss=LSSConfig(k_bits=10, n_tables=1),
    notes="LSS serves the 152064-wide LM head at decode.",
)
