"""ArchSpec: one selectable architecture = model config + its shape set
+ LSS applicability (DESIGN.md §Arch-applicability)."""

from __future__ import annotations

from typing import Any, NamedTuple

from repro.core.lss import LSSConfig


class ShapeSpec(NamedTuple):
    name: str
    kind: str          # train | prefill | decode | serve | retrieval | ...
    dims: dict         # family-specific sizes


class ArchSpec(NamedTuple):
    arch_id: str
    family: str        # lm | gnn | recsys_ctr | recsys_seq
    model_cfg: Any
    shapes: dict[str, ShapeSpec]
    lss: LSSConfig | None = None   # None => paper's technique inapplicable
    notes: str = ""

    def shape(self, name: str) -> ShapeSpec:
        return self.shapes[name]


# The four LM shapes shared by every LM arch (assignment block).
def lm_shapes() -> dict[str, ShapeSpec]:
    return {
        "train_4k": ShapeSpec("train_4k", "train",
                              {"seq_len": 4096, "global_batch": 256}),
        "prefill_32k": ShapeSpec("prefill_32k", "prefill",
                                 {"seq_len": 32768, "global_batch": 32}),
        "decode_32k": ShapeSpec("decode_32k", "decode",
                                {"seq_len": 32768, "global_batch": 128}),
        "long_500k": ShapeSpec("long_500k", "decode",
                               {"seq_len": 524288, "global_batch": 1}),
    }
