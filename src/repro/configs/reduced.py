"""Reduced same-family configs for CPU smoke tests and examples.

Same code paths and flags as the full assigned configs (MoE style, GQA
ratios, qk-norm, bias, AUGRU, ...), tiny dims.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.models.gnn import GCNConfig
from repro.models.recsys import Bert4RecConfig, CTRConfig
from repro.models.transformer import TransformerConfig


def reduced_model_cfg(arch_id: str):
    full = get_config(arch_id).model_cfg
    if isinstance(full, TransformerConfig):
        kw = dict(
            name=full.name + "-reduced", n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=max(1, 4 * full.n_kv_heads // full.n_heads),
            head_dim=16, d_ff=128, vocab=512, qkv_bias=full.qkv_bias,
            qk_norm=full.qk_norm, rope_base=full.rope_base,
            tie_embeddings=full.tie_embeddings, moe_style=full.moe_style,
            dtype=jnp.float32, kv_chunk=32, q_chunk=64)
        if full.moe_style != "none":
            kw.update(n_experts=4, n_experts_padded=4, moe_top_k=2,
                      moe_d_ff=64, capacity_factor=4.0,
                      shared_expert_ff=96 if full.shared_expert_ff else 0)
        return TransformerConfig(**kw)
    if isinstance(full, GCNConfig):
        return full._replace(d_feat=16, d_hidden=8, n_classes=4)
    if isinstance(full, CTRConfig):
        return full._replace(vocab_per_field=1000, n_fields=min(full.n_fields, 8),
                             embed_dim=8, mlp_dims=(32, 16), seq_len=12,
                             gru_dim=16, n_attn_layers=2, d_attn=8)
    if isinstance(full, Bert4RecConfig):
        return full._replace(n_items=2000, embed_dim=32, seq_len=16)
    raise TypeError(type(full))
