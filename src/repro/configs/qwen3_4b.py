"""qwen3-4b [dense] 36L d_model=2560 32H (GQA kv=8) d_ff=9728
vocab=151936 — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]."""

import jax.numpy as jnp

from repro.configs.base import ArchSpec, lm_shapes
from repro.core.lss import LSSConfig
from repro.models.transformer import TransformerConfig

CONFIG = ArchSpec(
    arch_id="qwen3-4b",
    family="lm",
    model_cfg=TransformerConfig(
        name="qwen3-4b", n_layers=36, d_model=2560, n_heads=32,
        n_kv_heads=8, head_dim=128, d_ff=9728, vocab=151936,
        qkv_bias=False, qk_norm=True, rope_base=1e6, dtype=jnp.bfloat16),
    shapes=lm_shapes(),
    lss=LSSConfig(k_bits=10, n_tables=1),
)
