"""dien [recsys] embed_dim=18 seq_len=100 gru_dim=108 mlp=200-80
interaction=augru [arXiv:1809.03672; unverified].

Item-sequence CTR: behavior history (100 items) -> GRU -> target
attention -> AUGRU.  Item vocab 2M (single huge table)."""

from repro.configs.base import ArchSpec
from repro.configs.deepfm import _SHAPES
from repro.models.recsys import CTRConfig

CONFIG = ArchSpec(
    arch_id="dien",
    family="recsys_ctr",
    model_cfg=CTRConfig(name="dien", kind="dien", n_fields=1,
                        vocab_per_field=2_000_000, embed_dim=18,
                        seq_len=100, gru_dim=108, mlp_dims=(200, 80)),
    shapes=dict(_SHAPES),
    lss=None,
    notes="LSS inapplicable (binary CTR output).",
)
