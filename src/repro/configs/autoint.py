"""autoint [recsys] n_sparse=39 embed_dim=16 n_attn_layers=3 n_heads=2
d_attn=32 interaction=self-attn [arXiv:1810.11921; paper]."""

from repro.configs.base import ArchSpec
from repro.configs.deepfm import _SHAPES
from repro.models.recsys import CTRConfig

CONFIG = ArchSpec(
    arch_id="autoint",
    family="recsys_ctr",
    model_cfg=CTRConfig(name="autoint", kind="autoint", n_fields=39,
                        vocab_per_field=1_000_000, embed_dim=16,
                        n_attn_layers=3, n_heads=2, d_attn=32),
    shapes=dict(_SHAPES),
    lss=None,
    notes="LSS inapplicable (binary CTR output).",
)
