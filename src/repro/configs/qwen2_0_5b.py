"""qwen2-0.5b [dense] 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151936 — GQA, QKV bias [arXiv:2407.10671; hf]."""

import jax.numpy as jnp

from repro.configs.base import ArchSpec, lm_shapes
from repro.core.lss import LSSConfig
from repro.models.transformer import TransformerConfig

CONFIG = ArchSpec(
    arch_id="qwen2-0.5b",
    family="lm",
    model_cfg=TransformerConfig(
        name="qwen2-0.5b", n_layers=24, d_model=896, n_heads=14,
        n_kv_heads=2, head_dim=64, d_ff=4864, vocab=151936,
        qkv_bias=True, qk_norm=False, rope_base=1e6,
        tie_embeddings=True, dtype=jnp.bfloat16),
    shapes=lm_shapes(),
    lss=LSSConfig(k_bits=10, n_tables=1),
    notes="LSS serves the 151936-wide LM head at decode.",
)
