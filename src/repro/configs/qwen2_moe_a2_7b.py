"""qwen2-moe-a2.7b [moe] 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, MoE 60e top-4 — 4 shared + 60 routed top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf].

60 experts don't divide the 16-way model axis: padded to 64 physical
experts (router masks the 4 pads; see models/moe.py).  The "4 shared"
experts are fused into one shared SwiGLU of hidden 4*1408=5632 with a
sigmoid gate, matching the HF reference implementation.
"""

import jax.numpy as jnp

from repro.configs.base import ArchSpec, lm_shapes
from repro.core.lss import LSSConfig
from repro.models.transformer import TransformerConfig

CONFIG = ArchSpec(
    arch_id="qwen2-moe-a2.7b",
    family="lm",
    model_cfg=TransformerConfig(
        name="qwen2-moe-a2.7b", n_layers=24, d_model=2048, n_heads=16,
        n_kv_heads=16, head_dim=128, d_ff=1408, vocab=151936,
        qkv_bias=True, rope_base=1e6, dtype=jnp.bfloat16,
        moe_style="replace", n_experts=60, n_experts_padded=64,
        moe_top_k=4, moe_d_ff=1408, shared_expert_ff=5632),
    shapes=lm_shapes(),
    lss=LSSConfig(k_bits=10, n_tables=1),
)
