"""deepfm [recsys] n_sparse=39 embed_dim=10 mlp=400-400-400
interaction=fm [arXiv:1703.04247; paper].

Unified embedding table: 39 fields x 1M rows = 39M rows x dim 10,
row-sharded over 'model'.  LSS inapplicable to the 1-logit CTR output;
retrieval_cand is per-candidate feature interaction, not a WOL matmul
(DESIGN.md §Arch-applicability).
"""

from repro.configs.base import ArchSpec, ShapeSpec
from repro.models.recsys import CTRConfig

_SHAPES = {
    "train_batch": ShapeSpec("train_batch", "train", {"batch": 65536}),
    "serve_p99": ShapeSpec("serve_p99", "serve", {"batch": 512}),
    "serve_bulk": ShapeSpec("serve_bulk", "serve", {"batch": 262144}),
    "retrieval_cand": ShapeSpec("retrieval_cand", "retrieval",
                                {"batch": 1, "n_candidates": 1000000}),
}

CONFIG = ArchSpec(
    arch_id="deepfm",
    family="recsys_ctr",
    model_cfg=CTRConfig(name="deepfm", kind="deepfm", n_fields=39,
                        vocab_per_field=1_000_000, embed_dim=10,
                        mlp_dims=(400, 400, 400)),
    shapes=dict(_SHAPES),
    lss=None,
    notes="LSS inapplicable (binary CTR output).",
)
