"""Pallas TPU kernels (validated in interpret mode on CPU).

Each kernel ships as <name>/{kernel.py, ops.py, ref.py}: the pallas_call
with explicit BlockSpec tiling, the public wrapper, and the pure-jnp
oracle.  Implementations register on the dispatch registry
(``repro.kernels.registry``): selection is automatic by backend (pallas
on TPU, ref elsewhere), overridable per call (``impl=``), per process
(``registry.set_default_impl`` / ``use_impl``), or via the
``REPRO_KERNEL_IMPL`` environment variable.

Ops can also expose *strategy* knobs — algorithm choices every impl
honors, resolved the same way (explicit arg > ``use_strategy`` > env >
auto-select on shape): ``lss_topk.dedup`` picks the cross-table dedup
(``quadratic`` below the measured C crossover, ``bitonic`` above; see
``repro.kernels.lss_topk.dedup``); ``lss_topk.slab_dtype`` picks the
bucket-major slab storage format (``fp32`` | ``bf16`` | ``int8``,
resolved once at index build time; see ``repro.kernels.lss_topk.slabs``).
"""
from repro.kernels import registry
from repro.kernels.simhash_codes import simhash_codes
from repro.kernels.bucket_logits import bucket_logits
from repro.kernels.lss_topk import lss_topk
__all__ = ["registry", "simhash_codes", "bucket_logits", "lss_topk"]
