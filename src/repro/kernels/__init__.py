"""Pallas TPU kernels (validated in interpret mode on CPU).

Each kernel ships as <name>/{kernel.py, ops.py, ref.py}: the pallas_call
with explicit BlockSpec tiling, the jit'd public wrapper with impl
dispatch, and the pure-jnp oracle.
"""
from repro.kernels.simhash_codes import simhash_codes
from repro.kernels.bucket_logits import bucket_logits
__all__ = ["simhash_codes", "bucket_logits"]
