"""Kernel dispatch registry: named ops with ref / pallas / pallas_interpret
implementations and automatic backend selection.

Every kernel package registers its implementations on a :class:`KernelOp`
(``kernel_op(name)`` is get-or-create, so registration order never
matters).  Callers go through the op object — ``op(*args, impl=None)`` —
and the registry picks the implementation:

  1. an explicit ``impl=`` argument at the call site (must exist, else
     ``KeyError``),
  2. a process-wide override set with :func:`set_default_impl` (or the
     :func:`use_impl` context manager),
  3. the ``REPRO_KERNEL_IMPL`` environment variable,
  4. backend auto-selection: ``pallas`` on TPU, ``ref`` elsewhere
     (falling back to ``pallas_interpret`` for ops that ship no jnp ref).

Overrides from (2)/(3) that an op does not implement fall through to the
backend default instead of erroring, so ``REPRO_KERNEL_IMPL=pallas`` on a
TPU host is safe even if some op is ref-only.

Besides *implementations* (which backend runs an op), ops can expose
*strategies* — named algorithm knobs within an op that every
implementation honors (e.g. ``lss_topk.dedup`` = ``quadratic`` |
``bitonic``).  A :class:`KernelStrategy` resolves the same way an impl
does — explicit argument > process override (:func:`set_default_strategy`
/ :func:`use_strategy`) > its own env var > an auto-select callback fed
call-site context (e.g. the candidate count) — so shape-dependent
algorithm switches are registry policy, not call-site ``if``\\ s.

Dispatches AND strategy resolutions are recorded at trace time (ops are
typically called inside ``jax.jit``, whose Python body runs once per
compilation), so tests and tooling can assert which implementation and
algorithm actually served a path via :func:`dispatch_log` /
:func:`last_dispatch`.
"""

from __future__ import annotations

import os
from contextlib import contextmanager
from typing import Callable

import jax

__all__ = [
    "IMPLS", "ENV_VAR", "KernelOp", "kernel_op", "get_op", "list_ops",
    "resolve_impl", "set_default_impl", "use_impl", "dispatch_log",
    "dispatch_counts", "last_dispatch", "reset_dispatch_log",
    "KernelStrategy", "kernel_strategy", "get_strategy", "list_strategies",
    "set_default_strategy", "use_strategy",
]

IMPLS = ("ref", "pallas", "pallas_interpret")
ENV_VAR = "REPRO_KERNEL_IMPL"

_ops: dict[str, "KernelOp"] = {}
_default_impl: str | None = None
_log: list[tuple[str, str]] = []
_strategies: dict[str, "KernelStrategy"] = {}
_default_strategies: dict[str, str] = {}


class KernelOp:
    """One named op and its registered implementations."""

    def __init__(self, name: str):
        self.name = name
        self.impls: dict[str, Callable] = {}

    def impl(self, impl_name: str) -> Callable:
        """Decorator: register ``fn`` as the ``impl_name`` implementation."""
        def deco(fn: Callable) -> Callable:
            self.register_impl(impl_name, fn)
            return fn
        return deco

    def register_impl(self, impl_name: str, fn: Callable) -> None:
        if impl_name not in IMPLS:
            raise ValueError(
                f"impl must be one of {IMPLS}, got {impl_name!r}")
        self.impls[impl_name] = fn

    def __call__(self, *args, impl: str | None = None, **kwargs):
        choice = resolve_impl(self.name, impl)
        _log.append((self.name, choice))
        return self.impls[choice](*args, **kwargs)

    def __repr__(self) -> str:
        return f"KernelOp({self.name!r}, impls={sorted(self.impls)})"


def kernel_op(name: str) -> KernelOp:
    """Get-or-create the op named ``name``."""
    if name not in _ops:
        _ops[name] = KernelOp(name)
    return _ops[name]


def get_op(name: str) -> KernelOp:
    if name not in _ops:
        raise KeyError(f"unknown kernel op {name!r}; "
                       f"registered: {sorted(_ops)}")
    return _ops[name]


def list_ops() -> list[str]:
    return sorted(_ops)


def set_default_impl(impl: str | None) -> None:
    """Process-wide impl override (``None`` clears it)."""
    global _default_impl
    if impl is not None and impl not in IMPLS:
        raise ValueError(f"impl must be one of {IMPLS} or None, got {impl!r}")
    _default_impl = impl


@contextmanager
def use_impl(impl: str | None):
    """Scoped :func:`set_default_impl`."""
    global _default_impl
    prev = _default_impl
    set_default_impl(impl)
    try:
        yield
    finally:
        _default_impl = prev


def resolve_impl(op_name: str, requested: str | None = None) -> str:
    """Resolve which implementation a call to ``op_name`` should use."""
    op = get_op(op_name)
    if requested is not None:
        if requested not in IMPLS:
            raise ValueError(
                f"impl must be one of {IMPLS}, got {requested!r}")
        if requested not in op.impls:
            raise KeyError(
                f"op {op_name!r} has no {requested!r} impl "
                f"(has: {sorted(op.impls)})")
        return requested
    for choice in (_default_impl, os.environ.get(ENV_VAR) or None):
        if choice is not None:
            if choice not in IMPLS:
                raise ValueError(
                    f"${ENV_VAR} must be one of {IMPLS}, got {choice!r}")
            if choice in op.impls:
                return choice
    if jax.default_backend() == "tpu" and "pallas" in op.impls:
        return "pallas"
    if "ref" in op.impls:
        return "ref"
    if "pallas_interpret" in op.impls:
        return "pallas_interpret"
    raise KeyError(f"op {op_name!r} has no registered impls")


# ----------------------------------------------------------- strategies --

class KernelStrategy:
    """One named algorithm knob shared by every implementation of an op.

    ``choices`` is the closed set of algorithm names; ``env_var`` (if
    given) is a ``REPRO_KERNEL_IMPL``-style per-knob override; ``auto``
    is a callback receiving the call-site context kwargs (e.g.
    ``n_candidates=``) and returning the data-dependent default.
    """

    def __init__(self, name: str, choices: tuple[str, ...],
                 env_var: str | None = None,
                 auto: Callable[..., str] | None = None):
        self.name = name
        self.choices = tuple(choices)
        self.env_var = env_var
        self.auto = auto

    def resolve(self, requested: str | None = None, **ctx) -> str:
        """Resolve which algorithm a call should use; logged like an impl
        dispatch (as ``(strategy_name, choice)``)."""
        choice = None
        if requested is not None:
            self._validate(requested, "explicit strategy")
            choice = requested
        if choice is None:
            override = _default_strategies.get(self.name)
            if override is not None:
                choice = override
        if choice is None and self.env_var:
            env = os.environ.get(self.env_var) or None
            if env is not None:
                self._validate(env, f"${self.env_var}")
                choice = env
        if choice is None and self.auto is not None:
            choice = self.auto(**ctx)
            self._validate(choice, f"{self.name} auto-select")
        if choice is None:
            choice = self.choices[0]
        _log.append((self.name, choice))
        return choice

    def _validate(self, choice: str, source: str) -> None:
        if choice not in self.choices:
            raise ValueError(f"{source} for {self.name!r} must be one of "
                             f"{self.choices}, got {choice!r}")

    def __repr__(self) -> str:
        return f"KernelStrategy({self.name!r}, choices={self.choices})"


def kernel_strategy(name: str, choices: tuple[str, ...] | None = None,
                    env_var: str | None = None,
                    auto: Callable[..., str] | None = None
                    ) -> KernelStrategy:
    """Get-or-create the strategy knob named ``name`` (conventionally
    ``"<op>.<knob>"``)."""
    if name not in _strategies:
        if choices is None:
            raise KeyError(f"unknown kernel strategy {name!r}; "
                           f"registered: {sorted(_strategies)}")
        _strategies[name] = KernelStrategy(name, choices, env_var, auto)
    return _strategies[name]


def get_strategy(name: str) -> KernelStrategy:
    if name not in _strategies:
        raise KeyError(f"unknown kernel strategy {name!r}; "
                       f"registered: {sorted(_strategies)}")
    return _strategies[name]


def list_strategies() -> list[str]:
    return sorted(_strategies)


def set_default_strategy(name: str, choice: str | None) -> None:
    """Process-wide strategy override (``None`` clears it)."""
    strat = get_strategy(name)
    if choice is None:
        _default_strategies.pop(name, None)
        return
    strat._validate(choice, "set_default_strategy")
    _default_strategies[name] = choice


@contextmanager
def use_strategy(name: str, choice: str | None):
    """Scoped :func:`set_default_strategy`."""
    prev = _default_strategies.get(name)
    set_default_strategy(name, choice)
    try:
        yield
    finally:
        set_default_strategy(name, prev)


# ------------------------------------------------------ dispatch records --

def dispatch_log() -> tuple[tuple[str, str], ...]:
    """All ``(op_name, impl)`` dispatches since the last reset, in order.

    Recorded at trace time: a jitted caller contributes one entry per
    compilation, not per device invocation.
    """
    return tuple(_log)


def dispatch_counts() -> dict[tuple[str, str], int]:
    counts: dict[tuple[str, str], int] = {}
    for entry in _log:
        counts[entry] = counts.get(entry, 0) + 1
    return counts


def last_dispatch(op_name: str) -> str | None:
    """The impl most recently dispatched for ``op_name`` (None if never)."""
    for name, impl in reversed(_log):
        if name == op_name:
            return impl
    return None


def reset_dispatch_log() -> None:
    _log.clear()
