"""Kernel dispatch registry: named ops with ref / pallas / pallas_interpret
implementations and automatic backend selection.

Every kernel package registers its implementations on a :class:`KernelOp`
(``kernel_op(name)`` is get-or-create, so registration order never
matters).  Callers go through the op object — ``op(*args, impl=None)`` —
and the registry picks the implementation:

  1. an explicit ``impl=`` argument at the call site (must exist, else
     ``KeyError``),
  2. a process-wide override set with :func:`set_default_impl` (or the
     :func:`use_impl` context manager),
  3. the ``REPRO_KERNEL_IMPL`` environment variable,
  4. backend auto-selection: ``pallas`` on TPU, ``ref`` elsewhere
     (falling back to ``pallas_interpret`` for ops that ship no jnp ref).

Overrides from (2)/(3) that an op does not implement fall through to the
backend default instead of erroring, so ``REPRO_KERNEL_IMPL=pallas`` on a
TPU host is safe even if some op is ref-only.

Dispatches are recorded at trace time (ops are typically called inside
``jax.jit``, whose Python body runs once per compilation), so tests and
tooling can assert which implementation actually served a path via
:func:`dispatch_log` / :func:`last_dispatch`.
"""

from __future__ import annotations

import os
from contextlib import contextmanager
from typing import Callable

import jax

__all__ = [
    "IMPLS", "ENV_VAR", "KernelOp", "kernel_op", "get_op", "list_ops",
    "resolve_impl", "set_default_impl", "use_impl", "dispatch_log",
    "dispatch_counts", "last_dispatch", "reset_dispatch_log",
]

IMPLS = ("ref", "pallas", "pallas_interpret")
ENV_VAR = "REPRO_KERNEL_IMPL"

_ops: dict[str, "KernelOp"] = {}
_default_impl: str | None = None
_log: list[tuple[str, str]] = []


class KernelOp:
    """One named op and its registered implementations."""

    def __init__(self, name: str):
        self.name = name
        self.impls: dict[str, Callable] = {}

    def impl(self, impl_name: str) -> Callable:
        """Decorator: register ``fn`` as the ``impl_name`` implementation."""
        def deco(fn: Callable) -> Callable:
            self.register_impl(impl_name, fn)
            return fn
        return deco

    def register_impl(self, impl_name: str, fn: Callable) -> None:
        if impl_name not in IMPLS:
            raise ValueError(
                f"impl must be one of {IMPLS}, got {impl_name!r}")
        self.impls[impl_name] = fn

    def __call__(self, *args, impl: str | None = None, **kwargs):
        choice = resolve_impl(self.name, impl)
        _log.append((self.name, choice))
        return self.impls[choice](*args, **kwargs)

    def __repr__(self) -> str:
        return f"KernelOp({self.name!r}, impls={sorted(self.impls)})"


def kernel_op(name: str) -> KernelOp:
    """Get-or-create the op named ``name``."""
    if name not in _ops:
        _ops[name] = KernelOp(name)
    return _ops[name]


def get_op(name: str) -> KernelOp:
    if name not in _ops:
        raise KeyError(f"unknown kernel op {name!r}; "
                       f"registered: {sorted(_ops)}")
    return _ops[name]


def list_ops() -> list[str]:
    return sorted(_ops)


def set_default_impl(impl: str | None) -> None:
    """Process-wide impl override (``None`` clears it)."""
    global _default_impl
    if impl is not None and impl not in IMPLS:
        raise ValueError(f"impl must be one of {IMPLS} or None, got {impl!r}")
    _default_impl = impl


@contextmanager
def use_impl(impl: str | None):
    """Scoped :func:`set_default_impl`."""
    global _default_impl
    prev = _default_impl
    set_default_impl(impl)
    try:
        yield
    finally:
        _default_impl = prev


def resolve_impl(op_name: str, requested: str | None = None) -> str:
    """Resolve which implementation a call to ``op_name`` should use."""
    op = get_op(op_name)
    if requested is not None:
        if requested not in IMPLS:
            raise ValueError(
                f"impl must be one of {IMPLS}, got {requested!r}")
        if requested not in op.impls:
            raise KeyError(
                f"op {op_name!r} has no {requested!r} impl "
                f"(has: {sorted(op.impls)})")
        return requested
    for choice in (_default_impl, os.environ.get(ENV_VAR) or None):
        if choice is not None:
            if choice not in IMPLS:
                raise ValueError(
                    f"${ENV_VAR} must be one of {IMPLS}, got {choice!r}")
            if choice in op.impls:
                return choice
    if jax.default_backend() == "tpu" and "pallas" in op.impls:
        return "pallas"
    if "ref" in op.impls:
        return "ref"
    if "pallas_interpret" in op.impls:
        return "pallas_interpret"
    raise KeyError(f"op {op_name!r} has no registered impls")


# ------------------------------------------------------ dispatch records --

def dispatch_log() -> tuple[tuple[str, str], ...]:
    """All ``(op_name, impl)`` dispatches since the last reset, in order.

    Recorded at trace time: a jitted caller contributes one entry per
    compilation, not per device invocation.
    """
    return tuple(_log)


def dispatch_counts() -> dict[tuple[str, str], int]:
    counts: dict[tuple[str, str], int] = {}
    for entry in _log:
        counts[entry] = counts.get(entry, 0) + 1
    return counts


def last_dispatch(op_name: str) -> str | None:
    """The impl most recently dispatched for ``op_name`` (None if never)."""
    for name, impl in reversed(_log):
        if name == op_name:
            return impl
    return None


def reset_dispatch_log() -> None:
    _log.clear()
