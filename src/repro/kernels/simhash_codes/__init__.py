from repro.kernels.simhash_codes.ops import simhash_codes
__all__ = ["simhash_codes"]
