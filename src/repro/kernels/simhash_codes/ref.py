"""Pure-jnp oracle for the fused simhash-code kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def simhash_codes_ref(x: jax.Array, theta: jax.Array, k_bits: int,
                      n_tables: int) -> jax.Array:
    """``[B, d] x [d, K*L] -> int32 bucket ids [B, L]``.

    sign(theta^T x) bits packed little-endian within each table.  No input
    normalization: sign() is scale-invariant, hard codes don't need it.
    """
    bits = (x.astype(jnp.float32) @ theta.astype(jnp.float32)) > 0
    shaped = bits.reshape(x.shape[0], n_tables, k_bits)
    weights = 2 ** jnp.arange(k_bits, dtype=jnp.int32)
    return jnp.sum(shaped.astype(jnp.int32) * weights, axis=-1)
