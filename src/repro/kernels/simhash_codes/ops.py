"""Public op: simhash bucket codes, dispatched through the kernel registry."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.registry import kernel_op
from repro.kernels.simhash_codes.kernel import simhash_codes_pallas
from repro.kernels.simhash_codes.ref import simhash_codes_ref

simhash_codes_op = kernel_op("simhash_codes")


@simhash_codes_op.impl("ref")
def _ref_impl(x: jax.Array, theta: jax.Array, k_bits: int, n_tables: int,
              *, block_b: int = 0) -> jax.Array:
    del block_b   # a pallas tiling knob; the jnp oracle has no blocks
    return simhash_codes_ref(x, theta, k_bits, n_tables)


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _pallas_impl(x: jax.Array, theta: jax.Array, k_bits: int, n_tables: int,
                 *, block_b: int, interpret: bool) -> jax.Array:
    bsz, d = x.shape
    xp = _pad_to(x, 0, block_b)
    tp = theta
    if not interpret:
        # Lane padding is a TPU tiling requirement only.  Interpret mode
        # runs the kernel body unpadded so the fp32 reductions see exactly
        # the ref's contraction length — bit-identical codes on CPU.
        xp = _pad_to(xp, 1, 128)
        tp = _pad_to(theta, 0, 128)
    out = simhash_codes_pallas(xp, tp, k_bits=k_bits, n_tables=n_tables,
                               block_b=block_b, interpret=interpret)
    return out[:bsz]


simhash_codes_op.register_impl(
    "pallas", functools.partial(_pallas_impl, interpret=False))
simhash_codes_op.register_impl(
    "pallas_interpret", functools.partial(_pallas_impl, interpret=True))


def simhash_codes(x: jax.Array, theta: jax.Array, k_bits: int,
                  n_tables: int, *, impl: str | None = None,
                  block_b: int = 256) -> jax.Array:
    """``[B, d] x [d, K*L] -> int32 bucket ids [B, L]``.

    impl: ``ref`` | ``pallas`` | ``pallas_interpret`` | None (registry
    auto-selection: pallas on TPU, ref elsewhere, overridable globally or
    via ``$REPRO_KERNEL_IMPL`` — see ``repro.kernels.registry``).
    """
    return simhash_codes_op(x, theta, k_bits, n_tables, impl=impl,
                            block_b=block_b)
