"""Public op: simhash bucket codes with impl dispatch + padding."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.simhash_codes.kernel import simhash_codes_pallas
from repro.kernels.simhash_codes.ref import simhash_codes_ref


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def simhash_codes(x: jax.Array, theta: jax.Array, k_bits: int,
                  n_tables: int, *, impl: str = "ref",
                  block_b: int = 256) -> jax.Array:
    """``[B, d] x [d, K*L] -> int32 bucket ids [B, L]``.

    impl: ``ref`` (pure jnp — used by the dry-run on any backend),
    ``pallas`` (TPU target), ``pallas_interpret`` (kernel body on CPU,
    used by tests).
    """
    if impl == "ref":
        return simhash_codes_ref(x, theta, k_bits, n_tables)
    bsz, d = x.shape
    xp = _pad_to(_pad_to(x, 1, 128), 0, block_b)
    tp = _pad_to(theta, 0, 128)
    out = simhash_codes_pallas(
        xp, tp, k_bits=k_bits, n_tables=n_tables, block_b=block_b,
        interpret=(impl == "pallas_interpret"))
    return out[:bsz]
