"""Pallas TPU kernel: fused ``X @ Theta -> sign -> bit-pack``.

One VMEM pass produces int32 bucket ids directly, instead of materialising
the ``[B, K*L]`` float scores and bool bits in HBM (3 HBM round-trips in the
naive lowering).  The bit-pack is expressed as a second tiny matmul against
a constant ``[K*L, L]`` selection matrix (MXU-friendly; values < 2^24 are
exact in f32).

Target layout notes (TPU v5e):
  * ``d_aug`` is padded to a multiple of 128 (lane dim) by ops.py.
  * block over batch: ``[TB, d]``; theta is small (KL <= 512 columns) and
    kept fully resident in VMEM across the grid.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 256


def _kernel(x_ref, theta_ref, pack_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)          # [TB, d]
    th = theta_ref[...].astype(jnp.float32)     # [d, KL]
    scores = jax.lax.dot_general(
        x, th, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)     # [TB, KL]
    bits = (scores > 0).astype(jnp.float32)
    packed = jax.lax.dot_general(
        bits, pack_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)     # [TB, L]
    out_ref[...] = packed.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k_bits", "n_tables",
                                             "block_b", "interpret"))
def simhash_codes_pallas(x: jax.Array, theta: jax.Array, *, k_bits: int,
                         n_tables: int, block_b: int = DEFAULT_BLOCK_B,
                         interpret: bool = False) -> jax.Array:
    """``[B, d] x [d, K*L] -> int32 [B, L]`` (B, d pre-padded by ops.py)."""
    bsz, d = x.shape
    kl = k_bits * n_tables
    assert theta.shape == (d, kl)
    assert bsz % block_b == 0, (bsz, block_b)
    # constant pack matrix: pack[l*K + j, l] = 2^j
    eye = jnp.eye(n_tables, dtype=jnp.float32)
    w = (2.0 ** jnp.arange(k_bits, dtype=jnp.float32))
    pack = (eye[:, None, :] * w[None, :, None]).reshape(kl, n_tables)

    grid = (bsz // block_b,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((d, kl), lambda i: (0, 0)),
            pl.BlockSpec((kl, n_tables), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, n_tables), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, n_tables), jnp.int32),
        interpret=interpret,
    )(x, theta, pack)
