from repro.kernels.lss_topk.ops import lss_topk
__all__ = ["lss_topk"]
