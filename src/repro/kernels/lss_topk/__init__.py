from repro.kernels.lss_topk.dedup import (dedup_auto_threshold,
                                          set_dedup_auto_threshold)
from repro.kernels.lss_topk.ops import (grid_steps, lss_topk,
                                        lss_topk_vmem_bytes)
__all__ = ["lss_topk", "grid_steps", "lss_topk_vmem_bytes",
           "dedup_auto_threshold", "set_dedup_auto_threshold"]
