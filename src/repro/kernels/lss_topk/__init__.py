"""Fused LSS retrieve->score->top-k: the serving hot path as ONE op.

Layout: ``kernel.py`` (the Pallas TPU pass), ``ref.py`` (the jnp
oracle), ``ops.py`` (registry dispatch + VMEM accounting), ``dedup.py``
(the ``lss_topk.dedup`` strategy), ``slabs.py`` (the
``lss_topk.slab_dtype`` storage strategy).

Invariants this package maintains — everything downstream (core.lss,
serve.heads, the engine's jitted steps) leans on them:

* **Oracle identity.** ``ref.lss_topk_ref`` composes the registered ref
  impls of the sub-ops, so it IS what ``lss_forward``'s ref path
  computes; pallas-interpret output is bit-identical to it for every
  (dedup, slab_dtype) combination, because interpret mode skips lane
  padding and both paths feed the same row-consistent CPU gemm the same
  fp32 operands (quantized storage dequantizes ELEMENTWISE before the
  gemm on both sides).
* **Static shapes.** Outputs are ``[B, k]`` / ``[B, L*P]`` with -1
  padding; duplicates are masked, never compacted.  Batch padding rows
  are row-local and sliced off, so they can never leak into a real
  query's top-k.
* **Storage is the index's choice.** ``slab_dtype`` resolves at index
  BUILD time (``core.lss.build_index``); this op consumes whatever
  format ``w_bucketed`` arrives in and requires ``w_scale`` iff it is
  int8.  DMA/VMEM cost helpers (``lss_topk_vmem_bytes``,
  ``lss_topk_slab_dma_bytes``) take the format so capacity planning
  reflects the real byte traffic.
"""

from repro.kernels.lss_topk.dedup import (dedup_auto_threshold,
                                          set_dedup_auto_threshold)
from repro.kernels.lss_topk.ops import (grid_steps, lss_topk,
                                        lss_topk_vmem_bytes)
from repro.kernels.lss_topk.slabs import (SLAB_DTYPE_CHOICES,
                                          lss_topk_slab_dma_bytes,
                                          quantize_slabs, dequantize_slabs,
                                          resolve_slab_dtype, slab_dtype_of)
__all__ = ["lss_topk", "grid_steps", "lss_topk_vmem_bytes",
           "dedup_auto_threshold", "set_dedup_auto_threshold",
           "SLAB_DTYPE_CHOICES", "lss_topk_slab_dma_bytes",
           "quantize_slabs", "dequantize_slabs", "resolve_slab_dtype",
           "slab_dtype_of"]
