"""Cross-table dedup strategies for the ``lss_topk`` candidate set.

The fused serving pass retrieves C = L*P candidate ids per query (one
``[P]`` slot row per table) and must keep exactly the FIRST occurrence of
every non-negative id before top-k — the paper's sample-size metric and
the exact-top-k contract both hang off that mask.  Two interchangeable
algorithms implement it, selected through the registry strategy knob
``lss_topk.dedup``:

``quadratic``
    The original ``[C, C]`` all-pairs compare: an id survives iff no
    EARLIER slot holds the same id.  O(C^2) work and O(C^2) memory per
    query — unbeatable VPU shape at small C, a VMEM wall past a few
    thousand candidates.

``bitonic``
    An O(C log^2 C) bitonic sorting network over (id, original-position)
    pairs, then a single neighbor compare marks the first occurrence of
    each id run.  Carrying the original position as the tie-break key
    makes the sort stable, so "first occurrence" means exactly what the
    quadratic mask means (lower index wins) and the two strategies are
    bit-identical end to end.  The network is expressed as static
    reshape / slice / select steps (power-of-two stage sizes, no
    gathers), so the same code path serves the jnp ref and the Pallas
    kernel body.

Auto-selection (``resolve_dedup``): ``quadratic`` up to
:func:`dedup_auto_threshold` candidates, ``bitonic`` beyond.  The
default threshold of 256 is the MEASURED CPU crossover of the full ref
path (quadratic beats both the bitonic network and the pre-strategy
argsort dedup below ~256 candidates, ties at 256, and collapses 5-10x
past 512; bitonic matches or beats the old argsort everywhere above —
re-measure with ``benchmarks.kernels_bench``, which records
``crossover_c`` in ``BENCH_kernels.json``), so the auto default is
never slower than the pre-strategy ref at any C.  The VMEM budget
alone would tolerate quadratic to C ~ 1024 (~9*C^2 bytes for the
``[C, C]`` compare plus its index iotas vs ~12 MiB) — a TPU host where
the compare's VPU shape wins can retune upward with the
``REPRO_LSS_DEDUP_AUTO_C`` env var or
:func:`set_dedup_auto_threshold`; per-call ``dedup=`` arguments,
``registry.use_strategy("lss_topk.dedup", ...)``, and the
``REPRO_LSS_DEDUP`` env var override the auto-select entirely,
mirroring how kernel impls resolve.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import registry

__all__ = [
    "DEDUP_CHOICES", "DEDUP_ENV_VAR", "AUTO_THRESHOLD_ENV_VAR",
    "INT32_MAX", "dedup_strategy", "resolve_dedup", "dedup_auto_threshold",
    "set_dedup_auto_threshold", "bitonic_sort_by_id_pos",
    "dedup_mask_quadratic", "dedup_mask_bitonic", "sorted_dedup",
]

DEDUP_CHOICES = ("quadratic", "bitonic")
DEDUP_ENV_VAR = "REPRO_LSS_DEDUP"
AUTO_THRESHOLD_ENV_VAR = "REPRO_LSS_DEDUP_AUTO_C"
DEFAULT_AUTO_THRESHOLD = 256

INT32_MAX = jnp.iinfo(jnp.int32).max   # sort sentinel for padded slots

_auto_threshold: int | None = None     # programmatic override


def dedup_auto_threshold() -> int:
    """Candidate count above which auto-select switches to bitonic."""
    if _auto_threshold is not None:
        return _auto_threshold
    env = os.environ.get(AUTO_THRESHOLD_ENV_VAR)
    return int(env) if env else DEFAULT_AUTO_THRESHOLD


def set_dedup_auto_threshold(c: int | None) -> None:
    """Pin the auto-select crossover (``None`` restores env/default) —
    e.g. from the measured crossover in ``benchmarks.kernels_bench``."""
    global _auto_threshold
    _auto_threshold = c


def _auto_dedup(n_candidates: int | None = None, **_ctx) -> str:
    if n_candidates is not None and n_candidates > dedup_auto_threshold():
        return "bitonic"
    return "quadratic"


dedup_strategy = registry.kernel_strategy(
    "lss_topk.dedup", DEDUP_CHOICES, env_var=DEDUP_ENV_VAR, auto=_auto_dedup)


def resolve_dedup(requested: str | None, n_candidates: int) -> str:
    """Resolve the dedup algorithm for a C-candidate call (logged in the
    registry dispatch log as ``("lss_topk.dedup", choice)``)."""
    return dedup_strategy.resolve(requested, n_candidates=n_candidates)


# ------------------------------------------------------------ quadratic --

def dedup_mask_quadratic(ids: jax.Array) -> jax.Array:
    """First-occurrence mask via the all-pairs compare.

    ``int32 [..., C] -> bool [..., C]``: True iff ``ids[i] >= 0`` and no
    ``j < i`` holds the same id.  Materialises ``[..., C, C]``.
    """
    c = ids.shape[-1]
    eq = ids[..., :, None] == ids[..., None, :]              # [..., C, C]
    earlier = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1) < \
        jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)       # col < row
    n_earlier = jnp.sum((eq & earlier).astype(jnp.int32), axis=-1)
    return (n_earlier == 0) & (ids >= 0)


# -------------------------------------------------------------- bitonic --

def _ceil_pow2(n: int) -> int:
    return 1 << max(n - 1, 1).bit_length() if n & (n - 1) else max(n, 2)


def _compare_exchange(arrays: tuple[jax.Array, ...], j: int, k: int
                      ) -> tuple[jax.Array, ...]:
    """One bitonic substage: compare-exchange elements at XOR-distance
    ``j`` inside stage ``k``, ordering by the lexicographic (id, pos) key
    held in ``arrays[0:2]``.

    Partners ``i`` and ``i ^ j`` are exposed by a static reshape to
    ``[..., n/(2j), 2, j]`` (j is a power of two), so the whole substage
    is slice/select/stack — no gathers, VPU-friendly in a kernel body.
    """
    keys, pos = arrays[0], arrays[1]
    n = keys.shape[-1]
    lead = keys.shape[:-1]

    def halves(a):
        s = a.reshape(lead + (n // (2 * j), 2, j))
        return s[..., 0, :], s[..., 1, :]

    kl, kr = halves(keys)
    pl_, pr = halves(pos)
    # ascending iff (i & k) == 0 — constant across each 2j-block because
    # 2j divides k, so it reduces to a per-block parity of (block*2j)//k
    blk = jnp.arange(n // (2 * j), dtype=jnp.int32)
    asc = ((blk * (2 * j)) & k) == 0                         # [n/(2j)]
    asc = asc.reshape((1,) * len(lead) + (n // (2 * j), 1))
    swap = (kl > kr) | ((kl == kr) & (pl_ > pr))             # asc violation
    swap = jnp.where(asc, swap, ~swap)

    def merge(a):
        lo, hi = halves(a)
        nlo = jnp.where(swap, hi, lo)
        nhi = jnp.where(swap, lo, hi)
        return jnp.stack([nlo, nhi], axis=-2).reshape(lead + (n,))

    return tuple(merge(a) for a in arrays)


def bitonic_sort_by_id_pos(ids: jax.Array, pos: jax.Array,
                           *payload: jax.Array) -> tuple[jax.Array, ...]:
    """Sort ``(ids, pos, *payload)`` along the last axis ascending by the
    (id, pos) pair with a bitonic network.

    The last axis must be a power of two >= 2.  Because ``pos`` values
    are distinct, the sort is a deterministic permutation — equal ids
    come out in original-position order, which is exactly the stable
    lower-index-wins contract the dedup + top-k epilogue needs.
    O(log^2 n) substages, each O(n) work, statically unrolled.
    """
    n = ids.shape[-1]
    assert n >= 2 and n & (n - 1) == 0, f"need pow2 length, got {n}"
    arrays = (ids, pos) + payload
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            arrays = _compare_exchange(arrays, j, k)
            j //= 2
        k *= 2
    return arrays


def sorted_dedup(ids: jax.Array, logits: jax.Array
                 ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Bitonic-sort (id, pos, logit) rows and mark first occurrences.

    ``ids, logits: [..., C]`` -> ``(sorted_ids, sorted_pos, sorted_logits,
    first)`` each ``[..., n]`` with n the next power of two: padded slots
    carry ``INT32_MAX`` ids / ``pos >= C`` and are never marked first;
    ``first`` is True exactly once per distinct non-negative id — at its
    lowest original position.  Everything downstream (sample size, top-k
    with position tie-breaks) can run in the sorted domain.
    """
    c = ids.shape[-1]
    n = _ceil_pow2(c)
    lead = ids.shape[:-1]
    pos = jnp.broadcast_to(
        jax.lax.broadcasted_iota(jnp.int32, (1,) * len(lead) + (n,),
                                 len(lead)), lead + (n,))
    if n != c:
        pad = [(0, 0)] * len(lead) + [(0, n - c)]
        ids = jnp.pad(ids, pad, constant_values=INT32_MAX)
        logits = jnp.pad(logits, pad, constant_values=0.0)
    sids, spos, slog = bitonic_sort_by_id_pos(ids, pos, logits)
    new_run = jnp.concatenate(
        [jnp.ones(lead + (1,), bool), sids[..., 1:] != sids[..., :-1]],
        axis=-1)
    first = new_run & (sids >= 0) & (sids != INT32_MAX)
    return sids, spos, slog, first


def dedup_mask_bitonic(ids: jax.Array) -> jax.Array:
    """First-occurrence mask via the sorting network, scattered back to
    original positions — boolean-identical to ``dedup_mask_quadratic``.

    ``int32 [B, C] -> bool [B, C]``.
    """
    bsz, c = ids.shape
    _, spos, _, first = sorted_dedup(ids, jnp.zeros_like(ids, jnp.float32))
    n = spos.shape[-1]
    b = jnp.arange(bsz)[:, None]
    return jnp.zeros((bsz, n), bool).at[b, spos].set(first)[:, :c]
