"""Pure-jnp oracle for the fused LSS top-k kernel.

Composes the registry ref impls of the two sub-ops (simhash_codes,
bucket_logits) with the dedup + top-k epilogue — so this oracle IS, op
for op, what ``lss_forward``'s ref path computes on a bucket-major
index.  Bit-identity between the fused kernel and ``lss_forward``
reduces to bit-identity against this function.

The dedup step honors the ``lss_topk.dedup`` strategy knob
(``quadratic`` | ``bitonic``, see ``kernels.lss_topk.dedup``): both
produce the identical first-occurrence boolean mask, so the oracle's
outputs are bit-identical across strategies — the knob only moves the
CPU cost from O(C^2) all-pairs compares to an O(C log^2 C) sorting
network, which is what keeps the ref path (the CPU-measurable serving
path) sub-quadratic in the paper's large-sample regimes.

Quantized slab storage (``lss_topk.slab_dtype``, see
``kernels.lss_topk.slabs``): when the index stores bf16/int8 slabs the
oracle widens the WHOLE slab tensor to fp32 up front
(``dequantize_slabs``) and then runs the identical pipeline.  Widening
is elementwise, so the kernel — which widens each fetched ``[P, d]``
slab in VMEM instead — sees bit-identical operand matrices and the
interpret-mode exact-equality contract holds per storage format.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bucket_logits.ref import bucket_logits_ref
from repro.kernels.lss_topk.dedup import (dedup_mask_bitonic,
                                          dedup_mask_quadratic,
                                          resolve_dedup)
from repro.kernels.lss_topk.slabs import dequantize_slabs
from repro.kernels.simhash_codes.ref import simhash_codes_ref


def lss_topk_ref(q_aug: jax.Array, theta: jax.Array, table_ids: jax.Array,
                 w_bucketed: jax.Array, *, top_k: int,
                 dedup: str | None = None,
                 w_scale: jax.Array | None = None
                 ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Retrieve -> slab logits -> dedup mask -> top-k, all in jnp.

    Args:
      q_aug:      ``[B, d_aug]`` bias-augmented queries.
      theta:      ``[d_aug, K*L]`` hyperplanes.
      table_ids:  int32 ``[L, 2^K, P]`` bucket-major neuron ids, -1 padded.
      w_bucketed: ``[L, 2^K, P, d_aug]`` bucket-major WOL slabs
                  (fp32 | bf16 | int8 storage, see
                  ``kernels.lss_topk.slabs``).
      dedup:      ``quadratic`` | ``bitonic`` | None (strategy
                  auto-select on C = L*P).
      w_scale:    fp32 ``[L, 2^K, P]`` per-neuron-row scales (int8
                  storage only, else None).

    Returns:
      (top_logits [B,k] f32, top_ids [B,k] i32, sample_size [B] i32,
       cand_ids [B, L*P] i32) — the :class:`repro.core.lss.LSSForward`
      fields.
    """
    # Deferred: core.lss routes through repro.kernels at module scope, so
    # importing it here at module scope would be circular.
    from repro.core import simhash
    from repro.core.lss import NEG_INF

    n_tables, n_buckets, cap = table_ids.shape
    k_bits = n_buckets.bit_length() - 1
    bsz = q_aug.shape[0]
    # dequantize-on-the-fly, oracle form: widen once, elementwise — the
    # kernel widens per fetched slab, which is the same values
    w_bucketed = dequantize_slabs(w_bucketed, w_scale)

    # sign(theta^T x) is scale-invariant; normalizing first matches the
    # hash definition in core.simhash (shared with the IUL relaxation).
    buckets = simhash_codes_ref(simhash.unit(q_aug), theta, k_bits,
                                n_tables)                       # [B, L]
    slab_ids = buckets + jnp.arange(
        n_tables, dtype=buckets.dtype)[None, :] * n_buckets     # [B, L]

    cand = table_ids.reshape(-1, cap)[slab_ids]                 # [B, L, P]
    cand = cand.reshape(bsz, -1)                                # [B, C]
    w_flat = w_bucketed.reshape(-1, cap, w_bucketed.shape[-1])
    logits = bucket_logits_ref(q_aug, w_flat, slab_ids)         # [B, L, P]
    logits = logits.reshape(bsz, -1)

    # an explicit dedup= arrives pre-resolved (and pre-logged) from the
    # dispatching wrapper; only resolve (and log) when called directly
    choice = (dedup if dedup is not None
              else resolve_dedup(None, n_candidates=cand.shape[-1]))
    assert choice in ("quadratic", "bitonic"), choice
    mask = (dedup_mask_quadratic(cand) if choice == "quadratic"
            else dedup_mask_bitonic(cand))
    logits = jnp.where(mask, logits, NEG_INF)
    top_logits, pos = jax.lax.top_k(logits, top_k)
    top_ids = jnp.take_along_axis(cand, pos, axis=-1)
    top_ids = jnp.where(top_logits > NEG_INF / 2, top_ids, -1)
    return top_logits, top_ids, jnp.sum(mask, axis=-1), cand
