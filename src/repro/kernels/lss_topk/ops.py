"""Public op: fused LSS retrieve->score->top-k, dispatched through the
kernel registry.

This is the serving hot path: ``core.lss.lss_forward`` routes every
bucket-major forward through this op, so whichever impl the registry
resolves (ref on CPU, pallas on TPU, pallas_interpret under test) is the
one that actually serves traffic.

Two registry knobs shape a call:

* ``impl`` — which implementation runs (``ref`` | ``pallas`` |
  ``pallas_interpret``), as for every op.
* ``dedup`` — which cross-table dedup algorithm every impl uses
  (``quadratic`` | ``bitonic``), resolved through the
  ``lss_topk.dedup`` strategy (auto-select on C = L*P, ``REPRO_LSS_DEDUP``
  env override; see ``kernels.lss_topk.dedup``).

A third knob, ``lss_topk.slab_dtype`` (``fp32`` | ``bf16`` | ``int8``,
see ``kernels.lss_topk.slabs``), is resolved at INDEX BUILD time rather
than per call: this op simply consumes whatever storage format
``w_bucketed`` arrives in, taking the per-neuron-row scale table via
``w_scale`` when the slabs are int8 and dequantizing on the fly inside
each impl.

There is no hardcoded candidate ceiling anymore: past the old ~2k
comfort limit the strategy auto-switches to the bitonic dedup, and a
warning fires only when the VMEM working set DERIVED from the actual
shape (:func:`lss_topk_vmem_bytes` over C, d, cap, Bq) exceeds the
budget a TPU core can stage.
"""

from __future__ import annotations

import functools
import os
import warnings

import jax
import jax.numpy as jnp

from repro.kernels.lss_topk import dedup as dedup_mod
from repro.kernels.lss_topk import slabs as slabs_mod
from repro.kernels.lss_topk.kernel import DEFAULT_BLOCK_Q, lss_topk_pallas
from repro.kernels.lss_topk.ref import lss_topk_ref
from repro.kernels.registry import kernel_op

lss_topk_op = kernel_op("lss_topk")
lss_topk_op.register_impl("ref", lss_topk_ref)

# Practical per-core VMEM budget for the kernel's working set (the full
# VMEM is ~16 MiB; leave headroom for the compiler's own staging).
VMEM_BUDGET_BYTES = 12 * 2 ** 20

BLOCK_Q_ENV_VAR = "REPRO_LSS_BLOCK_Q"


def default_block_q() -> int:
    """Query-tile rows per grid step (env ``REPRO_LSS_BLOCK_Q``)."""
    env = os.environ.get(BLOCK_Q_ENV_VAR)
    return int(env) if env else DEFAULT_BLOCK_Q


def grid_steps(bsz: int, block_q: int | None = None) -> int:
    """Pallas grid size for a B-query call: ``ceil(B / Bq)`` query tiles
    (the pre-blocking kernel ran ``B`` steps).  Single source of truth —
    ``_pallas_impl`` sizes its grid and padding from this."""
    bq = effective_block_q(bsz, block_q)
    return -(-bsz // bq)


def effective_block_q(bsz: int, block_q: int | None = None) -> int:
    """Tile height actually used: never taller than the batch, so a
    bucket-1 decode step keeps its single-row grid instead of paying for
    seven padded rows of hash + slab traffic."""
    bq = block_q or default_block_q()
    return max(1, min(bq, bsz))


def lss_topk_vmem_bytes(n_candidates: int, d: int, cap: int, *,
                        block_q: int | None = None,
                        dedup: str = "bitonic", kl: int = 64,
                        slab_dtype: str = "fp32") -> int:
    """Estimated VMEM working set of one fused-kernel grid step.

    Counts the resident operands (theta ``[d, KL]``, pack, the query
    tile, double-buffered ``2x[P, d]`` slab + ``2x[P]`` id scratch — the
    slab scratch shrinking with the storage itemsize, plus ``2x[P]``
    fp32 scale scratch when the storage is int8), the ``[Bq, C]``
    logit/candidate tiles, and the dedup working set: ``~9*C^2`` bytes
    for the quadratic all-pairs compare (id/iota int32 pairs + the bool
    mask) vs ``~4 arrays x [Bq, pow2(C)] x 4`` bytes for the bitonic
    network (id, pos, logit, plus one merge temp).
    """
    bq = block_q or default_block_q()
    c = n_candidates
    item = slabs_mod.slab_itemsize(slab_dtype)
    fixed = 4 * (d * kl + kl * bq + bq * d)        # theta + pack + q tile
    slabs = 2 * cap * d * item + 2 * cap * 4       # double-buffered scratch
    if slab_dtype == "int8":
        slabs += 2 * cap * 4                       # fp32 scale-row scratch
    tiles = 2 * bq * c * 4                         # logits + cand
    if dedup == "quadratic":
        dedup_ws = 9 * c * c                       # eq bool + iota pair
    else:
        n_pad = 1 << max(c - 1, 1).bit_length()
        dedup_ws = 4 * bq * n_pad * 4 * 2          # 4 arrays + merge temp
    return fixed + slabs + tiles + dedup_ws


@functools.lru_cache(maxsize=None)
def _warn_vmem_exceeded(n_candidates: int, d: int, cap: int, block_q: int,
                        dedup: str, slab_dtype: str, est: float) -> None:
    """One-time (per shape) heads-up that even the selected dedup
    strategy cannot stage this shape's working set in VMEM."""
    warnings.warn(
        f"lss_topk: estimated VMEM working set {est / 2**20:.1f} MiB for "
        f"C={n_candidates}, d={d}, P={cap}, Bq={block_q}, dedup={dedup}, "
        f"slab_dtype={slab_dtype} exceeds the "
        f"~{VMEM_BUDGET_BYTES / 2**20:.0f} MiB budget; the "
        f"fused kernel will spill or fail to fit at this size. Reduce "
        f"table capacity / k_bits / block_q, quantize the slabs "
        f"(lss_topk.slab_dtype), or shard the vocabulary "
        f"(serve.heads.shard_index).", stacklevel=4)


def _check_vmem(n_candidates: int, d: int, cap: int, block_q: int,
                dedup: str, kl: int, slab_dtype: str) -> None:
    est = lss_topk_vmem_bytes(n_candidates, d, cap, block_q=block_q,
                              dedup=dedup, kl=kl, slab_dtype=slab_dtype)
    if est > VMEM_BUDGET_BYTES:
        _warn_vmem_exceeded(n_candidates, d, cap, block_q, dedup,
                            slab_dtype, est)


def _pallas_impl(q_aug: jax.Array, theta: jax.Array, table_ids: jax.Array,
                 w_bucketed: jax.Array, *, top_k: int, interpret: bool,
                 dedup: str | None = None, block_q: int | None = None,
                 w_scale: jax.Array | None = None
                 ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    n_tables, n_buckets, cap = table_ids.shape
    k_bits = n_buckets.bit_length() - 1
    assert 2 ** k_bits == n_buckets, n_buckets
    bsz, d = q_aug.shape
    # an explicit dedup= arrives pre-resolved from the dispatching
    # wrapper; only resolve (and log) when called directly
    choice = (dedup if dedup is not None
              else dedup_mod.resolve_dedup(None, n_candidates=n_tables * cap))
    bq = effective_block_q(bsz, block_q)
    tids = table_ids.reshape(n_tables * n_buckets, cap)
    w_flat = w_bucketed.reshape(n_tables * n_buckets, cap, d)
    scales = (w_scale.reshape(n_tables * n_buckets, cap)
              .astype(jnp.float32) if w_scale is not None else None)
    # Query-tile padding applies in BOTH modes (the grid is blocked
    # either way): zero rows hash to some bucket like any query, produce
    # ordinary per-row outputs, and are sliced off below — padding can
    # never reach a real query's top-k because every row's dedup + top-k
    # is row-local.
    pad_b = (-bsz) % bq
    if pad_b:
        q_aug = jnp.pad(q_aug, ((0, pad_b), (0, 0)))
    pad_p = 0
    if not interpret:
        # TPU lane alignment; interpret mode runs unpadded so the fp32
        # reductions are bit-identical to the jnp oracle (see kernel.py).
        pad_d = (-d) % 128
        pad_p = (-cap) % 128
        if pad_d:
            q_aug = jnp.pad(q_aug, ((0, 0), (0, pad_d)))
            theta = jnp.pad(theta, ((0, pad_d), (0, 0)))
            w_flat = jnp.pad(w_flat, ((0, 0), (0, 0), (0, pad_d)))
        if pad_p:
            w_flat = jnp.pad(w_flat, ((0, 0), (0, pad_p), (0, 0)))
            # padded capacity slots must read as empty, not as neuron 0
            tids = jnp.pad(tids, ((0, 0), (0, pad_p)), constant_values=-1)
            if scales is not None:
                # padded slots hold zero codes; 0 * 0.0 dequantizes to 0
                scales = jnp.pad(scales, ((0, 0), (0, pad_p)))
    top_logits, top_ids, sample, cand = lss_topk_pallas(
        q_aug, theta, tids, w_flat, scales, k_bits=k_bits,
        n_tables=n_tables, top_k=top_k, block_q=bq, dedup=choice,
        interpret=interpret)
    if pad_b:
        top_logits = top_logits[:bsz]
        top_ids = top_ids[:bsz]
        sample = sample[:bsz]
        cand = cand[:bsz]
    if pad_p:
        cand = cand.reshape(bsz, n_tables, -1)[:, :, :cap]
        cand = cand.reshape(bsz, n_tables * cap)
    return top_logits, top_ids, sample[:, 0], cand


lss_topk_op.register_impl(
    "pallas", functools.partial(_pallas_impl, interpret=False))
lss_topk_op.register_impl(
    "pallas_interpret", functools.partial(_pallas_impl, interpret=True))


def lss_topk(q_aug: jax.Array, theta: jax.Array, table_ids: jax.Array,
             w_bucketed: jax.Array, *, top_k: int, impl: str | None = None,
             dedup: str | None = None, w_scale: jax.Array | None = None
             ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused Algorithm-2 forward over a bucket-major index.

    ``[B,d] x [d,KL] x [L,2^K,P] x [L,2^K,P,d] ->``
    ``(top_logits [B,k], top_ids [B,k], sample_size [B], cand_ids [B,L*P])``

    impl:    ``ref`` | ``pallas`` | ``pallas_interpret`` | None (registry
             auto-selection — see ``repro.kernels.registry``).
    dedup:   ``quadratic`` | ``bitonic`` | None (strategy auto-select on
             C = L*P — see ``repro.kernels.lss_topk.dedup``).
    w_scale: fp32 ``[L, 2^K, P]`` per-neuron-row scale table — required
             iff ``w_bucketed`` stores int8 slabs (the
             ``lss_topk.slab_dtype`` knob is resolved at index build
             time; see ``repro.kernels.lss_topk.slabs``).
    """
    n_tables, _, capacity = table_ids.shape
    c = n_tables * capacity
    sdt = slabs_mod.slab_dtype_of(w_bucketed)
    if (sdt == "int8") != (w_scale is not None):
        raise ValueError(
            f"slab_dtype={sdt} storage and w_scale disagree: int8 slabs "
            f"require a per-neuron-row scale table, other formats forbid "
            f"one (got w_scale={'set' if w_scale is not None else 'None'})")
    choice = dedup_mod.resolve_dedup(dedup, n_candidates=c)
    bq = effective_block_q(q_aug.shape[0])
    _check_vmem(c, q_aug.shape[1], capacity, bq, choice, theta.shape[1],
                sdt)
    return lss_topk_op(q_aug, theta, table_ids, w_bucketed, top_k=top_k,
                       dedup=choice, w_scale=w_scale, impl=impl)
