"""Public op: fused LSS retrieve->score->top-k, dispatched through the
kernel registry.

This is the serving hot path: ``core.lss.lss_forward`` routes every
bucket-major forward through this op, so whichever impl the registry
resolves (ref on CPU, pallas on TPU, pallas_interpret under test) is the
one that actually serves traffic.
"""

from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp

from repro.kernels.lss_topk.kernel import lss_topk_pallas
from repro.kernels.lss_topk.ref import lss_topk_ref
from repro.kernels.registry import kernel_op

lss_topk_op = kernel_op("lss_topk")
lss_topk_op.register_impl("ref", lss_topk_ref)

# Past this candidate count the O(C^2) in-kernel dedup (a [C, C] compare
# in fp32-adjacent int space) stops fitting comfortably in VMEM alongside
# the [P, d] slabs; the ROADMAP follow-up is a sorted/bitonic dedup.
DEDUP_COMFORT_LIMIT = 2048


@functools.lru_cache(maxsize=None)
def _warn_large_candidate_count(n_tables: int, capacity: int) -> None:
    """One-time (per L x P shape) heads-up that the dedup is the scaling
    wall, emitted at trace time from the dispatching call site."""
    c = n_tables * capacity
    warnings.warn(
        f"lss_topk: candidate count C = L*P = {n_tables}*{capacity} = {c} "
        f"exceeds ~{DEDUP_COMFORT_LIMIT}; the fused kernel's O(C^2) "
        f"duplicate-mask no longer fits comfortably in VMEM at this size "
        f"and will dominate the pass. Reduce table capacity / k_bits, or "
        f"see the ROADMAP item on switching to a sorted (bitonic) dedup.",
        stacklevel=3)


def _pallas_impl(q_aug: jax.Array, theta: jax.Array, table_ids: jax.Array,
                 w_bucketed: jax.Array, *, top_k: int, interpret: bool
                 ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    n_tables, n_buckets, cap = table_ids.shape
    k_bits = n_buckets.bit_length() - 1
    assert 2 ** k_bits == n_buckets, n_buckets
    bsz, d = q_aug.shape
    tids = table_ids.reshape(n_tables * n_buckets, cap)
    w_flat = w_bucketed.reshape(n_tables * n_buckets, cap, d)
    pad_p = 0
    if not interpret:
        # TPU lane alignment; interpret mode runs unpadded so the fp32
        # reductions are bit-identical to the jnp oracle (see kernel.py).
        pad_d = (-d) % 128
        pad_p = (-cap) % 128
        if pad_d:
            q_aug = jnp.pad(q_aug, ((0, 0), (0, pad_d)))
            theta = jnp.pad(theta, ((0, pad_d), (0, 0)))
            w_flat = jnp.pad(w_flat, ((0, 0), (0, 0), (0, pad_d)))
        if pad_p:
            w_flat = jnp.pad(w_flat, ((0, 0), (0, pad_p), (0, 0)))
            # padded capacity slots must read as empty, not as neuron 0
            tids = jnp.pad(tids, ((0, 0), (0, pad_p)), constant_values=-1)
    top_logits, top_ids, sample, cand = lss_topk_pallas(
        q_aug, theta, tids, w_flat, k_bits=k_bits, n_tables=n_tables,
        top_k=top_k, interpret=interpret)
    if pad_p:
        cand = cand.reshape(bsz, n_tables, -1)[:, :, :cap]
        cand = cand.reshape(bsz, n_tables * cap)
    return top_logits, top_ids, sample[:, 0], cand


lss_topk_op.register_impl(
    "pallas", functools.partial(_pallas_impl, interpret=False))
lss_topk_op.register_impl(
    "pallas_interpret", functools.partial(_pallas_impl, interpret=True))


def lss_topk(q_aug: jax.Array, theta: jax.Array, table_ids: jax.Array,
             w_bucketed: jax.Array, *, top_k: int, impl: str | None = None
             ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused Algorithm-2 forward over a bucket-major index.

    ``[B,d] x [d,KL] x [L,2^K,P] x [L,2^K,P,d] ->``
    ``(top_logits [B,k], top_ids [B,k], sample_size [B], cand_ids [B,L*P])``

    impl: ``ref`` | ``pallas`` | ``pallas_interpret`` | None (registry
    auto-selection — see ``repro.kernels.registry``).
    """
    n_tables, _, capacity = table_ids.shape
    if n_tables * capacity > DEDUP_COMFORT_LIMIT:
        _warn_large_candidate_count(n_tables, capacity)
    return lss_topk_op(q_aug, theta, table_ids, w_bucketed, top_k=top_k,
                       impl=impl)
