"""Quantized slab storage for the fused ``lss_topk`` path.

The bucket-major WOL slabs (``[L, 2^K, P, d]``) are the fused kernel's
dominant DMA traffic: every query streams ``L`` hit slabs from HBM to
VMEM, so at fp32 the per-query byte count is ``L * P * (4d + 4)`` and
slab bytes — not compute — bound the candidate ceiling (see
``ops.lss_topk_vmem_bytes`` / ``lss_topk_slab_dma_bytes``).  The paper's
own framing justifies compressing them aggressively: LSS is tuned for
*label recall*, not inner-product magnitude, so the slab representation
only has to preserve which labels survive the top-k (PAPER.md §4;
PAPERS.md: anisotropic/score-aware quantization à la ScaNN preserves
exactly this).

Three storage formats, selected through the registry strategy knob
``lss_topk.slab_dtype`` (resolved like ``lss_topk.dedup`` — explicit
argument > process override > ``$REPRO_LSS_SLAB_DTYPE`` > auto, which
defaults to ``fp32``; every resolution is recorded in the registry
dispatch log):

``fp32``
    The original layout.  Exact, 4 bytes/element.

``bf16``
    Slabs cast to bfloat16, no side table.  2 bytes/element; dequantize
    is a pure ``astype`` widening.

``int8``
    Symmetric per-NEURON-row int8 (``optim.compression.quantize_int8_rows``:
    one fp32 scale per ``[d]`` row, so a slab DMA becomes an int8
    ``[P, d]`` block plus a ``[P]`` scale row).  1 byte/element + 4/d
    for scales — ~3.6x fewer slab DMA bytes at d=64, and the index for a
    10M-class WOL shrinks from ~10 GB to ~2.7 GB.

Quantization happens ONCE, at :func:`repro.core.lss.build_index` time
(and again automatically on every IUL refit — ``fit_lss`` rebuilds the
index through the same constructor).  Both the jnp ref and the Pallas
kernel then dequantize on the fly: the ref widens the whole slab tensor
before its gemm, the kernel widens each fetched ``[P, d]`` slab in VMEM
right before its ``[Bq, d] @ [d, P]`` MXU matmul.  Because dequantize is
an elementwise fp32 op (``q * scale``), both paths feed bit-identical
operand matrices to the same row-consistent gemm, so the ref /
pallas-interpret exact-equality contract of the fp32 path carries over
unchanged to every storage format (tested in ``tests/test_slab_quant.py``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.optim.compression import dequantize_int8_rows, quantize_int8_rows

__all__ = [
    "SLAB_DTYPE_CHOICES", "SLAB_DTYPE_ENV_VAR", "slab_dtype_strategy",
    "resolve_slab_dtype", "slab_dtype_of", "slab_itemsize",
    "quantize_slabs", "dequantize_slabs", "lss_topk_slab_dma_bytes",
]

SLAB_DTYPE_CHOICES = ("fp32", "bf16", "int8")
SLAB_DTYPE_ENV_VAR = "REPRO_LSS_SLAB_DTYPE"

_DTYPES = {"fp32": jnp.float32, "bf16": jnp.bfloat16, "int8": jnp.int8}
_NAMES = {jnp.dtype(v): k for k, v in _DTYPES.items()}
_ITEMSIZE = {"fp32": 4, "bf16": 2, "int8": 1}


def _auto_slab_dtype(**_ctx) -> str:
    """Auto default: fp32 — storage compression is an opt-in accuracy
    trade (unlike the dedup knob, whose choices are bit-identical)."""
    return "fp32"


slab_dtype_strategy = registry.kernel_strategy(
    "lss_topk.slab_dtype", SLAB_DTYPE_CHOICES, env_var=SLAB_DTYPE_ENV_VAR,
    auto=_auto_slab_dtype)


def resolve_slab_dtype(requested: str | None = None, **ctx) -> str:
    """Resolve the slab storage format (logged in the registry dispatch
    log as ``("lss_topk.slab_dtype", choice)``).  Called at INDEX BUILD
    time — the serving-time kernel simply consumes whatever storage the
    index holds."""
    return slab_dtype_strategy.resolve(requested, **ctx)


def slab_dtype_of(w_bucketed: jax.Array) -> str:
    """The strategy name for a slab tensor's dtype (fp32|bf16|int8)."""
    name = _NAMES.get(jnp.dtype(w_bucketed.dtype))
    if name is None:
        raise ValueError(
            f"slab dtype {w_bucketed.dtype} is not one of the "
            f"lss_topk.slab_dtype storage formats {SLAB_DTYPE_CHOICES}")
    return name


def slab_itemsize(slab_dtype: str) -> int:
    """Bytes per slab element for a storage format name."""
    return _ITEMSIZE[slab_dtype]


def quantize_slabs(w_bucketed: jax.Array, slab_dtype: str
                   ) -> tuple[jax.Array, jax.Array | None]:
    """Encode fp32 bucket-major slabs into the requested storage format.

    ``[L, 2^K, P, d] -> (slabs, scales)`` where ``scales`` is the
    per-neuron-row fp32 ``[L, 2^K, P]`` table for int8 and ``None``
    otherwise.  Empty (-1) slots are zero rows; they quantize to zero
    codes and dequantize back to exactly 0, so the "padded slots score
    logit 0, masked by id" contract of ``bucketize_weights`` holds for
    every format.
    """
    if slab_dtype == "fp32":
        return w_bucketed.astype(jnp.float32), None
    if slab_dtype == "bf16":
        return w_bucketed.astype(jnp.bfloat16), None
    if slab_dtype == "int8":
        return quantize_int8_rows(w_bucketed)
    raise ValueError(f"slab_dtype must be one of {SLAB_DTYPE_CHOICES}, "
                     f"got {slab_dtype!r}")


def dequantize_slabs(w_bucketed: jax.Array, w_scale: jax.Array | None
                     ) -> jax.Array:
    """Widen stored slabs back to fp32 (the jnp-ref side of the
    dequantize-on-the-fly contract; the kernel applies the identical
    elementwise op per fetched slab)."""
    name = slab_dtype_of(w_bucketed)
    if name == "int8":
        assert w_scale is not None, "int8 slabs need their scale table"
        return dequantize_int8_rows(w_bucketed, w_scale)
    return w_bucketed.astype(jnp.float32)


def lss_topk_slab_dma_bytes(n_tables: int, cap: int, d: int,
                            slab_dtype: str = "fp32") -> int:
    """Slab-stream HBM->VMEM bytes PER QUERY for one fused-kernel pass:
    ``L`` slab fetches of ``[P, d]`` weights + ``[P]`` int32 ids, plus a
    ``[P]`` fp32 scale row per fetch when the storage is int8.  This is
    the kernel's real per-query bottleneck once C clears the dedup
    crossover (the quantity ``benchmarks.kernels_bench`` records per
    slab_dtype)."""
    per_slab = cap * d * slab_itemsize(slab_dtype) + cap * 4
    if slab_dtype == "int8":
        per_slab += cap * 4                      # the [P] scale row
    return n_tables * per_slab
