"""Fused Pallas TPU kernel: the whole LSS serving pipeline in one pass.

Per grid step — one ``[Bq, d]`` QUERY TILE, not one query:

    simhash codes for the tile (hash matmul + sign + bit-pack)
      -> double-buffered data-dependent slab DMA (bucket-major weights
         stay in HBM; only hit slabs ever reach VMEM, and table fetch
         t+1 overlaps the MXU matmul of fetch t)
      -> slab logits as [Bq, d] @ [d, P] MXU matmuls
      -> cross-table dedup (quadratic [C, C] mask or bitonic sorting
         network, per the ``lss_topk.dedup`` strategy)
      -> first-occurrence top-k

The slab index depends on the hash computed INSIDE the kernel, so the
canonical scalar-prefetch trick (``bucket_logits``) cannot express it:
instead ``w_slabs``/``table_ids`` are bound with ``memory_space=ANY`` and
fetched with ``pltpu.make_async_copy`` at a runtime-computed index — the
same manual-DMA pattern as paged attention, but through a 2-deep rotating
scratch (``w_vmem[2, P, d]``) so the fetch for slot ``s^1`` is in flight
while the matmul consumes slot ``s``.  Nothing wider than two ``[P, d]``
slabs is ever materialised, which is the point of LSS: the full head
streams ``m*d`` weights per batch, this kernel streams ``L*P*d`` per
query with no HBM round-trips for the intermediate codes or logits.

Quantized slab storage (``lss_topk.slab_dtype``, see
``kernels.lss_topk.slabs``): the slab scratch inherits the storage dtype,
so bf16 slabs halve and int8 slabs quarter the DMA bytes per fetch.  For
int8 each fetch also streams the slab's ``[P]`` fp32 scale row through a
third rotating scratch, and the kernel dequantizes IN VMEM right before
the matmul (``w.astype(f32) * scale[:, None]`` — elementwise, so the
operand matrix is bit-identical to the jnp oracle's up-front widening and
the interpret-mode exactness contract below extends to every format).

Query blocking (``grid=(ceil(B/Bq),)``) amortises per-step dispatch and
turns the slab product into an MXU-shaped ``[Bq, d] @ [d, P]`` matmul
(row b of the product is that query's logits; the other rows ride the
same MXU pass for free) instead of a degenerate ``[1, d]`` GEMV.  The
fetch schedule is shared: one double-buffered stream of ``Bq*L`` slab
copies per tile.

Bit-exactness contract (interpret mode, CPU): every fp32 reduction is
expressed so XLA lowers it to the same gemm the jnp oracle uses — XLA's
CPU gemm is row-consistent across leading-dim shapes, so slicing row b
out of the ``[Bq, d] @ [d, P]`` product is bit-identical to the ref's
einsum row (exact-equality tested across the C/B/d sweep).  ``ops.py``
skips lane padding in interpret mode so contraction lengths match the
ref, and pads B up to the tile multiple with zero rows that are sliced
off after the call.

Dedup strategies (see ``kernels.lss_topk.dedup``):

* ``quadratic`` — the original per-row ``[C, C]`` compare + original-
  order top-k.  VMEM cost grows with C^2; right answer below ~2k
  candidates.
* ``bitonic`` — sort (id, pos, logit) rows with an O(C log^2 C) network,
  mark first occurrences with one neighbor compare, then run top-k IN
  THE SORTED DOMAIN, breaking logit ties by the carried original
  position.  Because ties break on the same key and the surviving
  (logit, pos) multiset is identical, the outputs are bit-identical to
  the quadratic path — tested, not assumed.

Top-k is k passes of masked max with first-occurrence argmin-of-index,
which reproduces ``jax.lax.top_k``'s stable lower-index-first
tie-breaking exactly (k is small: 1-10 in every serving config).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.lss_topk.dedup import sorted_dedup

NEG_INF = -1e30   # matches repro.core.lss.NEG_INF (kept import-free)

DEFAULT_BLOCK_Q = 8   # MXU-friendly query-tile rows per grid step


def _topk_quadratic_row(cand_row, logits_row, top_k):
    """Original-order dedup + top-k for one ``[1, C]`` candidate row.
    Returns (top_l [1,k], top_i [1,k], sample [1,1]).

    The mask math intentionally restates ``dedup.dedup_mask_quadratic``
    in strictly 2-D form: the shared helper builds a batched
    ``[..., C, C]`` compare, and rank-3 intermediates don't lower well
    in Mosaic — the kernel keeps every array at the ``[C, C]`` /
    ``[1, C]`` shapes the pre-blocking kernel already compiled."""
    c = cand_row.shape[1]
    eq = cand_row.T == cand_row                               # [C, C]
    row = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    n_earlier = jnp.sum((eq & (col < row)).astype(jnp.int32),
                        axis=1, keepdims=True)                # [C, 1]
    valid = ((n_earlier == 0) & (cand_row.T >= 0)).T          # [1, C]
    work = jnp.where(valid, logits_row, NEG_INF)
    sample = jnp.sum(valid.astype(jnp.int32)).reshape(1, 1)

    pos = jax.lax.broadcasted_iota(jnp.int32, (1, c), 1)
    tl, ti = [], []
    for _ in range(top_k):                    # static unroll over k
        best = jnp.max(work, axis=1, keepdims=True)           # [1, 1]
        first = jnp.min(jnp.where(work == best, pos, c),
                        axis=1, keepdims=True)                # [1, 1]
        sel = pos == first                                    # [1, C]
        cid = jnp.sum(jnp.where(sel, cand_row, 0), axis=1,
                      keepdims=True)                          # [1, 1]
        tl.append(best)
        ti.append(jnp.where(best > NEG_INF / 2, cid, -1))
        work = jnp.where(sel, NEG_INF, work)
    return (jnp.concatenate(tl, axis=1), jnp.concatenate(ti, axis=1),
            sample)


def _topk_bitonic_tile(cand, logits, top_k):
    """Sorted-domain dedup + top-k for a whole ``[Bq, C]`` tile.
    Returns (top_l [Bq,k], top_i [Bq,k], sample [Bq,1])."""
    sids, spos, slog, first = sorted_dedup(cand, logits)      # [Bq, n]
    n = sids.shape[-1]
    sample = jnp.sum(first.astype(jnp.int32), axis=1, keepdims=True)
    work = jnp.where(first, slog, NEG_INF)
    tl, ti = [], []
    for _ in range(top_k):                    # static unroll over k
        best = jnp.max(work, axis=1, keepdims=True)           # [Bq, 1]
        # ties break on the carried ORIGINAL position — the exact
        # lower-index-wins contract of the quadratic path / lax.top_k
        firstpos = jnp.min(jnp.where(work == best, spos, n),
                           axis=1, keepdims=True)             # [Bq, 1]
        sel = spos == firstpos                                # [Bq, n]
        cid = jnp.sum(jnp.where(sel, sids, 0), axis=1, keepdims=True)
        tl.append(best)
        ti.append(jnp.where(best > NEG_INF / 2, cid, -1))
        work = jnp.where(sel, NEG_INF, work)
    return (jnp.concatenate(tl, axis=1),
            jnp.concatenate(ti, axis=1).astype(jnp.int32), sample)


def _make_kernel(k_bits: int, n_tables: int, top_k: int, cap: int,
                 block_q: int, dedup: str, quantized: bool):
    n_buckets = 2 ** k_bits

    def kernel(*refs):
        # int8 storage threads one extra HBM input (the per-row scales)
        # and one extra rotating scratch + semaphore through the ref list
        if quantized:
            (q_ref, theta_ref, pack_ref, tids_hbm, w_hbm, scales_hbm,
             top_l_ref, top_i_ref, sample_ref, cand_ref,
             w_vmem, ids_vmem, scale_vmem, sem_w, sem_i, sem_s) = refs
        else:
            (q_ref, theta_ref, pack_ref, tids_hbm, w_hbm,
             top_l_ref, top_i_ref, sample_ref, cand_ref,
             w_vmem, ids_vmem, sem_w, sem_i) = refs
            scales_hbm = scale_vmem = sem_s = None
        # ---- stage 1: simhash codes for the whole tile ----------------
        q = q_ref[...].astype(jnp.float32)                    # [Bq, d]
        # same normalization as core.simhash.unit (hash definition)
        norm = jnp.sqrt(jnp.sum(q * q, axis=-1, keepdims=True))
        qn = q / jnp.maximum(norm, 1e-12)
        scores = jnp.matmul(qn, theta_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)  # [Bq, KL]
        bits = (scores > 0).astype(jnp.float32)
        packed = jnp.matmul(bits, pack_ref[...],
                            preferred_element_type=jnp.float32)  # [Bq, L]
        buckets = packed.astype(jnp.int32)

        # ---- stage 2: double-buffered slab DMA + MXU logits -----------
        # One shared fetch schedule for the tile: Bq*L slab copies
        # through a 2-slot rotating scratch; copy i+1 is started before
        # copy i is consumed, so DMA overlaps the matmul.
        n_fetch = block_q * n_tables

        def slab_of(idx):
            b, t = divmod(idx, n_tables)
            return t * n_buckets + buckets[b, t]

        def copies(idx, slot):
            slab = slab_of(idx)
            cps = (pltpu.make_async_copy(w_hbm.at[slab], w_vmem.at[slot],
                                         sem_w.at[slot]),
                   pltpu.make_async_copy(tids_hbm.at[slab],
                                         ids_vmem.at[slot],
                                         sem_i.at[slot]))
            if quantized:
                cps += (pltpu.make_async_copy(scales_hbm.at[slab],
                                              scale_vmem.at[slot],
                                              sem_s.at[slot]),)
            return cps

        for cp in copies(0, 0):
            cp.start()
        logit_rows = [[None] * n_tables for _ in range(block_q)]
        id_rows = [[None] * n_tables for _ in range(block_q)]
        for idx in range(n_fetch):            # static unroll over Bq*L
            slot = idx % 2
            if idx + 1 < n_fetch:
                for cp in copies(idx + 1, (idx + 1) % 2):
                    cp.start()
            for cp in copies(idx, slot):
                cp.wait()
            b, t = divmod(idx, n_tables)
            w = w_vmem[slot].astype(jnp.float32)              # [P, d]
            if quantized:
                # in-VMEM dequantize: same elementwise op as the
                # oracle's dequantize_int8_rows, so bit-identical
                w = w * scale_vmem[slot].reshape(cap, 1)
            blk = jnp.matmul(q, w.T,
                             preferred_element_type=jnp.float32)  # [Bq, P]
            logit_rows[b][t] = blk[b:b + 1, :]                # this query's
            id_rows[b][t] = ids_vmem[slot].reshape(1, cap)
        logits = jnp.concatenate(
            [jnp.concatenate(r, axis=1) for r in logit_rows], axis=0)
        cand = jnp.concatenate(
            [jnp.concatenate(r, axis=1) for r in id_rows], axis=0)
        cand_ref[...] = cand                                  # [Bq, C]

        # ---- stage 3+4: dedup + stable top-k --------------------------
        if dedup == "quadratic":
            for b in range(block_q):          # static unroll over the tile
                tl, ti, sample = _topk_quadratic_row(
                    cand[b:b + 1], logits[b:b + 1], top_k)
                top_l_ref[b, :] = tl[0, :]
                top_i_ref[b, :] = ti[0, :]
                sample_ref[b, 0] = sample[0, 0]
        else:
            tl, ti, sample = _topk_bitonic_tile(cand, logits, top_k)
            top_l_ref[...] = tl
            top_i_ref[...] = ti
            sample_ref[...] = sample

    return kernel


@functools.partial(jax.jit, static_argnames=("k_bits", "n_tables", "top_k",
                                             "block_q", "dedup",
                                             "interpret"))
def lss_topk_pallas(q_aug: jax.Array, theta: jax.Array, tids_flat: jax.Array,
                    w_flat: jax.Array, scales_flat: jax.Array | None = None,
                    *, k_bits: int, n_tables: int,
                    top_k: int, block_q: int = DEFAULT_BLOCK_Q,
                    dedup: str = "quadratic", interpret: bool = False
                    ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused retrieve->score->top-k over ``[block_q, d]`` query tiles.

    Args:
      q_aug:     ``[B, d]`` augmented queries, B a multiple of
                 ``block_q`` (``ops.py`` pads B; pads d on TPU).
      theta:     ``[d, K*L]`` hyperplanes.
      tids_flat: int32 ``[S, P]`` flattened bucket-major ids (S = L*2^K).
      w_flat:    ``[S, P, d]`` flattened bucket-major slabs
                 (fp32 | bf16 | int8 storage).
      scales_flat: fp32 ``[S, P]`` per-neuron-row scales — required iff
                 ``w_flat`` is int8 (``lss_topk.slab_dtype = int8``).
      block_q:   query rows per grid step (``grid=(B/block_q,)``).
      dedup:     ``quadratic`` | ``bitonic`` (resolved by ``ops.py``).

    Returns:
      (top_logits [B,k], top_ids [B,k], sample [B,1], cand_ids [B, L*P]).
    """
    bsz, d = q_aug.shape
    n_slabs, cap, dw = w_flat.shape
    assert d == dw, (d, dw)
    assert n_slabs == n_tables * 2 ** k_bits, (n_slabs, n_tables, k_bits)
    assert bsz % block_q == 0, (bsz, block_q)
    kl = k_bits * n_tables
    assert theta.shape == (d, kl), (theta.shape, d, kl)
    n_cand = n_tables * cap
    assert top_k <= n_cand, (top_k, n_cand)
    assert dedup in ("quadratic", "bitonic"), dedup
    quantized = w_flat.dtype == jnp.int8
    assert quantized == (scales_flat is not None), \
        "int8 slabs require scales_flat (and only int8 slabs take one)"
    if quantized:
        assert scales_flat.shape == (n_slabs, cap), scales_flat.shape

    # constant pack matrix: pack[t*K + j, t] = 2^j (exact in fp32)
    eye = jnp.eye(n_tables, dtype=jnp.float32)
    weights = 2.0 ** jnp.arange(k_bits, dtype=jnp.float32)
    pack = (eye[:, None, :] * weights[None, :, None]).reshape(kl, n_tables)

    in_specs = [
        pl.BlockSpec((block_q, d), lambda b: (b, 0)),
        pl.BlockSpec((d, kl), lambda b: (0, 0)),
        pl.BlockSpec((kl, n_tables), lambda b: (0, 0)),
        pl.BlockSpec(memory_space=pltpu.ANY),     # ids stay in HBM
        pl.BlockSpec(memory_space=pltpu.ANY),     # slabs stay in HBM
    ]
    scratch = [
        pltpu.VMEM((2, cap, d), w_flat.dtype),    # double-buffered
        pltpu.VMEM((2, cap), jnp.int32),
    ]
    operands = [q_aug, theta, pack, tids_flat, w_flat]
    if quantized:
        in_specs.append(pl.BlockSpec(memory_space=pltpu.ANY))  # scales too
        scratch.append(pltpu.VMEM((2, cap), jnp.float32))
        operands.append(scales_flat)
    scratch += [pltpu.SemaphoreType.DMA((2,))] * (3 if quantized else 2)

    return pl.pallas_call(
        _make_kernel(k_bits, n_tables, top_k, cap, block_q, dedup,
                     quantized),
        grid=(bsz // block_q,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_q, top_k), lambda b: (b, 0)),
            pl.BlockSpec((block_q, top_k), lambda b: (b, 0)),
            pl.BlockSpec((block_q, 1), lambda b: (b, 0)),
            pl.BlockSpec((block_q, n_cand), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, top_k), jnp.float32),
            jax.ShapeDtypeStruct((bsz, top_k), jnp.int32),
            jax.ShapeDtypeStruct((bsz, 1), jnp.int32),
            jax.ShapeDtypeStruct((bsz, n_cand), jnp.int32),
        ],
        scratch_shapes=scratch,
        interpret=interpret,
    )(*operands)
