"""Fused Pallas TPU kernel: the whole LSS serving pipeline in one pass.

Per query, in a single ``pallas_call`` grid step:

    simhash code (hash matmul + sign + bit-pack)
      -> data-dependent slab DMA (bucket-major weights stay in HBM;
         only the L hit slabs ever reach VMEM)
      -> slab logits on the MXU
      -> cross-table dedup mask
      -> first-occurrence top-k

The slab index depends on the hash computed INSIDE the kernel, so the
canonical scalar-prefetch trick (``bucket_logits``) cannot express it:
instead ``w_slabs``/``table_ids`` are bound with ``memory_space=ANY`` and
fetched with ``pltpu.make_async_copy`` at a runtime-computed index — the
same manual-DMA pattern as paged attention.  Nothing wider than one
``[P, d]`` slab is ever materialised, which is the point of LSS: the full
head streams ``m*d`` weights per batch, this kernel streams ``L*P*d`` per
query with no HBM round-trips for the intermediate codes or logits.

Bit-exactness contract (interpret mode, CPU): every fp32 reduction is
expressed so XLA lowers it to the same gemm the jnp oracle uses —
``q @ w.T`` for slab logits (NOT ``dot_general`` over ``((1,),(1,))``,
which takes a different Eigen path), row-blocked hash matmul, and a
power-of-two bit-pack matmul that is exact in fp32.  ``ops.py`` skips
lane padding in interpret mode so contraction lengths match the ref.

VMEM budget: theta ``[d, KL]`` + one ``[P, d]`` slab + the ``[C, C]``
dedup compare (C = L*P).  C beyond ~2k needs a sorted dedup instead of
the quadratic mask; sized fine for the paper's 0.2-6% sample regimes.

Top-k is k passes of masked max with first-occurrence argmin-of-index,
which reproduces ``jax.lax.top_k``'s stable lower-index-first
tie-breaking exactly (k is small: 1-10 in every serving config).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30   # matches repro.core.lss.NEG_INF (kept import-free)


def _make_kernel(k_bits: int, n_tables: int, top_k: int, cap: int):
    n_buckets = 2 ** k_bits

    def kernel(q_ref, theta_ref, pack_ref, tids_hbm, w_hbm,
               top_l_ref, top_i_ref, sample_ref, cand_ref,
               w_vmem, ids_vmem, sem_w, sem_i):
        # ---- stage 1: simhash code ------------------------------------
        q = q_ref[...].astype(jnp.float32)                    # [1, d]
        # same normalization as core.simhash.unit (hash definition)
        norm = jnp.sqrt(jnp.sum(q * q, axis=-1, keepdims=True))
        qn = q / jnp.maximum(norm, 1e-12)
        scores = jnp.matmul(qn, theta_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)  # [1, KL]
        bits = (scores > 0).astype(jnp.float32)
        packed = jnp.matmul(bits, pack_ref[...],
                            preferred_element_type=jnp.float32)  # [1, L]
        buckets = packed.astype(jnp.int32)

        # ---- stage 2: slab DMA + MXU logits, one hit slab per table ---
        logit_rows = []
        id_rows = []
        for t in range(n_tables):                 # static unroll over L
            slab = t * n_buckets + buckets[0, t]
            cp_w = pltpu.make_async_copy(w_hbm.at[slab], w_vmem, sem_w)
            cp_i = pltpu.make_async_copy(tids_hbm.at[slab], ids_vmem, sem_i)
            cp_w.start()
            cp_i.start()
            cp_w.wait()
            cp_i.wait()
            w = w_vmem[...].astype(jnp.float32)               # [P, d]
            logit_rows.append(
                jnp.matmul(q, w.T, preferred_element_type=jnp.float32))
            id_rows.append(ids_vmem[...].reshape(1, cap))
        logits = jnp.concatenate(logit_rows, axis=1)          # [1, C]
        cand = jnp.concatenate(id_rows, axis=1)               # [1, C]
        cand_ref[...] = cand

        # ---- stage 3: first-occurrence dedup mask ---------------------
        c = cand.shape[1]
        eq = cand.T == cand                                   # [C, C]
        row = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
        n_earlier = jnp.sum((eq & (col < row)).astype(jnp.int32),
                            axis=1, keepdims=True)            # [C, 1]
        valid = ((n_earlier == 0) & (cand.T >= 0)).T          # [1, C]
        masked = jnp.where(valid, logits, NEG_INF)
        sample_ref[0, 0] = jnp.sum(valid.astype(jnp.int32))

        # ---- stage 4: top-k (stable, lower index wins ties) -----------
        pos = jax.lax.broadcasted_iota(jnp.int32, (1, c), 1)
        work = masked
        for i in range(top_k):                    # static unroll over k
            best = jnp.max(work, axis=1, keepdims=True)       # [1, 1]
            first = jnp.min(jnp.where(work == best, pos, c),
                            axis=1, keepdims=True)            # [1, 1]
            sel = pos == first                                # [1, C]
            cid = jnp.sum(jnp.where(sel, cand, 0), axis=1,
                          keepdims=True)                      # [1, 1]
            top_l_ref[0, i] = best[0, 0]
            top_i_ref[0, i] = jnp.where(best[0, 0] > NEG_INF / 2,
                                        cid[0, 0], -1)
            work = jnp.where(sel, NEG_INF, work)

    return kernel


@functools.partial(jax.jit, static_argnames=("k_bits", "n_tables", "top_k",
                                             "interpret"))
def lss_topk_pallas(q_aug: jax.Array, theta: jax.Array, tids_flat: jax.Array,
                    w_flat: jax.Array, *, k_bits: int, n_tables: int,
                    top_k: int, interpret: bool = False
                    ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused retrieve->score->top-k.

    Args:
      q_aug:     ``[B, d]`` augmented queries (``ops.py`` pads d on TPU).
      theta:     ``[d, K*L]`` hyperplanes.
      tids_flat: int32 ``[S, P]`` flattened bucket-major ids (S = L*2^K).
      w_flat:    ``[S, P, d]`` flattened bucket-major slabs.

    Returns:
      (top_logits [B,k], top_ids [B,k], sample [B,1], cand_ids [B, L*P]).
    """
    bsz, d = q_aug.shape
    n_slabs, cap, dw = w_flat.shape
    assert d == dw, (d, dw)
    assert n_slabs == n_tables * 2 ** k_bits, (n_slabs, n_tables, k_bits)
    kl = k_bits * n_tables
    assert theta.shape == (d, kl), (theta.shape, d, kl)
    n_cand = n_tables * cap
    assert top_k <= n_cand, (top_k, n_cand)

    # constant pack matrix: pack[t*K + j, t] = 2^j (exact in fp32)
    eye = jnp.eye(n_tables, dtype=jnp.float32)
    weights = 2.0 ** jnp.arange(k_bits, dtype=jnp.float32)
    pack = (eye[:, None, :] * weights[None, :, None]).reshape(kl, n_tables)

    return pl.pallas_call(
        _make_kernel(k_bits, n_tables, top_k, cap),
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, d), lambda b: (b, 0)),
            pl.BlockSpec((d, kl), lambda b: (0, 0)),
            pl.BlockSpec((kl, n_tables), lambda b: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),     # ids stay in HBM
            pl.BlockSpec(memory_space=pltpu.ANY),     # slabs stay in HBM
        ],
        out_specs=[
            pl.BlockSpec((1, top_k), lambda b: (b, 0)),
            pl.BlockSpec((1, top_k), lambda b: (b, 0)),
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
            pl.BlockSpec((1, n_cand), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, top_k), jnp.float32),
            jax.ShapeDtypeStruct((bsz, top_k), jnp.int32),
            jax.ShapeDtypeStruct((bsz, 1), jnp.int32),
            jax.ShapeDtypeStruct((bsz, n_cand), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((cap, d), w_flat.dtype),
            pltpu.VMEM((cap,), jnp.int32),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(q_aug, theta, pack, tids_flat, w_flat)
