"""Public op: bucket-major sparse WOL logits, dispatched through the
kernel registry."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.bucket_logits.kernel import bucket_logits_pallas
from repro.kernels.bucket_logits.ref import bucket_logits_ref
from repro.kernels.registry import kernel_op

bucket_logits_op = kernel_op("bucket_logits")
bucket_logits_op.register_impl("ref", bucket_logits_ref)


def _pallas_impl(q: jax.Array, w_slabs: jax.Array, slab_ids: jax.Array,
                 *, interpret: bool) -> jax.Array:
    bsz, d = q.shape
    n_slabs, cap, _ = w_slabs.shape
    if not interpret:
        # Lane padding is a TPU tiling requirement only; interpret mode
        # runs unpadded so the fp32 dot sees the ref's exact contraction
        # length — bit-identical logits on CPU.
        pad_d = (-d) % 128
        pad_p = (-cap) % 128
        if pad_d:
            q = jnp.pad(q, ((0, 0), (0, pad_d)))
            w_slabs = jnp.pad(w_slabs, ((0, 0), (0, 0), (0, pad_d)))
        if pad_p:
            w_slabs = jnp.pad(w_slabs, ((0, 0), (0, pad_p), (0, 0)))
    out = bucket_logits_pallas(q, w_slabs, slab_ids, interpret=interpret)
    return out[:, :, :cap]


bucket_logits_op.register_impl(
    "pallas", functools.partial(_pallas_impl, interpret=False))
bucket_logits_op.register_impl(
    "pallas_interpret", functools.partial(_pallas_impl, interpret=True))


def bucket_logits(q: jax.Array, w_slabs: jax.Array, slab_ids: jax.Array,
                  *, impl: str | None = None) -> jax.Array:
    """``[B,d] x [S,P,d] x [B,L] -> [B,L,P]`` fp32 sparse logits.

    impl: ``ref`` | ``pallas`` | ``pallas_interpret`` | None (registry
    auto-selection — see ``repro.kernels.registry``).
    """
    return bucket_logits_op(q, w_slabs, slab_ids, impl=impl)
