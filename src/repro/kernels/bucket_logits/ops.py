"""Public op: bucket-major sparse WOL logits with impl dispatch + padding."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bucket_logits.kernel import bucket_logits_pallas
from repro.kernels.bucket_logits.ref import bucket_logits_ref


def bucket_logits(q: jax.Array, w_slabs: jax.Array, slab_ids: jax.Array,
                  *, impl: str = "ref") -> jax.Array:
    """``[B,d] x [S,P,d] x [B,L] -> [B,L,P]`` fp32 sparse logits.

    impl: ``ref`` | ``pallas`` | ``pallas_interpret``.
    """
    if impl == "ref":
        return bucket_logits_ref(q, w_slabs, slab_ids)
    bsz, d = q.shape
    n_slabs, cap, _ = w_slabs.shape
    pad_d = (-d) % 128
    pad_p = (-cap) % 128
    if pad_d:
        q = jnp.pad(q, ((0, 0), (0, pad_d)))
        w_slabs = jnp.pad(w_slabs, ((0, 0), (0, 0), (0, pad_d)))
    if pad_p:
        w_slabs = jnp.pad(w_slabs, ((0, 0), (0, pad_p), (0, 0)))
    out = bucket_logits_pallas(q, w_slabs, slab_ids,
                               interpret=(impl == "pallas_interpret"))
    return out[:, :, :cap]
