"""Pure-jnp oracle for the bucket-major sparse-logits kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def bucket_logits_ref(q: jax.Array, w_slabs: jax.Array,
                      slab_ids: jax.Array) -> jax.Array:
    """Per-query contiguous-slab logits.

    Args:
      q:        ``[B, d]`` query embeddings.
      w_slabs:  ``[S, P, d]`` bucket-major WOL slabs (S = L * 2^K).
      slab_ids: int32 ``[B, L]`` slab index per (query, table).

    Returns:
      ``[B, L, P]`` float32 logits ``q . w`` for every neuron slot in the
      hit slabs (zero rows in padded slots give logit 0; masking by neuron
      id happens in the caller).
    """
    slabs = w_slabs[slab_ids]                       # [B, L, P, d]
    return jnp.einsum("bd,blpd->blp", q.astype(jnp.float32),
                      slabs.astype(jnp.float32))
