"""Pallas TPU kernel: bucket-major sparse WOL logits (the LSS hot path).

The TPU adaptation of the paper's hash-bucket scan: the WOL is physically
permuted into bucket-major slabs ``[S, P, d]`` so that serving one query
touches exactly L contiguous ``[P, d]`` slabs — a *scalar-prefetched
dynamic block index*, not a random gather.  The slab id for each (query,
table) is data-dependent, so it is fed through scalar prefetch and consumed
by the BlockSpec index_map (the canonical Pallas TPU pattern for
data-dependent tiling, same as MoE block-sparse kernels).

Arithmetic intensity: 2·P·d FLOPs over P·d·bytes_per_el slab bytes
→ ~1 FLOP/byte at bf16 — HBM-bandwidth-bound by construction, which is the
POINT of LSS: the full head would read m·d bytes; LSS reads L·P·d with
L·P ≈ 0.2–6 % of m.  See EXPERIMENTS.md §Perf for the measured ratio.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(slab_ids_ref, q_ref, w_ref, out_ref):
    # q_ref: [1, d]; w_ref: [1, P, d]; out_ref: [1, 1, P]
    del slab_ids_ref  # consumed by the index_map only
    q = q_ref[...].astype(jnp.float32)               # [1, d]
    w = w_ref[0].astype(jnp.float32)                 # [P, d]
    # q @ w.T (not dot_general over (1,1)): XLA lowers this to the same
    # gemm as the ref einsum, so interpret mode is bit-identical to the
    # jnp oracle on CPU.
    logits = jnp.matmul(q, w.T, preferred_element_type=jnp.float32)
    out_ref[...] = logits[:, None, :]                # [1, 1, P]


@functools.partial(jax.jit, static_argnames=("interpret",))
def bucket_logits_pallas(q: jax.Array, w_slabs: jax.Array,
                         slab_ids: jax.Array, *,
                         interpret: bool = False) -> jax.Array:
    """``[B,d] x [S,P,d] x int32 [B,L] -> [B,L,P]`` fp32 logits.

    ``d`` and ``P`` should be multiples of 128 (ops.py pads).  Grid is
    ``(B, L)``: one slab dot per step; the slab block index comes from the
    prefetched ``slab_ids``.
    """
    bsz, d = q.shape
    n_slabs, cap, dw = w_slabs.shape
    assert d == dw, (d, dw)
    n_tables = slab_ids.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bsz, n_tables),
        in_specs=[
            pl.BlockSpec((1, d), lambda b, l, ids: (b, 0)),
            pl.BlockSpec((1, cap, d), lambda b, l, ids: (ids[b, l], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, cap), lambda b, l, ids: (b, l, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, n_tables, cap), jnp.float32),
        interpret=interpret,
    )(slab_ids, q, w_slabs)
