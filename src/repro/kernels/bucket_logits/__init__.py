from repro.kernels.bucket_logits.ops import bucket_logits
__all__ = ["bucket_logits"]
