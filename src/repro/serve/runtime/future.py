"""Per-request futures and the admission-failure exception hierarchy.

A :class:`RankFuture` is what :meth:`AsyncRuntime.submit` hands back for
every request — a minimal, dependency-free future (one Event + a slot)
rather than ``concurrent.futures.Future`` so the runtime controls the
exact resolution semantics:

  * resolved exactly once, from the completion path (or the shed path),
  * ``result()`` re-raises the shed reason (:class:`QueueFullError`,
    :class:`DeadlineExceededError`, :class:`RuntimeClosedError`) so
    callers handle admission failures and successes through one object.

Timing metadata (``t_submit``, ``deadline``) lives on the future so the
dispatcher can shed already-late work without a side table.
"""

from __future__ import annotations

import threading
import time
from typing import TYPE_CHECKING

if TYPE_CHECKING:                                   # pragma: no cover
    from repro.serve.engine import RankResult

__all__ = ["RankFuture", "ShedError", "QueueFullError",
           "DeadlineExceededError", "RuntimeClosedError"]


class ShedError(RuntimeError):
    """Base: the runtime refused or abandoned a request (admission
    control), as opposed to the head itself failing."""


class QueueFullError(ShedError):
    """Admission queue at capacity under the ``shed`` policy (or a
    ``block``-policy wait timed out)."""


class DeadlineExceededError(ShedError):
    """The request's deadline passed while it sat in the queue; the
    dispatcher dropped it instead of wasting device time on late work."""


class RuntimeClosedError(ShedError):
    """Submitted to (or still queued in) a runtime that was closed."""


class RankFuture:
    """Write-once future for one submitted request."""

    __slots__ = ("rid", "t_submit", "deadline", "t_done", "span",
                 "_done", "_result", "_exc")

    def __init__(self, rid: int, t_submit: float,
                 deadline: float | None = None):
        self.rid = rid
        self.t_submit = t_submit          # perf_counter at admission
        self.deadline = deadline          # absolute perf_counter, or None
        self.t_done: float | None = None  # perf_counter at resolution
        self.span = None                  # obs span; closed at resolution
        self._done = threading.Event()
        self._result: RankResult | None = None
        self._exc: BaseException | None = None

    # -- producer side (runtime internals) --------------------------------
    # the future is the one object every terminal path goes through, so
    # resolution is where the request's span closes — a shed, a chunk
    # fault, or a close can never leak an open span
    def set_result(self, result: "RankResult") -> None:
        assert not self._done.is_set(), f"future {self.rid} resolved twice"
        self._result = result
        self.t_done = time.perf_counter()
        self._done.set()
        if self.span is not None:
            self.span.end("ok")

    def set_exception(self, exc: BaseException) -> None:
        assert not self._done.is_set(), f"future {self.rid} resolved twice"
        self._exc = exc
        self.t_done = time.perf_counter()
        self._done.set()
        if self.span is not None:
            self.span.end_from_exc(exc)

    # -- consumer side -----------------------------------------------------
    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: float | None = None) -> "RankResult":
        """Block for the result; re-raises the shed reason on failure."""
        if not self._done.wait(timeout):
            raise TimeoutError(f"request {self.rid} not resolved "
                               f"within {timeout}s")
        if self._exc is not None:
            raise self._exc
        return self._result

    def exception(self, timeout: float | None = None) -> BaseException | None:
        if not self._done.wait(timeout):
            raise TimeoutError(f"request {self.rid} not resolved "
                               f"within {timeout}s")
        return self._exc

    def __repr__(self) -> str:            # pragma: no cover - debug aid
        state = ("pending" if not self._done.is_set()
                 else "failed" if self._exc is not None else "done")
        return f"RankFuture(rid={self.rid}, {state})"
