"""Bounded, thread-safe admission queue with block | shed policies.

Pure queueing logic — no jax, no engine types — so backpressure semantics
are unit-testable in isolation (mirroring ``serve.batcher``'s design).

Policies when the queue is at capacity:

  * ``block`` — ``put`` waits for space (optionally up to a timeout);
    this pushes backpressure into the *producer* (closed-loop clients,
    or an RPC layer that translates the wait into flow control).
  * ``shed``  — ``put`` returns False immediately; the caller fails the
    request's future with :class:`QueueFullError`.  Open-loop traffic
    (the load harness, real user fan-in) must shed, not block, or the
    queue simply moves into the client.

``take(max_n)`` is the dispatcher side: block for the first item, then
greedily drain up to ``max_n`` — exactly the micro-batcher's coalescing
contract ("whatever is waiting, capped at the max bucket").
"""

from __future__ import annotations

import threading
from typing import Any

__all__ = ["AdmissionQueue", "POLICIES"]

POLICIES = ("block", "shed")


class AdmissionQueue:
    """FIFO with a hard depth bound and a full-queue policy."""

    def __init__(self, maxsize: int = 1024, policy: str = "block"):
        if maxsize <= 0:
            raise ValueError(f"maxsize must be positive, got {maxsize}")
        if policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}, "
                             f"got {policy!r}")
        self.maxsize = maxsize
        self.policy = policy
        self._items: list[Any] = []
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._not_full = threading.Condition(self._lock)
        self._closed = False

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)

    # ---------------------------------------------------------- producer --
    def put(self, item: Any, timeout: float | None = None) -> bool:
        """Admit one item.  True on admission; False when shed (queue full
        under ``shed``, wait timed out under ``block``, or queue closed)."""
        with self._lock:
            if self._closed:
                return False
            if len(self._items) >= self.maxsize:
                if self.policy == "shed":
                    return False
                if not self._not_full.wait_for(
                        lambda: self._closed
                        or len(self._items) < self.maxsize,
                        timeout=timeout):
                    return False                      # timed out
                if self._closed:
                    return False
            self._items.append(item)
            self._not_empty.notify()
            return True

    # -------------------------------------------------------- dispatcher --
    def take(self, max_n: int, timeout: float | None = None) -> list[Any]:
        """Block (up to ``timeout``) for at least one item, then drain up
        to ``max_n`` in FIFO order.  Empty list on timeout or close."""
        with self._lock:
            if not self._not_empty.wait_for(
                    lambda: self._items or self._closed, timeout=timeout):
                return []
            got = self._items[:max_n]
            del self._items[:max_n]
            if got:
                self._not_full.notify(len(got))
            return got

    # ------------------------------------------------------------ closing --
    def close(self) -> list[Any]:
        """Refuse further admissions; wake every waiter; return whatever
        was still queued (the runtime fails those futures)."""
        with self._lock:
            self._closed = True
            leftover, self._items = self._items, []
            self._not_empty.notify_all()
            self._not_full.notify_all()
            return leftover
