"""AsyncRuntime: the thread/queue front-end over a synchronous Engine.

The Engine is a *library* — ``submit``/``flush`` block the caller, so
host-side batching, padding, and device execution serialize.  The
runtime turns it into a *service*:

::

    producers --submit()--> AdmissionQueue --take(<=max_bucket)--+
      (futures back)            (block|shed, deadlines)          |
                                                        dispatcher thread
                                                 stack+pad chunk k+1 (host)
                                                 dispatch chunk k   (device)
                                                           |
                                              bounded completion queue
                                                           |
                                                   completion thread
                                            block_until_ready -> resolve
                                            futures, record metrics

Two properties fall out of the structure:

  * **Pipelining** — jax dispatch is asynchronous, so the dispatcher
    hands a padded chunk to the device and immediately starts stacking/
    padding the next one while the device executes; the completion
    thread is the only place that blocks on device results.  The
    completion queue is bounded (``pipeline_depth``), which is the
    backpressure that stops the dispatcher racing unboundedly ahead.
  * **Determinism** — chunks go through the SAME jitted (head, bucket)
    steps as ``Engine.flush`` and every head op is row-parallel, so a
    request's result is bit-identical to the synchronous path no matter
    how traffic was coalesced (asserted in tests/test_async_runtime.py).

Admission control: bounded queue depth with ``block`` | ``shed``
policies, per-request deadlines (already-late work is shed at dispatch
time, not executed), graceful ``drain()``/``close()``.  ``stats()``
reports queue depth, shed counts (capacity vs deadline, separately),
batch occupancy, and latency percentiles that INCLUDE queue wait — the
number a client actually experiences, not just device wall time.

Streaming decode is the runtime's SECOND request kind: construct with a
``DecodeScheduler`` (see ``repro.serve.decode``) and ``submit_decode``
returns a per-token :class:`TokenStream` future.  Decode sessions go
through the SAME admission queue — block|shed backpressure and
per-request deadlines apply exactly as for scoring — and the dispatcher
interleaves scheduler ticks with rank chunks, so one runtime serves
open-loop scoring traffic and many concurrent decode streams off one
engine.  The scheduler is itself software-pipelined (host token
gather/scatter for step k+1 overlaps device execution of step k), and
``stats()`` grows per-token latency: time-to-first-token and inter-token
p50/p95/p99, plus decode-slot occupancy.
"""

from __future__ import annotations

import math
import queue as _queue
import threading
import time
from typing import Any, NamedTuple

import jax
import numpy as np

from repro import obs
from repro.kernels.registry import dispatch_log
from repro.serve.batcher import MicroBatcher
from repro.serve.engine import Engine, RankResult
from repro.serve.runtime.future import (DeadlineExceededError, QueueFullError,
                                        RankFuture, RuntimeClosedError)
from repro.serve.runtime.queue import POLICIES, AdmissionQueue

__all__ = ["AsyncRuntime", "RuntimeStats", "submit_open_loop",
           "submit_decode_open_loop"]

_SENTINEL = object()


class RuntimeStats(NamedTuple):
    """Point-in-time snapshot of the runtime's serving behaviour.

    Shed accounting is split by CAUSE: ``n_shed_queue`` (capacity — the
    admission queue refused the request) vs ``n_shed_deadline`` (the
    request was admitted but already late when the dispatcher reached
    it).  Both cover scoring requests and decode sessions.  The
    ``n_decode_*`` / ``ttft_*`` / ``itl_*`` fields are zero/nan unless
    the runtime was built with a :class:`DecodeScheduler`.

    Scope note: ``n_decode_sessions``/``n_decode_done`` count THIS
    runtime's admissions, while the token/latency/occupancy decode
    fields snapshot the attached scheduler's whole stats window — if
    another producer (a concurrent blocking ``generate()``) shares the
    scheduler, its traffic is included there; call
    ``scheduler.reset_stats()`` between measured segments.
    """

    n_submitted: int             # futures handed out (incl. shed)
    n_completed: int             # resolved with a RankResult
    n_shed_queue: int            # capacity shed: refused at admission
    n_shed_deadline: int         # deadline shed: dropped at dispatch
    queue_depth: int             # waiting right now
    n_batches: int               # device chunks dispatched
    avg_batch_occupancy: float   # mean fill fraction of dispatched buckets
    latency_p50_ms: float        # submit -> resolve, queue wait INCLUDED
    latency_p95_ms: float
    latency_p99_ms: float
    device_ms_per_batch: float   # mean non-overlapping device wall/chunk
    wall_s: float                # first submit -> last completion
    throughput_rps: float        # n_completed / wall_s
    # ------------------------------------------------- streaming decode --
    n_decode_sessions: int = 0   # decode sessions submitted (incl. shed)
    n_decode_done: int = 0       # sessions that reached a terminal state
    n_decode_tokens: int = 0     # tokens streamed across all sessions
    ttft_p50_ms: float = math.nan   # submit -> first token (queue incl.)
    ttft_p95_ms: float = math.nan
    ttft_p99_ms: float = math.nan
    itl_p50_ms: float = math.nan    # inter-token latency
    itl_p95_ms: float = math.nan
    itl_p99_ms: float = math.nan
    decode_slot_occupancy: float = 0.0   # mean active/max_streams per step
    decode_tokens_per_s: float = 0.0
    n_prefill_skipped: int = 0      # full-prompt prefix-cache hits
    n_prefill_compiles: int = 0     # prefill traces (one per bucket)
    n_prefill_buckets: int = 0      # distinct power-of-two buckets
    prefix_hit_rate: float = math.nan   # shared / shareable prompt pages
    kv_pages_in_use: int = 0        # paged KV layout: live pages
    kv_peak_pages: int = 0          # paged KV layout: high-water mark


def _paced_submit(n: int, qps: float, seed: int, submit
                  ) -> tuple[list, np.ndarray]:
    """The open-loop pacer both load shapes share: draw Poisson arrival
    offsets for offered rate ``qps`` (``qps <= 0`` = burst, everything
    at t=0), sleep to each offset, call ``submit(i)`` — and never wait
    for results, so queueing delay stays visible instead of being hidden
    by a closed loop."""
    rng = np.random.default_rng(seed)
    arrivals = (np.zeros(n) if qps <= 0
                else np.cumsum(rng.exponential(1.0 / qps, n)))
    t0 = time.perf_counter()
    out = []
    for i in range(n):
        dt = (t0 + arrivals[i]) - time.perf_counter()
        if dt > 0:
            time.sleep(dt)
        out.append(submit(i))
    return out, arrivals


def submit_open_loop(runtime: "AsyncRuntime", xs, qps: float, *,
                     seed: int = 0, labels=None
                     ) -> tuple[list[RankFuture], np.ndarray]:
    """Open-loop scoring load: submit ``xs[i]`` at Poisson arrival times
    for offered rate ``qps``.  Returns (futures, arrival offsets in
    seconds).  Shared by the load harness, the launcher's ``--runtime
    async`` mode, and the serving example."""
    return _paced_submit(
        len(xs), qps, seed,
        lambda i: runtime.submit(xs[i],
                                 None if labels is None else labels[i]))


def submit_decode_open_loop(runtime: "AsyncRuntime", prompts, qps: float, *,
                            max_new_tokens: int, seed: int = 0,
                            eos_id: int | None = None
                            ) -> tuple[list, np.ndarray]:
    """Open-loop decode load: start session i (``prompts[i]``, a 1-D
    token row) at Poisson arrival times for offered SESSION rate ``qps``
    (``qps <= 0`` = burst).  Returns (TokenStreams, arrival offsets).
    Shared by the decode bench and the launcher's ``--mode decode``."""
    return _paced_submit(
        len(prompts), qps, seed,
        lambda i: runtime.submit_decode(prompts[i],
                                        max_new_tokens=max_new_tokens,
                                        eos_id=eos_id))


class _Work(NamedTuple):
    future: RankFuture
    x: Any                       # request pytree (no batch dim, numpy)
    labels: np.ndarray | None


class _DecodeWork(NamedTuple):
    session: Any                 # DecodeSession awaiting scheduler admission


class AsyncRuntime:
    """Admission queue + futures + overlapped host/device pipeline.

    Args:
      engine: the (thread-safe) Engine to serve through.  The runtime
        shares its jitted (head, bucket) step cache and metrics window.
      head: head kind override; None uses ``engine.default_head``.
      max_queue: admission queue depth bound.
      policy: ``block`` | ``shed`` when the queue is full (see
        ``runtime.queue``).
      default_deadline_s: per-request deadline applied when ``submit``
        does not pass one; None = no deadline.
      batch_window_s: how long the dispatcher lingers for more arrivals
        after the first, when a max bucket has not filled.  0 dispatches
        whatever is waiting immediately (lowest latency); a small window
        (~1-5 ms) trades p50 for occupancy at low QPS.
      pipeline_depth: max device chunks in flight past the dispatcher.
      scheduler: a ``repro.serve.decode.DecodeScheduler`` enabling the
        decode request kind (``submit_decode``); the dispatcher
        interleaves its ticks with rank chunks.  The scheduler must not
        be driven by anyone else while the runtime owns it.
      start: spawn the worker threads now; ``start=False`` lets tests
        and callers stage a backlog first (``start()`` later).
    """

    def __init__(self, engine: Engine, *, head: str | None = None,
                 max_queue: int = 1024, policy: str = "block",
                 default_deadline_s: float | None = None,
                 batch_window_s: float = 0.0, pipeline_depth: int = 2,
                 scheduler=None, start: bool = True,
                 close_timeout_s: float | None = None):
        if policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}, "
                             f"got {policy!r}")
        if pipeline_depth < 1:
            raise ValueError("pipeline_depth must be >= 1")
        self.engine = engine
        self.head = head or engine.default_head
        self.policy = policy
        self.scheduler = scheduler
        if scheduler is not None:
            if scheduler.on_session_done is not None:
                raise ValueError(
                    "scheduler is already attached to another "
                    "AsyncRuntime — close() that runtime first (it "
                    "detaches on close); silently re-attaching would "
                    "break the first runtime's decode accounting")
            scheduler.on_session_done = self._on_decode_done
        self.default_deadline_s = default_deadline_s
        self.batch_window_s = batch_window_s
        # bound for the ``with``-exit close(): an unbounded drain on a
        # wedged dispatcher blocks __exit__ forever and leaks every
        # sibling resource the caller meant to tear down after us (the
        # /metrics exporter thread was the observed casualty)
        self.close_timeout_s = close_timeout_s
        self._q = AdmissionQueue(max_queue, policy)
        self._done_q: _queue.Queue = _queue.Queue(maxsize=pipeline_depth)
        self._stop = threading.Event()
        self._closed = False
        self._started = False
        self._threads: list[threading.Thread] = []
        self._worker_exc: BaseException | None = None
        # stats (guarded by _mu; _drained signals pending == 0)
        self._mu = threading.Lock()
        self._drained = threading.Condition(self._mu)
        self._next_rid = 0
        self._n_submitted = 0
        self._n_admitted = 0
        self._n_completed = 0
        self._n_shed_queue = 0
        self._n_shed_deadline = 0
        self._n_failed = 0
        self._n_decode_submitted = 0
        self._n_decode_admitted = 0
        self._n_decode_done = 0
        self._n_decode_shed_deadline = 0
        self._n_batches = 0
        self._occupancy_sum = 0.0
        # bounded telemetry (was: unbounded list[float] + np.percentile
        # over full history per stats() call) — O(1) memory under any load
        self.obs = obs.MetricsRegistry(scope_prefix="runtime")
        self._h_lat = self.obs.histogram(
            "runtime_request_latency_seconds",
            "submit -> resolve, queue wait included")
        self._h_device = self.obs.histogram(
            "runtime_device_seconds_per_batch",
            "non-overlapping device wall per dispatched chunk")
        self.obs.collect(self._collect_gauges)
        self._t_first: float | None = None
        self._t_last: float | None = None
        if start:
            self.start()

    # ------------------------------------------------------------- lifecycle
    def start(self) -> "AsyncRuntime":
        if self._started:
            return self
        self._started = True
        self._threads = [
            threading.Thread(target=self._dispatch_loop,
                             name="repro-runtime-dispatch", daemon=True),
            threading.Thread(target=self._completion_loop,
                             name="repro-runtime-complete", daemon=True),
        ]
        for t in self._threads:
            t.start()
        return self

    def __enter__(self) -> "AsyncRuntime":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close(self.close_timeout_s)

    # -------------------------------------------------------------- pending
    def _pending(self) -> int:
        # deadline-shed decode sessions are already inside _n_decode_done
        # (the session-done hook counts every terminal state), so only
        # the RANK portion of the deadline sheds offsets _n_admitted here
        return (self._n_admitted - self._n_completed
                - (self._n_shed_deadline - self._n_decode_shed_deadline)
                - self._n_failed
                + self._n_decode_admitted - self._n_decode_done)

    def drain(self, timeout: float | None = None) -> None:
        """Block until every admitted request has been resolved."""
        if not self._started:
            with self._mu:
                if self._pending() == 0:
                    return
            raise RuntimeError(
                "drain() on a never-started runtime with an admitted "
                "backlog: no worker will ever resolve it — call start()")
        if self._started:
            with self._drained:
                if not self._drained.wait_for(
                        lambda: self._pending() == 0
                        or self._worker_exc is not None,
                        timeout=timeout):
                    raise TimeoutError(
                        f"drain: {self._pending()} requests still pending "
                        f"after {timeout}s")
        if self._worker_exc is not None:
            raise RuntimeError("runtime worker died") from self._worker_exc

    def close(self, timeout: float | None = None) -> None:
        """Graceful shutdown: stop admitting, drain in-flight work, stop
        the worker threads.  A drain timeout still stops the runtime —
        the TimeoutError propagates, but the workers are shut down and
        whatever was still queued is failed with
        :class:`RuntimeClosedError` (never-started runtimes included)."""
        with self._mu:
            if self._closed:
                return
            self._closed = True                 # submit() now refuses
        try:
            if self._started and self._worker_exc is None:
                self.drain(timeout)
        finally:
            self._stop.set()
            exc = RuntimeClosedError("runtime closed")
            for w in self._q.close():           # undrained leftovers
                self._fail_admitted(w, exc)
            if self.scheduler is not None:      # admitted, not yet joined
                self._count_decode_failed(self.scheduler.fail_pending(
                    exc, only=lambda s: s.owner is self))
            for t in self._threads:
                t.join(timeout=5.0)
            if (self.scheduler is not None
                    and self.scheduler.on_session_done
                    == self._on_decode_done):
                self.scheduler.on_session_done = None   # detach the hook

    # --------------------------------------------------------------- submit
    def submit(self, x, labels=None, *, deadline_s: float | None = None,
               timeout: float | None = None) -> RankFuture:
        """Admit one request (leaves WITHOUT the batch dim); returns its
        future.  A full queue blocks (``block``) or fails the future with
        :class:`QueueFullError` (``shed``); ``deadline_s`` is relative to
        now and already-late work is shed at dispatch time."""
        t_sub = time.perf_counter()
        if deadline_s is None:
            deadline_s = self.default_deadline_s
        deadline = None if deadline_s is None else t_sub + deadline_s
        with self._mu:
            rid = self._next_rid
            self._next_rid += 1
            self._n_submitted += 1
            if self._t_first is None:
                self._t_first = t_sub
        fut = RankFuture(rid, t_sub, deadline)
        # the span closes wherever the future resolves (set_result /
        # set_exception), so every shed/fault path closes it for free
        fut.span = obs.start_span("request", rid=rid, head=self.head)
        if self._closed:
            fut.set_exception(RuntimeClosedError("runtime closed"))
            with self._mu:
                self._n_shed_queue += 1
            return fut
        work = _Work(fut, jax.tree.map(np.asarray, x),
                     None if labels is None
                     else np.atleast_1d(np.asarray(labels, np.int32)))
        # count the admission BEFORE the put: once the work is in the
        # queue it can complete (and notify drain()) at any moment, and
        # drain() must never observe completed > admitted
        with self._mu:
            self._n_admitted += 1
        if not self._q.put(work, timeout=timeout):
            with self._drained:
                self._n_admitted -= 1
                self._n_shed_queue += 1
                self._drained.notify_all()
            # a put can also fail because close() raced us and shut the
            # queue — report that as closed, not as transient overload
            # (callers reasonably retry on QueueFullError)
            fut.set_exception(
                RuntimeClosedError("runtime closed") if self._closed
                else QueueFullError(
                    f"queue full (depth bound {self._q.maxsize}, "
                    f"policy {self.policy})"))
        return fut

    def submit_batch(self, xb, labels=None, **kw) -> list[RankFuture]:
        """Admit every row of a batched pytree."""
        xb = jax.tree.map(np.asarray, xb)
        n = jax.tree.leaves(xb)[0].shape[0]
        lab = None if labels is None else np.asarray(labels)
        return [self.submit(jax.tree.map(lambda leaf: leaf[i], xb),
                            None if lab is None else lab[i], **kw)
                for i in range(n)]

    # ------------------------------------------------------- decode submit
    def submit_decode(self, prompt, *, max_new_tokens: int,
                      eos_id: int | None = None,
                      deadline_s: float | None = None,
                      timeout: float | None = None):
        """Admit one decode session (1-D prompt tokens); returns its
        :class:`~repro.serve.decode.TokenStream`, which resolves token by
        token as the scheduler interleaves the session with every other
        in-flight stream.  Admission control matches ``submit``: a full
        queue blocks or fails the stream with :class:`QueueFullError`,
        and a ``deadline_s`` that expires before the session reaches a
        pool slot sheds it with :class:`DeadlineExceededError` (once
        streaming, a session runs to completion)."""
        if self.scheduler is None:
            raise RuntimeError(
                "this runtime has no DecodeScheduler: pass scheduler= "
                "at construction to enable the decode request kind")
        t_sub = time.perf_counter()
        if deadline_s is None:
            deadline_s = self.default_deadline_s
        deadline = None if deadline_s is None else t_sub + deadline_s
        session = self.scheduler.make_session(
            prompt, max_new_tokens, eos_id=eos_id, t_submit=t_sub,
            deadline=deadline)
        session.owner = self
        # closes in TokenStream.finish/fail — every terminal decode path
        session.stream.span = obs.start_span(
            "decode_session", sid=session.sid,
            prompt_len=int(session.prompt.shape[0]),
            max_new_tokens=max_new_tokens)
        with self._mu:
            self._n_decode_submitted += 1
            if self._t_first is None:
                self._t_first = t_sub
        if self._closed:
            session.stream.fail(RuntimeClosedError("runtime closed"))
            with self._mu:
                self._n_shed_queue += 1
            return session.stream
        with self._mu:
            self._n_decode_admitted += 1
        if not self._q.put(_DecodeWork(session), timeout=timeout):
            with self._drained:
                self._n_decode_admitted -= 1
                self._n_shed_queue += 1
                self._drained.notify_all()
            session.stream.fail(
                RuntimeClosedError("runtime closed") if self._closed
                else QueueFullError(
                    f"queue full (depth bound {self._q.maxsize}, "
                    f"policy {self.policy})"))
        return session.stream

    def _on_decode_done(self, session, reason: str) -> None:
        """Scheduler hook: a session reached a terminal state (finished,
        or shed at slot-join time).  Sessions another producer submitted
        to the shared scheduler (e.g. a concurrent blocking generate())
        are not this runtime's accounting problem."""
        if session.owner is not self:
            return
        with self._drained:
            self._n_decode_done += 1
            if reason == "shed_deadline":
                self._n_shed_deadline += 1
                self._n_decode_shed_deadline += 1
            self._drained.notify_all()

    # ------------------------------------------------------------ dispatcher
    def _sched_busy(self) -> bool:
        return self.scheduler is not None and not self.scheduler.idle

    def _route_decode(self, works: list) -> list:
        """Hand decode sessions to the scheduler; return the rank works."""
        if self.scheduler is None:
            return works
        for w in works:
            if isinstance(w, _DecodeWork):
                self.scheduler.add_session(w.session)
        return [w for w in works if not isinstance(w, _DecodeWork)]

    def _dispatch_loop(self) -> None:
        try:
            batcher = self.engine.batcher
            while not (self._stop.is_set() and len(self._q) == 0
                       and not self._sched_busy()):
                # an active decode pipeline paces the loop itself (tick
                # blocks on the lagged step), so don't linger on the
                # queue — poll it and get back to stepping the streams
                # decode sessions route to the scheduler as soon as they
                # are taken: the rank batch-window below must neither
                # delay a join nor count sessions against the rank bucket
                works = self._route_decode(self._q.take(
                    batcher.max_bucket,
                    timeout=0.0 if self._sched_busy() else 0.05))
                if (works and len(works) < batcher.max_bucket
                        and self.batch_window_s > 0
                        and not self._sched_busy()):
                    works += self._route_decode(
                        self._q.take(batcher.max_bucket - len(works),
                                     timeout=self.batch_window_s))
                if self.scheduler is not None:
                    # admit + one fused step + resolve the previous
                    # step's tokens; overlaps the rank chunk below
                    self.scheduler.tick()
                if not works:
                    continue
                live = self._shed_late(works)
                if not live:
                    continue
                span = obs.start_span("chunk", head=self.head,
                                      n=len(live))
                try:
                    # host side: stack rows and pad to the bucket in
                    # numpy — this is the work that overlaps the device
                    # executing the PREVIOUS chunk (whose dispatch below
                    # did not block).
                    bucket = batcher.bucket_for(len(live))
                    span.set(bucket=bucket)
                    for w in live:
                        w.future.span.event("dispatch", bucket=bucket)
                    x = jax.tree.map(lambda *rows: np.stack(rows),
                                     *[w.x for w in live])
                    padded = MicroBatcher.pad_rows(x, bucket)
                    # the engine's step seam: on a multi-process engine
                    # (Engine(spmd=...)) this is the leader-side wrapper
                    # that broadcasts the chunk to every follower_loop
                    # first — the runtime needs no multihost awareness
                    step = self.engine._step(self.head, bucket)
                    n_disp = len(dispatch_log())
                    n_comp = sum(self.engine.compile_counts.values())
                    t0 = time.perf_counter()
                    out = step(padded)          # async dispatch, no block
                    # kernel attribution: which registry impls this chunk
                    # dispatched, and whether it paid a (head, bucket)
                    # compile (both non-empty only on first trace)
                    new = dispatch_log()[n_disp:]
                    d_comp = (sum(self.engine.compile_counts.values())
                              - n_comp)
                    if new or d_comp:
                        span.set(dispatches=[f"{op}:{impl}"
                                             for op, impl in new],
                                 compile_delta=d_comp)
                except Exception as e:
                    # chunk-local failure (malformed request, trace
                    # error): fail THIS chunk's futures, keep serving —
                    # one bad request must not take down the front-end
                    span.end_from_exc(e)
                    for w in live:
                        self._fail(w.future, e)
                    continue
                self._put_done((live, out, bucket, t0, span))
        except BaseException as e:              # fail loudly, not silently
            self._abort(e)
            if self.scheduler is not None:
                # this runtime will never tick again: resolve ITS
                # streams so consumers see the failure instead of
                # hanging (other producers' sessions stay alive — their
                # own run() loops still tick)
                self._count_decode_failed(self.scheduler.fail_all(
                    RuntimeError("runtime worker died"),
                    only=lambda s: s.owner is self))
                if self.scheduler.on_session_done == self._on_decode_done:
                    self.scheduler.on_session_done = None   # detach: dead
        finally:
            try:
                self._done_q.put(_SENTINEL, timeout=5.0)
            except _queue.Full:                 # completion thread dead
                pass

    def _fail_chunk(self, item) -> None:
        item[4].end("error", error="runtime worker died")
        for w in item[0]:
            self._fail(w.future, RuntimeError("runtime worker died"))

    def _put_done(self, item) -> None:
        """Hand a dispatched chunk to the completion thread; if the
        completion thread died, fail the chunk's futures instead of
        blocking forever (or stranding the chunk in the queue)."""
        while self._worker_exc is None:
            try:
                self._done_q.put(item, timeout=0.1)
                break
            except _queue.Full:
                if self._stop.is_set():
                    self._fail_chunk(item)
                    return
        # _abort sets _worker_exc BEFORE draining _done_q, so if the
        # completion thread died around our put, one of the two drains
        # (abort's, or this reclaim) is guaranteed to see the chunk
        if self._worker_exc is not None:
            while True:
                try:
                    extra = self._done_q.get_nowait()
                except _queue.Empty:
                    return
                if extra is not _SENTINEL:
                    self._fail_chunk(extra)

    def _shed_late(self, works: list[_Work]) -> list[_Work]:
        now = time.perf_counter()
        live = []
        for w in works:
            if w.future.deadline is not None and now > w.future.deadline:
                self._fail(w.future, DeadlineExceededError(
                    f"request {w.future.rid} exceeded its deadline by "
                    f"{(now - w.future.deadline) * 1e3:.1f} ms in queue"),
                    kind="deadline")
            else:
                live.append(w)
        return live

    # ------------------------------------------------------------ completion
    def _completion_loop(self) -> None:
        try:
            while True:
                item = self._done_q.get()
                if item is _SENTINEL:
                    break
                works, out, bucket, t0, span = item
                jax.block_until_ready(out.logits)
                t1 = time.perf_counter()
                # chunks overlap under pipelining (chunk k+1 is dispatched
                # while k executes), so attribute each chunk only the wall
                # PAST the previous chunk's completion — the summed walls
                # then add up to pipeline busy time instead of ~2x it
                prev = self._t_last
                wall = t1 - (t0 if prev is None else max(t0, prev))
                n = len(works)
                logits = np.asarray(out.logits)[:n]
                ids = np.asarray(out.ids)[:n]
                lats = [t1 - w.future.t_submit for w in works]
                labels = Engine._stack_labels([w.labels for w in works])
                self.engine._record(out, n, wall, lats, labels)
                aud = getattr(self.engine, "auditor", None)
                if aud is not None and self.head != "full":
                    # thunk: the unpadded re-stack is only paid when the
                    # auditor's coin flip samples this chunk
                    aud.offer(lambda ws=works: jax.tree.map(
                        lambda *rows: np.stack(rows), *[w.x for w in ws]),
                        ids)
                span.end("ok", device_s=wall)
                for i, w in enumerate(works):
                    w.future.set_result(
                        RankResult(w.future.rid, logits[i], ids[i]))
                for v in lats:
                    self._h_lat.record(v)
                self._h_device.record(wall)
                with self._drained:
                    self._n_completed += n
                    self._n_batches += 1
                    self._occupancy_sum += n / bucket
                    self._t_last = t1
                    self._drained.notify_all()
        except BaseException as e:
            self._abort(e)

    # ---------------------------------------------------------------- misc
    def _fail_admitted(self, w, exc: BaseException) -> None:
        """Fail one admitted work item of either kind."""
        if isinstance(w, _DecodeWork):
            w.session.stream.fail(exc)
            self._count_decode_failed([w.session])
        else:
            self._fail(w.future, exc)

    def _count_decode_failed(self, sessions: list) -> None:
        mine = [s for s in sessions if s.owner is self]
        if not mine:
            return
        with self._drained:
            self._n_decode_done += len(mine)
            self._drained.notify_all()

    def _fail(self, fut: RankFuture, exc: BaseException,
              kind: str = "closed") -> None:
        if not fut.done():
            fut.set_exception(exc)
        with self._drained:
            if kind == "deadline":
                self._n_shed_deadline += 1
            else:
                self._n_failed += 1
            self._drained.notify_all()

    def _abort(self, exc: BaseException) -> None:
        """A worker died: record the error, fail everything still queued,
        and wake drain() so callers see the failure instead of hanging."""
        self._stop.set()
        with self._mu:
            if self._worker_exc is None:
                self._worker_exc = exc
        for w in self._q.close():
            self._fail_admitted(w, RuntimeError("runtime worker died"))
        while True:                     # unjam a blocked dispatcher put
            try:
                item = self._done_q.get_nowait()
            except _queue.Empty:
                break
            if item is not _SENTINEL:
                self._fail_chunk(item)
        with self._drained:
            self._drained.notify_all()

    def _collect_gauges(self, reg) -> None:
        """Exporter hook: refresh control-flow gauges from stats() so the
        Prometheus exposition carries them without double bookkeeping."""
        s = self.stats()
        reg.gauge("runtime_queue_depth").set(s.queue_depth)
        reg.gauge("runtime_submitted_total").set(s.n_submitted)
        reg.gauge("runtime_completed_total").set(s.n_completed)
        reg.gauge("runtime_shed_queue_total").set(s.n_shed_queue)
        reg.gauge("runtime_shed_deadline_total").set(s.n_shed_deadline)
        reg.gauge("runtime_batch_occupancy").set(s.avg_batch_occupancy)
        reg.gauge("runtime_throughput_rps").set(s.throughput_rps)
        if self.scheduler is not None:
            reg.gauge("decode_sessions_total").set(s.n_decode_sessions)
            reg.gauge("decode_tokens_total").set(s.n_decode_tokens)
            reg.gauge("decode_tokens_per_s").set(s.decode_tokens_per_s)
            reg.gauge("decode_slot_occupancy").set(s.decode_slot_occupancy)
            reg.gauge("decode_prefix_hit_rate").set(s.prefix_hit_rate)
            reg.gauge("decode_kv_pages_in_use").set(s.kv_pages_in_use)

    def stats(self) -> RuntimeStats:
        ds = None if self.scheduler is None else self.scheduler.stats()
        # quantile math runs on the histograms' own bounded reservoirs —
        # NEVER under self._mu, so a stats() poll cannot stall the
        # dispatcher/completion threads no matter the window size
        # (tests/test_obs.py pins the bound)
        p50, p95, p99 = self._h_lat.quantile((50, 95, 99))
        device_ms = self._h_device.mean() * 1e3
        with self._mu:
            wall = ((self._t_last - self._t_first)
                    if self._t_first is not None and self._t_last is not None
                    else 0.0)
            decode = {} if ds is None else dict(
                n_decode_sessions=self._n_decode_submitted,
                n_decode_done=self._n_decode_done,
                n_decode_tokens=ds.n_tokens,
                ttft_p50_ms=ds.ttft_p50_ms, ttft_p95_ms=ds.ttft_p95_ms,
                ttft_p99_ms=ds.ttft_p99_ms,
                itl_p50_ms=ds.itl_p50_ms, itl_p95_ms=ds.itl_p95_ms,
                itl_p99_ms=ds.itl_p99_ms,
                decode_slot_occupancy=ds.slot_occupancy,
                decode_tokens_per_s=ds.tokens_per_s,
                n_prefill_skipped=ds.n_prefill_skipped,
                n_prefill_compiles=ds.n_prefill_compiles,
                n_prefill_buckets=ds.n_prefill_buckets,
                prefix_hit_rate=ds.prefix_hit_rate,
                kv_pages_in_use=ds.kv_pages_in_use,
                kv_peak_pages=ds.kv_peak_pages,
            )
            return RuntimeStats(**decode,
                n_submitted=self._n_submitted,
                n_completed=self._n_completed,
                n_shed_queue=self._n_shed_queue,
                n_shed_deadline=self._n_shed_deadline,
                queue_depth=len(self._q),
                n_batches=self._n_batches,
                avg_batch_occupancy=(self._occupancy_sum
                                     / max(self._n_batches, 1)),
                latency_p50_ms=p50 * 1e3,
                latency_p95_ms=p95 * 1e3,
                latency_p99_ms=p99 * 1e3,
                device_ms_per_batch=device_ms,
                wall_s=wall,
                throughput_rps=(self._n_completed / wall if wall > 0
                                else 0.0),
            )
