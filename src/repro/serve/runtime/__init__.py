"""Async serving runtime: admission queue + futures + overlapped
host/device pipeline over a (thread-safe) :class:`~repro.serve.Engine`.

  * ``future``  — :class:`RankFuture` and the shed-exception hierarchy
    (shared with decode's :class:`~repro.serve.decode.TokenStream`).
  * ``queue``   — :class:`AdmissionQueue` (bounded, block | shed; admits
    both scoring requests and decode sessions).
  * ``runtime`` — :class:`AsyncRuntime` (dispatcher + completion threads,
    deadline shedding, drain/close, :class:`RuntimeStats`; with a
    ``DecodeScheduler`` attached, ``submit_decode`` streams tokens).
"""

from repro.serve.runtime.future import (DeadlineExceededError, QueueFullError,
                                        RankFuture, RuntimeClosedError,
                                        ShedError)
from repro.serve.runtime.queue import POLICIES, AdmissionQueue
from repro.serve.runtime.runtime import (AsyncRuntime, RuntimeStats,
                                         submit_decode_open_loop,
                                         submit_open_loop)

__all__ = [
    "AsyncRuntime", "RuntimeStats", "RankFuture",
    "AdmissionQueue", "POLICIES", "submit_open_loop",
    "submit_decode_open_loop",
    "ShedError", "QueueFullError", "DeadlineExceededError",
    "RuntimeClosedError",
]
