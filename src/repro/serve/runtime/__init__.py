"""Async serving runtime: admission queue + futures + overlapped
host/device pipeline over a (thread-safe) :class:`~repro.serve.Engine`.

  * ``future``  — :class:`RankFuture` and the shed-exception hierarchy
    (shared with decode's :class:`~repro.serve.decode.TokenStream`).
  * ``queue``   — :class:`AdmissionQueue` (bounded, block | shed; admits
    both scoring requests and decode sessions).
  * ``runtime`` — :class:`AsyncRuntime` (dispatcher + completion threads,
    deadline shedding, drain/close, :class:`RuntimeStats`; with a
    ``DecodeScheduler`` attached, ``submit_decode`` streams tokens).

Invariants the pieces rely on:

* **One mutator per structure.** The dispatcher thread is the only
  thread that pops the admission queue and launches device work; the
  completion thread only resolves futures.  Anything both touch (stats
  windows, future state) is lock-guarded; nothing here mutates Engine
  internals outside ``Engine.lock``.
* **Snapshots are copies.** Work captured at dispatch time (request
  batches, the decode scheduler's active-slot list) is materialised as
  a new list, never a live reference — sessions may retire and slots
  may be re-admitted between dispatch and completion, and completion
  must attribute results to what was ACTUALLY in the batch when it
  launched.
* **Shedding happens outside device code.** Deadlines are checked at
  admission and again at dispatch; once a batch is launched it runs to
  completion (there is no device-side cancellation), so a shed is
  always a cheap host-side future resolution.
"""

from repro.serve.runtime.future import (DeadlineExceededError, QueueFullError,
                                        RankFuture, RuntimeClosedError,
                                        ShedError)
from repro.serve.runtime.queue import POLICIES, AdmissionQueue
from repro.serve.runtime.runtime import (AsyncRuntime, RuntimeStats,
                                         submit_decode_open_loop,
                                         submit_open_loop)

__all__ = [
    "AsyncRuntime", "RuntimeStats", "RankFuture",
    "AdmissionQueue", "POLICIES", "submit_open_loop",
    "submit_decode_open_loop",
    "ShedError", "QueueFullError", "DeadlineExceededError",
    "RuntimeClosedError",
]
