"""Serving subpackage: unified batched engine + pluggable WOL heads +
the async serving runtime.

  * ``engine``  — :class:`Engine` (submit/flush/metrics), plus the legacy
    ``WOLServer`` / ``LMDecoder`` facades.
  * ``heads``   — the full | lss | lss-sharded head protocol.
  * ``batcher`` — bucketed continuous micro-batching (pure shape logic).
  * ``runtime`` — :class:`AsyncRuntime`: thread-safe admission queue with
    per-request futures, deadline/queue-depth load shedding, and a
    dispatcher that overlaps host-side padding with device execution.
  * ``decode``  — continuous-batching streaming decode:
    :class:`DecodeScheduler` over a slot-based :class:`KVCachePool`,
    per-token :class:`TokenStream` futures, token-exact with the
    blocking ``LMDecoder.generate`` path (which is now a facade over it).
  * ``multihost`` — multi-process SPMD serving over ``jax.distributed``:
    :class:`MultihostContext`, the leader's opcode broadcast seam, and
    ``follower_loop`` (process 0 owns admission; every process builds
    only its own vocab shards).
"""

from repro.serve.batcher import DEFAULT_BUCKETS, Chunk, MicroBatcher
from repro.serve.decode import (DecodeScheduler, DecodeSession, DecodeStats,
                                KVCachePool, KVPoolExhaustedError,
                                TokenStream)
from repro.serve.engine import (Engine, LMDecoder, RankResult, ServeMetrics,
                                WOLServer)
from repro.serve.heads import (HEAD_KINDS, HeadOutput, make_full_head,
                               make_lss_head, make_multihost_lss_head,
                               make_sharded_lss_head, shard_index)
from repro.serve.multihost import (MultihostContext, follower_loop,
                                   init_multihost, stop_followers)
from repro.serve.runtime import (AdmissionQueue, AsyncRuntime,
                                 DeadlineExceededError, QueueFullError,
                                 RankFuture, RuntimeClosedError,
                                 RuntimeStats, ShedError,
                                 submit_decode_open_loop, submit_open_loop)

__all__ = [
    "DEFAULT_BUCKETS", "Chunk", "MicroBatcher",
    "Engine", "LMDecoder", "RankResult", "ServeMetrics", "WOLServer",
    "HEAD_KINDS", "HeadOutput", "make_full_head", "make_lss_head",
    "make_sharded_lss_head", "make_multihost_lss_head", "shard_index",
    "MultihostContext", "init_multihost", "follower_loop",
    "stop_followers",
    "AsyncRuntime", "RuntimeStats", "RankFuture", "AdmissionQueue",
    "ShedError", "QueueFullError", "DeadlineExceededError",
    "RuntimeClosedError", "submit_open_loop", "submit_decode_open_loop",
    "DecodeScheduler", "DecodeSession", "DecodeStats", "KVCachePool",
    "KVPoolExhaustedError", "TokenStream",
]
