"""Serving subpackage: unified batched engine + pluggable WOL heads +
the async serving runtime.

  * ``engine``  — :class:`Engine` (submit/flush/metrics), plus the legacy
    ``WOLServer`` / ``LMDecoder`` facades.
  * ``heads``   — the full | lss | lss-sharded head protocol.
  * ``batcher`` — bucketed continuous micro-batching (pure shape logic).
  * ``runtime`` — :class:`AsyncRuntime`: thread-safe admission queue with
    per-request futures, deadline/queue-depth load shedding, and a
    dispatcher that overlaps host-side padding with device execution.
"""

from repro.serve.batcher import DEFAULT_BUCKETS, Chunk, MicroBatcher
from repro.serve.engine import (Engine, LMDecoder, RankResult, ServeMetrics,
                                WOLServer)
from repro.serve.heads import (HEAD_KINDS, HeadOutput, make_full_head,
                               make_lss_head, make_sharded_lss_head,
                               shard_index)
from repro.serve.runtime import (AdmissionQueue, AsyncRuntime,
                                 DeadlineExceededError, QueueFullError,
                                 RankFuture, RuntimeClosedError,
                                 RuntimeStats, ShedError)

__all__ = [
    "DEFAULT_BUCKETS", "Chunk", "MicroBatcher",
    "Engine", "LMDecoder", "RankResult", "ServeMetrics", "WOLServer",
    "HEAD_KINDS", "HeadOutput", "make_full_head", "make_lss_head",
    "make_sharded_lss_head", "shard_index",
    "AsyncRuntime", "RuntimeStats", "RankFuture", "AdmissionQueue",
    "ShedError", "QueueFullError", "DeadlineExceededError",
    "RuntimeClosedError",
]
