"""serve subpackage."""
