"""Pluggable WOL head protocol shared by the score and decode paths.

A *head* is a pure function ``q [B, d] -> HeadOutput`` ranking the wide
output layer for a batch of query embeddings.  Three implementations:

  * ``full``         — exact ``q @ W.T + b`` then top-k (the baseline the
    paper speeds up).
  * ``lss``          — Algorithm 2 over a fitted :class:`LSSIndex`
    (single retrieval pass; sample size comes from the same pass).
  * ``lss-sharded``  — the vocab-sharded index from ``core.sharded``:
    shard-local retrieve + top-k, O(TP*k) all-gather, global top-k.

All heads return the same :class:`HeadOutput`, so the engine's batcher,
metrics, and the LM decode loop are head-agnostic.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.lss import NEG_INF, LSSConfig, LSSIndex, lss_forward
from repro.core.sharded import (build_local_index, make_multihost_predict,
                                make_sharded_predict)
from repro.core.tables import LSSTables

__all__ = ["HeadOutput", "HEAD_KINDS", "make_full_head", "make_lss_head",
           "make_sharded_lss_head", "make_multihost_lss_head",
           "shard_index"]

HEAD_KINDS = ("full", "lss", "lss-sharded")


class HeadOutput(NamedTuple):
    """What every head returns for a query batch."""

    logits: jax.Array            # [B, k] top-k scores
    ids: jax.Array               # [B, k] top-k neuron ids (-1 = none)
    sample_size: jax.Array       # [B]    neurons actually scored
    cand_ids: jax.Array | None   # [B, C] retrieved set (None: full/sharded)


def make_full_head(w: jax.Array, b: jax.Array, top_k: int
                   ) -> Callable[[jax.Array], HeadOutput]:
    """Exact WOL: every neuron is scored (sample size == m)."""
    m = w.shape[0]

    def head(q: jax.Array) -> HeadOutput:
        logits = q.astype(jnp.float32) @ w.T.astype(jnp.float32) + b
        top, ids = jax.lax.top_k(logits, top_k)
        return HeadOutput(top, ids,
                          jnp.full((q.shape[0],), m, jnp.int32), None)

    return head


def make_lss_head(index: LSSIndex, w_aug: jax.Array | None, top_k: int,
                  impl: str | None = None, dedup: str | None = None
                  ) -> Callable[[jax.Array], HeadOutput]:
    """Algorithm 2 over one fitted index (single-device).

    ``impl`` pins the kernel-registry implementation serving the path
    (``ref`` | ``pallas`` | ``pallas_interpret``; None = backend auto);
    ``dedup`` pins the cross-table dedup strategy (``quadratic`` |
    ``bitonic``; None = auto-select on the candidate count).
    """

    def head(q: jax.Array) -> HeadOutput:
        out = lss_forward(q.astype(jnp.float32), index, w_aug, top_k,
                          impl=impl, dedup=dedup)
        return HeadOutput(out.top_logits, out.top_ids, out.sample_size,
                          out.cand_ids)

    return head


def _mask_index_tail(index: LSSIndex, n_valid: int) -> LSSIndex:
    """Remove local row ids >= ``n_valid`` (vocab padding) from a shard's
    tables: their slots become -1 and their slab rows zero, so padded
    neurons are simply never retrieved."""
    t = index.tables
    ids = jnp.where(t.table_ids < n_valid, t.table_ids, -1)
    tables = LSSTables(ids, t.n_dropped, t.k_bits, t.n_tables, t.capacity)
    wb = index.w_bucketed
    if wb is not None:
        # zeroing works for every slab_dtype: an int8 zero code (and a
        # zeroed scale) dequantizes to exactly 0, same as fp32/bf16
        wb = jnp.where((ids >= 0)[..., None], wb, jnp.zeros_like(wb))
    ws = index.w_scale
    if ws is not None:
        # pad rows carry the NEG_INF sentinel bias, so their per-row
        # scale is a huge garbage value; mask it like the weight rows so
        # a masked slot is all-zero in BOTH leaves (0 * scale is already
        # exactly 0 in fp32, but interpret-mode buffers and dumps must
        # not carry the sentinel through)
        ws = jnp.where(ids >= 0, ws, jnp.zeros_like(ws))
    return LSSIndex(index.theta, tables, wb, ws)


def shard_index(w_aug: jax.Array, theta: jax.Array, cfg: LSSConfig,
                n_shards: int, *, shard_range: tuple[int, int] | None = None,
                m_total: int | None = None):
    """Split the WOL rows into ``n_shards`` contiguous vocab shards, build
    one local index per shard, and stack the leaves ([n_built, ...]).

    When ``m % n_shards != 0`` the rows are padded up to the next multiple
    and the padded ids are masked out of the final shard's tables
    (:func:`_mask_index_tail`), so a padded neuron can never be retrieved
    and arbitrary vocab sizes shard without changing any real query's
    result.  The pad rows carry a NEG_INF bias column purely as a
    sentinel for humans inspecting ``w_stack`` dumps — queries are
    augmented with 0, so a bias never reaches a logit; the table masking
    is what excludes padding, not the sentinel.

    ``shard_range=(lo, hi)`` builds ONLY shards [lo, hi): ``w_aug`` then
    holds just the global rows those shards cover —
    ``[lo * m_local, min(hi * m_local, m_total))`` — and ``m_total``
    (the full vocab size) is required for the pad/mask math.  This is
    the multi-host build path: each process constructs the shards it
    addresses from its own row slice and no process ever materializes
    the full ``[m, d]`` weight.  The per-shard indexes (including the
    int8 ``w_scale`` leaf) are bit-identical to the same shards of a
    full-range build.

    Returns (stacked_index, stacked_w_aug or None, m_local).
    """
    if shard_range is None:
        if m_total is not None and m_total != w_aug.shape[0]:
            raise ValueError(f"m_total={m_total} disagrees with "
                             f"w_aug rows {w_aug.shape[0]}")
        m_total = w_aug.shape[0]
        shard_range = (0, n_shards)
    elif m_total is None:
        raise ValueError("shard_range requires m_total (the FULL vocab "
                         "size; w_aug holds only the range's rows)")
    lo, hi = shard_range
    if not (0 <= lo < hi <= n_shards):
        raise ValueError(f"shard_range {shard_range} outside "
                         f"[0, {n_shards})")
    m = m_total
    m_pad = -(-m // n_shards) * n_shards
    m_local = m_pad // n_shards
    row0 = lo * m_local
    n_rows_need = min(hi * m_local, m) - row0
    if w_aug.shape[0] != n_rows_need:
        raise ValueError(
            f"shard_range {shard_range} of m={m} needs rows "
            f"[{row0}, {row0 + n_rows_need}) = {n_rows_need} rows, "
            f"got {w_aug.shape[0]}")
    if hi * m_local > row0 + n_rows_need:         # padded vocab tail
        pad_rows = jnp.zeros((hi * m_local - row0 - n_rows_need,
                              w_aug.shape[-1]), w_aug.dtype)
        pad_rows = pad_rows.at[:, -1].set(NEG_INF)  # sentinel bias column
        w_aug = jnp.concatenate([w_aug, pad_rows], axis=0)
    locals_ = []
    for i in range(lo, hi):
        idx = build_local_index(
            w_aug[(i - lo) * m_local:(i - lo + 1) * m_local], theta, cfg)
        n_valid = min(max(m - i * m_local, 0), m_local)
        if n_valid < m_local:
            idx = _mask_index_tail(idx, n_valid)
        locals_.append(idx)
    stack = jax.tree.map(lambda *xs: jnp.stack(xs), *locals_)
    w_stack = None
    if not cfg.use_bucket_major:
        w_stack = w_aug.reshape(hi - lo, m_local, w_aug.shape[-1])
    return stack, w_stack, m_local


def make_sharded_lss_head(index_stack, w_stack, mesh, cfg: LSSConfig,
                          m_local: int, top_k: int,
                          model_axis: str = "model",
                          impl: str | None = None,
                          dedup: str | None = None
                          ) -> Callable[[jax.Array], HeadOutput]:
    """Vocab-sharded Algorithm 2 (sample size psum'd across shards).

    ``cand_ids`` is None: the retrieved sets live shard-local and only the
    O(TP*k) winners cross the interconnect — recall metrics fall back to
    the top-k set.
    """
    fwd = make_sharded_predict(mesh, model_axis, cfg, m_local, top_k,
                               with_aux=True, impl=impl, dedup=dedup)

    def head(q: jax.Array) -> HeadOutput:
        logits, ids, sample = fwd(q.astype(jnp.float32), index_stack,
                                  w_stack)
        return HeadOutput(logits, ids, sample, None)

    return head


def make_multihost_lss_head(index_stack, w_stack, mesh, cfg: LSSConfig,
                            m_local: int, top_k: int,
                            host_axis: str = "host",
                            model_axis: str = "model",
                            impl: str | None = None,
                            dedup: str | None = None
                            ) -> Callable[[jax.Array], HeadOutput]:
    """:func:`make_sharded_lss_head` over a multi-process (host, model)
    mesh: per-shard retrieve, hierarchical O(hosts*k) cross-host merge
    (``core.sharded.make_multihost_predict``), sample size psum'd over
    the whole fleet.  ``index_stack`` leaves are GLOBAL arrays sharded
    ``P((host_axis, model_axis))`` on the leading [n_shards] dim — build
    them with ``shard_index(..., shard_range=...)`` +
    ``compat.make_global_array``.
    """
    fwd = make_multihost_predict(mesh, host_axis, model_axis, cfg,
                                 m_local, top_k, with_aux=True,
                                 impl=impl, dedup=dedup)

    # Multi-process jit forbids CLOSING OVER arrays spanning
    # non-addressable devices, so the stacks cannot ride into a jitted
    # step as closure constants: the head exposes them on
    # ``head.global_operands`` plus the operand-threading form
    # ``head.with_operands(q, *operands)``, and Engine._step /
    # decode_logits pass them as explicit jit arguments instead.
    def with_operands(q: jax.Array, index_stack, w_stack) -> HeadOutput:
        logits, ids, sample = fwd(q.astype(jnp.float32), index_stack,
                                  w_stack)
        return HeadOutput(logits, ids, sample, None)

    def head(q: jax.Array) -> HeadOutput:
        return with_operands(q, index_stack, w_stack)

    head.global_operands = (index_stack, w_stack)
    head.with_operands = with_operands
    return head
