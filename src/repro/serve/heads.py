"""Pluggable WOL head protocol shared by the score and decode paths.

A *head* is a pure function ``q [B, d] -> HeadOutput`` ranking the wide
output layer for a batch of query embeddings.  Three implementations:

  * ``full``         — exact ``q @ W.T + b`` then top-k (the baseline the
    paper speeds up).
  * ``lss``          — Algorithm 2 over a fitted :class:`LSSIndex`
    (single retrieval pass; sample size comes from the same pass).
  * ``lss-sharded``  — the vocab-sharded index from ``core.sharded``:
    shard-local retrieve + top-k, O(TP*k) all-gather, global top-k.

All heads return the same :class:`HeadOutput`, so the engine's batcher,
metrics, and the LM decode loop are head-agnostic.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.lss import NEG_INF, LSSConfig, LSSIndex, lss_forward
from repro.core.sharded import build_local_index, make_sharded_predict
from repro.core.tables import LSSTables

__all__ = ["HeadOutput", "HEAD_KINDS", "make_full_head", "make_lss_head",
           "make_sharded_lss_head", "shard_index"]

HEAD_KINDS = ("full", "lss", "lss-sharded")


class HeadOutput(NamedTuple):
    """What every head returns for a query batch."""

    logits: jax.Array            # [B, k] top-k scores
    ids: jax.Array               # [B, k] top-k neuron ids (-1 = none)
    sample_size: jax.Array       # [B]    neurons actually scored
    cand_ids: jax.Array | None   # [B, C] retrieved set (None: full/sharded)


def make_full_head(w: jax.Array, b: jax.Array, top_k: int
                   ) -> Callable[[jax.Array], HeadOutput]:
    """Exact WOL: every neuron is scored (sample size == m)."""
    m = w.shape[0]

    def head(q: jax.Array) -> HeadOutput:
        logits = q.astype(jnp.float32) @ w.T.astype(jnp.float32) + b
        top, ids = jax.lax.top_k(logits, top_k)
        return HeadOutput(top, ids,
                          jnp.full((q.shape[0],), m, jnp.int32), None)

    return head


def make_lss_head(index: LSSIndex, w_aug: jax.Array | None, top_k: int,
                  impl: str | None = None, dedup: str | None = None
                  ) -> Callable[[jax.Array], HeadOutput]:
    """Algorithm 2 over one fitted index (single-device).

    ``impl`` pins the kernel-registry implementation serving the path
    (``ref`` | ``pallas`` | ``pallas_interpret``; None = backend auto);
    ``dedup`` pins the cross-table dedup strategy (``quadratic`` |
    ``bitonic``; None = auto-select on the candidate count).
    """

    def head(q: jax.Array) -> HeadOutput:
        out = lss_forward(q.astype(jnp.float32), index, w_aug, top_k,
                          impl=impl, dedup=dedup)
        return HeadOutput(out.top_logits, out.top_ids, out.sample_size,
                          out.cand_ids)

    return head


def _mask_index_tail(index: LSSIndex, n_valid: int) -> LSSIndex:
    """Remove local row ids >= ``n_valid`` (vocab padding) from a shard's
    tables: their slots become -1 and their slab rows zero, so padded
    neurons are simply never retrieved."""
    t = index.tables
    ids = jnp.where(t.table_ids < n_valid, t.table_ids, -1)
    tables = LSSTables(ids, t.n_dropped, t.k_bits, t.n_tables, t.capacity)
    wb = index.w_bucketed
    if wb is not None:
        # zeroing works for every slab_dtype: an int8 zero code (and its
        # untouched scale) dequantizes to exactly 0, same as fp32/bf16
        wb = jnp.where((ids >= 0)[..., None], wb, jnp.zeros_like(wb))
    return LSSIndex(index.theta, tables, wb, index.w_scale)


def shard_index(w_aug: jax.Array, theta: jax.Array, cfg: LSSConfig,
                n_shards: int):
    """Split the WOL rows into ``n_shards`` contiguous vocab shards, build
    one local index per shard, and stack the leaves ([TP, ...]).

    When ``m % n_shards != 0`` the rows are padded up to the next multiple
    and the padded ids are masked out of the final shard's tables
    (:func:`_mask_index_tail`), so a padded neuron can never be retrieved
    and arbitrary vocab sizes shard without changing any real query's
    result.  The pad rows carry a NEG_INF bias column purely as a
    sentinel for humans inspecting ``w_stack`` dumps — queries are
    augmented with 0, so a bias never reaches a logit; the table masking
    is what excludes padding, not the sentinel.

    Returns (stacked_index, stacked_w_aug or None, m_local).
    """
    m = w_aug.shape[0]
    m_pad = -(-m // n_shards) * n_shards
    if m_pad != m:
        pad_rows = jnp.zeros((m_pad - m, w_aug.shape[-1]), w_aug.dtype)
        pad_rows = pad_rows.at[:, -1].set(NEG_INF)   # sentinel bias column
        w_aug = jnp.concatenate([w_aug, pad_rows], axis=0)
    m_local = m_pad // n_shards
    locals_ = []
    for i in range(n_shards):
        idx = build_local_index(w_aug[i * m_local:(i + 1) * m_local],
                                theta, cfg)
        n_valid = min(max(m - i * m_local, 0), m_local)
        if n_valid < m_local:
            idx = _mask_index_tail(idx, n_valid)
        locals_.append(idx)
    stack = jax.tree.map(lambda *xs: jnp.stack(xs), *locals_)
    w_stack = None
    if not cfg.use_bucket_major:
        w_stack = w_aug.reshape(n_shards, m_local, w_aug.shape[-1])
    return stack, w_stack, m_local


def make_sharded_lss_head(index_stack, w_stack, mesh, cfg: LSSConfig,
                          m_local: int, top_k: int,
                          model_axis: str = "model",
                          impl: str | None = None,
                          dedup: str | None = None
                          ) -> Callable[[jax.Array], HeadOutput]:
    """Vocab-sharded Algorithm 2 (sample size psum'd across shards).

    ``cand_ids`` is None: the retrieved sets live shard-local and only the
    O(TP*k) winners cross the interconnect — recall metrics fall back to
    the top-k set.
    """
    fwd = make_sharded_predict(mesh, model_axis, cfg, m_local, top_k,
                               with_aux=True, impl=impl, dedup=dedup)

    def head(q: jax.Array) -> HeadOutput:
        logits, ids, sample = fwd(q.astype(jnp.float32), index_stack,
                                  w_stack)
        return HeadOutput(logits, ids, sample, None)

    return head
