"""Continuous micro-batcher: coalesce requests into bucketed batch shapes.

XLA recompiles on every new input shape, so a naive serving loop that
batches "whatever arrived" retriggers compilation whenever the arrival
pattern changes.  The batcher quantises every coalesced batch to a fixed
bucket ladder (powers of two by default) and pads to the bucket, so after
warm-up each (head, bucket) pair compiles exactly once regardless of
traffic shape.

Pure shape logic — no jax, no engine state — so it is unit-testable and
reusable by any caller that owns its own jit cache.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import numpy as np

__all__ = ["Chunk", "MicroBatcher", "DEFAULT_BUCKETS"]

DEFAULT_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128)


class Chunk(NamedTuple):
    """One jit-shaped unit of work: requests [start, start+size) padded to
    ``bucket`` rows."""

    start: int
    size: int
    bucket: int


class MicroBatcher:
    """Maps "n requests are waiting" to a static-shape execution plan."""

    def __init__(self, buckets: Sequence[int] = DEFAULT_BUCKETS):
        if not buckets or any(b <= 0 for b in buckets):
            raise ValueError(f"bad bucket ladder: {buckets}")
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        self.max_bucket = self.buckets[-1]

    def bucket_for(self, n: int) -> int:
        """Smallest bucket >= n (n must not exceed the max bucket)."""
        for b in self.buckets:
            if b >= n:
                return b
        raise ValueError(f"{n} exceeds max bucket {self.max_bucket}")

    def plan(self, n: int) -> list[Chunk]:
        """Split n queued requests into chunks: greedy max-bucket chunks,
        then one bucketed remainder chunk."""
        chunks: list[Chunk] = []
        start = 0
        while n - start >= self.max_bucket:
            chunks.append(Chunk(start, self.max_bucket, self.max_bucket))
            start += self.max_bucket
        rest = n - start
        if rest:
            chunks.append(Chunk(start, rest, self.bucket_for(rest)))
        return chunks

    @staticmethod
    def pad_rows(x, bucket: int, fill=0):
        """Pad axis 0 of an array (or each leaf of a dict) to ``bucket``
        rows with ``fill``; numpy-side so device buffers stay static."""
        if isinstance(x, dict):
            return {k: MicroBatcher.pad_rows(v, bucket, fill)
                    for k, v in x.items()}
        arr = np.asarray(x)
        n = arr.shape[0]
        if n == bucket:
            return arr
        pad = np.full((bucket - n,) + arr.shape[1:], fill, arr.dtype)
        return np.concatenate([arr, pad], axis=0)
