"""Multi-host SPMD serving: process 0 owns admission, followers mirror.

Topology (see ``compat.make_global_mesh``): one global (host, model)
mesh — rows are processes, columns each process's local devices — so a
stacked shard pytree sharded ``P(("host", "model"))`` puts contiguous
vocab-shard blocks on each host, exactly what the hierarchical top-k
merge's global-id offset math assumes.  Every process builds ONLY the
shards it addresses (``heads.shard_index(..., shard_range=...)``) and
:func:`assemble_global_stack` stitches the local stacks into global
arrays without any process materializing remote shards.

Control plane: the AsyncRuntime, the admission queue, deadlines, and
result futures live on process 0 only.  The jitted score steps are SPMD
collective programs, so before the leader runs one, every follower must
enter the same program with the same replicated batch.  The seam is
``Engine._step`` — the ONE choke point both ``Engine.rank``/``flush``
and the AsyncRuntime dispatcher fetch steps from — which on the leader
returns a :func:`make_leader_step` wrapper that first ships one opcode
message — an [4]-int32 header ``(opcode, head, rows, dim)`` plus the
padded batch — over :class:`_OpChannel` and then runs the step;
followers sit in :func:`follower_loop` replaying the opcode stream
until ``OP_STOP``.  The follower side of the channel is a single
thread, so every leader-side send sequence holds
``MultihostContext.lock`` end to end (message + step) — without it two
leader threads (the AsyncRuntime dispatcher and, say, the
RecallAuditor's background ``rank(head="full")``) could interleave
their messages and desync the whole fleet.

The channel rides the ``jax.distributed`` coordination service (a grpc
key-value store), NOT gloo collectives.  It used to be a stream of
tiny ``broadcast_one_to_all`` calls, but each of those is its own
jitted psum whose result is materialized from ``addressable_data(0)``
only — the OTHER local device's collective ops can still be in flight
when the caller issues the next, differently-shaped broadcast, and
under CPU contention two adjacent channel programs would overlap
across processes and collide on a gloo slot (the symptom is a fatal
``gloo ... op.preamble.length <= op.nbytes. 128 vs 4`` abort: a
4-byte scalar recv matched against a 128-byte segment of the batch
psum).  With the control plane on grpc, the only gloo traffic left is
INSIDE the SPMD step programs, which the channel strictly serializes.

Decode rides the same opcode channel at session granularity:
``OP_DECODE`` broadcasts the prompt block once, then EVERY process runs
the same deterministic blocking ``LMDecoder.generate`` — the fused
decode steps (which embed the multihost head's collectives) execute in
lockstep without per-token broadcasts, because blocking generate has no
wall-clock-dependent control flow.
"""

from __future__ import annotations

import contextlib
import dataclasses
import io
import threading

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.utils import compat

__all__ = ["MultihostContext", "init_multihost", "assemble_global_stack",
           "make_leader_step", "leader_generate", "leader_swap_index",
           "follower_loop", "stop_followers", "mirrored_region",
           "in_mirrored_region", "OP_STOP", "OP_SCORE", "OP_DECODE",
           "OP_SWAP_INDEX"]

OP_STOP, OP_SCORE, OP_DECODE, OP_SWAP_INDEX = 0, 1, 2, 3
_HEADER_LEN = 4
_HEAD_IDS = {"full": 0, "lss": 1, "lss-sharded": 2}
_ID_HEADS = {v: k for k, v in _HEAD_IDS.items()}


def _pack(arrays) -> bytes:
    """Serialize a tuple of arrays (dtype/shape/bytes verbatim)."""
    bio = io.BytesIO()
    np.savez(bio, **{f"a{i}": np.asarray(a) for i, a in enumerate(arrays)})
    return bio.getvalue()


def _unpack(blob: bytes) -> list[np.ndarray]:
    with np.load(io.BytesIO(blob)) as z:
        return [z[f"a{i}"] for i in range(len(z.files))]


class _OpChannel:
    """Leader -> followers opcode messaging over the ``jax.distributed``
    coordination service (grpc KV store; see the module docstring for
    why this must NOT be gloo collectives).

    One message per opcode: a monotonically increasing sequence number
    keys each blob, the leader's sends and every follower's recvs
    advance their local counters in lockstep (a follower consumes
    exactly one message per leader send), and payload bytes travel
    verbatim — followers see the leader's batch bit-identically, not a
    ``+ 0.0`` psum of it.  The leader lazily deletes keys ``_GC_WINDOW``
    sends behind, so a long-lived serving fleet cannot grow the
    coordinator's store without bound (a follower lagging 4096 whole
    opcodes is a broken fleet, not a slow one)."""

    _PREFIX = "repro/opch"
    _GC_WINDOW = 4096

    def __init__(self):
        self._seq = 0

    @property
    def _client(self):
        from jax._src import distributed
        client = distributed.global_state.client
        if client is None:
            raise RuntimeError("opcode channel requires an initialized "
                               "jax.distributed runtime (init_multihost)")
        return client

    def send(self, *arrays) -> None:
        self._seq += 1
        self._client.key_value_set_bytes(
            f"{self._PREFIX}/{self._seq}", _pack(arrays))
        old = self._seq - self._GC_WINDOW
        if old > 0:
            self._client.key_value_delete(f"{self._PREFIX}/{old}")

    def recv(self, timeout_s: float | None = 600.0) -> list[np.ndarray]:
        """Block for the next message.  ``None`` waits forever (an idle
        follower between requests), polling in bounded chunks so the
        grpc deadline never fires spuriously on a quiet channel."""
        self._seq += 1
        key = f"{self._PREFIX}/{self._seq}"
        chunk_ms = 60_000 if timeout_s is None \
            else max(1, int(timeout_s * 1000))
        while True:
            try:
                blob = self._client.blocking_key_value_get_bytes(
                    key, chunk_ms)
                return _unpack(blob)
            except Exception as exc:  # retry only grpc deadline expiry
                if timeout_s is None and "DEADLINE_EXCEEDED" in repr(exc):
                    continue
                raise


@dataclasses.dataclass(frozen=True)
class MultihostContext:
    """The fleet's shape, shared by engine, launcher, and bench.

    ``lock`` serializes the leader's opcode channel: followers replay
    opcodes strictly in sequence order, entering each SPMD program as
    they go, so a leader thread's send+step sequence must never
    interleave with another thread's (the swap's message pair and the
    collectives inside each step would cross).  Reentrant, because a
    mirrored decode holds it across ``generate`` while the inner
    prefill re-enters the step wrapper on the same thread."""

    mesh: jax.sharding.Mesh
    host_axis: str = "host"
    model_axis: str = "model"
    lock: threading.RLock = dataclasses.field(
        default_factory=threading.RLock, repr=False, compare=False)
    channel: _OpChannel = dataclasses.field(
        default_factory=_OpChannel, repr=False, compare=False)

    @property
    def process_id(self) -> int:
        return compat.process_index()

    @property
    def n_processes(self) -> int:
        return int(self.mesh.shape[self.host_axis])

    @property
    def shards_per_host(self) -> int:
        return int(self.mesh.shape[self.model_axis])

    @property
    def n_shards(self) -> int:
        return self.n_processes * self.shards_per_host

    @property
    def is_leader(self) -> bool:
        return self.process_id == 0

    def shard_range(self) -> tuple[int, int]:
        """[lo, hi) shard ids this process addresses (host-contiguous)."""
        tpl = self.shards_per_host
        return self.process_id * tpl, (self.process_id + 1) * tpl

    def row_range(self, m: int) -> tuple[int, int]:
        """Global weight rows [r0, r1) this process's shards cover for a
        vocab of m — the ONLY rows it needs to hold."""
        m_local = -(-m // self.n_shards)
        lo, hi = self.shard_range()
        return lo * m_local, min(hi * m_local, m)

    def stack_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh,
                             P((self.host_axis, self.model_axis)))


def init_multihost(coordinator: str | None = None,
                   num_processes: int | None = None,
                   process_id: int | None = None, *,
                   host_axis: str = "host", model_axis: str = "model"
                   ) -> MultihostContext | None:
    """Initialize ``jax.distributed`` (CPU collectives included; see
    ``compat.distributed_initialize`` — args default to the
    ``REPRO_DIST_COORDINATOR``-family env vars) and build the global
    serving mesh.
    Returns None in the single-process case: callers branch once and the
    whole single-host path stays untouched."""
    if not compat.distributed_initialize(coordinator, num_processes,
                                         process_id):
        return None
    mesh = compat.make_global_mesh((host_axis, model_axis))
    return MultihostContext(mesh, host_axis, model_axis)


def assemble_global_stack(ctx: MultihostContext, local_tree, n_shards: int):
    """Stitch each process's locally built shard stack (leading dim =
    shards_per_host) into global [n_shards, ...] arrays sharded over
    (host, model) — metadata only, no cross-process copies."""
    sharding = ctx.stack_sharding()

    def leaf(x):
        x = np.asarray(x)
        return compat.make_global_array(sharding, x,
                                        (n_shards,) + x.shape[1:])

    return jax.tree.map(leaf, local_tree)


# ------------------------------------------------------ opcode channel --
_MIRROR = threading.local()


def in_mirrored_region() -> bool:
    return getattr(_MIRROR, "depth", 0) > 0


@contextlib.contextmanager
def mirrored_region():
    """Marks a region EVERY process executes in lockstep (mirrored
    decode): inside it the leader's step wrapper stands down — nobody
    is waiting on the opcode channel, because the followers are running
    this very region themselves.  Without this, the decode prefill's
    ``engine.rank`` on the leader would send OP_SCORE at a follower
    that is inside its own mirrored ``generate`` — a deadlock."""
    _MIRROR.depth = getattr(_MIRROR, "depth", 0) + 1
    try:
        yield
    finally:
        _MIRROR.depth -= 1


def _header(op: int, kind_id: int, rows: int, dim: int) -> np.ndarray:
    return np.asarray([op, kind_id, rows, dim], np.int32)


def make_leader_step(ctx: MultihostContext, jitted, kind: str,
                     bucket: int):
    """Wrap a jitted score step for the leader: ship the opcode +
    replicated batch so every follower enters the same collective
    program, run it, and hand back HOST results (numpy) — the engine's
    slicing/metrics must not launch new device programs on global
    arrays outside the SPMD seam.  The whole message+step sequence runs
    under ``ctx.lock`` so concurrent leader threads (the AsyncRuntime
    dispatcher, the RecallAuditor, user threads) can never interleave
    opcodes on the single-threaded follower channel."""
    kind_id = _HEAD_IDS[kind]

    def step(padded):
        if in_mirrored_region():
            # every process is already running this same code in
            # lockstep — no message, the batch is identical everywhere
            # (uncommitted/local inputs are treated as replicated); on
            # the leader, ctx.lock is already held by leader_generate
            return jax.tree.map(lambda l: np.asarray(l), jitted(padded))
        x = np.asarray(padded, np.float32)
        if x.ndim != 2:
            raise ValueError(
                "multihost serving scores raw [B, d] embedding batches "
                f"(embed_fn=None engines); got shape {x.shape}")
        with ctx.lock:
            ctx.channel.send(
                _header(OP_SCORE, kind_id, x.shape[0], x.shape[1]), x)
            out = jitted(x)
            # materialize INSIDE the lock: the next opcode must not be
            # sent until this SPMD program has fully dispatched
            return jax.tree.map(lambda l: np.asarray(l), out)

    return step


def leader_generate(ctx: MultihostContext, decoder, prompt, steps: int,
                    head: str):
    """Blocking decode on the whole fleet: ship the session block, then
    run the same deterministic ``generate`` everywhere (followers pick
    it up via OP_DECODE in :func:`follower_loop`)."""
    prompt = np.asarray(prompt, np.int32)
    with ctx.lock:
        ctx.channel.send(
            _header(OP_DECODE, _HEAD_IDS[head], prompt.shape[0],
                    prompt.shape[1]),
            np.asarray([steps], np.int32), prompt)
        # hold the lock across the mirrored generate too: its fused
        # decode steps run fleet-wide collectives, so another leader
        # thread sending OP_SCORE mid-decode would interleave
        # collective programs across processes
        with mirrored_region():
            return decoder.generate(prompt, steps=steps, head=head)


def leader_swap_index(ctx: MultihostContext, engine, index) -> int:
    """Fleet-wide online index swap (``Engine.swap_index`` routes here
    on the leader).  Two-phase over the opcode channel: ship the
    hyperplanes, then a commit flag — followers rebuild the index
    deterministically from theta against their own weights (bit-identical
    by ``build_index`` determinism, no bucket arrays shipped) and flip
    only on commit=1.  If the leader dies between payload and commit
    (the ``multihost.swap_commit`` fault window), it sends commit=0 on
    the way out and EVERY process stays on the serving epoch — a swap
    is all-or-nothing, never split-brain.

    Holding ``ctx.lock`` across the whole sequence keeps the swap's
    message pair from interleaving with a score/decode opcode, which
    also means no score step can run BETWEEN a follower's flip and the
    leader's — the fleet is epoch-consistent at every opcode boundary."""
    from repro.testing import faults
    theta = np.asarray(index.theta, np.float32)
    with ctx.lock:
        ctx.channel.send(
            _header(OP_SWAP_INDEX, 0, theta.shape[0], theta.shape[1]),
            theta)
        try:
            faults.fire(faults.MULTIHOST_SWAP_COMMIT)
            ctx.channel.send(np.asarray([1], np.int32))
        except BaseException:
            # abort: tell the fleet to discard the payload and stay on
            # the old epoch, then surface the failure to the refresher
            ctx.channel.send(np.asarray([0], np.int32))
            raise
        # leader flips INSIDE the lock (the channel lock is the outer
        # half of the swap's channel->engine order anyway): the next
        # opcode can only be sent after both sides flipped
        return engine._swap_prepared(engine.prepare_epoch(index))


def stop_followers(ctx: MultihostContext) -> None:
    """Leader: release every follower_loop (call once, when done)."""
    with ctx.lock:
        ctx.channel.send(_header(OP_STOP, 0, 0, 0))


def follower_loop(engine, ctx: MultihostContext, decoder=None,
                  max_ops: int | None = None) -> int:
    """Run on every non-leader process: replay the leader's opcode
    stream — entering the same jitted steps with the same replicated
    payloads — until OP_STOP (or ``max_ops``).  Returns ops executed.

    The engine (and decoder, when decode traffic is expected) must be
    constructed identically to the leader's — same weights, same fitted
    index — which deterministic seeds give for free; the index stack
    itself is assembled from LOCAL shards, so "identical" never means
    shipping the full [m, d] weight anywhere.
    """
    if ctx.is_leader:
        raise RuntimeError("follower_loop on the leader would deadlock "
                           "waiting for its own opcode")
    n_ops = 0
    while max_ops is None or n_ops < max_ops:
        msg = ctx.channel.recv(timeout_s=None)
        op, kind_id, rows, dim = (int(v) for v in msg[0])
        if op == OP_STOP:
            break
        n_ops += 1
        kind = _ID_HEADS[kind_id]
        if op == OP_SCORE:
            out = engine._step(kind, rows)(msg[1])
            jax.block_until_ready(out.logits)
        elif op == OP_DECODE:
            steps, prompt = int(msg[1][0]), msg[2]
            if decoder is None:
                raise RuntimeError("OP_DECODE received but follower has "
                                   "no decoder to mirror generate on")
            with mirrored_region():
                decoder.generate(prompt, steps=steps, head=kind)
        elif op == OP_SWAP_INDEX:
            theta = msg[1]
            commit = int(ctx.channel.recv(timeout_s=None)[0][0])
            if commit:
                engine.swap_from_theta(theta)
            # commit=0: leader aborted mid-swap — drop theta, keep
            # serving the current epoch (graceful degradation)
        else:
            raise RuntimeError(f"unknown multihost opcode {op}")
    return n_ops
