"""Multi-host SPMD serving: process 0 owns admission, followers mirror.

Topology (see ``compat.make_global_mesh``): one global (host, model)
mesh — rows are processes, columns each process's local devices — so a
stacked shard pytree sharded ``P(("host", "model"))`` puts contiguous
vocab-shard blocks on each host, exactly what the hierarchical top-k
merge's global-id offset math assumes.  Every process builds ONLY the
shards it addresses (``heads.shard_index(..., shard_range=...)``) and
:func:`assemble_global_stack` stitches the local stacks into global
arrays without any process materializing remote shards.

Control plane: the AsyncRuntime, the admission queue, deadlines, and
result futures live on process 0 only.  The jitted score steps are SPMD
collective programs, so before the leader runs one, every follower must
enter the same program with the same replicated batch.  The seam is
``Engine._step`` — the ONE choke point both ``Engine.rank``/``flush``
and the AsyncRuntime dispatcher fetch steps from — which on the leader
returns a :func:`make_leader_step` wrapper that first broadcasts a
fixed [4]-int32 header ``(opcode, head, rows, dim)`` and then the
padded batch; followers sit in :func:`follower_loop` replaying the
opcode stream until ``OP_STOP``.  The follower side of the channel is
a single thread, so every leader-side broadcast sequence holds
``MultihostContext.lock`` end to end (header + payload + step) —
without it two leader threads (the AsyncRuntime dispatcher and, say,
the RecallAuditor's background ``rank(head="full")``) could interleave
their header/payload pairs and desync the whole fleet.

Decode rides the same opcode channel at session granularity:
``OP_DECODE`` broadcasts the prompt block once, then EVERY process runs
the same deterministic blocking ``LMDecoder.generate`` — the fused
decode steps (which embed the multihost head's collectives) execute in
lockstep without per-token broadcasts, because blocking generate has no
wall-clock-dependent control flow.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.utils import compat

__all__ = ["MultihostContext", "init_multihost", "assemble_global_stack",
           "make_leader_step", "leader_generate", "follower_loop",
           "stop_followers", "mirrored_region", "in_mirrored_region",
           "OP_STOP", "OP_SCORE", "OP_DECODE"]

OP_STOP, OP_SCORE, OP_DECODE = 0, 1, 2
_HEADER_LEN = 4
_HEAD_IDS = {"full": 0, "lss": 1, "lss-sharded": 2}
_ID_HEADS = {v: k for k, v in _HEAD_IDS.items()}


@dataclasses.dataclass(frozen=True)
class MultihostContext:
    """The fleet's shape, shared by engine, launcher, and bench.

    ``lock`` serializes the leader's opcode channel: the single-threaded
    ``follower_loop`` pairs each header with the payload that follows
    it, so a leader-side broadcast sequence must never interleave with
    another thread's.  Reentrant, because a mirrored decode holds it
    across ``generate`` while the inner prefill re-enters the step
    wrapper on the same thread."""

    mesh: jax.sharding.Mesh
    host_axis: str = "host"
    model_axis: str = "model"
    lock: threading.RLock = dataclasses.field(
        default_factory=threading.RLock, repr=False, compare=False)

    @property
    def process_id(self) -> int:
        return compat.process_index()

    @property
    def n_processes(self) -> int:
        return int(self.mesh.shape[self.host_axis])

    @property
    def shards_per_host(self) -> int:
        return int(self.mesh.shape[self.model_axis])

    @property
    def n_shards(self) -> int:
        return self.n_processes * self.shards_per_host

    @property
    def is_leader(self) -> bool:
        return self.process_id == 0

    def shard_range(self) -> tuple[int, int]:
        """[lo, hi) shard ids this process addresses (host-contiguous)."""
        tpl = self.shards_per_host
        return self.process_id * tpl, (self.process_id + 1) * tpl

    def row_range(self, m: int) -> tuple[int, int]:
        """Global weight rows [r0, r1) this process's shards cover for a
        vocab of m — the ONLY rows it needs to hold."""
        m_local = -(-m // self.n_shards)
        lo, hi = self.shard_range()
        return lo * m_local, min(hi * m_local, m)

    def stack_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh,
                             P((self.host_axis, self.model_axis)))


def init_multihost(coordinator: str | None = None,
                   num_processes: int | None = None,
                   process_id: int | None = None, *,
                   host_axis: str = "host", model_axis: str = "model"
                   ) -> MultihostContext | None:
    """Initialize ``jax.distributed`` (CPU collectives included; see
    ``compat.distributed_initialize`` — args default to the
    ``REPRO_DIST_COORDINATOR``-family env vars) and build the global
    serving mesh.
    Returns None in the single-process case: callers branch once and the
    whole single-host path stays untouched."""
    if not compat.distributed_initialize(coordinator, num_processes,
                                         process_id):
        return None
    mesh = compat.make_global_mesh((host_axis, model_axis))
    return MultihostContext(mesh, host_axis, model_axis)


def assemble_global_stack(ctx: MultihostContext, local_tree, n_shards: int):
    """Stitch each process's locally built shard stack (leading dim =
    shards_per_host) into global [n_shards, ...] arrays sharded over
    (host, model) — metadata only, no cross-process copies."""
    sharding = ctx.stack_sharding()

    def leaf(x):
        x = np.asarray(x)
        return compat.make_global_array(sharding, x,
                                        (n_shards,) + x.shape[1:])

    return jax.tree.map(leaf, local_tree)


# ------------------------------------------------------ opcode channel --
_MIRROR = threading.local()


def in_mirrored_region() -> bool:
    return getattr(_MIRROR, "depth", 0) > 0


@contextlib.contextmanager
def mirrored_region():
    """Marks a region EVERY process executes in lockstep (mirrored
    decode): inside it the leader's broadcast step wrapper stands down —
    nobody is waiting on the opcode channel, because the followers are
    running this very region themselves.  Without this, the decode
    prefill's ``engine.rank`` on the leader would broadcast OP_SCORE at
    a follower that is inside its own mirrored ``generate`` — a
    deadlock."""
    _MIRROR.depth = getattr(_MIRROR, "depth", 0) + 1
    try:
        yield
    finally:
        _MIRROR.depth -= 1


def _bcast(arr: np.ndarray) -> np.ndarray:
    return np.asarray(compat.broadcast_one_to_all(np.asarray(arr)))


def _bcast_header(vals=None) -> np.ndarray:
    if vals is None:                       # follower: receive
        vals = np.zeros((_HEADER_LEN,), np.int32)
    return _bcast(np.asarray(vals, np.int32))


def make_leader_step(ctx: MultihostContext, jitted, kind: str,
                     bucket: int):
    """Wrap a jitted score step for the leader: broadcast the opcode +
    replicated batch so every follower enters the same collective
    program, run it, and hand back HOST results (numpy) — the engine's
    slicing/metrics must not launch new device programs on global
    arrays outside the SPMD seam.  The whole header+payload+step
    sequence runs under ``ctx.lock`` so concurrent leader threads (the
    AsyncRuntime dispatcher, the RecallAuditor, user threads) can never
    interleave broadcasts on the single-threaded follower channel."""
    kind_id = _HEAD_IDS[kind]

    def step(padded):
        if in_mirrored_region():
            # every process is already running this same code in
            # lockstep — no broadcast, the batch is identical everywhere
            # (uncommitted/local inputs are treated as replicated); on
            # the leader, ctx.lock is already held by leader_generate
            return jax.tree.map(lambda l: np.asarray(l), jitted(padded))
        x = np.asarray(padded, np.float32)
        if x.ndim != 2:
            raise ValueError(
                "multihost serving scores raw [B, d] embedding batches "
                f"(embed_fn=None engines); got shape {x.shape}")
        with ctx.lock:
            _bcast_header([OP_SCORE, kind_id, x.shape[0], x.shape[1]])
            q = compat.broadcast_one_to_all(x)
            out = jitted(q)
            # materialize INSIDE the lock: the next opcode must not be
            # broadcast until this SPMD program has fully dispatched
            return jax.tree.map(lambda l: np.asarray(l), out)

    return step


def leader_generate(ctx: MultihostContext, decoder, prompt, steps: int,
                    head: str):
    """Blocking decode on the whole fleet: broadcast the session block,
    then run the same deterministic ``generate`` everywhere (followers
    pick it up via OP_DECODE in :func:`follower_loop`)."""
    prompt = np.asarray(prompt, np.int32)
    with ctx.lock:
        _bcast_header([OP_DECODE, _HEAD_IDS[head], prompt.shape[0],
                       prompt.shape[1]])
        _bcast(np.asarray([steps], np.int32))
        _bcast(prompt)
        # hold the lock across the mirrored generate too: its fused
        # decode steps run fleet-wide collectives, so another leader
        # thread broadcasting OP_SCORE mid-decode would interleave
        # collective programs across processes
        with mirrored_region():
            return decoder.generate(prompt, steps=steps, head=head)


def stop_followers(ctx: MultihostContext) -> None:
    """Leader: release every follower_loop (call once, when done)."""
    with ctx.lock:
        _bcast_header([OP_STOP, 0, 0, 0])


def follower_loop(engine, ctx: MultihostContext, decoder=None,
                  max_ops: int | None = None) -> int:
    """Run on every non-leader process: replay the leader's opcode
    stream — entering the same jitted steps with the same replicated
    payloads — until OP_STOP (or ``max_ops``).  Returns ops executed.

    The engine (and decoder, when decode traffic is expected) must be
    constructed identically to the leader's — same weights, same fitted
    index — which deterministic seeds give for free; the index stack
    itself is assembled from LOCAL shards, so "identical" never means
    shipping the full [m, d] weight anywhere.
    """
    if ctx.is_leader:
        raise RuntimeError("follower_loop on the leader would deadlock "
                           "waiting for its own broadcast")
    n_ops = 0
    while max_ops is None or n_ops < max_ops:
        op, kind_id, rows, dim = (int(v) for v in _bcast_header())
        if op == OP_STOP:
            break
        n_ops += 1
        kind = _ID_HEADS[kind_id]
        if op == OP_SCORE:
            q = compat.broadcast_one_to_all(
                np.zeros((rows, dim), np.float32))
            out = engine._step(kind, rows)(q)
            jax.block_until_ready(out.logits)
        elif op == OP_DECODE:
            steps = int(_bcast(np.zeros((1,), np.int32))[0])
            prompt = _bcast(np.zeros((rows, dim), np.int32))
            if decoder is None:
                raise RuntimeError("OP_DECODE received but follower has "
                                   "no decoder to mirror generate on")
            with mirrored_region():
                decoder.generate(prompt, steps=steps, head=kind)
        else:
            raise RuntimeError(f"unknown multihost opcode {op}")
    return n_ops
