"""Continuous-batching decode scheduler.

The blocking ``LMDecoder.generate`` loop ran ONE prompt group to
completion before touching the next — the WOL head (where the paper's
LSS win lives) saw exactly one query batch per token step, and every new
prompt paid the whole loop again.  The scheduler inverts that: sessions
JOIN a slot in a fixed-shape KV pool after prefill and LEAVE on EOS or
token budget, and every step runs ONE fused program over all
``max_streams`` slots::

    decode_step_pooled (per-row cache lengths)
        -> Engine head (full | lss | lss-sharded, kernel-registry
           dispatched)                                  [one jax.jit]
        -> next-token feedback  (tokens stay ON DEVICE)

Because the step shape never changes, the program compiles once per
(head, pool) no matter how sessions come and go — the Engine caches it
in the same jitted-step table as the score-path buckets (see
``Engine.decode_logits``), so trace counts stay observable.

Overlap: the scheduler is software-pipelined one step deep.  ``tick()``
dispatches step k (async jax dispatch; the next-token output feeds the
next step device-to-device, so the chain never waits on the host) and
THEN materializes step k-1's tokens, resolves the per-token streams, and
retires finished sessions.  The host-side gather/scatter for step k+1
(joins, length bumps, stream resolution) thus runs while the device
executes step k.  The one-step lag means a session discovered finished
at step k-1 still occupied its row during step k — that wasted row is
discarded, never emitted, and row-parallelism keeps it from perturbing
live rows.

Token-exactness: row i of the fused step computes exactly what a
single-stream run computes at the same pool shape, so interleaved decode
is bit-identical to sequential ``LMDecoder.generate`` calls on the same
decoder (asserted in tests/test_decode_stream.py, full AND lss heads).
"""

from __future__ import annotations

import functools
import math
import threading
import time
from collections import OrderedDict, deque
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.serve.decode.kv_pool import KVCachePool, KVPoolExhaustedError
from repro.serve.decode.sessions import DecodeSession, TokenStream
from repro.serve.runtime.future import DeadlineExceededError

__all__ = ["DecodeScheduler", "DecodeStats"]


# trace-time prefill compile counter, keyed (cfg.name, bucket) — the
# observable that proves bucketing works: O(log max_len) entries per cfg,
# not O(distinct prompt lengths).  Module-level because _prefill_jit's
# cache is module-level (shared across schedulers); the lock serializes
# the read-modify-write against OTHER schedulers' tick threads (tick
# serialization is per-scheduler) and the stats() iteration.
_PREFILL_COMPILES: dict[tuple, int] = {}
_PREFILL_LOCK = threading.Lock()

_MIN_PREFILL_BUCKET = 8


def _prefill_bucket(plen: int) -> int:
    """Power-of-two prefill bucket for a prompt length (floor 8).

    The bucket is BOTH the compile shape and a numeric shape: prefill's
    attention reduces over the padded width, and XLA:CPU reductions are
    not shape-invariant at the ulp level — so prompt KV is only
    bit-reproducible within one bucket, and every prefix-cache key
    includes it.  Causal masking makes end-padding exact: position i
    attends only to j <= i, so the pad tail cannot perturb real rows.
    """
    return max(_MIN_PREFILL_BUCKET, 1 << max(plen - 1, 0).bit_length())


@functools.partial(jax.jit, static_argnames=("cfg", "max_len"))
def _prefill_jit(params, prompt, cfg, max_len):
    """Jitted prefill, shared across schedulers (cached per cfg + padded
    prompt bucket).  Eager prefill measured ~500 ms/session on CPU for a
    tiny 2-layer model — pure op-dispatch overhead that would dwarf every
    decode step; one compile per power-of-two bucket removes it."""
    from repro.models import transformer as T
    key = (cfg.name, max_len)                     # trace-time side effect:
    with _PREFILL_LOCK:
        _PREFILL_COMPILES[key] = _PREFILL_COMPILES.get(key, 0) + 1
    return T.prefill(params, prompt, cfg, max_len=max_len)


@jax.jit
def _set_tok(tok, slot, t):
    return tok.at[slot].set(t)


class DecodeStats(NamedTuple):
    """Point-in-time snapshot of the scheduler's serving behaviour."""

    n_sessions: int              # sessions handed to the scheduler
    n_finished: int              # completed (eos | max_tokens)
    n_shed_deadline: int         # shed while waiting for a slot
    n_tokens: int                # tokens emitted across all streams
    n_steps: int                 # fused decode steps dispatched
    slot_occupancy: float        # mean active/max_streams per step
    ttft_p50_ms: float           # submit -> first token (queue incl.)
    ttft_p95_ms: float
    ttft_p99_ms: float
    itl_p50_ms: float            # inter-token gap
    itl_p95_ms: float
    itl_p99_ms: float
    tokens_per_s: float          # n_tokens / (first submit -> last token)
    wall_s: float
    # appended with defaults so positional consumers of the original 14
    # fields keep working
    n_prefill_skipped: int = 0   # full-prompt prefix hits (no prefill run)
    n_prefill_compiles: int = 0  # prefill traces for this cfg (all buckets)
    n_prefill_buckets: int = 0   # distinct prefill buckets compiled
    prefix_hit_rate: float = math.nan   # shared / shareable prompt pages
    kv_pages_in_use: int = 0     # paged layout: pages referenced now
    kv_peak_pages: int = 0       # paged layout: high-water mark
    n_shed_kv_oom: int = 0       # sessions shed: paged arena exhausted


class _Inflight(NamedTuple):
    ho: object                   # HeadOutput of the dispatched step
    snapshot: list               # [(slot, session)] active at dispatch
    t0: float


class DecodeScheduler:
    """Session-based streaming decode over one Engine head.

    Args:
      engine: the serving Engine; supplies the head (ranked through the
        kernel registry) and caches the fused step + compile counts.
      params, cfg: the LM whose ``decode_step_pooled`` feeds the head.
      max_streams: pool slots == rows of the fused step (a compile
        shape).
      max_len: pool cache width; every session needs
        ``len(prompt) + max_new_tokens <= max_len``.
      head: head kind for ALL sessions of this scheduler (one fused
        program serves one head; build one scheduler per head kind).
      kv_layout, kv_page_tokens, kv_pages: KV storage knobs, forwarded to
        :class:`KVCachePool` (layout None resolves the ``kv_pool.layout``
        strategy / ``$REPRO_KV_LAYOUT``; the paged layout enables prefix
        caching and prefill skipping).

    Threading: ``submit``/``add_session`` may be called from any thread;
    ``tick``/``run`` must be driven by ONE thread at a time (the
    AsyncRuntime's dispatcher, or the caller for standalone use).
    """

    def __init__(self, engine, params: dict, cfg, *, max_streams: int = 8,
                 max_len: int = 256, head: str | None = None,
                 kv_layout: str | None = None,
                 kv_page_tokens: int | None = None,
                 kv_pages: int | None = None):
        self.engine = engine
        self.params = params
        self.cfg = cfg
        self.head = head or engine.default_head
        self.pool = KVCachePool(cfg, max_streams, max_len,
                                layout=kv_layout,
                                page_tokens=kv_page_tokens,
                                n_pages=kv_pages)
        self.max_streams = int(max_streams)
        self.max_len = int(max_len)
        self.tok = jnp.zeros((max_streams,), jnp.int32)
        self.sessions: list[DecodeSession | None] = [None] * max_streams
        self._pending: deque[DecodeSession] = deque()
        self._inflight: _Inflight | None = None
        # names the fused step's compile shape in the engine's jitted-step
        # table; qualified by the model name so two schedulers over the
        # SAME engine with different model configs cannot collide on one
        # cached program.  The paged layout is a different program (page
        # gather + arena scatter), so it gets a distinct tag — the dense
        # tag is unchanged and stays the observable tests pin.
        if self.pool.layout == "paged":
            self._tag = (f"decode[{max_streams}x{max_len},"
                         f"paged{self.pool.page_tokens}]@{cfg.name}")
        else:
            self._tag = f"decode[{max_streams}x{max_len}]@{cfg.name}"
        # first-token memo for full-prompt prefix hits: (prompt bytes,
        # bucket) -> (head index object at compute time, tok0).  Keyed on
        # the index IDENTITY (not id() — addresses get reused) so an LSS
        # refit naturally invalidates; bounded LRU so pinned old indexes
        # cannot accumulate.
        self._tok0_cache: "OrderedDict[tuple, tuple]" = OrderedDict()
        self._tok0_cache_cap = 1024
        # index-epoch pin for the CURRENT generation: one fused program
        # serves every active slot, so the whole generation (first admit
        # until the pool drains) ranks through one engine epoch — an
        # online index swap changes what the NEXT generation pins, never
        # what in-flight sessions see.  Mutated only under _tick_lock.
        self._epoch: int | None = None
        self._lock = threading.Lock()
        # serializes tick(): a blocking generate() may drive the same
        # scheduler an AsyncRuntime dispatcher is ticking — two ticks
        # interleaving would tear pool/slot state, one at a time is safe
        self._tick_lock = threading.Lock()
        self._next_sid = 0
        # hook for the AsyncRuntime: called (session, reason) whenever a
        # session reaches a terminal state, from the tick thread
        self.on_session_done: Callable | None = None
        # stats (guarded by _lock)
        self._n_sessions = 0
        self._n_finished = 0
        self._n_shed_deadline = 0
        self._n_shed_kv_oom = 0
        self._n_tokens = 0
        self._n_steps = 0
        self._n_prefill_skipped = 0
        self._occupancy_sum = 0.0
        # bounded token-latency telemetry (was: unbounded TTFT/ITL lists)
        self.obs = obs.MetricsRegistry(scope_prefix="decode")
        self._h_ttft = self.obs.histogram(
            "decode_ttft_seconds", "submit -> first token, queue included")
        self._h_itl = self.obs.histogram(
            "decode_itl_seconds", "inter-token gap")
        self._t_first: float | None = None
        self._t_last: float | None = None

    # --------------------------------------------------------------- admit --
    def make_session(self, prompt, max_new_tokens: int, *,
                     eos_id: int | None = None,
                     t_submit: float | None = None,
                     deadline: float | None = None) -> DecodeSession:
        """Build (and validate) a session WITHOUT enqueueing it — the
        AsyncRuntime admits through its AdmissionQueue first.  Sessions
        only enter this scheduler's stats on ``add_session`` (actual
        admission), so runtime-refused sessions never skew the books."""
        prompt = np.asarray(prompt, np.int32)
        if prompt.ndim != 1:
            raise ValueError(f"prompt must be 1-D, got {prompt.shape}")
        if prompt.shape[0] + max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt ({prompt.shape[0]}) + max_new_tokens "
                f"({max_new_tokens}) exceeds the pool width {self.max_len}")
        with self._lock:
            sid = self._next_sid
            self._next_sid += 1
        return DecodeSession(sid, prompt, max_new_tokens, eos_id=eos_id,
                             t_submit=t_submit, deadline=deadline)

    def add_session(self, session: DecodeSession) -> None:
        with self._lock:
            self._n_sessions += 1
            if self._t_first is None:
                self._t_first = session.stream.t_submit
            self._pending.append(session)

    def submit(self, prompt, max_new_tokens: int, *,
               eos_id: int | None = None,
               deadline: float | None = None) -> TokenStream:
        """Standalone entry point: validate, enqueue, return the stream.
        (Through the AsyncRuntime use ``runtime.submit_decode`` instead —
        it applies queue-depth admission control.)"""
        s = self.make_session(prompt, max_new_tokens, eos_id=eos_id,
                              deadline=deadline)
        self.add_session(s)
        return s.stream

    # ---------------------------------------------------------------- state --
    @property
    def idle(self) -> bool:
        with self._lock:
            pending = bool(self._pending)
        return (not pending and self._inflight is None
                and self.pool.n_active == 0)

    # ----------------------------------------------------------------- tick --
    def tick(self) -> bool:
        """One scheduler iteration: admit waiting sessions to free slots,
        dispatch the next fused step, then resolve the PREVIOUS step's
        tokens (the overlap).  Returns True while there is work.

        Safe to drive from multiple threads (iterations serialize on an
        internal lock) — e.g. a blocking ``generate()`` call while an
        AsyncRuntime dispatcher owns the same scheduler.
        """
        with self._tick_lock:
            # only busy ticks get spans: the runtime dispatcher polls
            # tick() continuously, and idle polls are not work
            busy = (self._inflight is not None or self.pool.n_active > 0
                    or bool(self._pending))
            span = obs.start_span("tick") if busy else None
            self._admit()
            prev, self._inflight = self._inflight, self._dispatch()
            if prev is not None:
                self._collect(prev)
            if self._epoch is not None and self.idle:
                # generation drained: release the pinned index epoch so
                # a superseded index can be dropped (the next admit pins
                # whatever epoch is serving then)
                e, self._epoch = self._epoch, None
                self.engine.unpin_epoch(e)
            if span is not None:
                span.end("ok", dispatched=self._inflight is not None,
                         collected=prev is not None,
                         active=self.pool.n_active)
            return prev is not None or self._inflight is not None \
                or not self.idle

    def run(self, timeout: float | None = None,
            until: Callable[[], bool] | None = None) -> None:
        """Drive ``tick`` until every session has resolved — or, with
        ``until``, until that predicate holds (so a caller waiting on its
        OWN streams stops ticking once they finish instead of draining
        sessions other producers still have in flight)."""
        t_end = None if timeout is None else time.perf_counter() + timeout
        while not self.idle and not (until is not None and until()):
            self.tick()
            if t_end is not None and time.perf_counter() > t_end:
                raise TimeoutError(
                    f"scheduler not drained within {timeout}s "
                    f"({self.pool.n_active} active, "
                    f"{len(self._pending)} pending)")
        if until is not None and self.pool.n_active == 0:
            # an early exit leaves the final (wasted) step in flight; if
            # no other producer is active, nothing would ever collect it
            # and the scheduler would read busy forever — one more tick
            # drains it (dispatching nothing)
            self.tick()

    # ---------------------------------------------------------------- admit --
    def _admit(self) -> None:
        while self.pool.n_free:
            with self._lock:
                if not self._pending:
                    return
                sess = self._pending.popleft()
            now = time.perf_counter()
            if (sess.stream.deadline is not None
                    and now > sess.stream.deadline):
                # never executed: the slot-join analogue of the rank
                # path's shed-at-dispatch
                sess.finished = True
                sess.stream.fail(DeadlineExceededError(
                    f"decode session {sess.sid} exceeded its deadline by "
                    f"{(now - sess.stream.deadline) * 1e3:.1f} ms waiting "
                    f"for a slot"))
                self._done(sess, "shed_deadline")
                continue
            if self._epoch is None and self.head != "full":
                # first admit of a generation pins the serving epoch;
                # later joins inherit it so one fused program stays
                # consistent with every row's prefill ranking
                self._epoch = self.engine.pin_epoch()
            slot = self.pool.alloc()
            pspan = obs.start_span("prefill", sid=sess.sid, slot=slot,
                                   plen=int(sess.prompt.shape[0]))
            try:
                tok0 = self._prefill(slot, sess.prompt)
            except KVPoolExhaustedError as exc:
                # the join could not get pages (it unwound cleanly):
                # shed this one session, keep admitting/ticking the rest
                pspan.end_from_exc(exc)
                obs.event("shed_kv_oom", sid=sess.sid, at="join")
                self.pool.free(slot)
                sess.finished = True
                sess.stream.fail(exc)
                self._done(sess, "shed_kv_oom")
                continue
            pspan.end("ok")
            if sess.stream.span is not None:
                sess.stream.span.event("join", slot=slot)
            self.tok = _set_tok(self.tok, jnp.int32(slot),
                                jnp.int32(tok0))
            sess.slot = slot
            self.sessions[slot] = sess
            self._emit(sess, tok0, time.perf_counter())

    def _prefill(self, slot: int, prompt_np: np.ndarray) -> int:
        """Fill ``slot``'s KV for a prompt and return its first token.

        Fast path: with the paged layout, a prompt whose every page is
        already in the pool's prefix cache joins straight from cached
        pages AND reuses the memoized first token — no prefill, no head
        ranking (``n_prefill_skipped``).  The memo is keyed on the
        prompt+bucket and on the engine's index object identity, so an
        LSS refit invalidates it.

        Slow path: pad the prompt to its power-of-two bucket (one prefill
        compile per bucket, not per length; causal masking keeps real
        rows exact), join the KV sliced to the pool width, and rank the
        last REAL row's hidden state through the same bucket-1 head step
        the blocking loop uses.
        """
        plen = int(prompt_np.shape[0])
        bucket = _prefill_bucket(plen)
        key = (prompt_np.tobytes(), bucket)
        idx = (self.engine.index if self._epoch is None
               else self.engine.index_for(self._epoch))
        memo = self._tok0_cache.get(key)
        if memo is not None and memo[0] is idx \
                and self.pool.join_from_cache(slot, prompt_np, plen,
                                              bucket):
            self._tok0_cache.move_to_end(key)
            with self._lock:
                self._n_prefill_skipped += 1
            obs.event("prefill_skip", plen=plen, bucket=bucket)
            return memo[1]
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :plen] = prompt_np
        hidden, cache = _prefill_jit(self.params, jnp.asarray(padded),
                                     self.cfg, bucket)
        k_new, v_new = cache.k, cache.v
        if bucket > self.max_len:                 # pool never reads past
            k_new = k_new[:, :, :self.max_len]    # its own width
            v_new = v_new[:, :, :self.max_len]
        self.pool.join(slot, k_new, v_new, plen, prompt=prompt_np,
                       bucket=bucket)
        ho = self.engine.rank(hidden[:, plen - 1].astype(jnp.float32),
                              head=self.head, record=False,
                              epoch=self._epoch)
        tok0 = max(int(np.asarray(ho.ids)[0, 0]), 0)
        self._tok0_cache[key] = (idx, tok0)
        if len(self._tok0_cache) > self._tok0_cache_cap:
            self._tok0_cache.popitem(last=False)
        return tok0

    # -------------------------------------------------------------- dispatch --
    @functools.cached_property
    def _body(self):
        """The model half of the fused step, layout-resolved.
        Deliberately closes over ONLY ``cfg`` (plus the pool's view
        width for the paged gather) — the engine caches the jitted step
        whose closure holds this body, and capturing ``self`` would pin
        the whole scheduler (and its KV-pool slabs) in the engine's step
        table past this scheduler's lifetime."""
        cfg = self.cfg
        if self.pool.layout == "paged":
            max_len = self.max_len

            def body(params, tok, k, v, page_table, lengths):
                from repro.models import transformer as T
                return T.decode_step_paged(params, tok, k, v, page_table,
                                           lengths, cfg, max_len)
        else:
            def body(params, tok, k, v, lengths):
                from repro.models import transformer as T
                return T.decode_step_pooled(params, tok, k, v, lengths, cfg)

        return body

    def _dispatch(self) -> _Inflight | None:
        active = [i for i, s in enumerate(self.sessions) if s is not None]
        if not active:
            return None
        step = self.engine.decode_logits(self.head, self._tag, self._body,
                                         epoch=self._epoch)
        t0 = time.perf_counter()
        tok_next, ho, k_new, v_new = step(
            self.params, self.tok, *self.pool.step_operands())
        self.tok = tok_next                      # device-to-device feedback
        self.pool.k, self.pool.v = k_new, v_new
        # snapshot BEFORE any oom shed below nulls a slot: collect skips
        # finished sessions by flag, not by table lookup
        snapshot = [(i, self.sessions[i]) for i in active]
        for s in self.pool.advance(active):
            # this row crossed a page boundary and the arena had nothing
            # left: shed THIS session (its next step would read scratch
            # zeros past the boundary) and keep the rest of the batch
            # alive.  Freeing the slot mid-flight is the standard retire
            # pattern — collect skips finished sessions, and the freed
            # row's lagged write lands on the scratch page.
            self._shed_oom(self.sessions[s])
        with self._lock:
            self._n_steps += 1
            self._occupancy_sum += len(active) / self.max_streams
        return _Inflight(ho, snapshot, t0)

    # --------------------------------------------------------------- collect --
    def _collect(self, item: _Inflight) -> None:
        ids = np.asarray(item.ho.ids)            # blocks until step done
        t1 = time.perf_counter()
        for slot, sess in item.snapshot:
            if sess.finished:                    # retired after dispatch:
                continue                         # a wasted row, not a token
            self._emit(sess, max(int(ids[slot, 0]), 0), t1)

    def _emit(self, sess: DecodeSession, tok: int, t: float) -> None:
        sess.stream.append(tok, t)
        sess.n_emitted += 1
        with self._lock:
            self._n_tokens += 1
            self._t_last = t
        if sess.eos_id is not None and tok == sess.eos_id:
            self._finish(sess, "eos")
        elif sess.n_emitted >= sess.max_new_tokens:
            self._finish(sess, "max_tokens")

    def _finish(self, sess: DecodeSession, reason: str) -> None:
        sess.finished = True
        sess.stream.finish(reason)
        if sess.slot is not None:
            self.sessions[sess.slot] = None
            self.pool.free(sess.slot)
        ttft = sess.stream.ttft_s()
        if ttft is not None:
            self._h_ttft.record(ttft)
        for gap in sess.stream.inter_token_s():
            self._h_itl.record(gap)
        self._done(sess, reason)

    def _shed_oom(self, sess: DecodeSession | None) -> None:
        """Retire ONE session whose row the paged arena could no longer
        grow (see ``_dispatch``): fail its stream, free its slot, and
        let the rest of the batch keep decoding."""
        if sess is None or sess.finished:
            return
        sess.finished = True
        obs.event("shed_kv_oom", sid=sess.sid, at="page_boundary")
        sess.stream.fail(KVPoolExhaustedError(
            f"decode session {sess.sid} shed at a page boundary: the "
            f"paged KV arena has no free page (size n_pages for the "
            f"working set, or admit fewer concurrent sessions)"))
        self.sessions[sess.slot] = None
        self.pool.free(sess.slot)
        self._done(sess, "shed_kv_oom")

    def _done(self, sess: DecodeSession, reason: str) -> None:
        with self._lock:
            if reason == "shed_deadline":
                self._n_shed_deadline += 1
            elif reason == "shed_kv_oom":
                self._n_shed_kv_oom += 1
            else:
                self._n_finished += 1
        cb = self.on_session_done
        if cb is not None:
            cb(sess, reason)

    def fail_pending(self, exc: BaseException, *,
                     only: Callable | None = None) -> list[DecodeSession]:
        """Fail not-yet-joined sessions (runtime shutdown path).  With
        ``only``, fail just the sessions that predicate selects — a
        closing runtime must not kill sessions OTHER producers (e.g. a
        concurrent blocking generate()) still have queued."""
        with self._lock:
            if only is None:
                left, self._pending = list(self._pending), deque()
            else:
                left = [s for s in self._pending if only(s)]
                self._pending = deque(s for s in self._pending
                                      if not only(s))
        for sess in left:
            sess.finished = True
            sess.stream.fail(exc)
        return left

    def fail_all(self, exc: BaseException, *,
                 only: Callable | None = None) -> list[DecodeSession]:
        """Fail pending AND in-flight sessions (a ticker died and will
        never resolve them).  ``only`` scopes the kill to one producer's
        sessions; any surviving producer's own run() loop keeps ticking
        the rest, so the in-flight step is dropped only on a full
        (unfiltered) teardown."""
        failed = self.fail_pending(exc, only=only)
        with self._tick_lock:                  # a generate() may be mid-tick
            if only is None:
                self._inflight = None
            for slot, sess in enumerate(self.sessions):
                if sess is not None and (only is None or only(sess)):
                    sess.finished = True
                    sess.stream.fail(exc)
                    self.sessions[slot] = None
                    self.pool.free(slot)
                    failed.append(sess)
            if self._epoch is not None and only is None:
                e, self._epoch = self._epoch, None
                self.engine.unpin_epoch(e)
        return failed

    # ----------------------------------------------------------------- stats --
    def reset_stats(self) -> None:
        """Start a fresh stats window (counters, percentiles, and the
        wall-clock span all restart; in-flight sessions keep running).
        Call between measured segments — warmup traffic otherwise
        stretches ``wall_s`` and poisons ``tokens_per_s``."""
        with self._lock:
            self._n_sessions = 0
            self._n_finished = 0
            self._n_shed_deadline = 0
            self._n_shed_kv_oom = 0
            self._n_tokens = 0
            self._n_steps = 0
            self._n_prefill_skipped = 0
            self._occupancy_sum = 0.0
            self._h_ttft.reset()
            self._h_itl.reset()
            self._t_first = None
            self._t_last = None

    def stats(self) -> DecodeStats:
        with _PREFILL_LOCK:               # snapshot: another scheduler's
            prefill_compiles = list(_PREFILL_COMPILES.items())   # tick may
        # quantiles off the bounded reservoirs, OUTSIDE self._lock —
        # a stats() poll never stalls the tick thread
        ttft = tuple(v * 1e3 for v in self._h_ttft.quantile((50, 95, 99)))
        itl = tuple(v * 1e3 for v in self._h_itl.quantile((50, 95, 99)))
        with self._lock:                  # be tracing a new bucket
            wall = ((self._t_last - self._t_first)
                    if self._t_first is not None and self._t_last is not None
                    else 0.0)
            return DecodeStats(
                n_sessions=self._n_sessions,
                n_finished=self._n_finished,
                n_shed_deadline=self._n_shed_deadline,
                n_tokens=self._n_tokens,
                n_steps=self._n_steps,
                slot_occupancy=(self._occupancy_sum / self._n_steps
                                if self._n_steps else 0.0),
                ttft_p50_ms=ttft[0], ttft_p95_ms=ttft[1],
                ttft_p99_ms=ttft[2],
                itl_p50_ms=itl[0], itl_p95_ms=itl[1], itl_p99_ms=itl[2],
                tokens_per_s=(self._n_tokens / wall if wall > 0 else 0.0),
                wall_s=wall,
                n_prefill_skipped=self._n_prefill_skipped,
                n_prefill_compiles=sum(
                    n for (name, _), n in prefill_compiles
                    if name == self.cfg.name),
                n_prefill_buckets=sum(
                    1 for (name, _), _n in prefill_compiles
                    if name == self.cfg.name),
                prefix_hit_rate=(
                    self.pool.prefix_hits
                    / (self.pool.prefix_hits + self.pool.prefix_misses)
                    if self.pool.layout == "paged"
                    and (self.pool.prefix_hits + self.pool.prefix_misses)
                    else math.nan),
                kv_pages_in_use=self.pool.pages_in_use,
                kv_peak_pages=self.pool.peak_pages_in_use,
                n_shed_kv_oom=self._n_shed_kv_oom,
            )
