"""KV-cache pool for continuous-batching decode: dense slabs or paged
storage behind one slot API.

Two storage layouts, selected by the ``kv_pool.layout`` registry strategy
(``REPRO_KV_LAYOUT`` = ``dense`` | ``paged``):

**dense** — the original fixed-shape slabs ``[n_layers, max_streams,
max_len, n_kv_heads, head_dim]``: every slot reserves ``max_len`` rows up
front, so capacity is ``max_streams`` regardless of how short sessions
actually are.

**paged** — one ``[n_layers, n_pages, page_tokens, KV, H]`` arena per
cache side plus a host-side ``[max_streams, pages_per_slot]`` page table:
sessions map fixed-size pages on demand (at join, and as decode crosses a
page boundary), so pool capacity becomes sessions-per-GB instead of
``max_streams × max_len``.  Page 0 is a reserved scratch page — it is
never allocated, unmapped page-table entries point at it, and in-flight
writes from parked rows land there, so a freed session's lagged step can
never corrupt a page that has been recycled to a new session.

On top of the page table the paged layout adds **prefix caching**:
prompt pages are content-addressed (key = the full token prefix the
page's KV depends on, plus the prefill bucket — KV is only bit-reproducible
within one prefill reduction shape), so sessions joining with an
identical prompt prefix share read-only pages, and an identical *full*
prompt lets the scheduler skip prefill entirely
(:meth:`KVCachePool.join_from_cache`).  Sharing is safe while the donor
still decodes because KV pages are append-only: a session only ever
writes at offsets >= its own prompt length, and the page a new session
must write into (the partial remainder page) is copy-on-write at join.
Cache-held pages persist after their sessions leave (the cache holds one
reference) and are evicted LRU under page pressure.

Token exactness: the paged decode step gathers each row's pages in order
into a contiguous ``[max_len]``-wide view (see
``models.transformer.decode_step_paged``), so the attention reduction has
the SAME shape and the SAME valid contents as the dense slab — masked
positions contribute exact zeros either way — making paged decode
bit-identical to dense (asserted in tests/test_paged_decode.py).

Slot state is split across the device/host boundary deliberately:

  * the slabs/arenas (``k``/``v``) live on device and flow functionally
    through the scheduler's fused step (step k+1 consumes step k's
    output, so a join scatter issued after step k's dispatch can never
    race it);
  * per-slot lengths, the page table, page refcounts, and the prefix
    cache live on the HOST (`numpy`) — they are scheduler control state,
    snapshotted (copied!) into device operands every step.

A freed dense slot is simply abandoned in place; a freed paged slot
releases its page references (pages return to the free list once neither
a session nor the prefix cache holds them).
"""

from __future__ import annotations

import os
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.kernels import registry

__all__ = ["KVCachePool", "KVPoolExhaustedError", "KV_LAYOUTS",
           "KV_LAYOUT_ENV", "KV_PAGE_ENV"]


class KVPoolExhaustedError(RuntimeError):
    """The paged arena has no free page and nothing is evictable: every
    page is referenced by a live session.  Raised by ``join`` /
    ``join_from_cache`` (which unwind to the pre-call state first) so
    the scheduler can shed the ONE session that could not get a page
    instead of tearing down the whole tick."""

KV_LAYOUTS = ("dense", "paged")
KV_LAYOUT_ENV = "REPRO_KV_LAYOUT"
KV_PAGE_ENV = "REPRO_KV_PAGE_TOKENS"
DEFAULT_PAGE_TOKENS = 128

# registry-style strategy knob: explicit arg > set_default_strategy /
# use_strategy("kv_pool.layout", ...) > $REPRO_KV_LAYOUT > dense
_layout_strategy = registry.kernel_strategy(
    "kv_pool.layout", KV_LAYOUTS, env_var=KV_LAYOUT_ENV)


@jax.jit
def _scatter_prefill(k, v, k_new, v_new, slot):
    """Write [L, 1, S, KV, H] prefill slabs into dense pool slot ``slot``.

    ``slot`` is a traced scalar so one compilation serves every slot (a
    python-int index would specialize and retrace per slot); jax caches
    one program per prompt width S.
    """
    start = (0, slot, 0, 0, 0)
    return (jax.lax.dynamic_update_slice(k, k_new.astype(k.dtype), start),
            jax.lax.dynamic_update_slice(v, v_new.astype(v.dtype), start))


@jax.jit
def _scatter_pages(k, v, k_new, v_new, page_ids):
    """Write a join's prefill KV into its freshly allocated arena pages.

    ``k``/``v`` [L, n_pages, p, KV, H]; ``k_new``/``v_new`` [L, 1, S, KV,
    H] (S <= pages_per_slot * p); ``page_ids`` [pages_per_slot] int32 —
    the destination page of each logical chunk, with 0 (the scratch page)
    for chunks that must NOT be written (prefix-cache hits sharing an
    existing page, and chunks past the prompt).  One fused scatter per
    join, compiled once per (arena shape, prefill width).
    """
    L_, _, p, kv_h, h = k.shape
    n_pp = page_ids.shape[0]
    w = n_pp * p

    def rows(x):
        x = x[:, 0]                                   # [L, S, KV, H]
        pad = w - x.shape[1]
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return x.reshape(L_, n_pp, p, kv_h, h)

    return (k.at[:, page_ids].set(rows(k_new).astype(k.dtype)),
            v.at[:, page_ids].set(rows(v_new).astype(v.dtype)))


@jax.jit
def _copy_page(k, v, src, dst):
    """Copy arena page ``src`` -> ``dst`` (both traced scalars): the
    copy-on-write step when a join reuses a cached remainder page it will
    subsequently decode into."""
    kp = jax.lax.dynamic_index_in_dim(k, src, axis=1, keepdims=True)
    vp = jax.lax.dynamic_index_in_dim(v, src, axis=1, keepdims=True)
    return (jax.lax.dynamic_update_slice_in_dim(k, kp, dst, axis=1),
            jax.lax.dynamic_update_slice_in_dim(v, vp, dst, axis=1))


class KVCachePool:
    """Slot accounting + KV storage (dense slabs or a paged arena).

    Args:
      cfg: the TransformerConfig whose decode this pool backs.
      max_streams: slot count == rows of the fused step (a compile shape).
      max_len: logical cache width every session sees (and the paged
        step's gathered-view width, so dense and paged reductions share
        one shape).
      dtype: cache dtype; defaults to ``cfg.dtype``.
      layout: ``dense`` | ``paged`` | None (resolve via the
        ``kv_pool.layout`` registry strategy / ``$REPRO_KV_LAYOUT``).
      page_tokens: paged layout page size; None reads
        ``$REPRO_KV_PAGE_TOKENS`` (default 128).
      n_pages: paged arena size INCLUDING the reserved scratch page;
        None sizes for dense parity (every slot can reach ``max_len``).
        Smaller values cap memory — sessions then share capacity: a
        join that cannot get a page raises :class:`KVPoolExhaustedError`
        (leaving the pool untouched), and ``advance`` reports the
        starved slots so the caller can shed just those sessions.
    """

    def __init__(self, cfg, max_streams: int, max_len: int, dtype=None, *,
                 layout: str | None = None, page_tokens: int | None = None,
                 n_pages: int | None = None):
        if max_streams < 1:
            raise ValueError(f"max_streams must be >= 1, got {max_streams}")
        if max_len < 2:
            raise ValueError(f"max_len must be >= 2, got {max_len}")
        self.cfg = cfg
        self.max_streams = int(max_streams)
        self.max_len = int(max_len)
        self.dtype = dtype or cfg.dtype
        self.layout = _layout_strategy.resolve(layout)
        self.lengths = np.zeros((max_streams,), np.int32)   # host mirror
        self._free = list(range(max_streams - 1, -1, -1))   # pop() -> slot 0
        if self.layout == "dense":
            shape = (cfg.n_layers, max_streams, max_len,
                     cfg.n_kv_heads, cfg.head_dim)
            self.k = jnp.zeros(shape, self.dtype)
            self.v = jnp.zeros(shape, self.dtype)
            return
        # ------------------------------------------------- paged layout --
        if page_tokens is None:
            page_tokens = int(os.environ.get(KV_PAGE_ENV)
                              or DEFAULT_PAGE_TOKENS)
        if page_tokens < 1:
            raise ValueError(f"page_tokens must be >= 1, got {page_tokens}")
        self.page_tokens = int(page_tokens)
        self.pages_per_slot = -(-self.max_len // self.page_tokens)  # ceil
        parity = 1 + self.max_streams * self.pages_per_slot
        self.n_pages = parity if n_pages is None else int(n_pages)
        if self.n_pages < 2:
            raise ValueError(f"n_pages must be >= 2 (page 0 is scratch), "
                             f"got {self.n_pages}")
        shape = (cfg.n_layers, self.n_pages, self.page_tokens,
                 cfg.n_kv_heads, cfg.head_dim)
        self.k = jnp.zeros(shape, self.dtype)
        self.v = jnp.zeros(shape, self.dtype)
        # host control state: 0 in the table = unmapped (scratch)
        self.page_table = np.zeros((max_streams, self.pages_per_slot),
                                   np.int32)
        self._free_pages = list(range(self.n_pages - 1, 0, -1))
        self._ref = np.zeros((self.n_pages,), np.int32)
        self._cache: dict = {}            # content key -> page id
        self._lru: OrderedDict = OrderedDict()   # content key -> None
        self.prefix_hits = 0              # pages reused via the cache
        self.prefix_misses = 0            # shareable pages not found
        self._peak_pages = 0

    # ------------------------------------------------------ slot account --
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_active(self) -> int:
        return self.max_streams - len(self._free)

    def alloc(self) -> int | None:
        """Claim a free slot (None when the pool is full)."""
        return self._free.pop() if self._free else None

    def free(self, slot: int) -> None:
        """Release a slot.  Raises ``ValueError`` on an out-of-range slot
        or a double free (a real error, not an ``assert`` that vanishes
        under ``python -O``)."""
        self._check_owned(slot, "free")
        if self.layout == "paged":
            row = self.page_table[slot]
            for pid in row[row > 0]:
                self._unref(int(pid))
            row[:] = 0
        self.lengths[slot] = 0
        self._free.append(slot)

    def _check_owned(self, slot, what: str) -> None:
        if not isinstance(slot, (int, np.integer)) \
                or not 0 <= slot < self.max_streams:
            raise ValueError(f"{what}: slot {slot!r} out of range "
                             f"[0, {self.max_streams})")
        if slot in self._free:
            raise ValueError(f"{what}: slot {slot} is not allocated "
                             f"(double free, or join before alloc)")

    # ------------------------------------------------------ page account --
    @property
    def pages_in_use(self) -> int:
        """Pages currently referenced (by sessions and/or the prefix
        cache); excludes the scratch page.  0 for the dense layout."""
        return 0 if self.layout == "dense" else int((self._ref > 0).sum())

    @property
    def peak_pages_in_use(self) -> int:
        return 0 if self.layout == "dense" else self._peak_pages

    @property
    def n_free_pages(self) -> int:
        return 0 if self.layout == "dense" else len(self._free_pages)

    def page_bytes(self) -> int:
        """Device bytes of ONE page (both cache sides, all layers)."""
        if self.layout == "dense":
            return 0
        itemsize = jnp.zeros((), self.dtype).itemsize
        return (2 * self.cfg.n_layers * self.page_tokens
                * self.cfg.n_kv_heads * self.cfg.head_dim * itemsize)

    def storage_bytes(self) -> int:
        """Total device bytes of the k+v storage."""
        itemsize = jnp.zeros((), self.dtype).itemsize
        return 2 * int(np.prod(self.k.shape)) * itemsize

    def _note_usage(self) -> None:
        used = int((self._ref > 0).sum())
        if used > self._peak_pages:
            self._peak_pages = used

    def _alloc_page(self) -> int:
        if not self._free_pages:
            self._evict()
        if not self._free_pages:
            raise KVPoolExhaustedError(
                f"paged KV pool exhausted: all {self.n_pages - 1} pages "
                f"are referenced by live sessions (size n_pages for the "
                f"working set, or admit fewer concurrent sessions)")
        pid = self._free_pages.pop()
        self._ref[pid] = 1
        obs.event("page_alloc", pid=pid, free=len(self._free_pages))
        return pid

    def _unref(self, pid: int) -> None:
        self._ref[pid] -= 1
        if self._ref[pid] == 0:
            self._free_pages.append(pid)

    def _evict(self) -> None:
        """Drop LRU prefix-cache entries whose page only the cache still
        holds, until at least one page is free (or nothing is evictable)."""
        for key in list(self._lru):
            pid = self._cache[key]
            if self._ref[pid] == 1:       # cache is the sole holder
                del self._cache[key]
                del self._lru[key]
                self._unref(pid)
                obs.event("evict", pid=pid)
                return
        # every cached page is also live in a session: nothing to evict

    def _register(self, key, pid: int) -> None:
        self._cache[key] = pid
        self._lru[key] = None
        self._ref[pid] += 1               # the cache's own hold
        self._note_usage()

    @staticmethod
    def _full_key(prompt: np.ndarray, bucket: int, j: int, p: int):
        # page j's KV depends on every token <= its last position AND the
        # prefill reduction width (the bucket): key both
        return ("full", int(bucket), j, prompt[:(j + 1) * p].tobytes())

    @staticmethod
    def _rem_key(prompt: np.ndarray, bucket: int, length: int):
        return ("rem", int(bucket), int(length), prompt[:length].tobytes())

    # ------------------------------------------------------- device side --
    def join(self, slot: int, k_new: jax.Array, v_new: jax.Array,
             length: int, *, prompt: np.ndarray | None = None,
             bucket: int = 0) -> None:
        """Write a session's [L, 1, S, KV, H] prefill into ``slot`` and
        set its valid length.  Issued AFTER the current step's dispatch,
        so data flow (the scatter consumes that step's output slabs)
        orders it behind any stale in-flight write to this slot.

        Paged layout: allocates the pages covering positions
        ``[0, length]`` (the last one is the session's write page),
        reusing prefix-cache pages for full prompt pages whose content
        key matches (``prompt`` + ``bucket`` enable the lookup), and
        registers fresh prompt pages for future sessions to share.
        """
        self._check_owned(slot, "join")
        if not 1 <= length <= self.max_len:
            raise ValueError(f"join: length {length} outside "
                             f"[1, {self.max_len}]")
        if self.layout == "dense":
            self.k, self.v = _scatter_prefill(self.k, self.v, k_new, v_new,
                                              jnp.int32(slot))
            self.lengths[slot] = length
            return
        p = self.page_tokens
        n_need = min(length // p + 1, self.pages_per_slot)
        n_full = 0 if prompt is None else min(length // p, n_need)
        # Phase 1 — secure every page BEFORE touching the table, cache,
        # or counters.  Cache hits are pinned (ref += 1) the moment they
        # are found: a later _alloc_page may _evict, and eviction takes
        # exactly the cache-sole-holder (ref == 1) pages, which a hit
        # whose donor already left would be.  On exhaustion, unwind the
        # pins/allocations and re-raise — the pool is exactly as it was.
        hit_ids: list = []                # (j, pid, key) shared pages
        new_ids: list = []                # (j, pid, key|None) fresh pages
        try:
            for j in range(n_need):
                if j < n_full:
                    key = self._full_key(prompt, bucket, j, p)
                    pid = self._cache.get(key)
                    if pid is not None:
                        self._ref[pid] += 1        # shared, read-only
                        hit_ids.append((j, pid, key))
                        continue
                    new_ids.append((j, self._alloc_page(), key))
                else:
                    key = None
                    if prompt is not None and j == n_need - 1 \
                            and length % p:
                        key = self._rem_key(prompt, bucket, length)
                    new_ids.append((j, self._alloc_page(), key))
        except KVPoolExhaustedError:
            for _, pid, _ in hit_ids + new_ids:
                self._unref(pid)
            raise
        # Phase 2 — infallible bookkeeping.
        row = self.page_table[slot]
        for pid in row[row > 0]:          # re-join: release any previous
            self._unref(int(pid))         # mapping
        row[:] = 0
        scatter_ids = np.zeros((self.pages_per_slot,), np.int32)
        for j, pid, key in hit_ids:
            row[j] = pid
            self._lru.move_to_end(key)
            self.prefix_hits += 1
        if hit_ids:
            obs.event("prefix_hit", slot=slot, pages=len(hit_ids))
        for j, pid, key in new_ids:
            row[j] = pid
            scatter_ids[j] = pid
            if key is None:
                continue
            if j < n_full:
                self.prefix_misses += 1
                self._register(key, pid)
            elif key not in self._cache:
                # the remainder page: prompt KV at offsets < length%p is
                # append-only (the session decodes at offsets >=
                # length%p), so registering the LIVE page is safe —
                # hitters copy-on-write before touching it.  Never
                # re-register an existing key: overwriting the cache
                # entry would strand the old page's cache reference.
                self._register(key, pid)
        self._note_usage()
        self.k, self.v = _scatter_pages(self.k, self.v, k_new, v_new,
                                        jnp.asarray(scatter_ids))
        self.lengths[slot] = length

    def join_from_cache(self, slot: int, prompt: np.ndarray, length: int,
                        bucket: int) -> bool:
        """Map ``slot`` entirely from cached prompt pages — the
        full-prompt prefix hit that lets the scheduler SKIP prefill.
        Returns False (mutating nothing) unless every page covering the
        prompt is cached: all full pages by content key, plus the
        remainder page (copied, since this session will write into it).
        Raises :class:`KVPoolExhaustedError` — also mutating nothing —
        when the copy-on-write page cannot be allocated.
        """
        if self.layout == "dense":
            return False
        self._check_owned(slot, "join_from_cache")
        if not 1 <= length <= self.max_len:
            raise ValueError(f"join_from_cache: length {length} outside "
                             f"[1, {self.max_len}]")
        p = self.page_tokens
        n_need = min(length // p + 1, self.pages_per_slot)
        n_full = min(length // p, n_need)
        keys = [self._full_key(prompt, bucket, j, p) for j in range(n_full)]
        rem_key = (self._rem_key(prompt, bucket, length)
                   if length % p and n_full < n_need else None)
        if rem_key is not None:
            keys.append(rem_key)
        if any(k not in self._cache for k in keys):
            return False
        # Pin every cached page BEFORE allocating the write page: the
        # COW _alloc_page may _evict, and eviction takes exactly the
        # cache-sole-holder (ref == 1) pages — with the donor session
        # gone, that includes the very pages this join is mapping (the
        # remainder page above all: evicting it would free the copy
        # source out from under _copy_page and drop rem_key from the
        # LRU mid-join).  ref >= 2 makes _evict skip them.  Nothing
        # else is mutated until the allocation succeeds, so an
        # exhaustion error unwinds to the pre-call state.
        pids = [self._cache[k] for k in keys]
        for pid in pids:
            self._ref[pid] += 1
        new_page = None
        if n_need > n_full:                   # the session's write page
            try:
                new_page = self._alloc_page()
            except KVPoolExhaustedError:
                for pid in pids:
                    self._unref(pid)
                raise
        row = self.page_table[slot]
        for pid in row[row > 0]:          # re-join: release any previous
            self._unref(int(pid))         # mapping
        row[:] = 0
        for j in range(n_full):           # the pin doubles as the
            row[j] = pids[j]              # session's own reference
            self._lru.move_to_end(keys[j])
        if rem_key is not None:
            src = pids[-1]                    # copy-on-write: new_page
            self.k, self.v = _copy_page(      # is the session's write page
                self.k, self.v, jnp.int32(src), jnp.int32(new_page))
            self._unref(src)                  # session holds the copy,
            row[n_full] = new_page            # not the cached original
            self._lru.move_to_end(rem_key)
            obs.event("cow", slot=slot, src=int(src), dst=int(new_page))
        elif n_need > n_full:                 # page-aligned prompt: the
            row[n_full] = new_page            # write page starts empty
        self.prefix_hits += len(keys)
        obs.event("prefix_hit", slot=slot, pages=len(keys), full=True)
        self._note_usage()
        self.lengths[slot] = length
        return True

    def advance(self, slots) -> list[int]:
        """The fused step wrote one KV per listed slot: bump lengths (and,
        paged, map the next page when a row crosses a page boundary).

        Returns the (possibly empty) list of slots that crossed a page
        boundary but could NOT get a page — the arena is exhausted for
        THEM, not for the batch, so exhaustion must not raise mid-loop
        (that would leave lengths inconsistent and fail every in-flight
        session).  Their lengths stay correct (the step's token was
        written into the still-mapped previous page) and their unmapped
        entry redirects future writes to the scratch page, but their
        attention would read scratch zeros past the boundary — the
        caller must retire them before they decode further."""
        oom: list[int] = []
        for s in slots:
            self.lengths[s] += 1
            if self.layout == "paged":
                j, off = divmod(int(self.lengths[s]), self.page_tokens)
                if off == 0 and j < self.pages_per_slot \
                        and self.page_table[s, j] == 0:
                    try:
                        self.page_table[s, j] = self._alloc_page()
                    except KVPoolExhaustedError:
                        oom.append(int(s))
                        continue
                    self._note_usage()
        return oom

    # ---------------------------------------------------- step operands --
    def lengths_device(self) -> jax.Array:
        """Snapshot the host lengths as the step's [max_streams] operand.

        MUST copy: on CPU ``jnp.asarray(numpy)`` can alias the numpy
        buffer zero-copy, and ``advance``/``free`` mutate ``lengths``
        while the previous step is still in flight — the alias made the
        step read torn lengths (observed as nondeterministically
        duplicated tokens).  The copy freezes the snapshot.
        """
        return jnp.asarray(self.lengths.copy())

    def page_table_device(self) -> jax.Array:
        """Snapshot the host page table as the paged step's
        [max_streams, pages_per_slot] operand (same copy rule as
        :meth:`lengths_device` — joins/frees mutate the table while the
        previous step is in flight)."""
        return jnp.asarray(self.page_table.copy())

    def step_operands(self) -> tuple:
        """The fused step's cache-state operands, layout-resolved: the
        scheduler dispatches ``step(params, tok, *pool.step_operands())``
        so join/leave and layout never change its call site."""
        if self.layout == "dense":
            return (self.k, self.v, self.lengths_device())
        return (self.k, self.v, self.page_table_device(),
                self.lengths_device())
