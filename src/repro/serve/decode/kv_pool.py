"""Slot-based KV-cache pool for continuous-batching decode.

One pool owns fixed-shape cache slabs ``[n_layers, max_streams, max_len,
n_kv_heads, head_dim]``.  Sessions JOIN a free slot after prefill (their
prefill KV is scattered into the slot's rows and the slot's length set to
the prompt length) and LEAVE on EOS / token budget, so the batch
composition changes continuously while every device program keeps the
same static shape — the property that makes "sessions come and go" cost
zero recompiles.

Slot state is split across the device/host boundary deliberately:

  * the slabs (``k``/``v``) live on device and flow functionally through
    the scheduler's fused step (step k+1 consumes step k's output slabs,
    so a join scatter issued after step k's dispatch can never race it);
  * per-slot lengths live on the HOST (`numpy`) — they are scheduler
    control state, read every step to build the [max_streams] lengths
    operand, and mutating them must not synchronize with the device.

A freed slot is simply abandoned in place: parked rows keep decoding
garbage at a frozen length (row-parallel math — they cannot disturb live
rows) and the next join's prefill scatter overwrites everything the new
session can see (positions >= its length are masked by attention).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["KVCachePool"]


@jax.jit
def _scatter_prefill(k, v, k_new, v_new, slot):
    """Write [L, 1, S, KV, H] prefill slabs into pool slot ``slot``.

    ``slot`` is a traced scalar so one compilation serves every slot (a
    python-int index would specialize and retrace per slot); jax caches
    one program per prompt length S.
    """
    start = (0, slot, 0, 0, 0)
    return (jax.lax.dynamic_update_slice(k, k_new.astype(k.dtype), start),
            jax.lax.dynamic_update_slice(v, v_new.astype(v.dtype), start))


class KVCachePool:
    """Fixed ``[L, max_streams, max_len, KV, H]`` cache slabs + slot
    accounting."""

    def __init__(self, cfg, max_streams: int, max_len: int, dtype=None):
        if max_streams < 1:
            raise ValueError(f"max_streams must be >= 1, got {max_streams}")
        if max_len < 2:
            raise ValueError(f"max_len must be >= 2, got {max_len}")
        self.cfg = cfg
        self.max_streams = int(max_streams)
        self.max_len = int(max_len)
        dt = dtype or cfg.dtype
        shape = (cfg.n_layers, max_streams, max_len,
                 cfg.n_kv_heads, cfg.head_dim)
        self.k = jnp.zeros(shape, dt)
        self.v = jnp.zeros(shape, dt)
        self.lengths = np.zeros((max_streams,), np.int32)   # host mirror
        self._free = list(range(max_streams - 1, -1, -1))   # pop() -> slot 0

    # ------------------------------------------------------ slot account --
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_active(self) -> int:
        return self.max_streams - len(self._free)

    def alloc(self) -> int | None:
        """Claim a free slot (None when the pool is full)."""
        return self._free.pop() if self._free else None

    def free(self, slot: int) -> None:
        assert 0 <= slot < self.max_streams and slot not in self._free, slot
        self.lengths[slot] = 0
        self._free.append(slot)

    # ------------------------------------------------------- device side --
    def join(self, slot: int, k_new: jax.Array, v_new: jax.Array,
             length: int) -> None:
        """Scatter a session's [L, 1, S, KV, H] prefill into ``slot`` and
        set its valid length.  Issued AFTER the current step's dispatch,
        so data flow (the scatter consumes that step's output slabs)
        orders it behind any stale in-flight write to this slot."""
        assert length <= self.max_len, (length, self.max_len)
        self.k, self.v = _scatter_prefill(self.k, self.v, k_new, v_new,
                                          jnp.int32(slot))
        self.lengths[slot] = length

    def advance(self, slots) -> None:
        """The fused step wrote one KV per listed slot: bump lengths."""
        for s in slots:
            self.lengths[s] += 1

    def lengths_device(self) -> jax.Array:
        """Snapshot the host lengths as the step's [max_streams] operand.

        MUST copy: on CPU ``jnp.asarray(numpy)`` can alias the numpy
        buffer zero-copy, and ``advance``/``free`` mutate ``lengths``
        while the previous step is still in flight — the alias made the
        step read torn lengths (observed as nondeterministically
        duplicated tokens).  The copy freezes the snapshot.
        """
        return jnp.asarray(self.lengths.copy())
