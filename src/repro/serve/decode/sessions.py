"""Decode sessions and per-token streaming futures.

A :class:`DecodeSession` is one in-flight generation request: a prompt,
greedy sampling bounds (``max_new_tokens``, optional ``eos_id``), and the
:class:`TokenStream` the scheduler resolves token by token.  The stream
is the decode-side analogue of ``runtime.future.RankFuture`` — but where
a rank request resolves ONCE, a decode session resolves ``max_new_tokens``
times, so the stream is a write-many/read-many object:

  * the producer (the :class:`~repro.serve.decode.DecodeScheduler`, or
    the shed path) calls ``append`` per token and ``finish``/``fail``
    exactly once;
  * consumers iterate tokens as they land (``for tok in stream``), poll
    (``get(i)``), or block for the whole sequence (``result()``);
  * per-token timestamps live on the stream, so time-to-first-token and
    inter-token latency are computed from the same object that carried
    the tokens — no side table.

Timing metadata (``t_submit``, ``deadline``) mirrors ``RankFuture`` so
the runtime's admission control (queue-full shed, deadline shed) applies
to decode sessions exactly as it does to scoring requests.
"""

from __future__ import annotations

import threading
import time

import numpy as np

__all__ = ["TokenStream", "DecodeSession", "FINISH_REASONS"]

#: Terminal states a stream can reach: ``eos`` (the session's eos_id was
#: produced), ``max_tokens`` (the token budget ran out), ``error`` (shed
#: or failed — ``exception()`` carries the reason).
FINISH_REASONS = ("eos", "max_tokens", "error")


class TokenStream:
    """Write-many future: one slot per generated token, resolved in order.

    Thread-safe: the scheduler appends from the dispatcher thread while
    any number of consumer threads iterate/wait.
    """

    def __init__(self, sid: int, t_submit: float | None = None,
                 deadline: float | None = None):
        self.sid = sid
        self.t_submit = (time.perf_counter() if t_submit is None
                         else t_submit)
        self.deadline = deadline          # absolute perf_counter, or None
        # observability span (set by the submitting front-end); closed
        # here at finish/fail so EVERY terminal path — eos, max_tokens,
        # deadline shed, shed_kv_oom, runtime close — closes it
        self.span = None
        self._tokens: list[int] = []
        self._times: list[float] = []     # perf_counter per appended token
        self._finish_reason: str | None = None
        self._exc: BaseException | None = None
        self._cond = threading.Condition()

    # -- producer side (scheduler / shed path) ----------------------------
    def append(self, token: int, t: float | None = None) -> None:
        with self._cond:
            assert self._finish_reason is None, \
                f"stream {self.sid} appended after finish"
            self._tokens.append(int(token))
            self._times.append(time.perf_counter() if t is None else t)
            first = len(self._tokens) == 1
            self._cond.notify_all()
        if first and self.span is not None:
            self.span.event("first_token")

    def finish(self, reason: str) -> None:
        assert reason in FINISH_REASONS, reason
        with self._cond:
            assert self._finish_reason is None, \
                f"stream {self.sid} finished twice"
            self._finish_reason = reason
            n = len(self._tokens)
            self._cond.notify_all()
        if self.span is not None:         # outside _cond: span lock is leaf
            self.span.end("ok", reason=reason, n_tokens=n)

    def fail(self, exc: BaseException) -> None:
        with self._cond:
            if self._finish_reason is not None:
                return                    # already terminal; keep tokens
            self._exc = exc
            self._finish_reason = "error"
            n = len(self._tokens)
            self._cond.notify_all()
        if self.span is not None:
            self.span.set(n_tokens=n)
            self.span.end_from_exc(exc)

    # -- consumer side -----------------------------------------------------
    def done(self) -> bool:
        with self._cond:
            return self._finish_reason is not None

    @property
    def finish_reason(self) -> str | None:
        with self._cond:
            return self._finish_reason

    def exception(self, timeout: float | None = None) -> BaseException | None:
        with self._cond:
            if not self._cond.wait_for(
                    lambda: self._finish_reason is not None, timeout):
                raise TimeoutError(f"stream {self.sid} not finished "
                                   f"within {timeout}s")
            return self._exc

    def __len__(self) -> int:
        with self._cond:
            return len(self._tokens)

    def get(self, i: int, timeout: float | None = None) -> int:
        """Block until token ``i`` exists (raises if the stream finishes
        first with fewer tokens, re-raising the failure reason if any)."""
        with self._cond:
            if not self._cond.wait_for(
                    lambda: len(self._tokens) > i
                    or self._finish_reason is not None, timeout):
                raise TimeoutError(f"stream {self.sid}: token {i} not "
                                   f"resolved within {timeout}s")
            if len(self._tokens) > i:
                return self._tokens[i]
            if self._exc is not None:
                raise self._exc
            raise IndexError(
                f"stream {self.sid} finished ({self._finish_reason}) "
                f"after {len(self._tokens)} tokens; no token {i}")

    def __iter__(self):
        """Yield tokens in order as they resolve; stops at finish.  A
        failed stream re-raises its reason after the tokens that did
        land."""
        i = 0
        while True:
            try:
                yield self.get(i)
            except IndexError:
                return
            i += 1

    def result(self, timeout: float | None = None) -> np.ndarray:
        """Block for the full sequence; int32 [n_tokens]."""
        with self._cond:
            if not self._cond.wait_for(
                    lambda: self._finish_reason is not None, timeout):
                raise TimeoutError(f"stream {self.sid} not finished "
                                   f"within {timeout}s")
            if self._exc is not None:
                raise self._exc
            return np.asarray(self._tokens, np.int32)

    def tokens_so_far(self) -> np.ndarray:
        with self._cond:
            return np.asarray(self._tokens, np.int32)

    # -- timing ------------------------------------------------------------
    def ttft_s(self) -> float | None:
        """Submit -> first token, or None before the first token."""
        with self._cond:
            if not self._times:
                return None
            return self._times[0] - self.t_submit

    def inter_token_s(self) -> np.ndarray:
        """Gaps between consecutive token arrivals ([n-1] float64)."""
        with self._cond:
            return np.diff(np.asarray(self._times, np.float64))

    def __repr__(self) -> str:            # pragma: no cover - debug aid
        with self._cond:
            state = self._finish_reason or "streaming"
            return (f"TokenStream(sid={self.sid}, n={len(self._tokens)}, "
                    f"{state})")


class DecodeSession:
    """One generation request moving through the scheduler.

    ``prompt`` is a 1-D int32 token array; the session emits up to
    ``max_new_tokens`` greedy tokens (the first comes from the prefill's
    final hidden state, the rest from pooled decode steps), stopping
    early when ``eos_id`` is produced.
    """

    __slots__ = ("sid", "prompt", "max_new_tokens", "eos_id", "stream",
                 "slot", "n_emitted", "finished", "owner")

    def __init__(self, sid: int, prompt, max_new_tokens: int,
                 eos_id: int | None = None,
                 t_submit: float | None = None,
                 deadline: float | None = None):
        prompt = np.asarray(prompt, np.int32)
        if prompt.ndim != 1 or prompt.shape[0] < 1:
            raise ValueError(
                f"prompt must be a non-empty 1-D token array, "
                f"got shape {prompt.shape}")
        if max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {max_new_tokens}")
        self.sid = sid
        self.prompt = prompt
        self.max_new_tokens = int(max_new_tokens)
        self.eos_id = None if eos_id is None else int(eos_id)
        self.stream = TokenStream(sid, t_submit=t_submit, deadline=deadline)
        self.slot: int | None = None
        self.n_emitted = 0
        self.finished = False
        # which front-end admitted the session (the AsyncRuntime tags
        # sessions it owns so its accounting ignores sessions other
        # producers — e.g. a concurrent blocking generate() — submit
        # to the same scheduler)
        self.owner: object | None = None
