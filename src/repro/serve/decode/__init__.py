"""Continuous-batching streaming decode.

  * ``sessions``  — :class:`DecodeSession` (one generation request) and
    :class:`TokenStream` (write-many per-token future with TTFT /
    inter-token timing).
  * ``kv_pool``   — :class:`KVCachePool`: KV storage behind one slot
    API, in two layouts (the ``kv_pool.layout`` strategy /
    ``$REPRO_KV_LAYOUT``): ``dense`` fixed ``[L, max_streams, max_len,
    KV, H]`` slabs, or ``paged`` — a ``[L, n_pages, page_tokens, KV,
    H]`` arena + host page tables (``$REPRO_KV_PAGE_TOKENS``), with
    refcounted prefix-shared prompt pages and copy-on-write at
    divergence.  Sessions join a free slot after prefill and leave on
    EOS / token budget, so batch composition changes with zero
    recompiles — in either layout.
  * ``scheduler`` — :class:`DecodeScheduler`: one fused
    ``decode_step_pooled | decode_step_paged -> Engine head`` program
    per step over all slots, software-pipelined one step deep,
    token-exact with the blocking per-stream loop (and across layouts).
    Prefill pads prompts to power-of-two buckets (compiles are O(log
    max_len), not O(distinct lengths)), and a fully prefix-cached prompt
    skips prefill outright.

Hangs behind :class:`repro.serve.AsyncRuntime` via ``submit_decode``
(admission queue, block|shed, deadlines) or runs standalone via
``DecodeScheduler.submit`` / ``run``.

Invariants:

* **Dispatch snapshots are copied.** ``_dispatch`` materialises the
  active ``[(slot, session)]`` list into the in-flight record instead
  of re-reading ``self.sessions`` at collect time: a session can retire
  (EOS / budget) and its slot be re-admitted by a NEW session while the
  step is still on device, and emitting that step's token to the new
  occupant would corrupt both streams.  Collect consults the copy and
  skips rows whose session finished in flight (a wasted row, never a
  wrong token).
* **The blocking facade shares the pooled step shape.** ``generate``
  submits into the same fixed ``max_streams``-row scheduler the
  streaming path uses because XLA's CPU gemm is NOT batch-shape
  invariant (ROADMAP "Standing constraints"): a dedicated
  ``[batch]``-shaped step would produce ulp-level different logits and
  break "blocking results are bit-identical to interleaved ones" — as
  well as double the compile cache.
* **Per-row lengths, one program.** Batch composition (joins/retires)
  only changes the ``lengths`` vector and the token rows, never a
  shape, so the fused step compiles once per (head, pool shape) and a
  slot join is O(prefill), not O(recompile).
* **The paged view is dense-width.** ``decode_step_paged`` gathers each
  row's pages into a contiguous view sliced to exactly ``max_len`` —
  the dense slab's shape — so both layouts run the same reduction over
  the same valid contents and paged decode is BIT-identical to dense
  (tests/test_paged_decode.py).  Page 0 of the arena is reserved
  scratch: unmapped table entries and suppressed writes (parked rows,
  rows at ``max_len``) land there, never in a recycled page.
"""

from repro.serve.decode.kv_pool import KVCachePool, KVPoolExhaustedError
from repro.serve.decode.scheduler import DecodeScheduler, DecodeStats
from repro.serve.decode.sessions import (FINISH_REASONS, DecodeSession,
                                         TokenStream)

__all__ = ["KVCachePool", "KVPoolExhaustedError", "DecodeScheduler",
           "DecodeStats", "DecodeSession", "TokenStream", "FINISH_REASONS"]
