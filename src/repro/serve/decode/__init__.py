"""Continuous-batching streaming decode.

  * ``sessions``  — :class:`DecodeSession` (one generation request) and
    :class:`TokenStream` (write-many per-token future with TTFT /
    inter-token timing).
  * ``kv_pool``   — :class:`KVCachePool`: fixed ``[L, max_streams,
    max_len, KV, H]`` cache slabs; sessions join a free slot after
    prefill and leave on EOS / token budget, so batch composition
    changes with zero recompiles.
  * ``scheduler`` — :class:`DecodeScheduler`: one fused
    ``decode_step_pooled -> Engine head`` program per step over all
    slots, software-pipelined one step deep, token-exact with the
    blocking per-stream loop.

Hangs behind :class:`repro.serve.AsyncRuntime` via ``submit_decode``
(admission queue, block|shed, deadlines) or runs standalone via
``DecodeScheduler.submit`` / ``run``.
"""

from repro.serve.decode.kv_pool import KVCachePool
from repro.serve.decode.scheduler import DecodeScheduler, DecodeStats
from repro.serve.decode.sessions import (FINISH_REASONS, DecodeSession,
                                         TokenStream)

__all__ = ["KVCachePool", "DecodeScheduler", "DecodeStats",
           "DecodeSession", "TokenStream", "FINISH_REASONS"]
