"""Unified batched serving engine for WOL inference.

One :class:`Engine` owns:

  * the frozen model body (``embed_fn``) and WOL parameters ``w, b``,
  * a fitted :class:`LSSIndex` (plus its vocab-sharded form, built lazily),
  * a pluggable head per request — ``full`` | ``lss`` | ``lss-sharded`` —
    shared by the score path (XC / recsys top-k) and the decode path
    (LM next-token), see ``serve.heads``,
  * a continuous micro-batcher that coalesces submitted requests into
    fixed bucketed batch shapes (``serve.batcher``) so arrival patterns
    never retrigger compilation: exactly one jitted step per
    (head, bucket) pair, trace counts exposed via ``compile_counts``,
  * first-class serving metrics — p50/p95/p99 latency, throughput, avg
    sample size, label recall — computed from the SAME retrieval pass
    that produced the ranking (no second ``retrieve`` call).

Request flow::

    engine.submit(x, labels=...)   # enqueue one example
    engine.flush()                 # coalesce -> bucketed jitted steps
    engine.metrics()               # ServeMetrics snapshot

``WOLServer`` and ``LMDecoder`` remain as thin compatibility wrappers.
"""

from __future__ import annotations

import contextlib
import math
import threading
import time
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import simhash
from repro.core.iul import fit_lss
from repro.core.lss import LSSConfig, LSSIndex, build_index
from repro.kernels import registry
from repro.serve.batcher import DEFAULT_BUCKETS, MicroBatcher
from repro.serve.heads import (HEAD_KINDS, HeadOutput, make_full_head,
                               make_lss_head, make_sharded_lss_head,
                               shard_index)
from repro.utils import compat

__all__ = ["Engine", "ServeMetrics", "RankResult", "WOLServer", "LMDecoder"]


class ServeMetrics(NamedTuple):
    """Serving metrics window.  The first three fields keep the legacy
    (n_requests, wall_s, avg_sample_size) positional layout."""

    n_requests: int
    wall_s: float
    avg_sample_size: float
    throughput_rps: float
    latency_p50_ms: float
    latency_p95_ms: float
    latency_p99_ms: float
    label_recall: float          # nan until labels are supplied
    n_compiles: int


class RankResult(NamedTuple):
    """Per-request result handed back by ``flush``."""

    rid: int
    logits: np.ndarray           # [k]
    ids: np.ndarray              # [k]


class _Pending(NamedTuple):
    rid: int
    x: Any                       # example pytree (no batch dim)
    labels: np.ndarray | None    # [NL] int, -1 padded
    t_submit: float


class _IndexEpoch:
    """One fitted-index generation and everything derived from it.

    The Engine keeps a versioned table of these (``Engine._epochs``) so
    an online refresh can PREPARE a new generation — index, heads,
    sharded stacks, jitted LSS steps — entirely off the serving path,
    then flip ``Engine.index_epoch`` in O(1) under the lock.  Old
    generations stay resident while decode sessions that prefilled
    under them are still draining (``pins``) and are dropped at unpin
    or at the next swap once unpinned — "old steps drain while new
    ones warm"."""

    __slots__ = ("epoch", "index", "heads", "sharded", "steps", "pins")

    def __init__(self, epoch: int, index: LSSIndex):
        self.epoch = epoch
        self.index = index
        self.heads: dict[str, Callable] = {}     # lss kinds only
        self.sharded = None       # (index_stack, w_stack, m_local)
        self.steps: dict[tuple[str, Any], Callable] = {}
        self.pins = 0             # decode generations holding this epoch


def _as_label_row(labels) -> np.ndarray | None:
    if labels is None:
        return None
    arr = np.atleast_1d(np.asarray(labels, np.int32))
    return arr


class Engine:
    """Batched WOL serving with a pluggable head.

    ``embed_fn(batch) -> [B, d]`` maps a request batch to query
    embeddings; pass None when requests already ARE embeddings (the LM
    decode path).  ``w [m, d]``, ``b [m]`` are the WOL parameters.
    ``impl`` pins the kernel-registry implementation the LSS heads serve
    with (``ref`` | ``pallas`` | ``pallas_interpret``); None lets the
    registry auto-select by backend (pallas on TPU, ref elsewhere).
    ``dedup`` pins the ``lss_topk`` cross-table dedup strategy
    (``quadratic`` | ``bitonic``); None lets the registry auto-select on
    the candidate count C = L*P.  ``slab_dtype`` pins the bucket-major
    slab storage format (``fp32`` | ``bf16`` | ``int8``) by overriding
    ``lss_cfg.slab_dtype`` — it takes effect at every index (re)build,
    so ``fit`` and each IUL refit (re)quantize through the same knob;
    None defers to the ``lss_topk.slab_dtype`` registry strategy.

    ``spmd`` (a ``serve.multihost.MultihostContext``) runs the
    lss-sharded head over the multi-process (host, model) mesh: the
    index stack is built from ONLY this process's shards and stitched
    into global arrays, and — on the leader — every score step is
    wrapped to broadcast its opcode + batch first, so followers sitting
    in ``multihost.follower_loop`` enter the same collective program.
    Admission (``submit``/``rank``/the AsyncRuntime) happens on the
    leader only; the wrapped seam is ``_step``, which both the sync
    paths and the runtime dispatcher fetch from.

    Thread safety: every mutation of engine state — the pending request
    queue, finished results, the metrics window, and the jitted step
    cache — happens under ``self.lock`` (an RLock), so one Engine can be
    shared by the AsyncRuntime's worker threads and any number of user
    threads without racing ``_pending``/metrics state.  Device execution
    of an already-built step is jax's concern and needs no lock.
    """

    def __init__(self, embed_fn: Callable | None, w: jax.Array,
                 b: jax.Array | None = None,
                 lss_cfg: LSSConfig = LSSConfig(), *,
                 top_k: int = 5, head: str = "lss",
                 buckets=DEFAULT_BUCKETS,
                 mesh: jax.sharding.Mesh | None = None,
                 model_axis: str = "model",
                 impl: str | None = None,
                 dedup: str | None = None,
                 slab_dtype: str | None = None,
                 audit_rate: float | None = None,
                 spmd=None):
        if head not in HEAD_KINDS:
            raise ValueError(f"head must be one of {HEAD_KINDS}, got {head}")
        if spmd is not None and embed_fn is not None:
            # fail here, not inside the hot step: the opcode channel
            # broadcasts raw [B, d] float32 embedding batches, and an
            # embed_fn engine's [B, T] int token batch is also 2-D — it
            # would be silently cast to float and fed to embed().  A
            # mid-stream raise would also leave followers parked.
            raise ValueError(
                "multihost serving (spmd=...) requires embed_fn=None: "
                "requests must already be [B, d] embeddings; run the "
                "model body before submission")
        if impl is not None and impl not in registry.IMPLS:
            raise ValueError(
                f"impl must be one of {registry.IMPLS} or None, got {impl}")
        if dedup is not None:
            registry.get_strategy("lss_topk.dedup")._validate(
                dedup, "Engine(dedup=...)")
        if slab_dtype is not None:
            registry.get_strategy("lss_topk.slab_dtype")._validate(
                slab_dtype, "Engine(slab_dtype=...)")
            lss_cfg = lss_cfg._replace(slab_dtype=slab_dtype)
        self.impl = impl
        self.dedup = dedup
        self.embed_fn = embed_fn
        self.w = w.astype(jnp.float32)
        self.b = (jnp.zeros((w.shape[0],), jnp.float32) if b is None
                  else b.astype(jnp.float32))
        self.lss_cfg = lss_cfg
        self.top_k = top_k
        self.default_head = head
        self.batcher = MicroBatcher(buckets)
        self.mesh = mesh
        self.model_axis = model_axis
        self.spmd = spmd
        self._w_aug_cache: jax.Array | None = None
        # versioned double-buffered index slot: epoch id -> _IndexEpoch.
        # index_epoch names the SERVING generation; prepared-but-unswapped
        # and pinned-but-draining generations coexist in the table.
        self._epochs: dict[int, _IndexEpoch] = {}
        self.index_epoch: int = 0     # 0 = no fitted index yet
        self._epoch_seq: int = 0
        self._full_head: Callable | None = None
        # jitted steps: (head, bucket) score steps and (head, "decode[...]")
        # fused decode steps.  This table holds the INDEX-FREE full-head
        # programs only; LSS steps live in their _IndexEpoch so a refit
        # is an O(1) pointer flip, not an invalidation sweep.  One
        # compile-count table spans all epochs (a refit that retraces a
        # shape increments the same key — the observable tests pin).
        self._steps: dict[tuple[str, Any], Callable] = {}
        self.compile_counts: dict[tuple[str, Any], int] = {}
        self.calib: tuple | None = None   # (q, labels) refs from last fit
        self._queue: list[_Pending] = []
        self._results: list[RankResult] = []
        self._next_rid = 0
        self.lock = threading.RLock()
        # bounded latency telemetry (was: unbounded self._lat list)
        self.obs = obs.MetricsRegistry(scope_prefix="engine")
        self._h_lat = self.obs.histogram(
            "engine_request_latency_seconds",
            "submit -> result per ranked request")
        self.obs.collect(self._collect_gauges)
        # online label-recall auditor (ISSUE: the paper's LSS-recall
        # claim as a live gauge); rate 0 = off, env-tunable
        if audit_rate is None:
            audit_rate = obs.audit_rate_from_env(0.0)
        self.auditor = None
        if audit_rate > 0:
            # offers are gated per request group on kind != "full" (an
            # exact head needs no audit), so the default head doesn't
            # matter here — LSS traffic through any engine gets sampled
            from repro.obs.audit import RecallAuditor
            self.auditor = RecallAuditor(self, audit_rate)
        self.reset_metrics()

    @property
    def _w_aug(self) -> jax.Array:
        """Bias-augmented neurons, built on first LSS use — a full-head-only
        engine (e.g. LMDecoder without fit_lss) never pays the O(m*d)
        augment or holds the second copy of W."""
        if self._w_aug_cache is None:
            self._w_aug_cache = simhash.augment_neurons(self.w, self.b)
        return self._w_aug_cache

    # ------------------------------------------------- offline fitting --
    def fit(self, key: jax.Array, calib_batches: list, labels: jax.Array,
            verbose: bool = False) -> dict:
        """Paper Algorithm 1: embed the calibration batches through the
        frozen model body, then IUL-train the hyperplanes."""
        assert self.embed_fn is not None, "fit() needs an embed_fn; " \
            "use fit_from_queries() when requests are raw embeddings"
        q = jnp.concatenate([self.embed_fn(bb) for bb in calib_batches])
        return self.fit_from_queries(key, q, labels, verbose=verbose)

    def fit_from_queries(self, key: jax.Array, q: jax.Array,
                         labels: jax.Array, verbose: bool = False) -> dict:
        index, hist = fit_lss(key, q, labels, self.w, self.b, self.lss_cfg,
                              verbose=verbose)
        # keep references (not copies) to the calibration set: an
        # IndexRefresher snapshots them once to re-learn the hash online
        self.calib = (q, labels)
        self._set_index(index)
        return hist

    def fit_random(self, key: jax.Array) -> None:
        """SimHash init without IUL (the SLIDE-style baseline; also what
        the speed benchmarks use — timing is learning-independent)."""
        theta = simhash.init_hyperplanes(key, self._w_aug.shape[1],
                                         self.lss_cfg.k_bits,
                                         self.lss_cfg.n_tables)
        self._set_index(build_index(self._w_aug, theta, self.lss_cfg))

    # --------------------------------------------------- index lifecycle --
    @property
    def index(self) -> LSSIndex | None:
        """The SERVING epoch's index (None before any fit)."""
        st = self._epochs.get(self.index_epoch)
        return None if st is None else st.index

    def index_for(self, epoch: int) -> LSSIndex:
        """The index a specific (e.g. pinned) epoch serves."""
        return self._epoch_state(epoch).index

    def _epoch_state(self, epoch: int | None = None) -> _IndexEpoch:
        e = self.index_epoch if epoch is None else epoch
        st = self._epochs.get(e)
        if st is None:
            if e == 0:
                raise AssertionError(
                    "LSS head needs a fitted index: call fit()/"
                    "fit_random()")
            raise KeyError(f"index epoch {e} is gone (unpinned epochs "
                           f"are dropped at swap)")
        return st

    def _set_index(self, index: LSSIndex) -> None:
        """Install ``index`` as the serving epoch immediately (the
        offline fit path; mirrored identically on every multihost
        process, so no broadcast).  Online refresh goes through
        :meth:`swap_index` instead — prepare + warm + guarded flip."""
        self._swap_prepared(self.prepare_epoch(index))

    def prepare_epoch(self, index: LSSIndex) -> int:
        """Register ``index`` as a new, NOT-yet-serving epoch.  Heavy
        derived state (heads, sharded stacks, jitted steps) is built
        against it lazily or via :meth:`warm_epoch` — none of it on the
        serving path, none of it under a lock held across device work."""
        with self.lock:
            self._epoch_seq += 1
            e = self._epoch_seq
            self._epochs[e] = _IndexEpoch(e, index)
            return e

    def warm_epoch(self, epoch: int, shapes=None) -> None:
        """Trace the prepared epoch's LSS score steps for the bucket
        shapes the serving epoch already compiled (or explicit
        ``shapes``), so post-swap traffic hits warm programs instead of
        paying a trace on its first chunk.  Runs OFF the serving path:
        traces never hold ``self.lock``.  Decode steps are not warmed
        here — a scheduler generation traces its fused step when it
        first dispatches under the new epoch, also lock-free.  No-op on
        multihost engines (a leader-side dry run would broadcast; the
        fleet warms in lockstep through its first post-swap chunks) and
        on embed_fn engines (request shapes are not fabricable here)."""
        if self.spmd is not None or self.embed_fn is not None:
            return
        if shapes is None:
            cur = self._epochs.get(self.index_epoch)
            shapes = [] if cur is None else \
                [k for k in list(cur.steps) if isinstance(k[1], int)]
        d = int(self.w.shape[1])
        for kind, bucket in shapes:
            step = self._step(kind, bucket, epoch=epoch)
            out = step(np.zeros((bucket, d), np.float32))
            jax.block_until_ready(out.logits)

    def _swap_prepared(self, epoch: int) -> int:
        """Flip the serving epoch to ``epoch`` — the ONLY mutation on
        the swap path, O(1) under the channel->engine lock order (the
        same order submit/flush use), so it lands between runtime
        ticks: every chunk/step fetched before the flip runs the old
        generation to completion, every fetch after runs the new."""
        from repro.testing import faults
        with self._channel_lock(), self.lock:
            st = self._epoch_state(epoch)       # raises if dropped
            faults.fire(faults.ENGINE_SWAP, epoch=epoch)
            old = self.index_epoch
            self.index_epoch = st.epoch
            for k in [k for k, s in self._epochs.items()
                      if k != st.epoch and s.pins <= 0]:
                del self._epochs[k]
        obs.event("index_swap", epoch=epoch, prev=old)
        return epoch

    def swap_index(self, index: LSSIndex, *, warm: bool = True) -> int:
        """Online refresh entry: register ``index`` as a new epoch,
        warm its score steps off the serving path, then flip.  On a
        multihost leader the flip rides an ``OP_SWAP_INDEX`` broadcast
        so followers rebuild and flip in lockstep; followers themselves
        swap only via that channel (``follower_loop``), never directly.
        Returns the new epoch id."""
        if self.spmd is not None:
            if not self.spmd.is_leader:
                raise RuntimeError(
                    "followers swap via the OP_SWAP_INDEX broadcast in "
                    "follower_loop, not swap_index()")
            from repro.serve.multihost import leader_swap_index
            return leader_swap_index(self.spmd, self, index)
        e = self.prepare_epoch(index)
        if warm:
            self.warm_epoch(e)
        return self._swap_prepared(e)

    def swap_from_theta(self, theta) -> int:
        """Follower-side swap: rebuild the index deterministically from
        broadcast hyperplanes against this process's own ``_w_aug`` and
        flip.  ``build_index`` is value-deterministic, so every process
        lands on a bit-identical index without shipping buckets."""
        theta = jnp.asarray(theta, jnp.float32)
        index = build_index(self._w_aug, theta, self.lss_cfg)
        return self._swap_prepared(self.prepare_epoch(index))

    def pin_epoch(self, epoch: int | None = None) -> int:
        """Pin an epoch (default: the serving one) so a swap cannot drop
        it — decode sessions rank through the generation they prefilled
        under until they leave.  Returns the pinned epoch id."""
        with self.lock:
            st = self._epoch_state(epoch)
            st.pins += 1
            return st.epoch

    def unpin_epoch(self, epoch: int) -> None:
        """Release a pin; a non-serving epoch with no pins left is
        dropped (its index, heads, and jitted steps become collectable
        — the drained half of the double buffer)."""
        with self.lock:
            st = self._epochs.get(epoch)
            if st is None:
                return
            st.pins -= 1
            if st.pins <= 0 and epoch != self.index_epoch:
                del self._epochs[epoch]

    def drop_step(self, kind: str, tag) -> None:
        """Remove one cached jitted step (every epoch's copy included) —
        the scheduler-replacement path uses this so an outgrown fused
        program cannot collide with its successor's tag."""
        with self.lock:
            self._steps.pop((kind, tag), None)
            for st in self._epochs.values():
                st.steps.pop((kind, tag), None)

    # ------------------------------------------------------ head lookup --
    def _get_mesh(self):
        if self.spmd is not None:
            return self.spmd.mesh
        if self.mesh is None:
            self.mesh = compat.make_mesh(
                (len(jax.devices()),), (self.model_axis,),
                axis_types=compat.auto_axis_types(1))
        return self.mesh

    def _head(self, kind: str, st: _IndexEpoch | None = None) -> Callable:
        if kind not in HEAD_KINDS:
            raise ValueError(f"unknown head {kind!r}")
        if kind == "full":
            # index-free: one head for every epoch
            if self._full_head is None:
                self._full_head = make_full_head(self.w, self.b,
                                                 self.top_k)
            return self._full_head
        st = st if st is not None else self._epoch_state()
        if kind in st.heads:
            return st.heads[kind]
        if kind == "lss":
            w_aug = None if st.index.w_bucketed is not None \
                else self._w_aug
            head = make_lss_head(st.index, w_aug, self.top_k,
                                 impl=self.impl, dedup=self.dedup)
        elif self.spmd is not None:
            head = self._multihost_head(st)
        else:
            mesh = self._get_mesh()
            tp = mesh.shape[self.model_axis]
            if st.sharded is None:
                st.sharded = shard_index(self._w_aug, st.index.theta,
                                         self.lss_cfg, tp)
            stack, w_stack, m_local = st.sharded
            head = make_sharded_lss_head(stack, w_stack, mesh,
                                         self.lss_cfg, m_local,
                                         self.top_k, self.model_axis,
                                         impl=self.impl,
                                         dedup=self.dedup)
        st.heads[kind] = head
        return head

    def _multihost_head(self, st: _IndexEpoch) -> Callable:
        """lss-sharded over the multi-process mesh: build ONLY the
        shards this process addresses (its ``row_range`` slice of W —
        the only place the full weight is even indexed), stitch the
        local stacks into global (host, model)-sharded arrays, and rank
        through the hierarchical O(hosts*k) merge."""
        from repro.serve.heads import make_multihost_lss_head
        from repro.serve.multihost import assemble_global_stack
        ctx = self.spmd
        if st.sharded is None:
            m = self.w.shape[0]
            lo, hi = ctx.shard_range()
            r0, r1 = ctx.row_range(m)
            w_aug_local = simhash.augment_neurons(self.w[r0:r1],
                                                  self.b[r0:r1])
            local_stack, local_w, m_local = shard_index(
                w_aug_local, st.index.theta, self.lss_cfg,
                ctx.n_shards, shard_range=(lo, hi), m_total=m)
            stack = assemble_global_stack(ctx, local_stack, ctx.n_shards)
            w_stack = (None if local_w is None else
                       assemble_global_stack(ctx, local_w, ctx.n_shards))
            st.sharded = (stack, w_stack, m_local)
        stack, w_stack, m_local = st.sharded
        return make_multihost_lss_head(
            stack, w_stack, ctx.mesh, self.lss_cfg, m_local, self.top_k,
            ctx.host_axis, ctx.model_axis, impl=self.impl,
            dedup=self.dedup)

    # ------------------------------------------------------ jitted steps --
    def _step(self, kind: str, bucket: int,
              epoch: int | None = None) -> Callable:
        """One jitted step per (head, bucket) per index epoch: compile
        count is observable because the Python body runs exactly once
        per trace.  ``epoch`` selects a pinned generation's table (the
        decode path); None serves the current epoch."""
        key = (kind, bucket)
        # Lock-free hot path: a GIL-atomic dict read, so the runtime's
        # dispatcher never stalls behind a user thread's flush() (which
        # holds the lock across device execution).  Swapping while
        # serving can hand one in-flight chunk the pre-swap step, which
        # is inherent to concurrent refresh and no worse than the locked
        # path (the fetch could equally precede the flip) — the old
        # epoch's program stays valid until its state is dropped.
        table = (self._steps if kind == "full"
                 else self._epoch_state(epoch).steps)
        step = table.get(key)
        if step is not None:
            return step
        with self.lock:
            if key not in table:
                head = self._head(
                    kind, None if kind == "full"
                    else self._epoch_state(epoch))
                embed = self.embed_fn
                operands = getattr(head, "global_operands", None)

                def raw_step(x, *ops):
                    self.compile_counts[key] = \
                        self.compile_counts.get(key, 0) + 1
                    q = embed(x) if embed is not None else x
                    if ops:
                        return head.with_operands(q, *ops)
                    return head(q)

                jitted = jax.jit(raw_step)
                if operands is None:
                    step = jitted
                else:
                    # multi-process jit cannot CLOSE OVER the global
                    # (host, model)-sharded stacks — thread them as
                    # explicit arguments, keeping the step(x) seam
                    def step(x, _j=jitted, _ops=operands):
                        return _j(x, *_ops)
                if self.spmd is not None and self.spmd.is_leader:
                    # the SPMD seam: sync rank/flush AND the runtime
                    # dispatcher all fetch from here, so wrapping the
                    # leader's step makes every admission path broadcast
                    # to the follower_loop processes first
                    from repro.serve.multihost import make_leader_step
                    step = make_leader_step(self.spmd, step, kind, bucket)
                table[key] = step
            return table[key]

    def decode_logits(self, kind: str, tag: str, body: Callable,
                      epoch: int | None = None) -> Callable:
        """The batched decode head entry: one fused jitted program per
        (head kind, ``tag``) running ``body`` (the model's pooled decode
        step) straight into this engine's head — registry-dispatched for
        the LSS kinds, so the WOL ranking inside the token loop is the
        same kernel path the score buckets use.

        ``body(params, tok, *state) -> (hidden [B, d], k_new, v_new)``
        where ``state`` is the pool layout's cache operands — dense
        ``(k, v, lengths)``, paged ``(k, v, page_table, lengths)``; the
        returned step maps the same signature to ``(tok_next [B] int32,
        HeadOutput, k_new, v_new)`` with the next-token feedback computed
        IN-program, so a decode loop can chain steps device-to-device
        without a host round trip.  ``tag`` names the compile shape (the
        scheduler uses "decode[SxW]", paged "decode[SxW,pagedP]") and
        keys the shared jitted-step cache — compile counts land in
        ``compile_counts[(kind, tag)]`` next to the score buckets.  LSS
        decode steps live in their index epoch's table (``epoch`` pins a
        draining generation, None serves the current one), so a swap
        never invalidates a program a pinned decode generation is still
        running — it just stops being the default.

        The k/v slabs sit at argument positions 2 and 3 in EVERY layout,
        and on TPU the step donates them for in-place cache update
        (halving peak KV memory across a step); XLA:CPU does not support
        buffer donation, so donation is skipped there (the standing
        constraint) and the functional k-in/k-out flow stands alone.
        """
        key = (kind, tag)
        table = (self._steps if kind == "full"
                 else self._epoch_state(epoch).steps)
        step = table.get(key)             # lock-free hot path, like _step
        if step is not None:
            return step
        with self.lock:
            if key not in table:
                head = self._head(
                    kind, None if kind == "full"
                    else self._epoch_state(epoch))
                operands = getattr(head, "global_operands", None)
                n_ops = 0 if operands is None else len(operands)

                def raw_step(params, tok, *rest):
                    self.compile_counts[key] = \
                        self.compile_counts.get(key, 0) + 1
                    state = rest[:len(rest) - n_ops] if n_ops else rest
                    hidden, k_new, v_new = body(params, tok, *state)
                    h = hidden.astype(jnp.float32)
                    if n_ops:
                        ho = head.with_operands(h, *rest[len(rest) - n_ops:])
                    else:
                        ho = head(h)
                    tok_next = jnp.maximum(ho.ids[:, 0], 0).astype(jnp.int32)
                    return tok_next, ho, k_new, v_new

                donate = ((2, 3) if jax.default_backend() == "tpu"
                          and not n_ops else ())
                jitted = jax.jit(raw_step, donate_argnums=donate)
                if operands is None:
                    table[key] = jitted
                else:
                    # same operand threading as _step: the global stacks
                    # ride as trailing jit arguments, and every local
                    # operand is promoted to a mesh-replicated global
                    # array (metadata-only: each process holds the same
                    # mirrored value) so the fused decode program runs
                    # SPMD across the fleet
                    from repro.utils import compat
                    mesh = self.spmd.mesh
                    # params replicate ONCE per weight tree, not per
                    # token: re-stamping every fully-addressable weight
                    # leaf each fused step is a host->device device_put
                    # of the whole model per token.  The cache pins the
                    # source tree so its id can't be recycled; the k/v
                    # state leaves come back from the previous step as
                    # global arrays and pass through replicate_global
                    # untouched, so only tok (and the first step's
                    # state) get stamped per call.
                    params_cache: dict = {}

                    def step(params, tok, *state, _j=jitted,
                             _ops=operands):
                        cached = params_cache.get(id(params))
                        if cached is None or cached[0] is not params:
                            params_cache.clear()
                            params_cache[id(params)] = (
                                params,
                                compat.replicate_global(params, mesh))
                        params_g = params_cache[id(params)][1]
                        tok, state = compat.replicate_global(
                            (tok, state), mesh)
                        return _j(params_g, tok, *state, *_ops)

                    table[key] = step
            return table[key]

    def _pad_to_bucket(self, x, bucket: int):
        """Device-side row padding (no host round-trip for jax inputs)."""
        def pad(leaf):
            n = leaf.shape[0]
            if n == bucket:
                return leaf
            fill = jnp.zeros((bucket - n,) + leaf.shape[1:], leaf.dtype)
            return jnp.concatenate([leaf, fill], axis=0)
        if isinstance(x, dict):
            return {k: pad(jnp.asarray(v)) for k, v in x.items()}
        return pad(jnp.asarray(x))

    # ------------------------------------------------------- score path --
    def rank(self, x, head: str | None = None, labels=None,
             record: bool = True, epoch: int | None = None) -> HeadOutput:
        """Rank one already-batched request group (rows = requests).

        Pads to the bucket, runs the (head, bucket) jitted step, slices
        back to the true row count.  ``labels`` (int [B, NL], -1 padded)
        feed the recall metric.  The decode loop calls this with
        ``record=False`` to keep the token loop free of host syncs, and
        with ``epoch`` set to its pinned index generation so prefill
        first-tokens stay consistent with its fused decode steps across
        an online swap.
        """
        kind = head or self.default_head
        leaves = jax.tree.leaves(x)
        n = leaves[0].shape[0]
        t0 = time.perf_counter()
        outs = []
        for chunk in self.batcher.plan(n):
            part = jax.tree.map(
                lambda l: l[chunk.start:chunk.start + chunk.size], x)
            padded = self._pad_to_bucket(part, chunk.bucket)
            o = self._step(kind, chunk.bucket, epoch)(padded)
            outs.append(jax.tree.map(lambda l: l[:chunk.size], o))
        out = outs[0] if len(outs) == 1 else HeadOutput(
            *(None if any(l is None for l in ls) else jnp.concatenate(ls)
              for ls in zip(*outs)))
        if record:
            jax.block_until_ready(out.logits)
            wall = time.perf_counter() - t0
            self._record(out, n, wall, [wall] * n, labels)
            if self.auditor is not None and kind != "full":
                self.auditor.offer(x, np.asarray(out.ids))
        return out

    # --------------------------------------------------- request queue --
    def _channel_lock(self):
        """The multihost opcode-channel lock when this process is the
        leader (a no-op context otherwise).  Entry points that hold
        ``self.lock`` across a leader-wrapped step (submit/flush) take
        it FIRST, so lock order is always channel -> engine — the same
        order ``multihost.leader_generate`` (channel) -> decode-step
        build (engine) uses.  Both locks are reentrant."""
        if self.spmd is not None and self.spmd.is_leader:
            return self.spmd.lock
        return contextlib.nullcontext()

    def submit(self, x, labels=None) -> int:
        """Enqueue one example (leaves WITHOUT the batch dim).  Returns a
        request id; auto-flushes once a full max bucket is waiting."""
        with self._channel_lock(), self.lock:
            rid = self._next_rid
            self._next_rid += 1
            self._queue.append(_Pending(rid, x, _as_label_row(labels),
                                        time.perf_counter()))
            if len(self._queue) >= self.batcher.max_bucket:
                self._flush_ready()
            return rid

    def submit_batch(self, xb, labels=None) -> list[int]:
        """Enqueue every row of a batched pytree."""
        xb_np = jax.tree.map(np.asarray, xb)     # one device->host copy
        n = jax.tree.leaves(xb_np)[0].shape[0]
        lab = None if labels is None else np.asarray(labels)
        with self._channel_lock(), self.lock:    # rids stay contiguous
            return [self.submit(jax.tree.map(lambda l: l[i], xb_np),
                                None if lab is None else lab[i])
                    for i in range(n)]

    def _flush_ready(self) -> None:
        while len(self._queue) >= self.batcher.max_bucket:
            group = self._queue[:self.batcher.max_bucket]
            del self._queue[:self.batcher.max_bucket]
            self._results.extend(self._run_group(group))

    def flush(self, head: str | None = None) -> list[RankResult]:
        """Drain the queue through bucketed steps; return all finished
        results (including auto-flushed ones) in submit order."""
        with self._channel_lock(), self.lock:
            while self._queue:
                take = min(len(self._queue), self.batcher.max_bucket)
                group = self._queue[:take]
                del self._queue[:take]
                self._results.extend(self._run_group(group, head))
            out = sorted(self._results, key=lambda r: r.rid)
            self._results = []
            return out

    def _run_group(self, group: list[_Pending],
                   head: str | None = None) -> list[RankResult]:
        kind = head or self.default_head
        bucket = self.batcher.bucket_for(len(group))
        x = jax.tree.map(lambda *rows: np.stack(rows),
                         *[g.x for g in group])
        padded = self._pad_to_bucket(x, bucket)
        t0 = time.perf_counter()
        out = self._step(kind, bucket)(padded)
        jax.block_until_ready(out.logits)
        t1 = time.perf_counter()
        n = len(group)
        out = jax.tree.map(lambda l: l[:n], out)
        lats = [t1 - g.t_submit for g in group]
        labels = self._stack_labels([g.labels for g in group])
        self._record(out, n, t1 - t0, lats, labels)
        logits = np.asarray(out.logits)
        ids = np.asarray(out.ids)
        if self.auditor is not None and kind != "full":
            self.auditor.offer(x, ids)
        return [RankResult(g.rid, logits[i], ids[i])
                for i, g in enumerate(group)]

    @staticmethod
    def _stack_labels(rows) -> np.ndarray | None:
        if all(r is None for r in rows):
            return None
        width = max(1 if r is None else r.shape[0] for r in rows)
        out = np.full((len(rows), width), -1, np.int32)
        for i, r in enumerate(rows):
            if r is not None:
                out[i, :r.shape[0]] = r
        return out

    # ----------------------------------------------------------- metrics --
    def reset_metrics(self) -> None:
        """Start a fresh metrics window.  Pending request results are NOT
        metrics and survive (they belong to the next ``flush``)."""
        with self.lock:
            self._n = 0
            self._wall = 0.0
            self._h_lat.reset()
            self._sample_sum = 0.0
            self._recall_hit = 0
            self._recall_tot = 0

    def _record(self, out: HeadOutput, n: int, wall: float,
                lats: list[float], labels) -> None:
        with self.lock:
            self._record_locked(out, n, wall, lats, labels)

    def _record_locked(self, out: HeadOutput, n: int, wall: float,
                       lats: list[float], labels) -> None:
        self._n += n
        self._wall += wall
        for v in lats:
            self._h_lat.record(v)
        self._sample_sum += float(jnp.sum(out.sample_size[:n]))
        if labels is not None:
            lab = jnp.asarray(labels)[:n]
            if lab.ndim == 1:                 # one label per request
                lab = lab[:, None]
            pool = out.cand_ids if out.cand_ids is not None else out.ids
            hit = (lab[:, :, None] == pool[:n, None, :]).any(-1)
            valid = lab >= 0
            self._recall_hit += int(jnp.sum(hit & valid))
            self._recall_tot += int(jnp.sum(valid))

    def _collect_gauges(self, reg) -> None:
        """Exporter hook: surface the ServeMetrics window as gauges at
        snapshot time (no double bookkeeping on the record path)."""
        m = self.metrics()
        reg.gauge("engine_requests_total").set(m.n_requests)
        reg.gauge("engine_throughput_rps").set(m.throughput_rps)
        reg.gauge("engine_avg_sample_size").set(m.avg_sample_size)
        reg.gauge("engine_label_recall").set(m.label_recall)
        reg.gauge("engine_compiles_total").set(m.n_compiles)

    def metrics(self) -> ServeMetrics:
        # quantiles come off the histogram's own bounded reservoir, not
        # under self.lock — a metrics() poll never stalls flush()
        p50, p95, p99 = self._h_lat.quantile((50, 95, 99))
        p50, p95, p99 = p50 * 1e3, p95 * 1e3, p99 * 1e3
        with self.lock:
            return self._metrics_locked(p50, p95, p99)

    def _metrics_locked(self, p50: float, p95: float,
                        p99: float) -> ServeMetrics:
        return ServeMetrics(
            n_requests=self._n,
            wall_s=self._wall,
            avg_sample_size=self._sample_sum / max(self._n, 1),
            throughput_rps=self._n / self._wall if self._wall else 0.0,
            latency_p50_ms=float(p50),
            latency_p95_ms=float(p95),
            latency_p99_ms=float(p99),
            label_recall=(self._recall_hit / self._recall_tot
                          if self._recall_tot else math.nan),
            n_compiles=sum(self.compile_counts.values()),
        )


# ================================================= compatibility wrappers ==

class WOLServer:
    """Legacy facade: one wide output layer, full or LSS head.

    Kept API-stable for existing callers/tests; all work happens in the
    unified :class:`Engine`.
    """

    def __init__(self, embed_fn: Callable, w: jax.Array,
                 b: jax.Array | None, cfg: LSSConfig, top_k: int = 5):
        self.engine = Engine(embed_fn, w, b, cfg, top_k=top_k)

    @property
    def index(self):
        return self.engine.index

    def fit(self, key: jax.Array, calib_batches: list[dict],
            labels: jax.Array, verbose: bool = False) -> dict:
        return self.engine.fit(key, calib_batches, labels, verbose=verbose)

    def serve(self, batches: list[dict], use_lss: bool = True
              ) -> tuple[list, ServeMetrics]:
        assert not use_lss or self.engine.index is not None, "fit() first"
        self.engine.reset_metrics()
        kind = "lss" if use_lss else "full"
        out = []
        for b in batches:
            ho = self.engine.rank(b, head=kind)
            out.append((ho.logits, ho.ids))
        return out, self.engine.metrics()


class LMDecoder:
    """Session-based LM decode; the per-token head is the Engine's.

    Since the streaming-decode refactor this is a thin facade over a
    :class:`repro.serve.decode.DecodeScheduler`: ``generate`` submits one
    session per prompt row into a fixed-slot scheduler and blocks for the
    streams, so the blocking API and the AsyncRuntime's streaming path
    run the SAME fused ``decode_step_pooled -> head`` program — one
    compile per (head, pool shape) across all ``generate`` calls and all
    sessions, and blocking results are bit-identical to interleaved ones.

    ``max_streams`` fixes the slot count (the fused step's row shape);
    ``max_len`` fixes the pool cache width.  Both are compile shapes AND
    numeric shapes (XLA reductions differ across shapes at the ulp
    level), so pin them when comparing runs.  ``max_len=None`` sizes the
    pool lazily from the first ``generate`` call (growing later
    recompiles).
    """

    def __init__(self, params: dict, cfg, lss_cfg: LSSConfig | None = None,
                 impl: str | None = None, *, max_streams: int = 8,
                 max_len: int | None = None, dedup: str | None = None,
                 slab_dtype: str | None = None, kv_layout: str | None = None,
                 kv_page_tokens: int | None = None,
                 kv_pages: int | None = None, spmd=None):
        from repro.models import transformer as T
        self.T = T
        self.params = params
        self.cfg = cfg
        self.lss_cfg = lss_cfg
        self.max_streams = max_streams
        self._max_len = max_len
        # KV storage layout knobs, handed to each scheduler's pool:
        # layout dense|paged (None -> kv_pool.layout strategy /
        # $REPRO_KV_LAYOUT), page size, and an optional arena page cap
        self.kv_layout = kv_layout
        self.kv_page_tokens = kv_page_tokens
        self.kv_pages = kv_pages
        self._scheds: dict[str, Any] = {}
        self.engine = Engine(None, self.head_weights().astype(jnp.float32),
                             None, lss_cfg or LSSConfig(), top_k=1,
                             head="full", impl=impl, dedup=dedup,
                             slab_dtype=slab_dtype, spmd=spmd)

    @property
    def index(self):
        return self.engine.index

    def head_weights(self) -> jax.Array:
        return (self.params["embed"] if self.cfg.tie_embeddings
                else self.params["lm_head"])

    def fit_lss(self, key: jax.Array, calib_tokens: jax.Array,
                verbose: bool = False) -> dict:
        """Calibrate the LSS index from prefill hidden states; labels are
        the observed next tokens (teacher forcing — exactly the paper's
        'training data through the trained model' recipe)."""
        hidden, _, _ = self.T.forward(self.params, calib_tokens, self.cfg,
                                      mode="train")
        q = hidden[:, :-1].reshape(-1, hidden.shape[-1]).astype(jnp.float32)
        labels = calib_tokens[:, 1:].reshape(-1, 1)
        return self.engine.fit_from_queries(key, q, labels, verbose=verbose)

    def scheduler(self, head: str | None = None, min_len: int | None = None):
        """The per-head-kind DecodeScheduler (built lazily, reused across
        ``generate`` calls and by the AsyncRuntime's decode path).

        A ``min_len`` beyond the current pool width rebuilds the
        scheduler (a new compile shape) ONLY when the old one is idle
        and unattached; a scheduler an AsyncRuntime owns (or one with
        sessions in flight) must not be silently swapped out from under
        it — that raises instead, so callers size ``max_len`` up front.
        """
        from repro.serve.decode import DecodeScheduler
        kind = head or self.engine.default_head
        if kind != "full":
            assert self.engine.index is not None, "fit_lss() first"
        need = max(min_len or 0, self._max_len or 0)
        sched = self._scheds.get(kind)
        if sched is not None and sched.max_len >= need:
            return sched
        if sched is not None:
            if sched.on_session_done is not None or not sched.idle:
                raise ValueError(
                    f"head {kind!r} scheduler has pool width "
                    f"{sched.max_len} < required {need} but is busy or "
                    f"runtime-attached; construct the LMDecoder with "
                    f"max_len >= {need} instead of growing it mid-flight")
            # outgrown and safely replaceable: drop its fused step from
            # the engine's cache (every index epoch's copy) so the old
            # program (and its trace closure) cannot be pinned or
            # collide with the new shape
            self.engine.drop_step(kind, sched._tag)
        self._max_len = (max(need, 64) if self._max_len is None
                         else max(self._max_len, need))
        sched = DecodeScheduler(self.engine, self.params, self.cfg,
                                max_streams=self.max_streams,
                                max_len=self._max_len, head=kind,
                                kv_layout=self.kv_layout,
                                kv_page_tokens=self.kv_page_tokens,
                                kv_pages=self.kv_pages)
        self._scheds[kind] = sched
        return sched

    def generate(self, prompt: jax.Array, steps: int, use_lss: bool = False,
                 head: str | None = None) -> jax.Array:
        """Greedy decode.  prompt [B, S] -> tokens [B, steps].

        ``head`` overrides the full/LSS switch (e.g. "lss-sharded").
        Rows run as sessions through the slot pool: ``B > max_streams``
        decodes in waves of ``max_streams`` (construct the decoder with
        ``max_streams >= B`` for full batch parallelism).  Safe while an
        AsyncRuntime serves the same scheduler — ticks serialize, and
        this call returns once ITS streams finish, leaving other
        producers' sessions in flight."""
        kind = head or ("lss" if use_lss else "full")
        sched = self.scheduler(head=kind,
                               min_len=prompt.shape[1] + steps)
        rows = np.asarray(prompt, np.int32)
        streams = [sched.submit(rows[i], max_new_tokens=steps)
                   for i in range(rows.shape[0])]
        sched.run(until=lambda: all(s.done() for s in streams))
        return jnp.stack([jnp.asarray(s.result()) for s in streams], 0)
