"""Serving engine: batched WOL inference with the LSS head.

Two request kinds (the paper's two evaluation families):
  * ``score``   — XC / recsys: embedding -> WOL top-k (full or LSS).
  * ``decode``  — LM: KV-cache decode loop; the per-token head is either
    the exact vocab matmul or the LSS index (paper Algorithm 2).

The engine owns: frozen model params, the fitted LSSIndex, a simple
continuous batcher (pad-to-batch with -1 slots so arrival patterns don't
retrigger compilation), and serving metrics (sample size, recall when
labels are supplied).
"""

from __future__ import annotations

import time
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import lss as lss_lib
from repro.core.iul import fit_lss
from repro.core.lss import LSSConfig, LSSIndex


class ServeMetrics(NamedTuple):
    n_requests: int
    wall_s: float
    avg_sample_size: float


class WOLServer:
    """Serves one wide output layer, full or LSS.

    ``embed_fn(batch) -> [B, d]`` is the model body below the WOL;
    ``w, b`` are the WOL parameters.
    """

    def __init__(self, embed_fn: Callable, w: jax.Array,
                 b: jax.Array | None, cfg: LSSConfig, top_k: int = 5):
        self.embed_fn = jax.jit(embed_fn)
        self.w = w
        self.b = b if b is not None else jnp.zeros((w.shape[0],), w.dtype)
        self.cfg = cfg
        self.top_k = top_k
        self.index: LSSIndex | None = None
        self._full = jax.jit(self._full_topk)
        self._lss = jax.jit(self._lss_topk)

    # -- offline preprocessing (paper Algorithm 1) ----------------------
    def fit(self, key: jax.Array, calib_batches: list[dict],
            labels: jax.Array, verbose: bool = False) -> dict:
        q = jnp.concatenate([self.embed_fn(b) for b in calib_batches])
        self.index, hist = fit_lss(key, q, labels, self.w, self.b,
                                   self.cfg, verbose=verbose)
        return hist

    # -- heads -----------------------------------------------------------
    def _full_topk(self, q: jax.Array):
        logits = q @ self.w.T + self.b
        top, ids = jax.lax.top_k(logits, self.top_k)
        return top, ids

    def _lss_topk(self, q: jax.Array, index: LSSIndex):
        return lss_lib.lss_predict(
            q, index, lss_lib.simhash.augment_neurons(self.w, self.b),
            top_k=self.top_k)

    # -- serving ---------------------------------------------------------
    def serve(self, batches: list[dict], use_lss: bool = True
              ) -> tuple[list, ServeMetrics]:
        assert not use_lss or self.index is not None, "fit() first"
        out = []
        t0 = time.time()
        sample = 0.0
        for b in batches:
            q = self.embed_fn(b)
            if use_lss:
                top, ids = self._lss(q, self.index)
                cand, _ = lss_lib.retrieve(
                    lss_lib.simhash.augment_queries(q), self.index)
                sample += float(lss_lib.avg_sample_size(cand))
            else:
                top, ids = self._full(q)
            out.append((top, ids))
        jax.block_until_ready(out[-1])
        wall = time.time() - t0
        return out, ServeMetrics(len(batches), wall,
                                 sample / max(len(batches), 1))


class LMDecoder:
    """KV-cache decode loop with a pluggable head (exact | LSS)."""

    def __init__(self, params: dict, cfg, lss_cfg: LSSConfig | None = None):
        from repro.models import transformer as T
        self.T = T
        self.params = params
        self.cfg = cfg
        self.index: LSSIndex | None = None
        self.lss_cfg = lss_cfg
        self._decode = jax.jit(T.decode_step, static_argnames="cfg")

    def head_weights(self) -> jax.Array:
        return (self.params["embed"] if self.cfg.tie_embeddings
                else self.params["lm_head"])

    def fit_lss(self, key: jax.Array, calib_tokens: jax.Array,
                verbose: bool = False) -> dict:
        """Calibrate the LSS index from prefill hidden states; labels are
        the observed next tokens (teacher forcing — exactly the paper's
        'training data through the trained model' recipe)."""
        hidden, _, _ = self.T.forward(self.params, calib_tokens, self.cfg,
                                      mode="train")
        q = hidden[:, :-1].reshape(-1, hidden.shape[-1])
        labels = calib_tokens[:, 1:].reshape(-1, 1)
        self.index, hist = fit_lss(key, q, labels,
                                   self.head_weights().astype(jnp.float32),
                                   None, self.lss_cfg, verbose=verbose)
        return hist

    def generate(self, prompt: jax.Array, steps: int, use_lss: bool = False
                 ) -> jax.Array:
        """Greedy decode.  prompt [B, S] -> tokens [B, steps]."""
        hidden, cache = self.T.prefill(self.params, prompt, self.cfg,
                                       max_len=prompt.shape[1] + steps)
        w = self.head_weights()
        outs = []
        h = hidden[:, -1]
        for _ in range(steps):
            if use_lss:
                assert self.index is not None
                _, ids = lss_lib.lss_predict(
                    h.astype(jnp.float32), self.index, None, top_k=1)
                tok = jnp.maximum(ids[:, 0], 0)
            else:
                logits = jnp.einsum("bd,vd->bv", h.astype(jnp.float32),
                                    w.astype(jnp.float32))
                tok = jnp.argmax(logits, -1)
            outs.append(tok)
            h, cache = self._decode(self.params, tok, cache, self.cfg)
        return jnp.stack(outs, 1)
