"""Zero-downtime online index refresh (ROADMAP direction 3).

The LSS hash is *trained* (paper §3.3) — a serving system that never
re-learns it serves a stale index.  :class:`IndexRefresher` re-runs IUL
epochs on a snapshot of the calibration set entirely off the hot path,
then swaps the candidate index into the Engine through the versioned
epoch table (``Engine.swap_index``) with a guarded probation window and
automatic rollback.  See ``docs/ARCHITECTURE.md`` ("Index lifecycle").
"""

from repro.serve.refresh.refresher import IndexRefresher, RefreshConfig

__all__ = ["IndexRefresher", "RefreshConfig"]
