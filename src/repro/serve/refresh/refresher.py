"""Background index refresh with guarded swap and automatic rollback.

State machine (one ``refresh_once`` cycle)::

    IDLE --interval--> REFIT --ok--> SWAP --probation ok--> IDLE
                         |             |
                         | exception / |  audited recall dropped more
                         | NaN theta   |  than rollback_delta below the
                         v             v  pre-swap baseline
                       FAILED        ROLLBACK --> IDLE
                  (backoff, park       (swap BACK to the previous
                   after max_failures)  index as a NEW epoch)

Everything expensive — IUL epochs, ``build_index``, warming the new
epoch's jitted steps — happens before the swap, which itself is the
O(1) epoch flip of ``Engine._swap_prepared``.  Failures never
propagate to the serving path: the engine keeps serving the epoch it
already has (graceful degradation), and repeated failures back off
exponentially until the refresher parks itself.

Probation is judged by PR 8's :class:`~repro.obs.audit.RecallAuditor`:
the refresher snapshots ``(hits, total)`` at the swap and compares the
recall of ONLY the rows audited after it against the pre-swap baseline
— the cumulative gauge would dilute a regression by history.

Fault-injection hook points (``repro.testing.faults``): ``refresh.refit``
before the refit computes, ``refresh.built`` after the candidate is
built (a callable may substitute a corrupted index), and
``refresh.probation`` at each probation poll (a callable may override
``ctx["recall"]``).
"""

from __future__ import annotations

import os
import threading
import time
from typing import NamedTuple

import numpy as np

from repro import obs
from repro.core import simhash
from repro.core.iul import IULState, iul_init, iul_refit_epoch
from repro.testing import faults

__all__ = ["IndexRefresher", "RefreshConfig"]

_UNSET = object()


def _env_float(name: str, default: float) -> float:
    v = os.environ.get(name)
    return default if v in (None, "") else float(v)


class RefreshConfig(NamedTuple):
    """Knobs for the refresh loop (env overrides in :meth:`from_env`,
    documented in docs/KERNELS.md)."""

    interval_s: float = 30.0        # sleep between refresh cycles
    probation_s: float = 5.0        # watch window after each swap
    rollback_delta: float = 0.05    # tolerated recall drop vs baseline
    min_audit_rows: int = 64        # rows before probation can judge
    probation_poll_s: float = 0.25  # auditor poll cadence
    epochs_per_refresh: int = 1     # IUL epochs per cycle
    max_failures: int = 5           # consecutive failures before parking
    backoff_base_s: float = 1.0     # first retry delay
    backoff_max_s: float = 60.0     # retry delay ceiling
    warm: bool = True               # pre-trace the new epoch's steps

    @classmethod
    def from_env(cls, **overrides) -> "RefreshConfig":
        base = cls(
            interval_s=_env_float("REPRO_REFRESH_INTERVAL", cls().interval_s),
            probation_s=_env_float("REPRO_REFRESH_PROBATION",
                                   cls().probation_s),
            rollback_delta=_env_float("REPRO_REFRESH_ROLLBACK_DELTA",
                                      cls().rollback_delta),
        )
        return base._replace(**overrides) if overrides else base


class IndexRefresher:
    """Serve while you re-learn the hash.

    Args:
      engine: the serving Engine.  Must have been fitted through
        ``fit_from_queries`` (the refresher snapshots ``engine.calib``)
        or be given ``calib=(q, labels)`` explicitly.  On a multihost
        fleet, construct this on the LEADER only — ``swap_index``
        broadcasts ``OP_SWAP_INDEX`` so followers flip in lockstep.
      auditor: the live recall sensor probation watches.  ``None``
        disables the guard (swaps are trusted); a disabled auditor
        (``rate=0``) behaves like ``None`` because no rows ever arrive
        inside the probation window.
      cfg: :class:`RefreshConfig`.
      calib: optional ``(q, labels)`` calibration snapshot override.
      seed: RNG seed for the resumed IUL stream.

    The training stream RESUMES from the serving hyperplanes
    (``iul_init(theta=index.theta)``) and carries optimizer state across
    cycles — each refresh is a continuation, not a cold restart.
    """

    def __init__(self, engine, auditor=_UNSET,
                 cfg: RefreshConfig | None = None,
                 *, calib=None, seed: int = 0, registry=None):
        self.engine = engine
        # default: the engine's own auditor (None and rate-0 both mean
        # "no guard" — probation then passes on no-evidence)
        self.auditor = (getattr(engine, "auditor", None)
                        if auditor is _UNSET else auditor)
        self.cfg = cfg if cfg is not None else RefreshConfig.from_env()
        if engine.spmd is not None and not engine.spmd.is_leader:
            raise RuntimeError("IndexRefresher runs on the multihost "
                               "leader; followers swap via OP_SWAP_INDEX")
        if calib is None:
            calib = engine.calib
        if calib is None:
            raise RuntimeError(
                "engine has no calibration snapshot: fit with "
                "fit_from_queries() or pass calib=(q, labels)")
        q, labels = calib
        # freeze the snapshot ONCE: the refit must see an immutable view
        # no matter what the caller does with its arrays afterwards
        self._q_aug = simhash.augment_queries(np.asarray(q, np.float32))
        self._labels = np.asarray(labels)
        self._w_aug = engine._w_aug
        self._seed = seed
        self._state: IULState | None = None     # lazy: needs a fitted index
        self.n_refreshes = 0
        self.n_rollbacks = 0
        self.n_failures = 0                     # consecutive, resets on ok
        self.parked = False
        self.last_info: dict = {}
        self.reg = registry if registry is not None else obs.registry()
        self._c_total = self.reg.counter(
            "lss_refresh_total", "refresh cycles attempted")
        self._c_swapped = self.reg.counter(
            "lss_refresh_swapped_total", "refresh cycles that swapped")
        self._c_rollback = self.reg.counter(
            "lss_refresh_rollback_total",
            "swaps reverted because audited recall regressed")
        self._c_failures = self.reg.counter(
            "lss_refresh_failures_total", "refresh cycles that failed")
        self._g_epoch = self.reg.gauge(
            "lss_refresh_index_epoch", "engine epoch serving now")
        self._g_recall = self.reg.gauge(
            "lss_refresh_calib_recall",
            "calibration recall of the last candidate index")
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------ refit --
    def _refit(self):
        """Run the configured IUL epochs off the hot path; return the
        candidate index (NaN-guarded) and its calibration recall."""
        import jax.numpy as jnp
        faults.fire(faults.REFRESH_REFIT)
        if self._state is None:
            import jax
            idx = self.engine.index
            assert idx is not None, "refresh needs a fitted engine"
            self._state = iul_init(jax.random.PRNGKey(self._seed),
                                   self._q_aug, self._labels, self._w_aug,
                                   self.engine.lss_cfg, theta=idx.theta)
        index = self.engine.index
        info = {}
        for _ in range(max(1, self.cfg.epochs_per_refresh)):
            self._state, index, info = iul_refit_epoch(
                self._state, self._q_aug, self._labels, self._w_aug,
                index, self.engine.lss_cfg)
        if not bool(jnp.isfinite(self._state.theta).all()):
            raise FloatingPointError(
                "refit produced non-finite hyperplanes (diverged); "
                "keeping the serving index")
        self.last_info = info
        return index, float(info.get("recall", float("nan")))

    # -------------------------------------------------------- probation --
    def _probation(self, baseline: float, hits0: int, total0: int) -> bool:
        """Watch the auditor for ``probation_s``; True = the new epoch
        survives, False = roll back.  Judged on post-swap rows only;
        windows that never reach ``min_audit_rows`` pass (no evidence
        of regression is not evidence of regression)."""
        if self.auditor is None:
            return True
        deadline = time.monotonic() + self.cfg.probation_s
        while not self._stop.is_set():
            hits, total = self.auditor.snapshot()
            rows = total - total0
            if rows >= self.cfg.min_audit_rows:
                recall = (hits - hits0) / rows
                ctx = faults.fire(faults.REFRESH_PROBATION,
                                  recall=recall, rows=rows)
                recall = float(ctx["recall"])
                if (np.isfinite(baseline)
                        and recall < baseline - self.cfg.rollback_delta):
                    obs.event("refresh_probation_fail", recall=recall,
                              baseline=baseline, rows=rows)
                    return False
                return True
            if time.monotonic() >= deadline:
                return True
            self._stop.wait(self.cfg.probation_poll_s)
        return True

    # ------------------------------------------------------------ cycle --
    def refresh_once(self) -> str:
        """One full cycle: refit -> guarded swap -> probation.  Returns
        ``"swapped"``, ``"rolled_back"``, or ``"failed"``.  Never raises:
        a failure leaves the engine serving what it already served."""
        self._c_total.inc()
        span = obs.start_span("index_refresh")
        try:
            candidate, cand_recall = self._refit()
            ctx = faults.fire(faults.REFRESH_BUILT, index=candidate,
                              recall=cand_recall)
            candidate = ctx["index"]
            self._g_recall.set(cand_recall)
            prev_index = self.engine.index
            if self.auditor is not None:
                hits0, total0 = self.auditor.snapshot()
            else:
                hits0 = total0 = 0
            baseline = hits0 / total0 if total0 else float("nan")
            epoch = self.engine.swap_index(candidate, warm=self.cfg.warm)
            self._g_epoch.set(epoch)
            if self._probation(baseline, hits0, total0):
                self.n_refreshes += 1
                self.n_failures = 0
                self._c_swapped.inc()
                span.end("ok", outcome="swapped", epoch=epoch,
                         recall=cand_recall)
                return "swapped"
            # ------------------------------------------------ rollback --
            back = self.engine.swap_index(prev_index, warm=self.cfg.warm)
            self._g_epoch.set(back)
            self.n_rollbacks += 1
            self.n_failures = 0
            self._c_rollback.inc()
            obs.event("refresh_rollback", from_epoch=epoch, to_epoch=back)
            # the training stream followed a bad gradient — restart it
            # from the restored serving hyperplanes next cycle
            self._state = None
            span.end("ok", outcome="rolled_back", epoch=back)
            return "rolled_back"
        except Exception as exc:
            self.n_failures += 1
            self._c_failures.inc()
            obs.event("refresh_failed", error=type(exc).__name__,
                      consecutive=self.n_failures)
            span.end_from_exc(exc)
            return "failed"

    # ------------------------------------------------------------- loop --
    def _backoff(self) -> float:
        return min(self.cfg.backoff_base_s * 2 ** (self.n_failures - 1),
                   self.cfg.backoff_max_s)

    def _run(self) -> None:
        while not self._stop.is_set():
            outcome = self.refresh_once()
            if outcome == "failed":
                if self.n_failures >= self.cfg.max_failures:
                    self.parked = True
                    obs.event("refresh_parked",
                              failures=self.n_failures)
                    return          # serve the last good index forever
                self._stop.wait(self._backoff())
            else:
                self._stop.wait(self.cfg.interval_s)

    def start(self) -> "IndexRefresher":
        """Start the background loop (daemon thread; idempotent)."""
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self.parked = False
            self._thread = threading.Thread(
                target=self._run, name="index-refresher", daemon=True)
            self._thread.start()
        return self

    def close(self, timeout: float = 30.0) -> None:
        """Stop the loop; an in-progress cycle finishes its swap or
        rollback first (a half-applied swap is never left behind)."""
        self._stop.set()
        t = self._thread
        if t is not None:
            t.join(timeout)
        self._thread = None

    def __enter__(self) -> "IndexRefresher":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()
