"""repro — production-grade JAX framework reproducing and extending

    "Climbing the WOL: Training for Cheaper Inference" (Liu et al., 2020).

Core contribution: Label Sensitive Sampling (LSS) — learned SimHash retrieval
over wide output layers (WOLs), adapted TPU-natively (bucket-major weight
layout, static shapes, vocab-sharded serving).
"""

__version__ = "1.0.0"
