"""Roofline-term extraction from a compiled (dry-run) executable.

Three terms per (arch x shape x mesh), in seconds:

  compute    = HLO_FLOPs            / (chips * 197e12)     [bf16 MXU]
  memory     = HLO_bytes_accessed   / (chips * 819e9)      [HBM]
  collective = per-device collective traffic / 50e9        [ICI link]

FLOPs / bytes come from ``compiled.cost_analysis()`` (per-device on the
host backend — verified empirically).  Collective traffic is NOT in
cost_analysis: we parse ``compiled.as_text()`` (post-SPMD-partitioning
HLO) and apply ring accounting per op:

  all-reduce      2 * size * (g-1)/g      (reduce-scatter + all-gather)
  all-gather      size_out * (g-1)/g      (receives everyone else's shard)
  reduce-scatter  size_out * (g-1)        (sends/combines g-1 shards)
  all-to-all      size * (g-1)/g
  collective-permute  size
"""

from __future__ import annotations

import re
from typing import NamedTuple

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.utils import compat

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<lhs>\(?[^=]*?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_SHAPE_RE = re.compile(r"(?P<dt>[a-z]+[0-9]*(?:e[0-9]+m[0-9]+(?:fn)?)?)"
                       r"\[(?P<dims>[0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


class CollectiveStats(NamedTuple):
    bytes_by_op: dict[str, float]    # per-device traffic, ring-accounted
    count_by_op: dict[str, int]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_op.values())


def _shape_bytes(text: str) -> float:
    total = 0.0
    for m in _SHAPE_RE.finditer(text):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    bytes_by_op: dict[str, float] = {}
    count_by_op: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done" in line:
            continue
        op = m.group("op")
        size = _shape_bytes(m.group("lhs"))
        g = max(_group_size(line, n_devices), 1)
        if op == "all-reduce":
            traffic = 2.0 * size * (g - 1) / g
        elif op == "all-gather":
            traffic = size * (g - 1) / g
        elif op == "reduce-scatter":
            traffic = size * (g - 1)
        elif op == "all-to-all":
            traffic = size * (g - 1) / g
        else:  # collective-permute
            traffic = size
        bytes_by_op[op] = bytes_by_op.get(op, 0.0) + traffic
        count_by_op[op] = count_by_op.get(op, 0) + 1
    return CollectiveStats(bytes_by_op, count_by_op)


class Roofline(NamedTuple):
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float
    useful_ratio: float          # MODEL_FLOPS / (HLO_FLOPs * chips)

    def as_dict(self) -> dict:
        return self._asdict()


def roofline_from_terms(flops: float, bts: float, coll_bytes: float,
                        n_devices: int, model_flops: float) -> Roofline:
    """Per-device (flops, bytes, collective bytes) -> roofline terms."""
    t_c = flops / PEAK_FLOPS_BF16
    t_m = bts / HBM_BW
    t_x = coll_bytes / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    total_flops = flops * n_devices
    return Roofline(flops, bts, coll_bytes, t_c, t_m, t_x, bottleneck,
                    model_flops,
                    model_flops / total_flops if total_flops else 0.0)


def analyze(compiled, n_devices: int, model_flops: float,
            flops_correction: float = 0.0) -> Roofline:
    """``flops_correction``: GLOBAL FLOPs for scan bodies that
    cost_analysis counted once (intra-attention chunk loops); bytes are
    corrected at an assumed 100 FLOP/B intensity for those regions
    (fused online-softmax tiles are compute-leaning; documented
    approximation in EXPERIMENTS.md)."""
    cost = compat.cost_analysis(compiled)
    flops = float(cost.get("flops", 0.0)) + flops_correction / n_devices
    bts = float(cost.get("bytes accessed", 0.0)) \
        + flops_correction / n_devices / 100.0
    coll = parse_collectives(compiled.as_text(), n_devices)
    return roofline_from_terms(flops, bts, coll.total_bytes, n_devices,
                               model_flops)
