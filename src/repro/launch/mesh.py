"""Production meshes.

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model) — 'pod' is a
pure data-parallel axis with slow (DCI) links; the gradient compressor
(repro.optim.compression) targets exactly that axis.

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before any jax import).
"""

from __future__ import annotations

import jax

from repro.utils import compat


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(
        shape, axes, axis_types=compat.auto_axis_types(len(axes)))


def make_debug_mesh(shape=(2, 2), axes=("data", "model")) -> jax.sharding.Mesh:
    """Small mesh for CI tests (XLA_FLAGS host-device-count >= prod(shape))."""
    return compat.make_mesh(
        shape, axes, axis_types=compat.auto_axis_types(len(axes)))


def make_serving_mesh(host_axis: str = "host", model_axis: str = "model"
                      ) -> jax.sharding.Mesh:
    """The multi-host SERVING mesh: (host, model) over every process's
    devices — rows are processes, so vocab shards land host-contiguous
    (what the hierarchical top-k merge assumes).  Requires
    ``compat.distributed_initialize`` (or single-process, where the host
    axis is 1 and the hierarchical merge reduces to the flat one)."""
    return compat.make_global_mesh((host_axis, model_axis))


# v5e hardware constants for the roofline (per chip / per link)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link (~per direction)
