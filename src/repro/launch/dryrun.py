import os

from repro.xla_env import force_host_device_count

force_host_device_count(512)

"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell.

The ONLY entry point that fakes 512 devices (set above, before any jax
import; user-set XLA_FLAGS are preserved, not clobbered).  Produces one JSON record per cell under --out with:
memory_analysis (bytes/device), cost_analysis (FLOPs, bytes), the parsed
collective schedule, and the three roofline terms.

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  python -m repro.launch.dryrun --arch all --mesh both --out experiments
"""

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs.registry import all_cells, get_config       # noqa: E402
from repro.launch.mesh import make_production_mesh             # noqa: E402
from repro.utils import compat                                 # noqa: E402
from repro.launch.roofline import parse_collectives, \
    roofline_from_terms                                        # noqa: E402
from repro.launch.steps import build_cell                      # noqa: E402


def _compile_cell(cell, mesh):
    donate = (0,) if cell.donate_state else ()
    jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                     donate_argnums=donate)
    return jitted.lower(*cell.args).compile()


def _measure(compiled, cell, n_dev) -> dict:
    """Per-device corrected (flops, bytes, collective bytes)."""
    cost = compat.cost_analysis(compiled)
    coll = parse_collectives(compiled.as_text(), n_dev)
    return {
        "flops": float(cost.get("flops", 0.0))
        + cell.flops_correction / n_dev,
        "bytes": float(cost.get("bytes accessed", 0.0))
        + cell.flops_correction / n_dev / 100.0,
        "coll_bytes": coll.total_bytes,
        "coll_by_op": coll.bytes_by_op,
        "coll_counts": coll.count_by_op,
    }


def _mem_record(compiled) -> dict:
    mem = compiled.memory_analysis()
    return {
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        "total_per_device_gb": round(
            (mem.argument_size_in_bytes + mem.output_size_in_bytes
             + mem.temp_size_in_bytes - mem.alias_size_in_bytes) / 2**30, 3),
    }


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             out_dir: str | None) -> dict:
    """LM cells compile three ways: full depth w/ scan-over-layers (the
    production graph — this is the pass/fail + memory-fit proof) and
    unrolled at 2 & 4 layers, whose per-layer cost slope extrapolates
    exact FLOP/byte/collective counts to full depth (XLA cost_analysis
    ignores scan trip counts — measured, see EXPERIMENTS.md §Method).
    Non-LM cells have no layer stack and compile once."""
    from repro.configs.registry import get_config
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    t0 = time.time()
    rec = {"arch": arch_id, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    spec = get_config(arch_id)
    with compat.set_mesh(mesh):
        if spec.family == "lm":
            full_l = spec.model_cfg.n_layers
            cell = build_cell(arch_id, shape_name, mesh, lm_impl="scan")
            compiled = _compile_cell(cell, mesh)       # production proof
            rec["memory"] = _mem_record(compiled)
            c2 = build_cell(arch_id, shape_name, mesh, lm_layers=2)
            m2 = _measure(_compile_cell(c2, mesh), c2, n_dev)
            c4 = build_cell(arch_id, shape_name, mesh, lm_layers=4)
            m4 = _measure(_compile_cell(c4, mesh), c4, n_dev)
            meas = {}
            for k in ("flops", "bytes", "coll_bytes"):
                slope = (m4[k] - m2[k]) / 2.0
                meas[k] = m2[k] + slope * (full_l - 2)
            meas["coll_by_op"] = {
                k: m2["coll_by_op"].get(k, 0.0)
                + (m4["coll_by_op"].get(k, 0.0)
                   - m2["coll_by_op"].get(k, 0.0)) / 2.0 * (full_l - 2)
                for k in set(m2["coll_by_op"]) | set(m4["coll_by_op"])}
            meas["coll_counts"] = m4["coll_counts"]
            rec["method"] = "scan-proof + unrolled L2/L4 extrapolation"
        else:
            cell = build_cell(arch_id, shape_name, mesh)
            compiled = _compile_cell(cell, mesh)
            rec["memory"] = _mem_record(compiled)
            meas = _measure(compiled, cell, n_dev)
            rec["method"] = "direct"

        rec["cost"] = {"flops": meas["flops"],
                       "bytes_accessed": meas["bytes"]}
        rec["collectives"] = {
            "bytes_by_op": meas["coll_by_op"],
            "count_by_op": meas["coll_counts"],
            "total_bytes_per_device": meas["coll_bytes"],
        }
        roof = roofline_from_terms(meas["flops"], meas["bytes"],
                                   meas["coll_bytes"], n_dev,
                                   cell.model_flops)
        rec["roofline"] = roof.as_dict()
        rec["timings"] = {"total_s": round(time.time() - t0, 1)}
        rec["comment"] = cell.comment
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch_id}_{shape_name}_{rec['mesh'].replace('x','_')}"
        with open(os.path.join(out_dir, f"{tag}.json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    cells = all_cells()
    if args.arch != "all":
        cells = [(a, s) for a, s in cells if a == args.arch]
    if args.shape != "all":
        cells = [(a, s) for a, s in cells if s == args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for arch_id, shape_name in cells:
        for mp in meshes:
            tag = f"{arch_id}/{shape_name}/{'multi' if mp else 'single'}"
            try:
                rec = run_cell(arch_id, shape_name, mp, args.out)
                r = rec["roofline"]
                print(f"[dryrun] OK  {tag}: "
                      f"mem={rec['memory']['total_per_device_gb']}GB "
                      f"t_comp={r['t_compute']:.2e}s "
                      f"t_mem={r['t_memory']:.2e}s "
                      f"t_coll={r['t_collective']:.2e}s "
                      f"bound={r['bottleneck']} "
                      f"useful={r['useful_ratio']:.2f} "
                      f"({rec['timings']['total_s']}s)",
                      flush=True)
            except Exception as e:
                failures.append((tag, repr(e)))
                print(f"[dryrun] FAIL {tag}: {e!r}", flush=True)
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e)
        raise SystemExit(1)
    print(f"\nall {len(cells) * len(meshes)} cells OK")


if __name__ == "__main__":
    main()
