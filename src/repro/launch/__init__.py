"""launch subpackage."""
