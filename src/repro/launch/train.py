"""Production training launcher.

    python -m repro.launch.train --arch qwen2-0.5b --steps 200 \
        --ckpt-dir /tmp/ckpt [--devices 8 --mesh 2x4]

On a real TPU fleet this binary runs once per host (jax.distributed
initializes from the TPU environment); in this container ``--devices``
fakes host devices for an end-to-end multi-process-free rehearsal.
Auto-resumes from the newest valid checkpoint; survives preemption.
"""

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--devices", type=int, default=0,
                    help="fake host devices (0 = real devices)")
    ap.add_argument("--mesh", default="", help="e.g. 2x4 (data x model)")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced config (CPU-trainable)")
    args = ap.parse_args()

    if args.devices:
        from repro.xla_env import force_host_device_count
        force_host_device_count(args.devices)

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs.registry import get_config
    from repro.configs.reduced import reduced_model_cfg
    from repro.data.pipeline import ShardedBatchIterator
    from repro.data.synthetic import lm_dataset
    from repro.models import transformer as T
    from repro.train.trainer import TrainConfig, Trainer

    spec = get_config(args.arch)
    if spec.family != "lm":
        print("this launcher trains LM archs; see examples/ for others")
        sys.exit(2)
    cfg = reduced_model_cfg(args.arch) if args.reduced else spec.model_cfg

    mesh = None
    param_specs = None
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
        from repro.utils import compat
        mesh = compat.make_mesh(shape, ("data", "model")[: len(shape)],
                                axis_types=compat.auto_axis_types(len(shape)))
        param_specs = T.param_specs(cfg)

    toks = lm_dataset(0, args.batch * args.seq * 64, cfg.vocab,
                      args.seq + 1)
    data = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    tc = TrainConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                     total_steps=args.steps, ckpt_every=50)
    tr = Trainer(lambda p, b: T.lm_loss(p, b, cfg),
                 lambda k: T.init_params(k, cfg), tc,
                 ckpt_dir=args.ckpt_dir, mesh=mesh,
                 param_specs=param_specs)
    it = ShardedBatchIterator(data, args.batch, mesh=mesh)
    state, hist = tr.fit(jax.random.PRNGKey(0), it, args.steps)
    print(f"done: step {int(state.step)} loss {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
