"""Serving launcher: load (or train briefly) an LM, fit the LSS head,
then serve one of three modes:

  * ``--mode generate`` (default) — blocking batched decode through the
    unified serving engine (``--runtime async`` instead serves open-loop
    next-token SCORING traffic: Poisson arrivals at ``--qps``, optional
    ``--deadline-ms`` load shedding).
  * ``--mode decode --streams N`` — streaming decode through the
    AsyncRuntime: open-loop Poisson SESSION arrivals at ``--qps``
    sessions/s (0 = burst), N concurrent streams interleaved in one
    fused decode step, per-token TokenStream futures, TTFT/ITL stats.

Observability: ``--metrics-port P`` starts the stdlib ``/metrics``
endpoint (Prometheus text; ``/metrics.json``, ``/trace`` too — see
``repro.obs.export``) BEFORE training begins, so a scraper can watch the
whole run; ``--hold-metrics S`` keeps the process (and endpoint) alive S
seconds after serving finishes so a one-shot scrape (CI) always lands.
``--audit-rate F`` samples fraction F of LSS-served scoring requests
through the online label-recall auditor (``lss_audit_recall_at_k``;
also settable via ``$REPRO_OBS_AUDIT_RATE``).

    python -m repro.launch.serve --arch qwen2-0.5b --reduced \
        --batch 16 --steps 32 [--head full|lss|lss-sharded] \
        [--runtime async --qps 500 --deadline-ms 50] \
        [--mode decode --streams 8 --sessions 32 --qps 0] \
        [--metrics-port 9100 --audit-rate 0.25 --hold-metrics 30]
"""

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--train-steps", type=int, default=150)
    ap.add_argument("--head", choices=("full", "lss", "lss-sharded"),
                    default="lss")
    ap.add_argument("--impl", choices=("ref", "pallas", "pallas_interpret"),
                    default=None,
                    help="pin the kernel-registry impl for the LSS head "
                         "(default: auto — pallas on TPU, ref elsewhere)")
    ap.add_argument("--dedup", choices=("quadratic", "bitonic"),
                    default=None,
                    help="pin the lss_topk cross-table dedup strategy "
                         "(default: auto — quadratic below the C "
                         "crossover, bitonic above)")
    ap.add_argument("--slab-dtype", choices=("fp32", "bf16", "int8"),
                    default=None,
                    help="bucket-major slab storage format for the LSS "
                         "index (default: lss_topk.slab_dtype strategy / "
                         "$REPRO_LSS_SLAB_DTYPE, auto -> fp32)")
    ap.add_argument("--no-lss", action="store_true",
                    help="legacy alias for --head full")
    ap.add_argument("--mode", choices=("generate", "decode"),
                    default="generate",
                    help="generate: blocking batched decode (or scoring "
                         "with --runtime async); decode: streaming "
                         "sessions through the AsyncRuntime")
    ap.add_argument("--runtime", choices=("sync", "async"), default="sync",
                    help="sync: blocking batched decode; async: open-loop "
                         "next-token scoring through the AsyncRuntime")
    ap.add_argument("--streams", type=int, default=8,
                    help="concurrent decode streams (KV-pool slots) for "
                         "--mode decode")
    ap.add_argument("--sessions", type=int, default=None,
                    help="decode sessions to submit (default: --batch)")
    ap.add_argument("--qps", type=float, default=500.0,
                    help="offered Poisson rate: requests/s for --runtime "
                         "async, sessions/s for --mode decode "
                         "(0 = burst: everything arrives at once)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request (or per-session) deadline; "
                         "already-late work is shed, not executed")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve Prometheus text on "
                         "http://127.0.0.1:PORT/metrics (0 = ephemeral; "
                         "started before training so the whole run is "
                         "observable)")
    ap.add_argument("--audit-rate", type=float, default=None,
                    help="online label-recall audit: fraction of "
                         "LSS-served scoring requests re-ranked through "
                         "the exact full head (default: "
                         "$REPRO_OBS_AUDIT_RATE, 0 = off)")
    ap.add_argument("--hold-metrics", type=float, default=0.0,
                    help="keep the process (and /metrics) alive this many "
                         "seconds after serving, for one-shot scrapers")
    ap.add_argument("--refresh-interval", type=float, default=None,
                    help="online index refresh: re-run IUL on the "
                         "calibration snapshot every S seconds and swap "
                         "the new index in without a serving pause "
                         "(default: off; $REPRO_REFRESH_INTERVAL sets "
                         "the cadence once enabled)")
    ap.add_argument("--refresh-probation", type=float, default=None,
                    help="seconds the recall auditor watches a freshly "
                         "swapped index before trusting it "
                         "($REPRO_REFRESH_PROBATION)")
    ap.add_argument("--refresh-rollback-delta", type=float, default=None,
                    help="roll the swap back if audited recall drops "
                         "more than this below the pre-swap baseline "
                         "($REPRO_REFRESH_ROLLBACK_DELTA)")
    ap.add_argument("--coordinator", default=None,
                    help="multi-host serving: jax.distributed coordinator "
                         "host:port (default: $REPRO_DIST_COORDINATOR); "
                         "run one launcher per process with the same "
                         "flags, distinct --process-id; --mode decode "
                         "is not supported on a fleet")
    ap.add_argument("--num-processes", type=int, default=None,
                    help="multi-host serving: fleet size (default: "
                         "$REPRO_DIST_NUM_PROCESSES; <= 1 = single-host)")
    ap.add_argument("--process-id", type=int, default=None,
                    help="multi-host serving: this process's rank "
                         "(default: $REPRO_DIST_PROCESS_ID; 0 owns "
                         "admission, others mirror in follower_loop)")
    args = ap.parse_args()
    head = "full" if args.no_lss else args.head

    server = None
    if args.metrics_port is not None:
        from repro.obs.export import MetricsServer
        server = MetricsServer(port=args.metrics_port)
        print(f"metrics: {server.url}")
    if args.audit_rate is not None:
        import os
        from repro import obs as _obs
        os.environ[_obs.AUDIT_RATE_ENV] = str(args.audit_rate)

    # BEFORE any jax computation: gloo selection + distributed init
    # (None on every arg falls back to the REPRO_DIST_COORDINATOR-family
    # env vars)
    from repro.serve.multihost import (follower_loop, init_multihost,
                                       stop_followers)
    import os
    from repro.utils.compat import (DIST_COORDINATOR_ENV,
                                    DIST_NUM_PROCESSES_ENV)
    n_proc = (args.num_processes if args.num_processes is not None
              else int(os.environ.get(DIST_NUM_PROCESSES_ENV, "1")))
    coord = args.coordinator or os.environ.get(DIST_COORDINATOR_ENV)
    if args.mode == "decode" and n_proc > 1 and coord:
        # streaming decode sessions are not routed through the OP_DECODE
        # opcode channel: the leader's fused decode steps embed fleet
        # collectives the followers would never enter, deadlocking at
        # the first generate.  Checked BEFORE distributed init (which
        # blocks until the whole fleet connects) from the same
        # flag/env defaults init_multihost resolves, so every process
        # fails fast and consistently instead of hanging.
        raise SystemExit(
            "--mode decode is not supported with multi-host serving "
            "(--coordinator/--num-processes): use --mode generate for "
            "blocking fleet decode, or --runtime async for open-loop "
            "scoring")
    ctx = init_multihost(args.coordinator, args.num_processes,
                         args.process_id)
    if ctx is not None:
        from repro.obs.export import set_global_labels
        set_global_labels(process=str(ctx.process_id))
        print(f"multihost: process {ctx.process_id}/{ctx.n_processes} "
              f"({'leader' if ctx.is_leader else 'follower'}), "
              f"{ctx.n_shards} vocab shards")

    import jax
    import jax.numpy as jnp
    from repro.configs.registry import get_config
    from repro.configs.reduced import reduced_model_cfg
    from repro.core.lss import LSSConfig
    from repro.data.pipeline import ShardedBatchIterator
    from repro.data.synthetic import lm_dataset
    from repro.models import transformer as T
    from repro.serve.engine import LMDecoder
    from repro.train.trainer import TrainConfig, Trainer

    spec = get_config(args.arch)
    cfg = reduced_model_cfg(args.arch) if args.reduced else spec.model_cfg
    cfg = cfg._replace(vocab=min(cfg.vocab, 4096) if args.reduced
                       else cfg.vocab)

    toks = lm_dataset(0, 150_000, cfg.vocab, 33)
    tc = TrainConfig(lr=3e-3, warmup_steps=15,
                     total_steps=args.train_steps, ckpt_every=10 ** 9)
    tr = Trainer(lambda p, b: T.lm_loss(p, b, cfg),
                 lambda k: T.init_params(k, cfg), tc)
    it = ShardedBatchIterator({"tokens": toks[:, :-1],
                               "labels": toks[:, 1:]}, 64)
    state, _ = tr.fit(jax.random.PRNGKey(0), it, args.train_steps,
                      log_every=10 ** 9)

    lss_cfg = LSSConfig(k_bits=6, n_tables=1, iul_epochs=4,
                        iul_inner_steps=8, iul_lr=0.02)
    # decode mode: --streams slots; generate mode: one slot per prompt
    # row so the batch decodes in a single wave, like the pre-streaming
    # loop.  Pool width covers the warm call's 2-step floor.
    n_slots = args.streams if args.mode == "decode" else args.batch
    dec = LMDecoder(state.params, cfg, lss_cfg, impl=args.impl,
                    max_streams=n_slots,
                    max_len=16 + max(args.steps, 2), dedup=args.dedup,
                    slab_dtype=args.slab_dtype, spmd=ctx)
    if head != "full":
        dec.fit_lss(jax.random.PRNGKey(1), jnp.asarray(toks[:128]))
    prompt = jnp.asarray(toks[500:500 + args.batch, :16])

    refresher = None
    if (args.refresh_interval is not None and head != "full"
            and (ctx is None or ctx.is_leader)):
        from repro.serve.refresh import IndexRefresher, RefreshConfig
        rcfg = RefreshConfig.from_env(interval_s=args.refresh_interval)
        if args.refresh_probation is not None:
            rcfg = rcfg._replace(probation_s=args.refresh_probation)
        if args.refresh_rollback_delta is not None:
            rcfg = rcfg._replace(
                rollback_delta=args.refresh_rollback_delta)
        refresher = IndexRefresher(dec.engine, cfg=rcfg).start()
        print(f"index refresh: every {rcfg.interval_s}s, probation "
              f"{rcfg.probation_s}s, rollback delta "
              f"{rcfg.rollback_delta}")

    try:
        if ctx is not None and not ctx.is_leader:
            # followers mirrored the (deterministic) train + fit above,
            # so their engine state matches the leader's; now replay the
            # leader's opcode stream until it stops us
            n = follower_loop(dec.engine, ctx, decoder=dec)
            print(f"follower {ctx.process_id}: {n} ops served")
        elif args.mode == "decode":
            serve_decode(dec, toks, head, args)
        elif args.runtime == "async":
            serve_async(dec, prompt, head, args)
        elif ctx is not None:
            from repro.serve.multihost import leader_generate
            out = leader_generate(ctx, dec, prompt, args.steps, head)
            print(f"decoded {out.shape} tokens on {ctx.n_processes} "
                  f"processes; head={head}")
            print(out[:2])
        else:
            out = dec.generate(prompt, steps=args.steps, head=head)
            print(f"decoded {out.shape} tokens; head={head}")
            print(out[:2])
            print(f"engine compiles (head, bucket): "
                  f"{dec.engine.compile_counts}")
    finally:
        # the exporter teardown gets its own finally: a wedged runtime
        # close (TimeoutError), a follower-stop failure, or an
        # interrupted hold must still release the /metrics port — a
        # leaked HTTP thread otherwise outlives the whole launch
        try:
            if refresher is not None:
                refresher.close()
            if ctx is not None and ctx.is_leader:
                stop_followers(ctx)
            if args.hold_metrics > 0:
                import time
                print(f"holding /metrics for {args.hold_metrics}s",
                      flush=True)
                time.sleep(args.hold_metrics)
        finally:
            if server is not None:
                server.close()


def serve_decode(dec, toks, head: str, args) -> None:
    """Streaming decode: open-loop decode SESSIONS through the
    AsyncRuntime at --qps sessions/s, --streams concurrent slots."""
    import numpy as np
    from repro.serve import AsyncRuntime
    from repro.serve.runtime import submit_decode_open_loop

    n_sessions = (args.sessions if args.sessions is not None
                  else args.batch)
    prompts = np.asarray(toks[500:500 + n_sessions, :16], np.int32)
    # warm every compile the run needs (prefill, bucket-1 first-token
    # step, fused decode step — steps >= 2, or the fused step never
    # dispatches), THEN fetch the scheduler: the warm call must not
    # outgrow and replace the pool the runtime is about to own (the
    # decoder's max_len already covers the 2-step floor)
    dec.generate(prompts[:1], steps=2, head=head)
    sched = dec.scheduler(head=head, min_len=16 + args.steps)
    sched.reset_stats()
    deadline_s = (None if args.deadline_ms is None
                  else args.deadline_ms / 1e3)
    with AsyncRuntime(dec.engine, head=head, policy="shed",
                      default_deadline_s=deadline_s,
                      scheduler=sched, close_timeout_s=600.0) as rt:
        streams, _ = submit_decode_open_loop(
            rt, list(prompts), args.qps, max_new_tokens=args.steps, seed=0)
        rt.drain(timeout=600.0)
        s = rt.stats()
    ok = sum(st.exception(timeout=1.0) is None for st in streams)
    print(f"streaming decode: head={head} streams={args.streams} "
          f"qps={args.qps} {ok}/{len(streams)} sessions served, "
          f"{s.n_decode_tokens} tokens")
    print(f"  {s.decode_tokens_per_s:,.0f} tok/s  "
          f"ttft p50={s.ttft_p50_ms:.2f} p95={s.ttft_p95_ms:.2f} "
          f"p99={s.ttft_p99_ms:.2f} ms (incl. queue wait)")
    print(f"  itl p50={s.itl_p50_ms:.2f} p95={s.itl_p95_ms:.2f} "
          f"p99={s.itl_p99_ms:.2f} ms  "
          f"slot occupancy={s.decode_slot_occupancy:.2f}")
    print(f"  shed: queue={s.n_shed_queue} deadline={s.n_shed_deadline}")
    print(f"engine compiles (head, shape): {dec.engine.compile_counts}")


def serve_async(dec, prompt, head: str, args) -> None:
    """Open-loop next-token scoring: prefill once, then submit each
    sequence's final hidden state as an independent rank request through
    the AsyncRuntime at the offered QPS."""
    import jax.numpy as jnp
    import numpy as np
    from repro.serve.runtime import AsyncRuntime, submit_open_loop

    hidden, _ = dec.T.prefill(dec.params, prompt, dec.cfg,
                              max_len=prompt.shape[1])
    h = np.asarray(hidden[:, -1].astype(jnp.float32))        # [B, d]
    reqs = np.tile(h, (max(1, args.steps), 1))               # B*steps reqs
    # compile every ladder bucket the run could coalesce into (any group
    # size <= the backlog's max chunk), so the measured segment reports
    # serving latency, not trace time — a cold 1-row bucket otherwise
    # costs a >1s trace and deadline-sheds the whole backlog behind it
    batcher = dec.engine.batcher
    b_max = batcher.bucket_for(min(reqs.shape[0], batcher.max_bucket))
    for b in [b for b in batcher.buckets if b <= b_max]:
        dec.engine.rank(np.zeros((b, reqs.shape[1]), np.float32),
                        head=head, record=False)
    deadline_s = (None if args.deadline_ms is None
                  else args.deadline_ms / 1e3)
    with AsyncRuntime(dec.engine, head=head, policy="shed",
                      default_deadline_s=deadline_s,
                      close_timeout_s=300.0) as rt:
        futs, _ = submit_open_loop(rt, reqs, args.qps, seed=0)
        rt.drain(timeout=300.0)
        s = rt.stats()
    ok = sum(f.exception() is None for f in futs)
    aud = dec.engine.auditor
    if aud is not None:
        aud.drain()
        print(f"  audit recall@k={aud.recall:.4f} over {aud.n_rows} "
              f"rows (sampled at {aud.rate})")
    print(f"async runtime: head={head} qps={args.qps} "
          f"{ok}/{len(futs)} served")
    print(f"  throughput={s.throughput_rps:,.0f} rps  "
          f"p50={s.latency_p50_ms:.2f} p95={s.latency_p95_ms:.2f} "
          f"p99={s.latency_p99_ms:.2f} ms (incl. queue wait)")
    print(f"  batches={s.n_batches} occupancy={s.avg_batch_occupancy:.2f} "
          f"shed: queue={s.n_shed_queue} deadline={s.n_shed_deadline}")
    print(f"engine compiles (head, bucket): {dec.engine.compile_counts}")


if __name__ == "__main__":
    main()
