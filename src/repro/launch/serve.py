"""Serving launcher: load (or train briefly) an LM, fit the LSS head,
decode batched requests through the unified serving engine.

    python -m repro.launch.serve --arch qwen2-0.5b --reduced \
        --batch 16 --steps 32 [--head full|lss|lss-sharded]
"""

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--train-steps", type=int, default=150)
    ap.add_argument("--head", choices=("full", "lss", "lss-sharded"),
                    default="lss")
    ap.add_argument("--impl", choices=("ref", "pallas", "pallas_interpret"),
                    default=None,
                    help="pin the kernel-registry impl for the LSS head "
                         "(default: auto — pallas on TPU, ref elsewhere)")
    ap.add_argument("--no-lss", action="store_true",
                    help="legacy alias for --head full")
    args = ap.parse_args()
    head = "full" if args.no_lss else args.head

    import jax
    import jax.numpy as jnp
    from repro.configs.registry import get_config
    from repro.configs.reduced import reduced_model_cfg
    from repro.core.lss import LSSConfig
    from repro.data.pipeline import ShardedBatchIterator
    from repro.data.synthetic import lm_dataset
    from repro.models import transformer as T
    from repro.serve.engine import LMDecoder
    from repro.train.trainer import TrainConfig, Trainer

    spec = get_config(args.arch)
    cfg = reduced_model_cfg(args.arch) if args.reduced else spec.model_cfg
    cfg = cfg._replace(vocab=min(cfg.vocab, 4096) if args.reduced
                       else cfg.vocab)

    toks = lm_dataset(0, 150_000, cfg.vocab, 33)
    tc = TrainConfig(lr=3e-3, warmup_steps=15,
                     total_steps=args.train_steps, ckpt_every=10 ** 9)
    tr = Trainer(lambda p, b: T.lm_loss(p, b, cfg),
                 lambda k: T.init_params(k, cfg), tc)
    it = ShardedBatchIterator({"tokens": toks[:, :-1],
                               "labels": toks[:, 1:]}, 64)
    state, _ = tr.fit(jax.random.PRNGKey(0), it, args.train_steps,
                      log_every=10 ** 9)

    lss_cfg = LSSConfig(k_bits=6, n_tables=1, iul_epochs=4,
                        iul_inner_steps=8, iul_lr=0.02)
    dec = LMDecoder(state.params, cfg, lss_cfg, impl=args.impl)
    if head != "full":
        dec.fit_lss(jax.random.PRNGKey(1), jnp.asarray(toks[:128]))
    prompt = jnp.asarray(toks[500:500 + args.batch, :16])
    out = dec.generate(prompt, steps=args.steps, head=head)
    print(f"decoded {out.shape} tokens; head={head}")
    print(out[:2])
    print(f"engine compiles (head, bucket): {dec.engine.compile_counts}")


if __name__ == "__main__":
    main()
