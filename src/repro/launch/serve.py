"""Serving launcher: load (or train briefly) an LM, fit the LSS head,
then either decode batched requests through the unified serving engine
(``--runtime sync``, the default) or serve open-loop scoring traffic
through the async runtime (``--runtime async``: Poisson arrivals at
``--qps``, optional ``--deadline-ms`` load shedding).

    python -m repro.launch.serve --arch qwen2-0.5b --reduced \
        --batch 16 --steps 32 [--head full|lss|lss-sharded] \
        [--runtime async --qps 500 --deadline-ms 50]
"""

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--train-steps", type=int, default=150)
    ap.add_argument("--head", choices=("full", "lss", "lss-sharded"),
                    default="lss")
    ap.add_argument("--impl", choices=("ref", "pallas", "pallas_interpret"),
                    default=None,
                    help="pin the kernel-registry impl for the LSS head "
                         "(default: auto — pallas on TPU, ref elsewhere)")
    ap.add_argument("--no-lss", action="store_true",
                    help="legacy alias for --head full")
    ap.add_argument("--runtime", choices=("sync", "async"), default="sync",
                    help="sync: blocking batched decode; async: open-loop "
                         "next-token scoring through the AsyncRuntime")
    ap.add_argument("--qps", type=float, default=500.0,
                    help="offered Poisson QPS for --runtime async "
                         "(0 = burst: all requests arrive at once)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline for --runtime async; "
                         "already-late requests are shed, not executed")
    args = ap.parse_args()
    head = "full" if args.no_lss else args.head

    import jax
    import jax.numpy as jnp
    from repro.configs.registry import get_config
    from repro.configs.reduced import reduced_model_cfg
    from repro.core.lss import LSSConfig
    from repro.data.pipeline import ShardedBatchIterator
    from repro.data.synthetic import lm_dataset
    from repro.models import transformer as T
    from repro.serve.engine import LMDecoder
    from repro.train.trainer import TrainConfig, Trainer

    spec = get_config(args.arch)
    cfg = reduced_model_cfg(args.arch) if args.reduced else spec.model_cfg
    cfg = cfg._replace(vocab=min(cfg.vocab, 4096) if args.reduced
                       else cfg.vocab)

    toks = lm_dataset(0, 150_000, cfg.vocab, 33)
    tc = TrainConfig(lr=3e-3, warmup_steps=15,
                     total_steps=args.train_steps, ckpt_every=10 ** 9)
    tr = Trainer(lambda p, b: T.lm_loss(p, b, cfg),
                 lambda k: T.init_params(k, cfg), tc)
    it = ShardedBatchIterator({"tokens": toks[:, :-1],
                               "labels": toks[:, 1:]}, 64)
    state, _ = tr.fit(jax.random.PRNGKey(0), it, args.train_steps,
                      log_every=10 ** 9)

    lss_cfg = LSSConfig(k_bits=6, n_tables=1, iul_epochs=4,
                        iul_inner_steps=8, iul_lr=0.02)
    dec = LMDecoder(state.params, cfg, lss_cfg, impl=args.impl)
    if head != "full":
        dec.fit_lss(jax.random.PRNGKey(1), jnp.asarray(toks[:128]))
    prompt = jnp.asarray(toks[500:500 + args.batch, :16])

    if args.runtime == "async":
        serve_async(dec, prompt, head, args)
        return

    out = dec.generate(prompt, steps=args.steps, head=head)
    print(f"decoded {out.shape} tokens; head={head}")
    print(out[:2])
    print(f"engine compiles (head, bucket): {dec.engine.compile_counts}")


def serve_async(dec, prompt, head: str, args) -> None:
    """Open-loop next-token scoring: prefill once, then submit each
    sequence's final hidden state as an independent rank request through
    the AsyncRuntime at the offered QPS."""
    import jax.numpy as jnp
    import numpy as np
    from repro.serve.runtime import AsyncRuntime, submit_open_loop

    hidden, _ = dec.T.prefill(dec.params, prompt, dec.cfg,
                              max_len=prompt.shape[1])
    h = np.asarray(hidden[:, -1].astype(jnp.float32))        # [B, d]
    reqs = np.tile(h, (max(1, args.steps), 1))               # B*steps reqs
    # compile every ladder bucket the run could coalesce into (any group
    # size <= the backlog's max chunk), so the measured segment reports
    # serving latency, not trace time — a cold 1-row bucket otherwise
    # costs a >1s trace and deadline-sheds the whole backlog behind it
    batcher = dec.engine.batcher
    b_max = batcher.bucket_for(min(reqs.shape[0], batcher.max_bucket))
    for b in [b for b in batcher.buckets if b <= b_max]:
        dec.engine.rank(np.zeros((b, reqs.shape[1]), np.float32),
                        head=head, record=False)
    deadline_s = (None if args.deadline_ms is None
                  else args.deadline_ms / 1e3)
    with AsyncRuntime(dec.engine, head=head, policy="shed",
                      default_deadline_s=deadline_s) as rt:
        futs, _ = submit_open_loop(rt, reqs, args.qps, seed=0)
        rt.drain(timeout=300.0)
        s = rt.stats()
    ok = sum(f.exception() is None for f in futs)
    print(f"async runtime: head={head} qps={args.qps} "
          f"{ok}/{len(futs)} served")
    print(f"  throughput={s.throughput_rps:,.0f} rps  "
          f"p50={s.latency_p50_ms:.2f} p95={s.latency_p95_ms:.2f} "
          f"p99={s.latency_p99_ms:.2f} ms (incl. queue wait)")
    print(f"  batches={s.n_batches} occupancy={s.avg_batch_occupancy:.2f} "
          f"shed: queue={s.n_shed_queue} deadline={s.n_shed_deadline}")
    print(f"engine compiles (head, bucket): {dec.engine.compile_counts}")


if __name__ == "__main__":
    main()
