"""Cell builders: (architecture x input shape) -> a lowerable step.

``build_cell(arch_id, shape_name, mesh)`` returns a ``Cell`` with the jit
target, ShapeDtypeStruct example args (NO device allocation), and explicit
in_shardings — the single entry point used by the dry-run, the roofline,
and the real launchers.
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchSpec
from repro.configs.registry import get_config
from repro.core.lss import LSSConfig, LSSIndex
from repro.core.sharded import sharded_lss_predict
from repro.core.tables import LSSTables
from repro.models import gnn, recsys
from repro.models import transformer as T
from repro.optim import adamw_init
from repro.train.trainer import TrainConfig, TrainState, make_train_step, \
    state_shardings
from repro.utils import compat
from repro.utils.sharding import specs_to_shardings

f32, bf16, i32 = jnp.float32, jnp.bfloat16, jnp.int32


class Cell(NamedTuple):
    arch_id: str
    shape_name: str
    fn: Callable
    args: tuple                 # ShapeDtypeStructs / pytrees thereof
    in_shardings: tuple
    model_flops: float          # analytic useful FLOPs (6ND style)
    comment: str = ""
    # cost_analysis counts scan bodies once (trip count ignored).  Layer
    # stacks are unrolled for the dry-run; the remaining intra-attention
    # chunk scans are corrected analytically (global FLOPs to add).
    flops_correction: float = 0.0
    donate_state: bool = False  # train cells donate (params, opt) buffers


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _pad_up(n: int, mult: int) -> int:
    """pjit in_shardings require divisible input dims; models tolerate
    padded rows (-1 ids / zero rows) by construction."""
    return -(-n // mult) * mult


def _replicated(mesh, tree):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def _data_spec(mesh, tree, ndims: dict | None = None):
    def one(leaf):
        return NamedSharding(mesh, P(
            "data", *([None] * (len(leaf.shape) - 1))))
    return jax.tree.map(one, tree)


# ===================================================================== LM ==

def _lm_state_sds(cfg: T.TransformerConfig, opt_dtype) -> TrainState:
    params = jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    opt = jax.eval_shape(lambda: adamw_init(params, opt_dtype))
    return TrainState(params, opt, _sds((), i32))


def _attn_scan_steps(cfg, sl: int) -> int:
    nq = max(1, sl // cfg.q_chunk) if sl > cfg.q_chunk else 1
    nk = max(1, -(-sl // cfg.kv_chunk))
    return nq * nk


def _lm_train_cell(spec: ArchSpec, shape, mesh: Mesh) -> Cell:
    cfg = spec.model_cfg
    opt_dtype = bf16 if "arctic" in spec.arch_id else f32
    tc = TrainConfig(opt_state_dtype=opt_dtype, microbatches=1)
    loss_fn = functools.partial(_lm_loss_fn, cfg=cfg)
    step = make_train_step(loss_fn, tc)
    gb, sl = shape.dims["global_batch"], shape.dims["seq_len"]
    state = _lm_state_sds(cfg, opt_dtype)
    batch = {"tokens": _sds((gb, sl), i32), "labels": _sds((gb, sl), i32)}
    sh_state = state_shardings(mesh, T.param_specs(cfg))
    sh_batch = _data_spec(mesh, batch)
    # 6ND + attention term 12*L*n*h*S per token (causal halves it)
    n_active = cfg.active_param_count()
    tokens = gb * sl
    attn = 2 * 2 * cfg.n_layers * cfg.n_heads * cfg.head_dim * sl / 2
    mf = 3 * (2 * n_active + attn) * tokens    # fwd + 2x bwd
    # blockwise attention computes full S^2 (masked); scan counted once.
    # train = fwd + remat-fwd + 2x bwd = 4 passes.
    steps_ = _attn_scan_steps(cfg, sl)
    attn_full = 4 * gb * sl * sl * cfg.n_heads * cfg.head_dim \
        * cfg.n_layers * 4
    corr = attn_full * (1 - 1 / steps_)
    return Cell(spec.arch_id, shape.name, step, (state, batch),
                (sh_state, sh_batch), mf, "train_step w/ AdamW",
                flops_correction=corr, donate_state=True)


def _lm_loss_fn(params, batch, cfg):
    return T.lm_loss(params, batch, cfg)


def _lm_prefill_cell(spec: ArchSpec, shape, mesh: Mesh) -> Cell:
    cfg = spec.model_cfg
    gb, sl = shape.dims["global_batch"], shape.dims["seq_len"]

    def fn(params, tokens):
        hidden, cache = T.prefill(params, tokens, cfg, max_len=sl)
        return hidden[:, -1], cache

    params = jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    tokens = _sds((gb, sl), i32)
    sh = (specs_to_shardings(mesh, T.param_specs(cfg)),
          NamedSharding(mesh, P("data", None)))
    n_active = cfg.active_param_count()
    attn = 2 * 2 * cfg.n_layers * cfg.n_heads * cfg.head_dim * sl / 2
    mf = (2 * n_active + attn) * gb * sl
    steps_ = _attn_scan_steps(cfg, sl)
    attn_full = 4 * gb * sl * sl * cfg.n_heads * cfg.head_dim * cfg.n_layers
    corr = attn_full * (1 - 1 / steps_)
    return Cell(spec.arch_id, shape.name, fn, (params, tokens), sh, mf,
                "prefill -> (last hidden, kv cache)",
                flops_correction=corr)


def _lss_index_sds(lss: LSSConfig, m_local: int, d_aug: int, tp: int):
    """Stacked per-shard LSS index ShapeDtypeStructs ([tp, ...] leaves)."""
    cap = lss.resolve_capacity(m_local)
    nb = 2 ** lss.k_bits
    tables = LSSTables(
        table_ids=_sds((tp, lss.n_tables, nb, cap), i32),
        n_dropped=_sds((tp, lss.n_tables), i32),
        k_bits=lss.k_bits, n_tables=lss.n_tables, capacity=cap)
    return LSSIndex(
        theta=_sds((tp, d_aug, lss.k_bits * lss.n_tables), f32),
        tables=tables,
        w_bucketed=_sds((tp, lss.n_tables, nb, cap, d_aug), bf16))


def _lm_decode_cell(spec: ArchSpec, shape, mesh: Mesh) -> Cell:
    cfg = spec.model_cfg
    gb, sl = shape.dims["global_batch"], shape.dims["seq_len"]
    tp = mesh.shape["model"]
    m_local = -(-cfg.vocab // tp)
    lss = spec.lss
    d_aug = cfg.d_model + 1

    def fn(params, token, cache, index_stack):
        hidden, new_cache = T.decode_step(params, token, cache, cfg)
        # vocab-sharded LSS head (paper Algorithm 2, distributed)
        body = functools.partial(sharded_lss_predict, k=8,
                                 axis_name="model", m_local=m_local)

        def unstack(q, idx):
            return body(q, jax.tree.map(lambda x: x[0], idx), None)

        idx_specs = jax.tree.map(lambda _: P("model"), index_stack)
        logits, ids = compat.shard_map(
            unstack, mesh=mesh,
            in_specs=(P(), idx_specs),
            out_specs=(P(), P()))(hidden.astype(f32), index_stack)
        return logits, ids, new_cache

    params = jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    token = _sds((gb,), i32)
    cache = T.KVCache(
        k=_sds((cfg.n_layers, gb, sl, cfg.n_kv_heads, cfg.head_dim), bf16),
        v=_sds((cfg.n_layers, gb, sl, cfg.n_kv_heads, cfg.head_dim), bf16),
        length=_sds((), i32))
    index = _lss_index_sds(lss, m_local, d_aug, tp)
    cache_spec = specs_to_shardings(mesh, T.cache_specs(cfg, gb))
    sh = (specs_to_shardings(mesh, T.param_specs(cfg)),
          NamedSharding(mesh, P()),
          cache_spec,
          jax.tree.map(lambda _: NamedSharding(mesh, P("model")), index))
    # decode useful FLOPs: 2*N_active per token + KV attention 4*L*kv*h*S
    n_active = cfg.active_param_count() - cfg.vocab * cfg.d_model  # LSS head!
    attn = 2 * 2 * cfg.n_layers * cfg.n_heads * cfg.head_dim * sl
    cap = index.tables.capacity
    lss_flops = 2 * d_aug * (lss.k_bits * lss.n_tables + lss.n_tables * cap)
    mf = (2 * n_active + attn + lss_flops * tp) * gb
    return Cell(spec.arch_id, shape.name, fn, (params, token, cache, index),
                sh, mf, "decode_step + vocab-sharded LSS head")


# ==================================================================== GNN ==

def _gnn_train_cell(spec: ArchSpec, shape, mesh: Mesh) -> Cell:
    dims = shape.dims
    cfg = spec.model_cfg._replace(d_feat=dims["d_feat"],
                                  n_classes=dims["n_classes"])
    tc = TrainConfig()
    loss_fn = functools.partial(_gnn_loss_fn, cfg=cfg)
    step = make_train_step(loss_fn, tc)
    state = _gnn_state_sds(cfg)
    dp = mesh.shape["data"]
    n_pad = _pad_up(dims["n_nodes"], dp)
    e_pad = _pad_up(dims["n_edges"], dp)
    batch = {
        "x": _sds((n_pad, dims["d_feat"]), f32),
        "edges": _sds((e_pad, 2), i32),
        "labels": _sds((n_pad,), i32),
    }
    sh_state = state_shardings(mesh, gnn.param_specs(cfg))
    sh_batch = _data_spec(mesh, batch)
    e, n = dims["n_edges"], dims["n_nodes"]
    d0, dh, c = dims["d_feat"], cfg.d_hidden, dims["n_classes"]
    mf = 3 * (2 * n * (d0 * dh + dh * c) + 2 * e * (d0 + dh))
    return Cell(spec.arch_id, shape.name, step, (state, batch),
                (sh_state, sh_batch), mf, "full-batch GCN train_step",
                donate_state=True)


def _gnn_loss_fn(params, batch, cfg):
    return gnn.loss(params, batch, cfg)


def _gnn_state_sds(cfg) -> TrainState:
    params = jax.eval_shape(
        lambda: gnn.init_params(jax.random.PRNGKey(0), cfg))
    opt = jax.eval_shape(lambda: adamw_init(params, f32))
    return TrainState(params, opt, _sds((), i32))


def _gnn_minibatch_cell(spec: ArchSpec, shape, mesh: Mesh) -> Cell:
    dims = shape.dims
    cfg = spec.model_cfg._replace(d_feat=dims["d_feat"],
                                  n_classes=dims["n_classes"])
    fanout = dims["fanout"]
    bn = dims["batch_nodes"]
    tc = TrainConfig()

    def loss_fn(params, batch):
        nodes, edges = gnn.sampled_subgraph(
            batch["key"], batch["indptr"], batch["indices"],
            batch["seeds"], fanout)
        x = batch["x"][nodes]
        labels = jnp.full((nodes.shape[0],), -1, i32)
        labels = labels.at[:bn].set(batch["seed_labels"])
        return gnn.loss(params, {"x": x, "edges": edges, "labels": labels},
                        cfg)

    step = make_train_step(loss_fn, tc)
    state = _gnn_state_sds(cfg)
    both = mesh.shape["data"] * mesh.shape["model"]
    batch = {
        "key": _sds((2,), jnp.uint32),
        "indptr": _sds((dims["n_nodes"] + 1,), i32),
        "indices": _sds((_pad_up(dims["n_edges"], both),), i32),
        "seeds": _sds((bn,), i32),
        "seed_labels": _sds((bn,), i32),
        "x": _sds((_pad_up(dims["n_nodes"], both), dims["d_feat"]), f32),
    }
    sh_state = state_shardings(mesh, gnn.param_specs(cfg))
    sh_batch = {
        "key": NamedSharding(mesh, P()),
        "indptr": NamedSharding(mesh, P()),
        "indices": NamedSharding(mesh, P(("data", "model"))),
        "seeds": NamedSharding(mesh, P("data")),
        "seed_labels": NamedSharding(mesh, P("data")),
        "x": NamedSharding(mesh, P(("data", "model"), None)),
    }
    blk = bn * (1 + fanout[0] + fanout[0] * fanout[1])
    mf = 3 * 2 * blk * (dims["d_feat"] * cfg.d_hidden
                        + cfg.d_hidden * dims["n_classes"])
    return Cell(spec.arch_id, shape.name, step, (state, batch),
                (sh_state, sh_batch), mf,
                "fanout-sampled GCN train_step (sampler in-graph)",
                donate_state=True)


def _gnn_molecule_cell(spec: ArchSpec, shape, mesh: Mesh) -> Cell:
    dims = shape.dims
    cfg = spec.model_cfg._replace(d_feat=dims["d_feat"],
                                  n_classes=dims["n_classes"],
                                  readout="mean")
    tc = TrainConfig()
    loss_fn = functools.partial(_mol_loss_fn, cfg=cfg)
    step = make_train_step(loss_fn, tc)
    state = _gnn_state_sds(cfg)
    g, n, e = dims["batch"], dims["n_nodes"], dims["n_edges"]
    batch = {
        "x": _sds((g, n, dims["d_feat"]), f32),
        "edges": _sds((g, e, 2), i32),
        "labels": _sds((g,), i32),
    }
    sh_state = state_shardings(mesh, gnn.param_specs(cfg))
    sh_batch = _data_spec(mesh, batch)
    mf = 3 * 2 * g * n * (dims["d_feat"] * cfg.d_hidden
                          + cfg.d_hidden * dims["n_classes"])
    return Cell(spec.arch_id, shape.name, step, (state, batch),
                (sh_state, sh_batch), mf, "batched small-graph train_step",
                donate_state=True)


def _mol_loss_fn(params, batch, cfg):
    return gnn.molecule_loss(params, batch, cfg)


# ================================================================= RecSys ==

def _ctr_logits(params, batch, cfg):
    if cfg.kind == "deepfm":
        return recsys.deepfm_logits(params, batch["ids"], cfg)
    if cfg.kind == "autoint":
        return recsys.autoint_logits(params, batch["ids"], cfg)
    if cfg.kind == "dien":
        return recsys.dien_logits(
            params, {"hist": batch["hist"], "target": batch["target"]}, cfg)
    raise ValueError(cfg.kind)


def _ctr_loss(params, batch, cfg):
    lg = _ctr_logits(params, batch, cfg)
    y = batch["labels"].astype(f32)
    return jnp.mean(jnp.maximum(lg, 0) - lg * y + jnp.log1p(jnp.exp(-jnp.abs(lg))))


def _ctr_init(cfg):
    if cfg.kind == "deepfm":
        return recsys.init_deepfm, recsys.deepfm_specs
    if cfg.kind == "autoint":
        return recsys.init_autoint, recsys.autoint_specs
    return recsys.init_dien, recsys.dien_specs


def _ctr_batch_sds(cfg, b):
    if cfg.kind == "dien":
        return {"hist": _sds((b, cfg.seq_len), i32), "target": _sds((b,), i32),
                "labels": _sds((b,), i32)}
    return {"ids": _sds((b, cfg.n_fields), i32), "labels": _sds((b,), i32)}


def _ctr_flops(cfg, b):
    d = cfg.embed_dim
    if cfg.kind == "deepfm":
        dims = [cfg.n_fields * d, *cfg.mlp_dims, 1]
        mlp = sum(2 * dims[i] * dims[i + 1] for i in range(len(dims) - 1))
        return b * (mlp + 2 * cfg.n_fields * d)
    if cfg.kind == "autoint":
        da = cfg.d_attn * cfg.n_heads
        f = cfg.n_fields
        per_layer = 2 * f * (4 * d * da) + 4 * f * f * da
        return b * cfg.n_attn_layers * per_layer
    g = cfg.gru_dim
    per_t = 2 * (d * 3 * g + g * 3 * g) * 2       # gru1 + augru
    dims = [g + 2 * d, *cfg.mlp_dims, 1]
    mlp = sum(2 * dims[i] * dims[i + 1] for i in range(len(dims) - 1))
    return b * (cfg.seq_len * per_t + mlp)


def _ctr_train_cell(spec: ArchSpec, shape, mesh: Mesh) -> Cell:
    cfg = spec.model_cfg._replace(unroll_scan=True)
    b = shape.dims["batch"]
    init_fn, specs_fn = _ctr_init(cfg)
    tc = TrainConfig()
    loss_fn = functools.partial(_ctr_loss, cfg=cfg)
    step = make_train_step(loss_fn, tc)
    params = jax.eval_shape(lambda: init_fn(jax.random.PRNGKey(0), cfg))
    opt = jax.eval_shape(lambda: adamw_init(params, f32))
    state = TrainState(params, opt, _sds((), i32))
    batch = _ctr_batch_sds(cfg, b)
    sh_state = state_shardings(mesh, specs_fn(cfg))
    sh_batch = _data_spec(mesh, batch)
    return Cell(spec.arch_id, shape.name, step, (state, batch),
                (sh_state, sh_batch), 3 * _ctr_flops(cfg, b),
                "CTR train_step (BCE)", donate_state=True)


def _ctr_serve_cell(spec: ArchSpec, shape, mesh: Mesh) -> Cell:
    cfg = spec.model_cfg._replace(unroll_scan=True)
    b = shape.dims["batch"]
    init_fn, specs_fn = _ctr_init(cfg)

    def fn(params, batch):
        return jax.nn.sigmoid(_ctr_logits(params, batch, cfg))

    params = jax.eval_shape(lambda: init_fn(jax.random.PRNGKey(0), cfg))
    batch = _ctr_batch_sds(cfg, b)
    batch.pop("labels")
    sh = (specs_to_shardings(mesh, specs_fn(cfg)), _data_spec(mesh, batch))
    return Cell(spec.arch_id, shape.name, fn, (params, batch), sh,
                _ctr_flops(cfg, b), "CTR serve_step")


def _ctr_retrieval_cell(spec: ArchSpec, shape, mesh: Mesh) -> Cell:
    cfg = spec.model_cfg._replace(unroll_scan=True)
    c = shape.dims["n_candidates"]
    init_fn, specs_fn = _ctr_init(cfg)

    if cfg.kind == "dien":
        def fn(params, hist, cand):
            hist_b = jnp.broadcast_to(hist, (c,) + hist.shape[1:])
            return jax.nn.sigmoid(recsys.dien_logits(
                params, {"hist": hist_b, "target": cand}, cfg))
        user = _sds((1, cfg.seq_len), i32)
    else:
        def fn(params, user, cand):
            ids = jnp.concatenate(
                [cand[:, None],
                 jnp.broadcast_to(user[:, 1:], (c, cfg.n_fields - 1))], 1)
            return jax.nn.sigmoid(_ctr_logits(params, {"ids": ids}, cfg))
        user = _sds((1, cfg.n_fields), i32)

    params = jax.eval_shape(lambda: init_fn(jax.random.PRNGKey(0), cfg))
    cand = _sds((c,), i32)     # 1e6 % 16 == 0: shard over data only
    sh = (specs_to_shardings(mesh, specs_fn(cfg)),
          NamedSharding(mesh, P()),
          NamedSharding(mesh, P("data")))
    return Cell(spec.arch_id, shape.name, fn, (params, user, cand), sh,
                _ctr_flops(cfg, c), "1 query x 1M candidate scoring")


# BERT4Rec --------------------------------------------------------------

_N_MASK = 20        # masked positions per sequence (cloze)
_N_NEG = 8192       # sampled-softmax negatives (training only)


def _b4r_sampled_loss(params, batch, cfg):
    """Cloze with sampled softmax: full 1M softmax at train time is the
    exact cost LSS removes at serve time; sampled softmax is the standard
    training-side treatment (logQ-corrected in spirit; uniform here)."""
    hidden = recsys.bert4rec_encode(params, batch["seq"], cfg)
    hsel = jnp.take_along_axis(
        hidden, batch["mask_pos"][..., None], axis=1)       # [B, M, D]
    pos_rows = params["head"][batch["mask_labels"]]          # [B, M, D]
    neg_rows = params["head"][batch["neg_ids"]]              # [Nneg, D]
    pos_logit = jnp.einsum("bmd,bmd->bm", hsel, pos_rows).astype(f32)
    neg_logit = jnp.einsum("bmd,nd->bmn", hsel, neg_rows).astype(f32)
    logz = jnp.logaddexp(pos_logit, jax.nn.logsumexp(neg_logit, -1))
    return jnp.mean(logz - pos_logit)


def _b4r_train_cell(spec: ArchSpec, shape, mesh: Mesh) -> Cell:
    cfg = spec.model_cfg
    b = shape.dims["batch"]
    tc = TrainConfig()
    loss_fn = functools.partial(_b4r_sampled_loss, cfg=cfg)
    step = make_train_step(loss_fn, tc)
    params = jax.eval_shape(
        lambda: recsys.init_bert4rec(jax.random.PRNGKey(0), cfg))
    opt = jax.eval_shape(lambda: adamw_init(params, f32))
    state = TrainState(params, opt, _sds((), i32))
    batch = {
        "seq": _sds((b, cfg.seq_len), i32),
        "mask_pos": _sds((b, _N_MASK), i32),
        "mask_labels": _sds((b, _N_MASK), i32),
        "neg_ids": _sds((_N_NEG,), i32),
    }
    sh_state = state_shardings(mesh, recsys.bert4rec_specs(cfg))
    sh_batch = _data_spec(mesh, batch)
    sh_batch["neg_ids"] = NamedSharding(mesh, P())
    d = cfg.embed_dim
    enc = cfg.n_blocks * (8 * d * d + 4 * cfg.seq_len * d) * cfg.seq_len * 2
    head = 2 * _N_MASK * (_N_NEG + 1) * d
    return Cell(spec.arch_id, shape.name, step, (state, batch),
                (sh_state, sh_batch), 3 * b * (enc + head),
                "cloze train_step (sampled softmax)", donate_state=True)


def _b4r_serve_cell(spec: ArchSpec, shape, mesh: Mesh) -> Cell:
    """Encode + vocab-sharded LSS top-k over the 1M-item WOL."""
    cfg = spec.model_cfg
    b = shape.dims.get("batch", 1)
    tp = mesh.shape["model"]
    m_local = -(-cfg.n_items // tp)
    lss = spec.lss
    d_aug = cfg.embed_dim + 1

    def fn(params, seq, index_stack):
        hidden = recsys.bert4rec_encode(params, seq, cfg)
        q = hidden[:, -1].astype(f32)
        body = functools.partial(sharded_lss_predict, k=10,
                                 axis_name="model", m_local=m_local)

        def unstack(qq, idx):
            return body(qq, jax.tree.map(lambda x: x[0], idx), None)

        idx_specs = jax.tree.map(lambda _: P("model"), index_stack)
        return compat.shard_map(
            unstack, mesh=mesh, in_specs=(P(), idx_specs),
            out_specs=(P(), P()))(q, index_stack)

    params = jax.eval_shape(
        lambda: recsys.init_bert4rec(jax.random.PRNGKey(0), cfg))
    seq = _sds((b, cfg.seq_len), i32)
    index = _lss_index_sds(lss, m_local, d_aug, tp)
    # encoder is replicated (hillclimb 3 iter 1), so its batch can shard
    # over BOTH axes; only the [B, 64] query vectors all-gather over
    # 'model' at the shard_map boundary (iter 2).
    nd = mesh.shape["data"] * tp
    seq_spec = (P(("data", "model"), None) if b % nd == 0
                else P("data", None) if b % mesh.shape["data"] == 0
                else P())
    sh = (specs_to_shardings(mesh, recsys.bert4rec_specs(cfg)),
          NamedSharding(mesh, seq_spec),
          jax.tree.map(lambda _: NamedSharding(mesh, P("model")), index))
    d = cfg.embed_dim
    enc = cfg.n_blocks * (8 * d * d + 4 * cfg.seq_len * d) * cfg.seq_len * 2
    cap = index.tables.capacity
    lss_fl = 2 * d_aug * (lss.k_bits + cap) * tp
    return Cell(spec.arch_id, shape.name, fn, (params, seq, index), sh,
                b * (enc + lss_fl), "encode + sharded LSS item retrieval")


def _b4r_retrieval_cell(spec: ArchSpec, shape, mesh: Mesh) -> Cell:
    # retrieval_cand: batch=1 against the full 1M catalogue — identical
    # pipeline to serve, batch 1 (the paper's Table-1 setting).
    return _b4r_serve_cell(spec, shape, mesh)


# =============================================================== dispatch ==

def build_cell(arch_id: str, shape_name: str, mesh: Mesh, *,
               lm_layers: int | None = None,
               lm_impl: str = "unroll") -> Cell:
    """``lm_layers``/``lm_impl``: the dry-run compiles LM cells three ways
    — full depth with scan (the production graph: pass/fail + memory
    proof) and unrolled at 2 and 4 layers (XLA cost_analysis ignores scan
    trip counts; the per-layer slope extrapolates exact FLOP/byte/
    collective counts to full depth)."""
    spec = get_config(arch_id)
    shape = spec.shape(shape_name)
    if spec.family == "lm":
        # grouped dispatch pays off on big token batches (train/prefill);
        # at decode (<=128 tokens/step) per-group capacity padding costs
        # more than the scatter locality buys (measured 0.7x) — 1 group.
        groups = mesh.shape["data"] if shape.kind in ("train", "prefill") \
            else 1
        mc = spec.model_cfg._replace(
            n_layers=lm_layers or spec.model_cfg.n_layers,
            layers_impl=lm_impl,
            moe_groups=groups)
        spec = spec._replace(model_cfg=mc)
        if shape.kind == "train":
            return _lm_train_cell(spec, shape, mesh)
        if shape.kind == "prefill":
            return _lm_prefill_cell(spec, shape, mesh)
        return _lm_decode_cell(spec, shape, mesh)
    if spec.family == "gnn":
        if shape.kind == "train_sampled":
            return _gnn_minibatch_cell(spec, shape, mesh)
        if shape.kind == "train_batched":
            return _gnn_molecule_cell(spec, shape, mesh)
        return _gnn_train_cell(spec, shape, mesh)
    if spec.family == "recsys_ctr":
        if shape.kind == "train":
            return _ctr_train_cell(spec, shape, mesh)
        if shape.kind == "retrieval":
            return _ctr_retrieval_cell(spec, shape, mesh)
        return _ctr_serve_cell(spec, shape, mesh)
    if spec.family == "recsys_seq":
        if shape.kind == "train":
            return _b4r_train_cell(spec, shape, mesh)
        if shape.kind == "retrieval":
            return _b4r_retrieval_cell(spec, shape, mesh)
        return _b4r_serve_cell(spec, shape, mesh)
    raise ValueError(spec.family)
