"""Symmetric int8 quantization primitives + cross-pod gradient compression.

Two consumers share the same rowwise quantizer:

* **Gradient compression** (:func:`compressed_psum`): on a multi-pod mesh
  the 'pod' axis crosses data-center interconnect (~10x slower than ICI).
  The standard trick (1-bit Adam / error-feedback SGD lineage): keep
  in-pod reductions full-precision, quantize only the cross-pod exchange,
  and carry the quantization error into the next step so the compression
  is unbiased over time.

      g_pod      = in-pod mean grad           (full precision, fast links)
      q, scale   = quantize_int8(g_pod + err)
      g_global   = dequant(all_reduce_over_pods(q))
      err'       = (g_pod + err) - dequant(q)

  Implemented as pure functions usable inside a pjit'd train step via
  shard_map over the 'pod' axis; per-tensor block scales keep the quant
  error small (block = last axis rows).

* **Quantized LSS slab storage** (``kernels.lss_topk.slabs``): the serving
  index stores its bucket-major WOL slabs int8 with one
  :func:`quantize_int8_rows` scale per neuron row, and the fused kernel
  dequantizes on the fly inside its MXU matmul.  That path needs the
  per-ROW form (a row == one neuron's ``[d]`` vector, the natural unit a
  score-aware quantizer must preserve), so the rowwise primitive is
  public and the blockwise gradient form is a reshape over it.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "quantize_int8_rows",
           "dequantize_int8_rows", "compressed_psum", "init_error_state"]

_BLOCK = 256


def _blocked(x: jax.Array) -> jax.Array:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % _BLOCK
    return jnp.pad(flat, (0, pad)).reshape(-1, _BLOCK)


def quantize_int8_rows(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric int8 along the LAST axis: one scale per leading row.

    ``[..., d] -> (q int8 [..., d], scale f32 [...])`` with
    ``scale = max|row| / 127 + eps`` (the eps keeps all-zero rows — e.g.
    empty LSS bucket slots — dequantizing to exactly 0 instead of NaN).
    """
    rows = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(rows), axis=-1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(rows / scale[..., None]), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def dequantize_int8_rows(q: jax.Array, scale: jax.Array,
                         dtype=jnp.float32) -> jax.Array:
    """Inverse of :func:`quantize_int8_rows`:
    ``q [..., d] * scale [..., None] -> [..., d]``."""
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Blockwise symmetric int8. Returns (q [nb, B] int8, scale [nb] f32)."""
    return quantize_int8_rows(_blocked(x))


def dequantize_int8(q: jax.Array, scale: jax.Array, shape: tuple,
                    dtype) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def init_error_state(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(grads: Any, err: Any, axis_name: str
                    ) -> tuple[Any, Any]:
    """Error-feedback int8 mean-all-reduce over ``axis_name``.

    Call INSIDE shard_map where ``axis_name`` maps to the pod axis.
    Returns (global grads, new error state).  Traffic: 1 byte/element
    + 4/256 for scales vs 4 bytes/element uncompressed (~3.9x).
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = quantize_int8(corrected)
        deq_local = dequantize_int8(q, scale, g.shape, jnp.float32)
        new_err = corrected - deq_local
        # exchange int8 payloads + tiny scales (the 1-byte/elt wire format;
        # ~8x less DCI traffic than an fp32 ring all-reduce), dequantize
        # each pod's contribution locally, mean.
        q_all = jax.lax.all_gather(q, axis_name)              # [n, nb, B] i8
        s_all = jax.lax.all_gather(scale, axis_name)          # [n, nb]
        deq = jnp.sum(q_all.astype(jnp.float32) * s_all[..., None], axis=0)
        flat = deq.reshape(-1)[: corrected.size].reshape(g.shape)
        return (flat / n).astype(g.dtype), new_err

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))
