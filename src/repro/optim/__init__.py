from repro.optim.adamw import (AdamWState, adamw_init, adamw_update,
                               clip_by_global_norm)
from repro.optim.schedules import (constant_schedule, cosine_schedule,
                                   linear_warmup_cosine)

__all__ = [
    "AdamWState", "adamw_init", "adamw_update", "clip_by_global_norm",
    "constant_schedule", "cosine_schedule", "linear_warmup_cosine",
]
