"""AdamW + gradient clipping, written on raw pytrees (no optax at scale:
states shard exactly like params under pjit, nothing else to annotate)."""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "adamw_init", "adamw_update", "clip_by_global_norm"]


class AdamWState(NamedTuple):
    step: jax.Array   # int32 []
    mu: Any           # pytree like params
    nu: Any           # pytree like params


def adamw_init(params: Any, dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    """Returns (clipped grads, pre-clip global norm)."""
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


def adamw_update(grads: Any, state: AdamWState, params: Any, *,
                 lr: float | jax.Array, b1: float = 0.9, b2: float = 0.999,
                 eps: float = 1e-8, weight_decay: float = 0.0,
                 ) -> tuple[Any, AdamWState]:
    """One AdamW step. ``lr`` may be a traced scalar (schedule output)."""
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu = b1 * mu + (1.0 - b1) * g32
        nu = b2 * nu + (1.0 - b2) * jnp.square(g32)
        update = (mu / bc1) / (jnp.sqrt(nu / bc2) + eps)
        new_p = p.astype(jnp.float32) - lr * (update + weight_decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_mu, new_nu)
