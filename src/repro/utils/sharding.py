"""Sharding helpers: apply constraints only when a mesh is active, so the
same model code runs in single-device tests and under the production mesh."""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.utils.compat import get_abstract_mesh

__all__ = ["maybe_shard", "named_sharding", "specs_to_shardings"]


def _active_mesh_axes() -> tuple[str, ...] | None:
    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty:
        return None
    return tuple(mesh.axis_names)


def mesh_axis_size(name: str) -> int | None:
    """Size of a mesh axis at trace time, or None outside a mesh."""
    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty or name not in mesh.axis_names:
        return None
    return mesh.shape[name]


def maybe_shard(x: jax.Array, spec: P) -> jax.Array:
    """with_sharding_constraint if a mesh with the spec's axes is active;
    identity otherwise (CPU unit tests, single-device smoke runs)."""
    axes = _active_mesh_axes()
    if axes is None:
        return x
    used = {a for part in spec if part is not None
            for a in ((part,) if isinstance(part, str) else tuple(part))}
    if not used.issubset(set(axes)):
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def named_sharding(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def specs_to_shardings(mesh: Mesh, specs: Any) -> Any:
    """Map a pytree of PartitionSpecs to NamedShardings on ``mesh``,
    dropping axis names the mesh doesn't have (e.g. 'pod' on single-pod)."""
    names = set(mesh.axis_names)

    def fix(spec: P) -> NamedSharding:
        parts = []
        for part in spec:
            if part is None:
                parts.append(None)
            elif isinstance(part, str):
                parts.append(part if part in names else None)
            else:  # tuple of axes
                kept = tuple(a for a in part if a in names)
                parts.append(kept if kept else None)
        return NamedSharding(mesh, P(*parts))

    return jax.tree.map(fix, specs,
                        is_leaf=lambda s: isinstance(s, P))
