from repro.utils.sharding import maybe_shard, named_sharding, specs_to_shardings

__all__ = ["maybe_shard", "named_sharding", "specs_to_shardings"]
