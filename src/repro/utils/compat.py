"""Version-tolerant wrappers for jax APIs that moved between releases.

The repo pins jax 0.4.37 (the jaxlib in the image), but the sharding API
surface it uses was renamed upstream several times:

  * ``jax.sharding.get_abstract_mesh``  -> pre-0.5: thread-resources mesh
  * ``jax.set_mesh(mesh)`` context      -> pre-0.5: ``with mesh:``
  * ``jax.shard_map(..., check_vma=)``  -> pre-0.5:
    ``jax.experimental.shard_map.shard_map(..., check_rep=)``
  * ``jax.make_mesh(..., axis_types=)`` -> pre-0.5: no ``axis_types``
  * ``jax.sharding.AxisType``           -> absent pre-0.5

Every call site in the repo goes through these helpers so the same code
runs on the pinned jax and on current releases.
"""

from __future__ import annotations

import inspect

import jax

__all__ = ["get_abstract_mesh", "set_mesh", "make_mesh", "shard_map",
           "auto_axis_types", "cost_analysis"]


def get_abstract_mesh():
    """The mesh of the surrounding ``set_mesh``/``with mesh`` context.

    Returns a mesh object whose ``empty`` attribute is True when no mesh
    is active (matching ``jax.sharding.get_abstract_mesh`` semantics), or
    None when no context mechanism exists at all.
    """
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is None:
        fn = getattr(jax.sharding, "get_mesh", None)
    if fn is not None:
        return fn()
    try:
        from jax._src import mesh as _mesh_lib
        return _mesh_lib.thread_resources.env.physical_mesh
    except Exception:       # pragma: no cover - very old/new internals
        return None


def set_mesh(mesh):
    """Context manager activating ``mesh`` for sharding-constraint
    resolution: ``jax.set_mesh`` when present, else the classic
    ``with mesh:`` (Mesh is its own context manager pre-0.5)."""
    fn = getattr(jax, "set_mesh", None)
    if fn is not None:
        return fn(mesh)
    return mesh


def auto_axis_types(n_axes: int):
    """``(AxisType.Auto,) * n`` on jax versions that have AxisType,
    else None (pre-0.5 meshes are implicitly auto)."""
    at = getattr(jax.sharding, "AxisType", None)
    return None if at is None else (at.Auto,) * n_axes


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """``jax.make_mesh`` dropping kwargs the pinned version lacks."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if axis_types is not None and \
            "axis_types" in inspect.signature(jax.make_mesh).parameters:
        kwargs["axis_types"] = axis_types
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict: pre-0.5 jax returned a
    one-element list of per-program dicts."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` (check_vma) or the experimental one (check_rep)."""
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check)
