"""Version-tolerant wrappers for jax APIs that moved between releases.

The repo pins jax 0.4.37 (the jaxlib in the image), but the sharding API
surface it uses was renamed upstream several times:

  * ``jax.sharding.get_abstract_mesh``  -> pre-0.5: thread-resources mesh
  * ``jax.set_mesh(mesh)`` context      -> pre-0.5: ``with mesh:``
  * ``jax.shard_map(..., check_vma=)``  -> pre-0.5:
    ``jax.experimental.shard_map.shard_map(..., check_rep=)``
  * ``jax.make_mesh(..., axis_types=)`` -> pre-0.5: no ``axis_types``
  * ``jax.sharding.AxisType``           -> absent pre-0.5

Every call site in the repo goes through these helpers so the same code
runs on the pinned jax and on current releases.
"""

from __future__ import annotations

import inspect
import os

import jax

__all__ = ["get_abstract_mesh", "set_mesh", "make_mesh", "shard_map",
           "auto_axis_types", "cost_analysis",
           "distributed_initialize", "is_distributed", "process_index",
           "process_count", "make_global_mesh", "make_global_array",
           "broadcast_one_to_all", "process_allgather",
           "replicate_global",
           "DIST_COORDINATOR_ENV", "DIST_NUM_PROCESSES_ENV",
           "DIST_PROCESS_ID_ENV"]


def get_abstract_mesh():
    """The mesh of the surrounding ``set_mesh``/``with mesh`` context.

    Returns a mesh object whose ``empty`` attribute is True when no mesh
    is active (matching ``jax.sharding.get_abstract_mesh`` semantics), or
    None when no context mechanism exists at all.
    """
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is None:
        fn = getattr(jax.sharding, "get_mesh", None)
    if fn is not None:
        return fn()
    try:
        from jax._src import mesh as _mesh_lib
        return _mesh_lib.thread_resources.env.physical_mesh
    except Exception:       # pragma: no cover - very old/new internals
        return None


def set_mesh(mesh):
    """Context manager activating ``mesh`` for sharding-constraint
    resolution: ``jax.set_mesh`` when present, else the classic
    ``with mesh:`` (Mesh is its own context manager pre-0.5)."""
    fn = getattr(jax, "set_mesh", None)
    if fn is not None:
        return fn(mesh)
    return mesh


def auto_axis_types(n_axes: int):
    """``(AxisType.Auto,) * n`` on jax versions that have AxisType,
    else None (pre-0.5 meshes are implicitly auto)."""
    at = getattr(jax.sharding, "AxisType", None)
    return None if at is None else (at.Auto,) * n_axes


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """``jax.make_mesh`` dropping kwargs the pinned version lacks."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if axis_types is not None and \
            "axis_types" in inspect.signature(jax.make_mesh).parameters:
        kwargs["axis_types"] = axis_types
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict: pre-0.5 jax returned a
    one-element list of per-program dicts."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` (check_vma) or the experimental one (check_rep)."""
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check)


# ===================================================== multi-process ==
# jax.distributed moved less than the sharding API, but the pieces a
# multi-host serving mesh needs still differ across releases (the CPU
# collectives flag, make_array_from_process_local_data's signature), so
# every multi-process call site routes through here too.

DIST_COORDINATOR_ENV = "REPRO_DIST_COORDINATOR"
DIST_NUM_PROCESSES_ENV = "REPRO_DIST_NUM_PROCESSES"
DIST_PROCESS_ID_ENV = "REPRO_DIST_PROCESS_ID"

_dist_initialized = False


def distributed_initialize(coordinator_address: str | None = None,
                           num_processes: int | None = None,
                           process_id: int | None = None) -> bool:
    """Idempotent ``jax.distributed.initialize`` with CPU collectives.

    Arguments default to ``$REPRO_DIST_COORDINATOR`` /
    ``$REPRO_DIST_NUM_PROCESSES`` / ``$REPRO_DIST_PROCESS_ID``, so a
    launcher wrapper can configure a whole fleet through the
    environment.  Returns True when a multi-process runtime is (now)
    active, False for the single-process case (``num_processes`` <= 1 or
    unset) — callers can branch on it without re-reading the env.

    MUST run before any jax computation: on the CPU backend the
    cross-process collective implementation (gloo) has to be selected
    before the backend initializes, or every collective fails with
    "Multiprocess computations aren't implemented on the CPU backend".
    """
    global _dist_initialized
    if coordinator_address is None:
        coordinator_address = os.environ.get(DIST_COORDINATOR_ENV)
    if num_processes is None:
        num_processes = int(os.environ.get(DIST_NUM_PROCESSES_ENV, "1"))
    if process_id is None:
        process_id = int(os.environ.get(DIST_PROCESS_ID_ENV, "0"))
    if num_processes <= 1 or coordinator_address is None:
        return _dist_initialized
    if _dist_initialized:
        return True
    try:
        # renamed/absent on some releases; non-CPU backends don't need it
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:
        pass
    jax.distributed.initialize(coordinator_address=coordinator_address,
                               num_processes=num_processes,
                               process_id=process_id)
    try:
        # the mirrored-decode path runs eager (non-jit) ops on
        # replicated global arrays in lockstep on every process; jax
        # guards those behind spmd_mode (flag absent on newer releases)
        jax.config.update("jax_spmd_mode", "allow_all")
    except Exception:
        pass
    _dist_initialized = True
    return True


def is_distributed() -> bool:
    """True iff :func:`distributed_initialize` activated a fleet."""
    return _dist_initialized


def process_index() -> int:
    return jax.process_index()


def process_count() -> int:
    return jax.process_count()


def make_global_mesh(axis_names: tuple[str, str] = ("host", "model")
                     ) -> jax.sharding.Mesh:
    """Global (host, model) mesh over every process's devices.

    Rows are processes (devices sorted by ``(process_index, id)``), so
    the host axis is exactly the process grid and anything sharded over
    ``(host, model)`` lands contiguous shard blocks on each host — the
    layout the hierarchical top-k merge's offset math assumes.
    """
    import numpy as np
    devs = sorted(jax.devices(), key=lambda d: (d.process_index, d.id))
    n_proc = jax.process_count()
    grid = np.asarray(devs).reshape(n_proc, len(devs) // n_proc)
    return jax.sharding.Mesh(grid, axis_names)


def make_global_array(sharding, local_data, global_shape: tuple
                      ) -> jax.Array:
    """A global array from this process's slice of it (leading-axis
    sharded).  ``jax.make_array_from_process_local_data`` where present,
    else assembled per-device via ``make_array_from_single_device_arrays``.
    """
    fn = getattr(jax, "make_array_from_process_local_data", None)
    if fn is not None:
        try:
            return fn(sharding, local_data, global_shape)
        except TypeError:       # older signature: no global_shape arg
            return fn(sharding, local_data)
    import numpy as np
    local_devs = [d for d in sharding.mesh.devices.flat
                  if d.process_index == jax.process_index()]
    chunks = np.split(np.asarray(local_data), len(local_devs), axis=0)
    shards = [jax.device_put(c, d) for c, d in zip(chunks, local_devs)]
    return jax.make_array_from_single_device_arrays(
        global_shape, sharding, shards)


def broadcast_one_to_all(x):
    """Process 0's pytree on every process (identity single-process)."""
    if jax.process_count() == 1:
        return x
    from jax.experimental import multihost_utils
    return multihost_utils.broadcast_one_to_all(x)


def process_allgather(x):
    """Stack each process's pytree along a new leading axis (identity
    reshape single-process)."""
    if jax.process_count() == 1:
        import jax.numpy as jnp
        return jax.tree.map(lambda l: jnp.asarray(l)[None], x)
    from jax.experimental import multihost_utils
    return multihost_utils.process_allgather(x)


def replicate_global(tree, mesh) -> object:
    """Promote every LOCAL leaf of a pytree to a mesh-replicated global
    array, assuming each process already holds the same mirrored value
    (so no cross-process copy happens — each process just stamps its
    local copy onto its own devices).  Leaves that already span
    non-addressable devices pass through untouched; a multi-process jit
    can then take the tree as arguments next to (host, model)-sharded
    operands."""
    from jax.sharding import NamedSharding, PartitionSpec
    sharding = NamedSharding(mesh, PartitionSpec())

    def leaf(v):
        if isinstance(v, jax.Array) and not v.is_fully_addressable:
            return v
        import numpy as np
        v = np.asarray(v)
        return make_global_array(sharding, v, v.shape)

    return jax.tree.map(leaf, tree)
