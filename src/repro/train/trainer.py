"""Training loop: pjit'd step with microbatch accumulation, grad clipping,
LR schedule, rolling fault-tolerant checkpoints, auto-resume.

``make_train_step`` builds the jitted step from any ``loss_fn(params,
batch) -> scalar``; model-specific code stays in repro.models.
"""

from __future__ import annotations

import time
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.optim import AdamWState, adamw_init, adamw_update, \
    clip_by_global_norm
from repro.train import checkpoint as ckpt
from repro.utils.sharding import specs_to_shardings


class TrainConfig(NamedTuple):
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    microbatches: int = 1          # gradient accumulation factor
    opt_state_dtype: Any = jnp.float32
    ckpt_every: int = 200
    keep_last: int = 3


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    step: jax.Array


def make_train_step(loss_fn: Callable, tc: TrainConfig):
    """Returns ``step(state, batch) -> (state, metrics)`` (jit-friendly)."""
    from repro.optim.schedules import linear_warmup_cosine
    sched = linear_warmup_cosine(tc.lr, tc.warmup_steps, tc.total_steps)

    def single_grads(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        if tc.microbatches > 1:
            def split(x):
                return x.reshape((tc.microbatches,
                                  x.shape[0] // tc.microbatches) + x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc(carry, mb):
                loss, grads = single_grads(state.params, mb)
                return (carry[0] + loss,
                        jax.tree.map(jnp.add, carry[1], grads)), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (loss, grads), _ = jax.lax.scan(acc, (0.0, zeros), micro)
            loss = loss / tc.microbatches
            grads = jax.tree.map(lambda g: g / tc.microbatches, grads)
        else:
            loss, grads = single_grads(state.params, batch)

        grads, gnorm = clip_by_global_norm(grads, tc.clip_norm)
        lr = sched(state.step)
        params, opt = adamw_update(grads, state.opt, state.params, lr=lr,
                                   weight_decay=tc.weight_decay)
        new_state = TrainState(params, opt, state.step + 1)
        return new_state, {"loss": loss, "grad_norm": gnorm, "lr": lr}

    return step


def init_state(key: jax.Array, init_params_fn: Callable,
               tc: TrainConfig) -> TrainState:
    params = init_params_fn(key)
    return TrainState(params, adamw_init(params, tc.opt_state_dtype),
                      jnp.zeros((), jnp.int32))


def state_shardings(mesh: Mesh, param_spec_tree: Any) -> TrainState:
    """Optimizer state shards exactly like params; step is replicated."""
    p = specs_to_shardings(mesh, param_spec_tree)
    return TrainState(
        params=p,
        opt=AdamWState(step=NamedSharding(mesh, P()), mu=p, nu=p),
        step=NamedSharding(mesh, P()),
    )


class Trainer:
    """Orchestrates: auto-resume -> step loop -> rolling checkpoints.

    Fault tolerance: every ``ckpt_every`` steps the full state + data
    iterator state is written atomically.  On (re)start, the newest VALID
    checkpoint is restored — onto whatever mesh is current (elastic
    re-mesh).  ``crash_after`` is a test hook simulating preemption.
    """

    def __init__(self, loss_fn, init_params_fn, tc: TrainConfig, *,
                 ckpt_dir: str | None = None, mesh: Mesh | None = None,
                 param_specs: Any | None = None, donate: bool = True):
        self.tc = tc
        self.ckpt_dir = ckpt_dir
        self.mesh = mesh
        self.loss_fn = loss_fn
        self.init_params_fn = init_params_fn
        self.shardings = (state_shardings(mesh, param_specs)
                          if mesh is not None and param_specs is not None
                          else None)
        step_fn = make_train_step(loss_fn, tc)
        kwargs = {}
        if self.shardings is not None:
            # batch shardings resolve automatically from the device_put
            # done by the data pipeline; state is pinned explicitly
            kwargs["in_shardings"] = (self.shardings, None)
            kwargs["out_shardings"] = (self.shardings, None)
        if donate:
            kwargs["donate_argnums"] = (0,)
        self.step_fn = jax.jit(step_fn, **kwargs)

    def init_or_resume(self, key: jax.Array, data_iter=None) -> TrainState:
        state = init_state(key, self.init_params_fn, self.tc)
        if self.ckpt_dir:
            got = ckpt.restore_latest(self.ckpt_dir, state, self.shardings)
            if got is not None:
                state, extra, step = got
                if data_iter is not None and "data" in extra:
                    data_iter.load_state_dict(extra["data"])
                print(f"[trainer] resumed from step {step}")
                return state
        if self.shardings is not None:
            state = jax.device_put(state, self.shardings)
        return state

    def fit(self, key: jax.Array, data_iter, n_steps: int,
            crash_after: int | None = None, log_every: int = 50
            ) -> tuple[TrainState, list[dict]]:
        state = self.init_or_resume(key, data_iter)
        history = []
        start = int(state.step)
        t0 = time.time()
        for i in range(start, n_steps):
            batch = next(data_iter)
            state, metrics = self.step_fn(state, batch)
            if (i + 1) % log_every == 0 or i == n_steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = i + 1
                m["wall_s"] = round(time.time() - t0, 2)
                history.append(m)
                print(f"[trainer] step {i+1}: loss={m['loss']:.4f} "
                      f"gnorm={m['grad_norm']:.3f}")
            if self.ckpt_dir and (i + 1) % self.tc.ckpt_every == 0:
                ckpt.save(self.ckpt_dir, i + 1, state,
                          extra={"data": data_iter.state_dict()},
                          keep_last=self.tc.keep_last)
            if crash_after is not None and (i + 1) >= crash_after:
                raise RuntimeError("simulated preemption")
        if self.ckpt_dir:
            ckpt.save(self.ckpt_dir, n_steps, state,
                      extra={"data": data_iter.state_dict()},
                      keep_last=self.tc.keep_last)
        return state, history
