"""train subpackage."""
