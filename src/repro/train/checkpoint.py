"""Fault-tolerant checkpointing.

Guarantees targeted at preemptible fleets:
  * ATOMIC: a checkpoint directory appears only complete — written to
    ``<dir>/tmp.<step>``, fsynced, then renamed to ``<dir>/step_<n>``.
    A crash mid-write can never produce a loadable-but-corrupt state.
  * SELF-DESCRIBING: manifest.json carries step, the flattened tree
    structure, dtypes/shapes, mesh shape, and the data-iterator state.
  * ELASTIC: ``restore`` re-device_puts every leaf with the CURRENT mesh's
    NamedSharding — a 512-chip checkpoint restores onto 256 chips (or 1
    CPU) unchanged; resharding is free because arrays are saved unsharded
    per leaf (single-controller; a per-host shard writer would slot in
    here for multi-controller).
  * ROLLING: ``keep_last`` old checkpoints retained; newest valid wins on
    resume (a torn directory is skipped, not fatal).
"""

from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten(tree: Any) -> tuple[list[tuple[str, Any]], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    keys = [f"leaf_{i:05d}" for i in range(len(leaves))]
    return list(zip(keys, leaves)), treedef


def save(ckpt_dir: str, step: int, tree: Any,
         extra: dict | None = None, keep_last: int = 3) -> str:
    """Atomically write ``<ckpt_dir>/step_<step>``; prune old ones."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    kv, _ = _flatten(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in kv}
    np.savez(os.path.join(tmp, "leaves.npz"), **arrays)
    manifest = {
        "step": step,
        "n_leaves": len(kv),
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):      # re-save after resume: overwrite
        shutil.rmtree(final)
    os.rename(tmp, final)

    # retention
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)
    return final


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, like: Any,
            shardings: Any | None = None) -> tuple[Any, dict]:
    """Load ``step_<step>`` into the structure of ``like``.

    ``shardings``: optional pytree of NamedShardings (same structure) —
    this is the elastic-remesh path: leaves are device_put with the
    *current* mesh's sharding regardless of the mesh they were saved from.
    Returns (tree, manifest_extra).
    """
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "leaves.npz"))
    leaves_like, treedef = jax.tree.flatten(like)
    assert len(leaves_like) == manifest["n_leaves"], \
        (len(leaves_like), manifest["n_leaves"])
    shard_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                    else [None] * len(leaves_like))
    out = []
    for i, (ref, shard) in enumerate(zip(leaves_like, shard_leaves)):
        arr = data[f"leaf_{i:05d}"]
        arr = arr.astype(ref.dtype) if hasattr(ref, "dtype") else arr
        out.append(jax.device_put(arr, shard) if shard is not None
                   else jax.numpy.asarray(arr))
    return treedef.unflatten(out), manifest.get("extra", {})


def restore_latest(ckpt_dir: str, like: Any, shardings: Any | None = None
                   ) -> tuple[Any, dict, int] | None:
    """Newest VALID checkpoint or None.  Torn/corrupt dirs are skipped."""
    for step in reversed(all_steps(ckpt_dir)):
        try:
            tree, extra = restore(ckpt_dir, step, like, shardings)
            return tree, extra, step
        except Exception as e:  # torn checkpoint — try the previous one
            print(f"[ckpt] step_{step} unreadable ({e}); falling back")
    return None
