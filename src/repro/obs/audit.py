"""Online label-recall auditor: the paper's LSS claim as a live SLO.

``kernels_bench`` verifies *offline* that LSS retrieves the exact
brute-force WOL top-k; this module measures the same quantity
*continuously on live traffic*.  A sampled fraction
(``REPRO_OBS_AUDIT_RATE``) of LSS-served scoring groups is re-ranked
through the exact full head — on the engine's existing jitted-step
table, so the audit pays one extra compiled step per sampled group and
zero new compilation families — on a low-priority daemon thread, fully
off the dispatch hot path.

Recall uses the bench's exact definition (hit = exact top-k id present
in the served id set, averaged over rows x k), accumulated as integer
``hits / total`` — so at ``REPRO_OBS_AUDIT_RATE=1.0`` the published
gauge reproduces the offline brute-force recall exactly, not to
sampling noise.  Published metrics (global registry):

  * ``lss_audit_recall_at_k``     live recall@k gauge
  * ``lss_audit_top1_recall``     overlap of the exact top-1 id
  * ``lss_audit_rows_total``      rows audited
  * ``lss_audit_dropped_total``   sampled groups shed because the audit
    backlog was full — the *staleness* signal: when it grows, the gauge
    lags live traffic
  * ``lss_audit_backlog``         current queue depth

The backlog is bounded (default 64 groups) and ``offer`` never blocks:
under overload the auditor degrades to stale, never slows serving.
This is the sensor an online index refresh (ROADMAP direction 3) needs
to catch post-refit recall regressions.
"""

from __future__ import annotations

import queue
import random
import threading

import numpy as np

from repro import obs

__all__ = ["RecallAuditor"]

_SENTINEL = object()


class RecallAuditor:
    """Samples served groups, re-ranks via the exact full head, and
    publishes live recall gauges.  Construct with ``rate=0`` for a
    disabled auditor (every method is a cheap no-op)."""

    def __init__(self, engine, rate: float, *, queue_cap: int = 64,
                 registry=None, seed: int = 0):
        self.engine = engine
        self.rate = min(1.0, max(0.0, float(rate)))
        self.reg = registry if registry is not None else obs.registry()
        self._rng = random.Random(seed)
        self._mu = threading.Lock()
        self._hits = 0
        self._total = 0
        self._top1_hits = 0
        self._top1_total = 0
        self._g_recall = self.reg.gauge(
            "lss_audit_recall_at_k",
            "live label recall@k of LSS-served requests vs the exact "
            "full head")
        self._g_top1 = self.reg.gauge(
            "lss_audit_top1_recall",
            "live overlap of the exact top-1 label with the served set")
        self._g_backlog = self.reg.gauge(
            "lss_audit_backlog", "sampled groups awaiting audit")
        self._c_rows = self.reg.counter(
            "lss_audit_rows_total", "rows re-ranked by the auditor")
        self._c_dropped = self.reg.counter(
            "lss_audit_dropped_total",
            "sampled groups shed (audit backlog full) - staleness signal")
        self._q: queue.Queue = queue.Queue(maxsize=queue_cap)
        self._thread: threading.Thread | None = None
        if self.rate > 0:
            self._thread = threading.Thread(target=self._worker,
                                            name="repro-obs-audit",
                                            daemon=True)
            self._thread.start()

    # ------------------------------------------------------------ hot path --
    def offer(self, x, served_ids: np.ndarray) -> bool:
        """Maybe enqueue one served group for audit.  Called from the
        dispatch path right after results are sliced: coin-flips the
        sample, then a non-blocking put — NEVER stalls serving.  ``x``
        may be a thunk (the group pytree is only materialized when the
        flip samples it).  Returns True iff the group was enqueued."""
        if self.rate <= 0 or self._thread is None:
            return False
        if self.rate < 1.0 and self._rng.random() >= self.rate:
            return False
        if callable(x):
            x = x()
        try:
            self._q.put_nowait((x, np.asarray(served_ids)))
        except queue.Full:
            self._c_dropped.inc()
            obs.event("audit_drop", backlog=self._q.qsize())
            return False
        self._g_backlog.set(self._q.qsize())
        return True

    # ------------------------------------------------------------- worker --
    def _worker(self) -> None:
        while True:
            item = self._q.get()
            try:
                if item is _SENTINEL:
                    return
                x, served = item
                try:
                    self._audit_one(x, served)
                except Exception as exc:      # audit must never take the
                    obs.event("audit_error",  # serving process down
                              error=repr(exc))
            finally:
                self._q.task_done()
                self._g_backlog.set(self._q.qsize())

    def _audit_one(self, x, served: np.ndarray) -> None:
        span = obs.start_span("audit", rows=int(served.shape[0]),
                              k=int(served.shape[1]))
        try:
            # exact reference: the SAME weights through the engine's
            # full head (one jitted step, reused across audits)
            out = self.engine.rank(x, head="full", record=False)
            exact = np.asarray(out.ids)           # [B, k] brute-force ids
            hit = (exact[:, :, None] == served[:, None, :]).any(-1)
            with self._mu:
                self._hits += int(hit.sum())
                self._total += hit.size
                self._top1_hits += int(hit[:, 0].sum())
                self._top1_total += hit.shape[0]
                hits, total = self._hits, self._total
                t1h, t1t = self._top1_hits, self._top1_total
            self._g_recall.set(hits / total)
            self._g_top1.set(t1h / t1t)
            self._c_rows.inc(served.shape[0])
            span.end("ok", recall=hits / total)
        except BaseException as exc:
            span.end_from_exc(exc)
            raise

    # ------------------------------------------------------------ control --
    @property
    def recall(self) -> float:
        """Cumulative recall@k over every audited row (nan if none)."""
        with self._mu:
            return self._hits / self._total if self._total else float("nan")

    @property
    def n_rows(self) -> int:
        with self._mu:
            return self._top1_total

    def snapshot(self) -> tuple[int, int]:
        """Atomic ``(hits, total)`` — windowed consumers (the refresher's
        probation watch) subtract two snapshots to get recall over just
        the rows audited in between, instead of the cumulative gauge."""
        with self._mu:
            return self._hits, self._total

    def drain(self, timeout: float = 30.0) -> None:
        """Block until every enqueued group has been audited (tests use
        this to read a settled gauge)."""
        if self._thread is None:
            return
        import time
        deadline = time.monotonic() + timeout
        while self._q.unfinished_tasks and time.monotonic() < deadline:
            time.sleep(0.005)

    def close(self) -> None:
        if self._thread is None:
            return
        self._q.put(_SENTINEL)
        self._thread.join(timeout=10.0)
        self._thread = None
