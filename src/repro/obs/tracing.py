"""Request tracing: lightweight spans threaded through the serving seams.

A :class:`Span` is one timed unit of work — a scoring request's whole
submit→complete life, one dispatcher chunk, one decode session, one
prefill, one scheduler tick — carrying attributes (rid/sid, head kind,
bucket), point-in-time *events* (join, first token, KV page churn), and
a terminal *status*.  Spans are deliberately flat (no parent pointers):
the rid/sid attributes correlate a request span with the chunk/tick
spans that served it, which is all the life-of-a-request view needs and
keeps the record cheap enough for the hot path.

Terminal statuses mirror the runtime's failure taxonomy so every shed
path is distinguishable in a trace: ``ok``, ``shed_queue``,
``shed_deadline``, ``shed_kv_oom``, ``closed``, ``error``
(:func:`status_from_exc` maps the exception hierarchy by class name to
avoid importing serve modules here).

The process-wide tracer keeps the set of OPEN spans and a bounded ring
(``REPRO_OBS_TRACE_CAP`` finished spans/events, default 4096) —
sustained load cannot grow tracing memory.  :func:`assert_quiescent`
fails if any span is still open (the span-leak regression every
failure-path test runs in teardown), and :func:`trace_export` renders
the ring as a chrome://tracing / Perfetto-compatible JSON object
(``{"traceEvents": [...]}``, complete ``"X"`` events for spans, instant
``"i"`` events for point events).

One optional deep hook: :func:`maybe_jax_profile` wraps a block in a
``jax.profiler`` trace when ``REPRO_OBS_JAX_PROFILE`` names a directory
— one env var between "spans say the device step is slow" and an XLA
op-level timeline.
"""

from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from contextlib import contextmanager

__all__ = ["Span", "SPAN_STATUSES", "start_span", "event", "trace_export",
           "assert_quiescent", "open_spans", "reset_tracer",
           "status_from_exc", "maybe_jax_profile", "JAX_PROFILE_ENV",
           "TRACE_CAP_ENV"]

SPAN_STATUSES = ("ok", "shed_queue", "shed_deadline", "shed_kv_oom",
                 "closed", "error")
JAX_PROFILE_ENV = "REPRO_OBS_JAX_PROFILE"
TRACE_CAP_ENV = "REPRO_OBS_TRACE_CAP"

_EVENTS_PER_SPAN = 64                   # bound per-span event lists too

_EXC_STATUS = {
    "QueueFullError": "shed_queue",
    "DeadlineExceededError": "shed_deadline",
    "KVPoolExhaustedError": "shed_kv_oom",
    "RuntimeClosedError": "closed",
}


def status_from_exc(exc: BaseException) -> str:
    """Terminal span status for a failure, mapped by exception class
    name (by name, not import, so serve <-> obs stays acyclic);
    subclass walks the MRO so e.g. a ShedError subtype still maps."""
    for klass in type(exc).__mro__:
        s = _EXC_STATUS.get(klass.__name__)
        if s is not None:
            return s
    return "error"


class Span:
    """One timed unit of work.  ``end()`` is idempotent — the first
    terminal status wins, matching the write-once futures that close
    request spans."""

    __slots__ = ("name", "sid", "t0", "t1", "status", "attrs", "events",
                 "tid", "_n_dropped_events")

    def __init__(self, name: str, sid: int, attrs: dict):
        self.name = name
        self.sid = sid
        self.t0 = time.perf_counter()
        self.t1: float | None = None
        self.status: str | None = None
        self.attrs = attrs
        self.events: list[tuple[str, float, dict]] = []
        self.tid = threading.get_ident()
        self._n_dropped_events = 0

    def event(self, name: str, **attrs) -> None:
        if self.t1 is not None:
            return                      # late event on a closed span: drop
        if len(self.events) >= _EVENTS_PER_SPAN:
            self._n_dropped_events += 1
            return
        self.events.append((name, time.perf_counter(), attrs))

    def set(self, **attrs) -> None:
        self.attrs.update(attrs)

    @property
    def open(self) -> bool:
        return self.t1 is None

    def end(self, status: str = "ok", **attrs) -> None:
        if self.t1 is not None:
            return
        if status not in SPAN_STATUSES:
            raise ValueError(f"status must be one of {SPAN_STATUSES}, "
                             f"got {status!r}")
        if attrs:
            self.attrs.update(attrs)
        if self._n_dropped_events:
            self.attrs["dropped_events"] = self._n_dropped_events
        self.t1 = time.perf_counter()
        self.status = status
        _tracer._finish(self)

    def end_from_exc(self, exc: BaseException) -> None:
        self.end(status_from_exc(exc), error=repr(exc))

    def duration_s(self) -> float | None:
        return None if self.t1 is None else self.t1 - self.t0

    def __repr__(self) -> str:          # pragma: no cover - debug aid
        state = "open" if self.t1 is None else self.status
        return f"Span({self.name!r}, sid={self.sid}, {state})"


class _NoopSpan:
    """Shared span stand-in when observability is disabled."""

    __slots__ = ()
    name = "noop"
    sid = -1
    status = None
    attrs: dict = {}
    events: list = []
    open = False

    def event(self, name: str, **attrs) -> None:
        pass

    def set(self, **attrs) -> None:
        pass

    def end(self, status: str = "ok", **attrs) -> None:
        pass

    def end_from_exc(self, exc: BaseException) -> None:
        pass

    def duration_s(self) -> None:
        return None


NOOP_SPAN = _NoopSpan()


class _Tracer:
    def __init__(self, cap: int | None = None):
        if cap is None:
            cap = int(os.environ.get(TRACE_CAP_ENV, "4096") or 4096)
        self._mu = threading.Lock()
        self._open: dict[int, Span] = {}
        self._done: deque = deque(maxlen=cap)
        self._next_sid = 0
        self.n_started = 0
        self.n_finished = 0
        self.n_events = 0

    def start(self, name: str, attrs: dict) -> Span:
        with self._mu:
            sid = self._next_sid
            self._next_sid += 1
            self.n_started += 1
        span = Span(name, sid, attrs)
        with self._mu:
            self._open[sid] = span
        return span

    def _finish(self, span: Span) -> None:
        with self._mu:
            self._open.pop(span.sid, None)
            self._done.append(span)
            self.n_finished += 1

    def instant(self, name: str, attrs: dict) -> None:
        with self._mu:
            self._done.append((name, time.perf_counter(),
                               threading.get_ident(), attrs))
            self.n_events += 1

    def open_spans(self) -> list[Span]:
        with self._mu:
            return list(self._open.values())

    def drain(self) -> tuple[list, list[Span]]:
        with self._mu:
            return list(self._done), list(self._open.values())

    def reset(self) -> None:
        with self._mu:
            self._open.clear()
            self._done.clear()
            self.n_started = self.n_finished = self.n_events = 0


_tracer = _Tracer()


def _enabled() -> bool:
    from repro import obs
    return obs.enabled()


def start_span(name: str, **attrs) -> Span | _NoopSpan:
    """Open a span (returns the shared no-op when obs is disabled, so
    call sites never branch)."""
    if not _enabled():
        return NOOP_SPAN
    return _tracer.start(name, attrs)


def event(name: str, **attrs) -> None:
    """Record a process-level instant event (KV page churn, evictions —
    things not owned by any one span)."""
    if not _enabled():
        return
    _tracer.instant(name, attrs)


def open_spans() -> list[Span]:
    return _tracer.open_spans()


def assert_quiescent() -> None:
    """Raise if any span is still open — a failure path that forgot to
    close its span.  Run this in test teardown after drain/close."""
    left = _tracer.open_spans()
    if left:
        names = ", ".join(f"{s.name}(sid={s.sid}, {s.attrs})"
                          for s in left[:8])
        raise AssertionError(
            f"{len(left)} span(s) still open after teardown: {names}")


def reset_tracer() -> None:
    _tracer.reset()


def _json_attrs(attrs: dict) -> dict:
    return {k: (v if isinstance(v, (int, float, str, bool, type(None)))
                else repr(v)) for k, v in attrs.items()}


def trace_export(path: str | None = None, *,
                 include_open: bool = True) -> dict:
    """Render the trace ring as a chrome://tracing JSON object and
    optionally write it to ``path``.  Spans become complete (``"X"``)
    events with microsecond timestamps; point events become instant
    (``"i"``) events; still-open spans (if requested) become ``"B"``
    begin events so a hung request is visible in the timeline."""
    done, open_ = _tracer.drain()
    events: list[dict] = []
    pid = os.getpid()

    def us(t: float) -> float:
        return t * 1e6

    for item in done:
        if isinstance(item, Span):
            args = dict(_json_attrs(item.attrs), status=item.status)
            events.append({"name": item.name, "ph": "X", "pid": pid,
                           "tid": item.tid, "ts": us(item.t0),
                           "dur": us(item.t1 - item.t0), "args": args})
            for ev_name, ev_t, ev_attrs in item.events:
                events.append({"name": f"{item.name}.{ev_name}", "ph": "i",
                               "pid": pid, "tid": item.tid, "ts": us(ev_t),
                               "s": "t", "args": _json_attrs(ev_attrs)})
        else:
            name, t, tid, attrs = item
            events.append({"name": name, "ph": "i", "pid": pid, "tid": tid,
                           "ts": us(t), "s": "g",
                           "args": _json_attrs(attrs)})
    if include_open:
        for s in open_:
            events.append({"name": s.name, "ph": "B", "pid": pid,
                           "tid": s.tid, "ts": us(s.t0),
                           "args": _json_attrs(s.attrs)})
    out = {"traceEvents": events, "displayTimeUnit": "ms"}
    if path is not None:
        with open(path, "w") as f:
            json.dump(out, f)
    return out


@contextmanager
def maybe_jax_profile(suffix: str = ""):
    """When ``$REPRO_OBS_JAX_PROFILE`` names a directory, wrap the block
    in a ``jax.profiler`` trace written there (XLA op-level timeline,
    viewable in Perfetto/TensorBoard); otherwise a free no-op.  The one
    deep-capture hook the tracing layer exposes."""
    target = os.environ.get(JAX_PROFILE_ENV) or None
    if not target or not _enabled():
        yield
        return
    import jax
    with jax.profiler.trace(os.path.join(target, suffix) if suffix
                            else target):
        yield
