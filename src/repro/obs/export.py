"""Exporters: Prometheus text format + JSON snapshot + a stdlib HTTP
endpoint.

No third-party client library — the exposition format is a few lines of
text (https://prometheus.io/docs/instrumenting/exposition_formats/) and
the endpoint is ``http.server``, so the serving launcher can expose
``/metrics`` with zero new dependencies:

  * ``/metrics``        Prometheus text format, all live registries
                        merged (each registry's ``scope`` becomes a
                        label, so two engines never collide);
  * ``/metrics.json``   the same data as a JSON snapshot;
  * ``/trace``          the chrome://tracing export of the span ring.

Histograms render the standard triplet — ``_bucket{le=...}`` cumulative
counts, ``_sum``, ``_count`` — plus ``_p50/_p95/_p99`` convenience
gauges (quantiles computed server-side from the bounded reservoir).

``tools/check_metrics.py`` (stdlib again) parses and validates this
output in CI, so the format can't silently rot.
"""

from __future__ import annotations

import json
import math
import re
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               all_registries)

__all__ = ["prometheus_text", "json_snapshot", "MetricsServer",
           "set_global_labels"]

_NAME_OK = re.compile(r"[^a-zA-Z0-9_:]")

# process-wide labels stamped on EVERY exported sample — multi-host
# serving sets process="<rank>" here so each host's /metrics stays
# attributable after aggregation (this module stays jax-free: the
# launcher passes the process index in)
_GLOBAL_LABELS: dict[str, str] = {}


def set_global_labels(**labels: str) -> None:
    """Attach labels to every sample this process exports (e.g.
    ``set_global_labels(process="0")`` on a multi-host fleet).  Repeated
    calls merge; a None value removes the label."""
    for k, v in labels.items():
        if v is None:
            _GLOBAL_LABELS.pop(k, None)
        else:
            _GLOBAL_LABELS[k] = str(v)


def _prom_name(name: str) -> str:
    return _NAME_OK.sub("_", name)


def _fmt(v: float) -> str:
    if v != v:                                    # nan
        return "NaN"
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    return repr(float(v)) if not float(v).is_integer() else str(int(v))


def _labels(scope: str | None, extra: dict | None = None) -> str:
    parts = []
    for k, v in _GLOBAL_LABELS.items():
        parts.append(f'{k}="{v}"')
    if scope:
        parts.append(f'scope="{scope}"')
    for k, v in (extra or {}).items():
        parts.append(f'{k}="{v}"')
    return "{" + ",".join(parts) + "}" if parts else ""


def prometheus_text(registries: list[MetricsRegistry] | None = None) -> str:
    """Render registries (default: every live one) as Prometheus text.
    ``# TYPE`` lines are emitted once per metric name across registries
    (the format forbids repeats)."""
    if registries is None:
        registries = all_registries()
    lines: list[str] = []
    typed: set[str] = set()

    def header(name: str, kind: str, help_: str) -> None:
        if name in typed:
            return
        typed.add(name)
        if help_:
            lines.append(f"# HELP {name} {help_}")
        lines.append(f"# TYPE {name} {kind}")

    for reg in sorted(registries, key=lambda r: (r.scope or "")):
        reg.run_collectors()
        for raw, m in sorted(reg.metrics().items()):
            name = _prom_name(raw)
            if isinstance(m, Counter):
                header(name, "counter", m.help)
                lines.append(f"{name}{_labels(reg.scope)} {_fmt(m.value)}")
            elif isinstance(m, Gauge):
                header(name, "gauge", m.help)
                lines.append(f"{name}{_labels(reg.scope)} {_fmt(m.value)}")
            elif isinstance(m, Histogram):
                header(name, "histogram", m.help)
                for le, cum in m.bucket_snapshot():
                    lab = _labels(reg.scope, {"le": _fmt(le)})
                    lines.append(f"{name}_bucket{lab} {cum}")
                lines.append(f"{name}_sum{_labels(reg.scope)} "
                             f"{_fmt(m.sum)}")
                lines.append(f"{name}_count{_labels(reg.scope)} "
                             f"{m.count}")
                p50, p95, p99 = m.quantile((50, 95, 99))
                for q, v in (("p50", p50), ("p95", p95), ("p99", p99)):
                    qn = f"{name}_{q}"
                    header(qn, "gauge",
                           f"{q} of {name} (bounded-reservoir estimate)")
                    lines.append(f"{qn}{_labels(reg.scope)} {_fmt(v)}")
    return "\n".join(lines) + "\n"


def json_snapshot(registries: list[MetricsRegistry] | None = None) -> dict:
    if registries is None:
        registries = all_registries()
    return {"labels": dict(_GLOBAL_LABELS),
            "registries": [reg.snapshot() for reg in sorted(
                registries, key=lambda r: (r.scope or ""))]}


class _Handler(BaseHTTPRequestHandler):
    server: "MetricsServer._Server"

    def _send(self, body: bytes, ctype: str, code: int = 200) -> None:
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def do_GET(self) -> None:                      # noqa: N802 (stdlib API)
        from repro.obs.tracing import trace_export
        path = self.path.split("?")[0]
        try:
            if path in ("/metrics", "/"):
                self._send(prometheus_text().encode(),
                           "text/plain; version=0.0.4; charset=utf-8")
            elif path == "/metrics.json":
                self._send(json.dumps(json_snapshot()).encode(),
                           "application/json")
            elif path == "/trace":
                self._send(json.dumps(trace_export()).encode(),
                           "application/json")
            else:
                self._send(b"not found: try /metrics, /metrics.json, "
                           b"/trace", "text/plain", 404)
        except BrokenPipeError:                    # scraper went away
            pass

    def log_message(self, *a) -> None:             # silence per-request logs
        pass


class MetricsServer:
    """Background ``/metrics`` endpoint over every live registry.

    ``port=0`` binds an ephemeral port (``.port`` reports the real one).
    The server thread is a daemon, so a launcher that exits without
    ``close()`` doesn't hang — but call ``close()`` for a clean stop.
    """

    class _Server(ThreadingHTTPServer):
        daemon_threads = True
        allow_reuse_address = True

    def __init__(self, port: int = 0, host: str = "127.0.0.1"):
        self._httpd = self._Server((host, port), _Handler)
        self.host = host
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="repro-obs-metrics",
                                        daemon=True)
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/metrics"

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "MetricsServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
