"""Process-wide observability: metrics, tracing, exporters, recall audit.

One spine for the serving stack's telemetry (see
docs/ARCHITECTURE.md#observability):

  * :mod:`repro.obs.metrics`  — typed counters/gauges/bounded histograms
    in per-component registries, merged by the exporters;
  * :mod:`repro.obs.tracing`  — spans through the serving seams with a
    bounded ring and chrome://tracing export;
  * :mod:`repro.obs.export`   — Prometheus text / JSON snapshot over a
    stdlib ``http.server`` endpoint;
  * :mod:`repro.obs.audit`    — the online label-recall auditor
    (``lss_audit_recall@k`` as a live gauge).

The whole subsystem sits behind one switch: ``REPRO_OBS=0`` (or
:func:`set_enabled`) makes registries hand out shared no-op metrics and
:func:`start_span` return the shared no-op span — the "compiled-out"
baseline the overhead bench measures against.  Components read the
switch at construction, so toggle *before* building an engine/runtime.
"""

from __future__ import annotations

import os

from repro.obs.metrics import (DEFAULT_RESERVOIR, Counter, Gauge, Histogram,
                               MetricsRegistry, all_registries)
from repro.obs.tracing import (JAX_PROFILE_ENV, SPAN_STATUSES, TRACE_CAP_ENV,
                               Span, assert_quiescent, event,
                               maybe_jax_profile, open_spans, reset_tracer,
                               start_span, status_from_exc, trace_export)

__all__ = [
    "enabled", "set_enabled", "registry", "reset",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "all_registries",
    "DEFAULT_RESERVOIR",
    "Span", "SPAN_STATUSES", "start_span", "event", "trace_export",
    "assert_quiescent", "open_spans", "reset_tracer", "status_from_exc",
    "maybe_jax_profile", "JAX_PROFILE_ENV", "TRACE_CAP_ENV",
    "OBS_ENV", "AUDIT_RATE_ENV",
]

OBS_ENV = "REPRO_OBS"
AUDIT_RATE_ENV = "REPRO_OBS_AUDIT_RATE"

_ENABLED = os.environ.get(OBS_ENV, "1") != "0"


def enabled() -> bool:
    """Is observability on for this process?"""
    return _ENABLED


def set_enabled(flag: bool) -> None:
    """Flip the process switch.  Components capture it at construction
    (registries, span call sites), so build engines/runtimes *after*
    toggling — existing ones keep their old mode."""
    global _ENABLED
    _ENABLED = bool(flag)


_GLOBAL: MetricsRegistry | None = None


def registry() -> MetricsRegistry:
    """The process-global registry (scope ``None``) for metrics not
    owned by any one component (KV-pool events, audit gauges)."""
    global _GLOBAL
    if _GLOBAL is None or _GLOBAL.enabled != _ENABLED:
        _GLOBAL = MetricsRegistry(None, enabled=_ENABLED)
    return _GLOBAL


def reset() -> None:
    """Fresh telemetry window: zero the global registry and clear the
    trace ring (component registries are reset by their owners)."""
    registry().reset()
    reset_tracer()


def audit_rate_from_env(default: float = 0.0) -> float:
    """Sampling fraction for the online recall auditor, clamped to
    [0, 1] (``REPRO_OBS_AUDIT_RATE``; unset/empty -> ``default``)."""
    raw = os.environ.get(AUDIT_RATE_ENV, "")
    if not raw:
        return default
    try:
        return min(1.0, max(0.0, float(raw)))
    except ValueError:
        return default
