"""Typed metrics: counters, gauges, and BOUNDED streaming histograms.

Every ad-hoc stats path in the serving stack (``AsyncRuntime._lat_s``,
``Engine._lat``, the scheduler's TTFT/ITL lists) used to be an unbounded
``list[float]`` re-fed to ``np.percentile`` on every ``stats()`` call —
O(n) memory under sustained load and O(n log n) work per snapshot, both
inside the component's lock.  :class:`Histogram` replaces them:

  * **O(1) record** — one direct-indexed log-spaced bucket increment
    plus a uniform reservoir-sampling slot write (fixed capacity), so a
    week of traffic costs the same memory as a minute;
  * **O(buckets) quantiles** — computed from the reservoir (EXACT while
    ``count <= reservoir_cap``, an unbiased uniform sample past it), so
    small-window tests keep the precise percentiles they always saw;
  * the fixed log-spaced buckets feed the Prometheus exposition
    (cumulative ``le`` buckets) without touching the reservoir.

A :class:`MetricsRegistry` is a get-or-create namespace of metrics plus
optional *collector* callbacks (run at snapshot time to refresh gauges
from component state — how ``RuntimeStats``/``DecodeStats``/
``ServeMetrics`` counters surface without double bookkeeping).  Every
registry created while observability is enabled self-registers in a
process-wide weak set so the exporters can merge all live registries;
a ``scope`` label keeps two engines' metrics distinct in one exposition.

When observability is disabled (``REPRO_OBS=0`` or
:func:`repro.obs.set_enabled`), registries hand out shared no-op
metrics whose methods are empty — the "compiled-out" baseline the
observability-overhead bench compares against.
"""

from __future__ import annotations

import math
import random
import threading
import weakref
from typing import Callable

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "all_registries", "DEFAULT_RESERVOIR"]

DEFAULT_RESERVOIR = 4096

# live registries, merged by the exporters (weak: registries die with
# the engine/runtime that owns them)
_REGISTRIES: "weakref.WeakSet[MetricsRegistry]" = weakref.WeakSet()
_REG_LOCK = threading.Lock()
_SCOPE_SEQ: dict[str, int] = {}


def all_registries() -> list["MetricsRegistry"]:
    """Every live registry, registration order not guaranteed."""
    with _REG_LOCK:
        return list(_REGISTRIES)


def _next_scope(prefix: str) -> str:
    with _REG_LOCK:
        n = _SCOPE_SEQ.get(prefix, 0)
        _SCOPE_SEQ[prefix] = n + 1
    return f"{prefix}{n}"


class Counter:
    """Monotonically increasing accumulator (float-valued so wall-time
    sums can live here too)."""

    __slots__ = ("name", "help", "_mu", "_value")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._mu = threading.Lock()
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        with self._mu:
            self._value += n

    @property
    def value(self) -> float:
        with self._mu:
            return self._value

    def reset(self) -> None:
        with self._mu:
            self._value = 0.0


class Gauge:
    """Point-in-time value (set-only; collectors refresh it)."""

    __slots__ = ("name", "help", "_mu", "_value")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._mu = threading.Lock()
        self._value = math.nan

    def set(self, v: float) -> None:
        with self._mu:
            self._value = float(v)

    @property
    def value(self) -> float:
        with self._mu:
            return self._value

    def reset(self) -> None:
        with self._mu:
            self._value = math.nan


class Histogram:
    """Bounded streaming histogram: log-spaced buckets + reservoir.

    ``lo``/``hi`` bound the log-spaced bucket grid (values outside clamp
    to the edge buckets); ``per_decade`` sets resolution.  ``record`` is
    O(1); ``quantile`` is O(reservoir) and EXACT while the observation
    count fits the reservoir (the common test-window case), an unbiased
    sample estimate beyond it.  Memory is fixed at construction no
    matter how many values are recorded — the soak regression in
    tests/test_obs.py pins this.
    """

    __slots__ = ("name", "help", "lo", "hi", "_log_lo", "_inv_log_step",
                 "bounds", "_mu", "_bucket_counts", "_count", "_sum",
                 "_reservoir", "_cap", "_rng")

    def __init__(self, name: str, help: str = "", *, lo: float = 1e-3,
                 hi: float = 1e6, per_decade: int = 10,
                 reservoir: int = DEFAULT_RESERVOIR):
        if not (0 < lo < hi):
            raise ValueError(f"need 0 < lo < hi, got {lo}, {hi}")
        self.name = name
        self.help = help
        self.lo = lo
        self.hi = hi
        n = int(math.ceil(math.log10(hi / lo) * per_decade)) + 1
        self._log_lo = math.log10(lo)
        self._inv_log_step = per_decade
        # upper bound of bucket i; the last bucket is +inf (Prometheus
        # convention), so every value lands somewhere
        self.bounds = [lo * 10 ** (i / per_decade) for i in range(n)]
        self.bounds.append(math.inf)
        self._mu = threading.Lock()
        self._bucket_counts = [0] * len(self.bounds)
        self._count = 0
        self._sum = 0.0
        self._reservoir: list[float] = []
        self._cap = int(reservoir)
        self._rng = random.Random(0xC0FFEE ^ hash(name))

    def record(self, v: float) -> None:
        v = float(v)
        if v <= 0 or math.isnan(v):
            idx = 0                       # non-positive -> first bucket
        else:
            idx = int((math.log10(v) - self._log_lo) * self._inv_log_step)
            idx = min(max(idx + 1, 0), len(self.bounds) - 1)
        with self._mu:
            self._bucket_counts[idx] += 1
            self._count += 1
            self._sum += v
            if len(self._reservoir) < self._cap:
                self._reservoir.append(v)
            else:                         # uniform reservoir sampling
                j = self._rng.randrange(self._count)
                if j < self._cap:
                    self._reservoir[j] = v

    @property
    def count(self) -> int:
        with self._mu:
            return self._count

    @property
    def sum(self) -> float:
        with self._mu:
            return self._sum

    def sample(self) -> np.ndarray:
        """Copy of the reservoir (exact sample set while count <= cap).
        Cheap O(cap) snapshot; quantile math belongs OUTSIDE any caller
        lock (see the stats() satellite in runtime.py)."""
        with self._mu:
            return np.asarray(self._reservoir, np.float64)

    def quantile(self, q) -> float | tuple[float, ...]:
        """Percentile(s) of the recorded distribution; ``q`` in [0, 100]
        (scalar or sequence), nan when empty."""
        arr = self.sample()
        scalar = np.isscalar(q)
        if not arr.size:
            return math.nan if scalar else (math.nan,) * len(q)
        p = np.percentile(arr, q)
        return float(p) if scalar else tuple(float(x) for x in p)

    def mean(self) -> float:
        with self._mu:
            return self._sum / self._count if self._count else math.nan

    def bucket_snapshot(self) -> list[tuple[float, int]]:
        """Cumulative (le_bound, count) pairs — Prometheus layout."""
        with self._mu:
            counts = list(self._bucket_counts)
        out, cum = [], 0
        for le, c in zip(self.bounds, counts):
            cum += c
            out.append((le, cum))
        return out

    def reset(self) -> None:
        with self._mu:
            self._bucket_counts = [0] * len(self.bounds)
            self._count = 0
            self._sum = 0.0
            self._reservoir = []


class _NoopMetric:
    """Shared stand-in when observability is disabled: every method is a
    no-op, every read is empty/nan.  One instance serves all names."""

    __slots__ = ()
    name = "noop"
    help = ""

    def inc(self, n: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def record(self, v: float) -> None:
        pass

    def reset(self) -> None:
        pass

    count = 0
    sum = 0.0
    value = math.nan

    def sample(self) -> np.ndarray:
        return np.empty(0, np.float64)

    def quantile(self, q) -> float | tuple[float, ...]:
        return math.nan if np.isscalar(q) else (math.nan,) * len(q)

    def mean(self) -> float:
        return math.nan

    def bucket_snapshot(self) -> list[tuple[float, int]]:
        return []


NOOP_METRIC = _NoopMetric()


class MetricsRegistry:
    """Get-or-create namespace of metrics + snapshot-time collectors.

    ``scope`` becomes a label on every exported metric so registries
    from different components can merge into one exposition without
    colliding (``scope_prefix`` auto-numbers: ``engine0``, ``engine1``,
    ...).  ``enabled=None`` follows the process switch at construction
    time (``repro.obs.enabled()``); a disabled registry hands out the
    shared no-op metric and exports nothing.
    """

    def __init__(self, scope: str | None = None, *,
                 scope_prefix: str | None = None,
                 enabled: bool | None = None):
        if enabled is None:
            from repro import obs
            enabled = obs.enabled()
        self.enabled = bool(enabled)
        if scope is None and scope_prefix is not None:
            scope = _next_scope(scope_prefix)
        self.scope = scope
        self._mu = threading.Lock()
        self._metrics: dict[str, object] = {}
        self._collectors: list[Callable[["MetricsRegistry"], None]] = []
        if self.enabled:
            with _REG_LOCK:
                _REGISTRIES.add(self)

    # ----------------------------------------------------- get-or-create --
    def _get(self, name: str, factory: Callable, cls: type):
        if not self.enabled:
            return NOOP_METRIC
        with self._mu:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = factory()
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, lambda: Counter(name, help), Counter)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, lambda: Gauge(name, help), Gauge)

    def histogram(self, name: str, help: str = "", **kw) -> Histogram:
        return self._get(name, lambda: Histogram(name, help, **kw),
                         Histogram)

    # --------------------------------------------------------- snapshots --
    def collect(self, fn: Callable[["MetricsRegistry"], None]) -> None:
        """Register a snapshot-time callback that refreshes gauges from
        component state (e.g. ``RuntimeStats`` counters)."""
        with self._mu:
            self._collectors.append(fn)

    def run_collectors(self) -> None:
        with self._mu:
            collectors = list(self._collectors)
        for fn in collectors:
            fn(self)

    def metrics(self) -> dict[str, object]:
        with self._mu:
            return dict(self._metrics)

    def snapshot(self) -> dict:
        """Plain-data view (JSON-ready) of every metric, collectors run
        first.  Histograms carry count/sum/p50/p95/p99 + the cumulative
        bucket table."""
        self.run_collectors()
        out: dict = {"scope": self.scope, "metrics": {}}
        for name, m in sorted(self.metrics().items()):
            if isinstance(m, Counter):
                out["metrics"][name] = {"type": "counter",
                                        "value": m.value}
            elif isinstance(m, Gauge):
                out["metrics"][name] = {"type": "gauge", "value": m.value}
            elif isinstance(m, Histogram):
                p50, p95, p99 = m.quantile((50, 95, 99))
                out["metrics"][name] = {
                    "type": "histogram", "count": m.count, "sum": m.sum,
                    "p50": p50, "p95": p95, "p99": p99,
                    "buckets": [[le if math.isfinite(le) else "inf", c]
                                for le, c in m.bucket_snapshot()],
                }
        return out

    def reset(self) -> None:
        """Fresh window: zero every metric (the registry keeps its
        identity — callers hold metric references)."""
        for m in self.metrics().values():
            m.reset()
