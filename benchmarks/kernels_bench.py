"""C-sweep microbench for the ``lss_topk`` ref path's strategy knobs.

Two sweeps, one artifact:

* **dedup** — times the FULL fused-op ref path (hash -> slab gather ->
  dedup -> top-k) per dedup strategy across candidate counts C = L*P,
  so the quadratic / bitonic comparison reflects end-to-end us/query,
  not an isolated mask.  Records the measured crossover (the smallest
  swept C where bitonic wins) — that number is what
  ``REPRO_LSS_DEDUP_AUTO_C`` /
  ``kernels.lss_topk.dedup.set_dedup_auto_threshold`` should be fed, so
  the registry's auto-switch is data-derived rather than guessed.
* **slab_dtype** — builds one REAL synthetic-WOL index per storage
  format (fp32 | bf16 | int8, see ``kernels.lss_topk.slabs``) from the
  same weights/hyperplanes, and records per format: us/query, the
  per-query slab DMA byte count (``lss_topk_slab_dma_bytes`` — the ~3.6x
  int8 win at d=64), top-k label recall against the EXACT brute-force
  WOL top-k, and the recall delta vs the fp32 index.  Candidate
  retrieval is identical across formats (tables hash fp32 weights), so
  the delta isolates exactly what quantization can cost: ranked top-k
  membership.

Doubles as the CI smoke guard: ``--guard-c 512 --guard-ratio 1.5`` fails
the run when bitonic regresses past 1.5x quadratic at C = 512, and
``--guard-recall-delta 0.005`` fails it when a quantized format's label
recall drops more than 0.5% below fp32 — so neither the sorting network
nor storage compression can quietly pessimize the regimes they own.

    python -m benchmarks.kernels_bench --cs 512,2048,8192 \
        --guard-c 512 --guard-ratio 1.5 --guard-recall-delta 0.005

Writes ``BENCH_kernels.json`` (also embedded by ``benchmarks.run``'s
kernels section; schema checked by ``tools/check_bench_schema.py``).
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import sys
import time

import jax
import jax.numpy as jnp

DEDUPS = ("quadratic", "bitonic")


def build_case(c: int, d: int = 64, n_tables: int = 2, k_bits: int = 2,
               seed: int = 0):
    """Synthetic bucket-major index with C = L*P candidates per query and
    a heavy cross-table duplicate rate (ids drawn from a pool of C/2)."""
    assert c % n_tables == 0, (c, n_tables)
    cap = c // n_tables
    n_buckets = 2 ** k_bits
    kt, kw, kq = jax.random.split(jax.random.PRNGKey(seed), 3)
    table_ids = jax.random.randint(
        kt, (n_tables, n_buckets, cap), -1, max(c // 2, 2), jnp.int32)
    w_bucketed = jax.random.normal(kw, (n_tables, n_buckets, cap, d),
                                   jnp.float32)
    theta = jax.random.normal(kq, (d, k_bits * n_tables), jnp.float32)
    return theta, table_ids, w_bucketed


def bench_dedup_sweep(cs=(512, 2048, 8192), b: int = 8, d: int = 64,
                      top_k: int = 5, seed: int = 0, repeats: int = 3
                      ) -> dict:
    """Time the ref path per (C, dedup).  Returns
    ``{"rows": [...], "crossover_c": int | None}``.

    Each point is the BEST of ``repeats`` timed windows — shared CI
    runners get descheduled mid-loop, and the min is the standard
    noise-robust microbenchmark statistic (the guard gates CI on these
    numbers, so one scheduling hiccup must not fail the build)."""
    from repro.kernels.lss_topk.ops import lss_topk

    rows = []
    by_c: dict[int, dict[str, float]] = {}
    q = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, d), jnp.float32)
    for c in cs:
        theta, table_ids, w_bucketed = build_case(c, d=d, seed=seed)
        # fewer timed iters at large C: the quadratic [B, C, C] compare
        # is exactly the thing being measured as it blows up
        iters = max(2, min(10, (1 << 21) // (c * b)))
        by_c[c] = {}
        for dd in DEDUPS:
            f = jax.jit(functools.partial(lss_topk, top_k=top_k, impl="ref",
                                          dedup=dd))
            jax.block_until_ready(f(q, theta, table_ids, w_bucketed))
            us = float("inf")
            for _ in range(repeats):
                t0 = time.perf_counter()
                for _ in range(iters):
                    jax.block_until_ready(f(q, theta, table_ids, w_bucketed))
                us = min(us, (time.perf_counter() - t0) / iters / b * 1e6)
            by_c[c][dd] = us
            rows.append({"kernel": "lss_topk", "impl": "ref", "dedup": dd,
                         "c": c, "us_per_query": round(us, 3),
                         "shape": f"B{b}_d{d}_C{c}",
                         "iters": iters, "repeats": repeats})
    crossover = next((c for c in sorted(by_c)
                      if by_c[c]["bitonic"] < by_c[c]["quadratic"]), None)
    return {"rows": rows, "crossover_c": crossover}


def bench_slab_dtype_sweep(m: int = 4096, d: int = 63, b: int = 64,
                           top_k: int = 10, k_bits: int = 4,
                           n_tables: int = 4, seed: int = 0,
                           repeats: int = 3) -> dict:
    """One synthetic-WOL index per slab storage format; returns
    ``{"rows": [...]}`` with us/query, per-query slab DMA bytes, and
    top-k label recall (+ delta vs fp32) per format.

    Recall target: the exact brute-force WOL top-k (``q @ w_aug.T``),
    i.e. the labels a full head would rank — the quantity LSS serving
    exists to approximate.  The fp32 row's recall is the retrieval
    ceiling (what hashing alone loses); quantized rows can only differ
    from it through ranking error, so ``recall_delta_vs_fp32`` is a pure
    measurement of storage-compression cost."""
    from repro.core import simhash
    from repro.core.lss import LSSConfig, build_index, lss_forward
    from repro.kernels.lss_topk.slabs import (SLAB_DTYPE_CHOICES,
                                              lss_topk_slab_dma_bytes)

    kw, kq = jax.random.split(jax.random.PRNGKey(seed), 2)
    w = jax.random.normal(kw, (m, d), jnp.float32)
    q = jax.random.normal(kq, (b, d), jnp.float32)
    w_aug = simhash.augment_neurons(w)
    q_aug = simhash.augment_queries(q)
    # ground truth: exact full-WOL top-k labels per query
    exact = jax.lax.top_k(q_aug @ w_aug.T, top_k)[1]          # [B, k]

    rows = []
    recall_fp32 = None
    for sdt in SLAB_DTYPE_CHOICES:
        cfg = LSSConfig(k_bits=k_bits, n_tables=n_tables, slab_dtype=sdt)
        theta = simhash.init_hyperplanes(jax.random.PRNGKey(seed + 2),
                                         w_aug.shape[1], k_bits, n_tables)
        index = build_index(w_aug, theta, cfg)
        cap = index.tables.capacity
        f = jax.jit(functools.partial(lss_forward, top_k=top_k, impl="ref"))
        out = jax.block_until_ready(f(q, index, None))
        hit = (exact[:, :, None] == out.top_ids[:, None, :]).any(-1)
        recall = float(jnp.mean(hit))
        if sdt == "fp32":
            recall_fp32 = recall
        us = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            for _ in range(3):
                jax.block_until_ready(f(q, index, None))
            us = min(us, (time.perf_counter() - t0) / 3 / b * 1e6)
        rows.append({
            "kernel": "lss_topk", "impl": "ref", "slab_dtype": sdt,
            "us_per_query": round(us, 3),
            "dma_bytes_per_query": lss_topk_slab_dma_bytes(
                n_tables, cap, w_aug.shape[1], sdt),
            "recall": round(recall, 4),
            "recall_delta_vs_fp32": round(recall_fp32 - recall, 4),
            "shape": f"m{m}_B{b}_d{d}_K{k_bits}_L{n_tables}_P{cap}",
            "repeats": repeats,
        })
    return {"rows": rows}


def check_guard(rec: dict, guard_c: int, guard_ratio: float) -> str | None:
    """None if ok, else a failure message: bitonic must stay within
    ``guard_ratio`` x quadratic at the small-C guard point."""
    us = {(r["c"], r["dedup"]): r["us_per_query"] for r in rec["rows"]
          if "c" in r and "dedup" in r}
    quad, bit = us.get((guard_c, "quadratic")), us.get((guard_c, "bitonic"))
    if quad is None or bit is None:
        return f"guard C={guard_c} not in sweep"
    if bit > guard_ratio * quad:
        return (f"bitonic regresses the small-C regime: {bit:.1f} us/q vs "
                f"quadratic {quad:.1f} at C={guard_c} "
                f"(> {guard_ratio}x)")
    return None


def check_recall_guard(rec: dict, max_delta: float) -> str | None:
    """None if ok, else a failure message: no quantized slab format may
    lose more than ``max_delta`` label recall vs the fp32 index."""
    slab_rows = [r for r in rec["rows"] if "slab_dtype" in r]
    if not slab_rows:
        return "no slab_dtype rows in sweep"
    worst = max(slab_rows, key=lambda r: r["recall_delta_vs_fp32"])
    if worst["recall_delta_vs_fp32"] > max_delta:
        return (f"slab_dtype={worst['slab_dtype']} loses "
                f"{worst['recall_delta_vs_fp32']:.4f} label recall vs fp32 "
                f"(> {max_delta}) at {worst['shape']}")
    return None


def write_artifact(rec: dict, path: str | None = None) -> str:
    """Write (or MERGE into) ``BENCH_kernels.json``: rows from other
    kernels already in an existing artifact — e.g. ``benchmarks.run``'s
    simhash/bucket_logits timings — are preserved, and any stale
    lss_topk sweep rows are replaced by this run's, so the guard step
    and the main bench step can both land in one artifact regardless of
    order."""
    out_dir = os.environ.get("BENCH_OUT_DIR", ".")
    path = path or os.path.join(out_dir, "BENCH_kernels.json")
    try:
        with open(path) as f:
            prev = json.load(f)
        kept = [r for r in prev.get("rows", [])
                if r.get("kernel") != "lss_topk"]
    except (OSError, ValueError):
        prev, kept = {}, []
    rec = {**prev, "bench": "kernels", "backend": jax.default_backend(),
           **rec, "rows": kept + rec["rows"]}
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    print(f"wrote {path}")
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cs", default="512,2048,8192",
                    help="comma-separated candidate counts to sweep")
    ap.add_argument("--b", type=int, default=8, help="query batch size")
    ap.add_argument("--d", type=int, default=64, help="embedding dim")
    ap.add_argument("--guard-c", type=int, default=None,
                    help="fail if bitonic exceeds guard-ratio x quadratic "
                         "at this C")
    ap.add_argument("--guard-ratio", type=float, default=1.5)
    ap.add_argument("--guard-recall-delta", type=float, default=None,
                    help="fail if any quantized slab format loses more "
                         "than this label recall vs fp32")
    ap.add_argument("--skip-slab-sweep", action="store_true",
                    help="dedup sweep only (no slab_dtype rows)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    cs = tuple(int(x) for x in args.cs.split(","))

    rec = bench_dedup_sweep(cs=cs, b=args.b, d=args.d)
    for r in rec["rows"]:
        print(f"kernel_lss_topk_ref_{r['dedup']}_c{r['c']},"
              f"{r['us_per_query']:.3f},{r['shape']}")
    print(f"crossover_c={rec['crossover_c']}")
    if not args.skip_slab_sweep:
        slab = bench_slab_dtype_sweep()
        rec["rows"].extend(slab["rows"])
        for r in slab["rows"]:
            print(f"kernel_lss_topk_ref_slab_{r['slab_dtype']},"
                  f"{r['us_per_query']:.3f},{r['shape']},"
                  f"dma={r['dma_bytes_per_query']},"
                  f"recall={r['recall']:.4f},"
                  f"delta={r['recall_delta_vs_fp32']:.4f}")
    guard = None
    rec["guard"] = None
    if args.guard_c is not None:
        guard = check_guard(rec, args.guard_c, args.guard_ratio)
        rec["guard"] = {"c": args.guard_c, "ratio": args.guard_ratio,
                        "failed": guard}
    if guard is None and args.guard_recall_delta is not None:
        guard = check_recall_guard(rec, args.guard_recall_delta)
        rec["recall_guard"] = {"max_delta": args.guard_recall_delta,
                               "failed": guard}
    write_artifact(rec, args.out)
    if guard is not None:
        print(f"GUARD FAILED: {guard}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
