"""Serving benchmark: full vs LSS vs sharded-LSS on synthetic WOLs.

Measures us/query and req/s through the unified serving engine
(``repro.serve.engine.Engine``) for wide output layers of 50k-500k
classes, and writes the ``BENCH_serve.json`` artifact consumed by CI.

The LSS index here is SimHash-initialised (``fit_random``) — retrieval
*speed* is independent of whether the hyperplanes were IUL-trained, and
skipping Algorithm 1 keeps the benchmark CPU-friendly.  K is sized so the
expected candidate set is ~1k neurons regardless of m, which is exactly
the regime where the paper reports its ~5x win over the exact head.

Env: BENCH_FAST=1 (default when run via benchmarks.run) shrinks sizes
and iteration counts; BENCH_SERVE_OUT overrides the artifact path.
"""

from __future__ import annotations

import json
import math
import os
import time

import jax
import jax.numpy as jnp

from repro.core.lss import LSSConfig
from repro.serve.engine import Engine

D_MODEL = 64
BATCH = 128
TOP_K = 10
TARGET_SAMPLE = 1024           # aim ~1k candidates per query


def _lss_cfg(m: int) -> LSSConfig:
    k_bits = max(4, math.ceil(math.log2(max(2 * m / TARGET_SAMPLE, 2))))
    # gather path: the bucket-major slab for m=500k would be ~250MB; the
    # gather layout keeps the benchmark inside CI memory.
    return LSSConfig(k_bits=k_bits, n_tables=1, use_bucket_major=False)


def _time_head(eng: Engine, q, head: str, iters: int) -> float:
    out = eng.rank(q, head=head, record=False)           # warm/compile
    jax.block_until_ready(out.logits)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = eng.rank(q, head=head, record=False)
        jax.block_until_ready(out.logits)
    return (time.perf_counter() - t0) / iters


def bench_serving(fast: bool = True) -> dict:
    sizes = (50_000, 500_000) if fast else (50_000, 200_000, 500_000)
    rows = []
    for m in sizes:
        w = jax.random.normal(jax.random.PRNGKey(0), (m, D_MODEL),
                              jnp.float32)
        q = jax.random.normal(jax.random.PRNGKey(1), (BATCH, D_MODEL),
                              jnp.float32)
        eng = Engine(None, w, None, _lss_cfg(m), top_k=TOP_K,
                     buckets=(BATCH,))
        eng.fit_random(jax.random.PRNGKey(2))
        full_us = None
        for head in ("full", "lss", "lss-sharded"):
            iters = (2 if fast else 5) if head == "full" \
                else (20 if fast else 50)
            dt = _time_head(eng, q, head, iters)
            us = dt / BATCH * 1e6
            sample = float(eng.rank(q, head=head,
                                    record=False).sample_size.mean())
            if head == "full":
                full_us = us
            rows.append({
                "m": m, "head": head, "batch": BATCH, "d": D_MODEL,
                "k_bits": eng.lss_cfg.k_bits, "top_k": TOP_K,
                "us_per_query": round(us, 2),
                "req_per_s": round(BATCH / dt, 1),
                "avg_sample_size": round(sample, 1),
                "speedup_vs_full": round(full_us / us, 2),
            })
    return {
        "bench": "serve",
        "backend": jax.default_backend(),
        "n_devices": len(jax.devices()),
        "fast": fast,
        "rows": rows,
    }


def write_artifact(record: dict, path: str | None = None) -> str:
    path = path or os.environ.get("BENCH_SERVE_OUT", "BENCH_serve.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    return path


def main() -> None:
    fast = os.environ.get("BENCH_FAST", "1") != "0"
    rec = bench_serving(fast=fast)
    path = write_artifact(rec)
    print(f"wrote {path}")
    for r in rec["rows"]:
        print(f"  m={r['m']:>7} {r['head']:<11} "
              f"{r['us_per_query']:>9.1f} us/q  {r['req_per_s']:>9.0f} rps  "
              f"sample={r['avg_sample_size']:>8.0f}  "
              f"speedup={r['speedup_vs_full']:.2f}x")


if __name__ == "__main__":
    main()
