"""Serving benchmark: full vs LSS vs sharded-LSS on synthetic WOLs.

Measures us/query and req/s through the unified serving engine
(``repro.serve.engine.Engine``) for wide output layers of 50k-500k
classes, and writes the ``BENCH_serve.json`` artifact consumed by CI.

The LSS index here is SimHash-initialised (``fit_random``) — retrieval
*speed* is independent of whether the hyperplanes were IUL-trained, and
skipping Algorithm 1 keeps the benchmark CPU-friendly.  K is sized so the
expected candidate set is ~1k neurons regardless of m, which is exactly
the regime where the paper reports its ~5x win over the exact head.

Three sections:

  * the head comparison (full | lss | lss-sharded) on the gather-layout
    index at 50k-500k classes (the bucket-major slab for m=500k would be
    ~250MB; gather keeps CI memory bounded) — rows carry ``impl: ref``;
  * the kernel-impl dimension on a bucket-major index at a smaller m:
    one engine per registry impl (``ref`` | ``pallas_interpret`` and, on
    TPU, ``pallas``) so ``BENCH_serve.json`` reports ref-vs-pallas
    us/query side by side through the SAME fused ``lss_topk`` hot path.
    Interpret mode executes the kernel body per grid step in Python — it
    validates the fused pipeline, it does not represent TPU speed;
  * the slab-storage dimension (``lss_topk.slab_dtype``): one bucket-major
    engine per storage format, each row carrying the analytic index slab
    byte count.  The full pass (BENCH_FAST=0) adds an m=2,000,000 int8
    row — at that size the fp32 slab tensor is ~1 GB and does not fit
    the CI footprint, while the int8 index (~270 MB incl. scales) serves
    fine: storage compression moves the "largest m per host" wall, which
    is the paper-level point of the knob.

Env: BENCH_FAST=1 (default when run via benchmarks.run) shrinks sizes
and iteration counts; BENCH_SERVE_OUT overrides the artifact path.
"""

from __future__ import annotations

import json
import math
import os
import time

import jax
import jax.numpy as jnp

from repro.core.lss import LSSConfig
from repro.serve.engine import Engine

D_MODEL = 64
BATCH = 128
TOP_K = 10
TARGET_SAMPLE = 1024           # aim ~1k candidates per query
IMPL_BATCH = 16                # per-impl section: small B, interpret is slow
IMPL_TARGET_SAMPLE = 512


def _lss_cfg(m: int, *, bucket_major: bool = False,
             n_tables: int = 1, target: int = TARGET_SAMPLE) -> LSSConfig:
    k_bits = max(4, math.ceil(math.log2(max(2 * m / target, 2))))
    return LSSConfig(k_bits=k_bits, n_tables=n_tables,
                     use_bucket_major=bucket_major)


def _time_head(eng: Engine, q, head: str, iters: int) -> float:
    out = eng.rank(q, head=head, record=False)           # warm/compile
    jax.block_until_ready(out.logits)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = eng.rank(q, head=head, record=False)
        jax.block_until_ready(out.logits)
    return (time.perf_counter() - t0) / iters


def _row(eng: Engine, q, head: str, impl: str, m: int, batch: int,
         iters: int, full_us: float | None) -> dict:
    dt = _time_head(eng, q, head, iters)
    us = dt / batch * 1e6
    sample = float(eng.rank(q, head=head, record=False).sample_size.mean())
    return {
        "m": m, "head": head, "impl": impl, "batch": batch, "d": D_MODEL,
        "k_bits": eng.lss_cfg.k_bits, "n_tables": eng.lss_cfg.n_tables,
        "top_k": TOP_K,
        "us_per_query": round(us, 2),
        "req_per_s": round(batch / dt, 1),
        "avg_sample_size": round(sample, 1),
        "speedup_vs_full": (round(full_us / us, 2)
                            if full_us is not None else None),
    }


def bench_heads(fast: bool) -> list[dict]:
    sizes = (50_000, 500_000) if fast else (50_000, 200_000, 500_000)
    rows = []
    for m in sizes:
        w = jax.random.normal(jax.random.PRNGKey(0), (m, D_MODEL),
                              jnp.float32)
        q = jax.random.normal(jax.random.PRNGKey(1), (BATCH, D_MODEL),
                              jnp.float32)
        # impl pinned so the artifact's "impl": "ref" label stays true
        # even under $REPRO_KERNEL_IMPL or on a TPU backend
        eng = Engine(None, w, None, _lss_cfg(m), top_k=TOP_K,
                     buckets=(BATCH,), impl="ref")
        eng.fit_random(jax.random.PRNGKey(2))
        full_us = None
        for head in ("full", "lss", "lss-sharded"):
            iters = (2 if fast else 5) if head == "full" \
                else (20 if fast else 50)
            row = _row(eng, q, head, "ref", m, BATCH, iters, full_us)
            if head == "full":
                full_us = row["us_per_query"]
                row["speedup_vs_full"] = 1.0
            rows.append(row)
    return rows


def bench_impls(fast: bool) -> list[dict]:
    """One engine per kernel impl over the SAME bucket-major index."""
    m = 20_000 if fast else 100_000
    impls = ["ref", "pallas_interpret"]
    if jax.default_backend() == "tpu":
        impls.append("pallas")
    w = jax.random.normal(jax.random.PRNGKey(0), (m, D_MODEL), jnp.float32)
    q = jax.random.normal(jax.random.PRNGKey(1), (IMPL_BATCH, D_MODEL),
                          jnp.float32)
    cfg = _lss_cfg(m, bucket_major=True, n_tables=2,
                   target=IMPL_TARGET_SAMPLE)
    rows = []
    for impl in impls:
        eng = Engine(None, w, None, cfg, top_k=TOP_K, buckets=(IMPL_BATCH,),
                     impl=impl)
        eng.fit_random(jax.random.PRNGKey(2))
        iters = 1 if (impl == "pallas_interpret" and fast) else \
            (2 if impl == "pallas_interpret" else (20 if fast else 50))
        rows.append(_row(eng, q, "lss", impl, m, IMPL_BATCH, iters, None))
    return rows


def _slab_bytes(cfg: LSSConfig, m: int, d_aug: int, slab_dtype: str) -> int:
    """Analytic bucket-major index bytes for one storage format: the
    ``[L, 2^K, P, d_aug]`` slab tensor + int32 ids + (int8 only) the
    fp32 scale table."""
    from repro.kernels.lss_topk.slabs import slab_itemsize
    slots = cfg.n_tables * 2 ** cfg.k_bits * cfg.resolve_capacity(m)
    n = slots * d_aug * slab_itemsize(slab_dtype) + slots * 4
    if slab_dtype == "int8":
        n += slots * 4
    return n


def bench_slab_storage(fast: bool) -> list[dict]:
    """One bucket-major engine per slab storage format; the full pass
    adds the m=2M int8 row whose fp32 equivalent cannot fit CI."""
    m = 20_000 if fast else 100_000
    q = jax.random.normal(jax.random.PRNGKey(1), (IMPL_BATCH, D_MODEL),
                          jnp.float32)
    points = [(m, sdt) for sdt in ("fp32", "bf16", "int8")]
    if not fast:
        # fp32 at m=2M would be a ~1 GB slab tensor — int8 only
        points.append((2_000_000, "int8"))
    rows = []
    for m_i, sdt in points:
        w = jax.random.normal(jax.random.PRNGKey(0), (m_i, D_MODEL),
                              jnp.float32)
        cfg = _lss_cfg(m_i, bucket_major=True, n_tables=2,
                       target=IMPL_TARGET_SAMPLE)
        eng = Engine(None, w, None, cfg, top_k=TOP_K,
                     buckets=(IMPL_BATCH,), impl="ref", slab_dtype=sdt)
        eng.fit_random(jax.random.PRNGKey(2))
        iters = 5 if fast else 10
        row = _row(eng, q, "lss", "ref", m_i, IMPL_BATCH, iters, None)
        row["slab_dtype"] = sdt
        row["slab_bytes"] = _slab_bytes(cfg, m_i, D_MODEL + 1, sdt)
        rows.append(row)
        del eng, w
    return rows


def bench_serving(fast: bool = True) -> dict:
    return {
        "bench": "serve",
        "backend": jax.default_backend(),
        "n_devices": len(jax.devices()),
        "fast": fast,
        "rows": (bench_heads(fast) + bench_impls(fast)
                 + bench_slab_storage(fast)),
    }


def write_artifact(record: dict, path: str | None = None) -> str:
    """Precedence: explicit path > $BENCH_SERVE_OUT > $BENCH_OUT_DIR/
    BENCH_serve.json > ./BENCH_serve.json."""
    path = (path or os.environ.get("BENCH_SERVE_OUT")
            or os.path.join(os.environ.get("BENCH_OUT_DIR", "."),
                            "BENCH_serve.json"))
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    return path


def main() -> None:
    fast = os.environ.get("BENCH_FAST", "1") != "0"
    rec = bench_serving(fast=fast)
    path = write_artifact(rec)
    print(f"wrote {path}")
    for r in rec["rows"]:
        speed = ("" if r["speedup_vs_full"] is None
                 else f"  speedup={r['speedup_vs_full']:.2f}x")
        print(f"  m={r['m']:>7} {r['head']:<11} {r['impl']:<16} "
              f"{r['us_per_query']:>10.1f} us/q  {r['req_per_s']:>9.0f} rps"
              f"  sample={r['avg_sample_size']:>7.0f}{speed}")


if __name__ == "__main__":
    main()
