"""Zero-downtime index refresh benchmark -> ``BENCH_refresh.json``.

Three rows, one per question the online-refresh story has to answer:

  * ``swap_latency`` — open-loop load (Poisson arrivals, ``block``
    policy so nothing can hide in a shed) against an ``AsyncRuntime``
    while a background thread swaps freshly refit indexes into the
    Engine mid-run.  Requests whose in-flight interval overlaps a swap
    window form the "during swap" population; the row reports their p99
    against the steady-state p99 (``p99_swap_ratio`` is the CI gate),
    the count of failed futures (must be 0 — a swap may never fail a
    request), and a bit-exactness probe: after the run, the serving
    engine's output must equal a cold engine built directly on the
    final index.
  * ``recall_staleness`` — start from a SimHash-initialised (stale)
    index, let :class:`IndexRefresher` cycles re-learn the hash online,
    and compare against an OFFLINE ``fit_lss`` on the same calibration
    set: the claim is that refreshing in place reaches the same recall
    as taking the server down to refit.  (On this synthetic isotropic
    WOL, IUL has no structure to exploit, so both recalls sit near the
    SimHash baseline — the row pins online ≈ offline, not an absolute
    gain; the gain story lives in the paper's real-activation runs.)
  * ``rollback`` — guarded-swap drill: live traffic feeds the recall
    auditor, a fault injection corrupts the probation recall to 0, and
    the row records that the refresher rolled back and how long the
    probation took to decide.

Run:  PYTHONPATH=src python -m benchmarks.refresh_bench
Env:  BENCH_FAST=1 shrinks sizes (default); BENCH_REFRESH_OUT /
      BENCH_OUT_DIR override the artifact path.
"""

from __future__ import annotations

import argparse
import json
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import iul, simhash
from repro.core.lss import LSSConfig
from repro.serve import AsyncRuntime, Engine
from repro.serve.refresh import IndexRefresher, RefreshConfig
from repro.serve.runtime import submit_open_loop
from repro.testing import faults

D_MODEL = 32
TOP_K = 10
SWAP_WINDOW_MARGIN_S = 0.05     # swap effects tail past the flip itself


def build_engine(m: int, buckets: tuple[int, ...], *, n_calib: int,
                 audit_rate: float = 0.0, trained: bool = True) -> Engine:
    """Engine on a synthetic WOL with TRUE top-k calibration labels, so
    refit recall is meaningful (random labels would make IUL chase
    noise).  ``trained=False`` leaves the SimHash init in place but
    still attaches the calibration snapshot the refresher needs."""
    cfg = LSSConfig(k_bits=6, n_tables=2, use_bucket_major=True)
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (m, D_MODEL), jnp.float32)
    q = np.asarray(jax.random.normal(jax.random.PRNGKey(2),
                                     (n_calib, D_MODEL), jnp.float32))
    scores = q @ np.asarray(w).T
    labels = np.argpartition(-scores, TOP_K, axis=1)[:, :TOP_K]
    eng = Engine(None, w, None, cfg, top_k=TOP_K, head="lss",
                 buckets=buckets, audit_rate=audit_rate)
    if trained:
        eng.fit_from_queries(jax.random.PRNGKey(1), jnp.asarray(q),
                             jnp.asarray(labels))
    else:
        eng.fit_random(jax.random.PRNGKey(1))
        eng.calib = (jnp.asarray(q), jnp.asarray(labels))
    return eng


def warm(eng: Engine) -> None:
    for b in eng.batcher.buckets:
        eng.rank(np.zeros((b, D_MODEL), np.float32), record=False)


def _refit_candidates(eng: Engine, n: int) -> list:
    """Pre-run ``n`` IUL refit epochs so the load segment measures the
    SWAP, not the refit (the refit is off the hot path by construction;
    on a shared-CPU bench box it would just add noise)."""
    q, labels = eng.calib
    q_aug = simhash.augment_queries(np.asarray(q, np.float32))
    state = iul.iul_init(jax.random.PRNGKey(3), q_aug, labels,
                         eng._w_aug, eng.lss_cfg, theta=eng.index.theta)
    idx, cands = eng.index, []
    for _ in range(n):
        state, idx, _ = iul.iul_refit_epoch(state, q_aug, labels,
                                            eng._w_aug, idx, eng.lss_cfg)
        cands.append(idx)
    return cands


def bench_swap_latency(*, m: int, n_requests: int, qps: float,
                       n_swaps: int, buckets: tuple[int, ...]) -> dict:
    eng = build_engine(m, buckets, n_calib=512)
    warm(eng)
    cands = _refit_candidates(eng, n_swaps)

    windows: list[tuple[float, float]] = []     # perf_counter spans
    duration = n_requests / qps
    spacing = duration / (n_swaps + 1)

    def swapper(t_start: float) -> None:
        for k, cand in enumerate(cands):
            wake = t_start + (k + 1) * spacing
            time.sleep(max(0.0, wake - time.perf_counter()))
            t0 = time.perf_counter()
            eng.swap_index(cand, warm=True)
            windows.append((t0, time.perf_counter()))

    rng = np.random.default_rng(5)
    xs = rng.standard_normal((n_requests, D_MODEL)).astype(np.float32)
    rt = AsyncRuntime(eng, head="lss", max_queue=n_requests + 8,
                      policy="block")
    th = threading.Thread(target=swapper, args=(time.perf_counter(),),
                          daemon=True)
    th.start()
    futs, _ = submit_open_loop(rt, xs, qps, seed=9)
    rt.drain(timeout=600.0)
    s = rt.stats()
    rt.close()
    th.join(timeout=60.0)
    assert not th.is_alive(), "swapper wedged"

    n_failed = sum(f.exception() is not None for f in futs)
    done = [f for f in futs if f.exception() is None]

    def in_swap(f) -> bool:
        return any(f.t_submit < t1 + SWAP_WINDOW_MARGIN_S and f.t_done > t0
                   for t0, t1 in windows)

    swap_lat = np.array([(f.t_done - f.t_submit) * 1e3
                         for f in done if in_swap(f)])
    steady_lat = np.array([(f.t_done - f.t_submit) * 1e3
                           for f in done if not in_swap(f)])
    p99_steady = float(np.percentile(steady_lat, 99))
    p99_swap = (float(np.percentile(swap_lat, 99)) if swap_lat.size
                else p99_steady)

    # bit-exactness probe: the engine after N online swaps must equal a
    # cold engine built directly on the final candidate index
    cold = build_engine(m, buckets, n_calib=512, trained=False)
    cold._set_index(cands[-1])
    probe = xs[: max(buckets)]
    exact = bool(np.array_equal(
        np.asarray(eng.rank(probe, record=False).logits),
        np.asarray(cold.rank(probe, record=False).logits)))
    return {
        "kind": "swap_latency",
        "head": "lss", "m": m, "d": D_MODEL,
        "qps": qps, "n_requests": n_requests, "n_swaps": n_swaps,
        "p50_steady_ms": round(float(np.percentile(steady_lat, 50)), 3),
        "p99_steady_ms": round(p99_steady, 3),
        "p99_swap_ms": round(p99_swap, 3),
        "p99_swap_ratio": round(p99_swap / p99_steady, 3),
        "swap_window_n": int(swap_lat.size),
        "swap_ms_mean": round(float(np.mean(
            [(t1 - t0) * 1e3 for t0, t1 in windows])), 3),
        "n_failed": n_failed,
        "n_shed": s.n_shed_queue + s.n_shed_deadline,
        "exact_after_swaps": exact,
        "n_cpus": os.cpu_count() or 1,
    }


def bench_recall_staleness(*, m: int, n_cycles: int,
                           buckets: tuple[int, ...]) -> dict:
    eng = build_engine(m, buckets, n_calib=512, trained=False)
    q, labels = eng.calib
    q_aug = simhash.augment_queries(np.asarray(q, np.float32))
    stale = iul.calib_recall(eng.index, q_aug, labels)
    r = IndexRefresher(eng, auditor=None,
                       cfg=RefreshConfig(warm=False))
    for _ in range(n_cycles):
        outcome = r.refresh_once()
        assert outcome == "swapped", outcome
    online = iul.calib_recall(eng.index, q_aug, labels)
    offline_index, _ = iul.fit_lss(jax.random.PRNGKey(4), q, labels,
                                   eng.w, eng.b, eng.lss_cfg)
    offline = iul.calib_recall(offline_index, q_aug, labels)
    return {
        "kind": "recall_staleness",
        "m": m, "d": D_MODEL, "n_cycles": n_cycles,
        "n_calib": int(q_aug.shape[0]), "top_k": TOP_K,
        "recall_stale": round(stale, 4),
        "recall_refreshed": round(online, 4),
        "recall_offline_refit": round(offline, 4),
        "gap_to_offline": round(offline - online, 4),
    }


def bench_rollback(*, m: int, buckets: tuple[int, ...]) -> dict:
    cfg = RefreshConfig(probation_s=30.0, min_audit_rows=64,
                        probation_poll_s=0.02, warm=False)
    eng = build_engine(m, buckets, n_calib=512, audit_rate=1.0)
    warm(eng)
    xs = np.asarray(eng.calib[0], np.float32)
    b = max(buckets)
    for i in range(12):                         # pre-swap audit baseline
        eng.rank(xs[b * i % len(xs):][:b])
    eng.auditor.drain()

    stop = threading.Event()

    def traffic() -> None:
        # record=True feeds the auditor (record=False bypasses it); the
        # 50 ms pacing keeps a 1-CPU bench box from starving the refit
        # (16 rows / 50 ms = 320 audited rows/s, probation needs 64)
        i = 0
        while not stop.is_set():
            eng.rank(xs[b * i % len(xs):][:b])
            i += 1
            time.sleep(0.05)

    th = threading.Thread(target=traffic, daemon=True)
    th.start()
    r = IndexRefresher(eng, cfg=cfg)
    try:
        t0 = time.perf_counter()
        with faults.injected(faults.REFRESH_PROBATION,
                             lambda ctx: ctx.__setitem__("recall", 0.0)):
            outcome = r.refresh_once()
        dt = time.perf_counter() - t0
    finally:
        stop.set()
        th.join()
        eng.auditor.close()
    return {
        "kind": "rollback",
        "m": m, "d": D_MODEL,
        "outcome": outcome,
        "rollback_total": r.n_rollbacks,
        "time_to_rollback_s": round(dt, 3),
        "probation_s": cfg.probation_s,
        "min_audit_rows": cfg.min_audit_rows,
        "rollback_delta": cfg.rollback_delta,
    }


def write_artifact(record: dict, path: str | None = None) -> str:
    path = (path or os.environ.get("BENCH_REFRESH_OUT")
            or os.path.join(os.environ.get("BENCH_OUT_DIR", "."),
                            "BENCH_refresh.json"))
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    return path


def main(argv: list[str] | None = None) -> dict:
    fast = os.environ.get("BENCH_FAST", "1") != "0"
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--m", type=int, default=20_000 if fast else 100_000)
    ap.add_argument("--requests", type=int, default=900 if fast else 4000)
    ap.add_argument("--qps", type=float, default=300.0 if fast else 500.0)
    ap.add_argument("--swaps", type=int, default=3 if fast else 8)
    ap.add_argument("--cycles", type=int, default=2 if fast else 8,
                    help="recall_staleness refresh cycles")
    ap.add_argument("--buckets", type=lambda s: tuple(
        int(x) for x in s.split(",")), default=(1, 4, 16))
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    rows = [
        bench_swap_latency(m=args.m, n_requests=args.requests,
                           qps=args.qps, n_swaps=args.swaps,
                           buckets=args.buckets),
        bench_recall_staleness(m=args.m, n_cycles=args.cycles,
                               buckets=args.buckets),
        bench_rollback(m=args.m, buckets=args.buckets),
    ]
    rec = {
        "bench": "refresh",
        "backend": jax.default_backend(),
        "n_devices": len(jax.devices()),
        "buckets": list(args.buckets),
        "rows": rows,
    }
    path = write_artifact(rec, args.out)
    print(f"wrote {path}")
    sw, st, rb = rows
    print(f"  swap_latency: p99 steady={sw['p99_steady_ms']:.2f} ms  "
          f"during-swap={sw['p99_swap_ms']:.2f} ms  "
          f"ratio={sw['p99_swap_ratio']:.2f}  "
          f"({sw['swap_window_n']} in-window reqs, "
          f"{sw['n_swaps']} swaps @ {sw['swap_ms_mean']:.1f} ms, "
          f"failed={sw['n_failed']}, exact={sw['exact_after_swaps']})")
    print(f"  recall_staleness: stale={st['recall_stale']:.4f} "
          f"refreshed={st['recall_refreshed']:.4f} "
          f"offline-refit={st['recall_offline_refit']:.4f} "
          f"(gap {st['gap_to_offline']:+.4f} over "
          f"{st['n_cycles']} cycles)")
    print(f"  rollback: {rb['outcome']} in {rb['time_to_rollback_s']:.2f}s "
          f"(probation {rb['probation_s']}s, "
          f"rollbacks={rb['rollback_total']})")
    return rec


if __name__ == "__main__":
    main()
