"""Open-loop load harness for the async serving runtime.

Drives an :class:`AsyncRuntime` with Poisson arrivals at configurable
offered QPS (open loop: the generator never waits for results, exactly
the "heavy traffic from millions of users" regime — queueing delay is
visible instead of hidden by a closed loop), sweeping heads
(full | lss | lss-sharded) and kernel impls, and writes the
``BENCH_load.json`` artifact consumed by CI.

Each (head, impl, qps) point reports:

  * offered vs achieved request rate,
  * queue-wait-INCLUSIVE latency p50/p95/p99 (what a client sees),
  * shed counts (queue-full and deadline) and mean batch occupancy,
  * the synchronous baseline — a blocking ``submit``/``flush`` loop over
    the same requests on the same engine and bucket ladder (one request
    in flight at a time: the semantics the synchronous Engine offers an
    online caller) — and the async/sync throughput ratio.

Run:  PYTHONPATH=src python -m benchmarks.load_bench --qps 200,2000
Env:  BENCH_FAST=1 shrinks sizes (default); BENCH_LOAD_OUT / BENCH_OUT_DIR
      override the artifact path.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lss import LSSConfig
from repro.serve import AsyncRuntime, Engine
from repro.serve.runtime import submit_open_loop

D_MODEL = 32
TOP_K = 10
TARGET_SAMPLE = 512            # aim ~512 candidates per query


def build_engine(m: int, impl: str | None, buckets: tuple[int, ...]
                 ) -> Engine:
    """SimHash-initialised engine on a synthetic WOL (retrieval speed is
    learning-independent; see benchmarks/serve_bench.py)."""
    k_bits = max(4, math.ceil(math.log2(max(2 * m / TARGET_SAMPLE, 2))))
    cfg = LSSConfig(k_bits=k_bits, n_tables=2, use_bucket_major=True)
    w = jax.random.normal(jax.random.PRNGKey(0), (m, D_MODEL), jnp.float32)
    eng = Engine(None, w, None, cfg, top_k=TOP_K, buckets=buckets,
                 impl=impl)
    eng.fit_random(jax.random.PRNGKey(2))
    return eng


def warm(eng: Engine, head: str) -> None:
    """Compile every (head, bucket) step up front so the measured segment
    contains zero traces."""
    for b in eng.batcher.buckets:
        eng.rank(np.zeros((b, D_MODEL), np.float32), head=head,
                 record=False)


def run_async_point(eng: Engine, head: str, xs: np.ndarray, qps: float,
                    seed: int, *, policy: str, max_queue: int,
                    deadline_s: float | None) -> dict:
    """One open-loop segment: Poisson arrivals at ``qps`` (``qps <= 0`` =
    burst: every request arrives at t=0), drain, stats."""
    rt = AsyncRuntime(eng, head=head, max_queue=max_queue, policy=policy,
                      default_deadline_s=deadline_s)
    futs, arrivals = submit_open_loop(rt, xs, qps, seed=seed)
    rt.drain(timeout=120.0)
    s = rt.stats()
    rt.close()
    n_ok = sum(f.exception() is None for f in futs)
    assert n_ok == s.n_completed, (n_ok, s.n_completed)
    return {
        "n": xs.shape[0],
        "qps_offered": (None if qps <= 0
                        else round(xs.shape[0] / arrivals[-1], 1)),
        "achieved_rps": round(s.throughput_rps, 1),
        "p50_ms": round(s.latency_p50_ms, 3),
        "p95_ms": round(s.latency_p95_ms, 3),
        "p99_ms": round(s.latency_p99_ms, 3),
        "device_ms_per_batch": round(s.device_ms_per_batch, 3),
        "shed_queue": s.n_shed_queue,
        "shed_deadline": s.n_shed_deadline,
        "n_batches": s.n_batches,
        "occupancy": round(s.avg_batch_occupancy, 3),
    }


def run_sync_baseline(eng: Engine, head: str, xs: np.ndarray) -> float:
    """Blocking submit->flush per request (no cross-request batching):
    the throughput ceiling of the synchronous library interface."""
    t0 = time.perf_counter()
    for i in range(xs.shape[0]):
        eng.submit(xs[i])
        eng.flush(head=head)
    return xs.shape[0] / (time.perf_counter() - t0)


def bench_load(*, m: int, n_requests: int, qps_list: list[float],
               heads: list[str], impls: list[str | None],
               buckets: tuple[int, ...], policy: str, max_queue: int,
               deadline_ms: float | None) -> dict:
    deadline_s = None if deadline_ms is None else deadline_ms / 1e3
    rng = np.random.default_rng(1)
    xs = rng.standard_normal((n_requests, D_MODEL)).astype(np.float32)
    rows = []
    for impl in impls:
        eng = build_engine(m, impl, buckets)
        for head in heads:
            warm(eng, head)
            sync_rps = run_sync_baseline(eng, head, xs)
            for qps in qps_list:
                row = run_async_point(
                    eng, head, xs, qps, seed=7, policy=policy,
                    max_queue=max_queue, deadline_s=deadline_s)
                row.update({
                    "head": head, "impl": impl or "auto", "m": m,
                    "d": D_MODEL, "qps": qps, "policy": policy,
                    "max_queue": max_queue, "deadline_ms": deadline_ms,
                    "sync_rps": round(sync_rps, 1),
                    "speedup_vs_sync": round(row["achieved_rps"]
                                             / sync_rps, 2),
                })
                rows.append(row)
    return {
        "bench": "load",
        "backend": jax.default_backend(),
        "n_devices": len(jax.devices()),
        "buckets": list(buckets),
        "rows": rows,
    }


def write_artifact(record: dict, path: str | None = None) -> str:
    """Precedence: explicit path > $BENCH_LOAD_OUT > $BENCH_OUT_DIR/
    BENCH_load.json > ./BENCH_load.json."""
    path = (path or os.environ.get("BENCH_LOAD_OUT")
            or os.path.join(os.environ.get("BENCH_OUT_DIR", "."),
                            "BENCH_load.json"))
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    return path


def _csv_floats(s: str) -> list[float]:
    return [float(x) for x in s.split(",") if x]


def main(argv: list[str] | None = None) -> dict:
    fast = os.environ.get("BENCH_FAST", "1") != "0"
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--qps", type=_csv_floats,
                    default=[200.0, 0.0] if fast
                    else [100.0, 500.0, 2000.0, 0.0],
                    help="comma-separated offered QPS sweep; 0 = burst "
                         "(every request arrives at t=0, saturation point)")
    ap.add_argument("--requests", type=int, default=256 if fast else 2048)
    ap.add_argument("--m", type=int, default=20_000 if fast else 100_000)
    ap.add_argument("--heads", default="full,lss,lss-sharded",
                    help="comma-separated head kinds")
    ap.add_argument("--impls", default="ref",
                    help="comma-separated kernel impls (ref|pallas|"
                         "pallas_interpret|auto)")
    ap.add_argument("--buckets", type=lambda s: tuple(
        int(x) for x in s.split(",")),
        default=(1, 4, 16) if fast else (1, 2, 4, 8, 16, 32))
    ap.add_argument("--policy", choices=("block", "shed"), default="shed")
    ap.add_argument("--max-queue", type=int, default=4096)
    ap.add_argument("--deadline-ms", type=float, default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    rec = bench_load(
        m=args.m, n_requests=args.requests, qps_list=args.qps,
        heads=[h for h in args.heads.split(",") if h],
        impls=[None if i == "auto" else i
               for i in args.impls.split(",") if i],
        buckets=args.buckets, policy=args.policy,
        max_queue=args.max_queue, deadline_ms=args.deadline_ms)
    path = write_artifact(rec, args.out)
    print(f"wrote {path}")
    for r in rec["rows"]:
        qps = "  burst" if r["qps"] <= 0 else f"{r['qps']:>7.0f}"
        print(f"  {r['head']:<11} {r['impl']:<6} qps={qps} "
              f"achieved={r['achieved_rps']:>8.1f} rps  "
              f"p50={r['p50_ms']:>7.2f} p95={r['p95_ms']:>7.2f} "
              f"p99={r['p99_ms']:>7.2f} ms  occ={r['occupancy']:.2f}  "
              f"shed={r['shed_queue']}+{r['shed_deadline']}  "
              f"sync={r['sync_rps']:>8.1f} rps  "
              f"x{r['speedup_vs_sync']:.2f}")
    return rec


if __name__ == "__main__":
    main()
