"""Open-loop load harness for the async serving runtime.

Drives an :class:`AsyncRuntime` with Poisson arrivals at configurable
offered QPS (open loop: the generator never waits for results, exactly
the "heavy traffic from millions of users" regime — queueing delay is
visible instead of hidden by a closed loop), sweeping heads
(full | lss | lss-sharded) and kernel impls, and writes the
``BENCH_load.json`` artifact consumed by CI.

Each (head, impl, qps) point reports:

  * offered vs achieved request rate,
  * queue-wait-INCLUSIVE latency p50/p95/p99 (what a client sees),
  * shed counts (queue-full and deadline) and mean batch occupancy,
  * the synchronous baseline — a blocking ``submit``/``flush`` loop over
    the same requests on the same engine and bucket ladder (one request
    in flight at a time: the semantics the synchronous Engine offers an
    online caller) — and the async/sync throughput ratio.

A second mode (``--bench obs``) measures the observability tax and
writes ``BENCH_obs.json``: the same burst workload with obs fully on
(metric histograms + request tracing + 5% recall audit) vs a no-op
registry (``obs.set_enabled(False)`` before construction — every
record/span call hits the shared no-op object), reporting client-side
throughput and p99 for both plus an ``audit_recall`` row where the
online auditor at rate 1.0 is checked against an offline brute-force
rerank of the same requests.  ``--max-overhead-pct`` turns the overhead
number into a CI guard.

Run:  PYTHONPATH=src python -m benchmarks.load_bench --qps 200,2000
      PYTHONPATH=src python -m benchmarks.load_bench --bench obs
Env:  BENCH_FAST=1 shrinks sizes (default); BENCH_LOAD_OUT /
      BENCH_OBS_OUT / BENCH_OUT_DIR override the artifact paths.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.lss import LSSConfig
from repro.serve import AsyncRuntime, Engine
from repro.serve.runtime import submit_open_loop

D_MODEL = 32
TOP_K = 10
TARGET_SAMPLE = 512            # aim ~512 candidates per query


def build_engine(m: int, impl: str | None, buckets: tuple[int, ...]
                 ) -> Engine:
    """SimHash-initialised engine on a synthetic WOL (retrieval speed is
    learning-independent; see benchmarks/serve_bench.py)."""
    k_bits = max(4, math.ceil(math.log2(max(2 * m / TARGET_SAMPLE, 2))))
    cfg = LSSConfig(k_bits=k_bits, n_tables=2, use_bucket_major=True)
    w = jax.random.normal(jax.random.PRNGKey(0), (m, D_MODEL), jnp.float32)
    eng = Engine(None, w, None, cfg, top_k=TOP_K, buckets=buckets,
                 impl=impl)
    eng.fit_random(jax.random.PRNGKey(2))
    return eng


def warm(eng: Engine, head: str) -> None:
    """Compile every (head, bucket) step up front so the measured segment
    contains zero traces."""
    for b in eng.batcher.buckets:
        eng.rank(np.zeros((b, D_MODEL), np.float32), head=head,
                 record=False)


def run_async_point(eng: Engine, head: str, xs: np.ndarray, qps: float,
                    seed: int, *, policy: str, max_queue: int,
                    deadline_s: float | None) -> dict:
    """One open-loop segment: Poisson arrivals at ``qps`` (``qps <= 0`` =
    burst: every request arrives at t=0), drain, stats."""
    rt = AsyncRuntime(eng, head=head, max_queue=max_queue, policy=policy,
                      default_deadline_s=deadline_s)
    futs, arrivals = submit_open_loop(rt, xs, qps, seed=seed)
    rt.drain(timeout=120.0)
    s = rt.stats()
    rt.close()
    n_ok = sum(f.exception() is None for f in futs)
    assert n_ok == s.n_completed, (n_ok, s.n_completed)
    return {
        "n": xs.shape[0],
        "qps_offered": (None if qps <= 0
                        else round(xs.shape[0] / arrivals[-1], 1)),
        "achieved_rps": round(s.throughput_rps, 1),
        "p50_ms": round(s.latency_p50_ms, 3),
        "p95_ms": round(s.latency_p95_ms, 3),
        "p99_ms": round(s.latency_p99_ms, 3),
        "device_ms_per_batch": round(s.device_ms_per_batch, 3),
        "shed_queue": s.n_shed_queue,
        "shed_deadline": s.n_shed_deadline,
        "n_batches": s.n_batches,
        "occupancy": round(s.avg_batch_occupancy, 3),
    }


def run_sync_baseline(eng: Engine, head: str, xs: np.ndarray) -> float:
    """Blocking submit->flush per request (no cross-request batching):
    the throughput ceiling of the synchronous library interface."""
    t0 = time.perf_counter()
    for i in range(xs.shape[0]):
        eng.submit(xs[i])
        eng.flush(head=head)
    return xs.shape[0] / (time.perf_counter() - t0)


def bench_load(*, m: int, n_requests: int, qps_list: list[float],
               heads: list[str], impls: list[str | None],
               buckets: tuple[int, ...], policy: str, max_queue: int,
               deadline_ms: float | None) -> dict:
    deadline_s = None if deadline_ms is None else deadline_ms / 1e3
    rng = np.random.default_rng(1)
    xs = rng.standard_normal((n_requests, D_MODEL)).astype(np.float32)
    rows = []
    for impl in impls:
        eng = build_engine(m, impl, buckets)
        for head in heads:
            warm(eng, head)
            sync_rps = run_sync_baseline(eng, head, xs)
            for qps in qps_list:
                row = run_async_point(
                    eng, head, xs, qps, seed=7, policy=policy,
                    max_queue=max_queue, deadline_s=deadline_s)
                row.update({
                    "head": head, "impl": impl or "auto", "m": m,
                    "d": D_MODEL, "qps": qps, "policy": policy,
                    "max_queue": max_queue, "deadline_ms": deadline_ms,
                    "sync_rps": round(sync_rps, 1),
                    "speedup_vs_sync": round(row["achieved_rps"]
                                             / sync_rps, 2),
                })
                rows.append(row)
    return {
        "bench": "load",
        "backend": jax.default_backend(),
        "n_devices": len(jax.devices()),
        "buckets": list(buckets),
        "rows": rows,
    }


# --------------------------------------------------------- obs bench --

def _client_point(futs: list) -> tuple[float, float]:
    """(rps, p99_ms) measured entirely client-side from future
    timestamps — identical instrumentation in obs-on and no-op modes,
    so the comparison never depends on the registry being live."""
    done = [f for f in futs if f.exception() is None]
    lats = np.array([f.t_done - f.t_submit for f in done])
    span = max(f.t_done for f in done) - min(f.t_submit for f in done)
    return len(done) / span, float(np.percentile(lats, 99) * 1e3)


def _run_obs_mode(*, enabled: bool, audit_rate: float, m: int,
                  buckets: tuple[int, ...], xs: np.ndarray, reps: int
                  ) -> tuple[float, float]:
    """Best-of-``reps`` burst segment with obs force-(en|dis)abled before
    any component is constructed (registries capture the switch then)."""
    obs.set_enabled(enabled)
    eng = build_engine(m, "ref", buckets)
    if audit_rate > 0:
        from repro.obs.audit import RecallAuditor
        eng.auditor = RecallAuditor(eng, audit_rate,
                                    queue_cap=xs.shape[0])
    warm(eng, "lss")
    best_rps, best_p99 = 0.0, math.inf
    for rep in range(reps + 1):        # rep 0 is an untimed warm-up
        rt = AsyncRuntime(eng, head="lss", max_queue=xs.shape[0] + 8,
                          policy="shed")
        futs, _ = submit_open_loop(rt, xs, 0.0, seed=11 + rep)
        rt.drain(timeout=120.0)
        rt.close()
        if rep == 0:
            continue
        rps, p99 = _client_point(futs)
        best_rps, best_p99 = max(best_rps, rps), min(best_p99, p99)
    if eng.auditor is not None:
        eng.auditor.drain()
        eng.auditor.close()
    return best_rps, best_p99


def _run_audit_point(*, m: int, buckets: tuple[int, ...],
                     xs: np.ndarray) -> dict:
    """Auditor at rate 1.0 vs an offline brute-force rerank of the SAME
    requests through the engine's own full head."""
    obs.set_enabled(True)
    eng = build_engine(m, "ref", buckets)
    from repro.obs.audit import RecallAuditor
    eng.auditor = RecallAuditor(eng, 1.0, queue_cap=xs.shape[0])
    warm(eng, "lss")
    rt = AsyncRuntime(eng, head="lss", max_queue=xs.shape[0] + 8,
                      policy="shed")
    futs, _ = submit_open_loop(rt, xs, 0.0, seed=13)
    rt.drain(timeout=120.0)
    rt.close()
    eng.auditor.drain()
    online = eng.auditor.recall
    n_rows = eng.auditor.n_rows
    eng.auditor.close()

    served = np.stack([np.asarray(f.result().ids).reshape(-1)
                       for f in futs])
    bmax = max(eng.batcher.buckets)
    exact = np.concatenate(
        [np.asarray(eng.rank(xs[i:i + bmax], head="full",
                             record=False).ids).reshape(len(xs[i:i + bmax]), -1)
         for i in range(0, xs.shape[0], bmax)], axis=0)
    hit = (exact[:, :, None] == served[:, None, :]).any(-1)
    offline = float(hit.mean())
    return {
        "kind": "audit_recall",
        "recall_online": online,
        "recall_offline": offline,
        "recall_delta": abs(online - offline),
        "n_rows": n_rows,
        "top_k": TOP_K,
        "audit_rate": 1.0,
    }


def bench_obs(*, m: int, n_requests: int, buckets: tuple[int, ...],
              audit_rate: float, reps: int) -> dict:
    rng = np.random.default_rng(3)
    xs = rng.standard_normal((n_requests, D_MODEL)).astype(np.float32)
    was_enabled = obs.enabled()
    try:
        rps_on, p99_on = _run_obs_mode(
            enabled=True, audit_rate=audit_rate, m=m, buckets=buckets,
            xs=xs, reps=reps)
        rps_off, p99_off = _run_obs_mode(
            enabled=False, audit_rate=0.0, m=m, buckets=buckets,
            xs=xs, reps=reps)
        overhead = {
            "kind": "overhead",
            "rps_on": round(rps_on, 1),
            "rps_off": round(rps_off, 1),
            "overhead_pct": round((rps_off - rps_on) / rps_off * 100, 3),
            "p99_on_ms": round(p99_on, 3),
            "p99_off_ms": round(p99_off, 3),
            "audit_rate": audit_rate,
            "n_requests": n_requests,
            "reps": reps,
        }
        audit = _run_audit_point(m=m, buckets=buckets, xs=xs)
    finally:
        obs.set_enabled(was_enabled)
    return {
        "bench": "obs",
        "backend": jax.default_backend(),
        "n_devices": len(jax.devices()),
        "buckets": list(buckets),
        "rows": [overhead, audit],
    }


def write_artifact(record: dict, path: str | None = None) -> str:
    """Precedence: explicit path > $BENCH_LOAD_OUT / $BENCH_OBS_OUT >
    $BENCH_OUT_DIR/BENCH_<bench>.json > ./BENCH_<bench>.json."""
    bench = record.get("bench", "load")
    env = "BENCH_OBS_OUT" if bench == "obs" else "BENCH_LOAD_OUT"
    path = (path or os.environ.get(env)
            or os.path.join(os.environ.get("BENCH_OUT_DIR", "."),
                            f"BENCH_{bench}.json"))
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    return path


def _csv_floats(s: str) -> list[float]:
    return [float(x) for x in s.split(",") if x]


def main(argv: list[str] | None = None) -> dict:
    fast = os.environ.get("BENCH_FAST", "1") != "0"
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--bench", choices=("load", "obs"), default="load",
                    help="load: QPS sweep -> BENCH_load.json; obs: "
                         "observability overhead + online-vs-offline "
                         "audit recall -> BENCH_obs.json")
    ap.add_argument("--reps", type=int, default=3,
                    help="obs bench: best-of-N repetitions per mode")
    ap.add_argument("--audit-rate", type=float, default=0.05,
                    help="obs bench: audit sampling rate in the "
                         "obs-on overhead segment")
    ap.add_argument("--max-overhead-pct", type=float, default=None,
                    help="obs bench: fail (exit 1) if obs-on throughput "
                         "overhead exceeds this percentage")
    ap.add_argument("--qps", type=_csv_floats,
                    default=[200.0, 0.0] if fast
                    else [100.0, 500.0, 2000.0, 0.0],
                    help="comma-separated offered QPS sweep; 0 = burst "
                         "(every request arrives at t=0, saturation point)")
    ap.add_argument("--requests", type=int, default=256 if fast else 2048)
    ap.add_argument("--m", type=int, default=20_000 if fast else 100_000)
    ap.add_argument("--heads", default="full,lss,lss-sharded",
                    help="comma-separated head kinds")
    ap.add_argument("--impls", default="ref",
                    help="comma-separated kernel impls (ref|pallas|"
                         "pallas_interpret|auto)")
    ap.add_argument("--buckets", type=lambda s: tuple(
        int(x) for x in s.split(",")),
        default=(1, 4, 16) if fast else (1, 2, 4, 8, 16, 32))
    ap.add_argument("--policy", choices=("block", "shed"), default="shed")
    ap.add_argument("--max-queue", type=int, default=4096)
    ap.add_argument("--deadline-ms", type=float, default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    if args.bench == "obs":
        rec = bench_obs(
            m=args.m, n_requests=args.requests, buckets=args.buckets,
            audit_rate=args.audit_rate, reps=args.reps)
        path = write_artifact(rec, args.out)
        print(f"wrote {path}")
        oh = next(r for r in rec["rows"] if r["kind"] == "overhead")
        au = next(r for r in rec["rows"] if r["kind"] == "audit_recall")
        print(f"  obs-on  {oh['rps_on']:>8.1f} rps  "
              f"p99={oh['p99_on_ms']:>7.2f} ms  "
              f"(audit rate {oh['audit_rate']})")
        print(f"  obs-off {oh['rps_off']:>8.1f} rps  "
              f"p99={oh['p99_off_ms']:>7.2f} ms  (no-op registry)")
        print(f"  overhead {oh['overhead_pct']:+.2f}%")
        print(f"  audit recall@{au['top_k']}: online={au['recall_online']:.6f} "
              f"offline={au['recall_offline']:.6f} "
              f"delta={au['recall_delta']:.2e} over {au['n_rows']} rows")
        if (args.max_overhead_pct is not None
                and oh["overhead_pct"] > args.max_overhead_pct):
            print(f"OBS OVERHEAD GUARD FAILED: {oh['overhead_pct']:.2f}% "
                  f"> {args.max_overhead_pct}%", file=sys.stderr)
            sys.exit(1)
        return rec

    rec = bench_load(
        m=args.m, n_requests=args.requests, qps_list=args.qps,
        heads=[h for h in args.heads.split(",") if h],
        impls=[None if i == "auto" else i
               for i in args.impls.split(",") if i],
        buckets=args.buckets, policy=args.policy,
        max_queue=args.max_queue, deadline_ms=args.deadline_ms)
    path = write_artifact(rec, args.out)
    print(f"wrote {path}")
    for r in rec["rows"]:
        qps = "  burst" if r["qps"] <= 0 else f"{r['qps']:>7.0f}"
        print(f"  {r['head']:<11} {r['impl']:<6} qps={qps} "
              f"achieved={r['achieved_rps']:>8.1f} rps  "
              f"p50={r['p50_ms']:>7.2f} p95={r['p95_ms']:>7.2f} "
              f"p99={r['p99_ms']:>7.2f} ms  occ={r['occupancy']:.2f}  "
              f"shed={r['shed_queue']}+{r['shed_deadline']}  "
              f"sync={r['sync_rps']:>8.1f} rps  "
              f"x{r['speedup_vs_sync']:.2f}")
    return rec


if __name__ == "__main__":
    main()
