"""Paper reproduction benchmarks (Tables 1-3, Figure 2).

Pipeline per dataset (exactly the paper's): train the model on synthetic
topic-structured data at bench scale (paper dims are dry-run-only; no
internet in this container) -> freeze -> fit LSS on TRAIN embeddings ->
evaluate every method on TEST.

Metrics: P@1, P@5, label recall, sample size, wall-clock per 1000
queries (CPU, jit-warmed), and an energy PROXY (MFLOP/query — no power
rail in this container; the paper's Joules track FLOPs here).
"""

from __future__ import annotations

import os
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import baselines as B
from repro.configs.paper_datasets import ALL as SETTINGS
from repro.core import simhash
from repro.core.iul import fit_lss
from repro.core.lss import (avg_sample_size, label_recall, lss_predict,
                            precision_at_k, retrieve)
from repro.data.synthetic import lm_dataset, xc_dataset
from repro.data.pipeline import ShardedBatchIterator
from repro.models import lstm as lstm_mod
from repro.models import xc as xc_mod
from repro.train.trainer import TrainConfig, Trainer

# fast is the default across benchmarks; BENCH_FAST=0 runs full size
FAST = os.environ.get("BENCH_FAST", "1") != "0"


class Row(NamedTuple):
    dataset: str
    method: str
    p1: float
    p5: float
    recall: float
    sample: float
    us_per_query: float
    mflop_per_query: float


def _timeit(fn, *args, n_queries: int, reps: int = 3) -> float:
    fn(*args)  # warm (jit)
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps / n_queries * 1e6


def _train_xc(setting, n_train=4096, steps=600):
    cfg = setting.bench
    data = xc_dataset(7, n_train, cfg.input_dim, cfg.output_dim,
                      n_topics=48, max_in=cfg.max_in,
                      max_labels=cfg.max_labels)
    tc = TrainConfig(lr=5e-3, warmup_steps=30, total_steps=steps,
                     weight_decay=0.0, ckpt_every=10 ** 9)
    tr = Trainer(lambda p, b: xc_mod.loss(p, b, cfg),
                 lambda k: xc_mod.init_params(k, cfg), tc)
    it = ShardedBatchIterator({"x": data.x, "labels": data.labels}, 256,
                              seed=0)
    state, _ = tr.fit(jax.random.PRNGKey(0), it, steps, log_every=10 ** 9)
    params = state.params
    n_test = min(1024, n_train // 4)
    q_all = xc_mod.embed(params, jnp.asarray(data.x))
    q_train, q_test = q_all[n_test:], q_all[:n_test]
    lab = jnp.asarray(data.labels)
    return params, cfg, q_train, lab[n_test:], q_test, lab[:n_test]


def _train_lstm(setting, steps=200):
    cfg = setting.bench
    toks = lm_dataset(3, 120_000 if not FAST else 30_000, cfg.vocab, 36)
    tokens, labels = toks[:, :-1], toks[:, 1:]
    tc = TrainConfig(lr=5e-3, warmup_steps=30, total_steps=steps,
                     weight_decay=0.0, ckpt_every=10 ** 9)
    tr = Trainer(lambda p, b: lstm_mod.loss(p, b, cfg),
                 lambda k: lstm_mod.init_params(k, cfg), tc)
    it = ShardedBatchIterator({"tokens": tokens, "labels": labels}, 64,
                              seed=0)
    state, _ = tr.fit(jax.random.PRNGKey(0), it, steps, log_every=10 ** 9)
    params = state.params
    h = lstm_mod.embed_seq(params, jnp.asarray(tokens[:96]), cfg)
    q = h.reshape(-1, cfg.hidden)
    lab = jnp.asarray(labels[:96]).reshape(-1, 1)
    n_test = 1024
    return params, cfg, q[n_test:4096], lab[n_test:4096], \
        q[:n_test], lab[:n_test]


def _eval_common(name, ids_fn, q_test, lab_test, d, k=5):
    ids, scored = ids_fn()
    us = _timeit(lambda: ids_fn()[0], n_queries=q_test.shape[0])
    p1 = float(precision_at_k(ids, lab_test, 1))
    p5 = float(precision_at_k(ids, lab_test, 5))
    hit = (ids[:, :, None] == lab_test[:, None, :]) \
        & (lab_test >= 0)[:, None, :]
    rec = float(jnp.sum(hit.any(1)) / jnp.maximum((lab_test >= 0).sum(), 1))
    mflop = 2 * scored * d / 1e6
    return p1, p5, rec, scored, us, mflop


def run_setting(name: str, steps=None) -> list[Row]:
    setting = SETTINGS[name]
    fast_steps = 150 if FAST else 600
    if setting.kind == "lstm":
        params, cfg, q_tr, lab_tr, q_te, lab_te = _train_lstm(
            setting, steps or (60 if FAST else 200))
        w = params["w_out"].astype(jnp.float32)
        b = params["b_out"].astype(jnp.float32)
        d = cfg.hidden
        m = cfg.vocab
    else:
        params, cfg, q_tr, lab_tr, q_te, lab_te = _train_xc(
            setting, n_train=2048 if FAST else 4096,
            steps=steps or fast_steps)
        w = params["w_out"].astype(jnp.float32)
        b = params["b_out"].astype(jnp.float32)
        d = cfg.hidden
        m = cfg.output_dim

    rows = []
    nq = q_te.shape[0]

    # FULL
    f = jax.jit(lambda q: B.full_topk(q, w, b, 5)[0])
    ids = f(q_te)
    us = _timeit(f, q_te, n_queries=nq)
    rows.append(Row(name, "Full", float(precision_at_k(ids, lab_te, 1)),
                    float(precision_at_k(ids, lab_te, 5)), 1.0, m, us,
                    2 * m * d / 1e6))

    # LSS (paper)
    lss_cfg = setting.bench_lss
    index, hist = fit_lss(jax.random.PRNGKey(1), q_tr, lab_tr, w, b,
                          lss_cfg)
    lss_fn = jax.jit(lambda q: lss_predict(q, index, None, top_k=5)[1])
    cand, _ = retrieve(simhash.augment_queries(q_te), index)
    sample = float(avg_sample_size(cand))
    ids = lss_fn(q_te)
    us = _timeit(lss_fn, q_te, n_queries=nq)
    rows.append(Row(name, "LSS", float(precision_at_k(ids, lab_te, 1)),
                    float(precision_at_k(ids, lab_te, 5)),
                    float(label_recall(cand, lab_te)), sample, us,
                    2 * (d * lss_cfg.k_bits * lss_cfg.n_tables
                         + sample * d) / 1e6))
    run_setting.last_hist = hist     # fig2 consumer

    # SLIDE (random simhash)
    sl_index = B.slide_build(jax.random.PRNGKey(2), w, b, lss_cfg)
    sl_fn = jax.jit(lambda q: lss_predict(q, sl_index, None, top_k=5)[1])
    cand0, _ = retrieve(simhash.augment_queries(q_te), sl_index)
    sample0 = float(avg_sample_size(cand0))
    ids = sl_fn(q_te)
    us = _timeit(sl_fn, q_te, n_queries=nq)
    rows.append(Row(name, "SLIDE", float(precision_at_k(ids, lab_te, 1)),
                    float(precision_at_k(ids, lab_te, 5)),
                    float(label_recall(cand0, lab_te)), sample0, us,
                    2 * (d * lss_cfg.k_bits * lss_cfg.n_tables
                         + sample0 * d) / 1e6))

    # PQ
    pq = B.pq_build(jax.random.PRNGKey(3), w, b,
                    n_subspaces=8, n_iters=6 if FAST else 12)
    pq_fn = jax.jit(lambda q: B.pq_topk(q, pq, 5)[0])
    ids = pq_fn(q_te)
    us = _timeit(pq_fn, q_te, n_queries=nq)
    hit = (ids[:, :, None] == lab_te[:, None, :]) & (lab_te >= 0)[:, None, :]
    rec = float(jnp.sum(hit.any(1)) / jnp.maximum((lab_te >= 0).sum(), 1))
    rows.append(Row(name, "PQ", float(precision_at_k(ids, lab_te, 1)),
                    float(precision_at_k(ids, lab_te, 5)), rec, m, us,
                    (2 * d * 256 + m * 8) / 1e6))

    # ip-NSW
    nsw = B.ipnsw_build(jax.random.PRNGKey(4), w, b)
    nsw_fn = jax.jit(lambda q: B.ipnsw_topk(q, nsw, 5)[0])
    ids = nsw_fn(q_te)
    visited = B.ipnsw_topk(q_te[:1], nsw, 5)[1]
    us = _timeit(nsw_fn, q_te, n_queries=nq)
    hit = (ids[:, :, None] == lab_te[:, None, :]) & (lab_te >= 0)[:, None, :]
    rec = float(jnp.sum(hit.any(1)) / jnp.maximum((lab_te >= 0).sum(), 1))
    rows.append(Row(name, "ip-NSW", float(precision_at_k(ids, lab_te, 1)),
                    float(precision_at_k(ids, lab_te, 5)), rec,
                    float(visited), us, 2 * visited * d / 1e6))
    return rows


def table2_kl_sweep(name="delicious-200k") -> list[dict]:
    """Paper Table 2: K x L on the Delicious stand-in."""
    setting = SETTINGS[name]
    params, cfg, q_tr, lab_tr, q_te, lab_te = _train_xc(
        setting, n_train=2048 if FAST else 4096,
        steps=150 if FAST else 500)
    w = params["w_out"].astype(jnp.float32)
    b = params["b_out"].astype(jnp.float32)
    out = []
    ks = (4, 6) if FAST else (4, 6, 8)
    ls = (1, 10) if FAST else (1, 10, 50)
    for k_bits in ks:
        for n_tables in ls:
            lss_cfg = setting.bench_lss._replace(
                k_bits=k_bits, n_tables=n_tables,
                iul_epochs=4 if FAST else 8)
            index, _ = fit_lss(jax.random.PRNGKey(1), q_tr, lab_tr, w, b,
                               lss_cfg)
            _, ids = lss_predict(q_te, index, None, top_k=5)
            cand, _ = retrieve(simhash.augment_queries(q_te), index)
            out.append({
                "K": k_bits, "L": n_tables,
                "P@1": round(float(precision_at_k(ids, lab_te, 1)), 4),
                "P@5": round(float(precision_at_k(ids, lab_te, 5)), 4),
                "sample": round(float(avg_sample_size(cand)), 1),
            })
    return out


def fig2_collision_curves(name="delicious-200k") -> dict:
    setting = SETTINGS[name]
    params, cfg, q_tr, lab_tr, q_te, lab_te = _train_xc(
        setting, n_train=2048, steps=120 if FAST else 400)
    w = params["w_out"].astype(jnp.float32)
    _, hist = fit_lss(jax.random.PRNGKey(1), q_tr, lab_tr, w,
                      params["b_out"].astype(jnp.float32),
                      setting.bench_lss)
    return hist
