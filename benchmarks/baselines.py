"""MIPS baselines from the paper's Table 1, reimplemented in JAX.

* FULL    — exact dense head (the paper's "ideally parallelized" floor).
* SLIDE   — random-SimHash LSS (hash tables, no learning) [MLSys'20].
* PQ      — product quantization with asymmetric distance computation
            (k-means codebooks per subspace; ADC lookup) [Jegou TPAMI'11].
* ip-NSW  — greedy beam search on an exact top-IP neighbor graph
            (fixed-degree, fixed-iteration, batched — the static-shape
            JAX rendering of NSW) [Morozov & Babenko, NeurIPS'18].

Each returns (top-k ids, candidates-scored-per-query) so the benchmark
can report accuracy AND the compute proxy.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import simhash
from repro.core.lss import LSSConfig, build_index, lss_predict, retrieve, \
    dedup_mask, avg_sample_size


# ------------------------------------------------------------------ FULL --

def full_topk(q: jax.Array, w: jax.Array, b: jax.Array, k: int):
    logits = q @ w.T + b
    return jax.lax.top_k(logits, k)[1], w.shape[0]


# ----------------------------------------------------------------- SLIDE --

def slide_build(key, w, b, cfg: LSSConfig):
    w_aug = simhash.augment_neurons(w, b)
    theta = simhash.init_hyperplanes(key, w_aug.shape[1], cfg.k_bits,
                                     cfg.n_tables)
    return build_index(w_aug, theta, cfg)


def slide_topk(q, index, k: int):
    _, ids = lss_predict(q, index, None, top_k=k)
    cand, _ = retrieve(simhash.augment_queries(q), index)
    return ids, float(avg_sample_size(cand))


# -------------------------------------------------------------------- PQ --

class PQIndex(NamedTuple):
    codebooks: jax.Array   # [M, 256, d_sub]
    codes: jax.Array       # [m, M] uint8 (as int32)
    bias: jax.Array        # [m]


def pq_build(key, w: jax.Array, b: jax.Array, n_subspaces: int = 8,
             n_iters: int = 12, n_codes: int = 256) -> PQIndex:
    m, d = w.shape
    pad = (-d) % n_subspaces
    wp = jnp.pad(w, ((0, 0), (0, pad)))
    d_sub = wp.shape[1] // n_subspaces
    sub = wp.reshape(m, n_subspaces, d_sub).swapaxes(0, 1)  # [M, m, ds]

    def kmeans(key, x):
        n = x.shape[0]
        cent = x[jax.random.choice(key, n, (n_codes,), replace=n < n_codes)]

        def step(cent, _):
            d2 = ((x[:, None] - cent[None]) ** 2).sum(-1)
            assign = jnp.argmin(d2, 1)
            sums = jnp.zeros_like(cent).at[assign].add(x)
            cnt = jnp.zeros((n_codes,)).at[assign].add(1.0)
            new = jnp.where(cnt[:, None] > 0, sums / jnp.maximum(cnt, 1)[:, None],
                            cent)
            return new, None

        cent, _ = jax.lax.scan(step, cent, None, length=n_iters)
        d2 = ((x[:, None] - cent[None]) ** 2).sum(-1)
        return cent, jnp.argmin(d2, 1).astype(jnp.int32)

    keys = jax.random.split(key, n_subspaces)
    cents, codes = jax.vmap(kmeans)(keys, sub)
    return PQIndex(cents, codes.swapaxes(0, 1), b)


def pq_topk(q: jax.Array, index: PQIndex, k: int):
    """ADC: per-subspace inner-product tables then code-gather-sum."""
    bq, d = q.shape
    m_sub, n_codes, d_sub = index.codebooks.shape
    pad = m_sub * d_sub - d
    qp = jnp.pad(q, ((0, 0), (0, pad))).reshape(bq, m_sub, d_sub)
    # tables [B, M, 256]
    tables = jnp.einsum("bmd,mcd->bmc", qp, index.codebooks)
    scores = tables[:, jnp.arange(m_sub)[None, :], index.codes].sum(-1) \
        + index.bias                                      # [B, m]
    return jax.lax.top_k(scores, k)[1], index.codes.shape[0]


# ---------------------------------------------------------------- ip-NSW --

class NSWIndex(NamedTuple):
    graph: jax.Array       # [m, R] neighbor ids by best inner product
    w: jax.Array
    b: jax.Array
    entry: jax.Array       # [n_entries] random entry points


def ipnsw_build(key, w: jax.Array, b: jax.Array, degree: int = 16,
                n_entries: int = 8) -> NSWIndex:
    m = w.shape[0]
    ip = w @ w.T + b[None, :]
    ip = ip.at[jnp.arange(m), jnp.arange(m)].set(-jnp.inf)
    graph = jax.lax.top_k(ip, degree)[1].astype(jnp.int32)
    entry = jax.random.choice(key, m, (n_entries,), replace=False)
    return NSWIndex(graph, w, b, entry.astype(jnp.int32))


def ipnsw_topk(q: jax.Array, index: NSWIndex, k: int, beam: int = 32,
               n_steps: int = 12):
    """Batched greedy beam search; every query visits
    n_entries + n_steps*beam*degree candidates (static)."""
    m, r = index.graph.shape

    def one(qi):
        def score(ids):
            return index.w[ids] @ qi + index.b[ids]

        cand = index.entry
        cand_s = score(cand)
        pad = beam - cand.shape[0]
        beam_ids = jnp.pad(cand, (0, pad), constant_values=0)
        beam_s = jnp.pad(cand_s, (0, pad), constant_values=-jnp.inf)

        def step(carry, _):
            ids, s = carry
            nbrs = index.graph[ids].reshape(-1)            # [beam*R]
            ns = score(nbrs)
            all_ids = jnp.concatenate([ids, nbrs])
            all_s = jnp.concatenate([s, ns])
            # dedup-by-penalty then keep top beam
            order = jnp.argsort(-all_s)
            all_ids, all_s = all_ids[order], all_s[order]
            dup = jnp.concatenate([jnp.zeros((1,), bool),
                                   all_ids[1:] == all_ids[:-1]])
            # near-dup ids with equal score collapse after sort by id-break
            all_s = jnp.where(dup, -jnp.inf, all_s)
            top_s, pos = jax.lax.top_k(all_s, beam)
            return (all_ids[pos], top_s), (all_ids[pos], top_s)

        (ids, s), (hist_ids, hist_s) = jax.lax.scan(
            step, (beam_ids, beam_s), None, length=n_steps)
        flat_ids = hist_ids.reshape(-1)
        flat_s = hist_s.reshape(-1)
        order = jnp.argsort(-flat_s)
        flat_ids, flat_s = flat_ids[order], flat_s[order]
        dup = jnp.concatenate([jnp.zeros((1,), bool),
                               flat_ids[1:] == flat_ids[:-1]])
        flat_s = jnp.where(dup, -jnp.inf, flat_s)
        _, pos = jax.lax.top_k(flat_s, k)
        return flat_ids[pos]

    ids = jax.vmap(one)(q)
    visited = index.entry.shape[0] + n_steps * beam * r
    return ids, visited
