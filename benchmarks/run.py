"""Benchmark entry point: the serving benchmark (BENCH_serve.json
artifact) + one section per paper table/figure + the kernel microbench +
the roofline table from the dry-run artifacts.

Every section now writes a ``BENCH_<name>.json`` artifact next to the
existing ``BENCH_serve.json`` (load, decode, table1, table2, fig2,
kernels, roofline), so CI can upload machine-readable results even when
a section partially fails — failures are recorded in the artifact
instead of lost in stdout.

Prints ``name,us_per_call,derived`` CSV rows (one per method x dataset).
Env: BENCH_FAST=0 for the full pass (fast is the default); BENCH_SKIP_TABLES=1
to only run serving + kernels + roofline summary; BENCH_OUT_DIR overrides
where the JSON artifacts land (default: cwd).
"""

from __future__ import annotations

import glob
import json
import os
import time

import jax
import jax.numpy as jnp


def _write_artifact(name: str, payload: dict) -> str:
    out_dir = os.environ.get("BENCH_OUT_DIR", ".")
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    payload = {"bench": name, "backend": jax.default_backend(), **payload}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {path}")
    return path


def bench_kernels() -> tuple[dict, list[str]]:
    """Pallas-kernel wrappers vs refs (CPU: interpret-mode correctness
    pass + ref-path timing; TPU timing is the deploy target), plus the
    lss_topk dedup-strategy C-sweep with its measured quadratic/bitonic
    crossover (the data behind the registry's auto-select threshold)."""
    from repro.kernels import bucket_logits, simhash_codes
    recs, rows = [], []
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (256, 128))
    theta = jax.random.normal(jax.random.PRNGKey(1), (128, 12))
    f = jax.jit(lambda q: simhash_codes(q, theta, 12, 1, impl="ref"))
    f(q)
    t0 = time.perf_counter()
    for _ in range(20):
        jax.block_until_ready(f(q))
    us = (time.perf_counter() - t0) / 20 / 256 * 1e6
    recs.append({"kernel": "simhash_codes", "impl": "ref",
                 "us_per_query": round(us, 3), "shape": "B256_d128_K12"})
    rows.append(f"kernel_simhash_codes_ref,{us:.3f},B256_d128_K12")

    w = jax.random.normal(jax.random.PRNGKey(2), (1024, 128, 128))
    ids = jax.random.randint(jax.random.PRNGKey(3), (256, 1), 0, 1024)
    g = jax.jit(lambda q, ids: bucket_logits(q, w, ids, impl="ref"))
    g(q, ids)
    t0 = time.perf_counter()
    for _ in range(20):
        jax.block_until_ready(g(q, ids))
    us = (time.perf_counter() - t0) / 20 / 256 * 1e6
    recs.append({"kernel": "bucket_logits", "impl": "ref",
                 "us_per_query": round(us, 3), "shape": "S1024_P128_d128"})
    rows.append(f"kernel_bucket_logits_ref,{us:.3f},S1024_P128_d128")

    # lss_topk dedup strategy C-sweep (quadratic vs bitonic, ref path).
    # BENCH_SKIP_DEDUP_SWEEP=1 skips it (CI's dedicated guard step runs
    # the sweep itself and MERGES into the same artifact, so the main
    # bench job doesn't pay for — or clobber — a second sweep).
    if os.environ.get("BENCH_SKIP_DEDUP_SWEEP"):
        return {"rows": recs, "crossover_c": None}, rows
    from benchmarks.kernels_bench import bench_dedup_sweep
    fast = os.environ.get("BENCH_FAST", "1") != "0"
    sweep = bench_dedup_sweep(cs=(512, 2048, 8192) if fast
                              else (512, 1024, 2048, 4096, 8192, 16384))
    recs.extend(sweep["rows"])
    for r in sweep["rows"]:
        rows.append(f"kernel_lss_topk_ref_{r['dedup']}_c{r['c']},"
                    f"{r['us_per_query']:.3f},{r['shape']}")
    rows.append(f"kernel_lss_topk_dedup_crossover,0,"
                f"crossover_c={sweep['crossover_c']}")

    # slab_dtype storage sweep: us/query, per-query slab DMA bytes, and
    # top-k label recall delta vs fp32, one synthetic-WOL index per format
    from benchmarks.kernels_bench import bench_slab_dtype_sweep
    slab = bench_slab_dtype_sweep()
    recs.extend(slab["rows"])
    for r in slab["rows"]:
        rows.append(f"kernel_lss_topk_ref_slab_{r['slab_dtype']},"
                    f"{r['us_per_query']:.3f},{r['shape']},"
                    f"dma={r['dma_bytes_per_query']},"
                    f"recall_delta={r['recall_delta_vs_fp32']:.4f}")
    return {"rows": recs, "crossover_c": sweep["crossover_c"]}, rows


def roofline_summary() -> tuple[list[dict], list[str]]:
    recs, rows = [], []
    for tag, pat in (("dryrun", "experiments/dryrun/*.json"),
                     ("dryrun_opt", "experiments/dryrun_opt/*.json")):
        for path in sorted(glob.glob(pat)):
            rec = json.load(open(path))
            r = rec["roofline"]
            recs.append({"tag": tag, "arch": rec["arch"],
                         "shape": rec["shape"], "mesh": rec["mesh"],
                         "roofline": r, "memory": rec["memory"]})
            rows.append(
                f"{tag}_{rec['arch']}_{rec['shape']}_{rec['mesh']},"
                f"{max(r['t_compute'], r['t_memory'], r['t_collective']) * 1e6:.1f},"
                f"bound={r['bottleneck']};useful={r['useful_ratio']:.2f};"
                f"mem_gb={rec['memory']['total_per_device_gb']}")
    return recs, rows


def bench_serving_rows() -> list[str]:
    """Unified-engine serving bench; writes BENCH_serve.json first so the
    artifact lands even if a later section is interrupted."""
    from benchmarks.serve_bench import bench_serving, write_artifact
    fast = os.environ.get("BENCH_FAST", "1") != "0"
    rec = bench_serving(fast=fast)
    write_artifact(rec)   # honors BENCH_SERVE_OUT / BENCH_OUT_DIR itself
    return [
        f"serve_m{r['m']}_{r['head']}_{r['impl']},{r['us_per_query']:.1f},"
        f"rps={r['req_per_s']};sample={r['avg_sample_size']:.0f};"
        f"speedup={r['speedup_vs_full']}"
        for r in rec["rows"]
    ]


def bench_load_rows() -> list[str]:
    """Short open-loop load run through the AsyncRuntime (one paced QPS
    point + one burst/saturation point); writes BENCH_load.json."""
    from benchmarks.load_bench import bench_load, write_artifact
    fast = os.environ.get("BENCH_FAST", "1") != "0"
    rec = bench_load(
        m=5_000 if fast else 50_000,
        n_requests=128 if fast else 1024,
        qps_list=[200.0, 0.0],
        heads=["lss"], impls=["ref"],
        buckets=(1, 4, 16), policy="shed", max_queue=4096,
        deadline_ms=None)
    write_artifact(rec)   # honors BENCH_LOAD_OUT / BENCH_OUT_DIR itself
    return [
        f"load_{r['head']}_{r['impl']}_"
        f"{'burst' if r['qps'] <= 0 else 'qps%g' % r['qps']},"
        f"{r['p50_ms']:.2f},"
        f"rps={r['achieved_rps']};p99={r['p99_ms']};occ={r['occupancy']};"
        f"shed={r['shed_queue']}+{r['shed_deadline']};"
        f"speedup_vs_sync={r['speedup_vs_sync']}"
        for r in rec["rows"]
    ]


def bench_obs_rows() -> list[str]:
    """Observability tax + online-vs-offline audit recall agreement;
    writes BENCH_obs.json (the obs CI job re-runs this with the
    overhead guard armed)."""
    from benchmarks.load_bench import bench_obs, write_artifact
    fast = os.environ.get("BENCH_FAST", "1") != "0"
    rec = bench_obs(
        m=5_000 if fast else 50_000,
        n_requests=128 if fast else 1024,
        buckets=(1, 4, 16), audit_rate=0.05,
        reps=2 if fast else 3)
    write_artifact(rec)   # honors BENCH_OBS_OUT / BENCH_OUT_DIR itself
    oh = next(r for r in rec["rows"] if r["kind"] == "overhead")
    au = next(r for r in rec["rows"] if r["kind"] == "audit_recall")
    return [
        f"obs_overhead,{oh['overhead_pct']:.2f},"
        f"rps_on={oh['rps_on']};rps_off={oh['rps_off']};"
        f"p99_on={oh['p99_on_ms']};p99_off={oh['p99_off_ms']}",
        f"obs_audit_recall,{au['recall_online']:.6f},"
        f"offline={au['recall_offline']:.6f};delta={au['recall_delta']:.2e};"
        f"rows={au['n_rows']}",
    ]


def bench_decode_rows() -> list[str]:
    """Short streaming-decode load run (burst session arrivals, stream
    sweep, blocking per-prompt generate baseline); writes
    BENCH_decode.json."""
    from benchmarks.decode_bench import bench_decode, write_artifact
    fast = os.environ.get("BENCH_FAST", "1") != "0"
    rec = bench_decode(
        vocab=2048 if fast else 8192,
        n_sessions=8 if fast else 32,
        streams_list=[1, 2, 4] if fast else [1, 2, 4, 8],
        qps_list=[0.0], heads=["lss"],
        max_new_tokens=8 if fast else 32,
        impl="ref", max_queue=4096, deadline_ms=None)
    write_artifact(rec)   # honors BENCH_DECODE_OUT / BENCH_OUT_DIR itself
    return [
        f"decode_{r['head']}_s{r['streams']}_"
        f"{'burst' if r['qps'] <= 0 else 'qps%g' % r['qps']},"
        f"{r['ttft_p50_ms']:.2f},"
        f"tok_s={r['tokens_per_s']};itl_p50={r['itl_p50_ms']};"
        f"occ={r['occupancy']};shed={r['shed_queue']}+{r['shed_deadline']};"
        f"speedup_vs_blocking={r['speedup_vs_blocking']}"
        for r in rec["rows"]
    ]


def bench_tables(rows: list[str]) -> None:
    from benchmarks.paper_tables import (fig2_collision_curves,
                                         run_setting, table2_kl_sweep)
    # Table 1 (4 datasets x 5 methods)
    t1_rows, t1_failures = [], {}
    for name in ("wiki10-31k", "delicious-200k", "text8",
                 "wiki-text-2"):
        try:
            for r in run_setting(name):
                t1_rows.append(r._asdict())
                rows.append(
                    f"table1_{r.dataset}_{r.method},"
                    f"{r.us_per_query:.1f},"
                    f"P@1={r.p1:.4f};P@5={r.p5:.4f};"
                    f"recall={r.recall:.3f};sample={r.sample:.0f};"
                    f"mflop={r.mflop_per_query:.2f}")
        except Exception as e:   # keep the harness running
            t1_failures[name] = repr(e)
            rows.append(f"table1_{name}_FAILED,0,{e!r}")
    _write_artifact("table1", {"rows": t1_rows, "failures": t1_failures})
    # Table 2 (K x L sweep)
    try:
        t2 = table2_kl_sweep()
        _write_artifact("table2", {"rows": t2})
        for r in t2:
            rows.append(f"table2_K{r['K']}_L{r['L']},0,"
                        f"P@1={r['P@1']};P@5={r['P@5']};"
                        f"sample={r['sample']}")
    except Exception as e:
        _write_artifact("table2", {"rows": [], "failures": {"sweep": repr(e)}})
        rows.append(f"table2_FAILED,0,{e!r}")
    # Figure 2 (collision curves)
    try:
        hist = fig2_collision_curves()
        _write_artifact("fig2", {"curves": {
            k: list(map(float, v)) for k, v in hist.items()}})
        rows.append(
            "fig2_collision,0,"
            f"pos={[round(x, 3) for x in hist['p_collide_pos']]};"
            f"neg={[round(x, 3) for x in hist['p_collide_neg']]};"
            f"recall={[round(x, 3) for x in hist['recall']]}")
    except Exception as e:
        _write_artifact("fig2", {"curves": {}, "failures": {"fig2": repr(e)}})
        rows.append(f"fig2_FAILED,0,{e!r}")


def main() -> None:
    rows = []
    rows += bench_serving_rows()
    rows += bench_load_rows()
    rows += bench_obs_rows()
    rows += bench_decode_rows()
    kern_rec, kern_rows = bench_kernels()
    _write_artifact("kernels", kern_rec)
    rows += kern_rows
    if not os.environ.get("BENCH_SKIP_TABLES"):
        bench_tables(rows)
    roof_recs, roof_rows = roofline_summary()
    _write_artifact("roofline", {"rows": roof_recs})
    rows += roof_rows
    print("name,us_per_call,derived")
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
