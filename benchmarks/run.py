"""Benchmark entry point: the serving benchmark (BENCH_serve.json
artifact) + one section per paper table/figure + the kernel microbench +
the roofline table from the dry-run artifacts.

Prints ``name,us_per_call,derived`` CSV rows (one per method x dataset).
Env: BENCH_FAST=0 for the full pass (fast is the default); BENCH_SKIP_TABLES=1
to only run serving + kernels + roofline summary.
"""

from __future__ import annotations

import glob
import json
import os
import time

import jax
import jax.numpy as jnp


def bench_kernels() -> list[str]:
    """Pallas-kernel wrappers vs refs (CPU: interpret-mode correctness
    pass + ref-path timing; TPU timing is the deploy target)."""
    from repro.kernels import bucket_logits, simhash_codes
    rows = []
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (256, 128))
    theta = jax.random.normal(jax.random.PRNGKey(1), (128, 12))
    f = jax.jit(lambda q: simhash_codes(q, theta, 12, 1, impl="ref"))
    f(q)
    t0 = time.perf_counter()
    for _ in range(20):
        jax.block_until_ready(f(q))
    us = (time.perf_counter() - t0) / 20 / 256 * 1e6
    rows.append(f"kernel_simhash_codes_ref,{us:.3f},B256_d128_K12")

    w = jax.random.normal(jax.random.PRNGKey(2), (1024, 128, 128))
    ids = jax.random.randint(jax.random.PRNGKey(3), (256, 1), 0, 1024)
    g = jax.jit(lambda q, ids: bucket_logits(q, w, ids, impl="ref"))
    g(q, ids)
    t0 = time.perf_counter()
    for _ in range(20):
        jax.block_until_ready(g(q, ids))
    us = (time.perf_counter() - t0) / 20 / 256 * 1e6
    rows.append(f"kernel_bucket_logits_ref,{us:.3f},S1024_P128_d128")
    return rows


def roofline_summary() -> list[str]:
    rows = []
    for tag, pat in (("dryrun", "experiments/dryrun/*.json"),
                     ("dryrun_opt", "experiments/dryrun_opt/*.json")):
        for path in sorted(glob.glob(pat)):
            rec = json.load(open(path))
            r = rec["roofline"]
            rows.append(
                f"{tag}_{rec['arch']}_{rec['shape']}_{rec['mesh']},"
                f"{max(r['t_compute'], r['t_memory'], r['t_collective']) * 1e6:.1f},"
                f"bound={r['bottleneck']};useful={r['useful_ratio']:.2f};"
                f"mem_gb={rec['memory']['total_per_device_gb']}")
    return rows


def bench_serving_rows() -> list[str]:
    """Unified-engine serving bench; writes BENCH_serve.json first so the
    artifact lands even if a later section is interrupted."""
    from benchmarks.serve_bench import bench_serving, write_artifact
    fast = os.environ.get("BENCH_FAST", "1") != "0"
    rec = bench_serving(fast=fast)
    write_artifact(rec)
    return [
        f"serve_m{r['m']}_{r['head']},{r['us_per_query']:.1f},"
        f"rps={r['req_per_s']};sample={r['avg_sample_size']:.0f};"
        f"speedup={r['speedup_vs_full']}"
        for r in rec["rows"]
    ]


def main() -> None:
    rows = []
    rows += bench_serving_rows()
    rows += bench_kernels()
    if not os.environ.get("BENCH_SKIP_TABLES"):
        from benchmarks.paper_tables import (fig2_collision_curves,
                                             run_setting, table2_kl_sweep)
        # Table 1 (4 datasets x 5 methods)
        for name in ("wiki10-31k", "delicious-200k", "text8",
                     "wiki-text-2"):
            try:
                for r in run_setting(name):
                    rows.append(
                        f"table1_{r.dataset}_{r.method},"
                        f"{r.us_per_query:.1f},"
                        f"P@1={r.p1:.4f};P@5={r.p5:.4f};"
                        f"recall={r.recall:.3f};sample={r.sample:.0f};"
                        f"mflop={r.mflop_per_query:.2f}")
            except Exception as e:   # keep the harness running
                rows.append(f"table1_{name}_FAILED,0,{e!r}")
        # Table 2 (K x L sweep)
        try:
            for r in table2_kl_sweep():
                rows.append(f"table2_K{r['K']}_L{r['L']},0,"
                            f"P@1={r['P@1']};P@5={r['P@5']};"
                            f"sample={r['sample']}")
        except Exception as e:
            rows.append(f"table2_FAILED,0,{e!r}")
        # Figure 2 (collision curves)
        try:
            hist = fig2_collision_curves()
            rows.append(
                "fig2_collision,0,"
                f"pos={[round(x, 3) for x in hist['p_collide_pos']]};"
                f"neg={[round(x, 3) for x in hist['p_collide_neg']]};"
                f"recall={[round(x, 3) for x in hist['recall']]}")
        except Exception as e:
            rows.append(f"fig2_FAILED,0,{e!r}")
    rows += roofline_summary()
    print("name,us_per_call,derived")
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
