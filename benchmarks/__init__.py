"""Benchmark package.  Falls back to the in-repo ``src/`` layout when the
package is not pip-installed, so ``python -m benchmarks.run`` works from a
bare checkout."""

import os
import sys

try:
    import repro                                         # noqa: F401
except ImportError:                                      # bare checkout
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir,
                                    "src"))
