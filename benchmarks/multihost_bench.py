"""Multi-host vocab-sharded serving scaling bench -> BENCH_multihost.json.

Spawns real ``jax.distributed`` process fleets on localhost (gloo CPU
collectives; ``--local-devices`` fake XLA devices per process) and
measures the two scaling stories ROADMAP direction 2 names:

  * **qps_scaling** rows — aggregate query throughput through the
    hierarchical multihost predict, at (a) fixed per-host m with m and
    QPS both growing with hosts, and (b) equal TOTAL m, where the
    1->2-process ratio is the actual speedup of splitting one
    vocabulary across two hosts (``qps_ratio_1_to_2`` in the summary
    row; ~2x with real cores, ~1x when processes timeshare one core —
    ``n_cpus`` is recorded so the CI gate only binds where parallel
    hardware exists).
  * **capacity** rows — measured index bytes/vocab row per host, and
    the max total m a fixed PER-HOST memory budget admits as hosts grow
    (no process ever materializes the full [m, d] weight: every worker
    builds only its ``shard_range`` via
    ``shard_index(..., shard_range=...)``).

All processes run the timed loop in SPMD lockstep (the collectives
inside the jitted predict are the synchronization); process 0 reports.

Usage::

    python -m benchmarks.multihost_bench [--per-host-m 60000]
        [--procs 1,2] [--local-devices 2] [--min-ratio 1.7]

``--min-ratio`` makes the equal-total-m 1->2 ratio a hard gate (CI
passes 1.7; it is skipped with a note when the machine has < 2 CPUs,
where the ratio is physically unattainable).
"""

from __future__ import annotations

import argparse
import json
import os
import socket
import subprocess
import sys

RESULT_MARK = "MULTIHOST_RESULT "


# ---------------------------------------------------------------- worker --
def _rows_for_range(seed: int, r0: int, r1: int, d: int):
    """Rows [r0, r1) of a global weight matrix defined block-by-block
    (4096-row blocks, one fold_in per block) — the SAME matrix for every
    fleet size, without any process generating rows it does not own."""
    import jax
    import jax.numpy as jnp
    block = 4096
    key = jax.random.PRNGKey(seed)
    parts = []
    b0, b1 = r0 // block, -(-r1 // block)
    for b in range(b0, b1):
        rows = jax.random.normal(jax.random.fold_in(key, b), (block, d),
                                 jnp.float32)
        lo = max(r0 - b * block, 0)
        hi = min(r1 - b * block, block)
        parts.append(rows[lo:hi])
    return jnp.concatenate(parts, axis=0)


def worker(args) -> None:
    from repro.xla_env import force_host_device_count
    force_host_device_count(args.local_devices)

    import time

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import simhash
    from repro.core.lss import LSSConfig
    from repro.core.sharded import make_multihost_predict
    from repro.serve.heads import shard_index
    from repro.serve.multihost import (MultihostContext, assemble_global_stack,
                                       init_multihost)
    from repro.utils import compat

    ctx = init_multihost(args.coordinator, args.num_processes,
                         args.process_id)
    if ctx is None:                       # single-process fleet
        ctx = MultihostContext(compat.make_global_mesh())

    m, d, k = args.m_total, args.d, args.top_k
    cfg = LSSConfig(k_bits=args.k_bits, n_tables=2, use_bucket_major=True,
                    slab_dtype=args.slab_dtype)
    theta = simhash.init_hyperplanes(jax.random.PRNGKey(1), d + 1,
                                     cfg.k_bits, cfg.n_tables)
    r0, r1 = ctx.row_range(m)
    w_local = _rows_for_range(0, r0, r1, d)
    w_aug_local = simhash.augment_neurons(w_local, None)
    local_stack, local_w, m_local = shard_index(
        w_aug_local, theta, cfg, ctx.n_shards,
        shard_range=ctx.shard_range(), m_total=m)
    index_bytes = sum(np.asarray(x).nbytes
                      for x in jax.tree.leaves(local_stack))
    stack = assemble_global_stack(ctx, local_stack, ctx.n_shards)
    w_stack = (None if local_w is None
               else assemble_global_stack(ctx, local_w, ctx.n_shards))

    fwd = make_multihost_predict(ctx.mesh, ctx.host_axis, ctx.model_axis,
                                 cfg, m_local, k)
    q = jax.random.normal(jax.random.PRNGKey(2), (args.batch, d),
                          jnp.float32)
    q = compat.broadcast_one_to_all(np.asarray(q))

    # the stacks ride as jit ARGUMENTS: multi-process jit forbids
    # closing over arrays that span non-addressable devices
    jfwd = jax.jit(fwd)
    fn = lambda qq: jfwd(qq, stack, w_stack)            # noqa: E731
    jax.block_until_ready(fn(q))          # compile + warm (lockstep)
    jax.block_until_ready(fn(q))
    t0 = time.perf_counter()
    for _ in range(args.iters):
        out = fn(q)
    jax.block_until_ready(out)
    wall = time.perf_counter() - t0

    if ctx.is_leader:
        n_queries = args.batch * args.iters
        print(RESULT_MARK + json.dumps({
            "backend": jax.default_backend(),
            "processes": ctx.n_processes,
            "local_devices": ctx.shards_per_host,
            "n_shards": ctx.n_shards,
            "total_m": m,
            "per_host_m": r1 - r0,
            "batch": args.batch,
            "iters": args.iters,
            "qps": n_queries / wall,
            "us_per_query": wall / n_queries * 1e6,
            "index_bytes_per_host": int(index_bytes),
            "bytes_per_row": index_bytes / max(r1 - r0, 1),
        }), flush=True)


# ---------------------------------------------------------------- parent --
def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def run_fleet(n_procs: int, m_total: int, args) -> dict:
    """One fleet at one vocab size; returns the leader's RESULT dict."""
    coord = f"127.0.0.1:{_free_port()}"
    cmd_base = [sys.executable, "-m", "benchmarks.multihost_bench",
                "--worker", "--coordinator", coord,
                "--num-processes", str(n_procs),
                "--m-total", str(m_total),
                "--per-host-m", str(args.per_host_m),
                "--local-devices", str(args.local_devices),
                "--d", str(args.d), "--batch", str(args.batch),
                "--iters", str(args.iters), "--top-k", str(args.top_k),
                "--k-bits", str(args.k_bits),
                "--slab-dtype", args.slab_dtype]
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")
    procs = [subprocess.Popen(cmd_base + ["--process-id", str(i)],
                              stdout=subprocess.PIPE, text=True, env=env)
             for i in range(n_procs)]
    outs = [p.communicate(timeout=900)[0] for p in procs]
    for i, p in enumerate(procs):
        if p.returncode != 0:
            raise RuntimeError(f"worker {i}/{n_procs} failed "
                               f"(rc={p.returncode}):\n{outs[i]}")
    for out in outs:
        for line in out.splitlines():
            if line.startswith(RESULT_MARK):
                return json.loads(line[len(RESULT_MARK):])
    raise RuntimeError(f"no RESULT line from fleet n={n_procs}:\n"
                       + "\n".join(outs))


def main() -> int:
    fast = bool(int(os.environ.get("BENCH_FAST", "0")))
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--coordinator", default=None)
    ap.add_argument("--num-processes", type=int, default=1)
    ap.add_argument("--process-id", type=int, default=0)
    ap.add_argument("--m-total", type=int, default=0)
    ap.add_argument("--per-host-m", type=int,
                    default=20_000 if fast else 60_000)
    ap.add_argument("--procs", default="1,2",
                    help="fleet sizes to sweep (comma-separated)")
    ap.add_argument("--local-devices", type=int, default=2,
                    help="fake XLA devices per process (= shards/host)")
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--batch", type=int, default=16 if fast else 32)
    ap.add_argument("--iters", type=int, default=20 if fast else 50)
    ap.add_argument("--top-k", type=int, default=8)
    ap.add_argument("--k-bits", type=int, default=6)
    ap.add_argument("--slab-dtype", default="int8",
                    choices=("fp32", "bf16", "int8"))
    ap.add_argument("--budget-gb", type=float, default=1.0,
                    help="per-host index memory budget for capacity rows")
    ap.add_argument("--min-ratio", type=float, default=None,
                    help="fail unless the equal-total-m 1->2 QPS ratio "
                         "reaches this (skipped, with a note, on < 2 "
                         "CPUs where it is physically unattainable)")
    ap.add_argument("--out", default=os.environ.get(
        "BENCH_MULTIHOST_OUT", "BENCH_multihost.json"))
    args = ap.parse_args()

    if args.worker:
        worker(args)
        return 0

    fleet_sizes = [int(s) for s in args.procs.split(",")]
    m_host = args.per_host_m
    rows, results = [], {}
    # (a) fixed per-host m: m and QPS both scale with hosts
    for n in fleet_sizes:
        r = run_fleet(n, n * m_host, args)
        results[(n, n * m_host)] = r
        rows.append({"kind": "qps_scaling", "fixed": "per_host_m", **r})
        print(f"[multihost] n={n} m={n * m_host}: "
              f"{r['qps']:,.0f} qps ({r['us_per_query']:.0f} us/q)")
    # (b) equal total m: the 1->2 split speedup the summary row records
    m_eq = 2 * m_host
    for n in (1, 2):
        if (n, m_eq) not in results:
            r = run_fleet(n, m_eq, args)
            results[(n, m_eq)] = r
            rows.append({"kind": "qps_scaling", "fixed": "total_m", **r})
            print(f"[multihost] n={n} m={m_eq}: {r['qps']:,.0f} qps")
    # capacity: measured bytes/row -> max m under a per-host budget
    budget = args.budget_gb * 2 ** 30
    for n in fleet_sizes:
        r = results[(n, n * m_host)]
        rows.append({
            "kind": "capacity", "processes": n,
            "budget_gb_per_host": args.budget_gb,
            "index_bytes_per_host": r["index_bytes_per_host"],
            "bytes_per_row": r["bytes_per_row"],
            "max_m_total": int(n * budget // max(r["bytes_per_row"], 1)),
        })
    ratio = results[(2, m_eq)]["qps"] / results[(1, m_eq)]["qps"]
    n_cpus = os.cpu_count() or 1
    rows.append({"kind": "summary", "qps_ratio_1_to_2": ratio,
                 "total_m": m_eq, "per_host_m": m_host,
                 "n_cpus": n_cpus,
                 "min_ratio": args.min_ratio})
    payload = {"bench": "multihost",
               "backend": results[(1, m_host)].get("backend", "cpu")
               if (1, m_host) in results
               else results[(1, m_eq)]["backend"],
               "rows": rows}
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out}")
    print(f"[multihost] equal-total-m qps ratio 1->2 procs: "
          f"{ratio:.2f}x on {n_cpus} cpus")
    if args.min_ratio is not None:
        if n_cpus < 2:
            print(f"[multihost] NOTE: --min-ratio {args.min_ratio} "
                  f"skipped: only {n_cpus} CPU (two processes timeshare "
                  f"one core; the gate needs parallel hardware)")
        elif ratio < args.min_ratio:
            print(f"[multihost] FAIL: ratio {ratio:.2f} < "
                  f"--min-ratio {args.min_ratio}", file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
