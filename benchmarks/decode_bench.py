"""Open-loop decode load harness for the streaming-decode runtime.

Drives decode SESSIONS (not single rank requests) through an
:class:`AsyncRuntime` + :class:`DecodeScheduler`: Poisson session
arrivals at a configurable rate (``qps <= 0`` = burst — every session
arrives at t=0, the saturation point), sweeping the number of concurrent
streams (pool slots), and writes the ``BENCH_decode.json`` artifact
consumed by CI.

Each (head, streams, qps) point reports:

  * aggregate tokens/sec across all in-flight streams,
  * time-to-first-token p50/p95 (queue wait INCLUDED) and inter-token
    latency p50/p95 — the two numbers a streaming client experiences,
  * decode-slot occupancy and the split shed counts (queue-capacity vs
    deadline),
  * the blocking baseline — sequential per-prompt ``LMDecoder.generate``
    on a single-slot decoder (the semantics of the pre-streaming decode
    loop: one prompt runs to completion before the next starts) — and
    the streaming/blocking tokens-per-sec ratio.

The artifact also records whether burst tokens/sec improved
monotonically from 1 stream to the max — the "continuous batching pays
off" acceptance signal — plus three paged-KV capacity rows (always run,
measured on real sessions): ``sessions_per_gb`` (mixed prompt lengths,
peak-page accounting vs dense per-slot reservation), ``long_context``
(a >= 4k-prompt session in a page-capped arena a dense pool of equal
bytes cannot fit), and ``prefix_cache`` (shared-prompt joins skipping
prefill).  ``tools/check_bench_schema.py`` validates all of them.
The capacity rows count PERSISTENT arena bytes (what bounds sessions
held on device between steps); the paged step's per-step gather can
transiently materialize a dense-slab-sized view — see
docs/ARCHITECTURE.md "Paged KV decode" for the trade-off.

Run:  PYTHONPATH=src python -m benchmarks.decode_bench --streams 1,2,4,8
Env:  BENCH_FAST=1 shrinks sizes (default); BENCH_DECODE_OUT /
      BENCH_OUT_DIR override the artifact path.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lss import LSSConfig
from repro.models.transformer import TransformerConfig
from repro.serve import AsyncRuntime, LMDecoder
from repro.serve.runtime import submit_decode_open_loop

PROMPT_LEN = 8


def tiny_lm_cfg(vocab: int) -> TransformerConfig:
    return TransformerConfig(
        name="decode-bench", n_layers=2, d_model=32, n_heads=2,
        n_kv_heads=2, head_dim=16, d_ff=64, vocab=vocab,
        dtype=jnp.float32, kv_chunk=32)


def build_decoder(params, cfg, streams: int, max_len: int,
                  impl: str | None, *, kv_layout: str | None = None,
                  kv_page_tokens: int | None = None,
                  kv_pages: int | None = None) -> LMDecoder:
    """SimHash-initialised LSS head over the LM's WOL (retrieval speed is
    learning-independent; see benchmarks/serve_bench.py)."""
    dec = LMDecoder(params, cfg,
                    LSSConfig(k_bits=5, n_tables=2, use_bucket_major=True),
                    impl=impl, max_streams=streams, max_len=max_len,
                    kv_layout=kv_layout, kv_page_tokens=kv_page_tokens,
                    kv_pages=kv_pages)
    dec.engine.fit_random(jax.random.PRNGKey(2))
    return dec


def warm(dec: LMDecoder, head: str, steps: int) -> None:
    """Trace the prefill/join path, the bucket-1 first-token step, and
    the fused decode step before the measured segment."""
    prompt = jnp.zeros((1, PROMPT_LEN), jnp.int32)
    dec.generate(prompt, steps=min(2, steps), head=head)


def run_streaming_point(dec: LMDecoder, head: str, prompts, qps: float,
                        max_new_tokens: int, *, max_queue: int,
                        deadline_s: float | None) -> dict:
    sched = dec.scheduler(head=head)
    sched.reset_stats()               # warmup traffic must not count
    rt = AsyncRuntime(dec.engine, head=head, max_queue=max_queue,
                      policy="shed", default_deadline_s=deadline_s,
                      scheduler=sched)
    try:
        streams, arrivals = submit_decode_open_loop(
            rt, prompts, qps, max_new_tokens=max_new_tokens, seed=7)
        rt.drain(timeout=600.0)
        s = rt.stats()
    finally:
        # a drain timeout must not leak a live dispatcher still ticking
        # the shared scheduler into the next point
        rt.close(timeout=30.0)
    n_ok = sum(st.exception(timeout=1.0) is None for st in streams)
    return {
        "n_sessions": len(prompts),
        "qps_offered": (None if qps <= 0
                        else round(len(prompts) / arrivals[-1], 1)),
        "n_ok": n_ok,
        "tokens": s.n_decode_tokens,
        "tokens_per_s": round(s.decode_tokens_per_s, 1),
        "ttft_p50_ms": round(s.ttft_p50_ms, 3),
        "ttft_p95_ms": round(s.ttft_p95_ms, 3),
        "itl_p50_ms": round(s.itl_p50_ms, 3),
        "itl_p95_ms": round(s.itl_p95_ms, 3),
        "occupancy": round(s.decode_slot_occupancy, 3),
        "shed_queue": s.n_shed_queue,
        "shed_deadline": s.n_shed_deadline,
    }


def run_blocking_baseline(dec1: LMDecoder, head: str, prompts,
                          max_new_tokens: int) -> float:
    """Sequential per-prompt generate on a 1-slot decoder: the blocking
    decode loop's aggregate tokens/sec over the same session set."""
    t0 = time.perf_counter()
    n_tok = 0
    for p in prompts:
        out = dec1.generate(jnp.asarray(p)[None, :], steps=max_new_tokens,
                            head=head)
        n_tok += int(out.shape[0] * out.shape[1])
    return n_tok / (time.perf_counter() - t0)


def _drain_sessions(dec: LMDecoder, head: str, prompts,
                    max_new_tokens: int) -> "object":
    """Run a session set to completion on the decoder's scheduler and
    return the scheduler's DecodeStats for the measured window."""
    sched = dec.scheduler(head=head)
    sched.reset_stats()
    streams = [sched.submit(np.asarray(p, np.int32),
                            max_new_tokens=max_new_tokens) for p in prompts]
    sched.run(until=lambda: all(st.done() for st in streams))
    for st in streams:
        st.result()                          # surface any session failure
    return sched.stats()


def _dense_row_bytes(cfg, max_len: int) -> int:
    """Device bytes ONE dense slot reserves (both cache sides)."""
    itemsize = jnp.zeros((), cfg.dtype).itemsize
    return (2 * cfg.n_layers * max_len * cfg.n_kv_heads * cfg.head_dim
            * itemsize)


def bench_capacity(params, cfg, impl: str | None, *,
                   long_prompt: int, page_tokens: int) -> list[dict]:
    """The paged-KV memory story, measured (not modelled) on real
    sessions: sessions-per-GB at mixed prompt lengths, a >= 4k-prompt
    long-context session a dense pool cannot fit at equal memory, and
    the shared-prefix row where repeat joins skip prefill."""
    rows = []
    rng = np.random.default_rng(11)
    vocab = cfg.vocab
    steps = 8

    # -- sessions-per-GB: mixed prompt lengths against one wide pool ----
    # Dense reserves max_len rows per slot no matter the session; paged
    # allocates ceil((len+steps)/page) pages.  Peak pages come from the
    # pool's own high-water mark over a full concurrent run.
    mixed_lens = [8, 16, 32, 64]
    cap_len = 256
    n_mix = len(mixed_lens) * 2
    dec = build_decoder(params, cfg, n_mix, cap_len, impl,
                        kv_layout="paged", kv_page_tokens=page_tokens)
    prompts = [rng.integers(0, vocab, (n,)).astype(np.int32)
               for n in mixed_lens * 2]
    s = _drain_sessions(dec, "full", prompts, steps)
    page_bytes = dec.scheduler(head="full").pool.page_bytes()
    paged_per_session = s.kv_peak_pages * page_bytes / n_mix
    dense_per_session = _dense_row_bytes(cfg, cap_len)
    gb = 1 << 30
    rows.append({
        "kind": "sessions_per_gb", "head": "full",
        "kv_layout": "paged", "page_tokens": page_tokens,
        "max_len": cap_len, "prompt_lens": mixed_lens,
        "n_sessions": n_mix, "max_new_tokens": steps,
        "peak_pages": s.kv_peak_pages,
        "paged_bytes_per_session": int(paged_per_session),
        "dense_bytes_per_session": dense_per_session,
        "sessions_per_gb": round(gb / paged_per_session, 1),
        "sessions_per_gb_dense": round(gb / dense_per_session, 1),
        "sessions_per_gb_ratio": round(
            dense_per_session / paged_per_session, 2),
    })

    # -- long context: one >= 4k-prompt session in a page-capped arena --
    # The arena is sized to the measured working set; a dense pool of the
    # SAME bytes and slot count caps max_len far below the prompt.
    long_steps = 4
    long_max = long_prompt + 2 * long_steps
    n_slots = 4
    pps = -(-long_max // page_tokens)
    # 1 long session + (n_slots - 1) short ones + scratch + slack
    n_pages = 1 + (pps + 1) + (n_slots - 1) * 2 + 2
    dec = build_decoder(params, cfg, n_slots, long_max, impl,
                        kv_layout="paged", kv_page_tokens=page_tokens,
                        kv_pages=n_pages)
    prompts = [rng.integers(0, vocab, (long_prompt,)).astype(np.int32)]
    prompts += [rng.integers(0, vocab, (8,)).astype(np.int32)
                for _ in range(n_slots - 1)]
    s = _drain_sessions(dec, "full", prompts, long_steps)
    arena_bytes = dec.scheduler(head="full").pool.storage_bytes()
    dense_equal_len = arena_bytes // (_dense_row_bytes(cfg, 1) * n_slots)
    rows.append({
        "kind": "long_context", "head": "full",
        "kv_layout": "paged", "page_tokens": page_tokens,
        "prompt_len": long_prompt, "max_new_tokens": long_steps,
        "n_sessions": len(prompts), "n_pages": n_pages,
        "peak_pages": s.kv_peak_pages,
        "arena_bytes": arena_bytes,
        "dense_equal_mem_max_len": int(dense_equal_len),
        "fits_dense_at_equal_memory": bool(dense_equal_len >= long_max),
        "tokens": s.n_tokens,
    })

    # -- prefix cache: N sessions sharing one prompt skip N-1 prefills --
    n_shared = 8
    shared = rng.integers(0, vocab, (3 * page_tokens // 2,)).astype(np.int32)
    dec = build_decoder(params, cfg, 4, 4 * page_tokens, impl,
                        kv_layout="paged", kv_page_tokens=page_tokens)
    s = _drain_sessions(dec, "full", [shared] * n_shared, steps)
    rows.append({
        "kind": "prefix_cache", "head": "full",
        "kv_layout": "paged", "page_tokens": page_tokens,
        "prompt_len": int(shared.shape[0]), "n_sessions": n_shared,
        "max_new_tokens": steps,
        "n_prefill_skipped": s.n_prefill_skipped,
        "prefix_hit_rate": (None if s.prefix_hit_rate != s.prefix_hit_rate
                            else round(s.prefix_hit_rate, 3)),
        "n_prefill_compiles": s.n_prefill_compiles,
        "n_prefill_buckets": s.n_prefill_buckets,
    })
    return rows


def bench_decode(*, vocab: int, n_sessions: int, streams_list: list[int],
                 qps_list: list[float], heads: list[str],
                 max_new_tokens: int, impl: str | None,
                 max_queue: int, deadline_ms: float | None,
                 kv_layout: str | None = None,
                 kv_page_tokens: int | None = None,
                 long_prompt: int = 4096,
                 capacity_page_tokens: int = 16) -> dict:
    deadline_s = None if deadline_ms is None else deadline_ms / 1e3
    cfg = tiny_lm_cfg(vocab)
    params_key = jax.random.PRNGKey(0)
    from repro.models import transformer as T
    params = T.init_params(params_key, cfg)
    rng = np.random.default_rng(1)
    prompts = rng.integers(0, vocab, (n_sessions, PROMPT_LEN)).astype(np.int32)
    max_len = PROMPT_LEN + max_new_tokens

    rows = []
    baselines: dict[str, float] = {}
    dec1 = build_decoder(params, cfg, 1, max_len, impl,
                         kv_layout=kv_layout,
                         kv_page_tokens=kv_page_tokens)
    for head in heads:
        warm(dec1, head, max_new_tokens)
        baselines[head] = run_blocking_baseline(dec1, head, prompts,
                                                max_new_tokens)
    resolved_layout = dec1.scheduler(head=heads[0]).pool.layout
    for streams in streams_list:
        dec = build_decoder(params, cfg, streams, max_len, impl,
                            kv_layout=kv_layout,
                            kv_page_tokens=kv_page_tokens)
        for head in heads:
            warm(dec, head, max_new_tokens)
            for qps in qps_list:
                row = run_streaming_point(
                    dec, head, prompts, qps, max_new_tokens,
                    max_queue=max_queue, deadline_s=deadline_s)
                row.update({
                    "kind": "sweep",
                    "head": head, "impl": impl or "auto",
                    "streams": streams, "qps": qps, "vocab": vocab,
                    "prompt_len": PROMPT_LEN,
                    "max_new_tokens": max_new_tokens,
                    "kv_layout": resolved_layout,
                    "blocking_tok_s": round(baselines[head], 1),
                    "speedup_vs_blocking": round(
                        row["tokens_per_s"] / baselines[head], 2),
                })
                rows.append(row)
    rows.extend(bench_capacity(params, cfg, impl, long_prompt=long_prompt,
                               page_tokens=capacity_page_tokens))
    # acceptance signal: burst tokens/sec improves monotonically in the
    # number of concurrent streams (per head); None = no burst data
    monotonic = {}
    for head in heads:
        burst = sorted((r["streams"], r["tokens_per_s"]) for r in rows
                       if r.get("kind") == "sweep" and r["head"] == head
                       and r["qps"] <= 0)
        monotonic[head] = (None if not burst else
                           bool(all(b[1] >= a[1]
                                    for a, b in zip(burst, burst[1:]))))
    return {
        "bench": "decode",
        "backend": jax.default_backend(),
        "n_devices": len(jax.devices()),
        "streams": streams_list,
        "kv_layout": resolved_layout,
        "monotonic_tokens_per_s": monotonic,
        "rows": rows,
    }


def write_artifact(record: dict, path: str | None = None) -> str:
    """Precedence: explicit path > $BENCH_DECODE_OUT > $BENCH_OUT_DIR/
    BENCH_decode.json > ./BENCH_decode.json."""
    path = (path or os.environ.get("BENCH_DECODE_OUT")
            or os.path.join(os.environ.get("BENCH_OUT_DIR", "."),
                            "BENCH_decode.json"))
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    return path


def _csv_ints(s: str) -> list[int]:
    return [int(x) for x in s.split(",") if x]


def _csv_floats(s: str) -> list[float]:
    return [float(x) for x in s.split(",") if x]


def main(argv: list[str] | None = None) -> dict:
    fast = os.environ.get("BENCH_FAST", "1") != "0"
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--streams", type=_csv_ints,
                    default=[1, 2, 4] if fast else [1, 2, 4, 8, 16],
                    help="comma-separated concurrent-stream (slot) sweep")
    ap.add_argument("--sessions", type=int, default=8 if fast else 32)
    ap.add_argument("--steps", type=int, default=8 if fast else 32,
                    help="max_new_tokens per session")
    ap.add_argument("--qps", type=_csv_floats, default=[0.0],
                    help="offered SESSION arrival rates; 0 = burst")
    ap.add_argument("--heads", default="lss",
                    help="comma-separated head kinds (full,lss)")
    ap.add_argument("--vocab", type=int, default=2048 if fast else 16384)
    ap.add_argument("--impl", default=None,
                    choices=(None, "ref", "pallas", "pallas_interpret"))
    ap.add_argument("--max-queue", type=int, default=4096)
    ap.add_argument("--deadline-ms", type=float, default=None)
    ap.add_argument("--kv-layout", default=None,
                    choices=(None, "dense", "paged"),
                    help="sweep KV layout (None = $REPRO_KV_LAYOUT/dense); "
                         "capacity rows always run paged")
    ap.add_argument("--page-tokens", type=int, default=None,
                    help="sweep page size when --kv-layout paged")
    ap.add_argument("--long-prompt", type=int, default=4096,
                    help="long-context capacity row prompt length")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    rec = bench_decode(
        vocab=args.vocab, n_sessions=args.sessions,
        streams_list=args.streams, qps_list=args.qps,
        heads=[h for h in args.heads.split(",") if h],
        max_new_tokens=args.steps, impl=args.impl,
        max_queue=args.max_queue, deadline_ms=args.deadline_ms,
        kv_layout=args.kv_layout, kv_page_tokens=args.page_tokens,
        long_prompt=args.long_prompt)
    path = write_artifact(rec, args.out)
    print(f"wrote {path}")
    print(f"monotonic tokens/s vs streams: {rec['monotonic_tokens_per_s']}")
    for r in rec["rows"]:
        if r.get("kind") != "sweep":
            continue
        qps = "  burst" if r["qps"] <= 0 else f"{r['qps']:>7.1f}"
        print(f"  {r['head']:<5} streams={r['streams']:>3} qps={qps} "
              f"tok/s={r['tokens_per_s']:>8.1f}  "
              f"ttft p50={r['ttft_p50_ms']:>8.2f} p95={r['ttft_p95_ms']:>8.2f} ms  "
              f"itl p50={r['itl_p50_ms']:>6.2f} ms  occ={r['occupancy']:.2f}  "
              f"shed={r['shed_queue']}+{r['shed_deadline']}  "
              f"blocking={r['blocking_tok_s']:>8.1f} tok/s  "
              f"x{r['speedup_vs_blocking']:.2f}")
    for r in rec["rows"]:
        k = r.get("kind")
        if k == "sessions_per_gb":
            print(f"  sessions/GB: paged={r['sessions_per_gb']} "
                  f"dense={r['sessions_per_gb_dense']} "
                  f"ratio=x{r['sessions_per_gb_ratio']} "
                  f"(peak {r['peak_pages']} pages, "
                  f"prompts {r['prompt_lens']})")
        elif k == "long_context":
            print(f"  long-context: prompt={r['prompt_len']} on "
                  f"{r['n_pages']} pages ({r['arena_bytes']} B); dense at "
                  f"equal memory caps max_len at "
                  f"{r['dense_equal_mem_max_len']} "
                  f"(fits={r['fits_dense_at_equal_memory']})")
        elif k == "prefix_cache":
            print(f"  prefix-cache: {r['n_prefill_skipped']}/"
                  f"{r['n_sessions']} joins skipped prefill, page hit "
                  f"rate {r['prefix_hit_rate']}")
    return rec


if __name__ == "__main__":
    main()
