"""End-to-end driver: train a ~100M-parameter WOL model for a few
hundred steps with the production trainer (checkpoints, auto-resume,
LR schedule, grad clipping), then fit + evaluate the LSS head.

The model is the paper's extreme-classification family at Delicious-200K
width: 782585-dim BoW input -> 128 hidden -> 205443-neuron WOL
= 782585*128 + 205443*129 = ~126.7M parameters (exact paper dims).

Reduce with --fast (CI) which drops to the bench stand-in.

Run:  PYTHONPATH=src python examples/train_wol.py [--fast]
"""

import argparse

import jax
import jax.numpy as jnp

from repro.configs.paper_datasets import DELICIOUS
from repro.core.iul import fit_lss
from repro.core.lss import (avg_sample_size, label_recall, lss_predict,
                            precision_at_k, retrieve)
from repro.core import simhash
from repro.data.pipeline import ShardedBatchIterator
from repro.data.synthetic import xc_dataset
from repro.models import xc
from repro.train.trainer import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_wol_ckpt")
    args = ap.parse_args()

    cfg = DELICIOUS.bench if args.fast else DELICIOUS.full._replace(
        max_in=32, max_labels=4)
    steps = args.steps or (150 if args.fast else 500)
    n_params = cfg.param_count()
    print(f"model: {cfg.name} input={cfg.input_dim} WOL={cfg.output_dim} "
          f"params={n_params / 1e6:.1f}M")

    n_train = 2048 if args.fast else 6616     # paper's Delicious size
    data = xc_dataset(11, n_train, cfg.input_dim, cfg.output_dim,
                      n_topics=128, max_in=cfg.max_in,
                      max_labels=cfg.max_labels)
    tc = TrainConfig(lr=5e-3, warmup_steps=30, total_steps=steps,
                     weight_decay=0.0, ckpt_every=100, keep_last=2)
    tr = Trainer(lambda p, b: xc.loss(p, b, cfg),
                 lambda k: xc.init_params(k, cfg), tc,
                 ckpt_dir=args.ckpt_dir)
    it = ShardedBatchIterator({"x": data.x, "labels": data.labels},
                              min(256, n_train // 4))
    state, hist = tr.fit(jax.random.PRNGKey(0), it, steps, log_every=50)
    print(f"trained {steps} steps; final loss {hist[-1]['loss']:.4f}")

    # LSS head (paper Algorithm 1 on the trained model)
    params = state.params
    n_test = min(512, n_train // 4)
    q_all = xc.embed(params, jnp.asarray(data.x))
    q_tr, q_te = q_all[n_test:], q_all[:n_test]
    lab = jnp.asarray(data.labels)
    lss_cfg = DELICIOUS.bench_lss if args.fast else DELICIOUS.lss._replace(
        iul_epochs=4, iul_inner_steps=8, iul_lr=0.02)
    index, _ = fit_lss(jax.random.PRNGKey(1), q_tr, lab[n_test:],
                       params["w_out"].astype(jnp.float32),
                       params["b_out"].astype(jnp.float32), lss_cfg,
                       verbose=True)
    _, ids = lss_predict(q_te, index, None, top_k=5)
    cand, _ = retrieve(simhash.augment_queries(q_te), index)
    full_ids = jax.lax.top_k(
        q_te @ params["w_out"].T.astype(jnp.float32)
        + params["b_out"].astype(jnp.float32), 5)[1]
    print(f"full P@1={float(precision_at_k(full_ids, lab[:n_test], 1)):.4f}  "
          f"LSS P@1={float(precision_at_k(ids, lab[:n_test], 1)):.4f}  "
          f"recall={float(label_recall(cand, lab[:n_test])):.3f}  "
          f"sample={float(avg_sample_size(cand)):.0f}/{cfg.output_dim}")


if __name__ == "__main__":
    main()
