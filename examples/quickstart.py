"""Quickstart: the paper's pipeline end-to-end on CPU in ~2 minutes.

1. Train an extreme-classification model (Embedding -> ReLU -> WOL) on
   synthetic topic-structured data (Wiki10-31k stand-in, reduced dims).
2. Fit the LSS index (Algorithm 1: mine pairs -> IUL -> rebuild).
3. Serve with the LSS head (Algorithm 2) and compare against full
   inference: accuracy, label recall, sample size, wall time.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs.paper_datasets import WIKI10
from repro.core import simhash
from repro.core.iul import fit_lss
from repro.core.lss import (avg_sample_size, label_recall, lss_predict,
                            precision_at_k, retrieve)
from repro.data.pipeline import ShardedBatchIterator
from repro.data.synthetic import xc_dataset
from repro.models import xc
from repro.train.trainer import TrainConfig, Trainer


def main() -> None:
    cfg = WIKI10.bench
    print(f"== 1. train XC model ({cfg.input_dim} -> {cfg.hidden} -> "
          f"{cfg.output_dim} WOL) ==")
    data = xc_dataset(7, 3072, cfg.input_dim, cfg.output_dim, n_topics=48,
                      max_in=cfg.max_in, max_labels=cfg.max_labels)
    tc = TrainConfig(lr=5e-3, warmup_steps=30, total_steps=500,
                     weight_decay=0.0, ckpt_every=10 ** 9)
    tr = Trainer(lambda p, b: xc.loss(p, b, cfg),
                 lambda k: xc.init_params(k, cfg), tc)
    it = ShardedBatchIterator({"x": data.x, "labels": data.labels}, 256)
    state, _ = tr.fit(jax.random.PRNGKey(0), it, 500, log_every=100)
    params = state.params

    n_test = 512
    q_all = xc.embed(params, jnp.asarray(data.x))
    q_tr, q_te = q_all[n_test:], q_all[:n_test]
    lab = jnp.asarray(data.labels)
    lab_tr, lab_te = lab[n_test:], lab[:n_test]
    w = params["w_out"].astype(jnp.float32)
    b = params["b_out"].astype(jnp.float32)

    print("\n== 2. fit LSS (offline preprocessing, paper Alg. 1) ==")
    index, hist = fit_lss(jax.random.PRNGKey(1), q_tr, lab_tr, w, b,
                          WIKI10.bench_lss, verbose=True)

    print("\n== 3. serve: LSS vs full ==")
    full = jax.jit(lambda q: jax.lax.top_k(q @ w.T + b, 5)[1])
    lss = jax.jit(lambda q: lss_predict(q, index, None, top_k=5)[1])
    ids_full = full(q_te)
    ids_lss = lss(q_te)
    for name, fn in (("full", full), ("lss", lss)):
        jax.block_until_ready(fn(q_te))
        t0 = time.perf_counter()
        for _ in range(5):
            jax.block_until_ready(fn(q_te))
        dt = (time.perf_counter() - t0) / 5 / n_test * 1e6
        print(f"  {name}: {dt:.1f} us/query")
    cand, _ = retrieve(simhash.augment_queries(q_te), index)
    print(f"  full P@1={float(precision_at_k(ids_full, lab_te, 1)):.4f} "
          f"P@5={float(precision_at_k(ids_full, lab_te, 5)):.4f}")
    print(f"  LSS  P@1={float(precision_at_k(ids_lss, lab_te, 1)):.4f} "
          f"P@5={float(precision_at_k(ids_lss, lab_te, 5)):.4f} "
          f"recall={float(label_recall(cand, lab_te)):.3f} "
          f"sample={float(avg_sample_size(cand)):.0f}/{cfg.output_dim}")


if __name__ == "__main__":
    main()
