"""Serving example: batched LM decoding with the LSS head vs the exact
vocab head — the paper's technique as a first-class serving feature.

A small decoder-only LM (qwen2-family reduced config) is trained briefly
on synthetic topic LM data, then served through serve.engine.LMDecoder:
prefill -> per-token decode -> head (exact | LSS).  Reports tokens/s and
top-1 agreement between the two heads.

Run:  PYTHONPATH=src python examples/serve_lss.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs.reduced import reduced_model_cfg
from repro.core.lss import LSSConfig
from repro.data.pipeline import ShardedBatchIterator
from repro.data.synthetic import lm_dataset
from repro.models import transformer as T
from repro.serve.engine import LMDecoder
from repro.train.trainer import TrainConfig, Trainer


def main() -> None:
    cfg = reduced_model_cfg("qwen2-0.5b")._replace(vocab=2048)
    toks = lm_dataset(5, 200_000, cfg.vocab, 33)
    tokens, labels = toks[:, :-1], toks[:, 1:]
    tc = TrainConfig(lr=3e-3, warmup_steps=20, total_steps=300,
                     ckpt_every=10 ** 9)
    tr = Trainer(lambda p, b: T.lm_loss(p, b, cfg),
                 lambda k: T.init_params(k, cfg), tc)
    it = ShardedBatchIterator({"tokens": tokens, "labels": labels}, 128)
    state, hist = tr.fit(jax.random.PRNGKey(0), it, 300, log_every=100)
    print(f"LM trained: loss {hist[-1]['loss']:.3f} "
          f"(uniform={float(jnp.log(cfg.vocab)):.3f})")

    dec = LMDecoder(state.params, cfg,
                    LSSConfig(k_bits=6, n_tables=1, iul_epochs=4,
                              iul_inner_steps=8, iul_lr=0.02))
    print("fitting LSS index on the LM head...")
    dec.fit_lss(jax.random.PRNGKey(1), jnp.asarray(toks[:256]),
                verbose=True)

    prompt = jnp.asarray(toks[1000:1016, :16])
    for use_lss in (False, True):
        out = dec.generate(prompt, steps=32, use_lss=use_lss)  # warm
        t0 = time.perf_counter()
        out = dec.generate(prompt, steps=32, use_lss=use_lss)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        tps = prompt.shape[0] * 32 / dt
        name = "LSS " if use_lss else "full"
        print(f"  {name} head: {tps:,.0f} tok/s")
        if use_lss:
            lss_out = out
        else:
            full_out = out
    agree = float(jnp.mean(lss_out == full_out))
    print(f"top-1 agreement LSS vs full: {agree:.3f}")


if __name__ == "__main__":
    main()
