"""Serving example: the unified engine end to end on both request kinds.

1. Score path — an Engine over a trained-ish XC model: requests arrive
   one by one (``submit``), the continuous micro-batcher coalesces them
   into bucketed batches, and ``metrics()`` reports latency percentiles,
   throughput, sample size, and label recall from the single retrieval
   pass.
2. Decode path — a small decoder-only LM served through ``LMDecoder``
   (same Engine underneath): exact vs LSS head, tokens/s and agreement.
3. Streaming decode — the same decoder behind the AsyncRuntime's decode
   request kind: sessions join/leave a fixed slot pool mid-flight,
   tokens resolve through per-token ``TokenStream`` futures, and the
   interleaved tokens are bit-identical to blocking ``generate``.
4. Async path — the same Engine behind an ``AsyncRuntime``: open-loop
   Poisson traffic with per-request futures, then a burst segment, and
   an exact-equality check against the synchronous ``flush`` path.
5. Vocab-sharded path — the same Engine with ``head="lss-sharded"``:
   single-process here (where the hierarchical merge IS the flat
   merge), plus the exact launch lines that scale the identical code
   to a multi-host ``jax.distributed`` fleet.

Run:  PYTHONPATH=src python examples/serve_lss.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.reduced import reduced_model_cfg
from repro.core.lss import LSSConfig
from repro.data.pipeline import ShardedBatchIterator
from repro.data.synthetic import lm_dataset, xc_dataset
from repro.models import transformer as T
from repro.models import xc
from repro.serve import AsyncRuntime
from repro.serve.engine import Engine, LMDecoder
from repro.serve.runtime import submit_open_loop
from repro.train.trainer import TrainConfig, Trainer


def score_path() -> None:
    print("== score path: Engine.submit / flush / metrics ==")
    cfg = xc.XCConfig("t", input_dim=2000, hidden=32, output_dim=2000,
                      max_in=16, max_labels=4)
    data = xc_dataset(0, 1024, cfg.input_dim, cfg.output_dim, n_topics=16,
                      max_in=16, max_labels=4)
    params = xc.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(lambda b: xc.embed(params, b["x"]),
                 params["w_out"].astype(jnp.float32),
                 params["b_out"].astype(jnp.float32),
                 LSSConfig(k_bits=5, n_tables=2, iul_epochs=3,
                           iul_inner_steps=6, iul_lr=0.02),
                 top_k=5, head="lss")
    calib = [{"x": jnp.asarray(data.x[i * 128:(i + 1) * 128])}
             for i in range(4)]
    eng.fit(jax.random.PRNGKey(1), calib, jnp.asarray(data.labels[:512]))

    # requests trickle in with a ragged arrival pattern
    rng = np.random.default_rng(0)
    i = 512
    while i < 1024:
        n = int(rng.integers(1, 48))
        for j in range(i, min(i + n, 1024)):
            eng.submit({"x": data.x[j]}, labels=data.labels[j])
        eng.flush()
        i += n
    m = eng.metrics()
    print(f"  {m.n_requests} requests, {m.throughput_rps:,.0f} req/s, "
          f"p50={m.latency_p50_ms:.2f}ms p99={m.latency_p99_ms:.2f}ms")
    print(f"  sample size {m.avg_sample_size:.0f}/{cfg.output_dim}, "
          f"label recall {m.label_recall:.3f}, "
          f"{m.n_compiles} compiles for buckets "
          f"{sorted({k[1] for k in eng.compile_counts})}")


def decode_path():
    print("== decode path: LMDecoder on the same Engine ==")
    cfg = reduced_model_cfg("qwen2-0.5b")._replace(vocab=2048)
    toks = lm_dataset(5, 200_000, cfg.vocab, 33)
    tokens, labels = toks[:, :-1], toks[:, 1:]
    tc = TrainConfig(lr=3e-3, warmup_steps=20, total_steps=300,
                     ckpt_every=10 ** 9)
    tr = Trainer(lambda p, b: T.lm_loss(p, b, cfg),
                 lambda k: T.init_params(k, cfg), tc)
    it = ShardedBatchIterator({"tokens": tokens, "labels": labels}, 128)
    state, hist = tr.fit(jax.random.PRNGKey(0), it, 300, log_every=100)
    print(f"  LM trained: loss {hist[-1]['loss']:.3f} "
          f"(uniform={float(jnp.log(cfg.vocab)):.3f})")

    dec = LMDecoder(state.params, cfg,
                    LSSConfig(k_bits=6, n_tables=1, iul_epochs=4,
                              iul_inner_steps=8, iul_lr=0.02),
                    max_streams=16)      # one slot per prompt row below
    print("  fitting LSS index on the LM head...")
    dec.fit_lss(jax.random.PRNGKey(1), jnp.asarray(toks[:256]),
                verbose=True)

    prompt = jnp.asarray(toks[1000:1016, :16])
    outs = {}
    for head in ("full", "lss"):
        out = dec.generate(prompt, steps=32, head=head)      # warm
        t0 = time.perf_counter()
        out = dec.generate(prompt, steps=32, head=head)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        tps = prompt.shape[0] * 32 / dt
        print(f"  {head:4s} head: {tps:,.0f} tok/s")
        outs[head] = out
    agree = float(jnp.mean(outs["lss"] == outs["full"]))
    print(f"  top-1 agreement LSS vs full: {agree:.3f}")
    return dec, toks


def streaming_decode_path(dec, toks) -> None:
    print("== streaming decode: sessions + TokenStream futures ==")
    from repro.serve import AsyncRuntime
    from repro.serve.runtime import submit_decode_open_loop

    prompts = np.asarray(toks[2000:2012, :16], np.int32)
    steps = 24
    # blocking reference: one generate call per prompt (same fused step)
    blocking = [np.asarray(dec.generate(jnp.asarray(p)[None, :],
                                        steps=steps, head="lss"))[0]
                for p in prompts]
    sched = dec.scheduler(head="lss")
    sched.reset_stats()
    with AsyncRuntime(dec.engine, head="lss", policy="shed",
                      scheduler=sched) as rt:
        streams, _ = submit_decode_open_loop(rt, list(prompts), 50.0,
                                             max_new_tokens=steps, seed=0)
        first = list(streams[0])        # iterate tokens as they resolve
        rt.drain(timeout=300.0)
        s = rt.stats()
    exact = all(np.array_equal(st.result(), blocking[i])
                for i, st in enumerate(streams))
    print(f"  {s.n_decode_done} sessions, {s.n_decode_tokens} tokens at "
          f"{s.decode_tokens_per_s:,.0f} tok/s "
          f"(slots={dec.max_streams}, occupancy "
          f"{s.decode_slot_occupancy:.2f})")
    print(f"  ttft p50={s.ttft_p50_ms:.1f} ms  "
          f"itl p50={s.itl_p50_ms:.2f} ms  "
          f"first stream: {len(first)} tokens streamed live")
    print(f"  interleaved == blocking generate: {exact}")


def async_path() -> None:
    print("== async path: AsyncRuntime.submit -> futures -> stats ==")
    m, d = 4096, 32
    w = jax.random.normal(jax.random.PRNGKey(0), (m, d))
    eng = Engine(None, w, None, LSSConfig(k_bits=5, n_tables=2),
                 top_k=5, head="lss", buckets=(1, 4, 16))
    eng.fit_random(jax.random.PRNGKey(1))

    rng = np.random.default_rng(0)
    xs = rng.standard_normal((192, d)).astype(np.float32)
    # synchronous reference results for the exact-equality check
    for x in xs:
        eng.submit(x)
    sync = eng.flush()

    with AsyncRuntime(eng, max_queue=256, policy="shed") as rt:
        t0 = time.perf_counter()
        futs, _ = submit_open_loop(rt, xs[:96], 1000.0)   # paced Poisson
        burst, _ = submit_open_loop(rt, xs[96:], 0.0)     # then saturation
        futs += burst
        res = [f.result(timeout=30.0) for f in futs]
        s = rt.stats()
    exact = all(np.array_equal(r.logits, sy.logits)
                and np.array_equal(r.ids, sy.ids)
                for r, sy in zip(res, sync))
    print(f"  {s.n_completed} served in {time.perf_counter() - t0:.2f}s: "
          f"p50={s.latency_p50_ms:.2f} p95={s.latency_p95_ms:.2f} "
          f"p99={s.latency_p99_ms:.2f} ms (incl. queue wait), "
          f"occupancy={s.avg_batch_occupancy:.2f}, "
          f"shed={s.n_shed_queue}+{s.n_shed_deadline}")
    print(f"  bit-identical to synchronous flush: {exact}")


def sharded_multihost_path() -> None:
    print("== vocab-sharded path: head='lss-sharded' + fleet recipe ==")
    from repro.core import simhash
    from repro.serve.heads import shard_index

    m, d = 4096, 32
    w = jax.random.normal(jax.random.PRNGKey(0), (m, d))
    cfg = LSSConfig(k_bits=5, n_tables=2)
    eng = Engine(None, w, None, cfg, top_k=5, head="lss-sharded",
                 buckets=(16,))
    eng.fit_random(jax.random.PRNGKey(1))
    q = jnp.asarray(np.random.default_rng(3).standard_normal((16, d)),
                    jnp.float32)
    out = eng.rank(q)
    out2 = eng.rank(q)
    exact = (np.array_equal(np.asarray(out.ids), np.asarray(out2.ids))
             and np.array_equal(np.asarray(out.logits),
                                np.asarray(out2.logits)))
    print(f"  lss-sharded over {jax.local_device_count()} local "
          f"device(s): top-{out.ids.shape[1]} of {m}, "
          f"deterministic={exact}")

    # What each FLEET member would build — only its own shards.  Here:
    # process 1 of a 2-process fleet, 2 shards per host, so shards
    # [2, 4) of 4.  No process ever materializes the full [m, d] head;
    # serve.multihost.assemble_global_stack stitches these local stacks
    # into the global (host, model)-sharded arrays metadata-only.
    w_aug = simhash.augment_neurons(w, None)
    theta = simhash.init_hyperplanes(jax.random.PRNGKey(1), d + 1,
                                     cfg.k_bits, cfg.n_tables)
    lo, hi = 2, 4
    m_local = -(-m // 4)
    local_rows = w_aug[lo * m_local:min(hi * m_local, m)]
    stack, _, _ = shard_index(local_rows, theta, cfg, 4,
                              shard_range=(lo, hi), m_total=m)
    n_built = jax.tree.leaves(stack)[0].shape[0]
    print(f"  process 1/2 builds shards [{lo}, {hi}): "
          f"{n_built} local shard(s) over rows "
          f"[{lo * m_local}, {min(hi * m_local, m)}) — "
          f"never the full [{m}, {d}] weight")

    # The same Engine code runs a real jax.distributed fleet (gloo CPU
    # collectives work on plain multi-process localhost too) — process 0
    # owns admission/results, the rest mirror via follower_loop:
    print("  scale out (one line per host/process):")
    for pid in range(2):
        print("    python -m repro.launch.serve --arch qwen2-0.5b "
              "--reduced --head lss-sharded \\\n"
              "        --coordinator HOST0:1234 --num-processes 2 "
              f"--process-id {pid}")
    print("  (exact single-vs-multi-process parity: "
          "tests/test_multihost.py; scaling rows: "
          "python -m benchmarks.multihost_bench)")


def main() -> None:
    score_path()
    dec, toks = decode_path()
    streaming_decode_path(dec, toks)
    async_path()
    sharded_multihost_path()


if __name__ == "__main__":
    main()
