"""End-to-end behaviour tests for the paper's system.

The full pipeline on a miniature problem: train the paper's XC model on
topic-structured data -> fit LSS (Algorithm 1) -> serve (Algorithm 2)
and check the paper's qualitative claims hold:
  (1) LSS accuracy ~ full accuracy at a small sample size,
  (2) the learned index retrieves labels better than random SimHash,
  (3) retrieval compute/query shrinks by >5x vs full inference.
"""

import jax
import jax.numpy as jnp

from repro.core import simhash
from repro.core.iul import fit_lss
from repro.core.lss import (LSSConfig, avg_sample_size, build_index,
                            label_recall, lss_predict, precision_at_k,
                            retrieve)
from repro.data.pipeline import ShardedBatchIterator
from repro.data.synthetic import xc_dataset
from repro.models import xc
from repro.train.trainer import TrainConfig, Trainer


def test_paper_pipeline_end_to_end():
    cfg = xc.XCConfig("sys", input_dim=4000, hidden=48, output_dim=2000,
                      max_in=24, max_labels=4)
    data = xc_dataset(5, 1536, cfg.input_dim, cfg.output_dim, n_topics=32,
                      max_in=cfg.max_in, max_labels=cfg.max_labels)
    tc = TrainConfig(lr=5e-3, warmup_steps=20, total_steps=220,
                     weight_decay=0.0, ckpt_every=10 ** 9)
    tr = Trainer(lambda p, b: xc.loss(p, b, cfg),
                 lambda k: xc.init_params(k, cfg), tc)
    it = ShardedBatchIterator({"x": data.x, "labels": data.labels}, 256)
    state, hist = tr.fit(jax.random.PRNGKey(0), it, 220, log_every=10 ** 9)
    assert hist[-1]["loss"] < 7.0                      # learned something
    params = state.params

    n_test = 256
    q_all = xc.embed(params, jnp.asarray(data.x))
    q_tr, q_te = q_all[n_test:], q_all[:n_test]
    lab = jnp.asarray(data.labels)
    w = params["w_out"].astype(jnp.float32)
    b = params["b_out"].astype(jnp.float32)

    lss_cfg = LSSConfig(k_bits=3, n_tables=2, iul_epochs=6,
                        iul_inner_steps=8, iul_lr=0.02)
    index, _ = fit_lss(jax.random.PRNGKey(1), q_tr, lab[n_test:], w, b,
                       lss_cfg)

    # (2) learned beats random SimHash on label recall
    theta0 = simhash.init_hyperplanes(jax.random.PRNGKey(9),
                                      cfg.hidden + 1, lss_cfg.k_bits,
                                      lss_cfg.n_tables)
    idx0 = build_index(simhash.augment_neurons(w, b), theta0, lss_cfg)
    q_aug = simhash.augment_queries(q_te)
    rec_learned = float(label_recall(retrieve(q_aug, index)[0],
                                     lab[:n_test]))
    rec_random = float(label_recall(retrieve(q_aug, idx0)[0],
                                    lab[:n_test]))
    assert rec_learned > rec_random, (rec_learned, rec_random)

    # (1) LSS accuracy close to full at a fraction of the neurons
    full_p1 = float(precision_at_k(
        jax.lax.top_k(q_te @ w.T + b, 5)[1], lab[:n_test], 1))
    _, ids = lss_predict(q_te, index, None, top_k=5)
    lss_p1 = float(precision_at_k(ids, lab[:n_test], 1))
    assert lss_p1 > 0.5 * full_p1, (lss_p1, full_p1)

    # (3) compute reduction
    sample = float(avg_sample_size(retrieve(q_aug, index)[0]))
    assert sample < cfg.output_dim / 5, sample
