"""Serving engine: WOLServer end-to-end + LMDecoder LSS/full agreement."""

import jax
import jax.numpy as jnp
import pytest

from repro.core.lss import LSSConfig
from repro.data.synthetic import lm_dataset, xc_dataset
from repro.models import transformer as T
from repro.models import xc
from repro.serve.engine import LMDecoder, WOLServer


def test_wol_server_end_to_end():
    cfg = xc.XCConfig("t", input_dim=2000, hidden=32, output_dim=1000,
                      max_in=16, max_labels=4)
    data = xc_dataset(0, 512, cfg.input_dim, cfg.output_dim, n_topics=16,
                      max_in=16, max_labels=4)
    params = xc.init_params(jax.random.PRNGKey(0), cfg)
    server = WOLServer(lambda b: xc.embed(params, b["x"]),
                       params["w_out"].astype(jnp.float32),
                       params["b_out"].astype(jnp.float32),
                       LSSConfig(k_bits=4, n_tables=1, iul_epochs=2,
                                 iul_inner_steps=4, iul_lr=0.02),
                       top_k=5)
    batches = [{"x": jnp.asarray(data.x[i * 128:(i + 1) * 128])}
               for i in range(3)]
    server.fit(jax.random.PRNGKey(1), batches[:2],
               jnp.asarray(data.labels[:256]))
    out_full, m_full = server.serve(batches, use_lss=False)
    out_lss, m_lss = server.serve(batches, use_lss=True)
    assert len(out_full) == len(out_lss) == 3
    assert out_lss[0][1].shape == (128, 5)
    assert 0 < m_lss.avg_sample_size < cfg.output_dim


@pytest.mark.slow
def test_lm_decoder_lss_agreement():
    """After IUL fitting, the LSS head should frequently agree with the
    exact head on a trained-ish model (teacher-forced calibration)."""
    cfg = T.TransformerConfig(name="t", n_layers=2, d_model=32, n_heads=2,
                              n_kv_heads=2, head_dim=16, d_ff=64,
                              vocab=512, dtype=jnp.float32, kv_chunk=32)
    toks = jnp.asarray(lm_dataset(0, 64 * 33, 512, 33))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    dec = LMDecoder(params, cfg,
                    LSSConfig(k_bits=4, n_tables=2, iul_epochs=3,
                              iul_inner_steps=6, iul_lr=0.02))
    dec.fit_lss(jax.random.PRNGKey(1), toks[:32])
    prompt = toks[32:40, :8]
    full = dec.generate(prompt, steps=8, use_lss=False)
    lss = dec.generate(prompt, steps=8, use_lss=True)
    assert full.shape == lss.shape == (8, 8)
    # untrained model: agreement is not guaranteed per-token, but the LSS
    # head must return valid ids
    assert bool((lss >= 0).all()) and bool((lss < 512).all())
