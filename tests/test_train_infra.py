"""Trainer, checkpointing (atomicity/resume/elastic), data pipeline,
gradient compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import ShardedBatchIterator
from repro.optim.compression import dequantize_int8, quantize_int8
from repro.train import checkpoint as ckpt
from repro.train.trainer import TrainConfig, Trainer, make_train_step, \
    init_state


def _quad_loss(params, batch):
    pred = batch["x"] @ params["w"] + params["b"]
    return jnp.mean((pred - batch["y"]) ** 2)


def _init_fn(key):
    return {"w": jax.random.normal(key, (8, 1)) * 0.1,
            "b": jnp.zeros((1,))}


def _data(n=256):
    rng = np.random.default_rng(0)
    x = rng.normal(size=(n, 8)).astype(np.float32)
    w = rng.normal(size=(8, 1)).astype(np.float32)
    y = x @ w + 0.01 * rng.normal(size=(n, 1)).astype(np.float32)
    return {"x": x, "y": y}


def test_loss_decreases():
    tc = TrainConfig(lr=0.05, warmup_steps=5, total_steps=100,
                     ckpt_every=1000)
    tr = Trainer(_quad_loss, _init_fn, tc)
    it = ShardedBatchIterator(_data(), 32, seed=0)
    state, hist = tr.fit(jax.random.PRNGKey(0), it, 60, log_every=20)
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.2


def test_microbatch_equals_fullbatch_grads():
    tc1 = TrainConfig(lr=0.1, warmup_steps=0, clip_norm=1e9, microbatches=1)
    tc4 = tc1._replace(microbatches=4)
    s1 = init_state(jax.random.PRNGKey(0), _init_fn, tc1)
    s4 = init_state(jax.random.PRNGKey(0), _init_fn, tc4)
    batch = {k: jnp.asarray(v[:64]) for k, v in _data().items()}
    n1, _ = make_train_step(_quad_loss, tc1)(s1, batch)
    n4, _ = make_train_step(_quad_loss, tc4)(s4, batch)
    np.testing.assert_allclose(np.asarray(n1.params["w"]),
                               np.asarray(n4.params["w"]), rtol=1e-5)


def test_checkpoint_roundtrip_and_retention(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"a": jnp.arange(5.0), "b": {"c": jnp.ones((2, 2))}}
    for step in (10, 20, 30, 40):
        ckpt.save(d, step, tree, extra={"data": {"step": step}},
                  keep_last=2)
    assert ckpt.all_steps(d) == [30, 40]
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    got, extra = ckpt.restore(d, 40, like)
    np.testing.assert_allclose(np.asarray(got["a"]), np.arange(5.0))
    assert extra["data"]["step"] == 40


def test_torn_checkpoint_skipped(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"a": jnp.arange(3.0)}
    ckpt.save(d, 1, tree)
    ckpt.save(d, 2, tree)
    # corrupt the newest
    os.remove(os.path.join(d, "step_2", "leaves.npz"))
    got = ckpt.restore_latest(d, tree)
    assert got is not None and got[2] == 1


def test_preemption_resume_identical(tmp_path):
    """Crash at step 25, resume -> same final params as uninterrupted."""
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    tc = TrainConfig(lr=0.05, warmup_steps=0, total_steps=50, ckpt_every=10)
    data = _data()

    tr_ref = Trainer(_quad_loss, _init_fn, tc, ckpt_dir=d1)
    it = ShardedBatchIterator(data, 32, seed=7)
    ref_state, _ = tr_ref.fit(jax.random.PRNGKey(0), it, 40, log_every=100)

    tr1 = Trainer(_quad_loss, _init_fn, tc, ckpt_dir=d2)
    it2 = ShardedBatchIterator(data, 32, seed=7)
    with pytest.raises(RuntimeError):
        tr1.fit(jax.random.PRNGKey(0), it2, 40, crash_after=25,
                log_every=100)
    tr2 = Trainer(_quad_loss, _init_fn, tc, ckpt_dir=d2)
    it3 = ShardedBatchIterator(data, 32, seed=7)
    got_state, _ = tr2.fit(jax.random.PRNGKey(0), it3, 40, log_every=100)
    np.testing.assert_allclose(np.asarray(got_state.params["w"]),
                               np.asarray(ref_state.params["w"]),
                               rtol=1e-6)


def test_pipeline_resume_determinism():
    data = _data(128)
    it1 = ShardedBatchIterator(data, 32, seed=3)
    batches = [next(it1) for _ in range(7)]
    state = it1.state_dict()
    # fresh iterator resumed at step 5 must reproduce batches 5..
    it2 = ShardedBatchIterator(data, 32, seed=3, start_step=5)
    for i in range(5, 7):
        b = next(it2)
        np.testing.assert_array_equal(b["x"], batches[i]["x"])
    assert state["step"] == 7


def test_int8_quant_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32)) * 3
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s, x.shape, jnp.float32)
    err = np.abs(np.asarray(back - x))
    # blockwise symmetric int8: |err| <= scale/2 per block
    bound = np.repeat(np.asarray(s), 256)[:1000] * 0.5 + 1e-6
    assert (err <= bound).all()
