"""Attention (GQA, blockwise, decode), RoPE, norms, MoE dispatch."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L
from repro.models.moe import (MoEConfig, dispatch_indices, init_moe_params,
                              moe_ffn, moe_ffn_dense_oracle)


@pytest.mark.parametrize("kv,qc,kc", [(4, None, 16), (2, 16, 16),
                                      (1, 32, 24), (4, 64, 64)])
def test_blockwise_matches_naive(kv, qc, kc):
    key = jax.random.PRNGKey(0)
    b, s, n, h = 2, 64, 4, 16
    q = jax.random.normal(key, (b, s, n, h))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kv, h))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kv, h))
    for causal in (True, False):
        ref = L.attention_naive(q, k, v, causal=causal)
        out = L.attention_blockwise(q, k, v, causal=causal, kv_chunk=kc,
                                    q_chunk=qc)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                                   rtol=2e-5, atol=2e-5)


def test_decode_matches_naive_last_position():
    key = jax.random.PRNGKey(3)
    b, s, n, kv, h = 2, 33, 4, 2, 16
    q = jax.random.normal(key, (b, s, n, h))
    k = jax.random.normal(jax.random.PRNGKey(4), (b, s, kv, h))
    v = jax.random.normal(jax.random.PRNGKey(5), (b, s, kv, h))
    ref = L.attention_naive(q, k, v, causal=True)
    # decode: last query against padded cache of length 48
    pad = 48 - s
    kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    out = L.attention_decode(q[:, -1:], kc, vc, kv_len=s)
    np.testing.assert_allclose(np.asarray(ref[:, -1:]), np.asarray(out),
                               rtol=2e-5, atol=2e-5)


def test_rope_preserves_norm_and_relative_position():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, 8, 2, 32))
    pos = jnp.arange(8)[None]
    y = L.apply_rope(x, pos)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)
    # relative property: <R(p)q, R(p+d)k> depends only on d
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 32))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 32))
    def ip(pq, pk):
        rq = L.apply_rope(q, jnp.array([[pq]]))
        rk = L.apply_rope(k, jnp.array([[pk]]))
        return float(jnp.sum(rq * rk))
    assert abs(ip(0, 5) - ip(7, 12)) < 1e-3


def test_rms_norm():
    x = jnp.array([[1.0, 2.0, 3.0, 4.0]])
    y = L.rms_norm(x, jnp.ones(4))
    rms = float(jnp.sqrt(jnp.mean(x ** 2)))
    np.testing.assert_allclose(np.asarray(y), np.asarray(x) / rms, rtol=1e-5)


# ------------------------------------------------------------------- MoE --

def test_dispatch_indices_capacity_and_order():
    top_e = jnp.array([[0, 1], [0, 1], [0, 2], [0, 2]])    # expert 0: 4x
    pos, keep = dispatch_indices(top_e, n_experts=4, capacity=2)
    posn, keepn = np.asarray(pos), np.asarray(keep)
    # expert 0 gets exactly 2 kept slots (first-come by stable sort)
    e0 = [i for i in range(8) if i % 2 == 0]
    kept0 = [i for i in e0 if keepn[i]]
    assert len(kept0) == 2 and kept0 == [0, 2]
    assert sorted(posn[kept0].tolist()) == [0, 1]
    # every kept position is unique
    kept_pos = posn[keepn]
    assert len(set(kept_pos.tolist())) == len(kept_pos)


def test_moe_matches_dense_oracle_with_big_capacity():
    key = jax.random.PRNGKey(0)
    cfg = MoEConfig(n_experts=6, top_k=2, d_model=16, d_ff=32,
                    n_experts_padded=8, capacity_factor=8.0)
    params = init_moe_params(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (40, 16))
    out, aux = moe_ffn(x, params, cfg)
    want = moe_ffn_dense_oracle(x, params, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    assert float(aux) > 0


def test_moe_padded_experts_never_routed():
    key = jax.random.PRNGKey(0)
    cfg = MoEConfig(n_experts=3, top_k=2, d_model=8, d_ff=16,
                    n_experts_padded=4, capacity_factor=8.0)
    params = init_moe_params(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 8))
    from repro.models.moe import router_topk
    top_e, _, _ = router_topk(x, params["router"], cfg)
    assert int(top_e.max()) < 3
