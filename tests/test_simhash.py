"""SimHash primitives: unit + property tests."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import simhash


def test_pack_bits_roundtrip_exhaustive():
    k, l = 4, 3
    n = 2 ** (k * l)
    rng = np.random.default_rng(0)
    bits = rng.integers(0, 2, size=(64, k * l)).astype(bool)
    ids = simhash.pack_bits(jnp.asarray(bits), k, l)
    assert ids.shape == (64, l)
    # manual pack
    want = np.zeros((64, l), np.int32)
    for t in range(l):
        for j in range(k):
            want[:, t] += bits[:, t * k + j] << j
    np.testing.assert_array_equal(np.asarray(ids), want)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 8), st.integers(1, 4), st.integers(2, 33))
def test_bucket_ids_in_range(k, l, d):
    key = jax.random.PRNGKey(k * 100 + l * 10 + d)
    x = jax.random.normal(key, (16, d))
    theta = simhash.init_hyperplanes(key, d, k, l)
    ids = simhash.bucket_ids(x, theta, k, l)
    assert ids.shape == (16, l)
    assert (ids >= 0).all() and (ids < 2 ** k).all()


@settings(max_examples=20, deadline=None)
@given(st.floats(0.1, 100.0))
def test_hash_scale_invariance(scale):
    """sign(theta^T x) must not change under positive scaling of x."""
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (32, 16))
    theta = simhash.init_hyperplanes(jax.random.PRNGKey(8), 16, 4, 2)
    a = simhash.bucket_ids(x, theta, 4, 2)
    b = simhash.bucket_ids(x * scale, theta, 4, 2)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_collision_probability_matches_angle():
    """SimHash theory: P(bit collision) = 1 - angle/pi (sanity, 1 bit)."""
    key = jax.random.PRNGKey(0)
    d = 64
    x = jax.random.normal(key, (1, d))
    # construct y at a known angle ~60 degrees
    y = 0.5 * x + (3 ** 0.5 / 2) * jax.random.normal(jax.random.PRNGKey(1),
                                                     (1, d))
    cos = jnp.sum(x * y) / (jnp.linalg.norm(x) * jnp.linalg.norm(y))
    angle = float(jnp.arccos(jnp.clip(cos, -1, 1)))
    theta = simhash.init_hyperplanes(jax.random.PRNGKey(2), d, 1, 4096)
    bx = simhash.hash_bits(x, theta)
    by = simhash.hash_bits(y, theta)
    p = float(jnp.mean(bx == by))
    assert abs(p - (1 - angle / np.pi)) < 0.05


def test_augment():
    w = jnp.ones((3, 4))
    b = jnp.arange(3.0)
    wa = simhash.augment_neurons(w, b)
    assert wa.shape == (3, 5)
    np.testing.assert_array_equal(np.asarray(wa[:, -1]), np.arange(3.0))
    q = simhash.augment_queries(jnp.ones((2, 4)))
    assert q.shape == (2, 5) and float(q[:, -1].sum()) == 0.0


def test_soft_codes_gradient_nonzero():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (8, 16)) * 10.0   # large norm: would
    theta = simhash.init_hyperplanes(key, 16, 4, 1)  # saturate w/o _unit

    def loss(th):
        return jnp.sum(simhash.soft_codes(x, th) ** 2)

    g = jax.grad(loss)(theta)
    assert float(jnp.abs(g).max()) > 1e-4
