"""Quantized slab storage (``lss_topk.slab_dtype``): exactness, strategy
resolution, refit requantization, and the DMA/VMEM accounting.

The acceptance bar: for EVERY storage format (fp32 | bf16 | int8) the
jnp ref and the pallas-interpret kernel are BIT-IDENTICAL across the
dedup strategies and the C sweep — dequantization is elementwise on
both sides, so the fp32 path's exact-equality contract carries over —
while int8 cuts the per-query slab DMA bytes >= 3x and costs <= 0.5%
top-k label recall on a synthetic WOL.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import simhash
from repro.core.lss import LSSConfig, build_index, lss_forward
from repro.kernels import registry
from repro.kernels.lss_topk import dedup as D
from repro.kernels.lss_topk import slabs as S
from repro.kernels.lss_topk.ops import lss_topk, lss_topk_vmem_bytes

FIELDS = ("top_logits", "top_ids", "sample_size", "cand_ids")


@pytest.fixture(autouse=True)
def _clean_registry():
    registry.set_default_impl(None)
    registry.set_default_strategy("lss_topk.dedup", None)
    registry.set_default_strategy("lss_topk.slab_dtype", None)
    D.set_dedup_auto_threshold(None)
    os.environ.pop(S.SLAB_DTYPE_ENV_VAR, None)
    registry.reset_dispatch_log()
    yield
    registry.set_default_impl(None)
    registry.set_default_strategy("lss_topk.dedup", None)
    registry.set_default_strategy("lss_topk.slab_dtype", None)
    D.set_dedup_auto_threshold(None)
    os.environ.pop(S.SLAB_DTYPE_ENV_VAR, None)


def _case(c, b=4, d=16, n_tables=2, k_bits=2, seed=0, slab_dtype="fp32"):
    """Synthetic bucket-major index (heavy cross-table duplicates) with
    the slabs stored in the requested format."""
    cap = c // n_tables
    assert cap * n_tables == c, (c, n_tables)
    n_buckets = 2 ** k_bits
    kt, kw, kq = jax.random.split(jax.random.PRNGKey(seed), 3)
    table_ids = jax.random.randint(kt, (n_tables, n_buckets, cap), -1,
                                   max(c // 2, 2), jnp.int32)
    w_fp32 = jax.random.normal(kw, (n_tables, n_buckets, cap, d))
    wb, w_scale = S.quantize_slabs(w_fp32, slab_dtype)
    theta = jax.random.normal(jax.random.PRNGKey(seed + 1),
                              (d, k_bits * n_tables))
    q = jax.random.normal(kq, (b, d), jnp.float32)
    return q, theta, table_ids, wb, w_scale


def _assert_same(ref, out, msg=""):
    for name, r, o in zip(FIELDS, ref, out):
        np.testing.assert_array_equal(np.asarray(r), np.asarray(o),
                                      err_msg=f"{msg} {name}")


# ------------------------------------- ref == interpret, full knob grid --

@pytest.mark.parametrize("slab_dtype", S.SLAB_DTYPE_CHOICES)
@pytest.mark.parametrize("dedup", ["quadratic", "bitonic"])
@pytest.mark.parametrize("c", [512, 2048, 8192])
def test_ref_matches_interpret_per_format(slab_dtype, dedup, c):
    """Bit-identity of ref vs pallas-interpret for every storage format
    x dedup strategy across the C sweep — the fp32 exactness contract
    must survive quantized storage unchanged."""
    if c >= 8192 and dedup == "quadratic":
        pytest.skip("quadratic [C,C] at 8k is test_dedup's slow regime; "
                    "the storage format is orthogonal to the mask")
    b = 2 if c >= 8192 else 4
    q, theta, tids, wb, w_scale = _case(c, b=b, seed=c,
                                        slab_dtype=slab_dtype)
    ref = lss_topk(q, theta, tids, wb, top_k=5, impl="ref", dedup=dedup,
                   w_scale=w_scale)
    out = lss_topk(q, theta, tids, wb, top_k=5, impl="pallas_interpret",
                   dedup=dedup, w_scale=w_scale)
    _assert_same(ref, out, f"{slab_dtype}/{dedup}/C={c}")


@pytest.mark.parametrize("slab_dtype", ["bf16", "int8"])
def test_non_lane_aligned_shapes(slab_dtype):
    """Non-128 d and capacity (the interpret path runs unpadded; ops.py
    pads P with -1 ids and zero scales only on real TPUs)."""
    q, theta, tids, wb, w_scale = _case(2 * 13, b=3, d=17, n_tables=2,
                                        slab_dtype=slab_dtype, seed=7)
    assert wb.shape[2] == 13 and wb.shape[3] == 17
    ref = lss_topk(q, theta, tids, wb, top_k=4, impl="ref",
                   w_scale=w_scale)
    out = lss_topk(q, theta, tids, wb, top_k=4, impl="pallas_interpret",
                   w_scale=w_scale)
    _assert_same(ref, out, f"{slab_dtype} d=17 P=13")


@pytest.mark.parametrize("slab_dtype", S.SLAB_DTYPE_CHOICES)
def test_all_empty_buckets(slab_dtype):
    """All-(-1) tables: empty slots quantize to zero rows in every
    format (the eps scale keeps int8 dequantizing to exactly 0), so the
    outputs are all-(-1) ids / NEG_INF logits / zero sample sizes."""
    q, theta, _, wb_f, _ = _case(8, b=3, d=8, slab_dtype="fp32", seed=3)
    tids = jnp.full((2, 4, 4), -1, jnp.int32)
    wb, w_scale = S.quantize_slabs(jnp.zeros_like(
        S.dequantize_slabs(wb_f, None)), slab_dtype)
    for impl in ("ref", "pallas_interpret"):
        out = lss_topk(q, theta, tids, wb, top_k=3, impl=impl,
                       w_scale=w_scale)
        assert np.all(np.asarray(out[1]) == -1), impl
        assert np.all(np.asarray(out[2]) == 0), impl


def test_w_scale_contract_enforced():
    """int8 slabs without scales (and scales without int8 slabs) are
    rejected loudly, not served wrongly."""
    q, theta, tids, wb, w_scale = _case(8, b=2, d=8, slab_dtype="int8")
    with pytest.raises(ValueError, match="w_scale"):
        lss_topk(q, theta, tids, wb, top_k=2, impl="ref")
    wb_f, _ = _case(8, b=2, d=8, slab_dtype="fp32")[3], None
    with pytest.raises(ValueError, match="w_scale"):
        lss_topk(q, theta, tids, wb_f, top_k=2, impl="ref",
                 w_scale=w_scale)


# ----------------------------------------------- strategy resolution --

def test_resolution_order_and_log():
    """Explicit arg > process override > env var > auto(fp32), with
    every resolution recorded in the dispatch log."""
    assert S.resolve_slab_dtype(None) == "fp32"                 # auto
    os.environ[S.SLAB_DTYPE_ENV_VAR] = "int8"
    assert S.resolve_slab_dtype(None) == "int8"                 # env
    with registry.use_strategy("lss_topk.slab_dtype", "bf16"):
        assert S.resolve_slab_dtype(None) == "bf16"             # process
        assert S.resolve_slab_dtype("fp32") == "fp32"           # explicit
    log = [c for (k, c) in registry.dispatch_log()
           if k == "lss_topk.slab_dtype"]
    assert log == ["fp32", "int8", "bf16", "fp32"]
    with pytest.raises(Exception):
        S.resolve_slab_dtype("int4")


def test_build_index_resolves_from_env(monkeypatch):
    monkeypatch.setenv(S.SLAB_DTYPE_ENV_VAR, "int8")
    w_aug = simhash.augment_neurons(
        jax.random.normal(jax.random.PRNGKey(0), (64, 8)))
    cfg = LSSConfig(k_bits=2, n_tables=2)        # slab_dtype=None -> env
    theta = simhash.init_hyperplanes(jax.random.PRNGKey(1),
                                     w_aug.shape[1], 2, 2)
    index = build_index(w_aug, theta, cfg)
    assert index.w_bucketed.dtype == jnp.int8
    assert index.w_scale is not None
    assert index.w_scale.shape == index.tables.table_ids.shape
    # explicit config wins over the env
    idx2 = build_index(w_aug, theta, cfg._replace(slab_dtype="bf16"))
    assert idx2.w_bucketed.dtype == jnp.bfloat16
    assert idx2.w_scale is None


# ------------------------------------------------ refit requantization --

def test_refit_requantizes_and_invalidates_steps():
    """A refit rebuilds the index through build_index (requantizing from
    the new fp32 weights) and drops the engine's LSS jitted steps, so
    no step can serve stale scales."""
    from repro.serve.engine import Engine

    w = jax.random.normal(jax.random.PRNGKey(0), (256, 12))
    q = jax.random.normal(jax.random.PRNGKey(1), (4, 12))
    eng = Engine(None, w, None, LSSConfig(k_bits=3, n_tables=2),
                 top_k=3, buckets=(4,), impl="ref", slab_dtype="int8")
    eng.fit_random(jax.random.PRNGKey(2))
    assert eng.index.w_bucketed.dtype == jnp.int8
    scale0 = np.asarray(eng.index.w_scale)
    eng.rank(q, record=False)
    assert eng.compile_counts[("lss", 4)] == 1
    eng.fit_random(jax.random.PRNGKey(3))        # refit: new hyperplanes
    assert eng.index.w_bucketed.dtype == jnp.int8
    assert not np.array_equal(scale0, np.asarray(eng.index.w_scale))
    eng.rank(q, record=False)                    # step was invalidated
    assert eng.compile_counts[("lss", 4)] == 2


# --------------------------------------------- recall + byte accounting --

def test_int8_recall_within_half_percent_of_fp32():
    """Synthetic WOL: quantized ranking loses <= 0.5% top-k label recall
    vs the fp32 index (candidate retrieval is identical by construction
    — tables hash the fp32 weights)."""
    m, d, b, top_k = 2048, 31, 32, 10
    w = jax.random.normal(jax.random.PRNGKey(0), (m, d), jnp.float32)
    q = jax.random.normal(jax.random.PRNGKey(1), (b, d), jnp.float32)
    w_aug = simhash.augment_neurons(w)
    exact = jax.lax.top_k(simhash.augment_queries(q) @ w_aug.T, top_k)[1]
    recall = {}
    cands = {}
    for sdt in ("fp32", "int8"):
        cfg = LSSConfig(k_bits=3, n_tables=4, slab_dtype=sdt)
        theta = simhash.init_hyperplanes(jax.random.PRNGKey(2),
                                         w_aug.shape[1], 3, 4)
        out = lss_forward(q, build_index(w_aug, theta, cfg), None,
                          top_k=top_k, impl="ref")
        hit = (exact[:, :, None] == out.top_ids[:, None, :]).any(-1)
        recall[sdt] = float(jnp.mean(hit))
        cands[sdt] = np.asarray(out.cand_ids)
    # retrieval is storage-independent; only ranking may differ
    np.testing.assert_array_equal(cands["fp32"], cands["int8"])
    assert recall["fp32"] - recall["int8"] <= 0.005, recall


def test_dma_and_vmem_accounting():
    """int8 slab DMA bytes are >= 3x below fp32 at serving dims, and the
    VMEM model's slab term shrinks with the storage itemsize (while
    keeping its pre-slab_dtype positional signature)."""
    L, P, d = 4, 512, 64
    fp32 = S.lss_topk_slab_dma_bytes(L, P, d, "fp32")
    int8 = S.lss_topk_slab_dma_bytes(L, P, d, "int8")
    assert fp32 / int8 >= 3.0, (fp32, int8)
    assert S.lss_topk_slab_dma_bytes(L, P, d, "bf16") < fp32
    # VMEM estimate: int8 scratch (1B/elt + scale rows) < bf16 < fp32
    kw = dict(block_q=8, dedup="bitonic", kl=16)
    v = {s: lss_topk_vmem_bytes(L * P, d, P, slab_dtype=s, **kw)
         for s in S.SLAB_DTYPE_CHOICES}
    assert v["int8"] < v["bf16"] < v["fp32"]
    # legacy positional call (no slab_dtype) still works == fp32
    assert lss_topk_vmem_bytes(L * P, d, P, **kw) == v["fp32"]


def test_quantize_roundtrip_properties():
    """Rowwise int8: zero rows round-trip to exactly 0, values stay
    within one scale step, and bf16/fp32 return no scale table."""
    x = jnp.concatenate([jax.random.normal(jax.random.PRNGKey(0), (7, 9)),
                         jnp.zeros((1, 9))])
    q8, scale = S.quantize_slabs(x[None, None], "int8")
    deq = S.dequantize_slabs(q8, scale)
    assert np.all(np.asarray(deq[0, 0, -1]) == 0.0)
    err = np.abs(np.asarray(deq - x[None, None]))
    assert err.max() <= np.asarray(scale).max() / 2 + 1e-7
    for sdt in ("fp32", "bf16"):
        _, none_scale = S.quantize_slabs(x[None, None], sdt)
        assert none_scale is None
    with pytest.raises(ValueError):
        S.quantize_slabs(x[None, None], "fp64")
    with pytest.raises(ValueError):
        S.slab_dtype_of(x.astype(jnp.float16))
