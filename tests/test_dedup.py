"""Bitonic vs quadratic dedup: bit-identity past the old 2k-candidate
wall, adversarial duplicate/padding cases, the strategy knob, and the
query-blocked grid.

The acceptance bar: the bitonic sorting-network dedup is BIT-IDENTICAL
to the quadratic ref (same top logits, ids, sample counts, tie-breaks)
at C up to 16k in both the ref and pallas-interpret impls — including
all-duplicate candidate sets, interleaved cross-table duplicates,
non-power-of-two C, and top_k == C — and the blocked grid covers
ceil(B/Bq) steps with outputs equal at every B.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import registry
from repro.kernels.lss_topk import dedup as D
from repro.kernels.lss_topk.ops import (default_block_q, effective_block_q,
                                        grid_steps, lss_topk)

FIELDS = ("top_logits", "top_ids", "sample_size", "cand_ids")


@pytest.fixture(autouse=True)
def _clean_registry():
    registry.set_default_impl(None)
    registry.set_default_strategy("lss_topk.dedup", None)
    D.set_dedup_auto_threshold(None)
    registry.reset_dispatch_log()
    yield
    registry.set_default_impl(None)
    registry.set_default_strategy("lss_topk.dedup", None)
    D.set_dedup_auto_threshold(None)


def _case(c, b=4, d=16, n_tables=2, k_bits=2, seed=0, pool=None):
    """Synthetic bucket-major index with C = L*P candidates per query and
    a heavy duplicate rate (ids drawn from a pool of ~C/2)."""
    cap = c // n_tables
    assert cap * n_tables == c, (c, n_tables)
    n_buckets = 2 ** k_bits
    kt, kw, kq = jax.random.split(jax.random.PRNGKey(seed), 3)
    pool = pool or max(c // 2, 2)
    table_ids = jax.random.randint(kt, (n_tables, n_buckets, cap), -1,
                                   pool, jnp.int32)
    w_bucketed = jax.random.normal(kw, (n_tables, n_buckets, cap, d))
    theta = jax.random.normal(jax.random.PRNGKey(seed + 1),
                              (d, k_bits * n_tables))
    q = jax.random.normal(kq, (b, d), jnp.float32)
    return q, theta, table_ids, w_bucketed


def _assert_same(ref, out, msg=""):
    for name, r, o in zip(FIELDS, ref, out):
        np.testing.assert_array_equal(np.asarray(r), np.asarray(o),
                                      err_msg=f"{msg} {name}")


# ----------------------------------------------- large-C bit-identity --

@pytest.mark.parametrize("c", [512, 2048, 8192, 16384])
def test_ref_bitonic_matches_quadratic_bit_exact(c):
    """The sorting-network dedup is bit-identical to the quadratic mask
    on the jnp ref across the C sweep (heavy cross-table duplicates)."""
    b = 2 if c >= 8192 else 4
    args = _case(c, b=b)
    quad = lss_topk(*args, top_k=5, impl="ref", dedup="quadratic")
    bit = lss_topk(*args, top_k=5, impl="ref", dedup="bitonic")
    _assert_same(quad, bit, f"C={c}")


@pytest.mark.parametrize("c", [512, 2048, 8192, 16384])
def test_interpret_bitonic_matches_ref(c):
    """The fused kernel's in-VMEM bitonic dedup reproduces the quadratic
    ref bit-for-bit — the regime the 2k wall used to forbid."""
    b = 2 if c >= 8192 else 4
    args = _case(c, b=b, seed=c)
    ref = lss_topk(*args, top_k=5, impl="ref", dedup="quadratic")
    out = lss_topk(*args, top_k=5, impl="pallas_interpret", dedup="bitonic")
    _assert_same(ref, out, f"C={c}")


@pytest.mark.parametrize("c", [512, 2048])
def test_interpret_quadratic_matches_ref(c):
    """The original quadratic kernel path stays exact in its own (small
    C) regime after the query-blocking rewrite."""
    args = _case(c, seed=c + 1)
    ref = lss_topk(*args, top_k=5, impl="ref", dedup="quadratic")
    out = lss_topk(*args, top_k=5, impl="pallas_interpret",
                   dedup="quadratic")
    _assert_same(ref, out, f"C={c}")


# ------------------------------------------------- adversarial cases --

def test_all_duplicate_candidates_vs_topk_oracle():
    """Every slot of every table holds the SAME id: exactly one
    first-occurrence survives, and it matches the jax.lax.top_k oracle
    over the masked logits."""
    from repro.core.lss import NEG_INF, dedup_mask
    c, b, d = 256, 8, 16
    q, theta, table_ids, w_bucketed = _case(c, b=b, d=d, seed=3)
    table_ids = jnp.full_like(table_ids, 7)
    for impl in ("ref", "pallas_interpret"):
        for dd in ("quadratic", "bitonic"):
            tl, ti, sample, cand = lss_topk(
                q, theta, table_ids, w_bucketed, top_k=5, impl=impl,
                dedup=dd)
            np.testing.assert_array_equal(np.asarray(sample),
                                          np.ones(b, np.int32))
            np.testing.assert_array_equal(np.asarray(ti[:, 0]),
                                          np.full(b, 7, np.int32))
            np.testing.assert_array_equal(np.asarray(ti[:, 1:]),
                                          np.full((b, 4), -1, np.int32))
    # oracle: mask (first occurrence of each non-neg id) + lax.top_k
    ref = lss_topk(q, theta, table_ids, w_bucketed, top_k=5, impl="ref",
                   dedup="bitonic")
    cand = ref[3]
    slabs = w_bucketed.reshape(-1, c // 2, d)
    # recompute logits exactly as the ref does, then oracle-top-k them
    from repro.core import simhash
    from repro.kernels.bucket_logits.ref import bucket_logits_ref
    from repro.kernels.simhash_codes.ref import simhash_codes_ref
    buckets = simhash_codes_ref(simhash.unit(q), theta, 2, 2)
    slab_ids = buckets + jnp.arange(2, dtype=buckets.dtype)[None, :] * 4
    logits = bucket_logits_ref(q, slabs, slab_ids).reshape(b, -1)
    masked = jnp.where(dedup_mask(cand), logits, NEG_INF)
    otl, opos = jax.lax.top_k(masked, 5)
    oti = jnp.take_along_axis(cand, opos, axis=-1)
    oti = jnp.where(otl > NEG_INF / 2, oti, -1)
    np.testing.assert_array_equal(np.asarray(ref[0]), np.asarray(otl))
    np.testing.assert_array_equal(np.asarray(ref[1]), np.asarray(oti))


def test_interleaved_cross_table_duplicates():
    """Table 1 retrieves the SAME ids as table 0 but slot-reversed, so
    every duplicate pair straddles the table boundary with a different
    in-row position — the stable lower-index-wins tie-break is what the
    sorted dedup must preserve."""
    c, b = 128, 8
    q, theta, table_ids, w_bucketed = _case(c, b=b, seed=4)
    rev = table_ids[0, :, ::-1]
    table_ids = jnp.stack([table_ids[0], rev], axis=0)
    quad = lss_topk(q, theta, table_ids, w_bucketed, top_k=5,
                    impl="ref", dedup="quadratic")
    for impl, dd in (("ref", "bitonic"), ("pallas_interpret", "bitonic"),
                     ("pallas_interpret", "quadratic")):
        out = lss_topk(q, theta, table_ids, w_bucketed, top_k=5,
                       impl=impl, dedup=dd)
        _assert_same(quad, out, f"{impl}/{dd}")


@pytest.mark.parametrize("c,n_tables", [(24, 2), (120, 3), (1536, 2),
                                        (6144, 3)])
def test_c_not_power_of_two(c, n_tables):
    """Non-pow2 C exercises the bitonic pad-to-pow2 path: sentinel slots
    must never surface as candidates, samples, or top-k entries."""
    args = _case(c, b=4, n_tables=n_tables, seed=c)
    quad = lss_topk(*args, top_k=5, impl="ref", dedup="quadratic")
    for impl in ("ref", "pallas_interpret"):
        out = lss_topk(*args, top_k=5, impl=impl, dedup="bitonic")
        _assert_same(quad, out, f"{impl} C={c}")


def test_top_k_equals_c():
    """top_k == C forces the epilogue through every candidate slot,
    duplicates and -1 padding included."""
    c = 16
    args = _case(c, b=6, n_tables=2, k_bits=1, seed=9, pool=6)
    quad = lss_topk(*args, top_k=c, impl="ref", dedup="quadratic")
    for impl, dd in (("ref", "bitonic"), ("pallas_interpret", "bitonic"),
                     ("pallas_interpret", "quadratic")):
        out = lss_topk(*args, top_k=c, impl=impl, dedup=dd)
        _assert_same(quad, out, f"{impl}/{dd}")
    # beyond the unique count every id reads -1
    ti, sample = np.asarray(quad[1]), np.asarray(quad[2])
    for i in range(ti.shape[0]):
        assert (ti[i, sample[i]:] == -1).all()


def test_all_negative_candidates():
    """All-(-1) slabs: zero sample, all -1 ids, NEG_INF logits —
    identically across impls and strategies."""
    d = 8
    q = jax.random.normal(jax.random.PRNGKey(0), (5, d))
    theta = jax.random.normal(jax.random.PRNGKey(1), (d, 4))
    table_ids = jnp.full((2, 4, 32), -1, jnp.int32)
    w_bucketed = jnp.zeros((2, 4, 32, d))
    quad = lss_topk(q, theta, table_ids, w_bucketed, top_k=3,
                    impl="ref", dedup="quadratic")
    assert np.asarray(quad[2]).sum() == 0
    assert (np.asarray(quad[1]) == -1).all()
    for impl, dd in (("ref", "bitonic"), ("pallas_interpret", "bitonic"),
                     ("pallas_interpret", "quadratic")):
        out = lss_topk(q, theta, table_ids, w_bucketed, top_k=3,
                       impl=impl, dedup=dd)
        _assert_same(quad, out, f"{impl}/{dd}")


# ------------------------------------------------------ strategy knob --

def test_auto_select_switches_on_candidate_count():
    assert D.resolve_dedup(None, n_candidates=64) == "quadratic"
    assert D.resolve_dedup(None, n_candidates=D.dedup_auto_threshold()) \
        == "quadratic"
    assert D.resolve_dedup(None,
                           n_candidates=D.dedup_auto_threshold() + 1) \
        == "bitonic"
    assert D.resolve_dedup(None, n_candidates=4096) == "bitonic"


def test_auto_threshold_retunable():
    """The crossover is data, not a constant: the measured value from
    benchmarks.kernels_bench can be pinned at runtime."""
    D.set_dedup_auto_threshold(100)
    assert D.resolve_dedup(None, n_candidates=101) == "bitonic"
    assert D.resolve_dedup(None, n_candidates=99) == "quadratic"
    D.set_dedup_auto_threshold(None)
    assert D.resolve_dedup(None, n_candidates=101) == "quadratic"


def test_env_override(monkeypatch):
    monkeypatch.setenv(D.DEDUP_ENV_VAR, "bitonic")
    assert D.resolve_dedup(None, n_candidates=8) == "bitonic"
    # process-wide override beats the env var
    with registry.use_strategy("lss_topk.dedup", "quadratic"):
        assert D.resolve_dedup(None, n_candidates=10 ** 6) == "quadratic"
    monkeypatch.setenv(D.DEDUP_ENV_VAR, "mergesort")
    with pytest.raises(ValueError):
        D.resolve_dedup(None, n_candidates=8)


def test_explicit_choice_wins_and_is_validated():
    with registry.use_strategy("lss_topk.dedup", "bitonic"):
        assert D.resolve_dedup("quadratic", n_candidates=10 ** 6) \
            == "quadratic"
    with pytest.raises(ValueError):
        D.resolve_dedup("cuda", n_candidates=8)
    with pytest.raises(ValueError):
        registry.set_default_strategy("lss_topk.dedup", "cuda")
    with pytest.raises(KeyError):
        registry.get_strategy("definitely_not_a_strategy")


def test_strategy_resolution_logged():
    """The dispatch log proves which dedup actually served a call."""
    args = _case(24, b=2)
    registry.reset_dispatch_log()
    lss_topk(*args, top_k=3, impl="ref")                 # auto: quadratic
    assert ("lss_topk.dedup", "quadratic") in registry.dispatch_log()
    lss_topk(*args, top_k=3, impl="ref", dedup="bitonic")
    assert registry.last_dispatch("lss_topk.dedup") == "bitonic"


def test_engine_dedup_plumbing():
    """Engine(dedup=...) reaches the kernel: the strategy shows in the
    dispatch log and results stay bit-identical across strategies."""
    from repro.core.lss import LSSConfig
    from repro.serve.engine import Engine

    w = jax.random.normal(jax.random.PRNGKey(0), (512, 32))
    outs = {}
    for dd in ("quadratic", "bitonic"):
        eng = Engine(None, w, None,
                     LSSConfig(k_bits=4, n_tables=2, use_bucket_major=True),
                     top_k=5, head="lss", buckets=(8,), impl="ref",
                     dedup=dd)
        eng.fit_random(jax.random.PRNGKey(1))
        registry.reset_dispatch_log()
        q = np.asarray(jax.random.normal(jax.random.PRNGKey(7), (8, 32)))
        outs[dd] = eng.rank(q, record=False)
        assert registry.last_dispatch("lss_topk.dedup") == dd
    for name, a, b in zip(("logits", "ids", "sample", "cand"),
                          outs["quadratic"], outs["bitonic"]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)
    with pytest.raises(ValueError):
        Engine(None, w, dedup="cuda")


# -------------------------------------------------- query-blocked grid --

def test_grid_steps_reduced_by_block_q():
    bq = default_block_q()
    assert bq >= 2                      # MXU-shaped tiles by default
    assert grid_steps(32) == -(-32 // bq)
    assert grid_steps(32) * bq == 32    # Bq-fold fewer steps than B
    assert grid_steps(33) == grid_steps(32) + 1
    # small batches never pay for padded tile rows
    for b in (1, 2, 3):
        assert effective_block_q(b) == b
        assert grid_steps(b) == 1


def test_grid_steps_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_LSS_BLOCK_Q", "4")
    assert default_block_q() == 4
    assert grid_steps(32) == 8


@pytest.mark.parametrize("b", [1, 3, 7, 8, 9, 13, 16])
def test_blocked_grid_equal_outputs_any_b(b):
    """ceil(B/Bq) tiles with zero-padded tail rows produce outputs
    bit-identical to the ref at every B — padding never leaks."""
    args = _case(64, b=b, seed=b)
    ref = lss_topk(*args, top_k=5, impl="ref")
    out = lss_topk(*args, top_k=5, impl="pallas_interpret")
    _assert_same(ref, out, f"B={b}")
