"""Multi-host SPMD serving (subprocess fleets: REAL ``jax.distributed``
over gloo CPU collectives, 2 processes x 2 fake devices = 4 shards).

Covers the three multihost contracts:

  * the hierarchical multi-process predict is BIT-IDENTICAL (exact
    array equality, logits AND ids AND sample sizes) to the
    single-process ``make_sharded_predict`` flat merge at equal total m
    — with each process building ONLY its own shard_range, int8 slab +
    padded tail included;
  * the ``Engine._step`` SPMD seam: the leader's ``rank`` broadcasts
    through ``make_leader_step`` while followers replay in
    ``follower_loop``, and the leader's results equal the
    single-process Engine's exactly;
  * mirrored decode: ``leader_generate`` + OP_DECODE followers produce
    the same tokens as a single-process ``LMDecoder.generate``.

The single-process reference runs FIRST (its own subprocess, 4 fake
devices, no distributed runtime) and writes an npz oracle the fleet
workers compare against.
"""

import os
import socket
import subprocess
import sys

import pytest

# toy geometry shared by the oracle and the fleet: m=230 over 4 shards
# exercises the NEG_INF/-1 padded tail (m_local=58, last shard 56 rows)
_COMMON = r"""
import numpy as np
import jax, jax.numpy as jnp
from repro.core import simhash
from repro.core.lss import LSSConfig
from repro.serve.engine import Engine, LMDecoder
from repro.utils import compat

M, D, K, BATCH = 230, 16, 6, 8
CFG = LSSConfig(k_bits=3, n_tables=2, use_bucket_major=True,
                slab_dtype="int8")
W = jax.random.normal(jax.random.PRNGKey(0), (M, D), jnp.float32)
Q = np.asarray(jax.random.normal(jax.random.PRNGKey(2), (BATCH, D),
                                 jnp.float32))
THETA = simhash.init_hyperplanes(jax.random.PRNGKey(3), D + 1,
                                 CFG.k_bits, CFG.n_tables)
THETA2 = simhash.init_hyperplanes(jax.random.PRNGKey(11), D + 1,
                                  CFG.k_bits, CFG.n_tables)

from repro.models import transformer as T
LM_CFG = T.TransformerConfig(name="t", n_layers=1, d_model=16, n_heads=2,
                             n_kv_heads=2, head_dim=8, d_ff=32, vocab=64,
                             dtype=jnp.float32, kv_chunk=8)
LM_PARAMS = T.init_params(jax.random.PRNGKey(5), LM_CFG)
PROMPT = np.asarray(jax.random.randint(jax.random.PRNGKey(7), (2, 4),
                                       0, 64), np.int32)

def make_decoder(spmd=None):
    dec = LMDecoder(LM_PARAMS, LM_CFG, LSSConfig(k_bits=3, n_tables=2),
                    max_streams=2, max_len=12, spmd=spmd)
    dec.engine.fit_random(jax.random.PRNGKey(6))
    return dec

def make_engine(spmd=None, mesh=None):
    eng = Engine(None, W, None, CFG, top_k=K, head="lss-sharded",
                 buckets=(BATCH,), mesh=mesh, spmd=spmd)
    eng.fit_random(jax.random.PRNGKey(1))
    return eng
"""

_REF_SCRIPT = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
""" + _COMMON + r"""
from repro.core.sharded import make_sharded_predict
from repro.serve.heads import shard_index

w_aug = simhash.augment_neurons(W, None)
stack, w_stack, m_local = shard_index(w_aug, THETA, CFG, 4)
mesh = compat.make_mesh((4,), ("model",),
                        axis_types=compat.auto_axis_types(1))
fwd = make_sharded_predict(mesh, "model", CFG, m_local, K, with_aux=True)
logits, ids, sample = jax.jit(fwd)(Q, stack, w_stack)

eng = make_engine()                     # mesh=None -> all 4 local devices
out = eng.rank(Q)

# post-swap oracle: the refreshed index the fleet must agree on
from repro.core.lss import build_index
eng.swap_index(build_index(eng._w_aug, THETA2, CFG))
out_s = eng.rank(Q)

toks = make_decoder().generate(PROMPT, steps=4, head="lss-sharded")

np.savez(sys.argv[1],
         logits=np.asarray(logits), ids=np.asarray(ids),
         sample=np.asarray(sample),
         e_logits=np.asarray(out.logits), e_ids=np.asarray(out.ids),
         s_logits=np.asarray(out_s.logits), s_ids=np.asarray(out_s.ids),
         toks=np.asarray(toks))
print("REF-OK", flush=True)
"""

_WORKER_SCRIPT = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
ref_path, coord = sys.argv[1], sys.argv[2]
n_procs, pid = int(sys.argv[3]), int(sys.argv[4])
# the distributed runtime must come up before ANY jax computation (the
# _COMMON constants below run some), so init first thing
from repro.serve.multihost import (assemble_global_stack, follower_loop,
                                   init_multihost, leader_generate,
                                   stop_followers)
ctx = init_multihost(coord, n_procs, pid)
assert ctx is not None and ctx.n_shards == 4, ctx
""" + _COMMON + r"""
from repro.core.sharded import make_multihost_predict
from repro.serve.heads import shard_index

ref = np.load(ref_path)

# ---- 1. hierarchical predict == single-process flat merge, exactly ----
r0, r1 = ctx.row_range(M)
w_aug_local = simhash.augment_neurons(W[r0:r1], None)
local_stack, local_w, m_local = shard_index(
    w_aug_local, THETA, CFG, ctx.n_shards,
    shard_range=ctx.shard_range(), m_total=M)
stack = assemble_global_stack(ctx, local_stack, ctx.n_shards)
w_stack = assemble_global_stack(ctx, local_w, ctx.n_shards)
fwd = jax.jit(make_multihost_predict(ctx.mesh, ctx.host_axis,
                                     ctx.model_axis, CFG, m_local, K,
                                     with_aux=True))
qg = compat.broadcast_one_to_all(Q)
logits, ids, sample = fwd(qg, stack, w_stack)
np.testing.assert_array_equal(np.asarray(ids), ref["ids"])
np.testing.assert_array_equal(np.asarray(logits), ref["logits"])
np.testing.assert_array_equal(np.asarray(sample), ref["sample"])
print("MH-PREDICT-OK", flush=True)

# ---- 2. Engine._step seam: leader rank broadcasts, followers replay ---
eng = make_engine(spmd=ctx)
if ctx.is_leader:
    out = eng.rank(Q)
    np.testing.assert_array_equal(np.asarray(out.ids), ref["e_ids"])
    np.testing.assert_array_equal(np.asarray(out.logits), ref["e_logits"])
    out2 = eng.rank(Q)                  # cached wrapped step, same result
    np.testing.assert_array_equal(np.asarray(out2.ids), ref["e_ids"])
    print("MH-ENGINE-OK", flush=True)
else:
    n_ops = follower_loop(eng, ctx, max_ops=2)
    assert n_ops == 2, n_ops
    print("MH-FOLLOWER-OK", flush=True)

# ---- 2b. concurrent leader threads serialize on the opcode channel ----
# (the auditor-vs-dispatcher race: without ctx.lock the two threads'
# header+payload sequences interleave and the fleet desyncs/hangs)
if ctx.is_leader:
    import threading
    res = {}
    t = threading.Thread(target=lambda: res.update(
        full=eng.rank(Q, head="full", record=False)))
    t.start()
    out3 = eng.rank(Q, record=False)
    t.join(timeout=600)
    assert not t.is_alive(), "concurrent full-head rank hung"
    np.testing.assert_array_equal(np.asarray(out3.ids), ref["e_ids"])
    assert res["full"].ids.shape == (BATCH, K), res["full"].ids.shape
    print("MH-CONCURRENT-OK", flush=True)
else:
    n_ops = follower_loop(eng, ctx, max_ops=2)
    assert n_ops == 2, n_ops

# ---- 2c. fleet index swap: abort leaves both on the old epoch, ---------
# ---- commit flips both; leader crash window cannot split the fleet ----
from repro.core.lss import build_index
from repro.testing import faults
if ctx.is_leader:
    idx2 = build_index(eng._w_aug, THETA2, CFG)
    # leader "crashes" after broadcasting the candidate but before the
    # commit: the abort flag must keep BOTH processes on the old epoch
    try:
        with faults.injected(faults.MULTIHOST_SWAP_COMMIT,
                             RuntimeError("crash before commit")):
            eng.swap_index(idx2)
        raise SystemExit("aborted swap should have raised")
    except RuntimeError:
        pass
    assert eng.index_epoch == 1, eng.index_epoch
    out4 = eng.rank(Q, record=False)
    np.testing.assert_array_equal(np.asarray(out4.ids), ref["e_ids"])
    e2 = eng.swap_index(idx2)           # now commit for real
    assert eng.index_epoch == e2 == 2, (eng.index_epoch, e2)
    out5 = eng.rank(Q, record=False)
    np.testing.assert_array_equal(np.asarray(out5.ids), ref["s_ids"])
    np.testing.assert_array_equal(np.asarray(out5.logits),
                                  ref["s_logits"])
    print("MH-SWAP-OK", flush=True)
else:
    # ops: aborted swap, rank, committed swap, rank
    n_ops = follower_loop(eng, ctx, max_ops=4)
    assert n_ops == 4, n_ops
    assert eng.index_epoch == 2, eng.index_epoch

# ---- 3. mirrored decode: leader_generate == single-process generate ---
dec = make_decoder(spmd=ctx)
if ctx.is_leader:
    toks = leader_generate(ctx, dec, PROMPT, steps=4, head="lss-sharded")
    np.testing.assert_array_equal(np.asarray(toks), ref["toks"])
    stop_followers(ctx)
    print("MH-DECODE-OK", flush=True)
else:
    n_ops = follower_loop(eng, ctx, decoder=dec)
    assert n_ops == 1, n_ops
print("MH-ALL-OK", flush=True)
"""


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    env.setdefault("JAX_PLATFORMS", "cpu")
    # the fleet must not inherit a stray REPRO_DIST_* fleet config
    for k in ("REPRO_DIST_COORDINATOR", "REPRO_DIST_NUM_PROCESSES",
              "REPRO_DIST_PROCESS_ID"):
        env.pop(k, None)
    return env


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


@pytest.mark.multihost
@pytest.mark.slow
def test_multihost_fleet_matches_single_process(tmp_path):
    ref_npz = str(tmp_path / "ref.npz")
    env = _env()
    ref = subprocess.run([sys.executable, "-c", _REF_SCRIPT, ref_npz],
                         env=env, capture_output=True, text=True,
                         timeout=1200)
    assert ref.returncode == 0 and "REF-OK" in ref.stdout, \
        ref.stdout + "\n" + ref.stderr

    coord = f"127.0.0.1:{_free_port()}"
    procs = [subprocess.Popen(
        [sys.executable, "-c", _WORKER_SCRIPT, ref_npz, coord, "2", str(i)],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True) for i in range(2)]
    outs = [p.communicate(timeout=1200)[0] for p in procs]
    for i, p in enumerate(procs):
        assert p.returncode == 0, f"worker {i}:\n{outs[i]}"
        assert "MH-PREDICT-OK" in outs[i], outs[i][-3000:]
        assert "MH-ALL-OK" in outs[i], outs[i][-3000:]
    assert "MH-ENGINE-OK" in outs[0] and "MH-DECODE-OK" in outs[0]
    assert "MH-CONCURRENT-OK" in outs[0]
    assert "MH-SWAP-OK" in outs[0]
    assert "MH-FOLLOWER-OK" in outs[1]
