"""Property-style coverage of the ``heads.shard_index`` padding path.

When ``m % n_shards != 0`` the WOL rows are padded up to the next
multiple and the final shard's tables are masked.  Across a sweep of
(m, n_shards) — hypothesis when installed, the deterministic stub sweep
otherwise — the invariants are:

  * padded (marker) rows never enter any shard's hash tables, so they
    can never be retrieved;
  * they never surface in any shard's top-k (ids stay local AND < that
    shard's real-row count), hence never in the merged global top-k
    either — on the ref path and on the fused interpret-mode kernel
    alike;
  * the shard-local ranking over real rows equals brute force, i.e.
    masking removed the padding WITHOUT disturbing real candidates.

Also covers the fused kernel's QUERY-tile padding (the other padding
axis): B not divisible by the query-block height pads with rows that
never reach any real query's top-k — interpret == ref at every B.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import simhash
from repro.core.lss import LSSConfig, retrieve
from repro.core.sharded import local_topk
from repro.serve.heads import shard_index

D = 8
TOP_K = 3
N_QUERIES = 6


def _build(m: int, n_shards: int):
    cfg = LSSConfig(k_bits=3, n_tables=2, use_bucket_major=True)
    w = jax.random.normal(jax.random.PRNGKey(m * 7 + n_shards), (m, D))
    w_aug = simhash.augment_neurons(w, None)
    theta = simhash.init_hyperplanes(jax.random.PRNGKey(1), D + 1,
                                     cfg.k_bits, cfg.n_tables)
    stack, w_stack, m_local = shard_index(w_aug, theta, cfg, n_shards)
    q = jax.random.normal(jax.random.PRNGKey(2), (N_QUERIES, D))
    return cfg, w_aug, stack, m_local, q


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=3, max_value=40),
       st.integers(min_value=2, max_value=4))
def test_shard_index_padding_invariants(m, n_shards):
    cfg, w_aug, stack, m_local, q = _build(m, n_shards)
    q_aug = np.asarray(simhash.augment_queries(q))
    w_np = np.asarray(w_aug)
    merged: list[list[tuple]] = [[] for _ in range(N_QUERIES)]
    for s in range(n_shards):
        idx = jax.tree.map(lambda x, s=s: x[s], stack)
        n_valid = min(max(m - s * m_local, 0), m_local)
        # 1. marker rows are absent from the tables entirely
        ids_tab = np.asarray(idx.tables.table_ids)
        assert ids_tab.max(initial=-1) < max(n_valid, 1)
        assert ((ids_tab >= 0) | (ids_tab == -1)).all()
        # ...and their slab rows are zeroed
        wb = np.asarray(idx.w_bucketed)
        assert (wb[ids_tab < 0] == 0).all()
        # 2. retrieval can never produce a padded id
        cand, _ = retrieve(jnp.asarray(q_aug), idx)
        cand = np.asarray(cand)
        assert cand.max(initial=-1) < max(n_valid, 1)
        # 3. shard-local top-k == brute force over the REAL rows
        logits, top_i = local_topk(q, idx, None, TOP_K)
        top_i = np.asarray(top_i)
        logits = np.asarray(logits)
        assert top_i.max(initial=-1) < max(n_valid, 1), \
            "padding row surfaced in top-k"
        full = q_aug @ w_np[s * m_local:s * m_local + n_valid].T \
            if n_valid else np.zeros((N_QUERIES, 0))
        for i in range(N_QUERIES):
            uniq = sorted({int(x) for x in cand[i] if x >= 0},
                          key=lambda j: -full[i, j])
            got = [int(x) for x in top_i[i] if x >= 0]
            assert got == uniq[:len(got)]
            assert len(got) == min(TOP_K, len(uniq))
            for r, j in enumerate(got):        # merged-view bookkeeping
                merged[i].append((float(logits[i, r]),
                                  s * m_local + j))
    # 4. the cross-shard merge (what make_sharded_lss_head's all-gather
    # + global top-k computes) contains only REAL global ids
    for i in range(N_QUERIES):
        top = sorted(merged[i], reverse=True)[:TOP_K]
        assert all(0 <= gid < m for _, gid in top)


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=1, max_value=19))
def test_query_block_padding_parity(b):
    """The fused kernel's query-blocked grid pads B up to the tile
    multiple with rows that can never reach a real query's top-k: at
    every B — divisible by the tile height or not — interpret-mode
    outputs are bit-identical to the ref across all LSSForward fields,
    and the planned grid covers exactly ceil(B / Bq) tiles (the
    query-tile analogue of shard_index's marker-row invariants)."""
    from repro.core.lss import lss_forward
    from repro.kernels.lss_topk.ops import effective_block_q, grid_steps

    cfg = LSSConfig(k_bits=3, n_tables=2, use_bucket_major=True)
    w = jax.random.normal(jax.random.PRNGKey(b * 11 + 1), (40, D))
    w_aug = simhash.augment_neurons(w, None)
    theta = simhash.init_hyperplanes(jax.random.PRNGKey(1), D + 1,
                                     cfg.k_bits, cfg.n_tables)
    from repro.core.lss import build_index
    index = build_index(w_aug, theta, cfg)
    q = jax.random.normal(jax.random.PRNGKey(b), (b, D))
    ref = lss_forward(q, index, None, top_k=TOP_K, impl="ref")
    out = lss_forward(q, index, None, top_k=TOP_K,
                      impl="pallas_interpret")
    for name, r, o in zip(ref._fields, ref, out):
        np.testing.assert_array_equal(np.asarray(r), np.asarray(o),
                                      err_msg=f"B={b} {name}")
        assert np.asarray(o).shape[0] == b       # padding sliced off
    bq = effective_block_q(b)
    assert grid_steps(b) == -(-b // bq)


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=3, max_value=40),
       st.integers(min_value=2, max_value=4))
def test_shard_index_padding_masks_w_scale(m, n_shards):
    """int8 slab: the padded tail's ``w_scale`` rows are zeroed exactly
    like the marker weight rows.  The pad rows carry a NEG_INF sentinel
    bias column, so quantizing them would otherwise bake a garbage
    (inf-derived) scale into the slab — the mask keeps every marker
    slot's (weight, scale) pair identically zero, and the padded shard
    still ranks bit-identically on the ref and fused interpret paths."""
    cfg = LSSConfig(k_bits=3, n_tables=2, use_bucket_major=True,
                    slab_dtype="int8")
    w = jax.random.normal(jax.random.PRNGKey(m * 13 + n_shards), (m, D))
    w_aug = simhash.augment_neurons(w, None)
    theta = simhash.init_hyperplanes(jax.random.PRNGKey(1), D + 1,
                                     cfg.k_bits, cfg.n_tables)
    stack, _, m_local = shard_index(w_aug, theta, cfg, n_shards)
    for s in range(n_shards):
        idx = jax.tree.map(lambda x, s=s: x[s], stack)
        ids_tab = np.asarray(idx.tables.table_ids)
        ws = np.asarray(idx.w_scale)
        assert ws.shape == ids_tab.shape
        # real slots keep a usable (finite) scale everywhere
        assert np.isfinite(ws[ids_tab >= 0]).all()
    if m % n_shards:                       # the tail shard got masked:
        last = jax.tree.map(lambda x: x[-1], stack)
        ids_tab = np.asarray(last.tables.table_ids)
        ws = np.asarray(last.w_scale)
        # EVERY empty slot's scale is zeroed exactly like the weight
        # rows (no NEG_INF-derived garbage survives the mask)
        assert (ws[ids_tab < 0] == 0).all()
        assert (np.asarray(last.w_bucketed)[ids_tab < 0] == 0).all()
    q = jax.random.normal(jax.random.PRNGKey(2), (N_QUERIES, D))
    last = jax.tree.map(lambda x: x[-1], stack)      # the padded shard
    ref_l, ref_i = local_topk(q, last, None, TOP_K, impl="ref")
    out_l, out_i = local_topk(q, last, None, TOP_K,
                              impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(ref_i), np.asarray(out_i))
    np.testing.assert_array_equal(np.asarray(ref_l), np.asarray(out_l))


@settings(max_examples=4, deadline=None)
@given(st.integers(min_value=5, max_value=23),
       st.integers(min_value=2, max_value=3))
def test_shard_index_padding_fused_kernel_parity(m, n_shards):
    """The invariants hold identically through the fused interpret-mode
    kernel: padded shards rank exactly like the ref path."""
    cfg, _, stack, m_local, q = _build(m, n_shards)
    last = jax.tree.map(lambda x: x[-1], stack)    # the padded shard
    ref_l, ref_i = local_topk(q, last, None, TOP_K, impl="ref")
    out_l, out_i = local_topk(q, last, None, TOP_K,
                              impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(ref_i), np.asarray(out_i))
    np.testing.assert_array_equal(np.asarray(ref_l), np.asarray(out_l))
    n_valid = min(max(m - (n_shards - 1) * m_local, 0), m_local)
    assert np.asarray(out_i).max(initial=-1) < max(n_valid, 1)
