"""Continuous-batching streaming decode: TokenStream semantics, the
slot-based KV pool, token-exactness of interleaved vs blocking decode
(full AND lss heads, sessions joining/leaving mid-flight), single-compile
regression via the kernel-registry dispatch log, and the AsyncRuntime
decode request kind (admission control, deadlines, mixed traffic)."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lss import LSSConfig
from repro.data.synthetic import lm_dataset
from repro.kernels import registry
from repro.models import transformer as T
from repro.serve import (AsyncRuntime, DeadlineExceededError, KVCachePool,
                         LMDecoder, QueueFullError, RuntimeClosedError,
                         TokenStream)

VOCAB = 512
PROMPT_LEN = 6
MAX_LEN = 24          # prompt + the longest max_new_tokens any test uses


@pytest.fixture(scope="module")
def lm():
    cfg = T.TransformerConfig(name="t", n_layers=2, d_model=32, n_heads=2,
                              n_kv_heads=2, head_dim=16, d_ff=64,
                              vocab=VOCAB, dtype=jnp.float32, kv_chunk=32)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = np.asarray(lm_dataset(0, 64 * 33, VOCAB, 33))
    return params, cfg, toks


@pytest.fixture(scope="module")
def decoder(lm):
    """One decoder (and thus ONE fused step per head) shared by the whole
    module — itself an implicit single-compile regression."""
    params, cfg, toks = lm
    dec = LMDecoder(params, cfg, LSSConfig(k_bits=4, n_tables=2),
                    max_streams=3, max_len=MAX_LEN)
    dec.engine.fit_random(jax.random.PRNGKey(1))
    return dec


# ------------------------------------------------------------ TokenStream --

def test_token_stream_append_get_iter_result():
    st = TokenStream(0)
    st.append(5), st.append(7)
    assert len(st) == 2 and st.get(0) == 5 and st.get(1) == 7
    assert not st.done()
    st.append(9)
    st.finish("max_tokens")
    assert st.done() and st.finish_reason == "max_tokens"
    assert list(st) == [5, 7, 9]
    np.testing.assert_array_equal(st.result(), [5, 7, 9])
    assert st.exception() is None
    with pytest.raises(IndexError):
        st.get(3)


def test_token_stream_fail_reraises_after_tokens():
    st = TokenStream(1)
    st.append(3)
    st.fail(RuntimeError("boom"))
    assert st.finish_reason == "error"
    assert isinstance(st.exception(), RuntimeError)
    it = iter(st)
    assert next(it) == 3
    with pytest.raises(RuntimeError):
        next(it)
    with pytest.raises(RuntimeError):
        st.result()


def test_token_stream_timeouts_and_timing():
    st = TokenStream(2, t_submit=time.perf_counter())
    with pytest.raises(TimeoutError):
        st.get(0, timeout=0.01)
    with pytest.raises(TimeoutError):
        st.result(timeout=0.01)
    assert st.ttft_s() is None
    st.append(1)
    assert st.ttft_s() >= 0
    st.append(2)
    assert st.inter_token_s().shape == (1,)


# -------------------------------------------------------------- KV pool --

def test_kv_pool_alloc_free_and_validation(lm):
    _, cfg, _ = lm
    pool = KVCachePool(cfg, max_streams=2, max_len=8)
    assert pool.k.shape == (cfg.n_layers, 2, 8, cfg.n_kv_heads,
                            cfg.head_dim)
    a, b = pool.alloc(), pool.alloc()
    assert {a, b} == {0, 1} and pool.alloc() is None
    assert pool.n_active == 2 and pool.n_free == 0
    pool.lengths[a] = 5
    pool.free(a)
    assert pool.lengths[a] == 0 and pool.n_free == 1
    assert pool.alloc() == a
    with pytest.raises(ValueError):
        KVCachePool(cfg, max_streams=0, max_len=8)


# --------------------------------------------- interleaved == blocking --

@pytest.mark.parametrize("head", ["full", "lss"])
def test_interleaved_exact_vs_sequential_generate(decoder, lm, head):
    """N greedy sessions with STAGGERED lengths through the scheduler —
    sessions leave as their budgets run out and queued sessions join the
    freed slots mid-flight (5 sessions, 3 slots) — must produce
    bit-identical tokens to one-at-a-time blocking generate calls."""
    _, _, toks = lm
    budgets = [3, 6, 9, 4, 12]
    seq = [np.asarray(decoder.generate(
        jnp.asarray(toks[i:i + 1, :PROMPT_LEN]), steps=budgets[i],
        head=head))[0] for i in range(5)]

    sched = decoder.scheduler(head=head)
    streams = [sched.submit(toks[i, :PROMPT_LEN], max_new_tokens=budgets[i])
               for i in range(5)]
    sched.run(timeout=120.0)
    for i, st in enumerate(streams):
        assert st.finish_reason == "max_tokens"
        np.testing.assert_array_equal(st.result(), seq[i],
                                      err_msg=f"session {i} head {head}")
    # the fused step shape never changed: exactly one trace, ever
    assert decoder.engine.compile_counts[(head, "decode[3x24]@t")] == 1


def test_eos_stops_stream_early_and_frees_slot(decoder, lm):
    """Pick an eos that demonstrably occurs mid-sequence, re-run with it
    set: the stream must stop AT the eos token, report reason 'eos', and
    the freed slot must be reusable (a queued session completes)."""
    _, _, toks = lm
    ref = np.asarray(decoder.generate(
        jnp.asarray(toks[7:8, :PROMPT_LEN]), steps=10, head="full"))[0]
    eos = int(ref[4])
    cut = int(np.argmax(ref == eos)) + 1     # first occurrence, inclusive
    sched = decoder.scheduler(head="full")
    # fill all 3 slots + 1 queued: the eos'd session's slot must free
    streams = [sched.submit(toks[7, :PROMPT_LEN], max_new_tokens=10,
                            eos_id=eos)]
    streams += [sched.submit(toks[20 + i, :PROMPT_LEN], max_new_tokens=4)
                for i in range(3)]
    sched.run(timeout=120.0)
    assert streams[0].finish_reason == "eos"
    np.testing.assert_array_equal(streams[0].result(), ref[:cut])
    for st in streams[1:]:
        assert st.finish_reason == "max_tokens" and len(st) == 4
    assert sched.pool.n_free == sched.max_streams


# -------------------------------------------- single-compile regression --

def test_one_compiled_decode_step_across_sessions_and_generate_calls(lm):
    """The scheduler and every generate() call must share ONE compiled
    fused decode step per head: after warmup, neither new sessions nor
    new generate() calls may re-trace — asserted through the kernel
    registry's trace-time dispatch log (the lss head's ops only record
    on compilation) AND the engine's compile counters."""
    params, cfg, toks = lm
    dec = LMDecoder(params, cfg, LSSConfig(k_bits=4, n_tables=2),
                    max_streams=2, max_len=16)
    dec.engine.fit_random(jax.random.PRNGKey(3))
    dec.generate(jnp.asarray(toks[:1, :PROMPT_LEN]), steps=3,
                 head="lss")                         # warmup: traces land
    warm_counts = registry.dispatch_counts()
    assert any(op == "lss_topk" for op, _ in warm_counts)

    for i in range(3):                               # more generate calls
        dec.generate(jnp.asarray(toks[i:i + 1, :PROMPT_LEN]), steps=4,
                     head="lss")
    sched = dec.scheduler(head="lss")                # + interleaved load
    streams = [sched.submit(toks[i, :PROMPT_LEN], max_new_tokens=3 + i)
               for i in range(5)]
    sched.run(timeout=120.0)
    assert all(st.finish_reason == "max_tokens" for st in streams)

    assert registry.dispatch_counts() == warm_counts, \
        "head ops re-traced after warmup"
    decode_keys = [k for k in dec.engine.compile_counts
                   if isinstance(k[1], str) and k[1].startswith("decode")]
    assert decode_keys == [("lss", "decode[2x16]@t")]
    assert all(v == 1 for v in dec.engine.compile_counts.values()), \
        dec.engine.compile_counts


# ------------------------------------------------- runtime integration --

def test_runtime_decode_matches_blocking_and_streams_tokens(decoder, lm):
    _, _, toks = lm
    budgets = [4, 7, 5, 8]
    seq = [np.asarray(decoder.generate(
        jnp.asarray(toks[i:i + 1, :PROMPT_LEN]), steps=budgets[i],
        head="lss"))[0] for i in range(4)]
    sched = decoder.scheduler(head="lss")
    sched.reset_stats()
    with AsyncRuntime(decoder.engine, head="lss", scheduler=sched) as rt:
        streams = [rt.submit_decode(toks[i, :PROMPT_LEN],
                                    max_new_tokens=budgets[i])
                   for i in range(4)]
        # mixed traffic: rank requests on the same engine while decoding
        futs = [rt.submit(np.zeros(32, np.float32)) for _ in range(3)]
        first = list(streams[0])                   # live iteration
        rt.drain(timeout=120.0)
        s = rt.stats()
    assert first == list(seq[0])
    for i, st in enumerate(streams):
        np.testing.assert_array_equal(st.result(), seq[i])
    assert all(f.exception() is None for f in futs)
    assert s.n_decode_sessions == s.n_decode_done == 4
    assert s.n_decode_tokens == sum(budgets)
    assert s.ttft_p50_ms > 0 and s.itl_p50_ms >= 0
    assert s.ttft_p50_ms <= s.ttft_p95_ms <= s.ttft_p99_ms
    assert 0 < s.decode_slot_occupancy <= 1.0
    assert s.decode_tokens_per_s > 0
    assert s.n_completed == 3                      # the rank side


def test_generate_while_runtime_serves_same_scheduler(decoder, lm):
    """A blocking generate() racing an AsyncRuntime that owns the same
    scheduler must stay token-exact (ticks serialize) and must not
    perturb the runtime's session accounting (drain would otherwise
    return early)."""
    _, _, toks = lm
    ref_rt = np.asarray(decoder.generate(
        jnp.asarray(toks[0:1, :PROMPT_LEN]), steps=10, head="full"))[0]
    ref_gen = np.asarray(decoder.generate(
        jnp.asarray(toks[1:2, :PROMPT_LEN]), steps=6, head="full"))[0]
    sched = decoder.scheduler(head="full")
    with AsyncRuntime(decoder.engine, scheduler=sched) as rt:
        st = rt.submit_decode(toks[0, :PROMPT_LEN], max_new_tokens=10)
        out = decoder.generate(jnp.asarray(toks[1:2, :PROMPT_LEN]),
                               steps=6, head="full")   # concurrent ticks
        rt.drain(timeout=120.0)
        s = rt.stats()
    np.testing.assert_array_equal(st.result(), ref_rt)
    np.testing.assert_array_equal(np.asarray(out)[0], ref_gen)
    assert s.n_decode_sessions == s.n_decode_done == 1


def test_runtime_decode_deadline_shed(decoder, lm):
    _, _, toks = lm
    sched = decoder.scheduler(head="full")
    rt = AsyncRuntime(decoder.engine, scheduler=sched, start=False)
    late = rt.submit_decode(toks[0, :PROMPT_LEN], max_new_tokens=4,
                            deadline_s=0.01)
    ok = rt.submit_decode(toks[1, :PROMPT_LEN], max_new_tokens=4)
    time.sleep(0.05)                               # 'late' is now late
    rt.start()
    rt.drain(timeout=120.0)
    s = rt.stats()
    rt.close()
    with pytest.raises(DeadlineExceededError):
        late.result(timeout=5.0)
    assert len(ok.result(timeout=5.0)) == 4
    assert s.n_shed_deadline == 1 and s.n_decode_done == 2


def test_runtime_decode_queue_capacity_shed(decoder, lm):
    _, _, toks = lm
    sched = decoder.scheduler(head="full")
    rt = AsyncRuntime(decoder.engine, scheduler=sched, max_queue=2,
                      policy="shed", start=False)
    streams = [rt.submit_decode(toks[i, :PROMPT_LEN], max_new_tokens=3)
               for i in range(5)]
    shed = [st for st in streams if st.done()]
    assert len(shed) == 3                          # queue bound of 2 held
    for st in shed:
        with pytest.raises(QueueFullError):
            st.result()
    assert rt.stats().n_shed_queue == 3
    rt.start()
    rt.drain(timeout=120.0)
    s = rt.stats()
    assert s.n_decode_sessions == 5 and s.n_decode_done == 2
    assert sum(st.finish_reason == "max_tokens" for st in streams) == 2
    rt.close()


def test_runtime_close_fails_pending_decode(decoder, lm):
    _, _, toks = lm
    sched = decoder.scheduler(head="full")
    rt = AsyncRuntime(decoder.engine, scheduler=sched, start=False)
    st = rt.submit_decode(toks[0, :PROMPT_LEN], max_new_tokens=4)
    rt.close()
    with pytest.raises(RuntimeClosedError):
        st.result(timeout=5.0)
    with pytest.raises(RuntimeClosedError):
        rt.submit_decode(toks[1, :PROMPT_LEN], max_new_tokens=4) \
          .result(timeout=5.0)


def test_session_validation(decoder, lm):
    _, _, toks = lm
    sched = decoder.scheduler(head="full")
    with pytest.raises(ValueError):                # exceeds pool width
        sched.submit(toks[0, :PROMPT_LEN], max_new_tokens=MAX_LEN)
    with pytest.raises(ValueError):                # 2-D prompt
        sched.submit(toks[:2, :PROMPT_LEN], max_new_tokens=2)
    with pytest.raises(ValueError):                # empty budget
        sched.submit(toks[0, :PROMPT_LEN], max_new_tokens=0)
    rt = AsyncRuntime(decoder.engine, start=False)  # no scheduler attached
    with pytest.raises(RuntimeError):
        rt.submit_decode(toks[0, :PROMPT_LEN], max_new_tokens=2)
    rt.close()
