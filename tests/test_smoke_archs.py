"""Deliverable (f): one REDUCED-config smoke per assigned architecture —
a forward/train step on CPU asserting output shapes + no NaNs."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs.reduced import reduced_model_cfg
from repro.configs.registry import ALL_ARCHS
from repro.models import gnn, recsys
from repro.models import transformer as T

LM_ARCHS = ["arctic-480b", "qwen2-moe-a2.7b", "qwen2-0.5b", "qwen2-7b",
            "qwen3-4b"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_arch_smoke(arch):
    cfg = reduced_model_cfg(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab)
    loss, grads = jax.value_and_grad(T.lm_loss)(
        params, {"tokens": tokens, "labels": tokens}, cfg)
    assert jnp.isfinite(loss), arch
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))
    # serve path: prefill + one decode step + head
    hidden, cache = T.prefill(params, tokens, cfg, max_len=32)
    assert hidden.shape == (2, 24, cfg.d_model)
    h, cache = T.decode_step(params, tokens[:, 0], cache, cfg)
    logits = T.logits_head(params, h[:, None], cfg)
    assert logits.shape == (2, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_gcn_cora_smoke():
    cfg = reduced_model_cfg("gcn-cora")
    params = gnn.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (50, cfg.d_feat))
    edges = jax.random.randint(jax.random.PRNGKey(2), (120, 2), 0, 50)
    labels = jnp.where(jnp.arange(50) % 2 == 0,
                       jnp.arange(50) % cfg.n_classes, -1)
    loss, grads = jax.value_and_grad(gnn.loss)(
        params, {"x": x, "edges": edges, "labels": labels}, cfg)
    assert jnp.isfinite(loss)
    out = gnn.forward(params, x, edges, cfg)
    assert out.shape == (50, cfg.n_classes)
    assert bool(jnp.isfinite(out).all())


@pytest.mark.parametrize("arch", ["deepfm", "autoint"])
def test_ctr_arch_smoke(arch):
    cfg = reduced_model_cfg(arch)
    init = {"deepfm": recsys.init_deepfm, "autoint": recsys.init_autoint}
    logit_fn = {"deepfm": recsys.deepfm_logits,
                "autoint": recsys.autoint_logits}
    params = init[arch](jax.random.PRNGKey(0), cfg)
    ids = jax.random.randint(jax.random.PRNGKey(1), (16, cfg.n_fields),
                             0, cfg.vocab_per_field)
    y = jax.random.bernoulli(jax.random.PRNGKey(2), 0.3, (16,))

    def loss_fn(p):
        lg = logit_fn[arch](p, ids, cfg)
        return jnp.mean(jnp.maximum(lg, 0) - lg * y
                        + jnp.log1p(jnp.exp(-jnp.abs(lg))))

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert jnp.isfinite(loss), arch
    lg = logit_fn[arch](params, ids, cfg)
    assert lg.shape == (16,) and bool(jnp.isfinite(lg).all())


def test_dien_smoke():
    cfg = reduced_model_cfg("dien")
    params = recsys.init_dien(jax.random.PRNGKey(0), cfg)
    hist = jax.random.randint(jax.random.PRNGKey(1), (8, cfg.seq_len),
                              -1, cfg.vocab_per_field)
    target = jax.random.randint(jax.random.PRNGKey(2), (8,), 0,
                                cfg.vocab_per_field)
    lg = recsys.dien_logits(params, {"hist": hist, "target": target}, cfg)
    assert lg.shape == (8,) and bool(jnp.isfinite(lg).all())


def test_bert4rec_smoke():
    cfg = reduced_model_cfg("bert4rec")
    params = recsys.init_bert4rec(jax.random.PRNGKey(0), cfg)
    seq = jax.random.randint(jax.random.PRNGKey(1), (4, cfg.seq_len),
                             0, cfg.n_items)
    labels = jnp.where(jax.random.bernoulli(jax.random.PRNGKey(2), 0.2,
                                            seq.shape), seq, -1)
    loss, grads = jax.value_and_grad(recsys.bert4rec_loss)(
        params, {"seq": seq, "labels": labels}, cfg)
    assert jnp.isfinite(loss)
    hid = recsys.bert4rec_encode(params, seq, cfg)
    scores = recsys.retrieval_scores(params, hid[:, -1])
    assert scores.shape == (4, cfg.n_items)
    assert bool(jnp.isfinite(scores).all())


def test_all_archs_covered():
    covered = set(LM_ARCHS) | {"gcn-cora", "deepfm", "autoint", "dien",
                               "bert4rec"}
    assert covered == set(ALL_ARCHS)
