"""Zero-downtime online index refresh: the IndexRefresher's
refit -> guarded swap -> probation cycle through the fault-injection
harness (fail / slow / corrupt-recall), automatic rollback asserted on
the ``lss_refresh_rollback_total`` counter and ``lss_audit_recall_at_k``
gauge, bit-identical serving vs cold-built engines across a swap,
index-epoch pinning for in-flight decode sessions (directed AND a
hypothesis property over interleaved swap/join/leave/rank sequences),
the refit-off-the-lock regression (satellite: a concurrent ``rank`` is
never blocked by a slow refit), bounded AsyncRuntime close on a wedged
dispatcher, and /metrics port release."""

import socket
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import obs
from repro.core.lss import LSSConfig
from repro.data.synthetic import lm_dataset
from repro.models import transformer as T
from repro.obs.export import MetricsServer, prometheus_text
from repro.serve import AsyncRuntime, Engine, LMDecoder
from repro.serve.refresh import IndexRefresher, RefreshConfig
from repro.testing import faults
from tools.check_metrics import parse_exposition

M, D = 512, 32
LSS = LSSConfig(k_bits=4, n_tables=2)


@pytest.fixture(autouse=True)
def _fault_hygiene():
    faults.reset()
    yield
    faults.reset()


def _engine(audit_rate=None, key=0):
    w = jax.random.normal(jax.random.PRNGKey(key), (M, D))
    return Engine(None, w, None, LSS, top_k=5, head="lss", buckets=(8,),
                  audit_rate=audit_rate)


def _fitted(audit_rate=None):
    eng = _engine(audit_rate=audit_rate)
    q = jax.random.normal(jax.random.PRNGKey(2), (256, D))
    labels = jnp.asarray(np.asarray(
        jax.random.randint(jax.random.PRNGKey(3), (256, 3), 0, M),
        np.int32))
    eng.fit_from_queries(jax.random.PRNGKey(1), q, labels)
    return eng, np.asarray(q, np.float32)


def _sample(family, families):
    fam = families.get(family)
    assert fam is not None, f"{family} missing from exposition"
    return fam["samples"][0][2]


# ------------------------------------------------------------- lifecycle --

def test_refresh_swaps_and_matches_cold_built_engine():
    """A refresh cycle must swap in a genuinely retrained index, and
    serving through the swapped engine must be bit-identical to a COLD
    engine built directly on that index (acceptance criterion)."""
    eng, q = _fitted()
    idx_before = eng.index
    r = IndexRefresher(eng, auditor=None, cfg=RefreshConfig())
    assert r.refresh_once() == "swapped"
    assert eng.index_epoch == 2
    assert eng.index is not idx_before
    cold = _engine()
    cold._set_index(eng.index)
    hot_out, cold_out = eng.rank(q[:8], record=False), cold.rank(q[:8])
    np.testing.assert_array_equal(np.asarray(hot_out.logits),
                                  np.asarray(cold_out.logits))
    np.testing.assert_array_equal(np.asarray(hot_out.ids),
                                  np.asarray(cold_out.ids))
    # a second cycle continues the same training stream
    assert r.refresh_once() == "swapped"
    assert eng.index_epoch == 3 and r.n_refreshes == 2


def test_swap_drops_unpinned_and_keeps_pinned_epochs():
    eng, q = _fitted()
    e1 = eng.pin_epoch()
    idx1 = eng.index
    eng.swap_index(eng.index_for(e1))       # new epoch from same index
    assert eng.index_epoch == 2 and e1 in eng._epochs
    assert eng.index_for(e1) is idx1        # pinned epoch still readable
    eng.unpin_epoch(e1)
    assert e1 not in eng._epochs            # dropped once released
    with pytest.raises(KeyError):
        eng.index_for(e1)


def test_refit_failure_degrades_and_backs_off():
    """Injected refit failures must leave the serving index untouched,
    count consecutively, back off exponentially, and park the loop at
    max_failures — never crash the serving path."""
    eng, q = _fitted()
    cfg = RefreshConfig(interval_s=0.01, max_failures=3,
                        backoff_base_s=0.01, backoff_max_s=0.05)
    r = IndexRefresher(eng, auditor=None, cfg=cfg)
    before = eng.index
    with faults.injected(faults.REFRESH_REFIT, RuntimeError("refit boom")):
        assert r.refresh_once() == "failed"
        assert r.n_failures == 1 and r._backoff() == 0.01
        assert r.refresh_once() == "failed"
        assert r.n_failures == 2 and r._backoff() == 0.02
    assert eng.index is before and eng.index_epoch == 1
    assert eng.rank(q[:8], record=False).ids.shape == (8, 5)
    # recovery resets the consecutive counter
    assert r.refresh_once() == "swapped" and r.n_failures == 0
    # the background loop parks after max_failures consecutive failures
    faults.arm(faults.REFRESH_REFIT, RuntimeError("still broken"))
    r.start()
    deadline = time.monotonic() + 30.0
    while not r.parked and time.monotonic() < deadline:
        time.sleep(0.01)
    assert r.parked and r.n_failures == cfg.max_failures
    r.close()
    assert eng.rank(q[:8], record=False).ids.shape == (8, 5)


def test_nan_theta_guard_keeps_serving_index():
    eng, q = _fitted()
    r = IndexRefresher(eng, auditor=None, cfg=RefreshConfig())
    assert r.refresh_once() == "swapped"          # seeds the IUL state
    epoch = eng.index_epoch

    def poison(ctx):
        r._state = r._state._replace(
            theta=jnp.full_like(r._state.theta, jnp.nan))

    with faults.injected(faults.REFRESH_REFIT, poison):
        assert r.refresh_once() == "failed"
    assert eng.index_epoch == epoch
    assert np.isfinite(np.asarray(eng.rank(q[:8], record=False)
                                  .logits)).all()


# ------------------------------------- satellite: refit off the lock --

def test_slow_refit_never_blocks_concurrent_rank():
    """The regression the satellite demands: only the O(1) flip is under
    the engine lock, so a rank racing a (slow) refit must complete in
    per-chunk time, never wait out the refit."""
    eng, q = _fitted()
    eng.rank(q[:8], record=False)                   # warm the (lss, 8) step
    r = IndexRefresher(eng, auditor=None, cfg=RefreshConfig(warm=True))
    faults.arm(faults.REFRESH_REFIT, 1.5)           # refit sleeps 1.5 s
    out = {}
    th = threading.Thread(target=lambda: out.update(res=r.refresh_once()))
    th.start()
    worst, n = 0.0, 0
    while th.is_alive():
        t0 = time.perf_counter()
        eng.rank(q[:8], record=False)
        worst = max(worst, time.perf_counter() - t0)
        n += 1
    th.join()
    assert out["res"] == "swapped"
    assert n >= 3, f"only {n} ranks ran during a 1.5 s refit"
    assert worst < 0.75, \
        f"rank blocked {worst:.3f}s behind the refit — the refit is " \
        f"holding Engine.lock"


# --------------------------------------------------- guarded rollback --

def test_corrupt_recall_triggers_rollback_within_probation():
    """An injected recall regression during probation must roll the
    engine back to the previous index (bit-identical serving restored)
    and raise ``lss_refresh_rollback_total``, with the auditor's
    ``lss_audit_recall_at_k`` gauge live — the acceptance criterion."""
    eng, q = _fitted(audit_rate=1.0)
    for i in range(12):                             # pre-swap baseline
        eng.rank(q[8 * i:8 * i + 8])
    eng.auditor.drain()
    _, total0 = eng.auditor.snapshot()
    assert total0 > 0
    r = IndexRefresher(eng, cfg=RefreshConfig(
        probation_s=30.0, min_audit_rows=40, probation_poll_s=0.02))
    idx_before = eng.index
    ref_out = eng.rank(q[:8], record=False)

    stop = threading.Event()

    def traffic():                                  # feeds the auditor
        i = 0
        while not stop.is_set():
            eng.rank(q[8 * (i % 30):8 * (i % 30) + 8])
            i += 1
            time.sleep(0.005)

    th = threading.Thread(target=traffic, daemon=True)
    th.start()
    try:
        t0 = time.monotonic()
        with faults.injected(faults.REFRESH_PROBATION,
                             lambda ctx: ctx.__setitem__("recall", 0.0)):
            outcome = r.refresh_once()
        elapsed = time.monotonic() - t0
    finally:
        stop.set()
        th.join()
    assert outcome == "rolled_back" and r.n_rollbacks == 1
    assert elapsed < 30.0, "rollback decided by probation, not timeout"
    assert eng.index is idx_before                  # restored, new epoch
    assert eng.index_epoch == 3
    post = eng.rank(q[:8], record=False)
    np.testing.assert_array_equal(np.asarray(post.logits),
                                  np.asarray(ref_out.logits))
    fams, errors = parse_exposition(prometheus_text())
    assert not errors, errors
    assert _sample("lss_refresh_rollback_total", fams) >= 1
    assert np.isfinite(_sample("lss_audit_recall_at_k", fams))
    eng.auditor.close()


def test_probation_passes_without_evidence():
    """No auditor rows inside the window is NOT evidence of regression:
    the swap must stand (and a disabled auditor must behave the same)."""
    eng, _ = _fitted(audit_rate=1.0)
    r = IndexRefresher(eng, cfg=RefreshConfig(probation_s=0.05,
                                              probation_poll_s=0.01,
                                              min_audit_rows=10 ** 6))
    assert r.refresh_once() == "swapped"
    assert eng.index_epoch == 2
    eng.auditor.close()


# ----------------------------------------------------- decode pinning --

VOCAB, PLEN = 256, 6
_LM_CACHE = []


def _lm_data():
    """Small LM shared by the decode tests.  A plain cached helper (not
    a fixture) because the hypothesis STUB's ``@given`` erases the test
    signature, so fixtures cannot reach property tests."""
    if not _LM_CACHE:
        cfg = T.TransformerConfig(name="t", n_layers=1, d_model=32,
                                  n_heads=2, n_kv_heads=2, head_dim=16,
                                  d_ff=64, vocab=VOCAB, dtype=jnp.float32,
                                  kv_chunk=32)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        toks = np.asarray(lm_dataset(0, 64 * 33, VOCAB, 33))
        _LM_CACHE.append((params, cfg, toks))
    return _LM_CACHE[0]


@pytest.fixture(scope="module")
def lm():
    return _lm_data()


def _decoder(lm, fit_key=1):
    params, cfg, _ = lm
    dec = LMDecoder(params, cfg, LSS, max_streams=2, max_len=16)
    dec.engine.fit_random(jax.random.PRNGKey(fit_key))
    return dec


def _alt_index(lm):
    """A second, different LSS index over the same decoder weights."""
    dec = _decoder(lm, fit_key=9)
    return dec.engine.index


def test_swap_mid_decode_is_invisible_to_pinned_sessions(lm):
    """Sessions decode through the epoch their generation pinned: a swap
    mid-flight must not change a single token vs a no-swap run, and the
    NEXT generation must serve the new index — bit-identical to a cold
    engine fitted on it (acceptance criterion)."""
    _, _, toks = lm
    budgets = [4, 7, 3, 6]
    idx2 = _alt_index(lm)

    ref = _decoder(lm)                              # never swapped
    sref = ref.scheduler(head="lss")
    ref_streams = [sref.submit(toks[i, :PLEN], max_new_tokens=budgets[i])
                   for i in range(4)]
    sref.run(timeout=300.0)

    dec = _decoder(lm)                              # swapped mid-decode
    sched = dec.scheduler(head="lss")
    streams = [sched.submit(toks[i, :PLEN], max_new_tokens=budgets[i])
               for i in range(4)]
    for _ in range(3):                              # sessions in flight
        sched.tick()
    assert sched.pool.n_active > 0
    e_new = dec.engine.swap_index(idx2)             # mid-decode swap
    assert dec.engine.index_epoch == e_new
    sched.run(timeout=300.0)
    for i, (st_new, st_ref) in enumerate(zip(streams, ref_streams)):
        np.testing.assert_array_equal(
            st_new.result(), st_ref.result(),
            err_msg=f"session {i} perturbed by the swap")
    # the drained generation released its pin: old epoch is gone
    assert list(dec.engine._epochs) == [e_new]

    cold = _decoder(lm)                             # cold on the new index
    cold.engine._set_index(idx2)
    scold = cold.scheduler(head="lss")
    post = [sched.submit(toks[i, :PLEN], max_new_tokens=5)
            for i in range(3)]
    want = [scold.submit(toks[i, :PLEN], max_new_tokens=5)
            for i in range(3)]
    sched.run(timeout=300.0), scold.run(timeout=300.0)
    for i, (a, b) in enumerate(zip(post, want)):
        np.testing.assert_array_equal(a.result(), b.result(),
                                      err_msg=f"post-swap session {i}")


_PROP_ENV: dict = {}


def _prop_env():
    """One decoder + scheduler + two reference decoders shared by every
    property example — fresh decoders per example would pay a fused-step
    trace each, and the op sweep needs none of that isolation (each
    example drains the pool before the next starts)."""
    if not _PROP_ENV:
        lm = _lm_data()
        dec = _decoder(lm)
        idx1, idx2 = dec.engine.index, _alt_index(lm)
        ref1, ref2 = _decoder(lm), _decoder(lm)
        ref1.engine._set_index(idx1)
        ref2.engine._set_index(idx2)
        _PROP_ENV.update(lm=lm, dec=dec, sched=dec.scheduler(head="lss"),
                         idx1=idx1, idx2=idx2,
                         refs={id(idx1): ref1, id(idx2): ref2})
    return _PROP_ENV


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2 ** 32 - 1))
def test_epoch_pinning_property_interleaved_ops(seed):
    """Seeded sweep over interleaved swap / join / leave / rank / tick
    sequences (leaves happen inside ticks as budgets run out): every
    decode session's tokens must be bit-identical to a no-swap run of
    the same epoch (sequential blocking generate on a same-shaped
    decoder serving that session's pinned index)."""
    env = _prop_env()
    _, cfg, toks = env["lm"]
    dec, sched = env["dec"], env["sched"]
    idx1, idx2 = env["idx1"], env["idx2"]
    rng = np.random.default_rng(seed)
    sessions = []                   # [stream, prompt_row, budget, index]

    def record_pins():
        # a session's generation pinned its epoch by the time its first
        # token exists (tok0 is emitted at admit, under the pin)
        if sched._epoch is not None:
            pinned = dec.engine.index_for(sched._epoch)
            for s in sessions:
                if s[3] is None and s[0].ttft_s() is not None:
                    s[3] = pinned

    for _ in range(14):
        op = rng.choice(["join", "tick", "swap", "rank"],
                        p=[0.35, 0.4, 0.15, 0.1])
        if op == "join" and len(sessions) < 6:
            row = int(rng.integers(0, 32))
            budget = int(rng.integers(2, 5))
            stv = sched.submit(toks[row, :PLEN], max_new_tokens=budget)
            sessions.append([stv, row, budget, None])
        elif op == "swap":
            dec.engine.swap_index(
                idx2 if dec.engine.index is idx1 else idx1)
        elif op == "rank":
            x = rng.standard_normal((8, cfg.d_model)).astype(np.float32)
            dec.engine.rank(x, record=False)
        else:
            sched.tick()
        record_pins()
    while not sched.idle:                            # drain, still recording
        sched.tick()
        record_pins()
    sched.tick()                                     # collect the last step
    for stv, row, budget, pinned in sessions:
        assert stv.finish_reason == "max_tokens"
        assert pinned is not None
        ref = env["refs"][id(pinned)]
        want = np.asarray(ref.generate(
            jnp.asarray(toks[row:row + 1, :PLEN]), steps=budget,
            head="lss"))[0]
        np.testing.assert_array_equal(stv.result(), want)


# ----------------------------------- satellite: bounded runtime close --

def test_metrics_port_released_after_close():
    """The /metrics listener must actually release its port on close():
    a rebind on the SAME fixed port succeeds (a leaked HTTP thread would
    still hold the listener and EADDRINUSE here)."""
    srv = MetricsServer(port=0)
    port = srv.port
    import urllib.request
    with urllib.request.urlopen(srv.url, timeout=5) as resp:
        assert resp.status == 200
    srv.close()
    assert not srv._thread.is_alive()
    srv2 = MetricsServer(port=port)                 # rebind proves release
    try:
        assert srv2.port == port
    finally:
        srv2.close()
    with socket.socket() as s:                      # and truly free now
        s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        s.bind(("127.0.0.1", port))


def test_runtime_exit_bounded_on_wedged_dispatcher():
    """A wedged dispatcher must not hang ``with AsyncRuntime(...)`` exit
    forever: ``close_timeout_s`` bounds the drain, the TimeoutError
    escapes (so the launcher's nested ``finally`` still shuts the
    exporter down), and the exporter can in fact be shut down after."""
    eng, q = _fitted()
    eng.rank(q[:8], record=False)                   # compile outside timing
    real_step = eng._step

    def wedged_step(kind, bucket, epoch=None):
        inner = real_step(kind, bucket, epoch)

        def slow(padded):
            time.sleep(3.0)
            return inner(padded)
        return slow

    eng._step = wedged_step
    srv = MetricsServer(port=0)
    t0 = time.monotonic()
    try:
        with pytest.raises(TimeoutError):
            with AsyncRuntime(eng, head="lss", policy="shed",
                              close_timeout_s=0.5) as rt:
                rt.submit(q[0])
                time.sleep(0.2)                     # let dispatch wedge
        assert time.monotonic() - t0 < 20.0
    finally:
        eng._step = real_step
        srv.close()
    assert not srv._thread.is_alive()
