"""Retrieval, dedup, sparse logits, prediction, metrics."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import simhash
from repro.core.lss import (LSSConfig, avg_sample_size, build_index,
                            dedup_mask, label_recall, lss_predict,
                            precision_at_k, retrieve, sparse_logits_bucketed,
                            sparse_logits_gather)


def _setup(m=200, d=16, n=32, k=3, l=2, seed=0, bucket_major=True):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (m, d))
    q = jax.random.normal(jax.random.PRNGKey(seed + 1), (n, d))
    cfg = LSSConfig(k_bits=k, n_tables=l, use_bucket_major=bucket_major)
    w_aug = simhash.augment_neurons(w, None)
    theta = simhash.init_hyperplanes(jax.random.PRNGKey(seed + 2),
                                     d + 1, k, l)
    index = build_index(w_aug, theta, cfg)
    return w, q, w_aug, index


def test_retrieve_returns_bucket_mates():
    w, q, w_aug, index = _setup()
    q_aug = simhash.augment_queries(q)
    cand, buckets = retrieve(q_aug, index)
    t = index.tables
    qb = np.asarray(simhash.bucket_ids(q_aug, index.theta, t.k_bits,
                                       t.n_tables))
    ids = np.asarray(t.table_ids)
    c = np.asarray(cand).reshape(q.shape[0], t.n_tables, t.capacity)
    for i in range(q.shape[0]):
        for tt in range(t.n_tables):
            np.testing.assert_array_equal(c[i, tt], ids[tt, qb[i, tt]])


def test_gather_and_bucketed_logits_agree():
    w, q, w_aug, index = _setup()
    q_aug = simhash.augment_queries(q)
    cand, buckets = retrieve(q_aug, index)
    lg = sparse_logits_gather(q_aug, w_aug, cand)
    lb, ids = sparse_logits_bucketed(q_aug, index, buckets)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(cand))
    mask = np.asarray(cand) >= 0
    np.testing.assert_allclose(np.asarray(lg)[mask], np.asarray(lb)[mask],
                               rtol=1e-5, atol=1e-5)


def test_lss_predict_equals_exact_over_candidates():
    """Top-k inside the retrieved set must equal brute force over the
    same set (incl. dedup semantics)."""
    w, q, w_aug, index = _setup(seed=3)
    q_aug = simhash.augment_queries(q)
    cand, _ = retrieve(q_aug, index)
    top_l, top_i = lss_predict(q, index, w_aug, top_k=3)
    full = np.asarray(q_aug @ w_aug.T)
    candn = np.asarray(cand)
    for i in range(q.shape[0]):
        uniq = sorted(set(x for x in candn[i] if x >= 0),
                      key=lambda j: -full[i, j])
        want = uniq[:3]
        got = [x for x in np.asarray(top_i[i]) if x >= 0]
        assert got == want[:len(got)] and len(got) == min(3, len(uniq))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000))
def test_dedup_mask_properties(seed):
    rng = np.random.default_rng(seed)
    ids = rng.integers(-1, 10, size=(4, 16)).astype(np.int32)
    mask = np.asarray(dedup_mask(jnp.asarray(ids)))
    for b in range(4):
        kept = ids[b][mask[b]]
        assert len(kept) == len(set(kept.tolist()))          # unique
        assert (kept >= 0).all()                             # no padding
        assert set(kept.tolist()) == set(x for x in ids[b] if x >= 0)


def test_metrics():
    pred = jnp.array([[3, 1, 2], [0, 5, 4]])
    labels = jnp.array([[3, 9], [4, -1]])
    assert float(precision_at_k(pred, labels, 1)) == 0.5
    p5 = float(precision_at_k(pred, labels, 3))
    assert abs(p5 - (1 / 3 + 1 / 3) / 2) < 1e-6
    cand = jnp.array([[3, 9, 9, -1], [1, 2, 3, 4]])
    assert float(label_recall(cand, labels)) == (2 + 1) / 3
    assert float(avg_sample_size(cand)) == (2 + 4) / 2
