"""Paged KV cache + prefix caching: bit-exactness of the paged layout
against the dense slabs (blocking, interleaved join/leave, prefix-shared
and partially-shared sessions, page-boundary crossings), KVCachePool
slot/page accounting (double-free raises, exhaustion returns None,
refcounts under prefix sharing — including a seeded property sweep), and
prompt-length-bucketed prefill compile counts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.lss import LSSConfig
from repro.data.synthetic import lm_dataset
from repro.models import transformer as T
from repro.serve import KVCachePool, KVPoolExhaustedError, LMDecoder
from repro.serve.decode.scheduler import _PREFILL_COMPILES, _prefill_bucket

VOCAB = 512
PROMPT_LEN = 6
MAX_LEN = 24
PAGE = 8                 # pages_per_slot = 3 at MAX_LEN=24

CFG = T.TransformerConfig(name="tp", n_layers=2, d_model=32, n_heads=2,
                          n_kv_heads=2, head_dim=16, d_ff=64,
                          vocab=VOCAB, dtype=jnp.float32, kv_chunk=32)


@pytest.fixture(scope="module")
def lm():
    params = T.init_params(jax.random.PRNGKey(0), CFG)
    toks = np.asarray(lm_dataset(0, 64 * 33, VOCAB, 33))
    return params, toks


def _decoder(params, layout, *, page_tokens=PAGE, max_streams=3,
             max_len=MAX_LEN):
    dec = LMDecoder(params, CFG, LSSConfig(k_bits=4, n_tables=2),
                    max_streams=max_streams, max_len=max_len,
                    kv_layout=layout, kv_page_tokens=page_tokens)
    dec.engine.fit_random(jax.random.PRNGKey(1))  # same key across
    return dec                                    # layouts: same index


@pytest.fixture(scope="module")
def dense_dec(lm):
    return _decoder(lm[0], "dense")


@pytest.fixture(scope="module")
def paged_dec(lm):
    return _decoder(lm[0], "paged")


@pytest.fixture(scope="module")
def dense4_dec(lm):
    return _decoder(lm[0], "dense", page_tokens=4)


@pytest.fixture(scope="module")
def paged4_dec(lm):
    return _decoder(lm[0], "paged", page_tokens=4)


# ------------------------------------------------- paged == dense exact --

@pytest.mark.parametrize("head", ["full", "lss"])
def test_paged_blocking_exact_vs_dense(dense_dec, paged_dec, lm, head):
    _, toks = lm
    for i in range(3):
        a = np.asarray(dense_dec.generate(
            jnp.asarray(toks[i:i + 1, :PROMPT_LEN]), steps=8, head=head))
        b = np.asarray(paged_dec.generate(
            jnp.asarray(toks[i:i + 1, :PROMPT_LEN]), steps=8, head=head))
        np.testing.assert_array_equal(a, b, err_msg=f"row {i} head {head}")
    # the paged step is its own program under a distinct tag; the dense
    # tag (the observable other tests pin) is untouched
    assert (head, f"decode[3x{MAX_LEN},paged{PAGE}]@tp") \
        in paged_dec.engine.compile_counts


@pytest.mark.parametrize("head", ["full", "lss"])
def test_paged_interleaved_join_leave_exact(dense_dec, paged_dec, lm, head):
    """5 sessions through 3 paged slots with staggered budgets — sessions
    leave mid-flight and queued ones join freed slots (page recycling in
    anger) — must match one-at-a-time dense blocking generate exactly."""
    _, toks = lm
    budgets = [3, 6, 9, 4, 12]
    seq = [np.asarray(dense_dec.generate(
        jnp.asarray(toks[i:i + 1, :PROMPT_LEN]), steps=budgets[i],
        head=head))[0] for i in range(5)]
    sched = paged_dec.scheduler(head=head)
    streams = [sched.submit(toks[i, :PROMPT_LEN], max_new_tokens=budgets[i])
               for i in range(5)]
    sched.run(timeout=120.0)
    for i, st_ in enumerate(streams):
        assert st_.finish_reason == "max_tokens"
        np.testing.assert_array_equal(st_.result(), seq[i],
                                      err_msg=f"session {i} head {head}")
    assert sched.pool.n_free == sched.max_streams


def test_prefix_shared_sessions_skip_prefill_and_stay_exact(
        dense_dec, paged_dec, lm):
    """Identical prompts: the first join prefills and registers its
    pages; every later join maps straight from the cache (no prefill, no
    head rank) and still produces bit-identical tokens."""
    _, toks = lm
    prompt = toks[9, :PROMPT_LEN]
    ref = np.asarray(dense_dec.generate(
        jnp.asarray(prompt)[None, :], steps=7, head="full"))[0]
    sched = paged_dec.scheduler(head="full")
    sched.reset_stats()
    streams = [sched.submit(prompt, max_new_tokens=7) for _ in range(5)]
    sched.run(timeout=120.0)
    for st_ in streams:
        np.testing.assert_array_equal(st_.result(), ref)
    s = sched.stats()
    assert s.n_prefill_skipped >= 4          # all but (at most) the first
    assert s.prefix_hit_rate > 0


def test_partial_prefix_share_and_divergence_exact(dense4_dec, paged4_dec,
                                                   lm):
    """Two prompts sharing full pages but diverging in the remainder:
    the shared full pages come from the cache (refcount > 1), the
    divergent remainder does not, and both sessions decode exactly."""
    _, toks = lm
    dense, paged = dense4_dec, paged4_dec
    a = toks[3, :10].copy()
    b = a.copy()
    b[-1] = (b[-1] + 1) % VOCAB              # diverge inside the rem page
    refs = [np.asarray(dense.generate(jnp.asarray(p)[None, :], steps=5,
                                      head="full"))[0] for p in (a, b)]
    sched = paged.scheduler(head="full")
    st_a = sched.submit(a, max_new_tokens=5)
    sched.run(until=st_a.done)
    hits0 = sched.pool.prefix_hits
    st_b = sched.submit(b, max_new_tokens=5)
    sched.run(timeout=120.0)
    np.testing.assert_array_equal(st_a.result(), refs[0])
    np.testing.assert_array_equal(st_b.result(), refs[1])
    # b reused a's two full pages (tokens 0..7) but NOT the remainder
    assert sched.pool.prefix_hits - hits0 == 2


def test_page_boundary_crossing_exact(dense4_dec, paged4_dec, lm):
    """A tiny page size forces several advance-time page allocations per
    session; tokens must still match dense exactly."""
    _, toks = lm
    dense, paged = dense4_dec, paged4_dec
    for i in (11, 12):
        a = np.asarray(dense.generate(jnp.asarray(toks[i:i + 1, :5]),
                                      steps=14, head="full"))
        b = np.asarray(paged.generate(jnp.asarray(toks[i:i + 1, :5]),
                                      steps=14, head="full"))
        np.testing.assert_array_equal(a, b)


# ------------------------------------------------------ pool accounting --

def _dummy_kv(s):
    shape = (CFG.n_layers, 1, s, CFG.n_kv_heads, CFG.head_dim)
    return jnp.zeros(shape, CFG.dtype), jnp.zeros(shape, CFG.dtype)


@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_pool_slot_validation(layout):
    pool = KVCachePool(CFG, max_streams=2, max_len=16, layout=layout,
                       page_tokens=PAGE)
    a, b = pool.alloc(), pool.alloc()
    assert {a, b} == {0, 1} and pool.alloc() is None   # exhaustion: None
    pool.free(a)
    with pytest.raises(ValueError):                    # double free
        pool.free(a)
    with pytest.raises(ValueError):                    # out of range
        pool.free(7)
    k, v = _dummy_kv(8)
    with pytest.raises(ValueError):                    # join unowned slot
        pool.join(a, k, v, 4)
    with pytest.raises(ValueError):                    # length > width
        pool.join(b, k, v, 17)
    assert pool.alloc() == a                           # free -> reuse
    pool.join(a, k, v, 4)
    assert pool.lengths[a] == 4


def test_page_refcounting_under_prefix_sharing():
    pool = KVCachePool(CFG, max_streams=3, max_len=16, layout="paged",
                       page_tokens=4)
    prompt = np.arange(10, dtype=np.int32)
    k, v = _dummy_kv(12)
    s0 = pool.alloc()
    pool.join(s0, k, v, 10, prompt=prompt, bucket=16)
    row0 = pool.page_table[s0].copy()
    assert (row0[:3] > 0).all() and row0[3] == 0       # 2 full + 1 rem
    # full and rem pages: held by slot AND cache
    assert all(pool._ref[p] == 2 for p in row0[:3])
    s1 = pool.alloc()
    pool.join(s1, k, v, 10, prompt=prompt, bucket=16)
    row1 = pool.page_table[s1]
    np.testing.assert_array_equal(row0[:2], row1[:2])  # full pages shared
    assert row1[2] != row0[2]                          # rem NOT shared
    assert all(pool._ref[p] == 3 for p in row0[:2])
    # the cached rem key still points at s0's page (no re-registration)
    assert pool._ref[row0[2]] == 2 and pool._ref[row1[2]] == 1
    pool.free(s0)
    assert all(pool._ref[p] == 2 for p in row0[:2])    # s1 + cache
    assert pool._ref[row0[2]] == 1                     # cache only
    pool.free(s1)
    # cache keeps every registered page alive at ref 1
    assert all(pool._ref[p] == 1 for p in row0[:3])
    assert pool.pages_in_use == 3
    # full-prompt cache join: maps both full pages + a CoW'd remainder
    s2 = pool.alloc()
    assert pool.join_from_cache(s2, prompt, 10, bucket=16)
    row2 = pool.page_table[s2]
    np.testing.assert_array_equal(row2[:2], row0[:2])
    assert row2[2] not in (0, row0[2])                 # fresh CoW page
    # a different bucket is a different reduction shape: never a hit
    s3 = pool.alloc()
    assert not pool.join_from_cache(s3, prompt, 10, bucket=32)


def test_paged_pool_page_exhaustion_raises():
    pool = KVCachePool(CFG, max_streams=2, max_len=16, layout="paged",
                       page_tokens=4, n_pages=3)     # scratch + 2 pages
    k, v = _dummy_kv(12)
    s0 = pool.alloc()
    pool.join(s0, k, v, 5)                           # needs 2 pages
    s1 = pool.alloc()
    with pytest.raises(RuntimeError):
        pool.join(s1, k, v, 5)                       # nothing evictable


def test_join_from_cache_cow_alloc_cannot_evict_own_pages():
    """Regression: the COW _alloc_page inside join_from_cache runs the
    LRU evictor, which (donor gone, cache the sole holder) used to evict
    the very remainder page being joined — KeyError mid-mutation.  The
    pages of the in-progress join are pinned now: eviction must take an
    UNRELATED cache-only page and the join must complete."""
    pool = KVCachePool(CFG, max_streams=3, max_len=8, layout="paged",
                       page_tokens=4, n_pages=4)     # scratch + 3 pages
    k, v = _dummy_kv(8)
    pa = np.arange(6, dtype=np.int32)                # 1 full + 1 rem page
    pb = np.arange(10, 13, dtype=np.int32)           # 1 rem page
    s = pool.alloc()
    pool.join(s, k, v, 6, prompt=pa, bucket=8)
    pool.free(s)                                     # pa pages: cache-only
    s = pool.alloc()
    pool.join(s, k, v, 3, prompt=pb, bucket=8)
    pool.free(s)                                     # pb page: cache-only
    assert pool.n_free_pages == 0                    # all 3 pages cached
    s = pool.alloc()
    assert pool.join_from_cache(s, pa, 6, bucket=8)  # must NOT eat pa
    row = pool.page_table[s]
    assert (row[:2] > 0).all() and pool.lengths[s] == 6
    assert pool._ref[row[0]] == 2                    # full: cache + session
    assert pool._ref[row[1]] == 1                    # fresh CoW write page
    # pb's (LRU-evictable, unrelated) page paid for the CoW; pa survives
    s2 = pool.alloc()
    assert not pool.join_from_cache(s2, pb, 3, bucket=8)


def test_join_from_cache_exhaustion_unwinds_cleanly():
    """When even eviction cannot produce the CoW page, join_from_cache
    must raise KVPoolExhaustedError with the pool EXACTLY as it was —
    no refs bumped, no page-table row half-written, no LRU churn."""
    pool = KVCachePool(CFG, max_streams=3, max_len=8, layout="paged",
                       page_tokens=4, n_pages=4)     # scratch + 3 pages
    k, v = _dummy_kv(8)
    pa = np.arange(6, dtype=np.int32)
    s = pool.alloc()
    pool.join(s, k, v, 6, prompt=pa, bucket=8)
    pool.free(s)                                     # 2 cache-only pages
    s1 = pool.alloc()
    pool.join(s1, k, v, 3)                           # 3rd page: live, no key
    assert pool.n_free_pages == 0
    s2 = pool.alloc()
    ref0 = pool._ref.copy()
    cache0, lru0 = dict(pool._cache), list(pool._lru)
    with pytest.raises(KVPoolExhaustedError):
        pool.join_from_cache(s2, pa, 6, bucket=8)    # pa's pages pinned,
    np.testing.assert_array_equal(pool._ref, ref0)   # nothing evictable
    assert pool._cache == cache0 and list(pool._lru) == lru0
    assert (pool.page_table[s2] == 0).all() and pool.lengths[s2] == 0
    # join() CAN proceed by evicting pa's rem entry for its write page
    pool.join(s2, k, v, 6, prompt=pa, bucket=8)
    assert pool.lengths[s2] == 6


def test_join_exhaustion_unwinds_cleanly():
    """join() securing pages must also be all-or-nothing: on exhaustion
    nothing is mutated (no stale cache registrations pointing at pages
    whose KV was never scattered, no leaked refs)."""
    pool = KVCachePool(CFG, max_streams=3, max_len=8, layout="paged",
                       page_tokens=4, n_pages=3)     # scratch + 2 pages
    k, v = _dummy_kv(8)
    s0 = pool.alloc()
    pool.join(s0, k, v, 3)                           # 1 page, live
    s1 = pool.alloc()
    ref0 = pool._ref.copy()
    with pytest.raises(KVPoolExhaustedError):
        pool.join(s1, k, v, 6, prompt=np.arange(6, dtype=np.int32),
                  bucket=8)                          # needs 2, only 1 left
    np.testing.assert_array_equal(pool._ref, ref0)
    assert not pool._cache                           # no stale registration
    assert (pool.page_table[s1] == 0).all() and pool.lengths[s1] == 0
    assert pool.n_free_pages == 1


def test_advance_reports_starved_slots_without_raising():
    """advance() on an exhausted arena must not raise mid-loop: every
    slot's length still advances (the step DID write), and only the
    slots that could not map their next page are reported back."""
    pool = KVCachePool(CFG, max_streams=2, max_len=8, layout="paged",
                       page_tokens=4, n_pages=3)     # scratch + 2 pages
    k, v = _dummy_kv(8)
    s0, s1 = pool.alloc(), pool.alloc()
    pool.join(s0, k, v, 3)
    pool.join(s1, k, v, 2)
    assert pool.n_free_pages == 0
    assert pool.advance([s0, s1]) == [s0]            # s0 hit the boundary
    assert pool.lengths[s0] == 4 and pool.lengths[s1] == 3
    assert pool.page_table[s0, 1] == 0               # unmapped -> scratch
    pool.free(s0)                                    # the starved session
    pool.free(s1)                                    # is shed; pool drains
    assert pool.n_free_pages == 2 and pool.pages_in_use == 0


def test_scheduler_sheds_only_starved_session(lm):
    """A session that cannot grow past a page boundary is shed with
    KVPoolExhaustedError; the OTHER session keeps decoding and its
    tokens stay bit-identical to the dense blocking reference."""
    params, toks = lm
    cfg = CFG._replace(name="tp-oomshed")
    p2 = T.init_params(jax.random.PRNGKey(3), cfg)
    mk = lambda layout, pages: LMDecoder(          # noqa: E731
        p2, cfg, max_streams=2, max_len=16, kv_layout=layout,
        kv_page_tokens=4, kv_pages=pages)
    ref = np.asarray(mk("dense", None).generate(
        jnp.asarray(toks[1:2, :5]), steps=2, head="full"))[0]
    sched = mk("paged", 4).scheduler(head="full")  # scratch + 3 pages
    st_a = sched.submit(toks[0, :3], max_new_tokens=10)   # 1 page, grows
    st_b = sched.submit(toks[1, :5], max_new_tokens=2)    # 2 pages
    sched.run(timeout=120.0)
    assert st_a.finish_reason == "error"
    assert isinstance(st_a.exception(), KVPoolExhaustedError)
    assert len(st_a) >= 1                          # its landed tokens kept
    assert st_b.finish_reason == "max_tokens"
    np.testing.assert_array_equal(st_b.result(), ref)
    s = sched.stats()
    assert s.n_shed_kv_oom == 1 and s.n_finished == 1
    assert sched.pool.n_free == sched.max_streams  # accounting drained


def test_evict_lru_cached_pages_under_pressure():
    pool = KVCachePool(CFG, max_streams=1, max_len=8, layout="paged",
                       page_tokens=4, n_pages=4)     # scratch + 3 pages
    k, v = _dummy_kv(8)
    s0 = pool.alloc()
    pa = np.arange(3, dtype=np.int32)
    pb = np.arange(3, 6, dtype=np.int32)
    pool.join(s0, k, v, 3, prompt=pa, bucket=8)      # 1 rem page, cached
    pool.free(s0)
    s0 = pool.alloc()
    pool.join(s0, k, v, 3, prompt=pb, bucket=8)      # 2nd cached page
    pool.free(s0)
    assert pool.pages_in_use == 2 and pool.n_free_pages == 1
    # a 3-page join must evict both cache-only pages (LRU) to fit
    s0 = pool.alloc()
    pool.join(s0, k, v, 8, prompt=np.arange(8, dtype=np.int32), bucket=8)
    assert (pool.page_table[s0] > 0).sum() == 2      # len 8 = 2 full pages
    assert not pool.join_from_cache(
        (pool.free(s0), pool.alloc())[1], pa, 3, 8)  # pa was evicted


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_pool_accounting_property(seed):
    """Seeded op-sequence sweep (alloc/join/cache-join/advance/free over
    two shareable prompts): after every op, page refcounts must equal
    the number of slot mappings plus cache holds, the free list must be
    disjoint from referenced pages, and together they must cover the
    arena."""
    rng = np.random.default_rng(seed)
    pool = KVCachePool(CFG, max_streams=3, max_len=16, layout="paged",
                       page_tokens=4)
    k, v = _dummy_kv(12)
    prompts = [np.arange(9, dtype=np.int32),
               np.arange(100, 109, dtype=np.int32)]
    held: list[int | None] = []

    def check():
        refs = np.zeros(pool.n_pages, np.int64)
        for s in range(pool.max_streams):
            for pid in pool.page_table[s]:
                if pid > 0:
                    refs[pid] += 1
        for pid in pool._cache.values():
            refs[pid] += 1
        np.testing.assert_array_equal(refs[1:], pool._ref[1:])
        assert pool._ref[0] == 0
        free = set(pool._free_pages)
        assert len(free) == len(pool._free_pages)       # no dup frees
        assert all(pool._ref[p] == 0 for p in free)
        assert len(free) + pool.pages_in_use == pool.n_pages - 1

    for _ in range(40):
        op = rng.integers(0, 4)
        if op == 0:
            s = pool.alloc()
            if s is not None:
                held.append(s)
        elif op == 1 and held:
            s = held.pop(int(rng.integers(0, len(held))))
            pool.free(s)
        elif op == 2 and held:
            s = held[int(rng.integers(0, len(held)))]
            p = prompts[int(rng.integers(0, 2))]
            if not (rng.integers(0, 2)
                    and pool.join_from_cache(s, p, 9, bucket=16)):
                pool.join(s, k, v, 9, prompt=p, bucket=16)
        elif op == 3 and held:
            s = held[int(rng.integers(0, len(held)))]
            if 0 < pool.lengths[s] < pool.max_len:
                pool.advance([s])
        check()


# ------------------------------------------------- prefill bucketing --

def test_prefill_bucket_shape():
    assert _prefill_bucket(1) == 8 and _prefill_bucket(8) == 8
    assert _prefill_bucket(9) == 16 and _prefill_bucket(16) == 16
    assert _prefill_bucket(17) == 32 and _prefill_bucket(4096) == 4096


def test_prefill_compiles_per_bucket_not_per_length(lm):
    """Distinct prompt lengths within one power-of-two bucket share ONE
    prefill trace; the compile counter (surfaced through DecodeStats /
    RuntimeStats) proves it."""
    params, toks = lm
    cfg = CFG._replace(name="tp-buckets")
    p2 = T.init_params(jax.random.PRNGKey(2), cfg)
    dec = LMDecoder(p2, cfg, max_streams=2, max_len=MAX_LEN)
    sched = dec.scheduler(head="full")
    for plen in (3, 5, 6, 8, 9, 12, 15):     # buckets: {8, 16} only
        st_ = sched.submit(toks[0, :plen], max_new_tokens=2)
        sched.run(until=st_.done)
    sched.run(timeout=60.0)
    s = sched.stats()
    assert s.n_prefill_buckets == 2, dict(_PREFILL_COMPILES)
    assert s.n_prefill_compiles == 2, dict(_PREFILL_COMPILES)
