"""Async serving runtime: admission queue policies, futures, deadline and
queue-depth shedding, drain/close semantics, multi-threaded bit-identity
against the synchronous Engine.flush path, and Engine thread safety."""

import threading
import time

import jax
import numpy as np
import pytest

from repro.core.lss import LSSConfig
from repro.serve import (AdmissionQueue, AsyncRuntime,
                         DeadlineExceededError, Engine, QueueFullError,
                         RuntimeClosedError)


def _engine(m=512, d=32, k_bits=4, n_tables=2, top_k=5, buckets=(8,)):
    w = jax.random.normal(jax.random.PRNGKey(0), (m, d))
    eng = Engine(None, w, None,
                 LSSConfig(k_bits=k_bits, n_tables=n_tables),
                 top_k=top_k, head="lss", buckets=buckets)
    eng.fit_random(jax.random.PRNGKey(1))
    return eng


# -------------------------------------------------------- admission queue --

def test_admission_queue_fifo_and_take():
    q = AdmissionQueue(maxsize=8)
    for i in range(5):
        assert q.put(i)
    assert q.take(3) == [0, 1, 2]
    assert q.take(10) == [3, 4]
    assert q.take(1, timeout=0.01) == []         # empty -> timeout


def test_admission_queue_shed_policy():
    q = AdmissionQueue(maxsize=2, policy="shed")
    assert q.put("a") and q.put("b")
    assert not q.put("c")                        # full -> shed immediately
    assert q.take(10) == ["a", "b"]
    assert q.put("c")                            # space again


def test_admission_queue_block_policy_timeout_and_wakeup():
    q = AdmissionQueue(maxsize=1, policy="block")
    assert q.put("a")
    assert not q.put("b", timeout=0.05)          # blocked, then timed out
    admitted = []
    t = threading.Thread(target=lambda: admitted.append(q.put("c")))
    t.start()
    time.sleep(0.05)
    assert t.is_alive()                          # still blocked
    assert q.take(1) == ["a"]                    # frees a slot
    t.join(timeout=2.0)
    assert admitted == [True]
    assert q.take(1) == ["c"]


def test_admission_queue_close_returns_leftovers_and_refuses():
    q = AdmissionQueue(maxsize=8)
    q.put(1), q.put(2)
    assert q.close() == [1, 2]
    assert not q.put(3)
    assert q.take(1, timeout=5.0) == []          # returns instantly, closed


def test_admission_queue_validation():
    with pytest.raises(ValueError):
        AdmissionQueue(maxsize=0)
    with pytest.raises(ValueError):
        AdmissionQueue(policy="drop-oldest")


# ------------------------------------------------- bit-identity with flush --

def test_multithreaded_submit_bit_identical_to_flush():
    """N producer threads race submissions; every request's async result
    must equal, bit for bit, what a single synchronous flush produced for
    the same request.  A single-bucket ladder pins every chunk to one
    jitted program, and every head op is row-parallel, so grouping cannot
    change a row's result."""
    eng = _engine(buckets=(8,))
    n_threads, per_thread = 4, 16
    rng = np.random.default_rng(3)
    xs = rng.standard_normal((n_threads * per_thread, 32)).astype(np.float32)

    for x in xs:                                  # synchronous reference
        eng.submit(x)
    sync = eng.flush()                            # rid == row index

    rt = AsyncRuntime(eng, max_queue=1024, policy="block")
    futs: dict[int, object] = {}
    barrier = threading.Barrier(n_threads)

    def producer(t):
        barrier.wait()                            # maximise interleaving
        for i in range(t * per_thread, (t + 1) * per_thread):
            futs[i] = rt.submit(xs[i])            # dict write: GIL-atomic

    threads = [threading.Thread(target=producer, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30.0)
    rt.drain(timeout=60.0)
    s = rt.stats()
    rt.close()

    assert s.n_completed == len(xs) and s.n_shed_queue == 0
    for i in range(len(xs)):
        r = futs[i].result(timeout=5.0)
        np.testing.assert_array_equal(r.ids, sync[i].ids)
        np.testing.assert_array_equal(r.logits, sync[i].logits)


def test_paused_runtime_matches_flush_grouping_exactly():
    """start=False stages the whole backlog first, so the dispatcher
    coalesces identically to flush (max-bucket chunks in arrival order)
    even on a multi-bucket ladder."""
    eng = _engine(buckets=(1, 2, 4, 8))
    rng = np.random.default_rng(4)
    xs = rng.standard_normal((19, 32)).astype(np.float32)
    for x in xs:
        eng.submit(x)
    sync = eng.flush()

    rt = AsyncRuntime(eng, max_queue=64, start=False)
    futs = [rt.submit(x) for x in xs]
    rt.start()
    rt.drain(timeout=60.0)
    rt.close()
    for i, f in enumerate(futs):
        r = f.result(timeout=5.0)
        np.testing.assert_array_equal(r.ids, sync[i].ids)
        np.testing.assert_array_equal(r.logits, sync[i].logits)


# ------------------------------------------------------- admission control --

def test_deadline_shed():
    eng = _engine()
    rt = AsyncRuntime(eng, start=False)
    futs = [rt.submit(np.zeros(32, np.float32), deadline_s=0.01)
            for _ in range(5)]
    time.sleep(0.05)                              # all five are now late
    rt.start()
    rt.drain(timeout=30.0)
    s = rt.stats()
    rt.close()
    assert s.n_shed_deadline == 5 and s.n_completed == 0
    for f in futs:
        with pytest.raises(DeadlineExceededError):
            f.result(timeout=5.0)


def test_deadline_met_when_on_time():
    eng = _engine()
    with AsyncRuntime(eng, default_deadline_s=30.0) as rt:
        f = rt.submit(np.zeros(32, np.float32))
        assert f.result(timeout=30.0).ids.shape == (5,)
        assert rt.stats().n_shed_deadline == 0


def test_bounded_queue_shed_policy():
    eng = _engine()
    rt = AsyncRuntime(eng, max_queue=2, policy="shed", start=False)
    futs = [rt.submit(np.zeros(32, np.float32)) for _ in range(5)]
    shed = [f for f in futs if f.done()]
    assert len(shed) == 3                         # queue bound of 2 held
    for f in shed:
        with pytest.raises(QueueFullError):
            f.result()
    assert rt.stats().n_shed_queue == 3
    rt.start()
    rt.drain(timeout=30.0)
    assert rt.stats().n_completed == 2
    rt.close()


def test_block_policy_backpressure():
    eng = _engine()
    rt = AsyncRuntime(eng, max_queue=1, policy="block", start=False)
    rt.submit(np.zeros(32, np.float32))           # fills the queue
    blocked_fut = []
    t = threading.Thread(target=lambda: blocked_fut.append(
        rt.submit(np.ones(32, np.float32))))
    t.start()
    time.sleep(0.05)
    assert t.is_alive()                           # producer is blocked
    rt.start()                                    # dispatcher frees space
    t.join(timeout=10.0)
    assert not t.is_alive()
    rt.drain(timeout=30.0)
    assert blocked_fut[0].result(timeout=5.0) is not None
    assert rt.stats().n_completed == 2
    rt.close()


def test_block_policy_submit_timeout_sheds():
    eng = _engine()
    rt = AsyncRuntime(eng, max_queue=1, policy="block", start=False)
    rt.submit(np.zeros(32, np.float32))
    f = rt.submit(np.zeros(32, np.float32), timeout=0.02)
    with pytest.raises(QueueFullError):
        f.result()
    assert rt.stats().n_shed_queue == 1
    rt.close()


def test_malformed_request_fails_its_chunk_only():
    """A request the head cannot trace (wrong feature dim) fails ITS
    futures; the runtime keeps serving everyone else instead of dying."""
    eng = _engine(buckets=(8,))
    with AsyncRuntime(eng) as rt:
        bad = rt.submit(np.zeros(33, np.float32))     # d=33 != 32
        assert bad.exception(timeout=30.0) is not None
        good = rt.submit(np.zeros(32, np.float32))
        assert good.result(timeout=30.0).ids.shape == (5,)
        s = rt.stats()
    assert s.n_completed == 1 and s.n_submitted == 2


# ----------------------------------------------------------- drain / close --

def test_drain_on_close_completes_all_inflight():
    eng = _engine(buckets=(1, 2, 4, 8))
    rt = AsyncRuntime(eng, max_queue=256)
    futs = [rt.submit(np.full(32, i, np.float32)) for i in range(30)]
    rt.close()                                    # graceful: drains first
    assert all(f.done() for f in futs)
    assert all(f.exception() is None for f in futs)
    assert rt.stats().n_completed == 30
    with pytest.raises(RuntimeClosedError):       # closed for business
        rt.submit(np.zeros(32, np.float32)).result()


def test_close_never_started_fails_pending():
    eng = _engine()
    rt = AsyncRuntime(eng, start=False)
    futs = [rt.submit(np.zeros(32, np.float32)) for _ in range(3)]
    rt.close()
    for f in futs:
        with pytest.raises(RuntimeClosedError):
            f.result(timeout=1.0)


def test_close_timeout_still_stops_runtime():
    """A drain timeout inside close() must still shut the workers down
    and fail the undrained backlog — not leave a zombie runtime that a
    second close() silently ignores."""
    eng = _engine(buckets=(8,))
    rt = AsyncRuntime(eng, max_queue=4096)
    futs = [rt.submit(np.zeros(32, np.float32)) for _ in range(512)]
    with pytest.raises(TimeoutError):
        rt.close(timeout=1e-4)                    # cannot drain in 0.1ms
    for t in rt._threads:
        t.join(timeout=10.0)
    assert not any(t.is_alive() for t in rt._threads)
    # every future resolves: completed, or failed with RuntimeClosedError
    for f in futs:
        exc = f.exception(timeout=10.0)
        assert exc is None or isinstance(exc, RuntimeClosedError)
    assert any(isinstance(f.exception(0), RuntimeClosedError)
               for f in futs), "want some undrained requests failed"
    rt.close()                                    # now a no-op


def test_close_is_idempotent_and_context_manager():
    eng = _engine()
    with AsyncRuntime(eng) as rt:
        rt.submit(np.zeros(32, np.float32)).result(timeout=30.0)
    rt.close()                                    # second close: no-op


# ------------------------------------------------------------------ stats --

def test_stats_latency_occupancy_and_engine_metrics():
    eng = _engine(buckets=(8,))
    eng.reset_metrics()
    labels = np.arange(16, dtype=np.int32)
    with AsyncRuntime(eng, start=False) as rt:
        futs = [rt.submit(np.zeros(32, np.float32) + i, labels=labels[i])
                for i in range(16)]
        rt.start()
        rt.drain(timeout=60.0)
        s = rt.stats()
    assert all(f.result(5.0) is not None for f in futs)
    assert s.n_submitted == s.n_completed == 16
    assert s.n_batches == 2 and s.avg_batch_occupancy == 1.0
    assert s.latency_p50_ms > 0
    assert s.latency_p50_ms <= s.latency_p95_ms <= s.latency_p99_ms
    assert s.wall_s > 0 and s.throughput_rps > 0
    # queue-wait-inclusive client latency >= pure device wall per batch
    assert s.latency_p99_ms >= s.device_ms_per_batch / 2
    # the runtime records into the engine's metrics window too
    m = eng.metrics()
    assert m.n_requests == 16
    assert 0.0 <= m.label_recall <= 1.0


# -------------------------------------------------- engine thread safety --

def test_engine_submit_is_thread_safe():
    """Racing Engine.submit from many threads must lose no requests and
    assign unique rids (the pre-lock engine raced ``_pending``)."""
    eng = _engine(buckets=(1, 2, 4, 8))
    n_threads, per_thread = 8, 25
    rng = np.random.default_rng(0)
    xs = rng.standard_normal((n_threads * per_thread, 32)).astype(np.float32)
    rids: list[int] = []
    barrier = threading.Barrier(n_threads)

    def producer(t):
        got = []
        barrier.wait()
        for i in range(t * per_thread, (t + 1) * per_thread):
            got.append(eng.submit(xs[i]))
        rids.extend(got)                          # one append per thread

    threads = [threading.Thread(target=producer, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60.0)
    res = eng.flush()
    assert len(rids) == len(set(rids)) == n_threads * per_thread
    assert sorted(r.rid for r in res) == sorted(rids)
