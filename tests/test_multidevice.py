"""Multi-device integration (subprocess: own XLA_FLAGS, 8 fake devices).

Covers: vocab-sharded LSS == single-device LSS; sharded train step runs;
gradient compression all-reduce matches fp32 mean within error-feedback
bounds; mini dry-run (lower+compile) for one LM and one recsys cell on a
(2, 4) debug mesh.
"""

import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import functools
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.utils import compat

mesh = compat.make_mesh((2, 4), ("data", "model"),
                        axis_types=compat.auto_axis_types(2))

# ---- 1. vocab-sharded LSS == single-device LSS -------------------------
from repro.core import simhash
from repro.core.lss import LSSConfig, build_index, lss_predict
from repro.core.sharded import build_local_index, sharded_lss_predict

key = jax.random.PRNGKey(0)
m, d, bq, tp = 256, 32, 8, 4
w = jax.random.normal(key, (m, d))
q = jax.random.normal(jax.random.PRNGKey(1), (bq, d))
cfg = LSSConfig(k_bits=3, n_tables=2)
theta = simhash.init_hyperplanes(jax.random.PRNGKey(2), d + 1, 3, 2)
w_aug = simhash.augment_neurons(w, None)
m_local = m // tp
locals_ = [build_local_index(w_aug[i*m_local:(i+1)*m_local], theta, cfg)
           for i in range(tp)]
stack = jax.tree.map(lambda *xs: jnp.stack(xs), *locals_)

body = functools.partial(sharded_lss_predict, k=6, axis_name="model",
                         m_local=m_local)
def unstack(qq, idx):
    return body(qq, jax.tree.map(lambda x: x[0], idx), None)
idx_specs = jax.tree.map(lambda _: P("model"), stack)
with compat.set_mesh(mesh):
    fn = jax.jit(compat.shard_map(unstack, mesh=mesh,
                                  in_specs=(P(), idx_specs),
                                  out_specs=(P(), P())))
    logits_sh, ids_sh = fn(q, stack)

# single-device oracle: per-shard local top-k then global merge
want_ids = []
for i in range(bq):
    cands = []
    for s, loc in enumerate(locals_):
        lg, ids = lss_predict(q[i:i+1], loc, None, top_k=6)
        for ll, ii in zip(np.asarray(lg[0]), np.asarray(ids[0])):
            if ii >= 0:
                cands.append((float(ll), int(ii) + s * m_local))
    cands.sort(key=lambda t: -t[0])
    want_ids.append([c[1] for c in cands[:6]])
got = np.asarray(ids_sh)
for i in range(bq):
    got_valid = [int(x) for x in got[i] if x >= 0]
    assert got_valid == want_ids[i][:len(got_valid)] \
        and len(got_valid) == min(6, len(want_ids[i])), \
        (i, got[i], want_ids[i])
print("SHARDED-LSS-OK")

# ---- 2. sharded LM train step runs + loss finite -----------------------
from repro.configs.reduced import reduced_model_cfg
from repro.models import transformer as T
from repro.train.trainer import TrainConfig, Trainer
from repro.data.pipeline import ShardedBatchIterator
from repro.data.synthetic import lm_dataset

cfg_lm = reduced_model_cfg("qwen2-0.5b")._replace(vocab=512)
toks = lm_dataset(0, 64 * 33 * 8, 512, 33)
tr = Trainer(lambda p, b: T.lm_loss(p, b, cfg_lm),
             lambda k: T.init_params(k, cfg_lm),
             TrainConfig(lr=1e-3, warmup_steps=2, total_steps=8,
                         ckpt_every=10**9),
             mesh=mesh, param_specs=T.param_specs(cfg_lm))
it = ShardedBatchIterator({"tokens": toks[:, :-1], "labels": toks[:, 1:]},
                          16, mesh=mesh)
state, hist = tr.fit(jax.random.PRNGKey(0), it, 8, log_every=4)
assert np.isfinite(hist[-1]["loss"])
print("SHARDED-TRAIN-OK")

# ---- 3. int8 error-feedback compressed all-reduce ----------------------
from repro.optim.compression import compressed_psum, init_error_state
gmesh = compat.make_mesh((8,), ("pod",),
                         axis_types=compat.auto_axis_types(1))
g = {"w": jax.random.normal(jax.random.PRNGKey(5), (8, 64)) * 0.1}
err = {"w": jnp.zeros((8, 64))}
def body2(gg, ee):
    return compressed_psum(gg, ee, "pod")
with compat.set_mesh(gmesh):
    out, new_err = jax.jit(compat.shard_map(
        body2, mesh=gmesh,
        in_specs=({"w": P("pod", None)}, {"w": P("pod", None)}),
        out_specs=({"w": P("pod", None)}, {"w": P("pod", None)})))(g, err)
true_mean = jnp.mean(g["w"], axis=0)
got_rows = np.asarray(out["w"])
for r in range(8):
    err_abs = np.abs(got_rows[r] - np.asarray(true_mean))
    assert err_abs.max() < 5e-3, err_abs.max()
# error feedback state carries the quantization residual
assert float(jnp.abs(new_err["w"]).max()) > 0
print("COMPRESSION-OK")

# ---- 4. mini dry-run: lower + compile one cell per family --------------
from repro.launch.steps import build_cell
for arch, shape in (("qwen2-0.5b", "decode_32k"), ("deepfm", "serve_p99"),
                    ("gcn-cora", "molecule")):
    # shrink: reuse the production builder on the debug mesh
    cell = build_cell(arch, shape, mesh, lm_layers=2) \
        if arch == "qwen2-0.5b" else build_cell(arch, shape, mesh)
    with compat.set_mesh(mesh):
        compiled = jax.jit(cell.fn, in_shardings=cell.in_shardings
                           ).lower(*cell.args).compile()
    assert compat.cost_analysis(compiled)["flops"] > 0
    print(f"MINIDRY-{arch}-OK")
print("ALL-OK")
"""


@pytest.mark.slow
def test_multidevice_suite():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    for marker in ("SHARDED-LSS-OK", "SHARDED-TRAIN-OK", "COMPRESSION-OK",
                   "ALL-OK"):
        assert marker in proc.stdout, proc.stdout[-2000:]
