"""Unified serving engine: batcher shape-stability, head parity, and
single-pass metrics correctness."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import simhash
from repro.core.lss import LSSConfig, avg_sample_size, label_recall, retrieve
from repro.serve.batcher import MicroBatcher
from repro.serve.engine import Engine


def _engine(m=512, d=32, k_bits=4, n_tables=2, top_k=5, buckets=(1, 2, 4, 8),
            bucket_major=True):
    w = jax.random.normal(jax.random.PRNGKey(0), (m, d))
    eng = Engine(None, w, None,
                 LSSConfig(k_bits=k_bits, n_tables=n_tables,
                           use_bucket_major=bucket_major),
                 top_k=top_k, head="lss", buckets=buckets)
    eng.fit_random(jax.random.PRNGKey(1))
    return eng


# ------------------------------------------------------------- batcher --

def test_batcher_bucket_ladder():
    b = MicroBatcher((1, 2, 4, 8))
    assert [b.bucket_for(n) for n in (1, 2, 3, 5, 8)] == [1, 2, 4, 8, 8]
    with pytest.raises(ValueError):
        b.bucket_for(9)
    # 19 requests -> two full max buckets + one bucketed remainder
    assert [(c.size, c.bucket) for c in b.plan(19)] == \
        [(8, 8), (8, 8), (3, 4)]
    assert b.plan(0) == []


def test_batcher_pad_rows():
    b = MicroBatcher((4,))
    x = {"a": np.ones((3, 5)), "b": np.arange(3)}
    p = b.pad_rows(x, 4)
    assert p["a"].shape == (4, 5) and p["b"].shape == (4,)
    assert p["a"][3].sum() == 0 and p["b"][3] == 0


# ------------------------------------------- shape-stable compilation --

def test_no_recompile_across_arrival_patterns():
    """Any arrival pattern maps onto the fixed bucket ladder, so traces
    happen once per (head, bucket) no matter how traffic arrives."""
    eng = _engine(buckets=(1, 2, 4, 8))
    rng = np.random.default_rng(0)

    def drive(pattern):
        for n in pattern:
            for _ in range(n):
                eng.submit(rng.standard_normal(32).astype(np.float32))
            eng.flush()

    drive([3, 5, 2, 7, 1])
    counts1 = dict(eng.compile_counts)
    assert all(v == 1 for v in counts1.values())
    # a completely different arrival pattern: zero new compilations for
    # already-seen buckets, at most the missing ladder entries otherwise
    drive([7, 2, 3, 8, 8, 5, 1, 4, 6])
    for key, v in eng.compile_counts.items():
        assert v == 1, f"{key} recompiled: {v} traces"
    # every step was an ('lss', bucket) pair from the ladder
    assert all(k[0] == "lss" and k[1] in (1, 2, 4, 8)
               for k in eng.compile_counts)


def test_oversize_group_splits_into_max_buckets():
    eng = _engine(buckets=(4, 8))
    q = np.asarray(jax.random.normal(jax.random.PRNGKey(5), (20, 32)))
    out = eng.rank(q, record=False)            # 20 -> 8 + 8 + 4
    assert out.ids.shape == (20, 5)
    assert set(eng.compile_counts) == {("lss", 8), ("lss", 4)}


# ----------------------------------------------------------- parity --

def test_head_parity_full_lss_sharded():
    """When LSS retrieves the full head's argmax, all three heads agree
    on top-1; lss and lss-sharded agree everywhere (TP=1 shard)."""
    eng = _engine(m=256, d=16, k_bits=3)
    q = np.asarray(jax.random.normal(jax.random.PRNGKey(7), (8, 16)))
    full = eng.rank(q, head="full", record=False)
    lss = eng.rank(q, head="lss", record=False)
    sh = eng.rank(q, head="lss-sharded", record=False)
    np.testing.assert_array_equal(np.asarray(lss.ids), np.asarray(sh.ids))
    np.testing.assert_allclose(np.asarray(lss.logits),
                               np.asarray(sh.logits), rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(lss.sample_size),
                                  np.asarray(sh.sample_size))
    cand = np.asarray(lss.cand_ids)
    full_top1 = np.asarray(full.ids[:, 0])
    retrieved = [(full_top1[i] == cand[i]).any() for i in range(8)]
    assert any(retrieved), "degenerate test: no query retrieved its argmax"
    for i in range(8):
        if retrieved[i]:
            assert int(lss.ids[i, 0]) == int(full_top1[i])
            assert int(sh.ids[i, 0]) == int(full_top1[i])


def test_sharded_pads_missing_candidates_with_minus_one():
    """top_k > retrieved candidates: the sharded head must report -1 for
    the padded slots exactly like the single-device head, not arbitrary
    duplicate ids surviving the all-gather."""
    # C = 2 tables x 8 capacity = 16 >= top_k, but cross-table duplicates
    # leave fewer than top_k unique candidates per query
    eng = _engine(m=64, d=16, k_bits=4, n_tables=2, top_k=12,
                  buckets=(4,))
    q = np.asarray(jax.random.normal(jax.random.PRNGKey(11), (4, 16)))
    lss = eng.rank(q, head="lss", record=False)
    sh = eng.rank(q, head="lss-sharded", record=False)
    assert (np.asarray(lss.ids) == -1).any(), "want padded slots"
    np.testing.assert_array_equal(np.asarray(lss.ids), np.asarray(sh.ids))


def test_rank_accepts_1d_labels():
    eng = _engine(m=256, d=16)
    q = np.asarray(jax.random.normal(jax.random.PRNGKey(12), (4, 16)))
    eng.reset_metrics()
    eng.rank(q, head="lss", labels=np.array([1, 2, 3, 4], np.int32))
    assert 0.0 <= eng.metrics().label_recall <= 1.0


def test_reset_metrics_keeps_pending_results():
    eng = _engine(m=256, d=16, buckets=(1, 2))
    rids = [eng.submit(np.zeros(16, np.float32)) for _ in range(3)]
    # 3 submits > max bucket 2 -> one group auto-flushed already
    eng.reset_metrics()
    res = eng.flush()
    assert [r.rid for r in res] == rids


def test_full_head_sample_size_is_m():
    eng = _engine(m=256, d=16)
    q = np.asarray(jax.random.normal(jax.random.PRNGKey(2), (4, 16)))
    out = eng.rank(q, head="full", record=False)
    assert (np.asarray(out.sample_size) == 256).all()


# ----------------------------------------------------------- metrics --

def test_metrics_sample_size_matches_single_retrieval_pass():
    """avg_sample_size reported by the engine must equal the paper metric
    computed independently from a fresh retrieve() over the same queries —
    proving the serving pass and the metric share one retrieval."""
    eng = _engine(m=512, d=32)
    q = np.asarray(jax.random.normal(jax.random.PRNGKey(3), (8, 32)))
    eng.reset_metrics()
    out = eng.rank(q, head="lss")
    cand, _ = retrieve(simhash.augment_queries(jnp.asarray(q)), eng.index)
    want = float(avg_sample_size(cand))
    got = eng.metrics().avg_sample_size
    assert got == pytest.approx(want, rel=1e-6)
    # and the per-query sizes came from the same pass as the ranking
    assert float(jnp.mean(out.sample_size)) == pytest.approx(want, rel=1e-6)


def test_metrics_label_recall_and_latency():
    eng = _engine(m=512, d=32)
    q = np.asarray(jax.random.normal(jax.random.PRNGKey(4), (8, 32)))
    labels = np.asarray(jax.random.randint(jax.random.PRNGKey(5),
                                           (8, 2), 0, 512), np.int32)
    eng.reset_metrics()
    out = eng.rank(q, head="lss", labels=labels)
    m = eng.metrics()
    want = float(label_recall(out.cand_ids, jnp.asarray(labels)))
    assert m.label_recall == pytest.approx(want, rel=1e-6)
    assert m.n_requests == 8
    assert m.wall_s > 0 and m.throughput_rps > 0
    assert m.latency_p99_ms >= m.latency_p50_ms > 0


def test_metrics_nan_recall_without_labels():
    eng = _engine()
    eng.reset_metrics()
    eng.rank(np.zeros((2, 32), np.float32))
    assert math.isnan(eng.metrics().label_recall)


# ------------------------------------------------------ request layer --

def test_submit_flush_roundtrip_order_and_results():
    eng = _engine(m=256, d=16, buckets=(1, 2, 4))
    rng = np.random.default_rng(1)
    xs = rng.standard_normal((11, 16)).astype(np.float32)
    rids = [eng.submit(xs[i]) for i in range(11)]
    res = eng.flush()
    assert [r.rid for r in res] == sorted(rids)
    assert all(r.ids.shape == (5,) for r in res)
    # flushing a ranked batch == ranking it directly
    direct = eng.rank(xs, record=False)
    np.testing.assert_array_equal(
        np.stack([r.ids for r in res]), np.asarray(direct.ids))


@pytest.mark.slow
def test_serving_throughput_smoke():
    """End-to-end: a few hundred ragged submissions through the bucketed
    batcher; sane latency distribution and stable compile counts."""
    eng = _engine(m=2048, d=32, k_bits=5, buckets=(1, 2, 4, 8, 16, 32))
    rng = np.random.default_rng(0)
    total = 0
    while total < 400:
        n = int(rng.integers(1, 40))
        for _ in range(n):
            eng.submit(rng.standard_normal(32).astype(np.float32),
                       labels=int(rng.integers(0, 2048)))
        eng.flush()
        total += n
    m = eng.metrics()
    assert m.n_requests == total
    assert m.throughput_rps > 100          # CPU does thousands of req/s
    assert m.latency_p50_ms <= m.latency_p95_ms <= m.latency_p99_ms
    assert 0 < m.avg_sample_size < 2048
    assert 0 <= m.label_recall <= 1
    assert m.n_compiles <= 6               # one per bucket in the ladder
