"""Transformer: loss/grads finite, decode==forward, scan==unroll."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as T


def _cfg(**kw):
    base = dict(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                head_dim=16, d_ff=128, vocab=256, dtype=jnp.float32,
                kv_chunk=16, q_chunk=64)
    base.update(kw)
    return T.TransformerConfig(**base)


@pytest.mark.parametrize("cfg", [
    _cfg(qkv_bias=True, qk_norm=True),
    _cfg(moe_style="replace", n_experts=4, n_experts_padded=4, moe_top_k=2,
         moe_d_ff=64, shared_expert_ff=96, capacity_factor=4.0),
    _cfg(moe_style="parallel", n_experts=4, n_experts_padded=4,
         moe_top_k=2, moe_d_ff=64, capacity_factor=4.0,
         tie_embeddings=True),
], ids=["dense", "moe-shared", "moe-parallel"])
def test_decode_matches_forward(cfg):
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    loss = T.lm_loss(params, batch, cfg)
    assert jnp.isfinite(loss)
    hidden, cache = T.prefill(params, tokens, cfg, max_len=24)
    nxt = jnp.argmax(T.logits_head(params, hidden[:, -1:], cfg)[:, 0], -1)
    h2, cache2 = T.decode_step(params, nxt, cache, cfg)
    toks2 = jnp.concatenate([tokens, nxt[:, None]], 1)
    hidden_full, _, _ = T.forward(params, toks2, cfg, mode="train")
    err = float(jnp.abs(hidden_full[:, -1] - h2).max())
    assert err < 1e-3, err
    assert int(cache2.length) == 18


def test_scan_equals_unroll():
    cfg = _cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    l_scan = T.lm_loss(params, batch, cfg)
    l_unroll = T.lm_loss(params, batch, cfg._replace(layers_impl="unroll"))
    np.testing.assert_allclose(float(l_scan), float(l_unroll), rtol=1e-5)
    # decode paths too
    hidden, cache = T.prefill(params, tokens, cfg, max_len=20)
    tok = tokens[:, 0]
    h_s, _ = T.decode_step(params, tok, cache, cfg)
    h_u, _ = T.decode_step(params, tok, cache,
                           cfg._replace(layers_impl="unroll"))
    np.testing.assert_allclose(np.asarray(h_s), np.asarray(h_u),
                               rtol=1e-4, atol=1e-5)


def test_gold_logit_matches_take_along():
    logits = jax.random.normal(jax.random.PRNGKey(0), (2, 5, 32))
    labels = jax.random.randint(jax.random.PRNGKey(1), (2, 5), 0, 32)
    want = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    got = T.gold_logit(logits, labels)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_grads_flow_everywhere():
    cfg = _cfg(qkv_bias=True, qk_norm=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    g = jax.grad(lambda p: T.lm_loss(p, {"tokens": tokens,
                                         "labels": tokens}, cfg))(params)
    for path, leaf in jax.tree_util.tree_flatten_with_path(g)[0]:
        assert bool(jnp.isfinite(leaf).all()), path
        assert float(jnp.abs(leaf).sum()) > 0, path
