"""Kernel registry dispatch + fused lss_topk parity.

The acceptance bar for the fused serving path: interpret-mode kernels are
BIT-IDENTICAL to the jnp refs (assert_array_equal, no tolerances), and an
Engine pinned to ``pallas_interpret`` serves end-to-end through the fused
op (proven by the registry dispatch log, not by construction).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import simhash
from repro.core.lss import LSSConfig, build_index, lss_forward
from repro.kernels import bucket_logits, lss_topk, registry, simhash_codes
from repro.serve.engine import Engine


@pytest.fixture(autouse=True)
def _clean_registry():
    registry.set_default_impl(None)
    registry.reset_dispatch_log()
    yield
    registry.set_default_impl(None)


def _fitted_index(m, d, k, l, seed=0, bucket_major=True):
    w = jax.random.normal(jax.random.PRNGKey(seed), (m, d))
    cfg = LSSConfig(k_bits=k, n_tables=l, use_bucket_major=bucket_major)
    w_aug = simhash.augment_neurons(w, None)
    theta = simhash.init_hyperplanes(jax.random.PRNGKey(seed + 1),
                                     d + 1, k, l)
    return build_index(w_aug, theta, cfg), w_aug


# ------------------------------------------------------------ registry --

def test_ops_registered_with_all_impls():
    for name in ("simhash_codes", "bucket_logits", "lss_topk"):
        op = registry.get_op(name)
        assert set(op.impls) == {"ref", "pallas", "pallas_interpret"}, name


def test_auto_resolution_prefers_ref_off_tpu():
    assert jax.default_backend() != "tpu"   # CI is CPU
    for name in registry.list_ops():
        assert registry.resolve_impl(name) == "ref"


def test_explicit_impl_wins_over_global_override():
    with registry.use_impl("pallas_interpret"):
        assert registry.resolve_impl("lss_topk") == "pallas_interpret"
        assert registry.resolve_impl("lss_topk", "ref") == "ref"
    assert registry.resolve_impl("lss_topk") == "ref"


def test_env_override(monkeypatch):
    monkeypatch.setenv(registry.ENV_VAR, "pallas_interpret")
    assert registry.resolve_impl("bucket_logits") == "pallas_interpret"
    # global override beats the env var
    with registry.use_impl("ref"):
        assert registry.resolve_impl("bucket_logits") == "ref"
    monkeypatch.setenv(registry.ENV_VAR, "not_an_impl")
    with pytest.raises(ValueError):
        registry.resolve_impl("bucket_logits")


def test_unknown_impl_rejected():
    with pytest.raises(ValueError):
        registry.resolve_impl("lss_topk", "cuda")
    with pytest.raises(KeyError):
        registry.resolve_impl("definitely_not_an_op")
    with pytest.raises(ValueError):
        registry.set_default_impl("cuda")


def test_dispatch_log_records_op_and_impl():
    q = jax.random.normal(jax.random.PRNGKey(0), (4, 16))
    theta = jax.random.normal(jax.random.PRNGKey(1), (16, 6))
    registry.reset_dispatch_log()
    simhash_codes(q, theta, 3, 2, impl="ref")
    simhash_codes(q, theta, 3, 2, impl="pallas_interpret", block_b=4)
    assert registry.dispatch_log() == (
        ("simhash_codes", "ref"), ("simhash_codes", "pallas_interpret"))
    assert registry.last_dispatch("simhash_codes") == "pallas_interpret"
    assert registry.dispatch_counts()[("simhash_codes", "ref")] == 1


# ------------------------------------- sub-op bit-exact parity (edge d/P) --

@pytest.mark.parametrize("b,d,k,l", [
    (64, 128, 4, 1), (32, 129, 6, 3), (16, 31, 2, 4), (128, 897, 10, 1),
])
def test_simhash_codes_interpret_bit_exact(b, d, k, l):
    x = jax.random.normal(jax.random.PRNGKey(b + d), (b, d))
    theta = jax.random.normal(jax.random.PRNGKey(1), (d, k * l))
    ref = simhash_codes(x, theta, k, l, impl="ref")
    out = simhash_codes(x, theta, k, l, impl="pallas_interpret", block_b=16)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


@pytest.mark.parametrize("b,d,s,p,l", [
    (16, 128, 32, 128, 1), (8, 100, 48, 96, 3), (4, 64, 8, 256, 2),
    (32, 897, 16, 24, 1), (8, 31, 12, 17, 2),
])
def test_bucket_logits_interpret_bit_exact(b, d, s, p, l):
    q = jax.random.normal(jax.random.PRNGKey(b * p), (b, d))
    w = jax.random.normal(jax.random.PRNGKey(1), (s, p, d))
    ids = jax.random.randint(jax.random.PRNGKey(2), (b, l), 0, s)
    ref = bucket_logits(q, w, ids, impl="ref")
    out = bucket_logits(q, w, ids, impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


# -------------------------------------------- fused lss_topk bit-exact --

@pytest.mark.parametrize("m,d,k,l,b", [
    (200, 16, 3, 2, 32),      # small everything
    (150, 31, 4, 1, 16),      # d+1 = 32, single table
    (300, 63, 4, 3, 8),       # d not a lane multiple, 3-way dedup
    (64, 127, 5, 2, 4),       # d_aug = 128 exactly
    (500, 40, 6, 4, 64),      # deep K: empty buckets likely
])
def test_lss_topk_interpret_matches_ref_bit_exact(m, d, k, l, b):
    index, _ = _fitted_index(m, d, k, l, seed=m + d)
    q = jax.random.normal(jax.random.PRNGKey(m), (b, d))
    q_aug = simhash.augment_queries(q).astype(jnp.float32)
    t = index.tables
    ref = lss_topk(q_aug, index.theta, t.table_ids, index.w_bucketed,
                   top_k=5, impl="ref")
    out = lss_topk(q_aug, index.theta, t.table_ids, index.w_bucketed,
                   top_k=5, impl="pallas_interpret")
    for name, r, o in zip(("top_logits", "top_ids", "sample", "cand"),
                          ref, out):
        np.testing.assert_array_equal(np.asarray(r), np.asarray(o),
                                      err_msg=name)


@pytest.mark.parametrize("m,d,k,l", [(200, 16, 3, 2), (300, 63, 4, 3)])
def test_lss_forward_pallas_interpret_matches_ref(m, d, k, l):
    """Full lss_forward routing: impl flows core -> registry -> kernel."""
    index, _ = _fitted_index(m, d, k, l, seed=7)
    q = jax.random.normal(jax.random.PRNGKey(3), (16, d))
    ref = lss_forward(q, index, None, top_k=5, impl="ref")
    out = lss_forward(q, index, None, top_k=5, impl="pallas_interpret")
    for name, r, o in zip(ref._fields, ref, out):
        np.testing.assert_array_equal(np.asarray(r), np.asarray(o),
                                      err_msg=name)


def test_lss_topk_all_padding_bucket():
    """A query whose slab is entirely -1 must yield all -1 ids, NEG_INF
    logits, and sample size 0 — identically in ref and interpret mode."""
    d, k, l, cap = 8, 2, 1, 4
    theta = jax.random.normal(jax.random.PRNGKey(0), (d, k * l))
    # hand-built index: every bucket empty except bucket 0
    table_ids = jnp.full((l, 2 ** k, cap), -1, jnp.int32)
    table_ids = table_ids.at[0, 0].set(jnp.arange(cap))
    w_bucketed = jnp.zeros((l, 2 ** k, cap, d), jnp.float32)
    w_bucketed = w_bucketed.at[0, 0].set(
        jax.random.normal(jax.random.PRNGKey(1), (cap, d)))
    q_aug = jax.random.normal(jax.random.PRNGKey(2), (32, d))
    ref = lss_topk(q_aug, theta, table_ids, w_bucketed, top_k=3, impl="ref")
    out = lss_topk(q_aug, theta, table_ids, w_bucketed, top_k=3,
                   impl="pallas_interpret")
    for name, r, o in zip(("top_logits", "top_ids", "sample", "cand"),
                          ref, out):
        np.testing.assert_array_equal(np.asarray(r), np.asarray(o),
                                      err_msg=name)
    empty = np.asarray(ref[2]) == 0            # queries hashed to a -1 slab
    assert empty.any(), "degenerate: no query hit an empty bucket"
    np.testing.assert_array_equal(np.asarray(ref[1])[empty], -1)


def test_lss_topk_dtype_bf16_slabs():
    """bf16 slabs upcast in-kernel exactly like the ref einsum."""
    index, _ = _fitted_index(128, 32, 3, 2, seed=5)
    wb = index.w_bucketed.astype(jnp.bfloat16)
    index = index._replace(w_bucketed=wb)
    q_aug = simhash.augment_queries(
        jax.random.normal(jax.random.PRNGKey(0), (8, 32)))
    t = index.tables
    ref = lss_topk(q_aug, index.theta, t.table_ids, wb, top_k=4, impl="ref")
    out = lss_topk(q_aug, index.theta, t.table_ids, wb, top_k=4,
                   impl="pallas_interpret")
    for r, o in zip(ref, out):
        np.testing.assert_array_equal(np.asarray(r), np.asarray(o))


# ------------------------------------------------- engine end-to-end --

def _engine(impl, m=512, d=32, seed=1, head="lss", buckets=(8,)):
    w = jax.random.normal(jax.random.PRNGKey(0), (m, d))
    eng = Engine(None, w, None,
                 LSSConfig(k_bits=4, n_tables=2, use_bucket_major=True),
                 top_k=5, head=head, buckets=buckets, impl=impl)
    eng.fit_random(jax.random.PRNGKey(seed))
    return eng


def test_engine_pallas_interpret_serves_through_fused_kernel():
    q = np.asarray(jax.random.normal(jax.random.PRNGKey(7), (8, 32)))
    ref_eng = _engine("ref")
    fused_eng = _engine("pallas_interpret")
    registry.reset_dispatch_log()
    ref_out = ref_eng.rank(q, record=False)
    out = fused_eng.rank(q, record=False)
    # the registry actually dispatched the fused op for the serving step
    assert ("lss_topk", "pallas_interpret") in registry.dispatch_log()
    assert registry.last_dispatch("lss_topk") == "pallas_interpret"
    for name, r, o in zip(("logits", "ids", "sample_size", "cand_ids"),
                          ref_out, out):
        np.testing.assert_array_equal(np.asarray(r), np.asarray(o),
                                      err_msg=name)


def test_engine_pallas_interpret_submit_flush_roundtrip():
    fused_eng = _engine("pallas_interpret", buckets=(1, 2, 4))
    ref_eng = _engine("ref", buckets=(1, 2, 4))
    rng = np.random.default_rng(0)
    xs = rng.standard_normal((5, 32)).astype(np.float32)
    for eng in (fused_eng, ref_eng):
        for i in range(5):
            eng.submit(xs[i], labels=i % 3)
    got = fused_eng.flush()
    want = ref_eng.flush()
    for g, w_ in zip(got, want):
        assert g.rid == w_.rid
        np.testing.assert_array_equal(g.ids, w_.ids)
        np.testing.assert_array_equal(g.logits, w_.logits)
    m = fused_eng.metrics()
    assert m.n_requests == 5 and m.avg_sample_size > 0


def test_engine_sharded_head_with_interpret_impl():
    """The fused kernel also runs inside shard_map (TP=1 mesh on CPU)."""
    eng = _engine("pallas_interpret")
    q = np.asarray(jax.random.normal(jax.random.PRNGKey(9), (4, 32)))
    lss = eng.rank(q, head="lss", record=False)
    registry.reset_dispatch_log()
    sh = eng.rank(q, head="lss-sharded", record=False)
    assert ("lss_topk", "pallas_interpret") in registry.dispatch_log()
    np.testing.assert_array_equal(np.asarray(lss.ids), np.asarray(sh.ids))
    np.testing.assert_array_equal(np.asarray(lss.sample_size),
                                  np.asarray(sh.sample_size))


def test_engine_rejects_unknown_impl():
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 8))
    with pytest.raises(ValueError):
        Engine(None, w, impl="cuda")


# ------------------------------------------------- shard_index padding --

def test_shard_index_pads_non_divisible_vocab():
    from repro.core.sharded import local_topk
    from repro.serve.heads import shard_index
    m, d, n_shards = 13, 8, 2
    w = jax.random.normal(jax.random.PRNGKey(3), (m, d))
    w_aug = simhash.augment_neurons(w, None)
    theta = simhash.init_hyperplanes(jax.random.PRNGKey(4), d + 1, 3, 2)
    cfg = LSSConfig(k_bits=3, n_tables=2, use_bucket_major=True)
    stack, w_stack, m_local = shard_index(w_aug, theta, cfg, n_shards)
    assert m_local == 7
    ids = np.asarray(stack.tables.table_ids)
    # the final shard owns rows 7..12 -> 6 real rows; padding never enters
    assert ids[1].max() < 6
    # padded slab rows are zeroed
    wb = np.asarray(stack.w_bucketed[1])
    assert (wb[ids[1] < 0] == 0).all()
    # per-shard top-k == brute force over that query's retrieved REAL rows
    from repro.core.lss import retrieve
    q = jax.random.normal(jax.random.PRNGKey(5), (8, d))
    q_aug = simhash.augment_queries(q)
    w_np = np.asarray(w_aug)
    for s in range(n_shards):
        idx = jax.tree.map(lambda x: x[s], stack)
        n_valid = min(m - s * m_local, m_local)
        _, top_i = local_topk(q, idx, None, 3)
        cand_q = np.asarray(retrieve(q_aug, idx)[0])
        assert cand_q.max() < n_valid, "padding row retrieved"
        full = np.asarray(q_aug) @ w_np[s * m_local:s * m_local + n_valid].T
        for i in range(8):
            uniq = sorted(set(int(x) for x in cand_q[i] if x >= 0),
                          key=lambda j: -full[i, j])
            got = [int(x) for x in np.asarray(top_i[i]) if x >= 0]
            assert len(got) == min(3, len(uniq))
            assert got == uniq[:len(got)]


def test_shard_index_divisible_unchanged():
    from repro.serve.heads import shard_index
    w = jax.random.normal(jax.random.PRNGKey(3), (12, 8))
    w_aug = simhash.augment_neurons(w, None)
    theta = simhash.init_hyperplanes(jax.random.PRNGKey(4), 9, 3, 1)
    cfg = LSSConfig(k_bits=3, n_tables=1, use_bucket_major=True)
    stack, _, m_local = shard_index(w_aug, theta, cfg, 3)
    assert m_local == 4
    assert stack.tables.table_ids.shape[0] == 3
