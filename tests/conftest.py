"""Tests run on the single real CPU device (no fake device count here —
the dry-run is the ONLY 512-device entry point; multi-device tests spawn
subprocesses with their own XLA_FLAGS)."""

import numpy as np
import pytest

try:                                    # hypothesis is a dev-extra install;
    import hypothesis                   # noqa: F401
except ImportError:                     # fall back to a deterministic sweep
    from _hypothesis_stub import install as _install_hypothesis_stub
    _install_hypothesis_stub()


@pytest.fixture
def rng():
    return np.random.default_rng(0)
