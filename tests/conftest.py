"""Tests run on the single real CPU device (no fake device count here —
the dry-run is the ONLY 512-device entry point; multi-device tests spawn
subprocesses with their own XLA_FLAGS)."""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
