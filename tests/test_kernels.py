"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret
mode (the kernel body executes on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import bucket_logits, simhash_codes


@pytest.mark.parametrize("b,d,k,l", [
    (64, 128, 4, 1), (32, 129, 6, 3), (256, 64, 8, 2), (16, 31, 2, 4),
    (128, 897, 10, 1),
])
def test_simhash_codes_sweep(b, d, k, l):
    key = jax.random.PRNGKey(b + d)
    x = jax.random.normal(key, (b, d))
    theta = jax.random.normal(jax.random.PRNGKey(1), (d, k * l))
    ref = simhash_codes(x, theta, k, l, impl="ref")
    out = simhash_codes(x, theta, k, l, impl="pallas_interpret", block_b=16)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_simhash_codes_dtypes(dtype):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (32, 64)).astype(dtype)
    theta = jax.random.normal(jax.random.PRNGKey(1), (64, 8)).astype(dtype)
    ref = simhash_codes(x, theta, 4, 2, impl="ref")
    out = simhash_codes(x, theta, 4, 2, impl="pallas_interpret", block_b=32)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


@pytest.mark.parametrize("b,d,s,p,l", [
    (16, 128, 32, 128, 1), (8, 100, 48, 96, 3), (4, 64, 8, 256, 2),
    (32, 897, 16, 24, 1),
])
def test_bucket_logits_sweep(b, d, s, p, l):
    key = jax.random.PRNGKey(b * p)
    q = jax.random.normal(key, (b, d))
    w = jax.random.normal(jax.random.PRNGKey(1), (s, p, d))
    ids = jax.random.randint(jax.random.PRNGKey(2), (b, l), 0, s)
    ref = bucket_logits(q, w, ids, impl="ref")
    out = bucket_logits(q, w, ids, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype,rtol", [(jnp.float32, 1e-5),
                                        (jnp.bfloat16, 2e-2)])
def test_bucket_logits_dtypes(dtype, rtol):
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (8, 128)).astype(dtype)
    w = jax.random.normal(jax.random.PRNGKey(4), (16, 128, 128)).astype(dtype)
    ids = jax.random.randint(jax.random.PRNGKey(5), (8, 2), 0, 16)
    ref = bucket_logits(q, w, ids, impl="ref")
    out = bucket_logits(q, w, ids, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                               rtol=rtol, atol=rtol)


def test_bucket_logits_matches_full_index_pipeline():
    """End-to-end: kernel output == gather-path logits of the LSS index."""
    from repro.core import simhash as sh
    from repro.core.lss import LSSConfig, build_index, retrieve, \
        sparse_logits_gather
    key = jax.random.PRNGKey(0)
    m, d, n = 300, 63, 16
    w = jax.random.normal(key, (m, d))
    q = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    cfg = LSSConfig(k_bits=4, n_tables=2)
    w_aug = sh.augment_neurons(w, None)
    theta = sh.init_hyperplanes(jax.random.PRNGKey(2), d + 1, 4, 2)
    index = build_index(w_aug, theta, cfg)
    q_aug = sh.augment_queries(q)
    cand, buckets = retrieve(q_aug, index)
    want = sparse_logits_gather(q_aug, w_aug, cand)
    t = index.tables
    slabs = index.w_bucketed.reshape(-1, t.capacity, d + 1)
    slab_ids = buckets + jnp.arange(t.n_tables)[None, :] * t.n_buckets
    got = bucket_logits(q_aug, slabs, slab_ids, impl="pallas_interpret")
    got = got.reshape(n, -1)
    mask = np.asarray(cand) >= 0
    np.testing.assert_allclose(np.asarray(want)[mask],
                               np.asarray(got)[mask], rtol=1e-4, atol=1e-4)


def test_lss_topk_large_c_auto_switches_instead_of_warning():
    """Past the old ~2k comfort limit the registry now auto-switches to
    the bitonic dedup — no warning, because the bitonic working set
    still fits VMEM at this shape."""
    import warnings

    from repro.kernels import registry
    from repro.kernels.lss_topk import ops

    d_aug, cap = 8, 2560                        # C = 1 * 2560 > 2048
    q = jnp.zeros((1, d_aug))
    theta = jnp.ones((d_aug, 1))                # K=1 bit, L=1 table
    tids = jnp.full((1, 2, cap), -1, jnp.int32)
    wb = jnp.zeros((1, 2, cap, d_aug))
    registry.reset_dispatch_log()
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        ops.lss_topk(q, theta, tids, wb, top_k=3, impl="ref")
    assert ("lss_topk.dedup", "bitonic") in registry.dispatch_log()


def test_lss_topk_warns_once_past_vmem_budget():
    """The warning survives, but its limit is DERIVED from the shape:
    a dedup working set past the VMEM budget says so, once per shape."""
    import warnings

    from repro.kernels.lss_topk import ops

    d_aug, cap = 8, 2048                        # quadratic ws ~ 9*C^2
    assert ops.lss_topk_vmem_bytes(cap, d_aug, cap, dedup="quadratic") \
        > ops.VMEM_BUDGET_BYTES
    assert ops.lss_topk_vmem_bytes(cap, d_aug, cap, dedup="bitonic") \
        < ops.VMEM_BUDGET_BYTES
    q = jnp.zeros((1, d_aug))
    theta = jnp.ones((d_aug, 1))                # K=1 bit, L=1 table
    tids = jnp.full((1, 2, cap), -1, jnp.int32)
    wb = jnp.zeros((1, 2, cap, d_aug))
    ops._warn_vmem_exceeded.cache_clear()
    with pytest.warns(UserWarning, match=r"VMEM working set"):
        ops.lss_topk(q, theta, tids, wb, top_k=3, impl="ref",
                     dedup="quadratic")
    with warnings.catch_warnings():             # second call: silent
        warnings.simplefilter("error")
        ops.lss_topk(q, theta, tids, wb, top_k=3, impl="ref",
                     dedup="quadratic")
    # same shape under the auto-selected bitonic strategy: never warns
    ops._warn_vmem_exceeded.cache_clear()
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        ops.lss_topk(q, theta, tids, wb, top_k=3, impl="ref")
